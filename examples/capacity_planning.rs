//! Capacity planning: the workflow the paper's §IV-C2/Fig 12 motivates —
//! given a budget in A100-units, which decode-hardware mix maximizes
//! SLO-constrained throughput per dollar?
//!
//! ```sh
//! cargo run --release --example capacity_planning
//! ```

use tokensim::cluster::Simulation;
use tokensim::prelude::*;

/// Max request rate keeping >=90% SLO attainment (bisection).
fn max_goodput(build: &dyn Fn(f64) -> SimulationConfig) -> f64 {
    let attain = |qps: f64| {
        let r = Simulation::from_config(&build(qps))
            .expect("valid config")
            .run()
            .expect("workload must complete");
        (r.slo_attainment(), r.slo_throughput())
    };
    let (mut lo, mut hi, mut best) = (0.0f64, 4.0f64, 0.0f64);
    let mut res = attain(hi);
    let mut grow = 0;
    while res.0 >= 0.9 && grow < 8 {
        lo = hi;
        best = res.1;
        hi *= 2.0;
        res = attain(hi);
        grow += 1;
    }
    for _ in 0..6 {
        let mid = 0.5 * (lo + hi);
        let (a, g) = attain(mid);
        if a >= 0.9 {
            lo = mid;
            best = g;
        } else {
            hi = mid;
        }
    }
    best
}

fn main() {
    let model = ModelSpec::llama2_7b();
    let a100 = HardwareSpec::a100_80g();
    let workload = WorkloadSpec::mean_lengths(1500, 8.0, 128, 128);

    println!("decode-hardware shopping list (8 slots, 1xA100 prefill + 7 decode)\n");
    println!(
        "{:<22} {:>8} {:>14} {:>12}",
        "decode hardware", "price", "goodput req/s", "req/s per $"
    );

    for decode_hw in [
        HardwareSpec::a100_80g(),
        HardwareSpec::gddr6_aim(),
        HardwareSpec::v100_32g(),
        HardwareSpec::a100_quarter_flops(),
    ] {
        let price = a100.price + 7.0 * decode_hw.price;
        let hw = decode_hw.clone();
        let model2 = model.clone();
        let wl = workload.clone();
        let build = move |qps: f64| {
            let mut cfg = SimulationConfig::disaggregated(
                model2.clone(),
                HardwareSpec::a100_80g(),
                1,
                hw.clone(),
                7,
                wl.clone().with_qps(qps),
            );
            cfg.compute = ComputeSpec::new("table");
            cfg
        };
        let goodput = max_goodput(&build);
        println!(
            "{:<22} {:>8.2} {:>14.1} {:>12.2}",
            decode_hw.name,
            price,
            goodput,
            goodput / price
        );
    }

    println!(
        "\n(the paper's Finding 4: PIM decode devices are the cost-effective choice\n\
         under tight budgets, but slot limits keep A100s on top for peak throughput)"
    );
}
