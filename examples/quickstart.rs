//! Quickstart: simulate a vLLM-like single-A100 server on a
//! ShareGPT-like workload and read off the QoS metrics.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use tokensim::prelude::*;

fn main() {
    // 1. Describe the system: model + hardware + workload.
    let model = ModelSpec::llama2_7b();
    let hw = HardwareSpec::a100_80g();
    let workload = WorkloadSpec::sharegpt(2000, 16.0); // 2000 reqs @ 16 QPS

    // 2. A single unified worker with continuous batching (vLLM-like).
    let mut cfg = SimulationConfig::single_worker(model, hw, workload);
    // Use the AOT-compiled JAX/Pallas cost artifact when built
    // (`make artifacts`); it degrades to the bit-compatible analytic
    // mirror automatically otherwise.
    cfg.compute = ComputeSpec::new("table");
    cfg.sample_period = 0.5;

    // 3. Run to completion.
    let report = Simulation::from_config(&cfg)
        .expect("valid config")
        .run()
        .expect("workload must complete");

    // 4. Read the QoS metrics the paper's Figs 4-5 report.
    println!("{}", report.summary());
    let m = report.metrics();
    println!("\nthroughput : {:.2} req/s / {:.0} tok/s",
        m.request_throughput(), m.token_throughput());
    println!("latency    : p50 {:.3}s  p90 {:.3}s  p99 {:.3}s",
        m.latency_percentile(0.50),
        m.latency_percentile(0.90),
        m.latency_percentile(0.99));
    println!("ttft       : p50 {:.3}s  p99 {:.3}s",
        m.ttft_percentile(0.50), m.ttft_percentile(0.99));
    println!("normalized : {:.4} s/token", m.mean_normalized_latency());
    println!("slo        : {:.1}% attainment (TTFT<=15s, mTPOT<=0.3s)",
        100.0 * report.slo_attainment());

    println!("\nlatency CDF:");
    for (lat, frac) in m.latency_cdf().iter().step_by(m.len().max(10) / 10) {
        println!("  {frac:>5.2} <= {lat:.3}s");
    }

    println!("\nper-worker:");
    for w in &report.workers {
        println!(
            "  worker {} ({}): {} iterations, {:.1}% busy",
            w.id, w.hardware, w.iterations, 100.0 * w.utilization
        );
    }
}
