//! Multi-round chatbot serving with a cross-request KV memory pool
//! (CachedAttention / MemServe style): shows pool hit rates and the
//! latency effect of reusing conversation context instead of
//! re-prefilling it (the paper's Fig 14 mechanism).
//!
//! ```sh
//! cargo run --release --example memory_cache_chatbot
//! ```

use tokensim::cluster::Simulation;
use tokensim::prelude::*;
use tokensim::workload::ConversationSpec;

fn main() {
    let model = ModelSpec::llama2_7b();
    let hw = HardwareSpec::a100_80g();

    // chatbot: half single-round, half 2-7 rounds, ~5s think time
    let convs = ConversationSpec::chatbot(2000, 10.0, 128, 64).generate();
    let rounds: usize = convs.iter().map(|c| c.rounds.len()).sum();
    println!(
        "{} conversations / {} rounds, 128-token turns, 64-token replies @ 10 conv/s\n",
        convs.len(),
        rounds
    );

    for (name, pool) in [
        ("memory cache OFF", None),
        (
            "memory cache ON (800ns/block pool)",
            Some(PoolCacheConfig::with_capacity(2_000_000)),
        ),
    ] {
        let mut cfg = SimulationConfig::single_worker(
            model.clone(),
            hw.clone(),
            WorkloadSpec::fixed(1, 1.0, 8, 8), // unused stub for conversations
        );
        cfg.compute = ComputeSpec::new("table");
        cfg.pool_cache = pool;
        let report = Simulation::from_conversations(&cfg, &convs)
            .expect("valid config")
            .run()
            .expect("workload must complete");
        let m = report.metrics();
        println!("{name}:");
        println!(
            "  p50 {:.3}s  p99 {:.3}s  ttft-p99 {:.3}s  throughput {:.2} req/s",
            m.latency_percentile(0.50),
            m.latency_percentile(0.99),
            m.ttft_percentile(0.99),
            m.request_throughput(),
        );
        if report.pool_hits + report.pool_misses > 0 {
            println!(
                "  pool: {} hits / {} misses ({:.0}% hit rate), {} evictions",
                report.pool_hits,
                report.pool_misses,
                100.0 * report.pool_hits as f64
                    / (report.pool_hits + report.pool_misses) as f64,
                report.pool_evictions,
            );
            let cached: u64 = report
                .records
                .iter()
                .map(|r| r.cached_prefix as u64)
                .sum();
            println!("  prefill tokens served from the pool: {cached}");
        }
        println!();
    }
}
