//! Disaggregated prefill/decode serving (DistServe/Splitwise style):
//! compare a unified 8-GPU pool against P/D-split clusters on the same
//! workload, and show the KV-transfer traffic the communication model
//! accounts for.
//!
//! ```sh
//! cargo run --release --example disaggregated_serving
//! ```

use tokensim::prelude::*;

fn simulate(name: &str, cfg: &SimulationConfig) {
    let report = Simulation::from_config(cfg)
        .expect("valid config")
        .run()
        .expect("workload must complete");
    let m = report.metrics();
    println!(
        "{name:<28} {:>7.2} req/s  p99 {:>7.3}s  ttft-p99 {:>6.3}s  slo {:>5.1}%",
        m.request_throughput(),
        m.latency_percentile(0.99),
        m.ttft_percentile(0.99),
        100.0 * report.slo_attainment(),
    );
}

fn main() {
    let model = ModelSpec::llama2_7b();
    let a100 = HardwareSpec::a100_80g();
    let workload = WorkloadSpec::mean_lengths(3000, 24.0, 256, 128);

    println!("LLaMA2-7B, 8 devices, 256/128-token workload @ 24 QPS\n");

    // unified: every GPU does both phases
    let mut unified = SimulationConfig::single_worker(model.clone(), a100.clone(), workload.clone());
    unified.cluster.workers[0].quantity = 8;
    unified.compute = ComputeSpec::new("table");
    simulate("unified x8", &unified);

    // disaggregated splits over NVLink
    for (np, nd) in [(1u32, 7u32), (2, 6), (3, 5), (4, 4)] {
        let mut cfg = SimulationConfig::disaggregated(
            model.clone(),
            a100.clone(),
            np,
            a100.clone(),
            nd,
            workload.clone(),
        );
        cfg.compute = ComputeSpec::new("table");
        simulate(&format!("disaggregated P{np}-D{nd}"), &cfg);
    }

    // what the KV hand-off costs on a slower link
    println!("\nKV-transfer sensitivity (P2-D6):");
    for link in [LinkSpec::nvlink(), LinkSpec::pcie_gen4_x16(), LinkSpec::ethernet_100g()] {
        let mut cfg = SimulationConfig::disaggregated(
            model.clone(),
            a100.clone(),
            2,
            a100.clone(),
            6,
            workload.clone(),
        );
        cfg.compute = ComputeSpec::new("table");
        let name = link.name.clone();
        cfg.cluster.scheduler.interconnect = link;
        simulate(&format!("  over {name}"), &cfg);
    }
}
