"""Pure-jnp reference oracle for the TokenSim cost-model kernels.

These functions define the *semantics* that the Pallas kernels in
``roofline.py`` / ``attn_cost.py`` must reproduce bit-for-bit (up to float
associativity).  They are used by

  * ``python/tests/`` — pytest + hypothesis compare every kernel against
    the functions here;
  * ``model.py`` — a ``use_ref=True`` escape hatch builds the full L2
    iteration-cost model out of these instead of the Pallas kernels, which
    lets the AOT pipeline emit a kernel-free artifact for debugging.

Model parameter vector layout  (``MODEL_DIM`` entries, float32)::

    0: hidden        — hidden size h
    1: layers        — number of decoder layers
    2: heads         — attention heads
    3: kv_heads      — KV heads (GQA); == heads for MHA
    4: ffn           — MLP intermediate size (LLaMA counts gate+up once here)
    5: vocab         — vocabulary size
    6: dtype_bytes   — bytes per parameter / activation element
    7: tp            — tensor-parallel degree

Hardware parameter vector layout (``HW_DIM`` entries, float32)::

    0: peak_flops    — achievable FLOP/s (spec peak x efficiency)
    1: mem_bw        — HBM bandwidth, bytes/s
    2: op_overhead   — fixed per-operator launch overhead, seconds
    3: iter_overhead — fixed per-iteration framework overhead, seconds
    4: net_bw        — intra-node interconnect bandwidth, bytes/s (TP collectives)
    5: mem_cap       — device memory capacity, bytes (not used in timing)

Batch descriptor: two int-valued float32 vectors of length B
(``ctx[i]``, ``new[i]``): request *i* enters the iteration with ``ctx[i]``
tokens already in KV cache and computes ``new[i]`` new tokens this
iteration (prompt length during prefill, 1 during decode).  Empty slots are
all-zero.
"""

from __future__ import annotations

import jax.numpy as jnp

MODEL_DIM = 8
HW_DIM = 6

# Paged-attention KV reads are gather-style (block tables) and reach only
# a fraction of streaming bandwidth; the cost model charges attention
# bytes at ``mem_bw * ATTN_GATHER_EFF``, expressed as a byte inflation so
# the roofline keeps a single bandwidth term. This is the
# block-granularity memory effect the paper credits for TokenSim's
# accuracy ("we support block-granularity simulation").
ATTN_GATHER_EFF = 0.7

# Operator slots in the per-op outputs of the iteration cost model.  The
# rust side mirrors this enum in `compute/ops.rs`.
OP_NAMES = (
    "embed",        # 0  token embedding gather (bandwidth)
    "qkv_gemm",     # 1  fused QKV projection
    "attention",    # 2  QK^T + AV over the KV cache (paged attention)
    "softmax",      # 3  attention softmax (bandwidth)
    "out_gemm",     # 4  attention output projection
    "mlp_up",       # 5  gate+up projections
    "mlp_down",     # 6  down projection
    "layernorm",    # 7  2x RMS/LayerNorm per layer (bandwidth)
    "allreduce",    # 8  2x tensor-parallel all-reduce per layer
    "logits",       # 9  LM-head GEMM for sampled rows (once, not per layer)
)
NUM_OPS = len(OP_NAMES)


def roofline_time_ref(flops, bytes_moved, peak_flops, mem_bw, op_overhead):
    """Roofline execution-time estimate for a batch of operators.

    ``time = max(flops / peak_flops, bytes / mem_bw) + overhead`` with the
    convention that an all-zero operator (padding slot) costs exactly 0 —
    including no launch overhead.
    """
    flops = jnp.asarray(flops, jnp.float32)
    bytes_moved = jnp.asarray(bytes_moved, jnp.float32)
    t = jnp.maximum(flops / peak_flops, bytes_moved / mem_bw)
    nonzero = (flops > 0.0) | (bytes_moved > 0.0)
    return jnp.where(nonzero, t + op_overhead, 0.0)


def attn_cost_ref(ctx, new, model):
    """Per-request attention FLOPs / KV-bytes / score-elements.

    For request *i* with ``c = ctx[i]`` cached tokens and ``n = new[i]``
    new tokens the attention operator this iteration does (per single
    layer — the caller multiplies by ``layers``):

      * score GEMM   QK^T : 2 * n * (c + n) * h          FLOPs
      * value GEMM   AV   : 2 * n * (c + n) * h          FLOPs
      * KV-cache traffic  : read 2*(c+n)*h_kv*dtype, write 2*n*h_kv*dtype
      * Q read / out write: 2 * n * h * dtype
      * score elements    : n * (c + n) * heads   (softmax traffic)

    where ``h_kv = h * kv_heads / heads``.  Returns float32 arrays
    ``(flops[B], kv_bytes[B], score_elems[B])``; padding slots yield zero.
    """
    ctx = jnp.asarray(ctx, jnp.float32)
    new = jnp.asarray(new, jnp.float32)
    h = model[0]
    heads = model[2]
    kv_heads = model[3]
    dtype = model[6]
    tp = model[7]

    total = ctx + new
    h_kv = h * (kv_heads / heads)
    flops = 4.0 * new * total * h / tp
    kv_bytes = (
        (2.0 * total * h_kv / ATTN_GATHER_EFF + 2.0 * new * h_kv + 2.0 * new * h)
        * dtype / tp
    )
    score_elems = new * total * heads / tp
    return flops, kv_bytes, score_elems


def iter_ops_ref(ctx, new, model):
    """Assemble the per-iteration operator table (FLOPs, bytes) x NUM_OPS.

    Per-layer operators are reported *per single layer*; the ``layers``
    multiplication happens in :func:`iter_cost_ref` so that per-op outputs
    stay interpretable.  Returns ``(flops[NUM_OPS], bytes[NUM_OPS])``.
    """
    ctx = jnp.asarray(ctx, jnp.float32)
    new = jnp.asarray(new, jnp.float32)
    h = model[0]
    heads = model[2]
    kv_heads = model[3]
    ffn = model[4]
    vocab = model[5]
    dtype = model[6]
    tp = model[7]

    T = jnp.sum(new)                            # new tokens this iteration
    R = jnp.sum((new > 0).astype(jnp.float32))  # active requests
    g = kv_heads / heads
    qkv_out = h * (1.0 + 2.0 * g)

    attn_f, attn_b, scores = attn_cost_ref(ctx, new, model)
    attn_flops = jnp.sum(attn_f)
    attn_bytes = jnp.sum(attn_b)
    score_elems = jnp.sum(scores)

    zeros = jnp.zeros((), jnp.float32)

    def gemm(m_rows, k_dim, n_cols):
        f = 2.0 * m_rows * k_dim * n_cols / tp
        b = (k_dim * n_cols / tp + m_rows * k_dim + m_rows * n_cols / tp) * dtype
        return f, b

    qkv_f, qkv_b = gemm(T, h, qkv_out)
    out_f, out_b = gemm(T, h, h)
    up_f, up_b = gemm(T, h, 2.0 * ffn)    # gate + up fused
    down_f, down_b = gemm(T, ffn, h)
    logits_f, logits_b = gemm(R, h, vocab)

    embed_b = T * h * dtype
    softmax_f = 5.0 * score_elems
    softmax_b = 2.0 * score_elems * dtype
    ln_f = 2.0 * 4.0 * T * h
    ln_b = 2.0 * 2.0 * T * h * dtype
    # ring all-reduce of the layer activation, twice per layer
    ar_b = jnp.where(tp > 1.0, 2.0 * 2.0 * (tp - 1.0) / tp * T * h * dtype, zeros)

    flops = jnp.stack([
        zeros, qkv_f, attn_flops, softmax_f, out_f,
        up_f, down_f, ln_f, zeros, logits_f,
    ])
    bytes_ = jnp.stack([
        embed_b, qkv_b, attn_bytes, softmax_b, out_b,
        up_b, down_b, ln_b, ar_b, logits_b,
    ])
    return flops, bytes_


# Ops that run once per *iteration* rather than once per layer.
PER_ITER_OPS = jnp.array([1.0, 0, 0, 0, 0, 0, 0, 0, 0, 1.0], jnp.float32)


def iter_cost_ref(ctx, new, model, hw):
    """End-to-end per-iteration latency model (the L2 semantics).

    Returns ``(iter_time, op_times[NUM_OPS], per_req_attn[B])`` where
    ``op_times`` are single-instance times (one layer / one call) and
    ``iter_time = layers * sum(per_layer ops) + once ops + iter_overhead``.
    The all-reduce op uses ``net_bw`` rather than ``mem_bw``.
    """
    model = jnp.asarray(model, jnp.float32)
    hw = jnp.asarray(hw, jnp.float32)
    layers = model[1]
    peak, bw, op_oh, iter_oh, net_bw = hw[0], hw[1], hw[2], hw[3], hw[4]

    flops, bytes_ = iter_ops_ref(ctx, new, model)
    # allreduce goes over the interconnect; everything else over HBM
    eff_bw = jnp.where(
        jnp.arange(NUM_OPS) == OP_NAMES.index("allreduce"), net_bw, bw
    )
    op_times = roofline_time_ref(flops, bytes_, peak, eff_bw, op_oh)

    per_layer = jnp.sum(op_times * (1.0 - PER_ITER_OPS))
    per_iter = jnp.sum(op_times * PER_ITER_OPS)
    T = jnp.sum(jnp.asarray(new, jnp.float32))
    iter_time = jnp.where(
        T > 0.0, layers * per_layer + per_iter + iter_oh, 0.0
    )

    attn_f, attn_b, _ = attn_cost_ref(ctx, new, model)
    per_req = roofline_time_ref(attn_f, attn_b, peak, bw, op_oh)
    return iter_time, op_times, per_req


def xfer_cost_ref(sizes, link):
    """Communication-model reference.

    ``link = [bandwidth B/s, latency s, buffer_depth]``.  For a train of
    block transfers of ``sizes[i]`` bytes (0 = padding):

      * sequential: each transfer waits for the previous one,
        ``t_seq = sum(latency + size/bw)``;
      * overlapped: a preload buffer of depth ``d`` pipelines transfers, so
        only ``ceil(n/d)`` latencies are exposed,
        ``t_ovl = ceil(n / d) * latency + sum(size)/bw``.

    Returns ``(t_seq, t_ovl, per_block[B])``.
    """
    sizes = jnp.asarray(sizes, jnp.float32)
    bw, lat, depth = link[0], link[1], jnp.maximum(link[2], 1.0)
    active = (sizes > 0.0).astype(jnp.float32)
    per_block = active * lat + sizes / bw
    n = jnp.sum(active)
    t_seq = jnp.sum(per_block)
    t_ovl = jnp.ceil(n / depth) * lat + jnp.sum(sizes) / bw
    return t_seq, t_ovl, per_block
