"""Pallas kernels for the TokenSim compute-cost hot spot (L1).

Three kernels, all elementwise-plus-reduction shaped, all written
TPU-style even though this environment executes them through
``interpret=True`` on the CPU PJRT plugin (real-TPU lowering would emit a
Mosaic custom-call the CPU client cannot run):

* :func:`roofline_times` — the core roofline evaluator
  ``t = max(flops/peak, bytes/bw) + overhead`` over a padded
  ``(rows, 128)`` tile grid.  Used for the operator table, the
  per-request attention times, and cross-validated against
  ``ref.roofline_time_ref``.
* :func:`attn_descriptors` — per-request attention FLOPs / KV bytes /
  score elements from ``(ctx, new)`` batch descriptors.
* :func:`xfer_block_times` — per-block link transfer times for the
  communication model.

Layout notes (the §Hardware-Adaptation story): descriptors are padded to
lane width 128 and sublane multiples of 8, so a block is a whole number of
``(8, 128)`` float32 VMEM tiles.  All kernels are single-pass, fused
elementwise chains on the VPU; reductions happen in the surrounding jnp
(XLA fuses them into the same HLO module at AOT time).  VMEM footprint for
the default ``B = 1024`` batch is ``8 x 128 x 4 B`` per operand — a few KiB,
vastly below the ~16 MiB VMEM budget, so no double-buffering pipeline is
needed and the grid is a single program instance per 8-row stripe.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
SUBLANES = 8
TILE_ELEMS = LANES * SUBLANES

# interpret=True is mandatory on this CPU-only image; see module docstring.
INTERPRET = True


def pad_to_tiles(x, fill=0.0):
    """Pad a 1-D float32 array to a whole number of (8, 128) tiles.

    Returns ``(x2d, orig_len)`` where ``x2d`` has shape ``(rows, 128)``
    with ``rows % 8 == 0``.
    """
    x = jnp.asarray(x, jnp.float32).reshape(-1)
    n = x.shape[0]
    padded = ((n + TILE_ELEMS - 1) // TILE_ELEMS) * TILE_ELEMS
    x = jnp.pad(x, (0, padded - n), constant_values=fill)
    return x.reshape(-1, LANES), n


def unpad(x2d, n):
    """Inverse of :func:`pad_to_tiles` (values only)."""
    return x2d.reshape(-1)[:n]


def _roofline_body(flops_ref, bytes_ref, effbw_ref, scal_ref, out_ref):
    """t = max(f/peak, b/bw) + overhead, with all-zero slots costing 0."""
    f = flops_ref[...]
    b = bytes_ref[...]
    bw = effbw_ref[...]
    peak = scal_ref[0, 0]
    overhead = scal_ref[0, 1]
    t = jnp.maximum(f / peak, b / bw)
    nonzero = (f > 0.0) | (b > 0.0)
    out_ref[...] = jnp.where(nonzero, t + overhead, 0.0)


@functools.partial(jax.named_call, name="roofline_times")
def roofline_times(flops, bytes_moved, eff_bw, peak_flops, op_overhead):
    """Roofline time for a batch of operators (Pallas kernel).

    ``flops``, ``bytes_moved`` and ``eff_bw`` are 1-D arrays of the same
    length; ``eff_bw`` carries a *per-operator* bandwidth so the caller can
    route e.g. the all-reduce over the interconnect instead of HBM.
    Semantics match :func:`..kernels.ref.roofline_time_ref`.
    """
    f2, n = pad_to_tiles(flops)
    b2, _ = pad_to_tiles(bytes_moved)
    # padding bandwidth with 1.0 avoids 0/0 in padded slots
    w2, _ = pad_to_tiles(eff_bw, fill=1.0)
    scal = jnp.zeros((1, LANES), jnp.float32)
    scal = scal.at[0, 0].set(peak_flops).at[0, 1].set(op_overhead)
    rows = f2.shape[0]
    grid = (rows // SUBLANES,)
    block = pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0))
    scal_block = pl.BlockSpec((1, LANES), lambda i: (0, 0))
    out = pl.pallas_call(
        _roofline_body,
        grid=grid,
        in_specs=[block, block, block, scal_block],
        out_specs=block,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=INTERPRET,
    )(f2, b2, w2, scal)
    return unpad(out, n)


def _attn_body(ctx_ref, new_ref, model_ref, f_ref, kv_ref, s_ref):
    """Per-request attention descriptors; see ref.attn_cost_ref."""
    from .ref import ATTN_GATHER_EFF

    c = ctx_ref[...]
    n = new_ref[...]
    h = model_ref[0, 0]
    heads = model_ref[0, 2]
    kv_heads = model_ref[0, 3]
    dtype = model_ref[0, 6]
    tp = model_ref[0, 7]

    total = c + n
    h_kv = h * (kv_heads / heads)
    f_ref[...] = 4.0 * n * total * h / tp
    kv_ref[...] = (
        (2.0 * total * h_kv / ATTN_GATHER_EFF + 2.0 * n * h_kv + 2.0 * n * h)
        * dtype / tp
    )
    s_ref[...] = n * total * heads / tp


@functools.partial(jax.named_call, name="attn_descriptors")
def attn_descriptors(ctx, new, model):
    """Per-request attention (flops, kv_bytes, score_elems) — Pallas kernel.

    Semantics match :func:`..kernels.ref.attn_cost_ref`.
    """
    c2, n_req = pad_to_tiles(ctx)
    n2, _ = pad_to_tiles(new)
    model_row = jnp.zeros((1, LANES), jnp.float32)
    model_row = model_row.at[0, : model.shape[0]].set(
        jnp.asarray(model, jnp.float32)
    )
    rows = c2.shape[0]
    grid = (rows // SUBLANES,)
    block = pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0))
    scal_block = pl.BlockSpec((1, LANES), lambda i: (0, 0))
    shape = jax.ShapeDtypeStruct((rows, LANES), jnp.float32)
    f2, kv2, s2 = pl.pallas_call(
        _attn_body,
        grid=grid,
        in_specs=[block, block, scal_block],
        out_specs=[block, block, block],
        out_shape=[shape, shape, shape],
        interpret=INTERPRET,
    )(c2, n2, model_row)
    return unpad(f2, n_req), unpad(kv2, n_req), unpad(s2, n_req)


def _xfer_body(sizes_ref, link_ref, out_ref):
    """Per-block transfer time: latency + size/bw for non-empty blocks."""
    s = sizes_ref[...]
    bw = link_ref[0, 0]
    lat = link_ref[0, 1]
    active = (s > 0.0).astype(jnp.float32)
    out_ref[...] = active * lat + s / bw


@functools.partial(jax.named_call, name="xfer_block_times")
def xfer_block_times(sizes, link):
    """Per-block link transfer times — Pallas kernel.

    ``link = [bandwidth, latency, buffer_depth]``; semantics match the
    ``per_block`` output of :func:`..kernels.ref.xfer_cost_ref`.
    """
    s2, n = pad_to_tiles(sizes)
    link_row = jnp.zeros((1, LANES), jnp.float32)
    link_row = link_row.at[0, :3].set(jnp.asarray(link, jnp.float32)[:3])
    rows = s2.shape[0]
    grid = (rows // SUBLANES,)
    block = pl.BlockSpec((SUBLANES, LANES), lambda i: (i, 0))
    scal_block = pl.BlockSpec((1, LANES), lambda i: (0, 0))
    out = pl.pallas_call(
        _xfer_body,
        grid=grid,
        in_specs=[block, scal_block],
        out_specs=block,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=INTERPRET,
    )(s2, link_row)
    return unpad(out, n)
