"""AOT pipeline: lower the L2/L1 cost model to HLO text artifacts.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.  Lowering goes
stablehlo -> XlaComputation (``return_tuple=True``) -> ``as_hlo_text()``;
the rust loader unwraps the 1-tuple.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as m

ARTIFACT_VERSION = 3


def to_hlo_text(lowered) -> str:
    """stablehlo -> XLA computation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_iter_cost(batch_slots: int) -> str:
    spec_b = jax.ShapeDtypeStruct((batch_slots,), jnp.float32)
    spec_m = jax.ShapeDtypeStruct((m.MODEL_DIM,), jnp.float32)
    spec_h = jax.ShapeDtypeStruct((m.HW_DIM,), jnp.float32)
    lowered = jax.jit(m.iter_cost_flat).lower(spec_b, spec_b, spec_m, spec_h)
    return to_hlo_text(lowered)


def lower_xfer_cost(batch_slots: int) -> str:
    spec_s = jax.ShapeDtypeStruct((batch_slots,), jnp.float32)
    spec_l = jax.ShapeDtypeStruct((3,), jnp.float32)
    lowered = jax.jit(m.xfer_cost_flat).lower(spec_s, spec_l)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch-slots", type=int, default=m.BATCH_SLOTS)
    ap.add_argument(
        "--out", default=None,
        help="legacy single-file mode: write only iter_cost HLO here",
    )
    args = ap.parse_args()

    if args.out is not None:
        text = lower_iter_cost(args.batch_slots)
        pathlib.Path(args.out).write_text(text)
        print(f"wrote {len(text)} chars to {args.out}")
        return

    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    artifacts = {}
    for name, text in [
        ("iter_cost", lower_iter_cost(args.batch_slots)),
        ("xfer_cost", lower_xfer_cost(args.batch_slots)),
    ]:
        path = out / f"{name}.hlo.txt"
        path.write_text(text)
        artifacts[name] = {
            "file": path.name,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "chars": len(text),
        }
        print(f"wrote {len(text):>8} chars  {path}")

    manifest = {
        "version": ARTIFACT_VERSION,
        "batch_slots": args.batch_slots,
        "model_dim": m.MODEL_DIM,
        "hw_dim": m.HW_DIM,
        "num_ops": m.NUM_OPS,
        "op_names": list(__import__(
            "compile.kernels.ref", fromlist=["OP_NAMES"]
        ).OP_NAMES),
        "outputs": {
            "iter_cost": "[iter_time, op_times[num_ops], per_req_attn[batch_slots]]",
            "xfer_cost": "[t_seq, t_ovl, per_block[batch_slots]]",
        },
        "artifacts": artifacts,
        "jax_version": jax.__version__,
    }
    (out / "manifest.json").write_text(json.dumps(manifest, indent=2) + "\n")
    print(f"wrote manifest ({out / 'manifest.json'})")


if __name__ == "__main__":
    main()
