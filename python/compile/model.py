"""L2: the TokenSim compute-cost model as a JAX computation.

This is the "compute simulator, like GenZ" box of the paper's Fig. 1,
re-expressed as a single jax function built on the Pallas kernels in
``kernels/roofline.py``.  ``aot.py`` lowers it once to HLO text; the rust
coordinator (L3) loads the artifact through PJRT and evaluates it on the
simulation hot path — Python never runs at simulation time.

Two public computations:

* :func:`iter_cost` — per-iteration latency of a transformer worker given
  the batch composition ``(ctx, new)``, model parameters and hardware
  parameters.  Exact semantics documented in ``kernels/ref.py``.
* :func:`xfer_cost` — communication-model times for a train of KV-cache
  block transfers over a link (sequential vs. overlapped schedules).

Both have a pure-jnp twin in ``kernels/ref.py``; pytest asserts
equivalence, and the rust test-suite cross-validates its own analytic
mirror against the loaded artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import ref
from .kernels.roofline import attn_descriptors, roofline_times, xfer_block_times

# Default number of batch-descriptor slots in the AOT artifact.  1024 slots
# = 8 full (8, 128) float32 VMEM tiles per operand and comfortably exceeds
# any realistic max-batched-requests setting.
BATCH_SLOTS = 1024

MODEL_DIM = ref.MODEL_DIM
HW_DIM = ref.HW_DIM
NUM_OPS = ref.NUM_OPS


def iter_cost(ctx, new, model, hw, *, use_ref: bool = False):
    """Per-iteration latency model.

    Args:
      ctx: float32[B] — tokens already in KV cache per slot (0 = empty).
      new: float32[B] — new tokens computed this iteration per slot.
      model: float32[MODEL_DIM] — see ``kernels/ref.py``.
      hw: float32[HW_DIM] — see ``kernels/ref.py``.
      use_ref: build from the pure-jnp oracle instead of Pallas kernels
        (debugging / kernel-free artifact).

    Returns:
      ``(iter_time, op_times[NUM_OPS], per_req_attn[B])``.
    """
    if use_ref:
        return ref.iter_cost_ref(ctx, new, model, hw)

    ctx = jnp.asarray(ctx, jnp.float32)
    new = jnp.asarray(new, jnp.float32)
    model = jnp.asarray(model, jnp.float32)
    hw = jnp.asarray(hw, jnp.float32)

    h = model[0]
    layers = model[1]
    heads = model[2]
    kv_heads = model[3]
    ffn = model[4]
    vocab = model[5]
    dtype = model[6]
    tp = model[7]
    peak, bw, op_oh, iter_oh, net_bw = hw[0], hw[1], hw[2], hw[3], hw[4]

    # ---- L1 kernel: per-request attention descriptors ------------------
    attn_f, attn_b, attn_s = attn_descriptors(ctx, new, model)
    attn_flops = jnp.sum(attn_f)
    attn_bytes = jnp.sum(attn_b)
    score_elems = jnp.sum(attn_s)

    # ---- operator table (same formulas as ref.iter_ops_ref) ------------
    T = jnp.sum(new)
    R = jnp.sum((new > 0).astype(jnp.float32))
    g = kv_heads / heads
    qkv_out = h * (1.0 + 2.0 * g)
    zeros = jnp.zeros((), jnp.float32)

    def gemm(m_rows, k_dim, n_cols):
        f = 2.0 * m_rows * k_dim * n_cols / tp
        b = (k_dim * n_cols / tp + m_rows * k_dim + m_rows * n_cols / tp) * dtype
        return f, b

    qkv_f, qkv_b = gemm(T, h, qkv_out)
    out_f, out_b = gemm(T, h, h)
    up_f, up_b = gemm(T, h, 2.0 * ffn)
    down_f, down_b = gemm(T, ffn, h)
    logits_f, logits_b = gemm(R, h, vocab)

    embed_b = T * h * dtype
    softmax_f = 5.0 * score_elems
    softmax_b = 2.0 * score_elems * dtype
    ln_f = 2.0 * 4.0 * T * h
    ln_b = 2.0 * 2.0 * T * h * dtype
    ar_b = jnp.where(tp > 1.0, 2.0 * 2.0 * (tp - 1.0) / tp * T * h * dtype, zeros)

    op_flops = jnp.stack([
        zeros, qkv_f, attn_flops, softmax_f, out_f,
        up_f, down_f, ln_f, zeros, logits_f,
    ])
    op_bytes = jnp.stack([
        embed_b, qkv_b, attn_bytes, softmax_b, out_b,
        up_b, down_b, ln_b, ar_b, logits_b,
    ])
    eff_bw = jnp.where(
        jnp.arange(NUM_OPS) == ref.OP_NAMES.index("allreduce"), net_bw, bw
    )

    # ---- L1 kernel: roofline over the op table + per-request attention -
    op_times = roofline_times(op_flops, op_bytes, eff_bw, peak, op_oh)
    per_req = roofline_times(
        attn_f, attn_b, jnp.full_like(attn_f, bw), peak, op_oh
    )

    per_layer = jnp.sum(op_times * (1.0 - ref.PER_ITER_OPS))
    per_iter = jnp.sum(op_times * ref.PER_ITER_OPS)
    iter_time = jnp.where(T > 0.0, layers * per_layer + per_iter + iter_oh, 0.0)
    return iter_time, op_times, per_req


def xfer_cost(sizes, link, *, use_ref: bool = False):
    """Communication-model times for a train of block transfers.

    Args:
      sizes: float32[B] — bytes per block transfer (0 = padding).
      link: float32[3] — ``[bandwidth B/s, latency s, buffer_depth]``.

    Returns:
      ``(t_seq, t_ovl, per_block[B])`` — see ``kernels/ref.xfer_cost_ref``.
    """
    if use_ref:
        return ref.xfer_cost_ref(sizes, link)
    sizes = jnp.asarray(sizes, jnp.float32)
    link = jnp.asarray(link, jnp.float32)
    per_block = xfer_block_times(sizes, link)
    depth = jnp.maximum(link[2], 1.0)
    n = jnp.sum((sizes > 0.0).astype(jnp.float32))
    t_seq = jnp.sum(per_block)
    t_ovl = jnp.ceil(n / depth) * link[1] + jnp.sum(sizes) / link[0]
    return t_seq, t_ovl, per_block


def iter_cost_flat(ctx, new, model, hw):
    """AOT entry point: flatten outputs into one float32 vector.

    Layout: ``[iter_time, op_times[NUM_OPS], per_req_attn[B]]`` — a single
    array keeps the rust unpacking trivial (``to_tuple1`` + ``to_vec``).
    """
    iter_time, op_times, per_req = iter_cost(ctx, new, model, hw)
    return (jnp.concatenate([iter_time[None], op_times, per_req]),)


def xfer_cost_flat(sizes, link):
    """AOT entry point: ``[t_seq, t_ovl, per_block[B]]``."""
    t_seq, t_ovl, per_block = xfer_cost(sizes, link)
    return (jnp.concatenate([t_seq[None], t_ovl[None], per_block]),)
