"""L2 iteration-cost model: Pallas path vs ref oracle + physics sanity."""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model as m
from compile.kernels import ref


def _cmp(ctx, new, model, hw, rtol=2e-6):
    got = m.iter_cost(ctx, new, model, hw)
    want = ref.iter_cost_ref(ctx, new, model, hw)
    for g, w, name in zip(got, want, ["iter_time", "op_times", "per_req"]):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=rtol, err_msg=name)


def test_matches_ref_decode_batch(model_vec, hw_vec, rng):
    ctx = rng.integers(16, 2048, 64).astype(np.float32)
    new = np.ones(64, np.float32)
    _cmp(ctx, new, model_vec, hw_vec)


def test_matches_ref_prefill(model_vec, hw_vec):
    ctx = np.zeros(4, np.float32)
    new = np.array([512, 128, 1024, 32], np.float32)
    _cmp(ctx, new, model_vec, hw_vec)


def test_matches_ref_mixed(model_vec, hw_vec, rng):
    n = 200
    ctx = rng.integers(0, 4096, n).astype(np.float32)
    new = np.where(rng.random(n) < 0.9, 1, rng.integers(16, 512, n)).astype(
        np.float32
    )
    ctx[::7] = 0
    new[::7] = 0  # empty slots
    _cmp(ctx, new, model_vec, hw_vec)


def test_empty_batch_costs_zero(model_vec, hw_vec):
    t, ops, per = m.iter_cost(
        np.zeros(8, np.float32), np.zeros(8, np.float32), model_vec, hw_vec
    )
    assert float(t) == 0.0
    assert (np.asarray(per) == 0).all()


def test_prefill_compute_bound(model_vec, hw_vec):
    """A 2048-token prefill must be compute-dominated: doubling bandwidth
    barely changes latency; doubling FLOPS nearly halves it."""
    ctx = np.zeros(1, np.float32)
    new = np.array([2048.0], np.float32)
    t0, _, _ = m.iter_cost(ctx, new, model_vec, hw_vec)
    hw_bw = hw_vec.copy()
    hw_bw[1] *= 2
    t_bw, _, _ = m.iter_cost(ctx, new, model_vec, hw_bw)
    hw_fl = hw_vec.copy()
    hw_fl[0] *= 2
    t_fl, _, _ = m.iter_cost(ctx, new, model_vec, hw_fl)
    assert float(t_bw) > 0.95 * float(t0)
    assert float(t_fl) < 0.62 * float(t0)


def test_decode_memory_bound(model_vec, hw_vec):
    """Single-token decode must be bandwidth-dominated."""
    ctx = np.full(8, 512.0, np.float32)
    new = np.ones(8, np.float32)
    t0, _, _ = m.iter_cost(ctx, new, model_vec, hw_vec)
    hw_bw = hw_vec.copy()
    hw_bw[1] *= 2
    t_bw, _, _ = m.iter_cost(ctx, new, model_vec, hw_bw)
    hw_fl = hw_vec.copy()
    hw_fl[0] *= 2
    t_fl, _, _ = m.iter_cost(ctx, new, model_vec, hw_fl)
    assert float(t_bw) < 0.75 * float(t0)
    assert float(t_fl) > 0.9 * float(t0)


def test_batching_decode_is_cheaper_than_serial(model_vec, hw_vec):
    """One batched decode iteration of 32 requests must be far cheaper
    than 32 separate single-request iterations (weight reuse)."""
    ctx = np.full(32, 256.0, np.float32)
    new = np.ones(32, np.float32)
    t_batch, _, _ = m.iter_cost(ctx, new, model_vec, hw_vec)
    t_one, _, _ = m.iter_cost(
        ctx[:1], new[:1], model_vec, hw_vec
    )
    assert float(t_batch) < 0.2 * (32 * float(t_one))


def test_iter_time_monotone_in_context(model_vec, hw_vec):
    times = []
    for c in [128, 512, 2048, 8192]:
        t, _, _ = m.iter_cost(
            np.full(16, float(c), np.float32),
            np.ones(16, np.float32),
            model_vec,
            hw_vec,
        )
        times.append(float(t))
    assert all(a < b for a, b in zip(times, times[1:]))


def test_flat_layout(model_vec, hw_vec, rng):
    n = m.BATCH_SLOTS
    ctx = rng.integers(0, 1024, n).astype(np.float32)
    new = (rng.random(n) < 0.3).astype(np.float32)
    (flat,) = m.iter_cost_flat(ctx, new, model_vec, hw_vec)
    t, ops, per = m.iter_cost(ctx, new, model_vec, hw_vec)
    assert flat.shape == (1 + m.NUM_OPS + n,)
    assert_allclose(float(flat[0]), float(t), rtol=1e-6)
    assert_allclose(np.asarray(flat[1 : 1 + m.NUM_OPS]), np.asarray(ops), rtol=1e-6)
    assert_allclose(np.asarray(flat[1 + m.NUM_OPS :]), np.asarray(per), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 512),
    tp=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_matches_ref(n, tp, seed):
    model = np.array([4096, 32, 32, 32, 11008, 32000, 2, tp], np.float32)
    hw = np.array(
        [312e12 * 0.55, 2.039e12, 4.5e-6, 2.2e-4, 300e9, 80e9], np.float32
    )
    rng = np.random.default_rng(seed)
    ctx = rng.integers(0, 4096, n).astype(np.float32)
    new = rng.integers(0, 64, n).astype(np.float32)
    _cmp(ctx, new, model, hw)
