import os
import sys

# Make `compile` importable when pytest is run from python/ or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0xC0FFEE)


def llama2_7b_model():
    """MODEL vector for LLaMA2-7B (tp=1, fp16)."""
    return np.array([4096, 32, 32, 32, 11008, 32000, 2, 1], np.float32)


def a100_hw():
    """HW vector for an A100-80G: 312 TF peak x 0.55 eff, 2.039 TB/s."""
    return np.array(
        [312e12 * 0.55, 2.039e12, 4.5e-6, 2.2e-4, 300e9, 80e9], np.float32
    )


@pytest.fixture
def model_vec():
    return llama2_7b_model()


@pytest.fixture
def hw_vec():
    return a100_hw()
