"""AOT lowering: HLO text artifacts are well-formed and deterministic."""

import json
import subprocess
import sys
import pathlib

import pytest

from compile import aot, model as m


@pytest.fixture(scope="module")
def small_iter_hlo():
    return aot.lower_iter_cost(batch_slots=128)


def test_iter_cost_lowers(small_iter_hlo):
    assert "HloModule" in small_iter_hlo
    # 4 params: ctx, new, model, hw
    assert "f32[128]" in small_iter_hlo
    assert f"f32[{m.MODEL_DIM}]" in small_iter_hlo


def test_iter_cost_output_is_tuple(small_iter_hlo):
    # return_tuple=True -> ROOT is a tuple of one flat vector
    flat_len = 1 + m.NUM_OPS + 128
    assert f"f32[{flat_len}]" in small_iter_hlo


def test_xfer_cost_lowers():
    text = aot.lower_xfer_cost(batch_slots=128)
    assert "HloModule" in text
    assert "f32[130]" in text  # t_seq, t_ovl, per_block[128]


def test_lowering_deterministic():
    a = aot.lower_iter_cost(batch_slots=64)
    b = aot.lower_iter_cost(batch_slots=64)
    assert a == b


def test_no_custom_calls(small_iter_hlo):
    """interpret=True must lower pallas to plain HLO (no Mosaic
    custom-call) or the rust CPU PJRT client cannot execute it."""
    assert "custom-call" not in small_iter_hlo.lower()


def test_cli_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [
            sys.executable, "-m", "compile.aot",
            "--out-dir", str(out), "--batch-slots", "128",
        ],
        check=True,
        cwd=str(pathlib.Path(__file__).resolve().parents[1]),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["batch_slots"] == 128
    assert manifest["num_ops"] == m.NUM_OPS
    for name, entry in manifest["artifacts"].items():
        assert (out / entry["file"]).exists(), name
