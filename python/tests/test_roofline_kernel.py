"""L1 Pallas roofline kernel vs pure-jnp oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.roofline import pad_to_tiles, roofline_times, unpad


def _cmp(flops, bytes_, bw, peak, oh):
    got = np.asarray(roofline_times(flops, bytes_, bw, peak, oh))
    want = np.asarray(
        ref.roofline_time_ref(flops, bytes_, peak, np.asarray(bw), oh)
    )
    assert_allclose(got, want, rtol=1e-6, atol=0)


def test_basic_compute_bound():
    flops = np.array([1e12, 2e12], np.float32)
    bytes_ = np.array([1e6, 1e6], np.float32)
    bw = np.full(2, 2e12, np.float32)
    _cmp(flops, bytes_, bw, 1e12, 1e-6)


def test_basic_memory_bound():
    flops = np.array([1e6], np.float32)
    bytes_ = np.array([4e12], np.float32)
    _cmp(flops, bytes_, np.full(1, 2e12, np.float32), 1e15, 0.0)


def test_zero_slot_costs_zero():
    flops = np.array([0.0, 1e12, 0.0], np.float32)
    bytes_ = np.array([0.0, 1e9, 0.0], np.float32)
    bw = np.full(3, 1e12, np.float32)
    got = np.asarray(roofline_times(flops, bytes_, bw, 1e12, 1e-3))
    assert got[0] == 0.0 and got[2] == 0.0
    assert got[1] > 0.0


def test_overhead_added_once():
    got = np.asarray(
        roofline_times(
            np.array([1e12], np.float32),
            np.array([0.0], np.float32),
            np.array([1e12], np.float32),
            1e12,
            0.5,
        )
    )
    assert_allclose(got, [1.5], rtol=1e-6)


def test_per_element_bandwidth():
    """eff_bw is applied per element (allreduce routing)."""
    flops = np.zeros(2, np.float32)
    bytes_ = np.array([1e9, 1e9], np.float32)
    bw = np.array([1e9, 2e9], np.float32)
    got = np.asarray(roofline_times(flops, bytes_, bw, 1e12, 0.0))
    assert_allclose(got, [1.0, 0.5], rtol=1e-6)


def test_large_batch_multi_tile():
    rng = np.random.default_rng(7)
    n = 5000  # spans several (8,128) tiles with ragged padding
    flops = rng.uniform(0, 1e13, n).astype(np.float32)
    bytes_ = rng.uniform(0, 1e10, n).astype(np.float32)
    bw = rng.uniform(1e11, 2e12, n).astype(np.float32)
    _cmp(flops, bytes_, bw, 3e14, 5e-6)


@settings(max_examples=50, deadline=None)
@given(
    n=st.integers(1, 700),
    peak=st.floats(1e9, 1e15),
    oh=st.floats(0, 1e-3),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shapes_and_values(n, peak, oh, seed):
    rng = np.random.default_rng(seed)
    flops = rng.uniform(0, 1e14, n).astype(np.float32)
    bytes_ = rng.uniform(0, 1e11, n).astype(np.float32)
    # sprinkle padding-style zeros
    mask = rng.random(n) < 0.2
    flops[mask] = 0.0
    bytes_[mask] = 0.0
    bw = rng.uniform(1e10, 3e12, n).astype(np.float32)
    _cmp(flops, bytes_, bw, np.float32(peak), np.float32(oh))


def test_pad_unpad_roundtrip():
    for n in [1, 8, 127, 128, 129, 1024, 1025]:
        x = np.arange(n, dtype=np.float32)
        x2, m = pad_to_tiles(x)
        assert x2.shape[0] % 8 == 0 and x2.shape[1] == 128
        assert m == n
        assert_allclose(np.asarray(unpad(jnp.asarray(x2), m)), x)


def test_pad_fill_value():
    x2, _ = pad_to_tiles(np.ones(3, np.float32), fill=7.0)
    flat = np.asarray(x2).reshape(-1)
    assert (flat[3:] == 7.0).all()
