"""L1 Pallas attention-descriptor kernel vs pure-jnp oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.roofline import attn_descriptors


def _cmp(ctx, new, model):
    got = attn_descriptors(ctx, new, model)
    want = ref.attn_cost_ref(ctx, new, model)
    for g, w, name in zip(got, want, ["flops", "kv_bytes", "scores"]):
        assert_allclose(
            np.asarray(g), np.asarray(w), rtol=1e-6, err_msg=name
        )


def test_single_decode_request(model_vec):
    ctx = np.array([512.0], np.float32)
    new = np.array([1.0], np.float32)
    _cmp(ctx, new, model_vec)
    f, kv, s = attn_descriptors(ctx, new, model_vec)
    h = model_vec[0]
    assert_allclose(np.asarray(f), [4.0 * 1 * 513 * h], rtol=1e-6)


def test_prefill_request(model_vec):
    ctx = np.array([0.0], np.float32)
    new = np.array([256.0], np.float32)
    f, kv, s = attn_descriptors(ctx, new, model_vec)
    h, heads = model_vec[0], model_vec[2]
    assert_allclose(np.asarray(f), [4.0 * 256 * 256 * h], rtol=1e-6)
    assert_allclose(np.asarray(s), [256 * 256 * heads], rtol=1e-6)


def test_empty_slots_zero(model_vec):
    ctx = np.zeros(16, np.float32)
    new = np.zeros(16, np.float32)
    f, kv, s = attn_descriptors(ctx, new, model_vec)
    assert (np.asarray(f) == 0).all()
    assert (np.asarray(kv) == 0).all()
    assert (np.asarray(s) == 0).all()


def test_gqa_reduces_kv_bytes(model_vec):
    """kv_heads < heads shrinks KV traffic but not score FLOPs."""
    mha = model_vec.copy()
    gqa = model_vec.copy()
    gqa[3] = mha[2] / 4  # 4-way GQA
    ctx = np.array([1000.0], np.float32)
    new = np.array([1.0], np.float32)
    f_m, kv_m, _ = attn_descriptors(ctx, new, mha)
    f_g, kv_g, _ = attn_descriptors(ctx, new, gqa)
    assert_allclose(np.asarray(f_m), np.asarray(f_g), rtol=1e-6)
    assert np.asarray(kv_g)[0] < np.asarray(kv_m)[0]


def test_tensor_parallel_scaling(model_vec):
    tp1 = model_vec.copy()
    tp4 = model_vec.copy()
    tp4[7] = 4
    ctx = np.array([128.0, 64.0], np.float32)
    new = np.array([1.0, 32.0], np.float32)
    f1, kv1, s1 = attn_descriptors(ctx, new, tp1)
    f4, kv4, s4 = attn_descriptors(ctx, new, tp4)
    assert_allclose(np.asarray(f1) / 4.0, np.asarray(f4), rtol=1e-6)
    assert_allclose(np.asarray(kv1) / 4.0, np.asarray(kv4), rtol=1e-6)


def test_mixed_batch(model_vec, rng):
    n = 300
    ctx = rng.integers(0, 4096, n).astype(np.float32)
    new = rng.integers(0, 2, n).astype(np.float32)
    _cmp(ctx, new, model_vec)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 1024),
    h=st.sampled_from([512, 2048, 4096, 5120, 8192]),
    heads=st.sampled_from([8, 32, 40, 64]),
    gqa=st.sampled_from([1, 4, 8]),
    dtype_bytes=st.sampled_from([1, 2, 4]),
    tp=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(n, h, heads, gqa, dtype_bytes, tp, seed):
    if heads % gqa:
        return
    model = np.array(
        [h, 32, heads, heads // gqa, 4 * h, 32000, dtype_bytes, tp],
        np.float32,
    )
    rng = np.random.default_rng(seed)
    ctx = rng.integers(0, 8192, n).astype(np.float32)
    new = rng.integers(0, 512, n).astype(np.float32)
    _cmp(ctx, new, model)
