"""Communication-model kernel vs oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model as m
from compile.kernels import ref

NVLINK = np.array([600e9, 5e-6, 1.0], np.float32)
PCIE = np.array([64e9, 10e-6, 1.0], np.float32)


def _cmp(sizes, link):
    got = m.xfer_cost(sizes, link)
    want = ref.xfer_cost_ref(sizes, link)
    for g, w, name in zip(got, want, ["t_seq", "t_ovl", "per_block"]):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6, err_msg=name)


def test_single_block():
    sizes = np.array([2 << 20], np.float32)
    _cmp(sizes, NVLINK)
    t_seq, t_ovl, per = m.xfer_cost(sizes, NVLINK)
    assert_allclose(float(t_seq), 5e-6 + (2 << 20) / 600e9, rtol=1e-6)
    assert_allclose(float(t_seq), float(t_ovl), rtol=1e-6)


def test_overlap_beats_sequential():
    sizes = np.full(64, 1 << 20, np.float32)
    link = np.array([64e9, 50e-6, 8.0], np.float32)
    t_seq, t_ovl, _ = m.xfer_cost(sizes, link)
    assert float(t_ovl) < float(t_seq)
    # 64 blocks, depth 8 -> 8 exposed latencies
    assert_allclose(
        float(t_ovl), 8 * 50e-6 + 64 * (1 << 20) / 64e9, rtol=1e-6
    )


def test_empty_blocks_free():
    sizes = np.zeros(16, np.float32)
    t_seq, t_ovl, per = m.xfer_cost(sizes, PCIE)
    assert float(t_seq) == 0.0
    assert float(t_ovl) == 0.0
    assert (np.asarray(per) == 0).all()


def test_padding_mixed():
    sizes = np.array([1e6, 0, 2e6, 0, 0], np.float32)
    _cmp(sizes, PCIE)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 2000),
    bw=st.floats(1e9, 1e12),
    lat=st.floats(1e-7, 1e-3),
    depth=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(n, bw, lat, depth, seed):
    rng = np.random.default_rng(seed)
    sizes = rng.uniform(0, 64 << 20, n).astype(np.float32)
    sizes[rng.random(n) < 0.3] = 0.0
    link = np.array([bw, lat, depth], np.float32)
    _cmp(sizes, link)
