#!/usr/bin/env python3
"""Perf-regression gate: diff a fresh BENCH_ci.json against the
committed BENCH_baseline.json.

Every bench harness in rust/benches/ (plus `tokensim exp scale`) emits
one JSON row per case when TOKENSIM_BENCH_JSON is set; CI assembles
those lines into BENCH_ci.json. This script compares the `per_sec`
throughput of each row against the committed baseline:

  * current row missing from the baseline  -> STALE baseline, hard fail
    (the bench set changed; re-baseline as described below)
  * current `per_sec` below baseline by more than the threshold
    (default 25%)                          -> REGRESSION, fail
  * current `per_sec` above baseline by more than the threshold
                                           -> FASTER, warn (consider
    re-baselining so the gate keeps teeth)
  * baseline row absent from the current run -> SKIPPED, warn only
    (environment-conditional benches, e.g. the PJRT-artifact cases)

A markdown report is printed and, when GITHUB_STEP_SUMMARY is set,
appended to the job summary.

Re-baselining
-------------
Download the BENCH_ci artifact from a trusted green run on the target
runner class and regenerate the committed file:

    python3 scripts/bench_gate.py --rebaseline --current BENCH_ci.json

While the baseline's `meta.bootstrap` flag is true (numbers were
estimated or measured off the CI runner class), throughput deviations
are reported but do not fail the job; only stale-baseline coverage
errors do. Re-baselining from a real CI artifact clears the flag and
arms the full gate.

Usage:
    python3 scripts/bench_gate.py [--baseline BENCH_baseline.json]
        [--current BENCH_ci.json] [--threshold 0.25]
    python3 scripts/bench_gate.py --rebaseline [--current BENCH_ci.json]
        [--baseline BENCH_baseline.json]
"""

import argparse
import json
import os
import sys


def load_rows(path):
    """Return (meta, {name: row}) from a bench JSON file.

    Accepts both shapes: the CI artifact (a bare array of rows) and the
    committed baseline ({"meta": {...}, "rows": [...]}).
    """
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, dict):
        meta, rows = data.get("meta", {}), data.get("rows", [])
    else:
        meta, rows = {}, data
    by_name = {}
    for row in rows:
        name = row.get("name")
        if not name:
            raise SystemExit(f"{path}: bench row without a name: {row}")
        if name in by_name:
            raise SystemExit(f"{path}: duplicate bench row '{name}'")
        by_name[name] = row
    return meta, by_name


def rebaseline(args):
    _, current = load_rows(args.current)
    out = {
        "meta": {
            "source": os.path.basename(args.current),
            "threshold": args.threshold,
            "bootstrap": False,
            "note": (
                "committed perf baseline; regenerate with "
                "scripts/bench_gate.py --rebaseline from a trusted CI run"
            ),
        },
        "rows": [current[name] for name in sorted(current)],
    }
    with open(args.baseline, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {args.baseline} with {len(current)} rows (bootstrap off)")
    return 0


def emit_summary(lines):
    text = "\n".join(lines) + "\n"
    print(text)
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as f:
            f.write(text)


def check(args):
    base_meta, baseline = load_rows(args.baseline)
    _, current = load_rows(args.current)
    if not current:
        # every skipped row "warns only", so an empty current run would
        # otherwise sail through the gate having measured nothing
        print(f"FAIL: {args.current} has no bench rows", file=sys.stderr)
        return 1
    threshold = args.threshold
    bootstrap = bool(base_meta.get("bootstrap"))

    stale = sorted(set(current) - set(baseline))
    skipped = sorted(set(baseline) - set(current))
    regressions, faster, table = [], [], []
    for name in sorted(set(baseline) & set(current)):
        b, c = baseline[name]["per_sec"], current[name]["per_sec"]
        ratio = c / b if b else float("inf")
        if ratio < 1.0 - threshold:
            status = "REGRESSION"
            regressions.append(name)
        elif ratio > 1.0 + threshold:
            status = "faster"
            faster.append(name)
        else:
            status = "ok"
        table.append(f"| `{name}` | {b:.3f} | {c:.3f} | {ratio:.2f}x | {status} |")

    lines = ["## Bench gate", ""]
    lines.append(
        f"threshold ±{threshold:.0%} on `per_sec` vs `{args.baseline}`"
        + (" — **bootstrap baseline: deviations warn only**" if bootstrap else "")
    )
    lines += ["", "| bench | baseline/s | current/s | ratio | status |", "|---|---|---|---|---|"]
    lines += table
    if skipped:
        lines += ["", f"skipped (not in this run): {', '.join(f'`{n}`' for n in skipped)}"]
    if stale:
        lines += [
            "",
            "**STALE BASELINE** — rows with no committed reference: "
            + ", ".join(f"`{n}`" for n in stale),
            "",
            "Re-baseline: `python3 scripts/bench_gate.py --rebaseline --current BENCH_ci.json`",
        ]
    if faster:
        lines += [
            "",
            f">{threshold:.0%} faster (consider re-baselining): "
            + ", ".join(f"`{n}`" for n in faster),
        ]
    emit_summary(lines)

    if stale:
        print(f"FAIL: {len(stale)} bench row(s) missing from the baseline", file=sys.stderr)
        return 1
    if regressions and not bootstrap:
        print(f"FAIL: {len(regressions)} bench regression(s): {regressions}", file=sys.stderr)
        return 1
    if regressions:
        print(f"WARN (bootstrap baseline): {len(regressions)} deviation(s): {regressions}")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--current", default="BENCH_ci.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=float(os.environ.get("BENCH_GATE_THRESHOLD", "0.25")),
        help="relative per_sec band (0.25 = ±25%%)",
    )
    ap.add_argument("--rebaseline", action="store_true")
    args = ap.parse_args()
    sys.exit(rebaseline(args) if args.rebaseline else check(args))


if __name__ == "__main__":
    main()
