#!/usr/bin/env python3
"""Unit tests for scripts/bench_gate.py (stdlib only).

Run directly (CI does) or through unittest discovery:

    python3 scripts/test_bench_gate.py
"""

import json
import os
import sys
import tempfile
import unittest
from argparse import Namespace

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import bench_gate


def write_json(path, data):
    with open(path, "w") as f:
        json.dump(data, f)


def baseline(rows, bootstrap=False):
    return {"meta": {"bootstrap": bootstrap}, "rows": rows}


def row(name, per_sec):
    return {"name": name, "per_sec": per_sec}


class BenchGateTest(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()
        self.base_path = os.path.join(self.dir.name, "baseline.json")
        self.cur_path = os.path.join(self.dir.name, "current.json")
        # keep the gate's markdown out of a real job summary
        os.environ.pop("GITHUB_STEP_SUMMARY", None)

    def tearDown(self):
        self.dir.cleanup()

    def gate(self, threshold=0.25):
        return bench_gate.check(
            Namespace(baseline=self.base_path, current=self.cur_path, threshold=threshold)
        )

    def test_matching_rows_pass(self):
        write_json(self.base_path, baseline([row("a", 100.0), row("b", 50.0)]))
        write_json(self.cur_path, [row("a", 101.0), row("b", 49.0)])
        self.assertEqual(self.gate(), 0)

    def test_regression_beyond_band_fails(self):
        write_json(self.base_path, baseline([row("a", 100.0)]))
        write_json(self.cur_path, [row("a", 74.0)])  # 0.74x, band floor is 0.75x
        self.assertEqual(self.gate(), 1)

    def test_band_edges_are_inclusive(self):
        # exactly ±25% sits inside the band (strict comparisons)
        write_json(self.base_path, baseline([row("slow", 100.0), row("fast", 100.0)]))
        write_json(self.cur_path, [row("slow", 75.0), row("fast", 125.0)])
        self.assertEqual(self.gate(), 0)

    def test_bootstrap_baseline_downgrades_regressions_to_warnings(self):
        write_json(self.base_path, baseline([row("a", 100.0)], bootstrap=True))
        write_json(self.cur_path, [row("a", 10.0)])
        self.assertEqual(self.gate(), 0)

    def test_stale_row_fails_even_under_bootstrap(self):
        write_json(self.base_path, baseline([row("a", 100.0)], bootstrap=True))
        write_json(self.cur_path, [row("a", 100.0), row("new_bench", 5.0)])
        self.assertEqual(self.gate(), 1)

    def test_skipped_rows_warn_only(self):
        write_json(self.base_path, baseline([row("a", 100.0), row("env_only", 9.0)]))
        write_json(self.cur_path, [row("a", 100.0)])
        self.assertEqual(self.gate(), 0)

    def test_empty_current_fails(self):
        write_json(self.base_path, baseline([row("a", 100.0)]))
        write_json(self.cur_path, [])
        self.assertEqual(self.gate(), 1)

    def test_faster_rows_pass(self):
        write_json(self.base_path, baseline([row("a", 100.0)]))
        write_json(self.cur_path, [row("a", 1000.0)])
        self.assertEqual(self.gate(), 0)

    def test_duplicate_row_is_rejected(self):
        write_json(self.base_path, baseline([row("a", 100.0)]))
        write_json(self.cur_path, [row("a", 1.0), row("a", 2.0)])
        with self.assertRaises(SystemExit):
            self.gate()

    def test_rebaseline_writes_sorted_rows_with_bootstrap_off(self):
        write_json(self.cur_path, [row("b", 2.0), row("a", 1.0)])
        rc = bench_gate.rebaseline(
            Namespace(baseline=self.base_path, current=self.cur_path, threshold=0.25)
        )
        self.assertEqual(rc, 0)
        with open(self.base_path) as f:
            out = json.load(f)
        self.assertIs(out["meta"]["bootstrap"], False)
        self.assertEqual([r["name"] for r in out["rows"]], ["a", "b"])


if __name__ == "__main__":
    unittest.main()
