//! Compute-cost-model benchmarks — the simulation hot path.
//!
//! Compares the three evaluation paths (PJRT-executed HLO artifact,
//! extracted coefficient table, analytic mirror) plus the baselines'
//! models; the §Perf story is the Hlo → Table gap.

#[path = "harness.rs"]
mod harness;

use harness::{bench, budget, sink};
use tokensim::baselines::{LlmServingSimLike, VidurLike};
use tokensim::compute::{AnalyticCost, BatchDesc, ComputeModel, HloCost, RooflineCost, TableCost};
use tokensim::hardware::HardwareSpec;
use tokensim::model::ModelSpec;
use tokensim::oracle::{OracleCost, OracleParams};

fn mixed_batch() -> BatchDesc {
    let mut b = BatchDesc::new();
    b.push(0, 512);
    for i in 0..63u32 {
        b.push(100 + i * 37, 1);
    }
    b
}

fn main() {
    println!("== cost_model_bench ==");
    let model = ModelSpec::llama2_7b();
    let hw = HardwareSpec::a100_80g();
    let batch = mixed_batch();

    let mut analytic = AnalyticCost::new(&model, &hw);
    bench("cost/analytic_mirror", budget(), || {
        sink(analytic.iter_time(&batch));
    });

    let mut probe = AnalyticCost::new(&model, &hw);
    let mut table = TableCost::build(&mut probe, &model, &hw);
    bench("cost/table_extracted", budget(), || {
        sink(table.iter_time(&batch));
    });

    let mut roofline = RooflineCost::new(&model, &hw);
    bench("cost/roofline_aggregate", budget(), || {
        sink(roofline.iter_time(&batch));
    });

    let dir = tokensim::runtime::default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        let mut hlo = HloCost::load(&model, &hw, dir.to_str().unwrap()).unwrap();
        bench("cost/hlo_pjrt_execute", budget(), || {
            sink(hlo.iter_time(&batch));
        });
        let mut table_hlo = TableCost::build(&mut hlo, &model, &hw);
        bench("cost/table_from_artifact", budget(), || {
            sink(table_hlo.iter_time(&batch));
        });
    } else {
        eprintln!("(artifacts not built; skipping HLO benches — run `make artifacts`)");
    }

    let oracle = OracleCost::new(&model, &hw, OracleParams::vllm().noiseless(), 0);
    bench("cost/oracle_reference", budget(), || {
        sink(oracle.evaluate_mean(&batch).iter_time);
    });

    let mut vidur = VidurLike::train(&model, &hw, 800, 42);
    bench("cost/vidur_like_forest", budget(), || {
        sink(vidur.iter_time(&batch));
    });

    let mut cosim = LlmServingSimLike::new(&model, &hw);
    let mut short = BatchDesc::new();
    for i in 0..64u32 {
        short.push(100 + i * 7, 1);
    }
    bench("cost/llmservingsim_like_cosim", budget(), || {
        sink(cosim.iter_time(&short));
    });
}
