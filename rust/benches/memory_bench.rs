//! Memory-manager micro-benchmarks: block reserve/grow/release churn,
//! pool-cache operations, and the swap/prefix plugin hot paths.

#[path = "harness.rs"]
mod harness;

use harness::{bench, budget, sink};
use tokensim::memory::{MemoryManager, PagedBlockManager, PoolCache, SwapMemoryManager};

fn main() {
    println!("== memory_bench ==");

    bench("paged/reserve_release_1k_requests", budget(), || {
        let mut mem = PagedBlockManager::with_blocks(100_000, 16, 1024);
        for i in 0..1000 {
            mem.reserve(i, 64 + (i as u32 * 31) % 2048);
        }
        for i in 0..1000 {
            mem.release(i);
        }
        sink(mem.free_blocks());
    });

    bench("paged/decode_growth_10k_steps", budget(), || {
        let mut mem = PagedBlockManager::with_blocks(100_000, 16, 1024);
        for i in 0..100 {
            mem.reserve(i, 512);
        }
        let mut tokens = [512u32; 100];
        for step in 0..10_000 {
            let i = step % 100;
            tokens[i] += 1;
            let _ = mem.grow_one_token(i, tokens[i]);
        }
        sink(mem.used_blocks());
    });

    bench("pool/store_lookup_churn", budget(), || {
        let mut pool = PoolCache::new(10_000, 16);
        for i in 0..2000usize {
            pool.store(i % 128, 64 + (i as u32 * 17) % 4096);
            sink(pool.lookup(i % 128, 512));
        }
        sink(pool.used_blocks());
    });

    bench("swap/out_in_churn_1k", budget(), || {
        let mut mem = SwapMemoryManager::with_blocks(100_000, 16, 1024, 400_000);
        for i in 0..1000usize {
            mem.reserve(i, 64 + (i as u32 * 31) % 2048);
        }
        for i in 0..1000usize {
            sink(mem.swap_out(i));
        }
        for i in 0..1000usize {
            let _ = mem.swap_in(i, 64 + (i as u32 * 31) % 2048);
        }
        sink(mem.swap_space_used());
    });
}
