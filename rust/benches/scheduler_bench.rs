//! Scheduler micro-benchmarks: batch formation under load (the
//! per-iteration L3 control-path cost) and global dispatch, across the
//! built-in policy plugins.

#[path = "harness.rs"]
mod harness;

use std::collections::VecDeque;

use harness::{bench, budget, sink};
use tokensim::memory::{PagedBlockManager, PreemptionPolicy};
use tokensim::model::ModelSpec;
use tokensim::request::Request;
use tokensim::scheduler::{
    ChunkedPrefill, ContinuousBatching, GlobalScheduler, LeastLoaded, LocalSchedCtx,
    LocalScheduler, PowerOfTwoChoices, RoundRobin, ShortestJobFirst, WorkerView,
};
use tokensim::sim::SimRng;

fn make_requests(n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request::new(i, i, 0, 64 + (i as u32 * 37) % 1024, 64, 0.0))
        .collect()
}

/// Run one batch-formation case: `running` decodes + `waiting` fresh
/// prefills, rebuilt per iteration.
fn bench_local(name: &str, mut policy: Box<dyn LocalScheduler>, n_running: usize, n_waiting: usize) {
    bench(name, budget(), move || {
        let total = n_running + n_waiting;
        let mut requests = make_requests(total);
        let mut waiting: VecDeque<usize> = (n_running..total).collect();
        let mut running: Vec<usize> = (0..n_running).collect();
        let mut mem = PagedBlockManager::with_blocks(100_000, 16, 1024);
        for rid in 0..n_running {
            let r = &mut requests[rid];
            r.phase = tokensim::request::Phase::Decode;
            r.prompt_done = r.prompt_len;
            r.ctx_in_cache = r.prompt_len;
            mem.reserve(rid, r.ctx_in_cache + 1);
        }
        let mut ctx = LocalSchedCtx {
            requests: &mut requests,
            waiting: &mut waiting,
            running: &mut running,
            mem: &mut mem,
            now: 0.0,
            draining: false,
            oldest_wait: Some(0.0),
            preemption: PreemptionPolicy::Recompute,
        };
        sink(policy.form_batch(&mut ctx).members.len());
    });
}

fn main() {
    println!("== scheduler_bench ==");
    let model = ModelSpec::llama2_7b();
    let _ = &model;

    // continuous batch formation with 256 running decodes
    bench_local(
        "local/continuous_form_batch_256_running",
        Box::new(ContinuousBatching::vllm_default()),
        256,
        0,
    );

    // admission of 64 fresh prefills, per policy family
    bench_local(
        "local/continuous_admit_64_prefills",
        Box::new(ContinuousBatching {
            max_batched_tokens: 1 << 20,
            max_batch_size: None,
            mixed_batching: false,
        }),
        0,
        64,
    );
    bench_local(
        "local/chunked_prefill_admit_64_prefills",
        Box::new(ChunkedPrefill {
            chunk_tokens: 1 << 20,
            max_batch_size: None,
        }),
        0,
        64,
    );
    bench_local(
        "local/sjf_admit_64_prefills",
        Box::new(ShortestJobFirst {
            max_batched_tokens: 1 << 20,
            max_batch_size: None,
            starvation_age: Some(10.0),
        }),
        0,
        64,
    );

    // mixed steady state: 128 decodes + 32 waiting, chunked
    bench_local(
        "local/chunked_prefill_mixed_128d_32w",
        Box::new(ChunkedPrefill {
            chunk_tokens: 512,
            max_batch_size: None,
        }),
        128,
        32,
    );

    // global dispatch across an 8-worker cluster
    let views: Vec<WorkerView> = (0..8)
        .map(|id| WorkerView {
            id,
            hardware: "A100".into(),
            run_prefill: id < 2,
            run_decode: id >= 2,
            waiting_requests: id,
            running_requests: 2 * id,
            outstanding_tokens: 1000 * id as u64,
            free_blocks: 1000,
            total_blocks: 2000,
        })
        .collect();
    let requests = make_requests(64);
    let new_ids: Vec<usize> = (0..64).collect();
    let globals: Vec<(&str, Box<dyn GlobalScheduler>)> = vec![
        ("global/round_robin_dispatch_64", Box::new(RoundRobin::default())),
        ("global/least_loaded_dispatch_64", Box::new(LeastLoaded::default())),
        (
            "global/power_of_two_dispatch_64",
            Box::new(PowerOfTwoChoices::default()),
        ),
    ];
    for (name, mut policy) in globals {
        let mut rng = SimRng::new(1, "bench");
        bench(name, budget(), || {
            sink(
                policy
                    .dispatch(&new_ids, &[], &views, &requests, &mut rng)
                    .len(),
            );
        });
    }
}
