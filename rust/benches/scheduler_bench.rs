//! Scheduler micro-benchmarks: batch formation under load (the
//! per-iteration L3 control-path cost) and global dispatch.

#[path = "harness.rs"]
mod harness;

use std::collections::VecDeque;

use harness::{bench, budget, sink};
use tokensim::memory::PagedBlockManager;
use tokensim::model::ModelSpec;
use tokensim::request::Request;
use tokensim::scheduler::{GlobalPolicy, GlobalSchedulerState, LocalPolicy, LocalSchedCtx, WorkerView};
use tokensim::sim::SimRng;

fn make_requests(n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request::new(i, i, 0, 64 + (i as u32 * 37) % 1024, 64, 0.0))
        .collect()
}

fn main() {
    println!("== scheduler_bench ==");
    let model = ModelSpec::llama2_7b();
    let _ = &model;

    // continuous batch formation with 256 running decodes
    bench("local/continuous_form_batch_256_running", budget(), || {
        let mut requests = make_requests(256);
        let mut waiting: VecDeque<usize> = VecDeque::new();
        let mut running: Vec<usize> = (0..256).collect();
        for r in requests.iter_mut() {
            r.phase = tokensim::request::Phase::Decode;
            r.prompt_done = r.prompt_len;
            r.ctx_in_cache = r.prompt_len;
        }
        let mut mem = PagedBlockManager::with_blocks(100_000, 16, 1024);
        for (i, r) in requests.iter().enumerate() {
            mem.reserve(i, r.ctx_in_cache + 1);
        }
        let policy = LocalPolicy::continuous_default();
        let mut ctx = LocalSchedCtx {
            requests: &mut requests,
            waiting: &mut waiting,
            running: &mut running,
            mem: &mut mem,
            now: 0.0,
            draining: false,
            oldest_wait: None,
        };
        sink(policy.form_batch(&mut ctx).members.len());
    });

    // admission of 64 fresh prefills
    bench("local/continuous_admit_64_prefills", budget(), || {
        let mut requests = make_requests(64);
        let mut waiting: VecDeque<usize> = (0..64).collect();
        let mut running: Vec<usize> = Vec::new();
        let mut mem = PagedBlockManager::with_blocks(100_000, 16, 1024);
        let policy = LocalPolicy::Continuous {
            max_batched_tokens: 1 << 20,
            max_batch_size: None,
            mixed_batching: false,
        };
        let mut ctx = LocalSchedCtx {
            requests: &mut requests,
            waiting: &mut waiting,
            running: &mut running,
            mem: &mut mem,
            now: 0.0,
            draining: false,
            oldest_wait: Some(0.0),
        };
        sink(policy.form_batch(&mut ctx).members.len());
    });

    // global dispatch across an 8-worker cluster
    let views: Vec<WorkerView> = (0..8)
        .map(|id| WorkerView {
            id,
            hardware: "A100".into(),
            run_prefill: id < 2,
            run_decode: id >= 2,
            waiting_requests: id,
            running_requests: 2 * id,
            outstanding_tokens: 1000 * id as u64,
            free_blocks: 1000,
            total_blocks: 2000,
        })
        .collect();
    let requests = make_requests(64);
    let new_ids: Vec<usize> = (0..64).collect();
    for (name, policy) in [
        ("global/round_robin_dispatch_64", GlobalPolicy::RoundRobin),
        ("global/load_aware_dispatch_64", GlobalPolicy::LoadAware),
    ] {
        let mut state = GlobalSchedulerState::new(8);
        let mut rng = SimRng::new(1, "bench");
        bench(name, budget(), || {
            sink(
                policy
                    .dispatch(&mut state, &new_ids, &[], &views, &requests, &mut rng)
                    .len(),
            );
        });
    }
}
