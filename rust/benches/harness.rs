//! Minimal benchmarking harness (criterion is unavailable in this
//! offline build): warms up, runs timed batches until a time budget or
//! max iterations, reports mean / p50 / p99 per-op latency and
//! throughput. Used by every `cargo bench` target via `#[path]` module
//! inclusion.
//!
//! Set `TOKENSIM_BENCH_JSON=<path>` to additionally append one JSON
//! line per case (`{"name", "iters", "mean_ns", "p50_ns", "p99_ns",
//! "per_sec"}`) — CI collects these into the `BENCH_ci.json` artifact
//! so the perf trajectory is machine-readable across commits.

use std::io::Write as _;
use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Run `f` repeatedly for up to `budget` (after warm-up) and collect
/// per-iteration timings.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // warm-up: 3 iterations or 100 ms, whichever first
    let warm_start = Instant::now();
    for _ in 0..3 {
        f();
        if warm_start.elapsed() > Duration::from_millis(100) {
            break;
        }
    }
    let mut samples: Vec<u64> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget && samples.len() < 1_000_000 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as u64);
    }
    samples.sort_unstable();
    let n = samples.len().max(1);
    let mean = samples.iter().sum::<u64>() as f64 / n as f64;
    let result = BenchResult {
        name: name.to_string(),
        iters: samples.len() as u64,
        mean_ns: mean,
        p50_ns: samples[n / 2] as f64,
        p99_ns: samples[(n * 99 / 100).min(n - 1)] as f64,
    };
    println!(
        "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}  ({:.1}/s)",
        result.name,
        result.iters,
        fmt_ns(result.mean_ns),
        fmt_ns(result.p50_ns),
        fmt_ns(result.p99_ns),
        result.per_sec(),
    );
    emit_json(&result);
    result
}

/// Append the result as one JSON line to `TOKENSIM_BENCH_JSON` (no-op
/// when unset). Append mode lets every bench binary write into the same
/// artifact file.
fn emit_json(r: &BenchResult) {
    let Ok(path) = std::env::var("TOKENSIM_BENCH_JSON") else {
        return;
    };
    let line = format!(
        "{{\"name\":\"{}\",\"iters\":{},\"mean_ns\":{:.1},\"p50_ns\":{:.1},\"p99_ns\":{:.1},\"per_sec\":{:.3}}}\n",
        r.name.replace('"', "'"),
        r.iters,
        r.mean_ns,
        r.p50_ns,
        r.p99_ns,
        r.per_sec(),
    );
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .and_then(|mut f| f.write_all(line.as_bytes()));
    if let Err(e) = appended {
        eprintln!("warning: TOKENSIM_BENCH_JSON={path}: {e}");
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Default per-case budget; override with TOKENSIM_BENCH_SECS.
pub fn budget() -> Duration {
    let secs = std::env::var("TOKENSIM_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(2.0);
    Duration::from_secs_f64(secs)
}

/// `black_box` stand-in.
#[inline]
pub fn sink<T>(x: T) -> T {
    std::hint::black_box(x)
}
