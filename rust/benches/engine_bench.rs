//! L3 engine micro-benchmarks: event-queue throughput (the SimPy
//! replacement this rust rewrite justifies), RNG sampling, and the
//! decode-window costing paths (replay vs memoized vs affine).

#[path = "harness.rs"]
mod harness;

use harness::{bench, budget, sink};
use tokensim::cluster::Simulation;
use tokensim::compute::ComputeSpec;
use tokensim::config::{SimulationConfig, WindowCost};
use tokensim::hardware::HardwareSpec;
use tokensim::model::ModelSpec;
use tokensim::sim::{EventPayload, EventQueue, SimRng};
use tokensim::workload::WorkloadSpec;

/// Decode-heavy single-worker config: 1k-iteration decode tails, so
/// fast-forward coalesces long closed windows and the three window
/// costing strategies diverge in cost-model call volume.
fn window_cfg(compute: &ComputeSpec, window_cost: WindowCost) -> SimulationConfig {
    let mut cfg = SimulationConfig::single_worker(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        WorkloadSpec::fixed(32, 8.0, 32, 1_000),
    );
    cfg.compute = compute.clone();
    cfg.engine.fast_forward = true;
    cfg.engine.window_cost = window_cost;
    cfg
}

fn main() {
    println!("== engine_bench ==");

    bench("event_queue/push_pop_10k", budget(), || {
        let mut q = EventQueue::new();
        for i in 0..10_000u64 {
            q.schedule_at((i % 97) as f64, EventPayload::Kick { worker: i as usize % 8 });
        }
        let mut n = 0;
        while q.pop().is_some() {
            n += 1;
        }
        sink(n);
    });

    bench("event_queue/interleaved_steady_state", budget(), || {
        let mut q = EventQueue::new();
        let mut t = 0.0;
        for i in 0..64u64 {
            q.schedule_at(i as f64 * 0.1, EventPayload::SampleTick);
        }
        for _ in 0..10_000 {
            let ev = q.pop().unwrap();
            t = ev.time;
            q.schedule_at(t + 1.0, EventPayload::SampleTick);
        }
        sink(t);
    });

    bench("rng/exp_gap_1M", budget(), || {
        let mut rng = SimRng::new(7, "bench");
        let mut acc = 0.0;
        for _ in 0..1_000_000 {
            acc += rng.exp_gap(10.0);
        }
        sink(acc);
    });

    bench("rng/lognormal_100k", budget(), || {
        let mut rng = SimRng::new(7, "bench");
        let mut acc = 0.0;
        for _ in 0..100_000 {
            acc += rng.lognormal(4.0, 1.0);
        }
        sink(acc);
    });

    // closed decode windows (~1k iterations each): per-iteration replay
    // vs exact memoization vs the closed-form affine series — the PR-7
    // hot-path comparison, tracked per commit via TOKENSIM_BENCH_JSON
    let cases = [
        ("replay", ComputeSpec::new("analytic"), WindowCost::Replay),
        ("memo", ComputeSpec::new("memo").with("base", "analytic"), WindowCost::Replay),
        ("affine", ComputeSpec::new("analytic"), WindowCost::Affine),
    ];
    for (label, compute, wc) in cases {
        let cfg = window_cfg(&compute, wc);
        bench(&format!("decode_window/1k_iters_{label}"), budget(), || {
            let report = Simulation::from_config(&cfg)
                .expect("valid config")
                .run()
                .expect("workload must complete");
            sink(report.records.len());
        });
    }
}
