//! L3 engine micro-benchmarks: event-queue throughput (the SimPy
//! replacement this rust rewrite justifies) and RNG sampling.

#[path = "harness.rs"]
mod harness;

use harness::{bench, budget, sink};
use tokensim::sim::{EventPayload, EventQueue, SimRng};

fn main() {
    println!("== engine_bench ==");

    bench("event_queue/push_pop_10k", budget(), || {
        let mut q = EventQueue::new();
        for i in 0..10_000u64 {
            q.schedule_at((i % 97) as f64, EventPayload::Kick { worker: i as usize % 8 });
        }
        let mut n = 0;
        while q.pop().is_some() {
            n += 1;
        }
        sink(n);
    });

    bench("event_queue/interleaved_steady_state", budget(), || {
        let mut q = EventQueue::new();
        let mut t = 0.0;
        for i in 0..64u64 {
            q.schedule_at(i as f64 * 0.1, EventPayload::SampleTick);
        }
        for _ in 0..10_000 {
            let ev = q.pop().unwrap();
            t = ev.time;
            q.schedule_at(t + 1.0, EventPayload::SampleTick);
        }
        sink(t);
    });

    bench("rng/exp_gap_1M", budget(), || {
        let mut rng = SimRng::new(7, "bench");
        let mut acc = 0.0;
        for _ in 0..1_000_000 {
            acc += rng.exp_gap(10.0);
        }
        sink(acc);
    });

    bench("rng/lognormal_100k", budget(), || {
        let mut rng = SimRng::new(7, "bench");
        let mut acc = 0.0;
        for _ in 0..100_000 {
            acc += rng.lognormal(4.0, 1.0);
        }
        sink(acc);
    });
}
