//! End-to-end simulator throughput — the Fig-6-adjacent numbers: how
//! fast TokenSim itself simulates serving workloads (requests and
//! simulated tokens per wall-clock second), across cost models and
//! cluster shapes.

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use harness::{bench, budget, sink};
use tokensim::cluster::Simulation;
use tokensim::compute::ComputeSpec;
use tokensim::config::SimulationConfig;
use tokensim::hardware::HardwareSpec;
use tokensim::model::ModelSpec;
use tokensim::workload::WorkloadSpec;

fn run(cfg: &SimulationConfig) -> tokensim::cluster::SimulationReport {
    Simulation::from_config(cfg)
        .expect("valid config")
        .run()
        .expect("workload must complete")
}

fn cfg(n: usize, compute: &ComputeSpec) -> SimulationConfig {
    let mut cfg = SimulationConfig::single_worker(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        WorkloadSpec::sharegpt(n, 16.0),
    );
    cfg.compute = compute.clone();
    cfg
}

fn main() {
    println!("== end_to_end_bench ==");

    for name in ["analytic", "table", "roofline"] {
        let c = cfg(500, &ComputeSpec::new(name));
        bench(&format!("e2e/500_sharegpt_requests_{name}"), budget(), || {
            sink(run(&c).records.len());
        });
    }

    // decode fast-forwarding off/on over a decode-heavy workload — the
    // engine-level speedup `exp scale` quantifies, tracked per commit
    for (label, ff) in [("off", false), ("on", true)] {
        let mut c = cfg(500, &ComputeSpec::new("analytic"));
        c.workload = WorkloadSpec::fixed(500, 4.0, 32, 256).into();
        c.engine.fast_forward = ff;
        bench(&format!("e2e/500_decode_heavy_fast_forward_{label}"), budget(), || {
            sink(run(&c).records.len());
        });
    }

    if tokensim::runtime::default_artifacts_dir()
        .join("manifest.json")
        .exists()
    {
        let c = cfg(200, &ComputeSpec::new("hlo"));
        bench("e2e/200_sharegpt_requests_hlo", budget(), || {
            sink(run(&c).records.len());
        });
    }

    // disaggregated 8-worker cluster
    let mut disagg = SimulationConfig::disaggregated(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        2,
        HardwareSpec::a100_80g(),
        6,
        WorkloadSpec::sharegpt(500, 40.0),
    );
    disagg.compute = ComputeSpec::new("table");
    bench("e2e/500_requests_disaggregated_2p6d", budget(), || {
        sink(run(&disagg).records.len());
    });

    // the headline scale: Fig 9's 50k-request workload, one shot
    let big = cfg(50_000, &ComputeSpec::new("table"));
    let t0 = Instant::now();
    let report = run(&big);
    let wall = t0.elapsed().as_secs_f64();
    let tokens: u64 = report.records.iter().map(|r| r.output_len as u64).sum();
    println!(
        "one-shot: 50k ShareGPT requests in {:.2}s wall ({:.0} req/s, {:.2}M simulated tokens/s, {} events)",
        wall,
        50_000.0 / wall,
        tokens as f64 / wall / 1e6,
        report.events_processed,
    );
}
