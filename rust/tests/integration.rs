//! Integration tests: whole-stack behaviour across the runtime (PJRT
//! artifacts), cost models, scheduler, memory manager and driver.

use tokensim::cluster::{strip_compute_identity, Simulation};
use tokensim::compute::{
    AnalyticCost, BatchDesc, ComputeModel, ComputeSpec, CostModelKind, HloCost, TableCost,
};
use tokensim::config::{PoolCacheConfig, SimulationConfig};
use tokensim::hardware::{HardwareSpec, LinkSpec};
use tokensim::metrics::MetricSet;
use tokensim::model::ModelSpec;
use tokensim::workload::{ConversationSpec, WorkloadSpec};

fn artifacts_dir() -> Option<String> {
    let dir = tokensim::runtime::default_artifacts_dir();
    dir.join("manifest.json")
        .exists()
        .then(|| dir.to_str().unwrap().to_string())
}

fn base_cfg(n: usize, qps: f64) -> SimulationConfig {
    let mut cfg = SimulationConfig::single_worker(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        WorkloadSpec::sharegpt(n, qps),
    );
    cfg.compute = ComputeSpec::new("analytic");
    cfg
}

// ---- three-layer cross-validation -------------------------------------

#[test]
fn hlo_table_analytic_cost_models_agree() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let model = ModelSpec::llama2_7b();
    let hw = HardwareSpec::a100_80g();
    let mut hlo = HloCost::load(&model, &hw, &dir).unwrap();
    let mut analytic = AnalyticCost::new(&model, &hw);
    let mut table = TableCost::build(&mut hlo, &model, &hw);

    let mut batches = Vec::new();
    for seed in 0..20u32 {
        let mut b = BatchDesc::new();
        let n = 1 + (seed * 13 % 90) as usize;
        for i in 0..n {
            let ctx = (seed * 31 + i as u32 * 97) % 4096;
            let new = if i == 0 && seed % 3 == 0 { 256 } else { 1 };
            b.push(ctx, new);
        }
        batches.push(b);
    }
    for b in &batches {
        let t_h = hlo.iter_time(b);
        let t_a = analytic.iter_time(b);
        let t_t = table.iter_time(b);
        let rel_ha = ((t_h - t_a) / t_a).abs();
        let rel_ta = ((t_t - t_a) / t_a).abs();
        assert!(rel_ha < 1e-3, "hlo vs analytic: {t_h} vs {t_a} ({rel_ha})");
        assert!(rel_ta < 2e-3, "table vs analytic: {t_t} vs {t_a} ({rel_ta})");
    }
}

#[test]
fn simulation_identical_under_all_cost_models() {
    // same workload through analytic / hlo / table cost models must give
    // (near-)identical end-to-end results — the artifact IS the model.
    let Some(_) = artifacts_dir() else {
        return;
    };
    let mut reports = Vec::new();
    for kind in [CostModelKind::Analytic, CostModelKind::Hlo, CostModelKind::Table] {
        let mut cfg = base_cfg(120, 10.0);
        // lossless enum -> registry-spec conversion keeps this call
        // site's pre-registry shape working
        cfg.compute = kind.into();
        reports.push(Simulation::from_config(&cfg).unwrap().run().unwrap());
    }
    let base = MetricSet::new(&reports[0].records).latency_percentile(0.99);
    for r in &reports[1..] {
        let p99 = MetricSet::new(&r.records).latency_percentile(0.99);
        let rel = ((p99 - base) / base).abs();
        assert!(rel < 5e-3, "p99 drift across cost models: {p99} vs {base}");
    }
}

// ---- end-to-end serving behaviour --------------------------------------

#[test]
fn all_requests_complete_with_sane_timestamps() {
    let report = Simulation::from_config(&base_cfg(300, 20.0)).unwrap().run().unwrap();
    assert_eq!(report.records.len(), 300);
    for r in &report.records {
        assert!(r.first_token >= r.arrival, "req {}", r.id);
        assert!(r.finished >= r.first_token, "req {}", r.id);
        assert!(r.max_token_gap >= 0.0);
    }
}

#[test]
fn saturation_appears_beyond_service_capacity() {
    // throughput must plateau once offered load exceeds capacity
    let mut prev = 0.0;
    let mut plateaued = false;
    for qps in [2.0, 8.0, 32.0, 128.0, 512.0, 2048.0] {
        let report = Simulation::from_config(&base_cfg(250, qps)).unwrap().run().unwrap();
        let thr = report.request_throughput();
        if thr < prev * 1.05 {
            plateaued = true;
        }
        prev = thr;
    }
    assert!(plateaued, "no saturation observed up to 2048 qps");
}

#[test]
fn disaggregated_matches_unified_at_low_load_and_transfers_kv() {
    let model = ModelSpec::llama2_7b();
    let hw = HardwareSpec::a100_80g();
    let workload = WorkloadSpec::fixed(60, 2.0, 128, 32);
    let mut unified = SimulationConfig::single_worker(model.clone(), hw.clone(), workload.clone());
    unified.cluster.workers[0].quantity = 2;
    unified.compute = ComputeSpec::new("analytic");
    let mut disagg = SimulationConfig::disaggregated(model, hw.clone(), 1, hw, 1, workload);
    disagg.compute = ComputeSpec::new("analytic");

    let ru = Simulation::from_config(&unified).unwrap().run().unwrap();
    let rd = Simulation::from_config(&disagg).unwrap().run().unwrap();
    assert_eq!(rd.records.len(), 60);
    // at 2 qps both configurations are unloaded; latencies comparable
    // (disagg pays the KV transfer, bounded by ~20%)
    let (lu, ld) = (
        MetricSet::new(&ru.records).latency_percentile(0.5),
        MetricSet::new(&rd.records).latency_percentile(0.5),
    );
    assert!(
        (ld - lu).abs() / lu < 0.25,
        "unified p50 {lu} vs disagg p50 {ld}"
    );
}

#[test]
fn slow_interconnect_hurts_disaggregation() {
    let model = ModelSpec::llama2_7b();
    let hw = HardwareSpec::a100_80g();
    let workload = WorkloadSpec::fixed(80, 4.0, 512, 32);
    let mk = |link: LinkSpec| {
        let mut cfg = SimulationConfig::disaggregated(
            model.clone(),
            hw.clone(),
            1,
            hw.clone(),
            1,
            workload.clone(),
        );
        cfg.compute = ComputeSpec::new("analytic");
        cfg.cluster.scheduler.interconnect = link;
        Simulation::from_config(&cfg).unwrap().run().unwrap()
    };
    let fast = mk(LinkSpec::nvlink());
    let slow = mk(LinkSpec::ethernet_100g());
    let (pf, ps) = (
        MetricSet::new(&fast.records).latency_percentile(0.5),
        MetricSet::new(&slow.records).latency_percentile(0.5),
    );
    assert!(ps > pf, "ethernet p50 {ps} must exceed nvlink p50 {pf}");
}

#[test]
fn yaml_config_roundtrips_through_run() {
    let yaml = r#"
model: llama2-7b
cost_model: analytic
cluster:
  workers:
    - hardware: A100
      local_scheduler:
        policy: continuous
        max_batched_tokens: 4096
        max_batch_size: 128
workload:
  num_requests: 40
  qps: 8.0
  prompt_len:
    fixed: 64
  output_len:
    fixed: 16
  seed: 3
"#;
    let cfg = SimulationConfig::from_yaml_str(yaml).unwrap();
    let report = Simulation::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(report.records.len(), 40);
}

#[test]
fn conversation_pool_cache_reduces_prefill_work() {
    let convs = ConversationSpec::chatbot(150, 8.0, 128, 64).generate();
    let run = |pool: Option<PoolCacheConfig>| {
        let mut cfg = base_cfg(1, 1.0);
        cfg.pool_cache = pool;
        Simulation::from_conversations(&cfg, &convs).unwrap().run().unwrap()
    };
    let off = run(None);
    let on = run(Some(PoolCacheConfig::with_capacity(1_000_000)));
    assert_eq!(off.pool_hits, 0);
    assert!(on.pool_hits > 0);
    let cached_tokens: u64 = on.records.iter().map(|r| r.cached_prefix as u64).sum();
    assert!(cached_tokens > 0);
    // later rounds must see a TTFT win
    let ttft = |recs: &[tokensim::metrics::RequestRecord]| {
        let later: Vec<f64> = recs
            .iter()
            .filter(|r| r.round > 0)
            .map(|r| r.ttft())
            .collect();
        later.iter().sum::<f64>() / later.len() as f64
    };
    assert!(
        ttft(&on.records) < ttft(&off.records),
        "cached rounds must start faster"
    );
}

#[test]
fn static_batching_has_worse_tail_latency_under_load() {
    use tokensim::scheduler::PolicySpec;
    let mk = |policy: PolicySpec| {
        let mut cfg = base_cfg(250, 12.0);
        cfg.cluster.workers[0].local_scheduler = policy;
        Simulation::from_config(&cfg).unwrap().run().unwrap()
    };
    let cont = mk(PolicySpec::new("continuous")
        .with("max_batched_tokens", 8192u32)
        .with("max_batch_size", 16u32));
    let stat = mk(PolicySpec::new("static")
        .with("batch_size", 16u32)
        .with("max_linger", 2.0));
    let (pc, ps) = (
        MetricSet::new(&cont.records).mean_normalized_latency(),
        MetricSet::new(&stat.records).mean_normalized_latency(),
    );
    assert!(pc < ps, "continuous {pc} must beat static {ps}");
}

#[test]
fn trace_replay_reproduces_generated_workload() {
    let dir = tokensim::util::TempDir::new().unwrap();
    let path = dir.path().join("w.jsonl");
    let cfg = base_cfg(60, 10.0);
    let requests = cfg.workload.generate().unwrap();
    tokensim::workload::save_trace(&path, &requests).unwrap();
    let replayed = tokensim::workload::load_trace(&path).unwrap();

    let direct = Simulation::from_config(&cfg).unwrap().run().unwrap();
    let replay = Simulation::from_requests(&cfg, replayed).unwrap().run().unwrap();
    let (a, b) = (
        MetricSet::new(&direct.records).latency_percentile(0.9),
        MetricSet::new(&replay.records).latency_percentile(0.9),
    );
    assert!((a - b).abs() < 1e-9, "replay diverged: {a} vs {b}");
}

#[test]
fn trace_generator_replays_a_saved_trace_end_to_end() {
    // the full loop through the workload registry: archive a synthetic
    // workload, select `generator: trace` in the config, and get the
    // same serving behaviour back
    use tokensim::workload::WorkloadSpecV2;
    let dir = tokensim::util::TempDir::new().unwrap();
    let path = dir.path().join("archived.jsonl");
    let base = base_cfg(60, 10.0);
    tokensim::workload::save_trace(&path, &base.workload.generate().unwrap()).unwrap();

    let mut replay_cfg = base.clone();
    replay_cfg.workload = WorkloadSpecV2::new("trace").with("path", path.to_str().unwrap());
    let direct = Simulation::from_config(&base).unwrap().run().unwrap();
    let replay = Simulation::from_config(&replay_cfg).unwrap().run().unwrap();
    assert_eq!(direct.records.len(), replay.records.len());
    let (a, b) = (
        MetricSet::new(&direct.records).latency_percentile(0.9),
        MetricSet::new(&replay.records).latency_percentile(0.9),
    );
    assert!((a - b).abs() < 1e-9, "trace generator diverged: {a} vs {b}");
}

#[test]
fn unsorted_trace_replays_with_consistent_ids() {
    // regression: load_trace assigned ids in file order and then sorted
    // by arrival, so an out-of-order trace dispatched request A at
    // request B's arrival — and with `max_requests` truncation the
    // driver indexed out of bounds
    use tokensim::workload::WorkloadSpecV2;
    let dir = tokensim::util::TempDir::new().unwrap();
    let path = dir.path().join("unsorted.jsonl");
    let mut lines = String::new();
    for i in 0..20 {
        lines.push_str(&format!(
            "{{\"arrival\": {:.1}, \"prompt\": 32, \"output\": 8}}\n",
            (20 - i) as f64 * 0.1
        ));
    }
    std::fs::write(&path, lines).unwrap();
    let mut cfg = base_cfg(1, 1.0);
    cfg.workload = WorkloadSpecV2::new("trace")
        .with("path", path.to_str().unwrap())
        .with("max_requests", 10u32);
    let requests = cfg.workload.generate().unwrap();
    assert_eq!(requests.len(), 10);
    for (i, r) in requests.iter().enumerate() {
        assert_eq!(r.id, i, "ids must equal table positions");
        assert!(i == 0 || requests[i - 1].arrival <= r.arrival);
    }
    let report = Simulation::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(report.records.len(), 10);
}

#[test]
fn parallel_sweep_is_bit_identical_to_sequential() {
    use tokensim::experiments::parallel_sweep;
    let cfgs: Vec<SimulationConfig> = [4.0, 8.0, 16.0, 24.0]
        .iter()
        .map(|&qps| base_cfg(80, qps))
        .collect();
    let seq: Vec<_> = cfgs
        .iter()
        .map(|c| Simulation::from_config(c).unwrap().run().unwrap())
        .collect();
    let par = parallel_sweep(&cfgs, |c| Simulation::from_config(c).unwrap().run().unwrap());
    assert_eq!(seq.len(), par.len());
    for (a, b) in seq.iter().zip(&par) {
        assert_eq!(a.records, b.records, "sweep must be bit-deterministic");
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.pool_hits, b.pool_hits);
    }
}

#[test]
fn multi_tenant_generator_from_yaml_reports_per_tenant() {
    let yaml = r#"
model: llama2-7b
cost_model: analytic
cluster:
  workers:
    - hardware: A100
workload:
  generator: multi_tenant
  seed: 5
  tenants:
    - name: chat
      num_requests: 60
      qps: 6.0
      ttft: 5.0
      mtpot: 0.5
    - name: batch
      num_requests: 30
      qps: 2.0
      prompt_len:
        fixed: 512
      output_len:
        fixed: 128
"#;
    use tokensim::workload::WorkloadGenerator as _;
    let cfg = SimulationConfig::from_yaml_str(yaml).unwrap();
    let report = Simulation::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(report.records.len(), 90);
    assert!(report.records.iter().all(|r| r.tenant.is_some()));
    let slos = cfg.workload.build().unwrap().tenant_slos();
    let breakdown = report.metrics().tenant_breakdown(&slos);
    assert_eq!(breakdown.len(), 2);
    let chat = breakdown.iter().find(|t| t.tenant == "chat").unwrap();
    assert_eq!(chat.requests, 60);
    assert!(chat.slo_attainment.is_some());
    let batch = breakdown.iter().find(|t| t.tenant == "batch").unwrap();
    assert_eq!(batch.requests, 30);
    assert_eq!(batch.slo_attainment, None, "no SLO configured for batch");
}

#[test]
fn quarter_flops_decode_hardware_is_slower_end_to_end() {
    let model = ModelSpec::llama2_7b();
    let workload = WorkloadSpec::fixed(100, 16.0, 64, 128);
    let mk = |hw: HardwareSpec| {
        let mut cfg = SimulationConfig::disaggregated(
            model.clone(),
            HardwareSpec::a100_80g(),
            1,
            hw,
            3,
            workload.clone(),
        );
        cfg.compute = ComputeSpec::new("analytic");
        Simulation::from_config(&cfg).unwrap().run().unwrap()
    };
    let full = mk(HardwareSpec::a100_80g());
    let quarter = mk(HardwareSpec::a100_quarter_flops());
    assert!(
        quarter.makespan >= full.makespan,
        "quarter-FLOPS decode cannot be faster"
    );
}

// ---- pluggable scheduler policies ---------------------------------------

#[test]
fn every_example_config_parses_and_runs() {
    // configs/ is the documented CONFIG.md example set: one runnable
    // file per scheduler policy; every one must simulate to completion
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../configs");
    let mut seen = 0;
    let mut entries: Vec<_> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("{}: {e}", dir.display()))
        .map(|e| e.unwrap().path())
        .collect();
    entries.sort();
    for path in entries {
        if path.extension().and_then(|e| e.to_str()) != Some("yaml") {
            continue;
        }
        let cfg = SimulationConfig::from_yaml_file(&path)
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        let report = Simulation::from_config(&cfg).unwrap().run().unwrap();
        // view() rather than records.len(): metrics_sketch.yaml keeps
        // no per-request records, only streaming aggregates
        assert_eq!(
            report.view().len(),
            cfg.workload.generate().unwrap().len(),
            "{}",
            path.display()
        );
        seen += 1;
    }
    assert!(seen >= 14, "expected the documented example configs, saw {seen}");
}

#[test]
fn fast_forward_is_byte_identical_across_every_committed_config() {
    // the decode fast-forward contract, pinned for every example config
    // in configs/ — swap + prefix-cache + multi-tenant + hetero +
    // bursty + trace-replay included: coalescing closed decode batches
    // must leave the deterministic JSON report byte-identical (the CI
    // determinism gate re-checks this through the CLI with
    // `--fast-forward on|off`)
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../configs");
    let mut entries: Vec<_> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("{}: {e}", dir.display()))
        .map(|e| e.unwrap().path())
        .collect();
    entries.sort();
    let mut seen = 0;
    for path in entries {
        if path.extension().and_then(|e| e.to_str()) != Some("yaml") {
            continue;
        }
        let mut cfg = SimulationConfig::from_yaml_file(&path)
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        // the byte-identity contract is for replay window costing; the
        // affine series (configs/affine_window.yaml) is a documented
        // tolerance-bounded approximation, pinned by exp scale instead
        cfg.engine.window_cost = tokensim::config::WindowCost::Replay;
        cfg.engine.fast_forward = false;
        let off = Simulation::from_config(&cfg).unwrap().run().unwrap();
        cfg.engine.fast_forward = true;
        let on = Simulation::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(
            off.to_json().to_string(),
            on.to_json().to_string(),
            "{}: fast-forward changed the simulated report",
            path.display()
        );
        assert!(
            on.events_processed <= off.events_processed,
            "{}: coalescing cannot add events",
            path.display()
        );
        seen += 1;
    }
    assert!(seen >= 17, "expected all committed configs, saw {seen}");
}

#[test]
fn explicit_flat_network_is_byte_identical_to_default() {
    // acceptance gate for the network registry: selecting `flat`
    // explicitly (here under its `single_link` alias, which also pins
    // alias resolution) must reproduce the default pricing byte-for-byte
    // on every config that never chose a topology, with fast-forward
    // off and on
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../configs");
    let mut entries: Vec<_> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("{}: {e}", dir.display()))
        .map(|e| e.unwrap().path())
        .collect();
    entries.sort();
    let mut seen = 0;
    for path in entries {
        if path.extension().and_then(|e| e.to_str()) != Some("yaml") {
            continue;
        }
        let probe = SimulationConfig::from_yaml_file(&path).unwrap();
        if !probe.network.is_flat() {
            continue; // the topology demos legitimately price links differently
        }
        for ff in [false, true] {
            let run = |explicit: bool| {
                let mut cfg = SimulationConfig::from_yaml_file(&path).unwrap();
                cfg.engine.fast_forward = ff;
                if explicit {
                    cfg.network = tokensim::network::NetworkSpec::new("single_link");
                }
                let report = Simulation::from_config(&cfg).unwrap().run().unwrap();
                report.to_json().to_string()
            };
            assert_eq!(
                run(false),
                run(true),
                "{}: explicit flat (ff={ff}) changed the report",
                path.display()
            );
        }
        seen += 1;
    }
    assert!(seen >= 15, "expected the flat-default config suite, saw {seen}");
}

#[test]
fn memoized_hlo_is_byte_identical_across_fast_forward_modes() {
    // PR-7 regression pin: the memoization layer must be invisible in
    // the simulated report. On configs/scale.yaml, run the default
    // (memoized) hlo and the unmemoized hlo under BOTH fast-forward
    // modes; all four reports must byte-diff clean once the memo
    // layer's identity traces (compute name, cache counters) are
    // stripped. `hlo` resolves to the analytic mirror when the PJRT
    // artifacts are absent — the contract is the same either way.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../configs/scale.yaml");
    let mut reports = Vec::new();
    for memoize in [true, false] {
        for ff in [true, false] {
            let mut cfg = SimulationConfig::from_yaml_file(&path).unwrap();
            cfg.compute = ComputeSpec::new("hlo").with("memoize", memoize);
            cfg.engine.fast_forward = ff;
            let report = Simulation::from_config(&cfg).unwrap().run().unwrap();
            if memoize {
                assert!(
                    report.workers[0].cache.is_some(),
                    "memoized run must surface cache stats"
                );
            } else {
                assert!(report.workers[0].cache.is_none());
            }
            reports.push(strip_compute_identity(&report.to_json().to_string()));
        }
    }
    for (i, r) in reports.iter().enumerate().skip(1) {
        assert_eq!(
            &reports[0],
            r,
            "memoize/fast-forward combination {i} changed the simulated report"
        );
    }
}

#[test]
fn chunked_prefill_selected_from_yaml_runs_end_to_end() {
    let yaml = r#"
model: llama2-7b
cost_model: analytic
cluster:
  workers:
    - hardware: A100
      local_scheduler:
        policy: chunked_prefill
        chunk_tokens: 256
        max_batch_size: 32
workload:
  num_requests: 80
  qps: 10.0
  prompt_len:
    uniform:
      min: 64
      max: 1536
  output_len:
    fixed: 32
  seed: 5
"#;
    let cfg = SimulationConfig::from_yaml_str(yaml).unwrap();
    let report = Simulation::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(report.records.len(), 80);
    // chunking splits long prefills: more iterations than requests with
    // room to spare (80 prefill chunks alone would need > 80)
    assert!(report.workers[0].iterations > 80);
}

#[test]
fn chunked_prefill_caps_decode_stalls_under_long_prompts() {
    // long prompts + live decodes: the max inter-token gap with chunked
    // prefill must not exceed the monolithic-prefill gap
    use tokensim::scheduler::PolicySpec;
    let mk = |policy: PolicySpec| {
        let mut cfg = SimulationConfig::single_worker(
            ModelSpec::llama2_7b(),
            HardwareSpec::a100_80g(),
            WorkloadSpec::fixed(60, 6.0, 3000, 64),
        );
        cfg.compute = ComputeSpec::new("analytic");
        cfg.cluster.workers[0].local_scheduler = policy;
        Simulation::from_config(&cfg).unwrap().run().unwrap()
    };
    let mono = mk(PolicySpec::new("continuous").with("max_batched_tokens", 8192u32));
    let chunked = mk(PolicySpec::new("chunked_prefill").with("chunk_tokens", 512u32));
    let worst_gap = |r: &tokensim::cluster::SimulationReport| {
        r.records
            .iter()
            .map(|rec| rec.max_token_gap)
            .fold(0.0f64, f64::max)
    };
    assert_eq!(chunked.records.len(), 60);
    assert!(
        worst_gap(&chunked) <= worst_gap(&mono) * 1.05,
        "chunked {} vs monolithic {}",
        worst_gap(&chunked),
        worst_gap(&mono)
    );
}

#[test]
fn sjf_selected_from_yaml_runs_end_to_end() {
    let yaml = r#"
model: llama2-7b
cost_model: analytic
cluster:
  workers:
    - hardware: A100
      local_scheduler:
        policy: sjf
        max_batch_size: 16
        starvation_age: 5.0
workload:
  num_requests: 120
  qps: 12.0
  prompt_len:
    log_normal:
      median: 128.0
      sigma: 1.0
  output_len:
    fixed: 24
  seed: 9
"#;
    let cfg = SimulationConfig::from_yaml_str(yaml).unwrap();
    let report = Simulation::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(report.records.len(), 120);
}

// ---- pluggable memory managers ------------------------------------------

/// Tight-memory config (the Fig 10 stress shape) with a chosen manager.
fn tight_memory_cfg(memory: tokensim::memory::MemorySpec) -> SimulationConfig {
    let mut hw = HardwareSpec::a100_80g();
    hw.mem_cap = 16e9; // weights 13.5 GB -> tiny KV pool
    let mut cfg = SimulationConfig::single_worker(
        ModelSpec::llama2_7b(),
        hw,
        WorkloadSpec::fixed(30, 50.0, 256, 128),
    );
    cfg.cluster.workers[0].memory = memory;
    cfg.compute = ComputeSpec::new("analytic");
    cfg
}

#[test]
fn swap_manager_selected_from_yaml_runs_end_to_end() {
    let yaml = r#"
model: llama2-7b
cost_model: analytic
cluster:
  workers:
    - hardware:
        name: small-a100
        peak_flops: 312e12
        mem_bw: 2.0e12
        mem_cap: 16e9
      memory:
        manager: swap
        preemption: swap
        swap_blocks: 100000
workload:
  num_requests: 30
  qps: 50.0
  prompt_len:
    fixed: 256
  output_len:
    fixed: 128
  seed: 11
"#;
    let cfg = SimulationConfig::from_yaml_str(yaml).unwrap();
    let report = Simulation::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(report.records.len(), 30);
    let m = MetricSet::new(&report.records);
    assert!(m.total_swaps() > 0, "tight memory must force swaps");
    let totals = report.swap_totals();
    assert!(totals.swap_outs > 0 && totals.swap_ins > 0);
    assert_eq!(report.workers[0].manager, "swap");
}

#[test]
fn swap_preemption_strictly_reduces_reprefilled_tokens() {
    use tokensim::memory::MemorySpec;
    let recompute = Simulation::from_config(&tight_memory_cfg(
        MemorySpec::new("swap").with("preemption", "recompute"),
    ))
    .unwrap()
    .run()
    .unwrap();
    let swap = Simulation::from_config(&tight_memory_cfg(MemorySpec::new("swap")))
        .unwrap()
        .run()
        .unwrap();
    let (mr, ms) = (
        MetricSet::new(&recompute.records),
        MetricSet::new(&swap.records),
    );
    assert!(mr.total_preemptions() > 0);
    assert!(ms.total_swaps() > 0);
    assert!(
        ms.total_recomputed_tokens() < mr.total_recomputed_tokens(),
        "swap preemption must re-prefill strictly fewer tokens: {} vs {}",
        ms.total_recomputed_tokens(),
        mr.total_recomputed_tokens()
    );
    // the avoided recompute work is paid in host-link traffic instead
    assert!(swap.swap_totals().blocks_out > 0);
}

#[test]
fn token_contiguous_over_reserves_and_never_preempts() {
    use tokensim::memory::MemorySpec;
    let paged = Simulation::from_config(&tight_memory_cfg(MemorySpec::default()))
        .unwrap()
        .run()
        .unwrap();
    let contiguous =
        Simulation::from_config(&tight_memory_cfg(MemorySpec::new("token_contiguous")))
            .unwrap()
            .run()
            .unwrap();
    assert_eq!(contiguous.records.len(), 30);
    assert_eq!(
        MetricSet::new(&contiguous.records).total_preemptions(),
        0,
        "max-length reservation can never run out mid-decode"
    );
    assert!(
        MetricSet::new(&paged.records).total_preemptions() > 0,
        "paged must preempt on this workload (the contrast the exp shows)"
    );
}

#[test]
fn prefix_cache_manager_reduces_ttft_like_the_cluster_pool() {
    use tokensim::memory::MemorySpec;
    let convs = ConversationSpec::chatbot(150, 8.0, 128, 64).generate();
    let run = |memory: MemorySpec| {
        let mut cfg = base_cfg(1, 1.0);
        cfg.cluster.workers[0].memory = memory;
        Simulation::from_conversations(&cfg, &convs).unwrap().run().unwrap()
    };
    let off = run(MemorySpec::default());
    let on = run(MemorySpec::new("prefix_cache").with("capacity_blocks", 1_000_000u64));
    assert_eq!(off.pool_hits, 0);
    assert!(on.pool_hits > 0, "manager-layer pool must hit");
    assert!(on.pool_hit_rate() > 0.0);
    let ttft = |recs: &[tokensim::metrics::RequestRecord]| {
        let later: Vec<f64> = recs
            .iter()
            .filter(|r| r.round > 0)
            .map(|r| r.ttft())
            .collect();
        later.iter().sum::<f64>() / later.len() as f64
    };
    assert!(
        ttft(&on.records) < ttft(&off.records),
        "cached rounds must start faster through the registry path too"
    );
}

// ---- pluggable compute models -------------------------------------------

#[test]
fn hetero_pd_config_runs_mixed_hardware_with_per_worker_compute() {
    // the documented heterogeneous example: A100 prefill under the
    // table model, V100 decode under roofline, per-worker `compute:`
    // overrides routed through the compute registry
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../configs/hetero_pd.yaml");
    let cfg = SimulationConfig::from_yaml_file(&path).unwrap();
    assert_eq!(cfg.compute.name, "analytic");
    assert_eq!(cfg.cluster.workers[0].compute.as_ref().unwrap().name, "table");
    assert_eq!(cfg.cluster.workers[1].compute.as_ref().unwrap().name, "roofline");
    let report = Simulation::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(report.records.len(), 60);
    assert_eq!(report.workers.len(), 4, "1 prefill + 3 decode");
    assert!(report.workers[0].compute.starts_with("table["));
    assert_eq!(report.workers[0].hardware, "A100");
    for w in &report.workers[1..] {
        assert!(w.compute.starts_with("roofline["), "{}", w.compute);
        assert_eq!(w.hardware, "V100");
        assert!(w.iterations > 0, "decode worker {} idle", w.id);
    }
}

#[test]
fn compute_models_selected_from_yaml_change_predicted_latency() {
    // the same cluster under two registered models must simulate to
    // completion under both and actually use different cost physics
    let mk = |compute_yaml: &str| {
        let yaml = format!(
            "model: llama2-7b\n{compute_yaml}cluster:\n  workers:\n    - hardware: A100\nworkload:\n  num_requests: 50\n  qps: 5.0\n  prompt_len:\n    fixed: 128\n  output_len:\n    fixed: 32\n  seed: 6\n"
        );
        let cfg = SimulationConfig::from_yaml_str(&yaml).unwrap();
        Simulation::from_config(&cfg).unwrap().run().unwrap()
    };
    let analytic = mk("compute:\n  model: analytic\n");
    let roofline = mk("compute:\n  model: roofline\n");
    assert_eq!(analytic.records.len(), 50);
    assert_eq!(roofline.records.len(), 50);
    assert!(analytic.workers[0].compute.starts_with("analytic["));
    assert!(roofline.workers[0].compute.starts_with("roofline["));
    let (pa, pr) = (
        MetricSet::new(&analytic.records).latency_percentile(0.5),
        MetricSet::new(&roofline.records).latency_percentile(0.5),
    );
    assert!(
        (pa - pr).abs() / pa > 1e-3,
        "distinct models should predict distinct latencies: {pa} vs {pr}"
    );
    // roofline drops per-op launch overheads, so it can only be faster
    assert!(pr < pa, "roofline {pr} must lower-bound analytic {pa}");
}

#[test]
fn oracle_as_registry_model_runs_noisy_but_deterministic() {
    let mk = || {
        let mut cfg = base_cfg(40, 6.0);
        cfg.compute = ComputeSpec::new("oracle").with("seed", 3u64);
        Simulation::from_config(&cfg).unwrap().run().unwrap()
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.records.len(), 40);
    assert_eq!(a.records, b.records, "seeded oracle noise must replay");
    assert!(a.workers[0].compute == "oracle");
}

#[test]
fn power_of_two_selected_from_yaml_runs_end_to_end() {
    let yaml = r#"
model: llama2-7b
cost_model: analytic
cluster:
  workers:
    - hardware: A100
      quantity: 4
  scheduler:
    global:
      policy: power_of_two
workload:
  num_requests: 160
  qps: 40.0
  prompt_len:
    fixed: 128
  output_len:
    fixed: 32
  seed: 2
"#;
    let cfg = SimulationConfig::from_yaml_str(yaml).unwrap();
    let report = Simulation::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(report.records.len(), 160);
    // the two-choices rule must spread a 40 qps stream over all workers
    assert!(report.workers.iter().all(|w| w.iterations > 0));
}

/// Satellite of the streaming-metrics PR: sketch mode must change how
/// metrics are *aggregated*, never what the simulator *does*. Running
/// the committed multi-tenant config both ways, everything that comes
/// out of the event loop (timeline samples, worker stats, makespan,
/// counts, goodput) is identical, and the sketch quantiles sit inside
/// the documented relative-error window of the exact order statistics.
#[test]
fn sketch_mode_matches_exact_on_multi_tenant_config() {
    use tokensim::metrics::MetricsMode;
    use tokensim::workload::WorkloadGenerator as _;

    // `est` must fall in the rank window [floor(pos), ceil(pos)]
    // widened by the sketch's relative error (plus float slack)
    fn in_window(sorted: &[f64], q: f64, est: f64, eps: f64) -> bool {
        let pos = q * (sorted.len() - 1) as f64;
        let lo = sorted[pos.floor() as usize] * (1.0 - eps) - 1e-12;
        let hi = sorted[pos.ceil() as usize] * (1.0 + eps) + 1e-12;
        lo <= est && est <= hi
    }

    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../configs/multi_tenant.yaml");
    let mut cfg = SimulationConfig::from_yaml_file(&path).unwrap();
    cfg.sample_period = 0.25; // make the timeline-equality assert non-vacuous

    let exact = Simulation::from_config(&cfg).unwrap().run().unwrap();
    cfg.metrics.mode = MetricsMode::Sketch;
    let sketch = Simulation::from_config(&cfg).unwrap().run().unwrap();

    // the simulation itself is untouched by the metrics mode
    assert!(!exact.timeline.samples.is_empty());
    assert_eq!(exact.timeline.samples, sketch.timeline.samples);
    assert_eq!(exact.workers, sketch.workers);
    assert_eq!(exact.events_processed, sketch.events_processed);
    assert_eq!(exact.makespan, sketch.makespan);
    assert_eq!(exact.sim_end, sketch.sim_end);

    // sketch mode drops per-request records but keeps every aggregate
    assert!(!exact.records.is_empty());
    assert!(sketch.records.is_empty());
    let stream = sketch.stream.as_ref().expect("sketch mode keeps a stream");
    assert_eq!(stream.len(), exact.records.len());
    assert_eq!(sketch.view().len(), exact.records.len());

    // count-ratio metrics are bit-equal: same numerators, denominators
    assert_eq!(exact.request_throughput(), sketch.request_throughput());
    assert_eq!(exact.token_throughput(), sketch.token_throughput());
    assert_eq!(exact.slo_attainment(), sketch.slo_attainment());
    assert_eq!(exact.slo_throughput(), sketch.slo_throughput());

    // per-tenant parity: same tenants in the same order, same counts
    // and attainment, quantiles within the error window
    let slos = cfg.workload.build().unwrap().tenant_slos();
    let eb = exact.metrics().tenant_breakdown(&slos);
    let sb = sketch.view().tenant_breakdown(&slos);
    let eps = stream.relative_error();
    assert_eq!(eb.len(), sb.len());
    assert!(eb.len() >= 2, "multi_tenant.yaml defines several tenants");
    for (e, s) in eb.iter().zip(&sb) {
        assert_eq!(e.tenant, s.tenant);
        assert_eq!(e.requests, s.requests);
        assert_eq!(e.slo_attainment, s.slo_attainment, "{}", e.tenant);
        let mut ttfts: Vec<f64> = exact
            .records
            .iter()
            .filter(|r| r.tenant.as_deref() == Some(e.tenant.as_str()))
            .map(|r| r.ttft())
            .collect();
        ttfts.sort_by(|a, b| a.total_cmp(b));
        for (q, est) in [(0.50, s.ttft_p50), (0.99, s.ttft_p99)] {
            assert!(
                in_window(&ttfts, q, est, eps),
                "{} ttft p{} = {est}",
                e.tenant,
                q * 100.0
            );
        }
        let mut tbts: Vec<f64> = exact
            .records
            .iter()
            .filter(|r| r.tenant.as_deref() == Some(e.tenant.as_str()))
            .map(|r| r.max_token_gap)
            .collect();
        tbts.sort_by(|a, b| a.total_cmp(b));
        assert!(
            in_window(&tbts, 0.99, s.tbt_p99, eps),
            "{} tbt p99 = {}",
            e.tenant,
            s.tbt_p99
        );
    }

    // whole-run latency quantiles within the window
    let mut lats: Vec<f64> = exact.records.iter().map(|r| r.latency()).collect();
    lats.sort_by(|a, b| a.total_cmp(b));
    for q in [0.5, 0.9, 0.99] {
        let est = sketch.view().latency_percentile(q);
        assert!(in_window(&lats, q, est, eps), "latency p{} = {est}", q * 100.0);
    }
}

// ---------------------------------------------------------------------------
// CLI: lint subcommand, fixtures, strict flags, list, --audit
// ---------------------------------------------------------------------------

fn tokensim_cmd(args: &[&str]) -> std::process::Output {
    std::process::Command::new(env!("CARGO_BIN_EXE_tokensim"))
        .args(args)
        .output()
        .expect("spawn tokensim")
}

/// Every committed example config must lint clean even with warnings
/// denied — the same gate CI runs.
#[test]
fn committed_configs_lint_clean_under_deny_warnings() {
    let mut files: Vec<String> = std::fs::read_dir("../configs")
        .expect("configs dir")
        .filter_map(|e| {
            let p = e.unwrap().path();
            (p.extension().and_then(|x| x.to_str()) == Some("yaml"))
                .then(|| p.to_str().unwrap().to_string())
        })
        .collect();
    files.sort();
    assert!(files.len() >= 12, "expected the committed config suite, got {files:?}");
    let mut args = vec!["lint"];
    args.extend(files.iter().map(String::as_str));
    args.push("--deny-warnings");
    let out = tokensim_cmd(&args);
    assert!(
        out.status.success(),
        "committed configs must lint clean:\n{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
}

/// Each `configs/fixtures/bad_*.yaml` declares its expected diagnostic
/// in a `# expect: <CODE>` header; lint must fail it (warnings denied)
/// and the JSON report must carry that code exactly once.
#[test]
fn lint_fixtures_fail_with_their_expected_code() {
    let mut fixtures: Vec<std::path::PathBuf> = std::fs::read_dir("../configs/fixtures")
        .expect("fixtures dir")
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().and_then(|x| x.to_str()) == Some("yaml"))
        .collect();
    fixtures.sort();
    assert!(fixtures.len() >= 12, "expected the fixture suite, got {fixtures:?}");
    for f in &fixtures {
        let path = f.to_str().unwrap();
        let text = std::fs::read_to_string(f).unwrap();
        let expect = text
            .lines()
            .next()
            .and_then(|l| l.strip_prefix("# expect: "))
            .unwrap_or_else(|| panic!("{path}: missing '# expect: <CODE>' header"))
            .trim();
        let out = tokensim_cmd(&["lint", path, "--deny-warnings", "--json"]);
        assert!(!out.status.success(), "{path}: lint unexpectedly passed");
        let stdout = String::from_utf8_lossy(&out.stdout);
        let needle = format!("\"code\":\"{expect}\"");
        assert_eq!(
            stdout.matches(&needle).count(),
            1,
            "{path}: expected exactly one {expect} diagnostic in {stdout}"
        );
    }
}

/// Unknown flags and commands are hard errors with did-you-mean hints,
/// not silently ignored arguments.
#[test]
fn unknown_flags_and_commands_are_rejected_with_hints() {
    let out = tokensim_cmd(&["run", "--confg", "x.yaml"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown flag '--confg'"), "{err}");
    assert!(err.contains("did you mean '--config'?"), "{err}");

    let out = tokensim_cmd(&["lnt", "../configs/static.yaml"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("did you mean 'lint'?"), "{err}");

    let out = tokensim_cmd(&["run", "--config"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("requires a value"), "{err}");

    let out = tokensim_cmd(&["lint"]);
    assert!(!out.status.success(), "lint with no files must fail");
}

#[test]
fn list_enumerates_lint_rules_and_engine_knobs() {
    let out = tokensim_cmd(&["list"]);
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    for needle in [
        "E001",
        "E030",
        "W040",
        "I042",
        "E050",
        "E060",
        "W062",
        "E070",
        "W072",
        "I074",
        "A001",
        "A006",
        "A007",
        "fast_forward",
        "window_cost",
        "audit",
        "sketch_error",
        "network topologies",
        "nvlink_island",
        "fat_tree",
        "link presets",
        "static analyzer bound kinds",
        "compute-saturation",
        "memory-feasibility",
    ] {
        assert!(stdout.contains(needle), "list output missing {needle}:\n{stdout}");
    }
}

/// The static analyzer's headline contract, checked against every
/// committed example config: the closed-form throughput bound is a true
/// upper bound on the simulated throughput, and deriving it costs at
/// most 3 cost-model probes per worker config — zero simulation steps.
#[test]
fn static_bound_holds_on_every_committed_config() {
    use tokensim::lint::analyze;

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../configs");
    let mut entries: Vec<_> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("{}: {e}", dir.display()))
        .map(|e| e.unwrap().path())
        .collect();
    entries.sort();
    let mut seen = 0;
    let mut bounded = 0;
    for path in entries {
        if path.extension().and_then(|e| e.to_str()) != Some("yaml") {
            continue;
        }
        let mut cfg = SimulationConfig::from_yaml_file(&path)
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        cfg.engine.fast_forward = true;
        let requests = cfg
            .workload
            .generate()
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        let a = analyze::analyze(&cfg, &requests);
        assert!(
            a.probe_calls <= 3 * cfg.cluster.workers.len(),
            "{}: {} probes for {} worker configs",
            path.display(),
            a.probe_calls,
            cfg.cluster.workers.len()
        );
        let report = Simulation::from_config(&cfg).unwrap().run().unwrap();
        let achieved = report.records.len() as f64 / report.makespan.max(1e-12);
        if let Some(bound) = a.throughput_ub {
            bounded += 1;
            assert!(
                achieved <= bound * (1.0 + 1e-9),
                "{}: simulated {achieved} req/s beats the static bound {bound}",
                path.display()
            );
        }
        seen += 1;
    }
    assert!(seen >= 17, "expected all committed configs, saw {seen}");
    // most committed configs use probe-able cost models; the bound must
    // actually exist somewhere or this test is vacuous
    assert!(bounded >= 12, "expected finite bounds on most configs, got {bounded}");
}

/// `lint` and `analyze` accept directory arguments: non-recursive, so
/// `configs/fixtures/` stays excluded and the committed suite passes
/// even with warnings denied.
#[test]
fn lint_accepts_directory_arguments_excluding_fixtures() {
    let out = tokensim_cmd(&["lint", "../configs", "--deny-warnings"]);
    assert!(
        out.status.success(),
        "directory lint must pass (fixtures excluded):\n{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let out = tokensim_cmd(&["analyze", "../configs", "--deny-warnings"]);
    assert!(
        out.status.success(),
        "directory analyze must pass (fixtures excluded):\n{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    // a directory with no yaml files is a hard error, not a silent no-op
    let empty = tokensim::util::TempDir::new().unwrap();
    let out = tokensim_cmd(&["lint", empty.path().to_str().unwrap()]);
    assert!(!out.status.success(), "empty directory must be rejected");
}

/// `analyze --json` emits one {report, analysis} object per config with
/// the bound fields and the I074 summary diagnostic.
#[test]
fn analyze_json_reports_bounds_and_summary() {
    let out = tokensim_cmd(&["analyze", "../configs/continuous.yaml", "--json"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    for needle in [
        "\"report\":",
        "\"analysis\":",
        "\"code\":\"I074\"",
        "\"throughput_ub\":",
        "\"rho_decode\":",
        "\"kv_pool_tokens\":",
        "\"max_feasible_qps\":",
        "\"probe_calls\":",
        "\"workers\":",
        "\"links\":",
    ] {
        assert!(stdout.contains(needle), "analyze --json missing {needle}:\n{stdout}");
    }
    // human mode renders the bound report and the closing tally line
    let out = tokensim_cmd(&["analyze", "../configs/continuous.yaml"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("1 config(s) analyzed, 0 failing"), "{stdout}");
}

/// `--audit` re-checks every engine invariant but must not perturb the
/// simulation: the JSON report diffs byte-for-byte against a plain run.
#[test]
fn run_with_audit_flag_is_byte_identical_to_plain_run() {
    let dir = tokensim::util::TempDir::new().unwrap();
    let plain = dir.path().join("plain.json");
    let audited = dir.path().join("audited.json");
    let cfg = "../configs/continuous.yaml";
    let out = tokensim_cmd(&["run", "--config", cfg, "--json", plain.to_str().unwrap()]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out =
        tokensim_cmd(&["run", "--config", cfg, "--json", audited.to_str().unwrap(), "--audit"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let a = std::fs::read(&plain).unwrap();
    let b = std::fs::read(&audited).unwrap();
    assert!(!a.is_empty() && a == b, "audit mode changed the report bytes");
}
