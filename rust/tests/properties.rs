//! Randomized property tests (hand-rolled sweeps — proptest is not
//! available in this offline build; see Cargo.toml). Each property runs
//! across a deterministic seed sweep and asserts an invariant of the
//! coordinator, scheduler, or memory manager.

use std::collections::VecDeque;

use tokensim::cluster::Simulation;
use tokensim::compute::{compute_models, BatchDesc, ComputeCtx, ComputeModel, ComputeSpec};
use tokensim::config::SimulationConfig;
use tokensim::hardware::HardwareSpec;
use tokensim::memory::{
    AllocOutcome, MemoryManager, MemorySpec, PagedBlockManager, PoolCache, PreemptionPolicy,
    PrefixCacheManager, SwapMemoryManager, TokenContiguousManager,
};
use tokensim::model::ModelSpec;
use tokensim::request::Request;
use tokensim::scheduler::{
    ChunkedPrefill, ContinuousBatching, LocalSchedCtx, LocalScheduler, PolicySpec,
    ShortestJobFirst, StaticBatching,
};
use tokensim::sim::SimRng;
use tokensim::workload::{ArrivalProcess, LengthDistribution, WorkloadSpec};

const SEEDS: std::ops::Range<u64> = 0..25;

// ---- memory-manager invariants -----------------------------------------

#[test]
fn prop_block_manager_conserves_blocks() {
    for seed in SEEDS {
        let mut rng = SimRng::new(seed, "mem-prop");
        let total = 1 + rng.uniform_int(1, 500);
        let mut mem = PagedBlockManager::with_blocks(total, 16, 1024);
        let mut live: Vec<usize> = Vec::new();
        for step in 0..300 {
            match rng.pick(3) {
                0 => {
                    let rid = (seed as usize) * 1000 + step;
                    let tokens = rng.uniform_int(1, 900) as u32;
                    if mem.reserve(rid, tokens) == AllocOutcome::Ok {
                        live.push(rid);
                    }
                }
                1 => {
                    if !live.is_empty() {
                        let rid = live.swap_remove(rng.pick(live.len()));
                        mem.release(rid);
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let rid = live[rng.pick(live.len())];
                        let grown = mem.blocks_held(rid) as u32 * 16 + rng.uniform_int(1, 64) as u32;
                        let _ = mem.reserve(rid, grown);
                    }
                }
            }
            assert!(mem.check_invariants(), "seed {seed} step {step}");
            assert!(mem.free_blocks() <= mem.total_blocks());
        }
    }
}

/// Every registered manager shape, built small for op-sequence sweeps.
/// `(manager, swap_capable)`.
fn managers_under_test(total_blocks: u64) -> Vec<(Box<dyn MemoryManager>, bool)> {
    vec![
        (
            Box::new(PagedBlockManager::with_blocks(total_blocks, 16, 1024))
                as Box<dyn MemoryManager>,
            false,
        ),
        (
            Box::new(TokenContiguousManager::with_tokens(total_blocks * 16, 64))
                as Box<dyn MemoryManager>,
            false,
        ),
        (
            Box::new(SwapMemoryManager::with_blocks(
                total_blocks,
                16,
                1024,
                total_blocks * 4,
            )) as Box<dyn MemoryManager>,
            true,
        ),
        (
            Box::new(PrefixCacheManager::with_blocks(total_blocks, 16, 1024, 64))
                as Box<dyn MemoryManager>,
            false,
        ),
    ]
}

#[test]
fn prop_all_managers_conserve_memory_under_random_ops() {
    // invariants across every manager, any op sequence:
    //   * used + free == total (check_invariants)
    //   * alloc/release balance to zero once everything is released
    //   * preemption_frees matches the blocks preempt-ops actually freed
    //   * swap-out followed by swap-in preserves the blocks held
    for seed in SEEDS {
        let mut rng = SimRng::new(seed, "mgr-matrix-prop");
        let total = 1 + rng.uniform_int(1, 400);
        for (mut mem, swap_capable) in managers_under_test(total) {
            let mut live: Vec<usize> = Vec::new();
            let mut swapped: Vec<(usize, u64)> = Vec::new();
            let mut preempt_freed: u64 = 0;
            for step in 0..300 {
                match rng.pick(5) {
                    0 => {
                        let rid = (seed as usize) * 10_000 + step;
                        let tokens = rng.uniform_int(1, 900) as u32;
                        if mem.reserve(rid, tokens) == AllocOutcome::Ok {
                            live.push(rid);
                        }
                    }
                    1 => {
                        if !live.is_empty() {
                            let rid = live.swap_remove(rng.pick(live.len()));
                            mem.release(rid);
                        }
                    }
                    2 => {
                        if !live.is_empty() {
                            let rid = live.swap_remove(rng.pick(live.len()));
                            preempt_freed += mem.release_preempted(rid);
                        }
                    }
                    3 => {
                        // swap-out (inert on non-swap managers)
                        if !live.is_empty() {
                            let pos = rng.pick(live.len());
                            let rid = live[pos];
                            let held = mem.blocks_held(rid);
                            match mem.swap_out(rid) {
                                Some(blocks) => {
                                    assert!(swap_capable, "seed {seed}: unexpected swap support");
                                    assert_eq!(blocks, held, "swap-out moves exactly the held blocks");
                                    assert_eq!(mem.blocks_held(rid), 0);
                                    live.swap_remove(pos);
                                    swapped.push((rid, blocks));
                                    preempt_freed += blocks;
                                }
                                None => {
                                    assert_eq!(mem.blocks_held(rid), held, "failed swap is a no-op");
                                }
                            }
                        }
                    }
                    _ => {
                        // swap-in with enough tokens to cover the parked blocks
                        if !swapped.is_empty() {
                            let pos = rng.pick(swapped.len());
                            let (rid, blocks) = swapped[pos];
                            let tokens = (blocks * mem.block_size() as u64) as u32;
                            if mem.swap_in(rid, tokens.max(1)) == AllocOutcome::Ok {
                                assert_eq!(
                                    mem.blocks_held(rid),
                                    blocks,
                                    "seed {seed}: swap roundtrip must preserve KV blocks"
                                );
                                swapped.swap_remove(pos);
                                live.push(rid);
                            } else {
                                assert_eq!(mem.swapped_blocks(rid), blocks, "host copy kept");
                            }
                        }
                    }
                }
                assert!(mem.check_invariants(), "seed {seed} step {step} ({})", mem.name());
                assert!(mem.free_blocks() <= mem.total_blocks());
                assert_eq!(
                    mem.used_blocks(),
                    mem.total_blocks() - mem.free_blocks(),
                    "granularity views must agree"
                );
            }
            assert_eq!(
                mem.preemption_frees(),
                preempt_freed,
                "seed {seed} ({}): preemption_frees must match blocks actually released",
                mem.name()
            );
            // drain: alloc/release must balance to zero
            for rid in live.drain(..) {
                mem.release(rid);
            }
            for (rid, _) in swapped.drain(..) {
                mem.discard_swapped(rid);
            }
            assert_eq!(
                mem.free_blocks(),
                mem.total_blocks(),
                "seed {seed} ({}): all blocks must return to the pool",
                mem.name()
            );
            assert!(mem.check_invariants());
        }
    }
}

#[test]
fn prop_pool_cache_never_exceeds_capacity() {
    for seed in SEEDS {
        let mut rng = SimRng::new(seed, "pool-prop");
        let cap = 1 + rng.uniform_int(1, 200);
        let mut pool = PoolCache::new(cap, 16);
        for _ in 0..500 {
            let conv = rng.pick(40);
            match rng.pick(3) {
                0 => pool.store(conv, rng.uniform_int(1, 4000) as u32),
                1 => {
                    let _ = pool.lookup(conv, rng.uniform_int(1, 4000) as u32);
                }
                _ => pool.invalidate(conv),
            }
            assert!(pool.check_invariants(), "seed {seed}");
            assert!(pool.used_blocks() <= cap);
        }
    }
}

// ---- scheduler invariants ------------------------------------------------

fn random_policy(rng: &mut SimRng) -> Box<dyn LocalScheduler> {
    let cap = if rng.gen_bool(0.5) {
        Some(1 + rng.uniform_int(0, 64) as u32)
    } else {
        None
    };
    match rng.pick(5) {
        0 => Box::new(ContinuousBatching {
            max_batched_tokens: 256 + rng.uniform_int(0, 8192) as u32,
            max_batch_size: cap,
            mixed_batching: rng.gen_bool(0.3),
        }),
        1 => Box::new(StaticBatching {
            batch_size: 1 + rng.uniform_int(0, 32) as u32,
            max_linger: rng.uniform(0.0, 2.0),
        }),
        2 => Box::new(ChunkedPrefill {
            chunk_tokens: 1 + rng.uniform_int(0, 1024) as u32,
            max_batch_size: cap,
        }),
        3 => Box::new(ShortestJobFirst {
            max_batched_tokens: 256 + rng.uniform_int(0, 8192) as u32,
            max_batch_size: cap,
            starvation_age: if rng.gen_bool(0.5) {
                Some(rng.uniform(0.0, 5.0))
            } else {
                None
            },
        }),
        _ => Box::new(ContinuousBatching::vllm_default()),
    }
}

#[test]
fn prop_batch_plans_respect_memory_and_phases() {
    for seed in SEEDS {
        let mut rng = SimRng::new(seed, "sched-prop");
        let mut policy = random_policy(&mut rng);
        let n = 1 + rng.pick(40);
        let mut requests: Vec<Request> = (0..n)
            .map(|i| {
                Request::new(
                    i,
                    i,
                    0,
                    1 + rng.uniform_int(0, 512) as u32,
                    1 + rng.uniform_int(0, 64) as u32,
                    0.0,
                )
            })
            .collect();
        let mut waiting: VecDeque<usize> = (0..n).collect();
        let mut running: Vec<usize> = Vec::new();
        let mut mem = PagedBlockManager::with_blocks(1 + rng.uniform_int(1, 400), 16, 1024);

        for step in 0..50 {
            let mut ctx = LocalSchedCtx {
                requests: &mut requests,
                waiting: &mut waiting,
                running: &mut running,
                mem: &mut mem,
                now: step as f64,
                draining: true,
                oldest_wait: Some(0.0),
                preemption: PreemptionPolicy::Recompute,
            };
            let plan = policy.form_batch(&mut ctx);
            // members unique and consistent with batch slots
            let mut seen = std::collections::HashSet::new();
            for &rid in &plan.members {
                assert!(seen.insert(rid), "duplicate member {rid} (seed {seed})");
            }
            assert_eq!(plan.members.len(), plan.batch.len());
            // every member has a memory reservation covering its KV
            for (slot, &rid) in plan.members.iter().enumerate() {
                let tokens = plan.batch.ctx[slot] + plan.batch.new[slot];
                assert!(
                    mem.blocks_held(rid) >= (tokens as u64).div_ceil(16),
                    "seed {seed}: member {rid} under-reserved"
                );
            }
            assert!(mem.check_invariants());
            if plan.is_empty() {
                break;
            }
            // emulate iteration completion
            let mut finished = Vec::new();
            for (slot, &rid) in plan.members.iter().enumerate() {
                let new = plan.batch.new[slot];
                let r = &mut requests[rid];
                match r.phase {
                    tokensim::request::Phase::Prefill => {
                        r.prompt_done += new;
                        r.ctx_in_cache = r.prompt_done;
                        if r.prefill_done() {
                            r.generated += 1;
                            r.phase = tokensim::request::Phase::Decode;
                        }
                    }
                    tokensim::request::Phase::Decode => {
                        r.generated += 1;
                        r.ctx_in_cache += 1;
                    }
                    _ => {}
                }
                if r.done() {
                    finished.push(rid);
                }
            }
            for rid in finished {
                requests[rid].phase = tokensim::request::Phase::Finished;
                running.retain(|&x| x != rid);
                mem.release(rid);
            }
        }
    }
}

// ---- whole-simulation invariants -----------------------------------------

fn random_cfg(seed: u64) -> SimulationConfig {
    let mut rng = SimRng::new(seed, "cfg-prop");
    let n = 20 + rng.pick(60);
    let qps = rng.uniform(1.0, 40.0);
    let workload = WorkloadSpec {
        num_requests: n,
        qps,
        arrival: match rng.pick(3) {
            0 => ArrivalProcess::Poisson,
            1 => ArrivalProcess::Uniform,
            _ => ArrivalProcess::Gamma { cv: 2.0 },
        },
        prompt_len: LengthDistribution::Uniform {
            min: 1 + rng.uniform_int(0, 32) as u32,
            max: 64 + rng.uniform_int(0, 512) as u32,
        },
        output_len: LengthDistribution::Uniform {
            min: 1,
            max: 1 + rng.uniform_int(0, 128) as u32,
        },
        seed,
    };
    let mut cfg = if rng.gen_bool(0.4) {
        SimulationConfig::disaggregated(
            ModelSpec::llama2_7b(),
            HardwareSpec::a100_80g(),
            1,
            HardwareSpec::a100_80g(),
            1 + rng.pick(3) as u32,
            workload,
        )
    } else {
        SimulationConfig::single_worker(
            ModelSpec::llama2_7b(),
            HardwareSpec::a100_80g(),
            workload,
        )
    };
    cfg.compute = ComputeSpec::new("analytic");
    // occasionally a tight memory to provoke preemptions
    if rng.gen_bool(0.3) {
        for w in &mut cfg.cluster.workers {
            w.hardware.mem_cap = 16e9;
        }
    }
    // random memory managers through the registry spec layer, so the
    // whole-simulation invariants cover every built-in plugin x both
    // preemption policies
    let memory = match rng.pick(4) {
        0 => MemorySpec::default(),
        1 => MemorySpec::new("token_contiguous"),
        2 => MemorySpec::new("swap"), // defaults to swap preemption
        _ => MemorySpec::new("prefix_cache"),
    };
    let memory = if rng.gen_bool(0.3) {
        memory.with("preemption", "recompute")
    } else {
        memory
    };
    for w in &mut cfg.cluster.workers {
        w.memory = memory.clone();
    }
    // random scheduler policies through the registry spec layer, so the
    // whole-simulation invariants cover every continuous-family plugin
    if rng.gen_bool(0.5) {
        let spec = match rng.pick(3) {
            0 => PolicySpec::new("chunked_prefill")
                .with("chunk_tokens", 128 + rng.uniform_int(0, 512) as u32),
            1 => PolicySpec::new("sjf"),
            _ => PolicySpec::new("continuous"),
        };
        for w in &mut cfg.cluster.workers {
            w.local_scheduler = spec.clone();
        }
    }
    cfg.cluster.scheduler.global = match rng.pick(4) {
        0 => PolicySpec::new("round_robin"),
        1 => PolicySpec::new("least_loaded"),
        2 => PolicySpec::new("random"),
        _ => PolicySpec::new("power_of_two"),
    };
    cfg
}

#[test]
fn prop_every_request_finishes_exactly_once() {
    for seed in SEEDS {
        let cfg = random_cfg(seed);
        let n = cfg.workload.generate().unwrap().len();
        let report = Simulation::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(report.records.len(), n, "seed {seed}");
        let mut ids: Vec<usize> = report.records.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "seed {seed}: duplicate completions");
    }
}

#[test]
fn prop_causality_and_token_accounting() {
    for seed in SEEDS {
        let cfg = random_cfg(seed);
        let requests = cfg.workload.generate().unwrap();
        let report = Simulation::from_config(&cfg).unwrap().run().unwrap();
        for (rec, req) in report.records.iter().zip(&requests) {
            assert_eq!(rec.prompt_len, req.prompt_len, "seed {seed}");
            assert_eq!(rec.output_len, req.output_len, "seed {seed}");
            assert!(rec.first_token >= rec.arrival, "seed {seed}");
            assert!(rec.finished >= rec.first_token, "seed {seed}");
            // a request with one output token finishes at its first token
            if rec.output_len == 1 {
                assert!((rec.finished - rec.first_token).abs() < 1e-12);
            }
        }
    }
}

#[test]
fn prop_runs_are_bit_deterministic() {
    for seed in SEEDS.step_by(5) {
        let cfg = random_cfg(seed);
        let a = Simulation::from_config(&cfg).unwrap().run().unwrap();
        let b = Simulation::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(a.records, b.records, "seed {seed}");
        assert_eq!(a.events_processed, b.events_processed);
    }
}

#[test]
fn prop_fast_forward_is_invisible_in_reports() {
    // the decode fast-forward contract at property scale: coalescing
    // closed-batch decode iterations must not change ANY simulated
    // quantity, across random workloads x memory managers x scheduler
    // policies (preemption-heavy, multi-worker and disaggregated shapes
    // included) — only the internal heap-event count may shrink
    for seed in SEEDS.step_by(2) {
        let mut cfg = random_cfg(seed);
        cfg.engine.fast_forward = false;
        let off = Simulation::from_config(&cfg).unwrap().run().unwrap();
        cfg.engine.fast_forward = true;
        let on = Simulation::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(
            off.to_json().to_string(),
            on.to_json().to_string(),
            "seed {seed}: fast-forward changed the simulated report"
        );
        assert_eq!(off.records, on.records, "seed {seed}");
        assert!(
            on.events_processed <= off.events_processed,
            "seed {seed}: coalescing cannot add events ({} vs {})",
            on.events_processed,
            off.events_processed
        );
    }
}

#[test]
fn prop_audit_mode_finds_no_violations_and_is_invisible_in_reports() {
    // the invariant-audit contract at property scale: `engine: audit`
    // re-checks conservation laws at every event boundary (token
    // accounting, block release, window boundaries, batch geometry,
    // record consistency) across random workloads x memory managers x
    // scheduler policies — every check must hold, and because the
    // checks are read-only the report must diff byte-for-byte against
    // the same seed with auditing off
    for seed in SEEDS.step_by(2) {
        let mut cfg = random_cfg(seed);
        cfg.engine.audit = false;
        let plain = Simulation::from_config(&cfg).unwrap().run().unwrap();
        cfg.engine.audit = true;
        let audited = Simulation::from_config(&cfg)
            .unwrap()
            .run()
            .unwrap_or_else(|e| panic!("seed {seed}: audit violation: {e:#}"));
        assert_eq!(
            plain.to_json().to_string(),
            audited.to_json().to_string(),
            "seed {seed}: audit mode changed the simulated report"
        );
        assert_eq!(plain.records, audited.records, "seed {seed}");
    }
}

#[test]
fn prop_higher_load_never_reduces_makespan() {
    // for a fixed request set, raising qps compresses arrivals; the
    // system cannot finish *later* at lower load than at absurd load
    for seed in SEEDS.step_by(5) {
        let mut cfg = random_cfg(seed);
        // override the synthetic generator's params through the spec map
        cfg.workload = cfg.workload.clone().with("arrival", "uniform").with("qps", 2.0);
        let slow = Simulation::from_config(&cfg).unwrap().run().unwrap();
        cfg.workload = cfg.workload.clone().with("qps", 2000.0);
        let fast = Simulation::from_config(&cfg).unwrap().run().unwrap();
        // same total work, arrivals compressed => completion not later
        assert!(
            fast.sim_end <= slow.sim_end + 1e-6,
            "seed {seed}: {} vs {}",
            fast.sim_end,
            slow.sim_end
        );
    }
}

// ---- static-capacity-analyzer invariants --------------------------------

#[test]
fn prop_static_throughput_bound_is_a_true_upper_bound() {
    // the analyzer's contract: its closed-form throughput bound is an
    // over-estimate of what simulation can achieve, never an under-
    // estimate — across random workloads x memory managers x scheduler
    // policies x network topologies, with fast-forward on. It must also
    // keep its O(1) probe budget (<= 3 cost-model calls per worker
    // config) and issue zero simulation steps.
    use tokensim::lint::analyze;
    use tokensim::network::NetworkSpec;

    for seed in SEEDS {
        let mut cfg = random_cfg(seed);
        cfg.engine.fast_forward = true;
        // overlay a topology: migrations get priced and queued by the
        // network, which can only slow the run — the bound stays sound
        cfg.network = match seed % 4 {
            0 => NetworkSpec::new("flat"),
            1 => NetworkSpec::new("nvlink_island").with("island_size", 2u64),
            2 => NetworkSpec::new("fat_tree").with("arity", 2u64),
            _ => NetworkSpec::new("ethernet"),
        };
        let requests = cfg.workload.generate().unwrap();
        let a = analyze::analyze(&cfg, &requests);
        assert!(
            a.probe_calls <= 3 * cfg.cluster.workers.len(),
            "seed {seed}: {} probes for {} worker configs",
            a.probe_calls,
            cfg.cluster.workers.len()
        );
        let report = Simulation::from_config(&cfg).unwrap().run().unwrap();
        let achieved = report.records.len() as f64 / report.makespan.max(1e-12);
        if let Some(bound) = a.throughput_ub {
            assert!(
                achieved <= bound * (1.0 + 1e-9),
                "seed {seed}: simulated {achieved} req/s beats the static bound {bound}"
            );
        }
    }
}

// ---- cross-model compute-registry invariants ----------------------------

/// Build one instance of every registered compute model against
/// llama2-7b/A100, configured deterministically (oracle noise off,
/// small vidur forest) so the properties below are stable.
fn registered_models_under_test() -> Vec<(String, Box<dyn ComputeModel>)> {
    let model = ModelSpec::llama2_7b();
    let hw = HardwareSpec::a100_80g();
    let ctx = ComputeCtx::new(&model, &hw);
    compute_models()
        .into_iter()
        .map(|(name, _, _)| {
            let spec = match name.as_str() {
                "oracle" => ComputeSpec::new("oracle").with("noise_sigma", 0.0),
                "vidur_like" => ComputeSpec::new("vidur_like").with("samples", 600u64),
                other => ComputeSpec::new(other),
            };
            let built = spec
                .build(&ctx)
                .unwrap_or_else(|e| panic!("building '{name}': {e:#}"));
            (name, built)
        })
        .collect()
}

fn decode_batch(n: usize, ctx_len: u32) -> BatchDesc {
    let mut b = BatchDesc::new();
    for _ in 0..n {
        b.push(ctx_len, 1);
    }
    b
}

/// Per-model monotonicity slack: the physical models must be exactly
/// monotone (float-noise epsilon only); the learned `vidur_like`
/// regression is held to the same ordering with a small finite-sample
/// allowance — its forest averages leaf regions, so adjacent grid
/// points may wobble by a few percent even though the trend (asserted
/// strictly via the endpoints below) cannot invert.
fn mono_slack(name: &str, prev: f64) -> f64 {
    if name == "vidur_like" {
        1e-12 + 0.05 * prev
    } else {
        1e-12
    }
}

#[test]
fn prop_every_registered_compute_model_is_monotone_in_batch_aggregates() {
    // adding tokens to an iteration never decreases its predicted time:
    // growing the decode batch (T, R, S up), the attended context
    // (A, S up), or the prefill length (T, A, S up).
    // (`llmservingsim_like` truncates prompts beyond its short-request
    // limit, so equality — never a decrease — is allowed everywhere.)
    for (name, mut m) in registered_models_under_test() {
        let mut series: Vec<f64> = Vec::new();
        for n in [1usize, 4, 16, 64, 256] {
            series.push(m.iter_time(&decode_batch(n, 512)));
        }
        for ctx_len in [0u32, 512, 2048, 8192] {
            series.push(m.iter_time(&decode_batch(16, ctx_len)));
        }
        for prompt in [8u32, 64, 512, 4096] {
            let mut b = BatchDesc::new();
            b.push(0, prompt);
            series.push(m.iter_time(&b));
        }
        // each sweep restarts: check within-sweep adjacency
        for (i, sweep) in [&series[0..5], &series[5..9], &series[9..13]]
            .into_iter()
            .enumerate()
        {
            for w in sweep.windows(2) {
                assert!(
                    w[1] >= w[0] - mono_slack(&name, w[0]),
                    "{name}: adding tokens decreased iteration time ({} -> {}) in {series:?}",
                    w[0],
                    w[1]
                );
            }
            // endpoints are strictly ordered for every model (the
            // trend itself can never invert, slack or not) — except
            // the co-sim's prompt truncation, which legitimately
            // flattens the prefill sweep (i == 2)
            if !(name == "llmservingsim_like" && i == 2) {
                assert!(
                    sweep[sweep.len() - 1] > sweep[0],
                    "{name}: no growth across the whole sweep {sweep:?}"
                );
            }
        }
    }
}

#[test]
fn prop_every_registered_compute_model_charges_nothing_for_empty_batches() {
    for (name, mut m) in registered_models_under_test() {
        assert_eq!(m.iter_time(&BatchDesc::new()), 0.0, "{name}");
        let mut ctx_only = BatchDesc::new();
        ctx_only.push(100, 0);
        assert_eq!(m.iter_time(&ctx_only), 0.0, "{name}: no new tokens");
    }
}

#[test]
fn prop_every_registered_compute_model_repeats_bit_for_bit() {
    // the memoization contract only holds if repeated evaluation of the
    // same batch is bit-equal on EVERY registered model (the `memo`
    // layer itself is in the sweep: a hit must reproduce the miss);
    // mixed query orders exercise any internal caches between repeats
    for (name, mut m) in registered_models_under_test() {
        let mut probes: Vec<BatchDesc> = Vec::new();
        for n in [1usize, 7, 32, 128] {
            probes.push(decode_batch(n, 777));
        }
        let mut mixed = BatchDesc::new();
        mixed.push(0, 300);
        for i in 0..15 {
            mixed.push(64 + i * 37, 1);
        }
        probes.push(mixed);
        let first: Vec<u64> = probes.iter().map(|b| m.iter_time(b).to_bits()).collect();
        for (b, &bits) in probes.iter().zip(&first).rev() {
            assert_eq!(
                m.iter_time(b).to_bits(),
                bits,
                "{name}: repeated iter_time on the same batch not bit-equal"
            );
        }
    }
}

#[test]
fn prop_table_acceleration_stays_within_tolerance_of_its_base() {
    // the `table` layer is a perf path, not a different model: across a
    // randomized batch sweep its prediction must stay within solver
    // tolerance of the base model it was extracted from
    let model = ModelSpec::llama2_7b();
    let hw = HardwareSpec::a100_80g();
    let ctx = ComputeCtx::new(&model, &hw);
    for (base_name, tol) in [("analytic", 2e-3), ("roofline", 1e-6)] {
        let mut base = ComputeSpec::new(base_name).build(&ctx).unwrap();
        let mut table = ComputeSpec::new("table")
            .with("base", base_name)
            .build(&ctx)
            .unwrap();
        for seed in SEEDS {
            let mut rng = SimRng::new(seed, "table-tol");
            let mut b = BatchDesc::new();
            if rng.gen_bool(0.5) {
                b.push(0, 16 + rng.uniform_int(0, 2048) as u32);
            }
            for _ in 0..rng.uniform_int(1, 96) {
                b.push(rng.uniform_int(0, 4096) as u32, 1);
            }
            let t_base = base.iter_time(&b);
            let t_table = table.iter_time(&b);
            let rel = ((t_table - t_base) / t_base).abs();
            assert!(
                rel < tol,
                "table-over-{base_name} drifted {rel} (base {t_base}, table {t_table}, seed {seed})"
            );
        }
    }
}

// ---- streaming-quantile-sketch invariants ------------------------------

/// The bound [`tokensim::metrics::QuantileSketch`] documents: the
/// estimate for quantile `q` falls between the two order statistics
/// bracketing rank `q * (n - 1)`, each relaxed by the sketch's
/// relative error (1e-12 of float slack for near-zero values).
fn sketch_estimate_in_window(sorted: &[f64], q: f64, est: f64, eps: f64) -> bool {
    let pos = q * (sorted.len() - 1) as f64;
    let lo = sorted[pos.floor() as usize] * (1.0 - eps) - 1e-12;
    let hi = sorted[pos.ceil() as usize] * (1.0 + eps) + 1e-12;
    lo <= est && est <= hi
}

#[test]
fn prop_sketch_quantiles_track_exact_order_statistics() {
    use tokensim::metrics::QuantileSketch;

    // one stream shape per arm; all values non-negative, matching the
    // latency/ttft/tbt domains the sketch serves in production
    let shapes = ["uniform", "lognormal", "sorted", "reversed", "duplicate-heavy"];
    for seed in 0..5u64 {
        for name in shapes {
            let mut rng = SimRng::new(seed, &format!("sketch-prop-{name}"));
            let n = 1000 + rng.uniform_int(0, 3000) as usize;
            let values: Vec<f64> = match name {
                "uniform" => (0..n).map(|_| rng.uniform(0.001, 120.0)).collect(),
                "lognormal" => (0..n).map(|_| rng.lognormal(0.0, 1.5)).collect(),
                "sorted" => {
                    let mut v: Vec<f64> = (0..n).map(|_| rng.lognormal(1.0, 0.8)).collect();
                    v.sort_by(|a, b| a.total_cmp(b));
                    v
                }
                "reversed" => {
                    let mut v: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 40.0)).collect();
                    v.sort_by(|a, b| b.total_cmp(a));
                    v
                }
                // ~8 distinct values repeated; duplicates pile into the
                // same bucket, which must not bias the rank walk
                _ => {
                    let pool: Vec<f64> = (0..8).map(|_| rng.uniform(0.01, 10.0)).collect();
                    (0..n).map(|_| pool[rng.pick(pool.len())]).collect()
                }
            };
            let mut sketch = tokensim_sketch_of(&values);
            let eps = sketch.relative_error();
            let mut sorted = values.clone();
            sorted.sort_by(|a, b| a.total_cmp(b));
            for q in [0.5, 0.9, 0.99, 0.999] {
                let est = sketch.quantile(q);
                let exact = tokensim::metrics::percentile_of_sorted(&sorted, q);
                assert!(
                    sketch_estimate_in_window(&sorted, q, est, eps),
                    "{name} seed {seed} n {n} q {q}: sketch {est} vs exact {exact}"
                );
            }
            // extremes are tracked exactly, not bucket-approximated
            assert_eq!(sketch.quantile(0.0), sorted[0], "{name} seed {seed}");
            assert_eq!(sketch.quantile(1.0), sorted[n - 1], "{name} seed {seed}");
            assert_eq!(sketch.count(), n as u64);
            // feeding more data can only move counts, never epsilon
            sketch.add(1.0);
            assert_eq!(sketch.relative_error(), eps);
            assert_eq!(QuantileSketch::new(eps).relative_error(), eps);
        }
    }
}

#[test]
fn prop_sketch_merge_equals_sketch_of_concatenation() {
    use tokensim::metrics::QuantileSketch;

    for seed in 0..10u64 {
        let mut rng = SimRng::new(seed, "sketch-merge");
        let na = rng.uniform_int(0, 2000) as usize;
        let nb = rng.uniform_int(1, 2000) as usize;
        let a: Vec<f64> = (0..na).map(|_| rng.lognormal(0.5, 1.2)).collect();
        let b: Vec<f64> = (0..nb).map(|_| rng.uniform(0.0, 300.0)).collect();

        let mut left = QuantileSketch::new(0.01);
        a.iter().for_each(|&v| left.add(v));
        let mut right = QuantileSketch::new(0.01);
        b.iter().for_each(|&v| right.add(v));
        let mut both = QuantileSketch::new(0.01);
        a.iter().chain(b.iter()).for_each(|&v| both.add(v));

        left.merge(&right);
        // merge is exact (elementwise bucket addition), so the merged
        // sketch is *identical* to one fed the concatenated stream —
        // not merely within epsilon
        assert_eq!(left, both, "seed {seed} na {na} nb {nb}");
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(left.quantile(q), both.quantile(q), "seed {seed} q {q}");
        }
        assert_eq!(left.count(), (na + nb) as u64);
    }
}

fn tokensim_sketch_of(values: &[f64]) -> tokensim::metrics::QuantileSketch {
    let mut s = tokensim::metrics::QuantileSketch::new(0.01);
    values.iter().for_each(|&v| s.add(v));
    s
}
