//! Oracle: the fine-grained reference executor standing in for the
//! paper's real-hardware measurements (vLLM v0.6.2 on A100s, DistServe
//! on 2×A100).
//!
//! The paper validates TokenSim against real systems; this environment
//! has no GPUs, so validation runs against a *higher-fidelity* executor
//! instead (DESIGN.md §Substitutions): the oracle models effects the
//! TokenSim cost model deliberately coarsens —
//!
//! * **sequence-dependent GEMM efficiency**: small GEMMs achieve a
//!   fraction `m/(m + m_half)` of sustained peak (kernel ramp-up), where
//!   TokenSim assumes a flat sustained efficiency;
//! * **paged-attention bandwidth efficiency**: gather-style KV reads
//!   reach only ~70 % of streaming bandwidth;
//! * **request-count-dependent framework overhead**: the engine's
//!   per-iteration bookkeeping grows with batch size;
//! * **measurement noise**: multiplicative per-iteration jitter, plus
//!   bus fluctuation on KV transfers (the paper's Fig-7 discussion).
//!
//! Like the paper's methodology ("we measure the actual communication
//! bandwidth and use this data to configure TokenSim"),
//! [`calibrated_hardware`] profiles the oracle on microbenchmarks and
//! returns the hardware vector TokenSim should be configured with.

use crate::compute::{AnalyticCost, BatchDesc, ComputeModel, IterCost, NUM_OPS};
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;
use crate::sim::SimRng;

/// Fidelity knobs of the oracle executor.
#[derive(Debug, Clone)]
pub struct OracleParams {
    /// GEMM ramp half-point in rows: eff(m) = m / (m + m_half).
    pub gemm_half_rows: f64,
    /// Residual attention-bandwidth deviation from the cost model's
    /// shared `ATTN_GATHER_EFF` (1.0 = the gather model is exact).
    pub attn_bw_efficiency: f64,
    /// Per-iteration framework overhead: `base + per_request * R`.
    pub framework_base: f64,
    pub framework_per_request: f64,
    /// Multiplicative lognormal jitter sigma per iteration (0 = off).
    pub noise_sigma: f64,
    /// Runtime-framework multiplier (SwiftTransformer vs vLLM — the
    /// Fig-7 "inevitable source of error").
    pub runtime_factor: f64,
}

impl OracleParams {
    /// vLLM-v0.6.2-like fidelity (Figs 4, 5, 9, 10, Table II).
    pub fn vllm() -> Self {
        Self {
            gemm_half_rows: 16.0,
            attn_bw_efficiency: 0.97,
            framework_base: 1.6e-3,
            framework_per_request: 3.0e-6,
            noise_sigma: 0.012,
            runtime_factor: 1.0,
        }
    }

    /// DistServe/SwiftTransformer-like fidelity (Fig 7).
    pub fn distserve() -> Self {
        Self {
            runtime_factor: 0.94,
            framework_base: 1.1e-3,
            ..Self::vllm()
        }
    }

    /// Noise-free variant (deterministic ground truth for baselines'
    /// pre-training samples).
    pub fn noiseless(mut self) -> Self {
        self.noise_sigma = 0.0;
        self
    }
}

/// Which GEMM row count drives each op's ramp (T = new tokens,
/// R = active requests); attention and bandwidth ops are exempt.
const GEMM_ROWS_T: [bool; NUM_OPS] = [
    false, true, false, false, true, true, true, false, false, false,
];
const GEMM_ROWS_R: [bool; NUM_OPS] = [
    false, false, false, false, false, false, false, false, false, true,
];
const ATTN_IDX: usize = 2;

/// The oracle's per-iteration cost model.
pub struct OracleCost {
    inner: AnalyticCost,
    model: ModelSpec,
    hw: HardwareSpec,
    params: OracleParams,
    rng: SimRng,
    pub iterations: u64,
}

impl OracleCost {
    pub fn new(model: &ModelSpec, hw: &HardwareSpec, params: OracleParams, seed: u64) -> Self {
        Self {
            inner: AnalyticCost::new(model, hw),
            model: model.clone(),
            hw: hw.clone(),
            params,
            rng: SimRng::new(seed, "oracle-noise"),
            iterations: 0,
        }
    }

    /// Deterministic (noise-free) evaluation of one iteration.
    ///
    /// Decomposes every operator into its FLOP and byte components (via
    /// degenerate-hardware probes of the analytic mirror) so the GEMM
    /// ramp applies only to the *compute* term — a weight-read-bound
    /// decode GEMM is not slowed by pipeline ramp-up.
    pub fn evaluate_mean(&self, batch: &BatchDesc) -> IterCost {
        let base = self.inner.evaluate(batch);
        if batch.is_empty() {
            return base;
        }
        let t: f64 = batch.total_new() as f64;
        let r = batch.active_requests() as f64;
        let p = &self.params;

        const FLOPS_PROBE: [f32; 6] = [1.0, 1e30, 0.0, 0.0, 1e30, 0.0];
        const BYTES_PROBE: [f32; 6] = [1e30, 1.0, 0.0, 0.0, 1.0, 0.0];
        let f_ops = self.inner.evaluate_with_hw(batch, FLOPS_PROBE).op_times;
        let b_ops = self.inner.evaluate_with_hw(batch, BYTES_PROBE).op_times;
        let peak = self.hw.achievable_flops();
        let bw = self.hw.mem_bw;
        let net_bw = self.hw.net_bw;
        const ALLREDUCE_IDX: usize = 8;

        let mut op_times = [0.0f64; NUM_OPS];
        for i in 0..NUM_OPS {
            let (f, b) = (f_ops[i], b_ops[i]);
            if f <= 0.0 && b <= 0.0 {
                continue;
            }
            let eff = if GEMM_ROWS_T[i] || GEMM_ROWS_R[i] {
                let m = if GEMM_ROWS_T[i] { t } else { r };
                (m / (m + p.gemm_half_rows)).clamp(0.05, 1.0)
            } else {
                1.0
            };
            let eff_bw = if i == ALLREDUCE_IDX {
                net_bw
            } else if i == ATTN_IDX {
                bw * p.attn_bw_efficiency
            } else {
                bw
            };
            op_times[i] = (f / (peak * eff)).max(b / eff_bw) + self.hw.op_overhead;
        }

        let layers = self.model.layers as f64;
        const PER_ITER: [bool; NUM_OPS] = [
            true, false, false, false, false, false, false, false, false, true,
        ];
        let mut per_layer = 0.0;
        let mut per_iter = 0.0;
        for i in 0..NUM_OPS {
            if PER_ITER[i] {
                per_iter += op_times[i];
            } else {
                per_layer += op_times[i];
            }
        }
        let framework = p.framework_base + p.framework_per_request * r;
        let iter_time = (layers * per_layer + per_iter + framework) * p.runtime_factor;
        IterCost {
            iter_time,
            op_times,
            per_req_attn: base.per_req_attn,
        }
    }

    /// The hardware this oracle models (for calibration probes).
    pub fn hardware(&self) -> &HardwareSpec {
        &self.hw
    }
}

impl ComputeModel for OracleCost {
    fn iter_time(&mut self, batch: &BatchDesc) -> f64 {
        let mean = self.evaluate_mean(batch).iter_time;
        if mean == 0.0 {
            return 0.0;
        }
        self.iterations += 1;
        if self.params.noise_sigma > 0.0 {
            mean * self.rng.lognormal(0.0, self.params.noise_sigma)
        } else {
            mean
        }
    }

    fn iter_cost(&mut self, batch: &BatchDesc) -> IterCost {
        let mut cost = self.evaluate_mean(batch);
        if cost.iter_time > 0.0 {
            self.iterations += 1;
            if self.params.noise_sigma > 0.0 {
                cost.iter_time *= self.rng.lognormal(0.0, self.params.noise_sigma);
            }
        }
        cost
    }

    fn name(&self) -> &str {
        "oracle"
    }
}

/// Calibrate TokenSim's hardware description against the oracle — the
/// paper's "measure the real system, configure the simulator" step.
///
/// Runs noise-free oracle microbenchmarks and fits `efficiency` (from a
/// compute-bound prefill), `mem_bw` (least-squares over bandwidth-bound
/// decode batches) and `iter_overhead` (mean residual) by coordinate
/// descent; four rounds suffice — each update is a near-exact solve at
/// its own operating regime.
pub fn calibrated_hardware(
    model: &ModelSpec,
    hw: &HardwareSpec,
    params: &OracleParams,
) -> HardwareSpec {
    let oracle = OracleCost::new(model, hw, params.clone().noiseless(), 0);

    // prefill iterations batch multiple prompts up to the token budget,
    // so the representative GEMM row count sits in the hundreds
    let prefill = {
        let mut b = BatchDesc::new();
        b.push(0, 512);
        b
    };
    let decode_probes: Vec<BatchDesc> = [(16usize, 256u32), (64, 512), (192, 1024)]
        .iter()
        .map(|&(n, ctx)| {
            let mut b = BatchDesc::new();
            for _ in 0..n {
                b.push(ctx, 1);
            }
            b
        })
        .collect();

    let t_prefill_o = oracle.evaluate_mean(&prefill).iter_time;
    let t_decode_o: Vec<f64> = decode_probes
        .iter()
        .map(|b| oracle.evaluate_mean(b).iter_time)
        .collect();

    let mut fitted = hw.clone();
    for _ in 0..3 {
        // (1) efficiency from the compute-bound point
        let analytic = AnalyticCost::new(model, &fitted);
        let t_prefill_s = analytic.evaluate(&prefill).iter_time;
        fitted.efficiency =
            (fitted.efficiency * t_prefill_s / t_prefill_o).clamp(0.05, 1.0);

        // (2)+(3) joint (1/bw, overhead) least squares on the decode
        // probes: decompose each probe's analytic time into a
        // bandwidth-proportional slope and a bandwidth-independent
        // constant (op overheads + compute-bound residues), then solve
        // the 2x2 normal equations for the bandwidth scale and the
        // per-iteration overhead.
        let analytic = AnalyticCost::new(model, &fitted);
        let mut hw_vec = fitted.to_vec();
        hw_vec[3] = 0.0; // strip iter_overhead: it is a fit unknown
        let base_bw = fitted.mem_bw;
        let mut inf_bw_vec = hw_vec;
        inf_bw_vec[1] = 1e30;
        inf_bw_vec[4] = 1e30;
        // normal equations for min Σ (slope_i * y + const_i + oh - t_o_i)^2
        // over (y = base_bw / bw', oh)
        let (mut syy, mut sy1, mut s11) = (0.0f64, 0.0f64, 0.0f64);
        let (mut sty, mut st1) = (0.0f64, 0.0f64);
        for (b, &t_o) in decode_probes.iter().zip(&t_decode_o) {
            let t_full = analytic.evaluate_with_hw(b, hw_vec).iter_time;
            let t_const = analytic.evaluate_with_hw(b, inf_bw_vec).iter_time;
            let slope = t_full - t_const; // time spent moving bytes at base_bw
            let target = t_o - t_const;
            syy += slope * slope;
            sy1 += slope;
            s11 += 1.0;
            sty += slope * target;
            st1 += target;
        }
        let det = syy * s11 - sy1 * sy1;
        if det.abs() > 1e-18 {
            let y = (sty * s11 - st1 * sy1) / det;
            let oh = (syy * st1 - sy1 * sty) / det;
            fitted.mem_bw = (base_bw / y.clamp(0.2, 5.0)).min(base_bw * 5.0);
            fitted.iter_overhead = oh.clamp(1e-5, 0.05);
        }
    }
    fitted.name = format!("{}-calibrated", hw.name);
    fitted
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(noise: f64) -> OracleCost {
        let mut p = OracleParams::vllm();
        p.noise_sigma = noise;
        OracleCost::new(&ModelSpec::llama2_7b(), &HardwareSpec::a100_80g(), p, 7)
    }

    fn decode(n: usize, ctx: u32) -> BatchDesc {
        let mut b = BatchDesc::new();
        for _ in 0..n {
            b.push(ctx, 1);
        }
        b
    }

    #[test]
    fn oracle_deviates_from_flat_model_at_small_gemm_sizes() {
        // the GEMM ramp makes mid-size prefills slower than the flat
        // sustained-efficiency model predicts
        let mut oracle = setup(0.0);
        let mut flat = AnalyticCost::new(&ModelSpec::llama2_7b(), &HardwareSpec::a100_80g());
        let mut b = BatchDesc::new();
        b.push(0, 128);
        let ratio = oracle.iter_time(&b) / flat.iter_time(&b);
        assert!(ratio > 1.02, "ratio={ratio}");
    }

    #[test]
    fn ramp_vanishes_for_large_prefill() {
        let oracle = setup(0.0);
        let mut flat = AnalyticCost::new(&ModelSpec::llama2_7b(), &HardwareSpec::a100_80g());
        let mut b = BatchDesc::new();
        b.push(0, 4096);
        let ratio = oracle.evaluate_mean(&b).iter_time / flat.iter_time(&b);
        // attention is tiny here; GEMM ramp at 4096 rows ~ 0.6% effect
        assert!((1.0..1.15).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn noise_is_reproducible_and_bounded() {
        let mut a = setup(0.02);
        let mut b = setup(0.02);
        let batch = decode(8, 256);
        let mean = setup(0.0).evaluate_mean(&batch).iter_time;
        for _ in 0..50 {
            let ta = a.iter_time(&batch);
            assert_eq!(ta, b.iter_time(&batch), "same seed, same draw");
            assert!((ta / mean - 1.0).abs() < 0.15);
        }
    }

    #[test]
    fn empty_batch_free() {
        let mut oracle = setup(0.01);
        assert_eq!(oracle.iter_time(&BatchDesc::new()), 0.0);
        assert_eq!(oracle.iterations, 0);
    }

    #[test]
    fn framework_overhead_grows_with_batch() {
        let oracle = setup(0.0);
        let t8 = oracle.evaluate_mean(&decode(8, 128)).iter_time;
        let t256 = oracle.evaluate_mean(&decode(256, 128)).iter_time;
        assert!(t256 > t8);
    }

    #[test]
    fn calibration_brings_flat_model_close() {
        let model = ModelSpec::llama2_7b();
        let hw = HardwareSpec::a100_80g();
        let params = OracleParams::vllm();
        let fitted = calibrated_hardware(&model, &hw, &params);
        let oracle = OracleCost::new(&model, &hw, params.noiseless(), 0);
        let mut sim = AnalyticCost::new(&model, &fitted);
        // check on batches *away from* the calibration points
        for batch in [decode(32, 1024), decode(128, 300), {
            let mut b = BatchDesc::new();
            b.push(0, 512);
            b
        }] {
            let t_o = oracle.evaluate_mean(&batch).iter_time;
            let t_s = sim.iter_time(&batch);
            let rel = ((t_s - t_o) / t_o).abs();
            assert!(rel < 0.15, "calibrated model off by {rel} on {batch:?}");
        }
    }

    #[test]
    fn distserve_params_differ() {
        let v = OracleParams::vllm();
        let d = OracleParams::distserve();
        assert!(d.runtime_factor != v.runtime_factor);
    }
}
