//! Discrete-event simulation core (the SimPy replacement).
//!
//! TokenSim's original implementation rode on SimPy's generator-based
//! processes; here the engine is a plain binary-heap event queue with a
//! typed event payload, which is both faster (no coroutine switching)
//! and simpler to reason about for the worker/scheduler state machines
//! that make up an inference cluster.

mod engine;
mod rng;

pub use engine::{Event, EventPayload, EventQueue, SimTime};
pub use rng::SimRng;
