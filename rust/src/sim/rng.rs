//! Deterministic RNG used throughout the simulator (hand-rolled —
//! rand/rand_distr are unavailable in this offline build).
//!
//! Core generator is xoshiro256++ seeded via splitmix64. Every
//! stochastic component (arrival process, length sampling, random
//! routing, oracle noise) derives its stream from a `SimRng` seeded from
//! the experiment seed plus a component label, so experiments are
//! bit-reproducible and components are independent of evaluation order.

/// Deterministic simulator RNG (xoshiro256++).
#[derive(Debug, Clone)]
pub struct SimRng {
    s: [u64; 4],
    /// Cached second normal from Box-Muller.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl SimRng {
    /// Seed from an experiment seed and a component label.
    pub fn new(seed: u64, label: &str) -> Self {
        // FNV-1a over the label, mixed with the seed.
        let mut h: u64 = 0xcbf29ce484222325;
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        let mut sm = seed ^ h;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // avoid the all-zero state
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self {
            s,
            spare_normal: None,
        }
    }

    /// Fork an independent stream (e.g. one per worker).
    pub fn fork(&mut self, label: &str) -> Self {
        let seed = self.next_u64();
        Self::new(seed, label)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(hi >= lo);
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[lo, hi]` (inclusive), unbiased via rejection.
    pub fn uniform_int(&mut self, lo: u64, hi_inclusive: u64) -> u64 {
        assert!(hi_inclusive >= lo, "empty integer range");
        let span = hi_inclusive - lo + 1;
        if span == 0 {
            // full u64 range
            return self.next_u64();
        }
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + v % span;
            }
        }
    }

    /// Exponential inter-arrival gap for a Poisson process of `rate`/s.
    #[inline]
    pub fn exp_gap(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0, "rate must be > 0");
        // 1 - U in (0,1] avoids ln(0)
        -(1.0 - self.next_f64()).ln() / rate
    }

    /// Standard normal via Box-Muller (with spare caching).
    pub fn standard_normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.standard_normal()).exp()
    }

    /// Poisson sample: Knuth for small lambda, normal approximation for
    /// large (accurate enough for workload round counts).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        let lambda = lambda.max(0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.next_f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let v = lambda + lambda.sqrt() * self.standard_normal();
            v.round().max(0.0) as u64
        }
    }

    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p.clamp(0.0, 1.0)
    }

    /// Pick an index in `0..n` uniformly.
    #[inline]
    pub fn pick(&mut self, n: usize) -> usize {
        assert!(n > 0, "pick from empty range");
        self.uniform_int(0, (n - 1) as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed_and_label() {
        let mut a = SimRng::new(42, "arrivals");
        let mut b = SimRng::new(42, "arrivals");
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn labels_give_independent_streams() {
        let mut a = SimRng::new(42, "arrivals");
        let mut b = SimRng::new(42, "lengths");
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_range_and_covers() {
        let mut r = SimRng::new(7, "u");
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let v = r.uniform_int(3, 7);
            assert!((3..=7).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 7;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn exp_gap_mean_close_to_inverse_rate() {
        let mut r = SimRng::new(7, "exp");
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp_gap(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = SimRng::new(9, "n");
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.standard_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = SimRng::new(7, "poisson");
        for lambda in [3.5, 80.0] {
            let n = 30_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!((mean - lambda).abs() / lambda < 0.05, "lambda={lambda} mean={mean}");
        }
    }

    #[test]
    fn lognormal_median() {
        let mut r = SimRng::new(11, "ln");
        let n = 50_000;
        let mut v: Vec<f64> = (0..n).map(|_| r.lognormal(100f64.ln(), 1.0)).collect();
        v.sort_by(|a, b| a.total_cmp(b));
        let med = v[n / 2];
        assert!((med - 100.0).abs() < 5.0, "median={med}");
    }

    #[test]
    fn fork_is_deterministic_and_distinct() {
        let mut a = SimRng::new(1, "root");
        let mut b = SimRng::new(1, "root");
        let mut fa = a.fork("w0");
        let mut fb = b.fork("w0");
        assert_eq!(fa.next_u64(), fb.next_u64());
        let mut fc = a.fork("w1");
        assert_ne!(fa.next_u64(), fc.next_u64());
    }

    #[test]
    fn gen_bool_probability() {
        let mut r = SimRng::new(5, "b");
        let n = 50_000;
        let hits = (0..n).filter(|_| r.gen_bool(0.3)).count();
        let frac = hits as f64 / n as f64;
        assert!((frac - 0.3).abs() < 0.01, "frac={frac}");
    }
}
