//! Binary-heap event queue with deterministic tie-breaking.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::request::RequestId;

/// Simulated time in seconds.
pub type SimTime = f64;

/// Typed event payloads for the serving-system state machines.
///
/// The engine itself is payload-agnostic; this enum enumerates every
/// event kind the cluster driver ([`crate::cluster::Simulation`]) and the
/// oracle executor use.
#[derive(Debug, Clone, PartialEq)]
pub enum EventPayload {
    /// A new request (or conversation round) enters the system.
    Arrival(RequestId),
    /// Worker `worker` finishes the iteration it started earlier.
    IterDone { worker: usize },
    /// A KV-cache transfer for `req` into `worker` completed.
    TransferDone { worker: usize, req: RequestId },
    /// Periodic metrics sampling tick.
    SampleTick,
    /// Generic wake-up for a worker (e.g. after a dispatch).
    Kick { worker: usize },
}

/// A scheduled event.
#[derive(Debug, Clone)]
pub struct Event {
    pub time: SimTime,
    /// Monotone sequence number: FIFO order among same-time events, which
    /// keeps runs bit-reproducible regardless of heap internals.
    pub seq: u64,
    pub payload: EventPayload,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        //
        // `total_cmp`, not `partial_cmp(..).unwrap_or(Equal)`: the
        // fields are `pub`, so a directly-constructed Event can carry a
        // NaN timestamp that `schedule_at`'s finiteness assert never
        // saw. Treating NaN as equal to everything is not a total
        // order — BinaryHeap's internal invariants silently collapse
        // and events pop in arbitrary order. Under `total_cmp` NaN is
        // merely the largest value (sorted last), and ordering among
        // finite timestamps is unchanged.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The discrete-event queue: `push` schedules, `pop` advances time.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    now: SimTime,
    next_seq: u64,
    processed: u64,
    /// Audit mode: record (instead of merely debug-asserting) a
    /// monotonicity violation for the driver to surface.
    audit: bool,
    violation: Option<String>,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current simulated time (the timestamp of the last popped event).
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total number of events processed so far.
    #[inline]
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Number of pending events.
    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `payload` at absolute time `at`.
    ///
    /// Panics if `at` is in the past or not finite — scheduling into the
    /// past is always a logic error in the caller.
    pub fn schedule_at(&mut self, at: SimTime, payload: EventPayload) {
        assert!(at.is_finite(), "non-finite event time {at}");
        assert!(
            at >= self.now,
            "scheduling into the past: at={at} now={}",
            self.now
        );
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event {
            time: at,
            seq,
            payload,
        });
    }

    /// Schedule `payload` after a relative delay.
    pub fn schedule_in(&mut self, delay: SimTime, payload: EventPayload) {
        assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule_at(self.now + delay, payload);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<Event> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.time >= self.now);
        if self.audit && ev.time < self.now && self.violation.is_none() {
            self.violation = Some(format!(
                "event {:?} pops at t={} with the clock already at t={}",
                ev.payload, ev.time, self.now
            ));
        }
        self.now = ev.time;
        self.processed += 1;
        Some(ev)
    }

    /// Enable audit mode: a time-ordering violation is recorded for
    /// [`take_violation`](Self::take_violation) instead of only being a
    /// debug assertion. Release builds otherwise skip the check.
    pub fn set_audit(&mut self, audit: bool) {
        self.audit = audit;
    }

    /// Take the recorded monotonicity violation, if any (audit mode).
    pub fn take_violation(&mut self) -> Option<String> {
        self.violation.take()
    }

    /// Peek at the next event time without advancing.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, EventPayload::SampleTick);
        q.schedule_at(1.0, EventPayload::IterDone { worker: 0 });
        q.schedule_at(2.0, EventPayload::Kick { worker: 1 });
        let times: Vec<f64> = std::iter::from_fn(|| q.pop().map(|e| e.time)).collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
        assert_eq!(q.now(), 3.0);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn fifo_among_equal_times() {
        let mut q = EventQueue::new();
        for w in 0..100 {
            q.schedule_at(5.0, EventPayload::Kick { worker: w });
        }
        for expect in 0..100 {
            match q.pop().unwrap().payload {
                EventPayload::Kick { worker } => assert_eq!(worker, expect),
                other => panic!("unexpected payload {other:?}"),
            }
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule_in(1.5, EventPayload::SampleTick);
        q.pop();
        q.schedule_in(0.5, EventPayload::SampleTick);
        q.schedule_in(0.0, EventPayload::SampleTick);
        assert_eq!(q.pop().unwrap().time, 1.5);
        assert_eq!(q.pop().unwrap().time, 2.0);
        assert!(q.is_empty());
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn rejects_past_events() {
        let mut q = EventQueue::new();
        q.schedule_at(2.0, EventPayload::SampleTick);
        q.pop();
        q.schedule_at(1.0, EventPayload::SampleTick);
    }

    #[test]
    fn ordering_is_total_even_for_nan_timestamps() {
        // regression: Event fields are `pub`, so a NaN time can enter a
        // heap without passing `schedule_at`'s finiteness assert; the
        // old `partial_cmp(..).unwrap_or(Equal)` made NaN compare equal
        // to everything, which is not a total order and silently broke
        // heap invariants. Under `total_cmp`, NaN sorts after every
        // finite time (max-heap inverted => popped last) and finite
        // events keep their earliest-first FIFO order.
        let ev = |time: f64, seq: u64| Event {
            time,
            seq,
            payload: EventPayload::SampleTick,
        };
        let nan = ev(f64::NAN, 0);
        let one = ev(1.0, 1);
        let two = ev(2.0, 2);
        // earliest-first => in the inverted order, smaller time is Greater
        assert_eq!(one.cmp(&two), Ordering::Greater);
        assert_eq!(two.cmp(&one), Ordering::Less);
        // NaN is a totally-ordered extreme, not "equal to everything"
        assert_eq!(nan.cmp(&one), Ordering::Less, "NaN pops last");
        assert_eq!(one.cmp(&nan), Ordering::Greater);
        assert_eq!(nan.cmp(&ev(f64::NAN, 9)), Ordering::Greater, "seq ties");
        // antisymmetry + transitivity hold through a real heap: finite
        // events drain earliest-first even with a NaN event present
        let mut heap = std::collections::BinaryHeap::new();
        for e in [nan, two, one] {
            heap.push(e);
        }
        assert_eq!(heap.pop().unwrap().time, 1.0);
        assert_eq!(heap.pop().unwrap().time, 2.0);
        assert!(heap.pop().unwrap().time.is_nan());
    }

    #[test]
    fn peek_does_not_advance() {
        let mut q = EventQueue::new();
        q.schedule_at(4.0, EventPayload::SampleTick);
        assert_eq!(q.peek_time(), Some(4.0));
        assert_eq!(q.now(), 0.0);
    }
}
