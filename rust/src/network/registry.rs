//! String-keyed network-topology registry — the network counterpart
//! of [`crate::scheduler::registry`] and the other three registries.
//!
//! A topology is selected by name — from YAML (`network: {topology:
//! nvlink_island}`) or programmatically via [`NetworkSpec`] — and
//! built from its parameter map against a [`NetCtx`] describing the
//! fleet. The cluster driver only ever sees `Box<dyn NetworkModel>`,
//! so adding a topology never touches `cluster/mod.rs`: implement the
//! trait, then either add a [`NetworkEntry`] to the built-in table or
//! call [`register_network`] at startup.

use std::sync::{Mutex, OnceLock};

use anyhow::{bail, Context, Result};

use crate::config::yaml::Yaml;
use crate::hardware::LinkSpec;

use super::topology::{EthernetNetwork, FatTreeNetwork, FlatNetwork, NvlinkIslandNetwork};
use super::NetworkModel;

/// The fleet a topology is built against: worker count plus the link
/// presets the pre-registry driver wired directly — the scheduler
/// interconnect, the pool fabric, and each worker's swap link (if its
/// memory manager has one). `flat` reproduces exactly these; the
/// contended topologies use them as per-hop defaults.
#[derive(Debug, Clone)]
pub struct NetCtx {
    pub n_workers: usize,
    /// The `cluster: scheduler: interconnect:` link.
    pub interconnect: LinkSpec,
    /// The pool-cache link (`pool_cache: link:`, or the PoolFabric
    /// preset when no pool is configured).
    pub pool_link: LinkSpec,
    /// Per-worker host swap link, `None` for managers that never swap.
    pub swap_links: Vec<Option<LinkSpec>>,
}

impl NetCtx {
    /// A uniform fleet over one interconnect: pool on the default
    /// fabric, no swap links. What [`NetworkSpec::validate`] and most
    /// tests build against.
    pub fn uniform(n_workers: usize, interconnect: LinkSpec) -> Self {
        Self {
            n_workers,
            interconnect,
            pool_link: LinkSpec::pool_fabric(),
            swap_links: vec![None; n_workers],
        }
    }

    /// The fleet a [`SimulationConfig`] describes — the same
    /// quantity-expanded workers, interconnect, pool link and per-worker
    /// swap links the cluster driver builds its topology against, but
    /// without constructing a cluster. This is what static analysis
    /// (`tokensim analyze`) routes expected traffic over.
    ///
    /// [`SimulationConfig`]: crate::config::SimulationConfig
    pub fn for_config(cfg: &crate::config::SimulationConfig) -> Result<Self> {
        use crate::memory::MemoryManager as _;
        let mut swap_links = Vec::new();
        for wc in &cfg.cluster.workers {
            let mem = wc.memory.build(&cfg.model, wc.hardware.mem_cap)?;
            let link = mem.swap_link().cloned();
            for _ in 0..wc.quantity {
                swap_links.push(link.clone());
            }
        }
        Ok(Self {
            n_workers: swap_links.len(),
            interconnect: cfg.cluster.scheduler.interconnect.clone(),
            pool_link: cfg
                .pool_cache
                .as_ref()
                .map(|pc| pc.link.clone())
                .unwrap_or_else(LinkSpec::pool_fabric),
            swap_links,
        })
    }
}

/// A declarative, cloneable network-topology selection: a registry
/// name plus a parameter map (the YAML subtree, or a programmatically
/// built map). This is what configs store — the built
/// `Box<dyn NetworkModel>` carries a mutable occupancy ledger and is
/// neither cloneable nor comparable.
///
/// # Examples
///
/// ```
/// use tokensim::hardware::LinkSpec;
/// use tokensim::network::{NetCtx, NetworkSpec};
///
/// let spec = NetworkSpec::new("nvlink_island").with("island_size", 2u64);
/// let net = spec.build(&NetCtx::uniform(4, LinkSpec::nvlink())).unwrap();
/// assert_eq!(net.name(), "nvlink_island");
/// assert_eq!(net.replica_groups(), 2);
///
/// // unknown names are errors listing the known topologies
/// assert!(NetworkSpec::new("torus")
///     .build(&NetCtx::uniform(2, LinkSpec::nvlink()))
///     .is_err());
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkSpec {
    /// Registry name (case-insensitive; aliases accepted).
    pub name: String,
    /// Topology parameters (a [`Yaml::Map`]).
    pub params: Yaml,
}

impl Default for NetworkSpec {
    /// The default topology: `flat`, byte-identical to the
    /// pre-registry single-link pricing.
    fn default() -> Self {
        Self::new("flat")
    }
}

impl NetworkSpec {
    /// A spec with no parameters (registry defaults apply).
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            params: Yaml::Map(Default::default()),
        }
    }

    /// Builder-style parameter.
    pub fn with(mut self, key: &str, value: impl Into<Yaml>) -> Self {
        if let Yaml::Map(m) = &mut self.params {
            m.insert(key.to_string(), value.into());
        }
        self
    }

    /// Parse from a YAML map of the form `{topology: <name>, <params>…}`.
    /// A missing `topology` key selects `flat` (configs without a
    /// `network:` section keep their pre-registry behavior).
    pub fn from_yaml(y: &Yaml) -> Result<Self> {
        let name = match y.get("topology") {
            None => "flat".to_string(),
            Some(v) => v
                .as_str()
                .context("'topology' must be a string (a network-topology name)")?
                .to_string(),
        };
        Ok(Self {
            name,
            params: y.clone(),
        })
    }

    /// Build the topology this spec names over the given fleet.
    pub fn build(&self, ctx: &NetCtx) -> Result<Box<dyn NetworkModel>> {
        build_network(self, ctx)
    }

    /// Check the spec without a real fleet: unknown topology names,
    /// unknown link presets, typo'd parameter keys and malformed
    /// values are errors at parse time, not mid-simulation.
    pub fn validate(&self) -> Result<()> {
        self.build(&NetCtx::uniform(4, LinkSpec::nvlink())).map(|_| ())
    }

    /// Whether this spec selects the default flat topology (under any
    /// alias) — the only one with no shape to check.
    pub fn is_flat(&self) -> bool {
        NETWORK_TOPOLOGIES
            .iter()
            .find(|e| matches_name(&self.name, e.name, e.aliases))
            .is_some_and(|e| e.name == "flat")
    }
}

/// A built-in network topology: name, aliases, summary, parameter
/// keys, constructor.
pub struct NetworkEntry {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    /// One-line description (shown by `tokensim list`).
    pub summary: &'static str,
    /// Accepted parameter keys — anything else in the spec is an error
    /// (catches typo'd keys at parse time).
    pub params: &'static [&'static str],
    pub build: fn(&Yaml, &NetCtx) -> Result<Box<dyn NetworkModel>>,
}

// Strict optional accessors, as in the other registries: a *missing*
// key takes the default, a present-and-malformed value is an error.

fn opt_usize_strict(p: &Yaml, key: &str, default: usize) -> Result<usize> {
    match p.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_u64()
            .map(|n| n as usize)
            .with_context(|| format!("'{key}' must be a non-negative integer")),
    }
}

fn link_param(p: &Yaml, key: &str, default: LinkSpec) -> Result<LinkSpec> {
    match p.get(key) {
        None => Ok(default),
        Some(v) => {
            let name = v
                .as_str()
                .with_context(|| format!("'{key}' must be a link preset name"))?;
            LinkSpec::by_name(name).with_context(|| format!("unknown link preset '{name}'"))
        }
    }
}

fn build_flat(_p: &Yaml, ctx: &NetCtx) -> Result<Box<dyn NetworkModel>> {
    Ok(Box::new(FlatNetwork::new(ctx)))
}

fn build_nvlink_island(p: &Yaml, ctx: &NetCtx) -> Result<Box<dyn NetworkModel>> {
    let island_size = opt_usize_strict(p, "island_size", 4)?;
    if island_size == 0 {
        bail!("'island_size' must be >= 1");
    }
    let intra = link_param(p, "intra_link", ctx.interconnect.clone())?;
    let inter = link_param(p, "inter_link", LinkSpec::infiniband())?;
    Ok(Box::new(NvlinkIslandNetwork::new(ctx, island_size, intra, inter)))
}

fn build_fat_tree(p: &Yaml, ctx: &NetCtx) -> Result<Box<dyn NetworkModel>> {
    let arity = opt_usize_strict(p, "arity", 4)?;
    if arity == 0 {
        bail!("'arity' must be >= 1");
    }
    let access = link_param(p, "access_link", ctx.interconnect.clone())?;
    let uplink = link_param(p, "uplink", LinkSpec::infiniband())?;
    Ok(Box::new(FatTreeNetwork::new(ctx, arity, access, uplink)))
}

fn build_ethernet(p: &Yaml, ctx: &NetCtx) -> Result<Box<dyn NetworkModel>> {
    let segment = link_param(p, "link", LinkSpec::ethernet_100g())?;
    Ok(Box::new(EthernetNetwork::new(ctx, segment)))
}

/// Built-in network topologies.
pub const NETWORK_TOPOLOGIES: &[NetworkEntry] = &[
    NetworkEntry {
        name: "flat",
        aliases: &["uniform", "single_link"],
        summary: "one uncontended all-to-all link (the pre-registry CommModel; default)",
        params: &[],
        build: build_flat,
    },
    NetworkEntry {
        name: "nvlink_island",
        aliases: &["island", "dgx"],
        summary: "full-bandwidth islands bridged by a slower inter-island link",
        params: &["island_size", "intra_link", "inter_link"],
        build: build_nvlink_island,
    },
    NetworkEntry {
        name: "fat_tree",
        aliases: &["fattree", "clos"],
        summary: "k-ary leaf/spine tree; cross-leaf transfers share per-uplink bandwidth",
        params: &["arity", "access_link", "uplink"],
        build: build_fat_tree,
    },
    NetworkEntry {
        name: "ethernet",
        aliases: &["shared", "lan"],
        summary: "one shared segment every worker-to-worker and pool transfer contends on",
        params: &["link"],
        build: build_ethernet,
    },
];

// ---------------------------------------------------------------------------
// Runtime registration (library users; built-ins live in the table)
// ---------------------------------------------------------------------------

struct DynNetworkEntry {
    name: String,
    summary: String,
    #[allow(clippy::type_complexity)]
    build: Box<dyn Fn(&Yaml, &NetCtx) -> Result<Box<dyn NetworkModel>> + Send + Sync>,
}

fn extra_networks() -> &'static Mutex<Vec<DynNetworkEntry>> {
    static EXTRA: OnceLock<Mutex<Vec<DynNetworkEntry>>> = OnceLock::new();
    EXTRA.get_or_init(|| Mutex::new(Vec::new()))
}

/// Register a network topology at runtime. Registered names take
/// precedence over built-ins, so a library user can also shadow a
/// built-in topology.
///
/// # Examples
///
/// A "bring your own fabric" flow — here just a reparameterized
/// built-in, but any [`NetworkModel`] implementation works the same:
///
/// ```
/// use tokensim::hardware::LinkSpec;
/// use tokensim::network::{register_network, Endpoint, FlatNetwork, NetCtx, NetworkSpec};
///
/// register_network("copper", "flat over PCIe (demo)", |_params, ctx| {
///     let mut slow = ctx.clone();
///     slow.interconnect = LinkSpec::pcie_gen4_x16();
///     Ok(Box::new(FlatNetwork::new(&slow)))
/// });
///
/// let mut net = NetworkSpec::new("copper")
///     .build(&NetCtx::uniform(2, LinkSpec::nvlink()))
///     .unwrap();
/// let t = net.transfer(Endpoint::Worker(0), Endpoint::Worker(1), 8, 1 << 20, 0.0);
/// let mut fast = NetworkSpec::new("flat")
///     .build(&NetCtx::uniform(2, LinkSpec::nvlink()))
///     .unwrap();
/// let t0 = fast.transfer(Endpoint::Worker(0), Endpoint::Worker(1), 8, 1 << 20, 0.0);
/// assert!(t.finish > t0.finish);
/// ```
pub fn register_network(
    name: &str,
    summary: &str,
    build: impl Fn(&Yaml, &NetCtx) -> Result<Box<dyn NetworkModel>> + Send + Sync + 'static,
) {
    extra_networks().lock().unwrap().push(DynNetworkEntry {
        name: name.to_string(),
        summary: summary.to_string(),
        build: Box::new(build),
    });
}

fn matches_name(candidate: &str, name: &str, aliases: &[&str]) -> bool {
    candidate.eq_ignore_ascii_case(name)
        || aliases.iter().any(|a| candidate.eq_ignore_ascii_case(a))
}

/// Reject typo'd parameter keys for built-in topologies ("topology"
/// itself is the selector key YAML specs carry). Runtime-registered
/// topologies validate their own params in their builder.
fn check_param_keys(spec: &NetworkSpec, known: &[&str]) -> Result<()> {
    if let Yaml::Map(m) = &spec.params {
        for key in m.keys() {
            if key != "topology" && !known.contains(&key.as_str()) {
                bail!(
                    "unknown parameter '{key}' for network topology '{}' (accepted: {})",
                    spec.name,
                    if known.is_empty() {
                        "none".to_string()
                    } else {
                        known.join(", ")
                    }
                );
            }
        }
    }
    Ok(())
}

/// Build a network topology from a spec. Unknown names list the known
/// topologies in the error.
pub fn build_network(spec: &NetworkSpec, ctx: &NetCtx) -> Result<Box<dyn NetworkModel>> {
    {
        let extras = extra_networks().lock().unwrap();
        if let Some(e) = extras
            .iter()
            .rev()
            .find(|e| spec.name.eq_ignore_ascii_case(&e.name))
        {
            return (e.build)(&spec.params, ctx)
                .with_context(|| format!("building network topology '{}'", spec.name));
        }
    }
    let entry = NETWORK_TOPOLOGIES
        .iter()
        .find(|e| matches_name(&spec.name, e.name, e.aliases))
        .with_context(|| {
            format!(
                "unknown network topology '{}' (known: {})",
                spec.name,
                network_topologies()
                    .iter()
                    .map(|(n, _, _)| n.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })?;
    check_param_keys(spec, entry.params)?;
    (entry.build)(&spec.params, ctx)
        .with_context(|| format!("building network topology '{}'", spec.name))
}

/// All registered topologies as `(name, summary, accepted-params)`,
/// built-ins first.
pub fn network_topologies() -> Vec<(String, String, String)> {
    let mut out: Vec<(String, String, String)> = NETWORK_TOPOLOGIES
        .iter()
        .map(|e| {
            (
                e.name.to_string(),
                e.summary.to_string(),
                if e.params.is_empty() {
                    "(none)".to_string()
                } else {
                    e.params.join(", ")
                },
            )
        })
        .collect();
    for e in extra_networks().lock().unwrap().iter() {
        out.push((
            e.name.clone(),
            e.summary.clone(),
            "(topology-defined)".to_string(),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::Endpoint;

    fn ctx() -> NetCtx {
        NetCtx::uniform(4, LinkSpec::nvlink())
    }

    #[test]
    fn builds_every_builtin_topology_with_defaults() {
        for e in NETWORK_TOPOLOGIES {
            let mut net = NetworkSpec::new(e.name)
                .build(&ctx())
                .unwrap_or_else(|err| panic!("{}: {err:#}", e.name));
            assert_eq!(net.name(), e.name);
            let t = net.transfer(Endpoint::Worker(0), Endpoint::Worker(1), 4, 1 << 20, 0.0);
            assert!(t.finish > 0.0, "{}", e.name);
            assert!(net.audit_ledger(t.finish).is_ok(), "{}", e.name);
        }
    }

    #[test]
    fn aliases_and_case_resolve() {
        for (alias, canonical) in [
            ("UNIFORM", "flat"),
            ("island", "nvlink_island"),
            ("DGX", "nvlink_island"),
            ("clos", "fat_tree"),
            ("lan", "ethernet"),
        ] {
            let net = NetworkSpec::new(alias).build(&ctx()).unwrap();
            assert_eq!(net.name(), canonical, "{alias}");
        }
    }

    #[test]
    fn unknown_topology_is_error_listing_known() {
        let err = NetworkSpec::new("torus").build(&ctx()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown network topology 'torus'"), "{msg}");
        assert!(msg.contains("flat") && msg.contains("fat_tree"), "{msg}");
    }

    #[test]
    fn typod_params_are_errors() {
        let err = NetworkSpec::new("nvlink_island")
            .with("island_sz", 2u64)
            .build(&ctx())
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown parameter 'island_sz'"));
        let bad_link = NetworkSpec::new("ethernet").with("link", "warp-pipe");
        let err = bad_link.build(&ctx()).unwrap_err();
        assert!(format!("{err:#}").contains("unknown link preset 'warp-pipe'"));
        let zero = NetworkSpec::new("nvlink_island").with("island_size", 0u64);
        assert!(zero.build(&ctx()).is_err());
    }

    #[test]
    fn from_yaml_defaults_to_flat() {
        let y = Yaml::Map(Default::default());
        let spec = NetworkSpec::from_yaml(&y).unwrap();
        assert_eq!(spec.name, "flat");
        assert!(spec.is_flat());
        assert!(spec.validate().is_ok());
        assert!(!NetworkSpec::new("ethernet").is_flat());
        assert!(NetworkSpec::new("single_link").is_flat());
    }

    #[test]
    fn runtime_registration_shadows_builtins() {
        register_network("test_shadow_eth", "shadow test", |_p, ctx| {
            Ok(Box::new(FlatNetwork::new(ctx)))
        });
        let net = NetworkSpec::new("test_shadow_eth").build(&ctx()).unwrap();
        assert_eq!(net.name(), "flat");
        let names: Vec<String> = network_topologies().into_iter().map(|(n, _, _)| n).collect();
        assert!(names.contains(&"test_shadow_eth".to_string()));
    }
}
