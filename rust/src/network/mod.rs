//! Network subsystem: KV-cache movement between workers, hosts and
//! the memory pool — the fifth pluggable registry.
//!
//! Mirrors the paper's §III-B communication component: "takes cache
//! location, data size and memory bandwidth as arguments and returns
//! the time to transfer the data", with sequential and overlapped
//! (preload-buffer) schedules. [`CommModel`] is the original flat
//! point-to-point model (artifact-backed on the validation path);
//! [`NetworkModel`] generalizes it to whole topologies selected by
//! name through [`NetworkSpec`] (`network: {topology: …}` in YAML):
//! `flat` (the default, byte-identical to `CommModel` pricing),
//! `nvlink_island`, `fat_tree` and `ethernet`, each charging per-link
//! bandwidth contention through a busy-until occupancy ledger
//! ([`LinkLedger`]). Out-of-tree topologies plug in via
//! [`register_network`].

pub mod registry;
pub mod topology;

pub use registry::{
    build_network, network_topologies, register_network, NetCtx, NetworkEntry, NetworkSpec,
    NETWORK_TOPOLOGIES,
};
pub use topology::{EthernetNetwork, FatTreeNetwork, FlatNetwork, LinkLedger, NvlinkIslandNetwork};

use anyhow::Result;

use crate::hardware::LinkSpec;
use crate::runtime::{CompiledArtifact, Manifest};

/// One end of a KV transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Endpoint {
    /// A worker's device memory.
    Worker(usize),
    /// Host DRAM attached to a worker (the swap path).
    Host(usize),
    /// The shared cross-request memory pool.
    Pool,
}

/// A priced transfer: when it starts (after queueing behind earlier
/// traffic on its links), when it finishes, the on-wire time, and the
/// links it crossed.
#[derive(Debug, Clone, PartialEq)]
pub struct Transfer {
    /// When the transfer acquires its links (`>= now` at the call).
    pub start: f64,
    /// When the last byte lands (`start + duration`).
    pub finish: f64,
    /// On-wire time, excluding queueing delay.
    pub duration: f64,
    /// Names of the links crossed, in path order.
    pub path: Vec<String>,
}

impl Transfer {
    /// A zero-byte transfer: free, crosses nothing.
    pub fn instant(now: f64) -> Self {
        Self {
            start: now,
            finish: now,
            duration: 0.0,
            path: Vec::new(),
        }
    }

    /// Wall-clock cost seen by a caller blocking from `now`: queueing
    /// delay plus on-wire time. Exactly `duration` when uncontended.
    pub fn elapsed_from(&self, now: f64) -> f64 {
        (self.start - now) + self.duration
    }
}

/// The transfer schedule a src/dst class pair uses: worker-to-worker
/// KV migration pipelines through the preload buffer (overlapped);
/// swap and pool traffic moves sequentially — matching the three
/// pre-registry `CommModel` fields of the cluster driver.
pub fn class_schedule(src: Endpoint, dst: Endpoint) -> Schedule {
    match (src, dst) {
        (Endpoint::Worker(_), Endpoint::Worker(_)) => Schedule::Overlapped,
        _ => Schedule::Sequential,
    }
}

/// A cluster-wide communication topology.
///
/// The cluster driver holds one `Box<dyn NetworkModel>` and charges
/// every KV movement through it: prefill→decode migration
/// (`Worker→Worker`), swap preempt/restore (`Host↔Worker`) and pool
/// fetches (`Pool→Worker`). Implementations price each transfer and
/// may additionally track per-link occupancy so concurrent transfers
/// queue against each other.
pub trait NetworkModel: Send {
    /// Registry name of the topology.
    fn name(&self) -> &str;

    /// Price a transfer of `n_blocks` KV blocks of `block_bytes` bytes
    /// each from `src` to `dst`, claiming link occupancy from `now`.
    fn transfer(
        &mut self,
        src: Endpoint,
        dst: Endpoint,
        n_blocks: u64,
        block_bytes: u64,
        now: f64,
    ) -> Transfer;

    /// Release hook: drop in-flight bookkeeping for transfers that
    /// finished by `now`. Contended models also self-advance on every
    /// [`NetworkModel::transfer`], so calling this is an optimization,
    /// not a correctness requirement.
    fn advance(&mut self, _now: f64) {}

    /// Audit hook (check A007): link-occupancy conservation — no
    /// transfer finishes before it starts, busy-time is never
    /// double-released. Read-only; must not perturb pricing.
    fn audit_ledger(&self, _now: f64) -> Result<(), String> {
        Ok(())
    }

    /// Number of replica groups the topology partitions workers into
    /// (islands, leaves, …). `1` means no partitioning: the global
    /// scheduler dispatches exactly as it did pre-registry.
    fn replica_groups(&self) -> usize {
        1
    }

    /// The replica group a worker belongs to.
    fn group_of(&self, _worker: usize) -> usize {
        0
    }

    /// Static-analysis hook: every link this topology can route traffic
    /// over, so `tokensim analyze` can compare expected byte rates
    /// against per-link bandwidth without pricing a single transfer.
    /// The default (no links) makes out-of-tree topologies opt out of
    /// network-saturation bounds rather than report wrong ones.
    fn links(&self) -> Vec<LinkSpec> {
        Vec::new()
    }
}

/// Transfer schedule selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Schedule {
    /// Each block transfer waits for the previous (default method).
    Sequential,
    /// Preload-buffer pipelining (depth from the link spec).
    #[default]
    Overlapped,
}

/// Result of a transfer-time evaluation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct XferTime {
    pub sequential: f64,
    pub overlapped: f64,
}

impl XferTime {
    pub fn of(&self, schedule: Schedule) -> f64 {
        match schedule {
            Schedule::Sequential => self.sequential,
            Schedule::Overlapped => self.overlapped,
        }
    }
}

/// Analytic mirror of `xfer_cost_ref` (see `python/compile/kernels/ref.py`).
pub fn xfer_time_analytic(block_bytes: &[f64], link: &LinkSpec) -> XferTime {
    let bw = link.bandwidth;
    let lat = link.latency;
    let depth = (link.buffer_depth as f64).max(1.0);
    let mut n = 0.0f64;
    let mut t_seq = 0.0f64;
    let mut total = 0.0f64;
    for &s in block_bytes {
        if s > 0.0 {
            n += 1.0;
            t_seq += lat + s / bw;
            total += s;
        }
    }
    XferTime {
        sequential: t_seq,
        overlapped: (n / depth).ceil() * lat + total / bw,
    }
}

/// Uniform-blocks convenience: `n_blocks` transfers of `block_bytes` each.
pub fn xfer_time_uniform(n_blocks: u64, block_bytes: u64, link: &LinkSpec) -> XferTime {
    let bw = link.bandwidth;
    let lat = link.latency;
    let depth = (link.buffer_depth as f64).max(1.0);
    let n = n_blocks as f64;
    let total = n * block_bytes as f64;
    XferTime {
        sequential: n * lat + total / bw,
        overlapped: (n / depth).ceil() * lat + total / bw,
    }
}

/// Communication model over a link, optionally artifact-backed.
pub struct CommModel {
    link: LinkSpec,
    schedule: Schedule,
    artifact: Option<(CompiledArtifact, usize)>,
}

impl CommModel {
    /// Pure-rust mirror (default).
    pub fn analytic(link: LinkSpec, schedule: Schedule) -> Self {
        Self {
            link,
            schedule,
            artifact: None,
        }
    }

    /// Artifact-backed evaluation through PJRT (validation path).
    pub fn with_artifact(link: LinkSpec, schedule: Schedule, artifacts_dir: &str) -> Result<Self> {
        let dir = if artifacts_dir.is_empty() {
            crate::runtime::default_artifacts_dir()
        } else {
            artifacts_dir.into()
        };
        let manifest = Manifest::load(&dir)?;
        let entry = manifest
            .artifacts
            .get("xfer_cost")
            .ok_or_else(|| anyhow::anyhow!("manifest lacks xfer_cost"))?;
        let artifact = CompiledArtifact::load(dir.join(&entry.file))?;
        Ok(Self {
            link,
            schedule,
            artifact: Some((artifact, manifest.batch_slots)),
        })
    }

    pub fn link(&self) -> &LinkSpec {
        &self.link
    }

    /// Time to move `n_blocks` KV blocks of `block_bytes` each.
    pub fn kv_transfer_time(&self, n_blocks: u64, block_bytes: u64) -> f64 {
        if n_blocks == 0 {
            return 0.0;
        }
        if let Some((artifact, slots)) = &self.artifact {
            let mut sizes = vec![0.0f32; *slots];
            // Fold transfers beyond the slot count together (latency
            // exposure for the folded tail is approximated by one block).
            let direct = (n_blocks as usize).min(*slots - 1);
            for s in sizes.iter_mut().take(direct) {
                *s = block_bytes as f32;
            }
            if n_blocks as usize > direct {
                sizes[*slots - 1] = ((n_blocks as usize - direct) as u64 * block_bytes) as f32;
            }
            let out = artifact
                .run_f32(&[&sizes, &self.link.to_vec()])
                .expect("xfer artifact failed");
            let t = XferTime {
                sequential: out[0] as f64,
                overlapped: out[1] as f64,
            };
            t.of(self.schedule)
        } else {
            xfer_time_uniform(n_blocks, block_bytes, &self.link).of(self.schedule)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link() -> LinkSpec {
        LinkSpec {
            name: "test".into(),
            bandwidth: 100e9,
            latency: 10e-6,
            buffer_depth: 4,
        }
    }

    #[test]
    fn uniform_matches_general() {
        let l = link();
        let per_block = vec![1e6; 32];
        let a = xfer_time_analytic(&per_block, &l);
        let b = xfer_time_uniform(32, 1_000_000, &l);
        assert!((a.sequential - b.sequential).abs() < 1e-12);
        assert!((a.overlapped - b.overlapped).abs() < 1e-12);
    }

    #[test]
    fn overlap_reduces_latency_exposure() {
        let l = link();
        let t = xfer_time_uniform(64, 1 << 20, &l);
        assert!(t.overlapped < t.sequential);
        // 64 blocks / depth 4 = 16 exposed latencies
        let expect = 16.0 * 10e-6 + 64.0 * (1u64 << 20) as f64 / 100e9;
        assert!((t.overlapped - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn empty_transfer_free() {
        let c = CommModel::analytic(link(), Schedule::Overlapped);
        assert_eq!(c.kv_transfer_time(0, 4096), 0.0);
    }

    #[test]
    fn pool_fabric_800ns_per_block() {
        // Fig 14's setting: retrieval cost is dominated by 800ns/block.
        let c = CommModel::analytic(LinkSpec::pool_fabric(), Schedule::Sequential);
        let t = c.kv_transfer_time(100, 0);
        assert!((t - 100.0 * 800e-9).abs() < 1e-12, "t={t}");
    }

    #[test]
    fn artifact_matches_analytic_when_available() {
        let dir = crate::runtime::default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let l = link();
        let art = CommModel::with_artifact(l.clone(), Schedule::Overlapped, dir.to_str().unwrap())
            .unwrap();
        let ana = CommModel::analytic(l, Schedule::Overlapped);
        for n in [1u64, 7, 64, 500] {
            let ta = art.kv_transfer_time(n, 512 * 1024);
            let tb = ana.kv_transfer_time(n, 512 * 1024);
            let rel = ((ta - tb) / tb).abs();
            assert!(rel < 1e-4, "n={n}: artifact {ta} vs analytic {tb}");
        }
    }
}
