//! Built-in network topologies and the busy-until occupancy ledger.
//!
//! [`FlatNetwork`] reproduces the pre-registry `CommModel` pricing
//! exactly (no contention, per-class schedules); the other three
//! charge per-link bandwidth contention through [`LinkLedger`]: a
//! transfer's start is pushed past the busy-until horizon of every
//! link on its path, so concurrent KV migrations, swap traffic and
//! pool fetches queue against each other instead of being priced
//! independently.

use crate::hardware::LinkSpec;

use super::registry::NetCtx;
use super::{class_schedule, xfer_time_uniform, Endpoint, NetworkModel, Schedule, Transfer};

/// Busy-until occupancy ledger over a set of named links.
///
/// Claiming a path serializes the transfer behind whatever is already
/// occupying any link on it; the claim then extends every path link's
/// busy horizon to the transfer's finish. [`LinkLedger::audit`] is the
/// A007 invariant check: no transfer finishes before it starts, busy
/// horizons only move forward, and every claimed transfer is released
/// exactly once (by [`LinkLedger::advance`], after its finish).
pub struct LinkLedger {
    names: Vec<String>,
    busy_until: Vec<f64>,
    /// `(start, finish)` of claims not yet released by `advance`.
    in_flight: Vec<(f64, f64)>,
    claimed: u64,
    released: u64,
    violation: Option<String>,
}

impl LinkLedger {
    pub fn new(names: Vec<String>) -> Self {
        let n = names.len();
        Self {
            names,
            busy_until: vec![0.0; n],
            in_flight: Vec::new(),
            claimed: 0,
            released: 0,
            violation: None,
        }
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// The time link `id` is occupied through.
    pub fn busy_until(&self, id: usize) -> f64 {
        self.busy_until[id]
    }

    /// Claims not yet released by [`LinkLedger::advance`].
    pub fn in_flight(&self) -> usize {
        self.in_flight.len()
    }

    /// Occupy every link on `path` for `duration` seconds, starting no
    /// earlier than `now` and no earlier than any link's busy horizon.
    /// Returns `(start, finish)`.
    pub fn claim(&mut self, path: &[usize], duration: f64, now: f64) -> (f64, f64) {
        let mut start = now;
        for &id in path {
            if self.busy_until[id] > start {
                start = self.busy_until[id];
            }
        }
        let finish = start + duration;
        if (finish < start || finish.is_nan()) && self.violation.is_none() {
            // negative or NaN duration: record for A007 rather than panic
            self.violation = Some(format!(
                "transfer would finish at {finish:e} before its start at {start:e}"
            ));
        }
        for &id in path {
            if finish < self.busy_until[id] {
                if self.violation.is_none() {
                    self.violation = Some(format!(
                        "link '{}' busy horizon would rewind from {:e} to {finish:e}",
                        self.names[id],
                        self.busy_until[id]
                    ));
                }
            } else {
                self.busy_until[id] = finish;
            }
        }
        self.claimed += 1;
        self.in_flight.push((start, finish));
        (start, finish)
    }

    /// Release claims whose finish is at or before `now`.
    pub fn advance(&mut self, now: f64) {
        let before = self.in_flight.len();
        self.in_flight.retain(|&(_, f)| f > now);
        self.released += (before - self.in_flight.len()) as u64;
    }

    /// A007: link-occupancy conservation.
    pub fn audit(&self, _now: f64) -> Result<(), String> {
        if let Some(v) = &self.violation {
            return Err(v.clone());
        }
        for &(s, f) in &self.in_flight {
            if f < s {
                return Err(format!("in-flight transfer finishes at {f:e} before start {s:e}"));
            }
        }
        for (name, &b) in self.names.iter().zip(&self.busy_until) {
            if !b.is_finite() || b < 0.0 {
                return Err(format!("link '{name}' busy horizon {b:e} is not a valid time"));
            }
        }
        if self.claimed != self.released + self.in_flight.len() as u64 {
            return Err(format!(
                "claim/release imbalance: {} claimed, {} released, {} in flight",
                self.claimed,
                self.released,
                self.in_flight.len()
            ));
        }
        Ok(())
    }
}

/// The effective point-to-point link of a multi-hop path: bottleneck
/// bandwidth, accumulated latency, narrowest preload depth.
pub fn path_link<'a>(links: impl IntoIterator<Item = &'a LinkSpec>) -> LinkSpec {
    let mut bandwidth = f64::INFINITY;
    let mut latency = 0.0;
    let mut buffer_depth = u32::MAX;
    for l in links {
        bandwidth = bandwidth.min(l.bandwidth);
        latency += l.latency;
        buffer_depth = buffer_depth.min(l.buffer_depth);
    }
    LinkSpec {
        name: "path".into(),
        bandwidth,
        latency,
        buffer_depth: if buffer_depth == u32::MAX { 1 } else { buffer_depth },
    }
}

/// Shared plumbing of the contended topologies: the link specs plus
/// the occupancy ledger over them.
struct Fabric {
    specs: Vec<LinkSpec>,
    ledger: LinkLedger,
}

impl Fabric {
    fn new(specs: Vec<LinkSpec>) -> Self {
        let names = specs.iter().map(|s| s.name.clone()).collect();
        Self {
            ledger: LinkLedger::new(names),
            specs,
        }
    }

    fn claim(
        &mut self,
        path: &[usize],
        n_blocks: u64,
        block_bytes: u64,
        schedule: Schedule,
        now: f64,
    ) -> Transfer {
        self.ledger.advance(now);
        if n_blocks == 0 || path.is_empty() {
            return Transfer::instant(now);
        }
        let eff = path_link(path.iter().map(|&i| &self.specs[i]));
        let duration = xfer_time_uniform(n_blocks, block_bytes, &eff).of(schedule);
        let (start, finish) = self.ledger.claim(path, duration, now);
        Transfer {
            start,
            finish,
            duration,
            path: path.iter().map(|&i| self.specs[i].name.clone()).collect(),
        }
    }
}

fn named(name: String, spec: &LinkSpec) -> LinkSpec {
    LinkSpec {
        name,
        ..spec.clone()
    }
}

// ---------------------------------------------------------------------------
// flat
// ---------------------------------------------------------------------------

/// The pre-registry model: one uncontended link between every worker
/// pair, the pool fabric for pool fetches, per-worker host links for
/// swap. Pricing is byte-identical to the three `CommModel` fields the
/// cluster driver used to hold.
pub struct FlatNetwork {
    interconnect: LinkSpec,
    pool_link: LinkSpec,
    swap_links: Vec<Option<LinkSpec>>,
}

impl FlatNetwork {
    pub fn new(ctx: &NetCtx) -> Self {
        Self {
            interconnect: ctx.interconnect.clone(),
            pool_link: ctx.pool_link.clone(),
            swap_links: ctx.swap_links.clone(),
        }
    }
}

impl NetworkModel for FlatNetwork {
    fn name(&self) -> &str {
        "flat"
    }

    fn transfer(
        &mut self,
        src: Endpoint,
        dst: Endpoint,
        n_blocks: u64,
        block_bytes: u64,
        now: f64,
    ) -> Transfer {
        if n_blocks == 0 {
            return Transfer::instant(now);
        }
        let (link, schedule) = match (src, dst) {
            (Endpoint::Worker(_), Endpoint::Worker(_)) => {
                (Some(&self.interconnect), Schedule::Overlapped)
            }
            (Endpoint::Host(w), _) | (_, Endpoint::Host(w)) => {
                (self.swap_links[w].as_ref(), Schedule::Sequential)
            }
            (Endpoint::Pool, _) | (_, Endpoint::Pool) => {
                (Some(&self.pool_link), Schedule::Sequential)
            }
        };
        let Some(link) = link else {
            return Transfer::instant(now);
        };
        let duration = xfer_time_uniform(n_blocks, block_bytes, link).of(schedule);
        Transfer {
            start: now,
            finish: now + duration,
            duration,
            path: vec![link.name.clone()],
        }
    }

    fn links(&self) -> Vec<LinkSpec> {
        let mut out = vec![self.interconnect.clone(), self.pool_link.clone()];
        out.extend(self.swap_links.iter().flatten().cloned());
        out
    }
}

// ---------------------------------------------------------------------------
// nvlink_island
// ---------------------------------------------------------------------------

/// Full-bandwidth islands of `island_size` workers each (a shared
/// intra-island bus), bridged by one slower inter-island link. Islands
/// are the replica groups.
pub struct NvlinkIslandNetwork {
    island_size: usize,
    islands: usize,
    swap_present: Vec<bool>,
    fabric: Fabric,
}

impl NvlinkIslandNetwork {
    pub fn new(ctx: &NetCtx, island_size: usize, intra: LinkSpec, inter: LinkSpec) -> Self {
        let island_size = island_size.max(1);
        let islands = ctx.n_workers.div_ceil(island_size).max(1);
        let mut specs = Vec::with_capacity(islands + 2 + ctx.n_workers);
        for i in 0..islands {
            specs.push(named(format!("island{i}.bus"), &intra));
        }
        specs.push(named("bridge".into(), &inter));
        specs.push(named("pool".into(), &ctx.pool_link));
        for (w, l) in ctx.swap_links.iter().enumerate() {
            let base = l.clone().unwrap_or_else(LinkSpec::host_bus);
            specs.push(named(format!("worker{w}.host"), &base));
        }
        Self {
            island_size,
            islands,
            swap_present: ctx.swap_links.iter().map(|l| l.is_some()).collect(),
            fabric: Fabric::new(specs),
        }
    }

    fn island_of(&self, w: usize) -> usize {
        (w / self.island_size).min(self.islands - 1)
    }

    fn bus(&self, island: usize) -> usize {
        island
    }

    fn bridge(&self) -> usize {
        self.islands
    }

    fn pool(&self) -> usize {
        self.islands + 1
    }

    fn host(&self, w: usize) -> usize {
        self.islands + 2 + w
    }
}

impl NetworkModel for NvlinkIslandNetwork {
    fn name(&self) -> &str {
        "nvlink_island"
    }

    fn transfer(
        &mut self,
        src: Endpoint,
        dst: Endpoint,
        n_blocks: u64,
        block_bytes: u64,
        now: f64,
    ) -> Transfer {
        let schedule = class_schedule(src, dst);
        match (src, dst) {
            (Endpoint::Worker(a), Endpoint::Worker(b)) => {
                let (ia, ib) = (self.island_of(a), self.island_of(b));
                if ia == ib {
                    let path = [self.bus(ia)];
                    self.fabric.claim(&path, n_blocks, block_bytes, schedule, now)
                } else {
                    let path = [self.bus(ia), self.bridge(), self.bus(ib)];
                    self.fabric.claim(&path, n_blocks, block_bytes, schedule, now)
                }
            }
            (Endpoint::Host(h), _) | (_, Endpoint::Host(h)) => {
                if !self.swap_present[h] {
                    return Transfer::instant(now);
                }
                let path = [self.host(h)];
                self.fabric.claim(&path, n_blocks, block_bytes, schedule, now)
            }
            (Endpoint::Pool, Endpoint::Worker(w)) | (Endpoint::Worker(w), Endpoint::Pool) => {
                let path = [self.pool(), self.bus(self.island_of(w))];
                self.fabric.claim(&path, n_blocks, block_bytes, schedule, now)
            }
            (Endpoint::Pool, Endpoint::Pool) => Transfer::instant(now),
        }
    }

    fn advance(&mut self, now: f64) {
        self.fabric.ledger.advance(now);
    }

    fn audit_ledger(&self, now: f64) -> Result<(), String> {
        self.fabric.ledger.audit(now)
    }

    fn links(&self) -> Vec<LinkSpec> {
        self.fabric.specs.clone()
    }

    fn replica_groups(&self) -> usize {
        self.islands
    }

    fn group_of(&self, worker: usize) -> usize {
        self.island_of(worker)
    }
}

// ---------------------------------------------------------------------------
// fat_tree
// ---------------------------------------------------------------------------

/// A k-ary leaf/spine tree: every worker hangs off its own access
/// link, `arity` workers share a leaf, and each leaf reaches the spine
/// over one uplink whose bandwidth all of its cross-leaf transfers
/// share. Leaves are the replica groups.
pub struct FatTreeNetwork {
    arity: usize,
    n_workers: usize,
    leaves: usize,
    swap_present: Vec<bool>,
    fabric: Fabric,
}

impl FatTreeNetwork {
    pub fn new(ctx: &NetCtx, arity: usize, access: LinkSpec, uplink: LinkSpec) -> Self {
        let arity = arity.max(1);
        let leaves = ctx.n_workers.div_ceil(arity).max(1);
        let mut specs = Vec::with_capacity(2 * ctx.n_workers + leaves + 1);
        for w in 0..ctx.n_workers {
            specs.push(named(format!("worker{w}.access"), &access));
        }
        for l in 0..leaves {
            specs.push(named(format!("leaf{l}.uplink"), &uplink));
        }
        specs.push(named("pool".into(), &ctx.pool_link));
        for (w, l) in ctx.swap_links.iter().enumerate() {
            let base = l.clone().unwrap_or_else(LinkSpec::host_bus);
            specs.push(named(format!("worker{w}.host"), &base));
        }
        Self {
            arity,
            n_workers: ctx.n_workers,
            leaves,
            swap_present: ctx.swap_links.iter().map(|l| l.is_some()).collect(),
            fabric: Fabric::new(specs),
        }
    }

    fn leaf_of(&self, w: usize) -> usize {
        (w / self.arity).min(self.leaves - 1)
    }

    fn access(&self, w: usize) -> usize {
        w
    }

    fn uplink(&self, leaf: usize) -> usize {
        self.n_workers + leaf
    }

    fn pool(&self) -> usize {
        self.n_workers + self.leaves
    }

    fn host(&self, w: usize) -> usize {
        self.n_workers + self.leaves + 1 + w
    }
}

impl NetworkModel for FatTreeNetwork {
    fn name(&self) -> &str {
        "fat_tree"
    }

    fn transfer(
        &mut self,
        src: Endpoint,
        dst: Endpoint,
        n_blocks: u64,
        block_bytes: u64,
        now: f64,
    ) -> Transfer {
        let schedule = class_schedule(src, dst);
        match (src, dst) {
            (Endpoint::Worker(a), Endpoint::Worker(b)) => {
                let (la, lb) = (self.leaf_of(a), self.leaf_of(b));
                if a == b {
                    let path = [self.access(a)];
                    self.fabric.claim(&path, n_blocks, block_bytes, schedule, now)
                } else if la == lb {
                    let path = [self.access(a), self.access(b)];
                    self.fabric.claim(&path, n_blocks, block_bytes, schedule, now)
                } else {
                    let path = [
                        self.access(a),
                        self.uplink(la),
                        self.uplink(lb),
                        self.access(b),
                    ];
                    self.fabric.claim(&path, n_blocks, block_bytes, schedule, now)
                }
            }
            (Endpoint::Host(h), _) | (_, Endpoint::Host(h)) => {
                if !self.swap_present[h] {
                    return Transfer::instant(now);
                }
                let path = [self.host(h)];
                self.fabric.claim(&path, n_blocks, block_bytes, schedule, now)
            }
            (Endpoint::Pool, Endpoint::Worker(w)) | (Endpoint::Worker(w), Endpoint::Pool) => {
                let path = [self.pool(), self.uplink(self.leaf_of(w)), self.access(w)];
                self.fabric.claim(&path, n_blocks, block_bytes, schedule, now)
            }
            (Endpoint::Pool, Endpoint::Pool) => Transfer::instant(now),
        }
    }

    fn advance(&mut self, now: f64) {
        self.fabric.ledger.advance(now);
    }

    fn audit_ledger(&self, now: f64) -> Result<(), String> {
        self.fabric.ledger.audit(now)
    }

    fn links(&self) -> Vec<LinkSpec> {
        self.fabric.specs.clone()
    }

    fn replica_groups(&self) -> usize {
        self.leaves
    }

    fn group_of(&self, worker: usize) -> usize {
        self.leaf_of(worker)
    }
}

// ---------------------------------------------------------------------------
// ethernet
// ---------------------------------------------------------------------------

/// One shared segment: every worker-to-worker and pool transfer in the
/// cluster contends on the same link. Swap stays on per-worker host
/// buses (it never crosses the wire).
pub struct EthernetNetwork {
    swap_present: Vec<bool>,
    fabric: Fabric,
}

impl EthernetNetwork {
    pub fn new(ctx: &NetCtx, segment: LinkSpec) -> Self {
        let mut specs = Vec::with_capacity(2 + ctx.n_workers);
        specs.push(named("segment".into(), &segment));
        specs.push(named("pool".into(), &ctx.pool_link));
        for (w, l) in ctx.swap_links.iter().enumerate() {
            let base = l.clone().unwrap_or_else(LinkSpec::host_bus);
            specs.push(named(format!("worker{w}.host"), &base));
        }
        Self {
            swap_present: ctx.swap_links.iter().map(|l| l.is_some()).collect(),
            fabric: Fabric::new(specs),
        }
    }

    fn host(&self, w: usize) -> usize {
        2 + w
    }
}

impl NetworkModel for EthernetNetwork {
    fn name(&self) -> &str {
        "ethernet"
    }

    fn transfer(
        &mut self,
        src: Endpoint,
        dst: Endpoint,
        n_blocks: u64,
        block_bytes: u64,
        now: f64,
    ) -> Transfer {
        let schedule = class_schedule(src, dst);
        match (src, dst) {
            (Endpoint::Worker(_), Endpoint::Worker(_)) => {
                self.fabric.claim(&[0], n_blocks, block_bytes, schedule, now)
            }
            (Endpoint::Host(h), _) | (_, Endpoint::Host(h)) => {
                if !self.swap_present[h] {
                    return Transfer::instant(now);
                }
                let path = [self.host(h)];
                self.fabric.claim(&path, n_blocks, block_bytes, schedule, now)
            }
            (Endpoint::Pool, Endpoint::Worker(_)) | (Endpoint::Worker(_), Endpoint::Pool) => {
                self.fabric.claim(&[1, 0], n_blocks, block_bytes, schedule, now)
            }
            (Endpoint::Pool, Endpoint::Pool) => Transfer::instant(now),
        }
    }

    fn advance(&mut self, now: f64) {
        self.fabric.ledger.advance(now);
    }

    fn audit_ledger(&self, now: f64) -> Result<(), String> {
        self.fabric.ledger.audit(now)
    }

    fn links(&self) -> Vec<LinkSpec> {
        self.fabric.specs.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::CommModel;

    fn ctx(n: usize) -> NetCtx {
        NetCtx {
            n_workers: n,
            interconnect: LinkSpec::nvlink(),
            pool_link: LinkSpec::pool_fabric(),
            swap_links: (0..n).map(|_| Some(LinkSpec::host_bus())).collect(),
        }
    }

    const BB: u64 = 8 << 20; // llama2-7b 16-token block

    #[test]
    fn flat_matches_comm_model_per_class() {
        let c = ctx(4);
        let mut net = FlatNetwork::new(&c);
        let comm = CommModel::analytic(LinkSpec::nvlink(), Schedule::Overlapped);
        let pool = CommModel::analytic(LinkSpec::pool_fabric(), Schedule::Sequential);
        for n in [0u64, 1, 7, 129] {
            let t = net.transfer(Endpoint::Worker(0), Endpoint::Worker(1), n, BB, 3.0);
            assert_eq!(t.elapsed_from(3.0), comm.kv_transfer_time(n, BB), "n={n}");
            assert_eq!(t.finish, 3.0 + comm.kv_transfer_time(n, BB), "n={n}");
            let p = net.transfer(Endpoint::Pool, Endpoint::Worker(2), n, BB, 3.0);
            assert_eq!(p.elapsed_from(3.0), pool.kv_transfer_time(n, BB), "n={n}");
        }
        // swap is priced sequentially over the per-worker host link
        let s = net.transfer(Endpoint::Host(1), Endpoint::Worker(1), 10, BB, 0.0);
        let want = xfer_time_uniform(10, BB, &LinkSpec::host_bus()).of(Schedule::Sequential);
        assert_eq!(s.elapsed_from(0.0), want);
    }

    #[test]
    fn flat_without_swap_link_is_free() {
        let mut c = ctx(2);
        c.swap_links = vec![None, None];
        let mut net = FlatNetwork::new(&c);
        let t = net.transfer(Endpoint::Host(0), Endpoint::Worker(0), 10, BB, 1.0);
        assert_eq!(t.finish, 1.0);
        assert!(t.path.is_empty());
    }

    fn island2(c: &NetCtx) -> NvlinkIslandNetwork {
        NvlinkIslandNetwork::new(c, 2, LinkSpec::nvlink(), LinkSpec::infiniband())
    }

    #[test]
    fn island_paths_and_bandwidth() {
        let c = ctx(4);
        let mut net = island2(&c);
        assert_eq!(net.replica_groups(), 2);
        assert_eq!(net.group_of(1), 0);
        assert_eq!(net.group_of(2), 1);
        // same island: one bus hop at full NVLink bandwidth
        let intra = net.transfer(Endpoint::Worker(0), Endpoint::Worker(1), 16, BB, 0.0);
        assert_eq!(intra.path, vec!["island0.bus"]);
        let want = xfer_time_uniform(16, BB, &LinkSpec::nvlink()).of(Schedule::Overlapped);
        assert_eq!(intra.duration, want);
        // cross island: bus -> bridge -> bus, bottlenecked by the bridge
        let mut fresh = island2(&c);
        let inter = fresh.transfer(Endpoint::Worker(0), Endpoint::Worker(2), 16, BB, 0.0);
        assert_eq!(inter.path, vec!["island0.bus", "bridge", "island1.bus"]);
        assert!(inter.duration > intra.duration);
        let eff = path_link([&LinkSpec::nvlink(), &LinkSpec::infiniband(), &LinkSpec::nvlink()]);
        assert_eq!(eff.bandwidth, LinkSpec::infiniband().bandwidth);
        assert_eq!(inter.duration, xfer_time_uniform(16, BB, &eff).of(Schedule::Overlapped));
    }

    #[test]
    fn fat_tree_paths() {
        let c = ctx(4);
        let mut net = FatTreeNetwork::new(&c, 2, LinkSpec::nvlink(), LinkSpec::infiniband());
        assert_eq!(net.replica_groups(), 2);
        let same = net.transfer(Endpoint::Worker(0), Endpoint::Worker(1), 8, BB, 0.0);
        assert_eq!(same.path, vec!["worker0.access", "worker1.access"]);
        let cross = net.transfer(Endpoint::Worker(0), Endpoint::Worker(3), 8, BB, 100.0);
        let hops = vec!["worker0.access", "leaf0.uplink", "leaf1.uplink", "worker3.access"];
        assert_eq!(cross.path, hops);
        assert!(cross.duration > same.duration, "uplink is the bottleneck");
        let pooled = net.transfer(Endpoint::Pool, Endpoint::Worker(2), 8, BB, 200.0);
        assert_eq!(pooled.path, vec!["pool", "leaf1.uplink", "worker2.access"]);
    }

    #[test]
    fn ethernet_contention_queues_transfers() {
        let c = ctx(4);
        let mut net = EthernetNetwork::new(&c, LinkSpec::ethernet_100g());
        let a = net.transfer(Endpoint::Worker(0), Endpoint::Worker(1), 64, BB, 0.0);
        assert_eq!(a.start, 0.0);
        assert!(a.finish > 0.0);
        // second transfer on the shared segment queues behind the first
        let b = net.transfer(Endpoint::Worker(2), Endpoint::Worker(3), 64, BB, 0.0);
        assert_eq!(b.start, a.finish);
        assert_eq!(b.finish, a.finish + b.duration);
        // swap rides the per-worker host bus, not the segment
        let s = net.transfer(Endpoint::Host(0), Endpoint::Worker(0), 4, BB, 0.0);
        assert_eq!(s.start, 0.0);
        // after the wire drains, new transfers start immediately again
        let late = net.transfer(Endpoint::Worker(0), Endpoint::Worker(2), 1, BB, b.finish + 1.0);
        assert_eq!(late.start, b.finish + 1.0);
        assert!(net.audit_ledger(late.finish).is_ok());
    }

    #[test]
    fn contention_never_decreases_finish_time() {
        // property: against every contended topology, a transfer priced
        // with prior traffic on the ledger finishes no earlier than the
        // same transfer against an idle network.
        fn build_topo(name: &str) -> Box<dyn NetworkModel> {
            let c = NetCtx {
                n_workers: 8,
                interconnect: LinkSpec::nvlink(),
                pool_link: LinkSpec::pool_fabric(),
                swap_links: (0..8).map(|_| Some(LinkSpec::host_bus())).collect(),
            };
            match name {
                "nvlink_island" => Box::new(NvlinkIslandNetwork::new(
                    &c,
                    4,
                    LinkSpec::nvlink(),
                    LinkSpec::infiniband(),
                )),
                "fat_tree" => Box::new(FatTreeNetwork::new(
                    &c,
                    2,
                    LinkSpec::nvlink(),
                    LinkSpec::infiniband(),
                )),
                _ => Box::new(EthernetNetwork::new(&c, LinkSpec::ethernet_100g())),
            }
        }
        for name in ["nvlink_island", "fat_tree", "ethernet"] {
            // deterministic LCG over (src, dst, size, gap)
            let mut state = 0x2545F4914F6CDD1Du64;
            let mut rng = move |m: u64| {
                state = state.wrapping_mul(6364136223846793005);
                state = state.wrapping_add(1442695040888963407);
                (state >> 33) % m
            };
            let mut net = build_topo(name);
            let mut now = 0.0f64;
            for step in 0..400 {
                let src = rng(8) as usize;
                let dst = rng(8) as usize;
                let n = rng(64) + 1;
                let ep = |w: usize, kind: u64| match kind {
                    0 => Endpoint::Worker(w),
                    1 => Endpoint::Host(w),
                    _ => Endpoint::Pool,
                };
                let (s, d) = (ep(src, rng(3)), ep(dst, rng(3)));
                let t = net.transfer(s, d, n, BB, now);
                let mut idle = build_topo(name);
                let t0 = idle.transfer(s, d, n, BB, now);
                assert!(t.start >= now, "{name} step {step}");
                assert!(
                    t.finish >= t0.finish,
                    "{name} step {step}: contended {} < idle {}",
                    t.finish,
                    t0.finish
                );
                assert_eq!(t.duration, t0.duration, "{name} step {step}");
                assert!(net.audit_ledger(now).is_ok(), "{name} step {step}");
                now += rng(1000) as f64 * 1e-5;
            }
        }
    }

    #[test]
    fn ledger_audit_catches_negative_duration() {
        let mut l = LinkLedger::new(vec!["x".into()]);
        l.claim(&[0], 1.0, 0.0);
        assert!(l.audit(0.0).is_ok());
        l.claim(&[0], -1.0, 2.0);
        assert!(l.audit(2.0).is_err());
    }

    #[test]
    fn ledger_releases_each_claim_once() {
        let mut l = LinkLedger::new(vec!["a".into(), "b".into()]);
        l.claim(&[0], 1.0, 0.0);
        l.claim(&[0, 1], 2.0, 0.0);
        assert_eq!(l.in_flight(), 2);
        l.advance(0.5);
        assert_eq!(l.in_flight(), 2, "nothing finished yet");
        l.advance(1.0);
        assert_eq!(l.in_flight(), 1);
        l.advance(100.0);
        assert_eq!(l.in_flight(), 0);
        l.advance(200.0);
        assert!(l.audit(200.0).is_ok());
        assert_eq!(l.busy_until(1), 3.0, "second claim queued behind the first");
    }
}
