//! Hardware catalog: accelerator specs and interconnect links.
//!
//! The simulator only consumes *parameters* (peak FLOPS, memory
//! bandwidth/capacity, overheads, price) — exactly like the paper, which
//! models the A100/V100/GDDR6-AiM as parameter sets fed to the compute
//! simulator. Scaling helpers implement the `T`/`B`/`C` knobs of Fig 15.

mod catalog;
mod link;

pub use catalog::HardwareSpec;
pub use link::{link_preset_names, LinkCatalogEntry, LinkKind, LinkSpec, LINK_CATALOG};
