//! Interconnect link specifications for the communication model.


/// Named link presets matching the paper's hardware config (Fig 2a).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    Nvlink,
    Pcie,
    Ethernet100G,
    /// Host DRAM <-> device (swap path).
    HostBus,
    /// Memory-pool fabric of MemServe-style KV caches.
    PoolFabric,
}

/// A point-to-point link: bandwidth, latency, and the preload-buffer
/// depth the overlapped transfer schedule may use.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkSpec {
    pub name: String,
    /// Bytes per second.
    pub bandwidth: f64,
    /// Per-transfer latency, seconds.
    pub latency: f64,
    /// Preload-buffer depth for overlapped schedules (1 = sequential).
    pub buffer_depth: u32,
}

impl LinkSpec {
    pub fn nvlink() -> Self {
        Self {
            name: "NVLink".into(),
            bandwidth: 600e9,
            latency: 5e-6,
            buffer_depth: 8,
        }
    }

    pub fn pcie_gen4_x16() -> Self {
        Self {
            name: "PCIe".into(),
            bandwidth: 32e9,
            latency: 10e-6,
            buffer_depth: 4,
        }
    }

    pub fn ethernet_100g() -> Self {
        Self {
            name: "Ethernet-100G".into(),
            bandwidth: 12.5e9,
            latency: 50e-6,
            buffer_depth: 4,
        }
    }

    pub fn host_bus() -> Self {
        Self {
            name: "HostBus".into(),
            bandwidth: 24e9,
            latency: 8e-6,
            buffer_depth: 2,
        }
    }

    /// MemServe-style memory-pool retrieval: the paper's Fig 14 uses
    /// 800 ns per block, which we encode as pure latency on a fat pipe.
    pub fn pool_fabric() -> Self {
        Self {
            name: "PoolFabric".into(),
            bandwidth: 1e12,
            latency: 800e-9,
            buffer_depth: 1,
        }
    }

    pub fn of_kind(kind: LinkKind) -> Self {
        match kind {
            LinkKind::Nvlink => Self::nvlink(),
            LinkKind::Pcie => Self::pcie_gen4_x16(),
            LinkKind::Ethernet100G => Self::ethernet_100g(),
            LinkKind::HostBus => Self::host_bus(),
            LinkKind::PoolFabric => Self::pool_fabric(),
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "NVLink" | "nvlink" => Some(Self::nvlink()),
            "PCIe" | "pcie" => Some(Self::pcie_gen4_x16()),
            "Ethernet-100G" | "ethernet-100g" => Some(Self::ethernet_100g()),
            "HostBus" | "host-bus" => Some(Self::host_bus()),
            "PoolFabric" | "pool-fabric" => Some(Self::pool_fabric()),
            _ => None,
        }
    }

    /// The float32 vector consumed by the xfer-cost artifact.
    pub fn to_vec(&self) -> [f32; 3] {
        [
            self.bandwidth as f32,
            self.latency as f32,
            self.buffer_depth as f32,
        ]
    }

    /// Set the measured bandwidth (the paper's Fig 7 methodology: "we
    /// measure the actual communication bandwidth ... and use this data
    /// to configure TokenSim").
    pub fn with_measured_bandwidth(mut self, bw: f64) -> Self {
        self.bandwidth = bw;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_ordering() {
        assert!(LinkSpec::nvlink().bandwidth > LinkSpec::pcie_gen4_x16().bandwidth);
        assert!(LinkSpec::pcie_gen4_x16().bandwidth > LinkSpec::ethernet_100g().bandwidth);
    }

    #[test]
    fn pool_fabric_is_pure_latency() {
        let l = LinkSpec::pool_fabric();
        assert!((l.latency - 800e-9).abs() < 1e-15);
        // a 16-token llama2-7b block (8 MiB) transfers in ~8.4 us
        let t = l.latency + 8.4e6 / l.bandwidth;
        assert!(t < 1e-5);
    }

    #[test]
    fn kind_and_name_lookup_agree() {
        for (kind, name) in [
            (LinkKind::Nvlink, "NVLink"),
            (LinkKind::Pcie, "PCIe"),
            (LinkKind::Ethernet100G, "Ethernet-100G"),
        ] {
            assert_eq!(LinkSpec::of_kind(kind), LinkSpec::by_name(name).unwrap());
        }
    }

    #[test]
    fn measured_bandwidth_override() {
        let l = LinkSpec::nvlink().with_measured_bandwidth(432e9);
        assert_eq!(l.bandwidth, 432e9);
        assert_eq!(l.latency, LinkSpec::nvlink().latency);
    }
}
