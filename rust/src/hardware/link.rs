//! Interconnect link specifications for the communication model.
//!
//! All link presets live in one [`LINK_CATALOG`] table — the single
//! source of truth for names, aliases and parameters — read by
//! [`LinkSpec::by_name`], the network-topology registry
//! (`crate::network::registry`), the linter's did-you-mean hints and
//! `tokensim list`.

/// Named link presets matching the paper's hardware config (Fig 2a).
///
/// Pre-catalog enum kept for source compatibility; new code should
/// select links by name through [`LinkSpec::by_name`] / the
/// [`LINK_CATALOG`] table instead. Converts losslessly via
/// `LinkSpec::from(kind)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    Nvlink,
    Pcie,
    Ethernet100G,
    /// Host DRAM <-> device (swap path).
    HostBus,
    /// Memory-pool fabric of MemServe-style KV caches.
    PoolFabric,
}

/// A point-to-point link: bandwidth, latency, and the preload-buffer
/// depth the overlapped transfer schedule may use.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkSpec {
    pub name: String,
    /// Bytes per second.
    pub bandwidth: f64,
    /// Per-transfer latency, seconds.
    pub latency: f64,
    /// Preload-buffer depth for overlapped schedules (1 = sequential).
    pub buffer_depth: u32,
}

/// One row of the link-preset catalog: canonical name, accepted
/// aliases (matched case-insensitively, like the registry tables), a
/// one-line summary and the preset constructor.
pub struct LinkCatalogEntry {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub summary: &'static str,
    pub build: fn() -> LinkSpec,
}

/// The link-preset catalog. `by_name`, the network registry, lint
/// did-you-mean hints and `tokensim list` all read this table, so a
/// new preset is one row here.
pub const LINK_CATALOG: &[LinkCatalogEntry] = &[
    LinkCatalogEntry {
        name: "NVLink",
        aliases: &[],
        summary: "intra-node GPU interconnect (600 GB/s, 5 us)",
        build: LinkSpec::nvlink,
    },
    LinkCatalogEntry {
        name: "PCIe",
        aliases: &["pcie_gen4_x16"],
        summary: "PCIe gen4 x16 (32 GB/s, 10 us)",
        build: LinkSpec::pcie_gen4_x16,
    },
    LinkCatalogEntry {
        name: "InfiniBand",
        aliases: &["ib", "hdr200"],
        summary: "inter-node HDR fabric (25 GB/s, 2 us)",
        build: LinkSpec::infiniband,
    },
    LinkCatalogEntry {
        name: "Ethernet-100G",
        aliases: &["ethernet", "eth100g"],
        summary: "shared 100G segment (12.5 GB/s, 50 us)",
        build: LinkSpec::ethernet_100g,
    },
    LinkCatalogEntry {
        name: "HostBus",
        aliases: &["host-bus", "host_bus"],
        summary: "host DRAM <-> device swap path (24 GB/s, 8 us)",
        build: LinkSpec::host_bus,
    },
    LinkCatalogEntry {
        name: "PoolFabric",
        aliases: &["pool-fabric", "pool_fabric"],
        summary: "MemServe-style pool retrieval (800 ns/block)",
        build: LinkSpec::pool_fabric,
    },
];

/// Canonical names of every catalogued link preset (listing order).
pub fn link_preset_names() -> Vec<&'static str> {
    LINK_CATALOG.iter().map(|e| e.name).collect()
}

impl LinkSpec {
    pub fn nvlink() -> Self {
        Self {
            name: "NVLink".into(),
            bandwidth: 600e9,
            latency: 5e-6,
            buffer_depth: 8,
        }
    }

    pub fn pcie_gen4_x16() -> Self {
        Self {
            name: "PCIe".into(),
            bandwidth: 32e9,
            latency: 10e-6,
            buffer_depth: 4,
        }
    }

    /// Inter-node HDR InfiniBand (200 Gb/s per port): the default
    /// inter-island / uplink fabric of the topology models.
    pub fn infiniband() -> Self {
        Self {
            name: "InfiniBand".into(),
            bandwidth: 25e9,
            latency: 2e-6,
            buffer_depth: 8,
        }
    }

    pub fn ethernet_100g() -> Self {
        Self {
            name: "Ethernet-100G".into(),
            bandwidth: 12.5e9,
            latency: 50e-6,
            buffer_depth: 4,
        }
    }

    pub fn host_bus() -> Self {
        Self {
            name: "HostBus".into(),
            bandwidth: 24e9,
            latency: 8e-6,
            buffer_depth: 2,
        }
    }

    /// MemServe-style memory-pool retrieval: the paper's Fig 14 uses
    /// 800 ns per block, which we encode as pure latency on a fat pipe.
    pub fn pool_fabric() -> Self {
        Self {
            name: "PoolFabric".into(),
            bandwidth: 1e12,
            latency: 800e-9,
            buffer_depth: 1,
        }
    }

    pub fn of_kind(kind: LinkKind) -> Self {
        kind.into()
    }

    /// Look a preset up in [`LINK_CATALOG`] by canonical name or alias,
    /// case-insensitively.
    pub fn by_name(name: &str) -> Option<Self> {
        LINK_CATALOG
            .iter()
            .find(|e| {
                e.name.eq_ignore_ascii_case(name)
                    || e.aliases.iter().any(|a| a.eq_ignore_ascii_case(name))
            })
            .map(|e| (e.build)())
    }

    /// The float32 vector consumed by the xfer-cost artifact.
    pub fn to_vec(&self) -> [f32; 3] {
        [
            self.bandwidth as f32,
            self.latency as f32,
            self.buffer_depth as f32,
        ]
    }

    /// Set the measured bandwidth (the paper's Fig 7 methodology: "we
    /// measure the actual communication bandwidth ... and use this data
    /// to configure TokenSim").
    pub fn with_measured_bandwidth(mut self, bw: f64) -> Self {
        self.bandwidth = bw;
        self
    }
}

impl From<LinkKind> for LinkSpec {
    fn from(kind: LinkKind) -> Self {
        match kind {
            LinkKind::Nvlink => Self::nvlink(),
            LinkKind::Pcie => Self::pcie_gen4_x16(),
            LinkKind::Ethernet100G => Self::ethernet_100g(),
            LinkKind::HostBus => Self::host_bus(),
            LinkKind::PoolFabric => Self::pool_fabric(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_ordering() {
        assert!(LinkSpec::nvlink().bandwidth > LinkSpec::pcie_gen4_x16().bandwidth);
        assert!(LinkSpec::pcie_gen4_x16().bandwidth > LinkSpec::infiniband().bandwidth);
        assert!(LinkSpec::infiniband().bandwidth > LinkSpec::ethernet_100g().bandwidth);
    }

    #[test]
    fn pool_fabric_is_pure_latency() {
        let l = LinkSpec::pool_fabric();
        assert!((l.latency - 800e-9).abs() < 1e-15);
        // a 16-token llama2-7b block (8 MiB) transfers in ~8.4 us
        let t = l.latency + 8.4e6 / l.bandwidth;
        assert!(t < 1e-5);
    }

    #[test]
    fn kind_and_name_lookup_agree() {
        for (kind, name) in [
            (LinkKind::Nvlink, "NVLink"),
            (LinkKind::Pcie, "PCIe"),
            (LinkKind::Ethernet100G, "Ethernet-100G"),
            (LinkKind::HostBus, "HostBus"),
            (LinkKind::PoolFabric, "PoolFabric"),
        ] {
            assert_eq!(LinkSpec::of_kind(kind), LinkSpec::by_name(name).unwrap());
            assert_eq!(LinkSpec::from(kind), LinkSpec::by_name(name).unwrap());
        }
    }

    #[test]
    fn catalog_resolves_every_name_alias_and_case() {
        for entry in LINK_CATALOG {
            let canon = (entry.build)();
            assert_eq!(canon.name, entry.name, "preset name matches catalog row");
            assert_eq!(LinkSpec::by_name(entry.name).unwrap(), canon);
            assert_eq!(
                LinkSpec::by_name(&entry.name.to_lowercase()).unwrap(),
                canon,
                "{}: case-insensitive",
                entry.name
            );
            for alias in entry.aliases {
                assert_eq!(LinkSpec::by_name(alias).unwrap(), canon, "alias {alias}");
            }
        }
        // the pre-catalog spellings stay accepted
        for name in ["nvlink", "pcie", "ethernet-100g", "host-bus", "pool-fabric"] {
            assert!(LinkSpec::by_name(name).is_some(), "{name}");
        }
        assert!(LinkSpec::by_name("no-such-link").is_none());
    }

    #[test]
    fn measured_bandwidth_override() {
        let l = LinkSpec::nvlink().with_measured_bandwidth(432e9);
        assert_eq!(l.bandwidth, 432e9);
        assert_eq!(l.latency, LinkSpec::nvlink().latency);
    }
}
