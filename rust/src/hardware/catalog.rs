//! Accelerator parameter sets.


/// An accelerator described by the parameters the cost model consumes.
///
/// `peak_flops` is the *spec-sheet* dense fp16 peak; the cost artifact
/// receives `peak_flops * efficiency` (sustained GEMM efficiency), which
/// is how the paper's "compute simulator" configs encode achievable
/// throughput.
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareSpec {
    pub name: String,
    /// Spec-sheet peak fp16 FLOP/s.
    pub peak_flops: f64,
    /// Sustained fraction of peak achieved on large GEMMs.
    pub efficiency: f64,
    /// HBM/DRAM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Device memory capacity, bytes.
    pub mem_cap: f64,
    /// Fixed per-operator launch overhead, seconds.
    pub op_overhead: f64,
    /// Fixed per-iteration framework overhead, seconds.
    pub iter_overhead: f64,
    /// Intra-node interconnect bandwidth for TP collectives, bytes/s.
    pub net_bw: f64,
    /// Relative price (A100 = 1.0) for the cost-efficiency studies.
    pub price: f64,
}

impl HardwareSpec {
    /// NVIDIA A100-80G (SXM): 312 TF fp16, 2.039 TB/s, 80 GB.
    pub fn a100_80g() -> Self {
        Self {
            name: "A100".into(),
            peak_flops: 312e12,
            efficiency: 0.55,
            mem_bw: 2.039e12,
            mem_cap: 80e9,
            op_overhead: 4.5e-6,
            iter_overhead: 2.0e-3,
            net_bw: 300e9,
            price: 1.0,
        }
    }

    /// NVIDIA V100-32G: 125 TF fp16, 0.9 TB/s, 32 GB — the "cheaper GPU
    /// from a previous generation" of Fig 12 (~1/4 A100 price).
    pub fn v100_32g() -> Self {
        Self {
            name: "V100".into(),
            peak_flops: 125e12,
            efficiency: 0.50,
            mem_bw: 0.9e12,
            mem_cap: 32e9,
            op_overhead: 5.5e-6,
            iter_overhead: 2.0e-3,
            net_bw: 150e9,
            price: 0.25,
        }
    }

    /// SK Hynix GDDR6-AiM processing-in-memory device (~1/2 A100 price):
    /// the per-bank MAC arrays give high *aggregate* throughput on
    /// bandwidth-resident operands (GEMV/flat GEMM) with near-bank
    /// bandwidth above HBM, but a small per-device capacity — favourable
    /// for the memory-bound decode stage, KV-capacity-limited at scale
    /// (the paper's Finding 4).
    pub fn gddr6_aim() -> Self {
        Self {
            name: "G6-AiM".into(),
            peak_flops: 120e12,
            efficiency: 0.70,
            mem_bw: 2.6e12,
            mem_cap: 32e9,
            op_overhead: 6.0e-6,
            iter_overhead: 2.0e-3,
            net_bw: 64e9,
            price: 0.5,
        }
    }

    /// "AL" of Fig 12: an A100 with 1/4 peak FLOPS (same memory system).
    pub fn a100_quarter_flops() -> Self {
        let mut hw = Self::a100_80g();
        hw.name = "A100-1/4T".into();
        hw.peak_flops /= 4.0;
        hw
    }

    /// Look a preset up by name (config files / CLI).
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "A100" | "a100" | "a100-80g" => Some(Self::a100_80g()),
            "V100" | "v100" | "v100-32g" => Some(Self::v100_32g()),
            "G6-AiM" | "g6-aim" | "gddr6-aim" => Some(Self::gddr6_aim()),
            "A100-1/4T" | "a100-quarter" => Some(Self::a100_quarter_flops()),
            _ => None,
        }
    }

    /// Achievable FLOP/s fed to the cost model.
    #[inline]
    pub fn achievable_flops(&self) -> f64 {
        self.peak_flops * self.efficiency
    }

    /// Scale compute performance by `f` (the `T` knob of Fig 15).
    pub fn scale_compute(&self, f: f64) -> Self {
        let mut hw = self.clone();
        hw.name = format!("{}-T{f}", self.name);
        hw.peak_flops *= f;
        hw
    }

    /// Scale memory bandwidth by `f` (the `B` knob of Fig 15).
    pub fn scale_bandwidth(&self, f: f64) -> Self {
        let mut hw = self.clone();
        hw.name = format!("{}-B{f}", self.name);
        hw.mem_bw *= f;
        hw
    }

    /// Scale memory capacity by `f` (the `C` knob of Fig 15).
    pub fn scale_capacity(&self, f: f64) -> Self {
        let mut hw = self.clone();
        hw.name = format!("{}-C{f}", self.name);
        hw.mem_cap *= f;
        hw
    }

    /// The float32 parameter vector consumed by the HLO cost artifact.
    pub fn to_vec(&self) -> [f32; 6] {
        [
            self.achievable_flops() as f32,
            self.mem_bw as f32,
            self.op_overhead as f32,
            self.iter_overhead as f32,
            self.net_bw as f32,
            self.mem_cap as f32,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_roofline_ridge_point() {
        // ridge = achievable flops / bandwidth: A100 sits near 84 FLOP/B
        let hw = HardwareSpec::a100_80g();
        let ridge = hw.achievable_flops() / hw.mem_bw;
        assert!((60.0..120.0).contains(&ridge), "ridge={ridge}");
    }

    #[test]
    fn price_ordering_matches_paper() {
        let a = HardwareSpec::a100_80g();
        let v = HardwareSpec::v100_32g();
        let g = HardwareSpec::gddr6_aim();
        assert!((v.price - 0.25).abs() < 1e-9);
        assert!((g.price - 0.5).abs() < 1e-9);
        assert!(a.price > g.price && g.price > v.price);
    }

    #[test]
    fn aim_bandwidth_exceeds_a100() {
        assert!(HardwareSpec::gddr6_aim().mem_bw > HardwareSpec::a100_80g().mem_bw);
    }

    #[test]
    fn scaling_knobs() {
        let hw = HardwareSpec::a100_80g();
        assert_eq!(hw.scale_compute(0.25).peak_flops, 312e12 * 0.25);
        assert_eq!(hw.scale_bandwidth(4.0).mem_bw, 2.039e12 * 4.0);
        assert_eq!(hw.scale_capacity(0.5).mem_cap, 40e9);
        // scaling one knob leaves others untouched
        assert_eq!(hw.scale_compute(2.0).mem_bw, hw.mem_bw);
    }

    #[test]
    fn by_name_lookup() {
        for n in ["A100", "v100", "g6-aim", "a100-quarter"] {
            assert!(HardwareSpec::by_name(n).is_some(), "{n}");
        }
        assert!(HardwareSpec::by_name("h100").is_none());
    }

    #[test]
    fn quarter_flops_only_touches_compute() {
        let a = HardwareSpec::a100_80g();
        let q = HardwareSpec::a100_quarter_flops();
        assert_eq!(q.peak_flops, a.peak_flops / 4.0);
        assert_eq!(q.mem_bw, a.mem_bw);
        assert_eq!(q.mem_cap, a.mem_cap);
    }
}
