//! Workloads as a pluggable subsystem: arrival processes, length
//! distributions (including a ShareGPT-fit sampler), multi-round
//! conversations, trace import/export, and the [`WorkloadGenerator`]
//! trait + string-keyed [registry](crate::workload::registry) selecting
//! scenario generators by name from YAML or code.
//!
//! "TokenSim generates workloads from datasets and parameters, with
//! requests dispatched by a dispatcher to the global scheduler" (§III).
//! The real ShareGPT dataset is not redistributable here; `sharegpt()`
//! uses a lognormal fit to its published prompt/output length statistics
//! (see DESIGN.md §Substitutions).
//!
//! Built-in generators: `synthetic` (the classic parametric
//! [`WorkloadSpec`]), `trace` (JSONL replay), `bursty` (BurstGPT-style
//! on/off phases), `multi_tenant` (per-class rates/lengths/SLOs, tagged
//! through [`Request`](crate::request::Request) →
//! [`RequestRecord`](crate::metrics::RequestRecord)) and `long_context`
//! (heavy-prefill lognormal mix). `tokensim list` prints the live
//! registry; [`register_workload`] adds generators at runtime.

mod conversation;
mod distributions;
mod generator;
pub mod registry;
mod trace;

pub use conversation::{ConversationSpec, ConversationWorkload};
pub use distributions::{ArrivalProcess, LengthDistribution};
pub use generator::{
    BurstyWorkload, LongContextWorkload, MultiTenantWorkload, SyntheticWorkload, TenantClass,
    TraceWorkload, WorkloadGenerator,
};
pub use registry::{
    build_workload, register_workload, workload_generators, WorkloadEntry, WorkloadSpecV2,
    WORKLOAD_GENERATORS,
};
pub use trace::{load_trace, save_trace, TraceEntry};


use crate::request::Request;
use crate::sim::SimRng;

/// Declarative workload description (the paper's workload config).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    /// Number of requests to generate.
    pub num_requests: usize,
    /// Queries-per-second of the arrival process.
    pub qps: f64,
    pub arrival: ArrivalProcess,
    pub prompt_len: LengthDistribution,
    pub output_len: LengthDistribution,
    /// RNG seed (experiments fix this for reproducibility).
    pub seed: u64,
}

impl WorkloadSpec {
    /// ShareGPT-like workload at `qps` queries/second.
    ///
    /// Lognormal marginals fit to the ShareGPT statistics used by the
    /// vLLM/DistServe evaluations: prompts median ≈ 96 tokens with a
    /// heavy tail (mean ≈ 180), outputs median ≈ 128 (mean ≈ 210),
    /// both clamped to [4, 2048] (vLLM's preprocessing drops longer).
    pub fn sharegpt(num_requests: usize, qps: f64) -> Self {
        Self {
            num_requests,
            qps,
            arrival: ArrivalProcess::Poisson,
            prompt_len: LengthDistribution::LogNormal {
                median: 96.0,
                sigma: 1.1,
                min: 4,
                max: 2048,
            },
            output_len: LengthDistribution::LogNormal {
                median: 128.0,
                sigma: 1.0,
                min: 4,
                max: 2048,
            },
            seed: 0xD06F00D,
        }
    }

    /// Fixed prompt/output lengths (validation experiments).
    pub fn fixed(num_requests: usize, qps: f64, prompt: u32, output: u32) -> Self {
        Self {
            num_requests,
            qps,
            arrival: ArrivalProcess::Poisson,
            prompt_len: LengthDistribution::Fixed(prompt),
            output_len: LengthDistribution::Fixed(output),
            seed: 0xD06F00D,
        }
    }

    /// Uniform lengths around a mean (Fig 11 / Fig 14 style "average
    /// input and output lengths").
    pub fn mean_lengths(num_requests: usize, qps: f64, prompt_mean: u32, output_mean: u32) -> Self {
        Self {
            num_requests,
            qps,
            arrival: ArrivalProcess::Poisson,
            prompt_len: LengthDistribution::Uniform {
                min: (prompt_mean / 2).max(1),
                max: prompt_mean + prompt_mean / 2,
            },
            output_len: LengthDistribution::Uniform {
                min: (output_mean / 2).max(1),
                max: output_mean + output_mean / 2,
            },
            seed: 0xD06F00D,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_qps(mut self, qps: f64) -> Self {
        self.qps = qps;
        self
    }

    /// Materialize the request table (single-round workloads).
    pub fn generate(&self) -> Vec<Request> {
        let mut arrival_rng = SimRng::new(self.seed, "arrivals");
        let mut len_rng = SimRng::new(self.seed, "lengths");
        let mut t = 0.0;
        (0..self.num_requests)
            .map(|id| {
                t += self.arrival.next_gap(self.qps, &mut arrival_rng);
                let prompt = self.prompt_len.sample(&mut len_rng);
                let output = self.output_len.sample(&mut len_rng);
                Request::new(id, id, 0, prompt, output, t)
            })
            .collect()
    }
}

/// Aggregate offered load of a materialized request table — the
/// closed-form workload summary `tokensim analyze` derives its bounds
/// from. Works for *any* generator (synthetic, bursty, multi-tenant,
/// trace replay): rates are measured from the generated arrivals, not
/// re-derived per generator.
#[derive(Debug, Clone, PartialEq)]
pub struct OfferedLoad {
    /// Number of requests in the table.
    pub requests: usize,
    /// Empirical arrival rate `(n-1) / span`, `None` when fewer than
    /// two requests arrive or they all arrive at once (a burst has no
    /// meaningful sustained rate).
    pub qps: Option<f64>,
    /// Arrival span `max(arrival) - min(arrival)`, seconds.
    pub span: f64,
    /// Mean prompt length, tokens.
    pub mean_prompt: f64,
    /// Mean *uncached* prompt length (`prompt_len - cached_prefix`) —
    /// the tokens prefill actually computes.
    pub mean_prefill: f64,
    /// Mean output length, tokens.
    pub mean_output: f64,
    pub min_prompt: u32,
    pub max_prompt: u32,
    pub max_output: u32,
    /// Per-request output lengths, ascending — lets the analyzer form
    /// partial-sum backlog bounds (e.g. "the smallest 90% of the work
    /// alone exceeds the service capacity").
    pub sorted_outputs: Vec<u32>,
}

/// Summarize a request table into its [`OfferedLoad`]. Returns `None`
/// for an empty table (nothing to bound).
pub fn offered_load(requests: &[Request]) -> Option<OfferedLoad> {
    if requests.is_empty() {
        return None;
    }
    let n = requests.len();
    let mut first = f64::INFINITY;
    let mut last = f64::NEG_INFINITY;
    let mut prompt_sum = 0u64;
    let mut prefill_sum = 0u64;
    let mut output_sum = 0u64;
    let mut min_prompt = u32::MAX;
    let mut max_prompt = 0u32;
    let mut max_output = 0u32;
    let mut sorted_outputs = Vec::with_capacity(n);
    for r in requests {
        first = first.min(r.arrival);
        last = last.max(r.arrival);
        prompt_sum += r.prompt_len as u64;
        prefill_sum += r.prompt_len.saturating_sub(r.cached_prefix) as u64;
        output_sum += r.output_len as u64;
        min_prompt = min_prompt.min(r.prompt_len);
        max_prompt = max_prompt.max(r.prompt_len);
        max_output = max_output.max(r.output_len);
        sorted_outputs.push(r.output_len);
    }
    sorted_outputs.sort_unstable();
    let span = last - first;
    let qps = if n >= 2 && span > 0.0 {
        Some((n - 1) as f64 / span)
    } else {
        None
    };
    Some(OfferedLoad {
        requests: n,
        qps,
        span,
        mean_prompt: prompt_sum as f64 / n as f64,
        mean_prefill: prefill_sum as f64 / n as f64,
        mean_output: output_sum as f64 / n as f64,
        min_prompt,
        max_prompt,
        max_output,
        sorted_outputs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_is_deterministic() {
        let spec = WorkloadSpec::sharegpt(100, 5.0);
        let a = spec.generate();
        let b = spec.generate();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt_len, y.prompt_len);
            assert_eq!(x.arrival, y.arrival);
        }
    }

    #[test]
    fn seeds_change_the_draw() {
        let a = WorkloadSpec::sharegpt(50, 5.0).generate();
        let b = WorkloadSpec::sharegpt(50, 5.0).with_seed(1).generate();
        assert!(a.iter().zip(&b).any(|(x, y)| x.prompt_len != y.prompt_len));
    }

    #[test]
    fn arrival_rate_close_to_qps() {
        let spec = WorkloadSpec::sharegpt(5000, 20.0);
        let reqs = spec.generate();
        let span = reqs.last().unwrap().arrival - reqs[0].arrival;
        let rate = (reqs.len() - 1) as f64 / span;
        assert!((rate - 20.0).abs() / 20.0 < 0.05, "rate={rate}");
    }

    #[test]
    fn sharegpt_length_statistics() {
        let spec = WorkloadSpec::sharegpt(20000, 1.0);
        let reqs = spec.generate();
        let mut prompts: Vec<u32> = reqs.iter().map(|r| r.prompt_len).collect();
        prompts.sort_unstable();
        let median = prompts[prompts.len() / 2];
        assert!((60..150).contains(&median), "median={median}");
        let mean: f64 =
            prompts.iter().map(|&p| p as f64).sum::<f64>() / prompts.len() as f64;
        assert!(mean > median as f64, "heavy tail expected: mean={mean}");
        assert!(*prompts.last().unwrap() <= 2048);
        assert!(*prompts.first().unwrap() >= 4);
    }

    #[test]
    fn fixed_workload_lengths() {
        let reqs = WorkloadSpec::fixed(10, 1.0, 64, 64).generate();
        assert!(reqs.iter().all(|r| r.prompt_len == 64 && r.output_len == 64));
    }

    #[test]
    fn arrivals_strictly_increasing() {
        let reqs = WorkloadSpec::sharegpt(1000, 50.0).generate();
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
    }
}
