//! Multi-round conversation workloads (the Fig 14 memory-cache study).
//!
//! "The conversation lengths are generated with a mean length following
//! a Poisson distribution. To mimic a realistic chatbot scenario, half
//! of the requests are single-round, while the other half involves two
//! to seven rounds." Rounds after the first arrive a think-time after
//! the previous round finishes; each round's prompt is the full history
//! (previous prompt + previous output + new user text).


use super::{ArrivalProcess, LengthDistribution};
use crate::sim::SimRng;

/// Declarative multi-round workload description.
#[derive(Debug, Clone, PartialEq)]
pub struct ConversationSpec {
    /// Number of conversations to generate.
    pub num_conversations: usize,
    /// Conversation arrival rate (first rounds), per second.
    pub qps: f64,
    pub arrival: ArrivalProcess,
    /// Fresh user-text length per round.
    pub prompt_len: LengthDistribution,
    pub output_len: LengthDistribution,
    /// Fraction of single-round conversations (paper: 0.5).
    pub single_round_fraction: f64,
    /// Multi-round conversations draw rounds uniformly from this range
    /// (paper: 2..=7).
    pub rounds_min: u32,
    pub rounds_max: u32,
    /// Mean think time between a round finishing and the next arriving.
    pub think_time_mean: f64,
    pub seed: u64,
}

impl ConversationSpec {
    /// The Fig-14 chatbot scenario with mean input/output lengths.
    pub fn chatbot(num_conversations: usize, qps: f64, input_mean: u32, output_mean: u32) -> Self {
        Self {
            num_conversations,
            qps,
            arrival: ArrivalProcess::Poisson,
            prompt_len: LengthDistribution::Uniform {
                min: (input_mean / 2).max(1),
                max: input_mean + input_mean / 2,
            },
            output_len: LengthDistribution::Uniform {
                min: (output_mean / 2).max(1),
                max: output_mean + output_mean / 2,
            },
            single_round_fraction: 0.5,
            rounds_min: 2,
            rounds_max: 7,
            think_time_mean: 5.0,
            seed: 0xBEEF,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Materialize the conversation plans.
    pub fn generate(&self) -> Vec<ConversationWorkload> {
        let mut arrival_rng = SimRng::new(self.seed, "conv-arrivals");
        let mut len_rng = SimRng::new(self.seed, "conv-lengths");
        let mut t = 0.0;
        (0..self.num_conversations)
            .map(|id| {
                t += self.arrival.next_gap(self.qps, &mut arrival_rng);
                let rounds = if len_rng.gen_bool(self.single_round_fraction) {
                    1
                } else {
                    len_rng.uniform_int(self.rounds_min as u64, self.rounds_max as u64) as u32
                };
                let plans = (0..rounds)
                    .map(|_| RoundPlan {
                        user_tokens: self.prompt_len.sample(&mut len_rng),
                        output_tokens: self.output_len.sample(&mut len_rng),
                        think_time: if self.think_time_mean > 0.0 {
                            len_rng.exp_gap(1.0 / self.think_time_mean)
                        } else {
                            0.0
                        },
                    })
                    .collect();
                ConversationWorkload {
                    id,
                    first_arrival: t,
                    rounds: plans,
                }
            })
            .collect()
    }
}

/// One planned round of a conversation.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundPlan {
    /// New user text this round (excluding history).
    pub user_tokens: u32,
    pub output_tokens: u32,
    /// Gap between the previous round finishing and this round arriving.
    pub think_time: f64,
}

/// A materialized conversation: the driver replays rounds, computing
/// each round's full prompt length from the history.
#[derive(Debug, Clone, PartialEq)]
pub struct ConversationWorkload {
    pub id: usize,
    pub first_arrival: f64,
    pub rounds: Vec<RoundPlan>,
}

impl ConversationWorkload {
    /// Prompt length of `round` = all previous prompts + outputs + the
    /// new user text.
    pub fn prompt_len_of_round(&self, round: usize) -> u32 {
        let history: u32 = self.rounds[..round]
            .iter()
            .map(|r| r.user_tokens + r.output_tokens)
            .sum();
        history + self.rounds[round].user_tokens
    }

    /// Total requests across all conversations in a workload.
    pub fn total_rounds(convs: &[ConversationWorkload]) -> usize {
        convs.iter().map(|c| c.rounds.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ConversationSpec {
        ConversationSpec::chatbot(2000, 10.0, 128, 64)
    }

    #[test]
    fn half_single_round() {
        let convs = spec().generate();
        let single = convs.iter().filter(|c| c.rounds.len() == 1).count();
        let frac = single as f64 / convs.len() as f64;
        assert!((frac - 0.5).abs() < 0.05, "frac={frac}");
    }

    #[test]
    fn multi_round_counts_in_range() {
        let convs = spec().generate();
        for c in &convs {
            if c.rounds.len() > 1 {
                assert!((2..=7).contains(&c.rounds.len()));
            }
        }
    }

    #[test]
    fn prompt_grows_with_history() {
        let convs = spec().generate();
        let multi = convs.iter().find(|c| c.rounds.len() >= 3).unwrap();
        let p0 = multi.prompt_len_of_round(0);
        let p1 = multi.prompt_len_of_round(1);
        let p2 = multi.prompt_len_of_round(2);
        assert!(p1 > p0 && p2 > p1);
        // round 1 prompt includes round 0's user + output text
        assert_eq!(
            p1,
            multi.rounds[0].user_tokens
                + multi.rounds[0].output_tokens
                + multi.rounds[1].user_tokens
        );
    }

    #[test]
    fn deterministic() {
        let a = spec().generate();
        let b = spec().generate();
        assert_eq!(a, b);
    }

    #[test]
    fn think_times_positive_mean() {
        let convs = spec().generate();
        let gaps: Vec<f64> = convs
            .iter()
            .flat_map(|c| c.rounds.iter().map(|r| r.think_time))
            .collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        assert!((mean - 5.0).abs() < 0.5, "mean={mean}");
    }
}
