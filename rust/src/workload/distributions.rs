//! Arrival processes and token-length distributions.


use crate::sim::SimRng;

/// Request arrival process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Poisson process: exponential inter-arrival gaps.
    Poisson,
    /// Deterministic equal spacing (1/qps).
    Uniform,
    /// Gamma-distributed gaps with the given coefficient of variation
    /// (cv > 1 = burstier than Poisson; DistServe's workload knob).
    Gamma { cv: f64 },
    /// All requests arrive at t = 0 (offline / batch mode).
    Burst,
}

impl ArrivalProcess {
    /// Sample the next inter-arrival gap for rate `qps`.
    pub fn next_gap(&self, qps: f64, rng: &mut SimRng) -> f64 {
        assert!(qps > 0.0, "qps must be positive");
        match self {
            ArrivalProcess::Poisson => rng.exp_gap(qps),
            ArrivalProcess::Uniform => 1.0 / qps,
            ArrivalProcess::Gamma { cv } => {
                // Gamma with mean 1/qps, cv = sigma/mean: shape k = 1/cv^2.
                let k = 1.0 / (cv * cv);
                let theta = 1.0 / (qps * k);
                // sum-of-exponentials for integer k, Marsaglia-Tsang
                // otherwise is overkill here: use the simple
                // Wilson-Hilferty-ish approximation via normals.
                let mut x = 0.0;
                let ki = k.floor() as u64;
                for _ in 0..ki {
                    x += rng.exp_gap(1.0);
                }
                let frac = k - ki as f64;
                if frac > 1e-9 {
                    // Ahrens-Dieter for the fractional part.
                    loop {
                        let u = rng.uniform(0.0, 1.0);
                        let v = rng.uniform(0.0, 1.0);
                        let b = (std::f64::consts::E + frac) / std::f64::consts::E;
                        let p = b * u;
                        if p <= 1.0 {
                            let cand = p.powf(1.0 / frac);
                            if v <= (-cand).exp() {
                                x += cand;
                                break;
                            }
                        } else {
                            let cand = -((b - p) / frac).ln();
                            if v <= cand.powf(frac - 1.0) {
                                x += cand;
                                break;
                            }
                        }
                    }
                }
                x * theta
            }
            ArrivalProcess::Burst => 0.0,
        }
    }
}

/// Token-length distribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LengthDistribution {
    Fixed(u32),
    Uniform {
        min: u32,
        max: u32,
    },
    /// Lognormal with the given median (= exp(mu)) and log-sigma,
    /// clamped to [min, max] — the ShareGPT-fit shape.
    LogNormal {
        median: f64,
        sigma: f64,
        min: u32,
        max: u32,
    },
}

impl LengthDistribution {
    pub fn sample(&self, rng: &mut SimRng) -> u32 {
        match *self {
            LengthDistribution::Fixed(n) => n.max(1),
            LengthDistribution::Uniform { min, max } => {
                assert!(min <= max, "uniform min > max");
                // clamp BOTH bounds to >= 1 token: `min` alone would
                // invert the range for `{min: 0, max: 0}` and panic in
                // `uniform_int` (config parsing rejects min > max, so
                // lo <= hi always holds here)
                rng.uniform_int(min.max(1) as u64, max.max(1) as u64) as u32
            }
            LengthDistribution::LogNormal {
                median,
                sigma,
                min,
                max,
            } => {
                let v = rng.lognormal(median.ln(), sigma);
                (v.round() as u32).clamp(min.max(1), max)
            }
        }
    }

    /// Expected value (used for sizing heuristics; clamping ignored for
    /// the lognormal tail so treat as an approximation).
    pub fn mean(&self) -> f64 {
        match *self {
            LengthDistribution::Fixed(n) => n as f64,
            LengthDistribution::Uniform { min, max } => (min + max) as f64 / 2.0,
            LengthDistribution::LogNormal { median, sigma, .. } => {
                median * (sigma * sigma / 2.0).exp()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_gap_mean() {
        let mut rng = SimRng::new(1, "t");
        let n = 50_000;
        let mean: f64 = (0..n)
            .map(|_| ArrivalProcess::Poisson.next_gap(8.0, &mut rng))
            .sum::<f64>()
            / n as f64;
        assert!((mean - 0.125).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn uniform_process_is_deterministic() {
        let mut rng = SimRng::new(1, "t");
        let g = ArrivalProcess::Uniform.next_gap(4.0, &mut rng);
        assert_eq!(g, 0.25);
    }

    #[test]
    fn gamma_mean_and_burstiness() {
        let mut rng = SimRng::new(1, "t");
        let p = ArrivalProcess::Gamma { cv: 2.0 };
        let n = 50_000;
        let gaps: Vec<f64> = (0..n).map(|_| p.next_gap(10.0, &mut rng)).collect();
        let mean = gaps.iter().sum::<f64>() / n as f64;
        assert!((mean - 0.1).abs() < 0.01, "mean={mean}");
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / n as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 2.0).abs() < 0.2, "cv={cv}");
    }

    #[test]
    fn burst_arrives_at_zero() {
        let mut rng = SimRng::new(1, "t");
        assert_eq!(ArrivalProcess::Burst.next_gap(3.0, &mut rng), 0.0);
    }

    #[test]
    fn lognormal_respects_clamp() {
        let d = LengthDistribution::LogNormal {
            median: 100.0,
            sigma: 2.0,
            min: 8,
            max: 512,
        };
        let mut rng = SimRng::new(2, "len");
        for _ in 0..10_000 {
            let v = d.sample(&mut rng);
            assert!((8..=512).contains(&v));
        }
    }

    #[test]
    fn lognormal_median_close() {
        let d = LengthDistribution::LogNormal {
            median: 100.0,
            sigma: 1.0,
            min: 1,
            max: 100_000,
        };
        let mut rng = SimRng::new(3, "len");
        let mut v: Vec<u32> = (0..20_000).map(|_| d.sample(&mut rng)).collect();
        v.sort_unstable();
        let med = v[v.len() / 2];
        assert!((85..115).contains(&med), "median={med}");
    }

    #[test]
    fn fixed_never_zero() {
        let mut rng = SimRng::new(4, "len");
        assert_eq!(LengthDistribution::Fixed(0).sample(&mut rng), 1);
    }

    #[test]
    fn uniform_zero_bounds_clamp_to_one_token() {
        // regression: `{min: 0, max: 0}` used to clamp only `min`,
        // calling uniform_int(1, 0) with an inverted range (panic)
        let mut rng = SimRng::new(4, "len");
        let d = LengthDistribution::Uniform { min: 0, max: 0 };
        for _ in 0..100 {
            assert_eq!(d.sample(&mut rng), 1);
        }
        let d = LengthDistribution::Uniform { min: 0, max: 3 };
        for _ in 0..100 {
            assert!((1..=3).contains(&d.sample(&mut rng)));
        }
    }

    #[test]
    fn means() {
        assert_eq!(LengthDistribution::Fixed(10).mean(), 10.0);
        assert_eq!(LengthDistribution::Uniform { min: 0, max: 10 }.mean(), 5.0);
        let ln = LengthDistribution::LogNormal {
            median: 100.0,
            sigma: 1.0,
            min: 1,
            max: 1 << 20,
        };
        assert!((ln.mean() - 100.0 * (0.5f64).exp()).abs() < 1e-9);
    }
}
