//! The [`WorkloadGenerator`] trait and the built-in generator
//! implementations — the workload counterpart of the scheduler and
//! memory plugin subsystems.
//!
//! A generator owns its parameters (rates, length distributions, trace
//! paths, tenant classes) and materializes a request table on demand.
//! The simulation driver only ever sees `Box<dyn WorkloadGenerator>`
//! through [`WorkloadSpecV2`](crate::workload::WorkloadSpecV2), so a new
//! serving scenario never touches `cluster/mod.rs`: implement the
//! trait, then either add a
//! [`WorkloadEntry`](crate::workload::registry::WorkloadEntry) to the
//! built-in table or call
//! [`register_workload`](crate::workload::register_workload) at startup.

use anyhow::{Context, Result};

use crate::metrics::SloSpec;
use crate::request::Request;
use crate::sim::SimRng;

use super::{load_trace, ArrivalProcess, LengthDistribution, WorkloadSpec};

/// A pluggable workload scenario (the paper's §IV "workloads generated
/// from datasets and parameters", generalized to a registry).
///
/// The contract of [`generate`](WorkloadGenerator::generate):
///
/// * requests are returned sorted by arrival time, with `id` equal to
///   their index in the returned table (the driver schedules
///   `Arrival(id)` events directly from it);
/// * generation is a pure function of the generator's parameters —
///   every stochastic draw comes from a [`SimRng`] stream seeded from
///   the generator's own seed, so repeated calls are bit-identical
///   (what the parallel sweep runner relies on);
/// * multi-tenant generators tag each request's `tenant` field and
///   expose per-class objectives via
///   [`tenant_slos`](WorkloadGenerator::tenant_slos) so reports can
///   break out per-tenant TTFT/TBT percentiles.
pub trait WorkloadGenerator: Send {
    /// Registry name of this generator (stable, lowercase).
    fn name(&self) -> &'static str;

    /// Materialize the request table (sorted by arrival, ids = indices).
    fn generate(&self) -> Result<Vec<Request>>;

    /// Per-tenant service-level objectives, for generators that model
    /// tenant classes (empty for single-tenant workloads).
    fn tenant_slos(&self) -> Vec<(String, SloSpec)> {
        Vec::new()
    }
}

// ---------------------------------------------------------------------------
// Built-in generators
// ---------------------------------------------------------------------------

/// `synthetic`: the classic parametric workload — an arrival process
/// crossed with prompt/output length distributions (wraps
/// [`WorkloadSpec`]).
#[derive(Debug, Clone, PartialEq)]
pub struct SyntheticWorkload(pub WorkloadSpec);

impl WorkloadGenerator for SyntheticWorkload {
    fn name(&self) -> &'static str {
        "synthetic"
    }

    fn generate(&self) -> Result<Vec<Request>> {
        Ok(self.0.generate())
    }
}

/// `trace`: JSONL trace replay through the [`load_trace`] loader, so
/// real dataset traces (or archived synthetic ones saved with
/// `tokensim run --save-trace`) drive the simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceWorkload {
    /// Path of the JSONL trace (one `{"arrival", "prompt", "output"}`
    /// object per line; resolved against the process working
    /// directory).
    pub path: String,
    /// Multiply every arrival time (2.0 = half the offered load).
    pub time_scale: f64,
    /// Keep only the first N requests by arrival (None = all).
    pub max_requests: Option<usize>,
}

impl WorkloadGenerator for TraceWorkload {
    fn name(&self) -> &'static str {
        "trace"
    }

    fn generate(&self) -> Result<Vec<Request>> {
        let mut requests =
            load_trace(&self.path).with_context(|| format!("replaying trace '{}'", self.path))?;
        if let Some(cap) = self.max_requests {
            anyhow::ensure!(cap > 0, "'max_requests' must be >= 1");
            requests.truncate(cap);
        }
        if self.time_scale != 1.0 {
            for r in &mut requests {
                r.arrival *= self.time_scale;
            }
        }
        Ok(requests)
    }
}

/// `bursty`: BurstGPT-style on/off load — alternating high-rate and
/// low-rate phases, with Gamma-distributed gaps inside each phase
/// (`cv` > 1 adds within-phase burstiness on top of the phase
/// envelope).
#[derive(Debug, Clone, PartialEq)]
pub struct BurstyWorkload {
    pub num_requests: usize,
    /// Arrival rate during ON phases (req/s).
    pub qps_on: f64,
    /// Arrival rate during OFF phases (req/s).
    pub qps_off: f64,
    /// ON-phase duration (s).
    pub on_s: f64,
    /// OFF-phase duration (s).
    pub off_s: f64,
    /// Coefficient of variation of the within-phase Gamma gaps
    /// (1.0 = Poisson).
    pub cv: f64,
    pub prompt_len: LengthDistribution,
    pub output_len: LengthDistribution,
    pub seed: u64,
}

impl WorkloadGenerator for BurstyWorkload {
    fn name(&self) -> &'static str {
        "bursty"
    }

    fn generate(&self) -> Result<Vec<Request>> {
        let mut arrival_rng = SimRng::new(self.seed, "bursty-arrivals");
        let mut len_rng = SimRng::new(self.seed, "bursty-lengths");
        let process = ArrivalProcess::Gamma { cv: self.cv };
        let mut t = 0.0f64;
        let mut on = true;
        let mut phase_end = self.on_s;
        let mut requests = Vec::with_capacity(self.num_requests);
        for id in 0..self.num_requests {
            loop {
                let rate = if on { self.qps_on } else { self.qps_off };
                let gap = process.next_gap(rate, &mut arrival_rng);
                if t + gap <= phase_end {
                    t += gap;
                    break;
                }
                // the sampled gap crosses the phase boundary: jump to
                // the boundary and resample at the next phase's rate
                // (memoryless across the switch)
                t = phase_end;
                on = !on;
                phase_end += if on { self.on_s } else { self.off_s };
            }
            let prompt = self.prompt_len.sample(&mut len_rng);
            let output = self.output_len.sample(&mut len_rng);
            requests.push(Request::new(id, id, 0, prompt, output, t));
        }
        Ok(requests)
    }
}

/// One tenant class of a [`MultiTenantWorkload`].
#[derive(Debug, Clone, PartialEq)]
pub struct TenantClass {
    pub name: String,
    pub num_requests: usize,
    pub qps: f64,
    pub arrival: ArrivalProcess,
    pub prompt_len: LengthDistribution,
    pub output_len: LengthDistribution,
    /// This class's service-level objectives (reported per tenant).
    pub slo: SloSpec,
}

/// `multi_tenant`: N tenant classes, each with its own rate, length
/// distributions and SLOs. Streams are merged by arrival time and every
/// request is tagged with its tenant so reports can break out
/// per-tenant TTFT/TBT percentiles and SLO attainment.
#[derive(Debug, Clone, PartialEq)]
pub struct MultiTenantWorkload {
    pub tenants: Vec<TenantClass>,
    pub seed: u64,
}

impl WorkloadGenerator for MultiTenantWorkload {
    fn name(&self) -> &'static str {
        "multi_tenant"
    }

    fn generate(&self) -> Result<Vec<Request>> {
        let mut all: Vec<Request> = Vec::new();
        for tc in &self.tenants {
            // one independent stream pair per tenant, labelled by name,
            // so adding a tenant never perturbs the others' draws
            let mut arrival_rng = SimRng::new(self.seed, &format!("tenant-{}-arrivals", tc.name));
            let mut len_rng = SimRng::new(self.seed, &format!("tenant-{}-lengths", tc.name));
            let mut t = 0.0;
            for _ in 0..tc.num_requests {
                t += tc.arrival.next_gap(tc.qps, &mut arrival_rng);
                let prompt = tc.prompt_len.sample(&mut len_rng);
                let output = tc.output_len.sample(&mut len_rng);
                let mut r = Request::new(0, 0, 0, prompt, output, t);
                r.tenant = Some(tc.name.clone());
                all.push(r);
            }
        }
        // stable by arrival; ties keep tenant declaration order
        all.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        for (id, r) in all.iter_mut().enumerate() {
            r.id = id;
            r.conversation = id;
        }
        Ok(all)
    }

    fn tenant_slos(&self) -> Vec<(String, SloSpec)> {
        self.tenants
            .iter()
            .map(|t| (t.name.clone(), t.slo))
            .collect()
    }
}

/// `long_context`: a heavy-prefill mix — most prompts follow the
/// ShareGPT-like lognormal, but a `long_fraction` tail draws from a
/// long-context lognormal (RAG / document-QA style), stressing prefill
/// scheduling and KV capacity.
#[derive(Debug, Clone, PartialEq)]
pub struct LongContextWorkload {
    pub num_requests: usize,
    pub qps: f64,
    /// Fraction of requests drawn from the long-context distribution.
    pub long_fraction: f64,
    pub short_prompt: LengthDistribution,
    pub long_prompt: LengthDistribution,
    pub output_len: LengthDistribution,
    pub seed: u64,
}

impl WorkloadGenerator for LongContextWorkload {
    fn name(&self) -> &'static str {
        "long_context"
    }

    fn generate(&self) -> Result<Vec<Request>> {
        let mut arrival_rng = SimRng::new(self.seed, "longctx-arrivals");
        let mut len_rng = SimRng::new(self.seed, "longctx-lengths");
        let mut t = 0.0;
        let requests = (0..self.num_requests)
            .map(|id| {
                t += ArrivalProcess::Poisson.next_gap(self.qps, &mut arrival_rng);
                let prompt = if len_rng.gen_bool(self.long_fraction) {
                    self.long_prompt.sample(&mut len_rng)
                } else {
                    self.short_prompt.sample(&mut len_rng)
                };
                let output = self.output_len.sample(&mut len_rng);
                Request::new(id, id, 0, prompt, output, t)
            })
            .collect();
        Ok(requests)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;
    use crate::workload::save_trace;

    #[test]
    fn synthetic_matches_workload_spec() {
        let spec = WorkloadSpec::sharegpt(100, 5.0);
        let direct = spec.generate();
        let via = SyntheticWorkload(spec).generate().unwrap();
        assert_eq!(direct.len(), via.len());
        for (a, b) in direct.iter().zip(&via) {
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.output_len, b.output_len);
            assert_eq!(a.arrival, b.arrival);
        }
    }

    #[test]
    fn trace_generator_replays_scales_and_caps() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("t.jsonl");
        let reqs = WorkloadSpec::fixed(20, 10.0, 64, 8).generate();
        save_trace(&path, &reqs).unwrap();
        let full = TraceWorkload {
            path: path.to_str().unwrap().to_string(),
            time_scale: 1.0,
            max_requests: None,
        }
        .generate()
        .unwrap();
        assert_eq!(full.len(), 20);
        let scaled = TraceWorkload {
            path: path.to_str().unwrap().to_string(),
            time_scale: 2.0,
            max_requests: Some(5),
        }
        .generate()
        .unwrap();
        assert_eq!(scaled.len(), 5);
        for (a, b) in full.iter().zip(&scaled) {
            assert!((b.arrival - 2.0 * a.arrival).abs() < 1e-9);
        }
    }

    fn bursty(cv: f64) -> BurstyWorkload {
        BurstyWorkload {
            num_requests: 4000,
            qps_on: 40.0,
            qps_off: 2.0,
            on_s: 10.0,
            off_s: 10.0,
            cv,
            prompt_len: LengthDistribution::Fixed(64),
            output_len: LengthDistribution::Fixed(8),
            seed: 7,
        }
    }

    #[test]
    fn bursty_phases_modulate_the_rate() {
        let reqs = bursty(1.0).generate().unwrap();
        assert_eq!(reqs.len(), 4000);
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        // count arrivals in ON windows [0,10), [20,30), … vs OFF windows
        let (mut on, mut off) = (0usize, 0usize);
        for r in &reqs {
            let phase = (r.arrival / 10.0).floor() as u64;
            if phase % 2 == 0 {
                on += 1;
            } else {
                off += 1;
            }
        }
        assert!(
            on as f64 > 5.0 * off as f64,
            "ON phases must dominate: on={on} off={off}"
        );
    }

    #[test]
    fn bursty_is_deterministic() {
        let a = bursty(2.0).generate().unwrap();
        let b = bursty(2.0).generate().unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.prompt_len, y.prompt_len);
        }
    }

    fn two_tenants() -> MultiTenantWorkload {
        MultiTenantWorkload {
            tenants: vec![
                TenantClass {
                    name: "chat".into(),
                    num_requests: 300,
                    qps: 10.0,
                    arrival: ArrivalProcess::Poisson,
                    prompt_len: LengthDistribution::Fixed(64),
                    output_len: LengthDistribution::Fixed(32),
                    slo: SloSpec {
                        ttft: Some(2.0),
                        mtpot: Some(0.2),
                    },
                },
                TenantClass {
                    name: "batch".into(),
                    num_requests: 100,
                    qps: 3.0,
                    arrival: ArrivalProcess::Poisson,
                    prompt_len: LengthDistribution::Fixed(512),
                    output_len: LengthDistribution::Fixed(128),
                    slo: SloSpec::none(),
                },
            ],
            seed: 11,
        }
    }

    #[test]
    fn multi_tenant_tags_merges_and_reports_slos() {
        let workload = two_tenants();
        let reqs = workload.generate().unwrap();
        assert_eq!(reqs.len(), 400);
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival, "merged stream sorted");
        }
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i, "ids are table indices");
            assert!(r.tenant.is_some());
        }
        let chat = reqs
            .iter()
            .filter(|r| r.tenant.as_deref() == Some("chat"))
            .count();
        assert_eq!(chat, 300);
        let slos = workload.tenant_slos();
        assert_eq!(slos.len(), 2);
        assert_eq!(slos[0].0, "chat");
        assert_eq!(slos[0].1.ttft, Some(2.0));
    }

    #[test]
    fn long_context_mix_has_a_heavy_tail() {
        let workload = LongContextWorkload {
            num_requests: 4000,
            qps: 10.0,
            long_fraction: 0.25,
            short_prompt: LengthDistribution::LogNormal {
                median: 96.0,
                sigma: 1.1,
                min: 4,
                max: 2048,
            },
            long_prompt: LengthDistribution::LogNormal {
                median: 4096.0,
                sigma: 0.3,
                min: 2048,
                max: 16384,
            },
            output_len: LengthDistribution::Fixed(32),
            seed: 3,
        };
        let reqs = workload.generate().unwrap();
        let long = reqs.iter().filter(|r| r.prompt_len >= 2048).count();
        let frac = long as f64 / reqs.len() as f64;
        assert!((frac - 0.25).abs() < 0.03, "long fraction {frac}");
        let mut prompts: Vec<u32> = reqs.iter().map(|r| r.prompt_len).collect();
        prompts.sort_unstable();
        assert!(prompts[prompts.len() / 2] < 1024, "median stays short");
        assert!(*prompts.last().unwrap() > 3000, "tail is long");
    }
}
