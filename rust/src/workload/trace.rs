//! Request-trace import/export (JSONL), so real dataset traces can be
//! replayed when available and synthetic workloads can be archived.

use std::io::{BufRead, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::request::Request;
use crate::util::json::Json;

/// One trace line: `{"arrival": 1.25, "prompt": 96, "output": 128}`.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEntry {
    pub arrival: f64,
    pub prompt: u32,
    pub output: u32,
    pub conversation: Option<usize>,
    pub round: Option<usize>,
}

impl TraceEntry {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            arrival: j.req("arrival")?.as_f64().context("'arrival' must be a number")?,
            prompt: j.req("prompt")?.as_u64().context("'prompt' must be an integer")? as u32,
            output: j.req("output")?.as_u64().context("'output' must be an integer")? as u32,
            conversation: j.get("conversation").and_then(Json::as_u64).map(|v| v as usize),
            round: j.get("round").and_then(Json::as_u64).map(|v| v as usize),
        })
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("arrival", Json::num(self.arrival)),
            ("prompt", Json::num(self.prompt as f64)),
            ("output", Json::num(self.output as f64)),
        ];
        if let Some(c) = self.conversation {
            pairs.push(("conversation", Json::num(c as f64)));
        }
        if let Some(r) = self.round {
            pairs.push(("round", Json::num(r as f64)));
        }
        Json::obj(pairs)
    }
}

/// Load a JSONL trace into a request table. Entries are sorted by
/// arrival **before** ids are assigned, so ids always equal table
/// positions — the invariant the simulation driver indexes by (an
/// out-of-order trace must not dispatch request A at request B's
/// arrival time).
pub fn load_trace(path: impl AsRef<Path>) -> Result<Vec<Request>> {
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening trace {}", path.as_ref().display()))?;
    let reader = std::io::BufReader::new(file);
    let mut entries = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let entry = TraceEntry::from_json(&Json::parse(&line)?)
            .with_context(|| format!("trace line {}", lineno + 1))?;
        entries.push(entry);
    }
    anyhow::ensure!(!entries.is_empty(), "trace is empty");
    entries.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    Ok(entries
        .iter()
        .enumerate()
        .map(|(id, e)| {
            Request::new(
                id,
                e.conversation.unwrap_or(id),
                e.round.unwrap_or(0),
                e.prompt.max(1),
                e.output.max(1),
                e.arrival,
            )
        })
        .collect())
}

/// Save a request table as a JSONL trace.
pub fn save_trace(path: impl AsRef<Path>, requests: &[Request]) -> Result<()> {
    let mut file = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating trace {}", path.as_ref().display()))?;
    for r in requests {
        let entry = TraceEntry {
            arrival: r.arrival,
            prompt: r.prompt_len,
            output: r.output_len,
            conversation: Some(r.conversation),
            round: Some(r.round),
        };
        writeln!(file, "{}", entry.to_json().to_string())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;
    use crate::workload::WorkloadSpec;

    #[test]
    fn roundtrip() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("trace.jsonl");
        let reqs = WorkloadSpec::sharegpt(50, 4.0).generate();
        save_trace(&path, &reqs).unwrap();
        let back = load_trace(&path).unwrap();
        assert_eq!(back.len(), 50);
        for (a, b) in reqs.iter().zip(&back) {
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.output_len, b.output_len);
            assert!((a.arrival - b.arrival).abs() < 1e-9);
        }
    }

    #[test]
    fn sorts_by_arrival_and_reindexes_ids() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("trace.jsonl");
        std::fs::write(
            &path,
            "{\"arrival\": 5.0, \"prompt\": 10, \"output\": 10}\n\
             {\"arrival\": 1.0, \"prompt\": 20, \"output\": 20}\n",
        )
        .unwrap();
        let reqs = load_trace(&path).unwrap();
        assert_eq!(reqs[0].prompt_len, 20);
        assert_eq!(reqs[1].prompt_len, 10);
        // regression: ids must equal table positions even when the
        // trace file is not arrival-sorted — the driver indexes its
        // request table by id, so a stale pre-sort id dispatched one
        // request at another's arrival time
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i, "ids must be reassigned after sorting");
        }
        // distinct defaulted conversation keys follow the new ids
        assert_ne!(reqs[0].conversation, reqs[1].conversation);
    }

    #[test]
    fn explicit_conversation_keys_survive_reordering() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("conv.jsonl");
        std::fs::write(
            &path,
            "{\"arrival\": 5.0, \"prompt\": 10, \"output\": 10, \"conversation\": 3, \"round\": 1}\n\
             {\"arrival\": 1.0, \"prompt\": 20, \"output\": 20, \"conversation\": 3, \"round\": 0}\n",
        )
        .unwrap();
        let reqs = load_trace(&path).unwrap();
        assert_eq!(reqs[0].id, 0);
        assert_eq!(reqs[1].id, 1);
        assert_eq!(reqs[0].conversation, 3, "explicit grouping preserved");
        assert_eq!(reqs[1].conversation, 3);
        assert_eq!((reqs[0].round, reqs[1].round), (0, 1));
    }

    #[test]
    fn rejects_empty() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("empty.jsonl");
        std::fs::write(&path, "\n").unwrap();
        assert!(load_trace(&path).is_err());
    }

    #[test]
    fn rejects_garbage() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("bad.jsonl");
        std::fs::write(&path, "not json\n").unwrap();
        assert!(load_trace(&path).is_err());
    }
}
