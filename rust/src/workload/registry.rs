//! String-keyed workload-generator registry — the workload counterpart
//! of [`crate::scheduler::registry`] and [`crate::memory::registry`].
//!
//! A generator is selected by name — from YAML
//! (`workload: {generator: bursty, …}`) or programmatically via
//! [`WorkloadSpecV2`] — and built from its parameter map by a
//! registered constructor. The simulation driver only ever sees
//! `Box<dyn WorkloadGenerator>`, so opening a new serving scenario
//! never touches `cluster/mod.rs`: implement the trait, then either add
//! a [`WorkloadEntry`] to the built-in table or call
//! [`register_workload`] at startup.

use std::sync::{Mutex, OnceLock};

use anyhow::{bail, ensure, Context, Result};

use crate::config::yaml::Yaml;
use crate::metrics::SloSpec;

use super::generator::{
    BurstyWorkload, LongContextWorkload, MultiTenantWorkload, SyntheticWorkload, TenantClass,
    TraceWorkload, WorkloadGenerator,
};
use super::{ArrivalProcess, LengthDistribution, WorkloadSpec};

/// A declarative, cloneable workload selection: a registry name plus a
/// parameter map (the YAML subtree, or a programmatically built map).
/// This is what configs store — the built `Box<dyn WorkloadGenerator>`
/// is neither cloneable nor comparable.
///
/// The name carries the `V2` suffix because the original
/// [`WorkloadSpec`] — now the parameter struct of the `synthetic`
/// generator — remains a first-class public type; `From<WorkloadSpec>`
/// converts it losslessly, so existing call sites keep working.
///
/// # Examples
///
/// ```
/// use tokensim::workload::WorkloadSpecV2;
///
/// let spec = WorkloadSpecV2::new("bursty")
///     .with("num_requests", 50u32)
///     .with("qps", 20.0)
///     .with("off_qps", 2.0);
/// let requests = spec.generate().unwrap();
/// assert_eq!(requests.len(), 50);
///
/// // unknown names are errors listing the known generators
/// assert!(WorkloadSpecV2::new("fancy").build().is_err());
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpecV2 {
    /// Registry name (case-insensitive; aliases accepted).
    pub name: String,
    /// Generator parameters (a [`Yaml::Map`]).
    pub params: Yaml,
}

impl WorkloadSpecV2 {
    /// A spec with no parameters (registry defaults apply).
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            params: Yaml::Map(Default::default()),
        }
    }

    /// Builder-style parameter.
    pub fn with(mut self, key: &str, value: impl Into<Yaml>) -> Self {
        if let Yaml::Map(m) = &mut self.params {
            m.insert(key.to_string(), value.into());
        }
        self
    }

    /// Parse from a YAML map of the form `{generator: <name>, <params>…}`.
    /// A missing `generator` key selects `synthetic` (the pre-registry
    /// `workload:` sections keep working unchanged).
    pub fn from_yaml(y: &Yaml) -> Result<Self> {
        let name = match y.get("generator") {
            None => "synthetic".to_string(),
            Some(v) => v
                .as_str()
                .context("'generator' must be a string (a workload-generator name)")?
                .to_string(),
        };
        Ok(Self {
            name,
            params: y.clone(),
        })
    }

    /// Build the generator this spec names.
    pub fn build(&self) -> Result<Box<dyn WorkloadGenerator>> {
        build_workload(self)
    }

    /// Check the spec without generating: unknown names, typo'd
    /// parameter keys and malformed values are errors at parse time,
    /// not mid-simulation. (Trace files are read at generation time,
    /// not here.)
    pub fn validate(&self) -> Result<()> {
        self.build().map(|_| ())
    }

    /// Build and materialize the request table in one step.
    pub fn generate(&self) -> Result<Vec<crate::request::Request>> {
        self.build()?.generate()
    }

    /// The RNG seed this spec configures (also seeds the driver's own
    /// stream, like the pre-registry `workload.seed` field).
    pub fn seed(&self) -> u64 {
        self.params
            .get("seed")
            .and_then(Yaml::as_u64)
            .unwrap_or(0)
    }
}

fn ymap(pairs: Vec<(&str, Yaml)>) -> Yaml {
    Yaml::Map(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn dist_to_yaml(d: &LengthDistribution) -> Yaml {
    match *d {
        LengthDistribution::Fixed(n) => ymap(vec![("fixed", Yaml::from(n))]),
        LengthDistribution::Uniform { min, max } => ymap(vec![(
            "uniform",
            ymap(vec![("min", Yaml::from(min)), ("max", Yaml::from(max))]),
        )]),
        LengthDistribution::LogNormal {
            median,
            sigma,
            min,
            max,
        } => ymap(vec![(
            "log_normal",
            ymap(vec![
                ("median", Yaml::from(median)),
                ("sigma", Yaml::from(sigma)),
                ("min", Yaml::from(min)),
                ("max", Yaml::from(max)),
            ]),
        )]),
    }
}

fn arrival_to_yaml(a: &ArrivalProcess) -> Yaml {
    match *a {
        ArrivalProcess::Poisson => Yaml::from("poisson"),
        ArrivalProcess::Uniform => Yaml::from("uniform"),
        ArrivalProcess::Burst => Yaml::from("burst"),
        ArrivalProcess::Gamma { cv } => {
            ymap(vec![("gamma", ymap(vec![("cv", Yaml::from(cv))]))])
        }
    }
}

impl From<WorkloadSpec> for WorkloadSpecV2 {
    /// Lossless conversion to the `synthetic` generator (numbers pass
    /// through the parameter map as `f64`, exact up to 2^53 — every
    /// distribution parameter and the seed round-trip bit-identically).
    fn from(w: WorkloadSpec) -> Self {
        WorkloadSpecV2::new("synthetic")
            .with("num_requests", w.num_requests as u64)
            .with("qps", w.qps)
            .with("arrival", arrival_to_yaml(&w.arrival))
            .with("prompt_len", dist_to_yaml(&w.prompt_len))
            .with("output_len", dist_to_yaml(&w.output_len))
            .with("seed", w.seed)
    }
}

/// Parse a length distribution from its YAML form (`fixed` / `uniform`
/// / `log_normal`). Malformed bounds — `uniform` with `min > max`, a
/// non-positive `log_normal` median — are parse-time errors rather than
/// sampling-time panics.
pub(crate) fn length_dist_from_yaml(y: &Yaml) -> Result<LengthDistribution> {
    if let Some(v) = y.get("fixed") {
        return Ok(LengthDistribution::Fixed(
            v.as_u32().context("'fixed' must be an integer")?,
        ));
    }
    if let Some(u) = y.get("uniform") {
        let min = u.req_u32("min")?;
        let max = u.req_u32("max")?;
        ensure!(min <= max, "uniform length: min ({min}) > max ({max})");
        return Ok(LengthDistribution::Uniform { min, max });
    }
    if let Some(l) = y.get("log_normal") {
        let median = l.req_f64("median")?;
        let sigma = l.req_f64("sigma")?;
        let min = l.opt_u32("min", 1);
        let max = l.opt_u32("max", 1 << 20);
        ensure!(median > 0.0, "log_normal median must be > 0");
        ensure!(sigma >= 0.0, "log_normal sigma must be >= 0");
        ensure!(min <= max, "log_normal clamp: min ({min}) > max ({max})");
        return Ok(LengthDistribution::LogNormal {
            median,
            sigma,
            min,
            max,
        });
    }
    bail!("length distribution needs 'fixed', 'uniform' or 'log_normal'")
}

/// Parse an arrival process (`poisson` / `uniform` / `burst` / a
/// `gamma: {cv}` map).
pub(crate) fn arrival_from_yaml(y: &Yaml) -> Result<ArrivalProcess> {
    match y {
        Yaml::Str(s) => match s.as_str() {
            "poisson" => Ok(ArrivalProcess::Poisson),
            "uniform" => Ok(ArrivalProcess::Uniform),
            "burst" => Ok(ArrivalProcess::Burst),
            other => bail!("unknown arrival process '{other}'"),
        },
        Yaml::Map(_) => {
            if let Some(g) = y.get("gamma") {
                let cv = g.req_f64("cv")?;
                ensure!(cv > 0.0, "gamma cv must be > 0");
                Ok(ArrivalProcess::Gamma { cv })
            } else {
                bail!("arrival map must contain 'gamma'")
            }
        }
        other => bail!("bad arrival process {other:?}"),
    }
}

/// A built-in workload generator: name, aliases, summary, parameter
/// keys, constructor.
pub struct WorkloadEntry {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    /// One-line description (shown by `tokensim list`).
    pub summary: &'static str,
    /// Accepted parameter keys — anything else in the spec is an error
    /// (catches typo'd keys at parse time).
    pub params: &'static [&'static str],
    pub build: fn(&Yaml) -> Result<Box<dyn WorkloadGenerator>>,
}

// Strict optional accessors: a *missing* key takes the default, but a
// present-and-malformed value is an error rather than a silent default.

fn opt_usize_strict(p: &Yaml, key: &str, default: usize) -> Result<usize> {
    match p.get(key) {
        None => Ok(default),
        Some(v) => Ok(v
            .as_u64()
            .with_context(|| format!("'{key}' must be a non-negative integer"))?
            as usize),
    }
}

fn opt_u64_strict(p: &Yaml, key: &str, default: u64) -> Result<u64> {
    match p.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_u64()
            .with_context(|| format!("'{key}' must be a non-negative integer")),
    }
}

fn opt_f64_strict(p: &Yaml, key: &str, default: f64) -> Result<f64> {
    match p.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .with_context(|| format!("'{key}' must be a number")),
    }
}

fn opt_dist_strict(p: &Yaml, key: &str, default: LengthDistribution) -> Result<LengthDistribution> {
    match p.get(key) {
        None => Ok(default),
        Some(d) => length_dist_from_yaml(d).with_context(|| format!("in '{key}'")),
    }
}

fn req_qps(p: &Yaml, key: &str) -> Result<f64> {
    let qps = p.req_f64(key)?;
    ensure!(qps > 0.0, "'{key}' must be > 0");
    Ok(qps)
}

fn sharegpt_prompt() -> LengthDistribution {
    LengthDistribution::LogNormal {
        median: 96.0,
        sigma: 1.1,
        min: 4,
        max: 2048,
    }
}

fn sharegpt_output() -> LengthDistribution {
    LengthDistribution::LogNormal {
        median: 128.0,
        sigma: 1.0,
        min: 4,
        max: 2048,
    }
}

fn build_synthetic(p: &Yaml) -> Result<Box<dyn WorkloadGenerator>> {
    let spec = WorkloadSpec {
        num_requests: p
            .req("num_requests")?
            .as_u64()
            .context("'num_requests' must be a non-negative integer")? as usize,
        qps: req_qps(p, "qps")?,
        arrival: match p.get("arrival") {
            Some(a) => arrival_from_yaml(a)?,
            None => ArrivalProcess::Poisson,
        },
        prompt_len: length_dist_from_yaml(p.req("prompt_len")?).context("in 'prompt_len'")?,
        output_len: length_dist_from_yaml(p.req("output_len")?).context("in 'output_len'")?,
        seed: opt_u64_strict(p, "seed", 0)?,
    };
    Ok(Box::new(SyntheticWorkload(spec)))
}

fn build_trace(p: &Yaml) -> Result<Box<dyn WorkloadGenerator>> {
    let time_scale = opt_f64_strict(p, "time_scale", 1.0)?;
    ensure!(time_scale > 0.0, "'time_scale' must be > 0");
    let max_requests = match p.get("max_requests") {
        None | Some(Yaml::Null) => None,
        Some(v) => Some(
            v.as_u64()
                .context("'max_requests' must be a non-negative integer or null")? as usize,
        ),
    };
    Ok(Box::new(TraceWorkload {
        path: p.req_str("path")?.to_string(),
        time_scale,
        max_requests,
    }))
}

fn build_bursty(p: &Yaml) -> Result<Box<dyn WorkloadGenerator>> {
    let qps_on = req_qps(p, "qps")?;
    let qps_off = opt_f64_strict(p, "off_qps", qps_on / 10.0)?;
    let on_s = opt_f64_strict(p, "on_s", 10.0)?;
    let off_s = opt_f64_strict(p, "off_s", 10.0)?;
    let cv = opt_f64_strict(p, "cv", 1.0)?;
    ensure!(qps_off > 0.0, "'off_qps' must be > 0");
    ensure!(on_s > 0.0 && off_s > 0.0, "'on_s'/'off_s' must be > 0");
    ensure!(cv > 0.0, "'cv' must be > 0");
    Ok(Box::new(BurstyWorkload {
        num_requests: p
            .req("num_requests")?
            .as_u64()
            .context("'num_requests' must be a non-negative integer")? as usize,
        qps_on,
        qps_off,
        on_s,
        off_s,
        cv,
        prompt_len: opt_dist_strict(p, "prompt_len", sharegpt_prompt())?,
        output_len: opt_dist_strict(p, "output_len", sharegpt_output())?,
        seed: opt_u64_strict(p, "seed", 0)?,
    }))
}

const TENANT_KEYS: &[&str] = &[
    "name",
    "num_requests",
    "qps",
    "arrival",
    "prompt_len",
    "output_len",
    "ttft",
    "mtpot",
];

fn parse_tenant(ty: &Yaml) -> Result<TenantClass> {
    let Yaml::Map(m) = ty else {
        bail!("tenant entries must be maps");
    };
    for key in m.keys() {
        if !TENANT_KEYS.contains(&key.as_str()) {
            bail!(
                "unknown tenant parameter '{key}' (accepted: {})",
                TENANT_KEYS.join(", ")
            );
        }
    }
    Ok(TenantClass {
        name: ty.req_str("name")?.to_string(),
        num_requests: ty
            .req("num_requests")?
            .as_u64()
            .context("'num_requests' must be a non-negative integer")? as usize,
        qps: req_qps(ty, "qps")?,
        arrival: match ty.get("arrival") {
            Some(a) => arrival_from_yaml(a)?,
            None => ArrivalProcess::Poisson,
        },
        prompt_len: opt_dist_strict(ty, "prompt_len", sharegpt_prompt())?,
        output_len: opt_dist_strict(ty, "output_len", sharegpt_output())?,
        slo: SloSpec {
            ttft: ty.get("ttft").and_then(Yaml::as_f64),
            mtpot: ty.get("mtpot").and_then(Yaml::as_f64),
        },
    })
}

fn build_multi_tenant(p: &Yaml) -> Result<Box<dyn WorkloadGenerator>> {
    let list = p
        .req("tenants")?
        .as_list()
        .context("'tenants' must be a list of tenant classes")?;
    ensure!(!list.is_empty(), "'tenants' must name at least one class");
    let mut tenants: Vec<TenantClass> = Vec::with_capacity(list.len());
    for (i, ty) in list.iter().enumerate() {
        let tenant = parse_tenant(ty).with_context(|| format!("in tenant {}", i + 1))?;
        if tenants.iter().any(|t| t.name == tenant.name) {
            bail!("duplicate tenant name '{}'", tenant.name);
        }
        tenants.push(tenant);
    }
    Ok(Box::new(MultiTenantWorkload {
        tenants,
        seed: opt_u64_strict(p, "seed", 0)?,
    }))
}

fn build_long_context(p: &Yaml) -> Result<Box<dyn WorkloadGenerator>> {
    let long_fraction = opt_f64_strict(p, "long_fraction", 0.25)?;
    ensure!(
        (0.0..=1.0).contains(&long_fraction),
        "'long_fraction' must be in [0, 1]"
    );
    let long_median = opt_f64_strict(p, "long_median", 4096.0)?;
    let long_sigma = opt_f64_strict(p, "long_sigma", 0.3)?;
    let max_prompt = opt_u64_strict(p, "max_prompt", 16_384)? as u32;
    ensure!(long_median > 0.0, "'long_median' must be > 0");
    ensure!(max_prompt >= 1, "'max_prompt' must be >= 1");
    Ok(Box::new(LongContextWorkload {
        num_requests: opt_usize_strict(p, "num_requests", 1000)?,
        qps: req_qps(p, "qps")?,
        long_fraction,
        short_prompt: sharegpt_prompt(),
        long_prompt: LengthDistribution::LogNormal {
            median: long_median,
            sigma: long_sigma,
            min: 1,
            max: max_prompt,
        },
        output_len: opt_dist_strict(
            p,
            "output_len",
            LengthDistribution::LogNormal {
                median: 128.0,
                sigma: 1.0,
                min: 4,
                max: 1024,
            },
        )?,
        seed: opt_u64_strict(p, "seed", 0)?,
    }))
}

/// Built-in workload generators.
pub const WORKLOAD_GENERATORS: &[WorkloadEntry] = &[
    WorkloadEntry {
        name: "synthetic",
        aliases: &["parametric"],
        summary: "arrival process x length distributions (the classic workload section)",
        params: &[
            "num_requests",
            "qps",
            "arrival",
            "prompt_len",
            "output_len",
            "seed",
        ],
        build: build_synthetic,
    },
    WorkloadEntry {
        name: "trace",
        aliases: &["replay", "jsonl"],
        summary: "JSONL trace replay (archive one with `tokensim run --save-trace`)",
        params: &["path", "time_scale", "max_requests"],
        build: build_trace,
    },
    WorkloadEntry {
        name: "bursty",
        aliases: &["burstgpt", "on_off"],
        summary: "BurstGPT-style on/off phases over Gamma within-phase arrivals",
        params: &[
            "num_requests",
            "qps",
            "off_qps",
            "on_s",
            "off_s",
            "cv",
            "prompt_len",
            "output_len",
            "seed",
        ],
        build: build_bursty,
    },
    WorkloadEntry {
        name: "multi_tenant",
        aliases: &["tenants"],
        summary: "N tenant classes with per-class rate/lengths/SLOs, tagged in reports",
        params: &["tenants", "seed"],
        build: build_multi_tenant,
    },
    WorkloadEntry {
        name: "long_context",
        aliases: &["longctx", "rag"],
        summary: "heavy-prefill mix: ShareGPT prompts with a long-context lognormal tail",
        params: &[
            "num_requests",
            "qps",
            "long_fraction",
            "long_median",
            "long_sigma",
            "max_prompt",
            "output_len",
            "seed",
        ],
        build: build_long_context,
    },
];

// ---------------------------------------------------------------------------
// Runtime registration (library users; built-ins live in the table)
// ---------------------------------------------------------------------------

struct DynWorkloadEntry {
    name: String,
    summary: String,
    #[allow(clippy::type_complexity)]
    build: Box<dyn Fn(&Yaml) -> Result<Box<dyn WorkloadGenerator>> + Send + Sync>,
}

fn extra_workloads() -> &'static Mutex<Vec<DynWorkloadEntry>> {
    static EXTRA: OnceLock<Mutex<Vec<DynWorkloadEntry>>> = OnceLock::new();
    EXTRA.get_or_init(|| Mutex::new(Vec::new()))
}

/// Register a workload generator at runtime. Registered names take
/// precedence over built-ins, so a library user can also shadow a
/// built-in generator.
///
/// # Examples
///
/// A "bring your own scenario" flow — any [`WorkloadGenerator`]
/// implementation becomes selectable by name, including from YAML:
///
/// ```
/// use tokensim::request::Request;
/// use tokensim::workload::{register_workload, WorkloadGenerator, WorkloadSpecV2};
///
/// /// Two back-to-back probe requests (demo).
/// struct Probe;
///
/// impl WorkloadGenerator for Probe {
///     fn name(&self) -> &'static str { "probe" }
///     fn generate(&self) -> anyhow::Result<Vec<Request>> {
///         Ok(vec![
///             Request::new(0, 0, 0, 8, 4, 0.0),
///             Request::new(1, 1, 0, 8, 4, 0.1),
///         ])
///     }
/// }
///
/// register_workload("probe", "two probe requests (demo)", |_params| Ok(Box::new(Probe)));
///
/// let requests = WorkloadSpecV2::new("probe").generate().unwrap();
/// assert_eq!(requests.len(), 2);
/// ```
pub fn register_workload(
    name: &str,
    summary: &str,
    build: impl Fn(&Yaml) -> Result<Box<dyn WorkloadGenerator>> + Send + Sync + 'static,
) {
    extra_workloads().lock().unwrap().push(DynWorkloadEntry {
        name: name.to_string(),
        summary: summary.to_string(),
        build: Box::new(build),
    });
}

fn matches_name(candidate: &str, name: &str, aliases: &[&str]) -> bool {
    candidate.eq_ignore_ascii_case(name)
        || aliases.iter().any(|a| candidate.eq_ignore_ascii_case(a))
}

/// Reject typo'd parameter keys for built-in generators ("generator"
/// itself is the selector key YAML specs carry). Runtime-registered
/// generators validate their own params in their builder.
fn check_param_keys(spec: &WorkloadSpecV2, known: &[&str]) -> Result<()> {
    if let Yaml::Map(m) = &spec.params {
        for key in m.keys() {
            if key != "generator" && !known.contains(&key.as_str()) {
                bail!(
                    "unknown parameter '{key}' for workload generator '{}' (accepted: {})",
                    spec.name,
                    known.join(", ")
                );
            }
        }
    }
    Ok(())
}

/// Build a workload generator from a spec. Unknown names list the known
/// generators in the error.
pub fn build_workload(spec: &WorkloadSpecV2) -> Result<Box<dyn WorkloadGenerator>> {
    {
        let extras = extra_workloads().lock().unwrap();
        if let Some(e) = extras
            .iter()
            .rev()
            .find(|e| spec.name.eq_ignore_ascii_case(&e.name))
        {
            return (e.build)(&spec.params)
                .with_context(|| format!("building workload generator '{}'", spec.name));
        }
    }
    let entry = WORKLOAD_GENERATORS
        .iter()
        .find(|e| matches_name(&spec.name, e.name, e.aliases))
        .with_context(|| {
            format!(
                "unknown workload generator '{}' (known: {})",
                spec.name,
                workload_generators()
                    .iter()
                    .map(|(n, _, _)| n.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })?;
    check_param_keys(spec, entry.params)?;
    (entry.build)(&spec.params)
        .with_context(|| format!("building workload generator '{}'", spec.name))
}

/// All registered generators as `(name, summary, accepted-params)`,
/// built-ins first.
pub fn workload_generators() -> Vec<(String, String, String)> {
    let mut out: Vec<(String, String, String)> = WORKLOAD_GENERATORS
        .iter()
        .map(|e| {
            (
                e.name.to_string(),
                e.summary.to_string(),
                e.params.join(", "),
            )
        })
        .collect();
    for e in extra_workloads().lock().unwrap().iter() {
        out.push((e.name.clone(), e.summary.clone(), "(generator-defined)".to_string()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_round_trips_workload_spec_bit_identically() {
        let spec = WorkloadSpec::sharegpt(200, 7.5).with_seed(42);
        let direct = spec.clone().generate();
        let v2: WorkloadSpecV2 = spec.into();
        assert_eq!(v2.name, "synthetic");
        assert_eq!(v2.seed(), 42);
        let via = v2.generate().unwrap();
        assert_eq!(direct.len(), via.len());
        for (a, b) in direct.iter().zip(&via) {
            assert_eq!(a.arrival, b.arrival, "arrivals must round-trip exactly");
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.output_len, b.output_len);
        }
    }

    #[test]
    fn builds_every_builtin_generator() {
        let trace_params = |spec: WorkloadSpecV2| spec.with("path", "unused.jsonl");
        let tenants = Yaml::List(vec![Yaml::parse(
            "name: a\nnum_requests: 5\nqps: 1.0\n",
        )
        .unwrap()]);
        for e in WORKLOAD_GENERATORS {
            let spec = match e.name {
                "trace" => trace_params(WorkloadSpecV2::new(e.name)),
                "multi_tenant" => WorkloadSpecV2::new(e.name).with("tenants", tenants.clone()),
                "synthetic" => WorkloadSpecV2::new(e.name)
                    .with("num_requests", 10u32)
                    .with("qps", 4.0)
                    .with("prompt_len", ymap(vec![("fixed", Yaml::from(8u32))]))
                    .with("output_len", ymap(vec![("fixed", Yaml::from(8u32))])),
                // bursty / long_context: every length knob has a default
                name => WorkloadSpecV2::new(name)
                    .with("num_requests", 10u32)
                    .with("qps", 4.0),
            };
            let generator = spec
                .build()
                .unwrap_or_else(|err| panic!("{}: {err:#}", e.name));
            assert_eq!(generator.name(), e.name);
        }
    }

    #[test]
    fn aliases_and_case_resolve() {
        for (alias, canonical) in [
            ("BurstGPT", "bursty"),
            ("Tenants", "multi_tenant"),
            ("longctx", "long_context"),
            ("Replay", "trace"),
        ] {
            let spec = match canonical {
                "trace" => WorkloadSpecV2::new(alias).with("path", "x.jsonl"),
                "multi_tenant" => WorkloadSpecV2::new(alias).with(
                    "tenants",
                    Yaml::List(vec![Yaml::parse("name: a\nnum_requests: 1\nqps: 1.0\n").unwrap()]),
                ),
                _ => WorkloadSpecV2::new(alias)
                    .with("num_requests", 1u32)
                    .with("qps", 1.0),
            };
            assert_eq!(spec.build().unwrap().name(), canonical);
        }
    }

    #[test]
    fn unknown_generator_is_an_error_listing_known() {
        let err = WorkloadSpecV2::new("infinite").build().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown workload generator"), "{msg}");
        assert!(msg.contains("multi_tenant"), "{msg}");
    }

    #[test]
    fn typod_or_malformed_params_are_errors() {
        let err = WorkloadSpecV2::new("bursty")
            .with("num_requests", 10u32)
            .with("qps", 4.0)
            .with("off_qsp", 1.0)
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown parameter 'off_qsp'"));
        // malformed value on a well-known key
        let err = WorkloadSpecV2::new("trace")
            .with("path", "t.jsonl")
            .with("time_scale", "fast")
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("time_scale"));
        // typo'd per-tenant key
        let err = WorkloadSpecV2::new("multi_tenant")
            .with(
                "tenants",
                Yaml::List(vec![Yaml::parse(
                    "name: a\nnum_requests: 1\nqps: 1.0\nqqs: 2.0\n",
                )
                .unwrap()]),
            )
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown tenant parameter 'qqs'"));
    }

    #[test]
    fn uniform_min_above_max_is_a_parse_error_not_a_panic() {
        let y = Yaml::parse(
            "num_requests: 5\nqps: 1.0\nprompt_len:\n  uniform:\n    min: 5\n    max: 2\noutput_len:\n  fixed: 8\n",
        )
        .unwrap();
        let spec = WorkloadSpecV2::from_yaml(&y).unwrap();
        let err = spec.validate().unwrap_err();
        assert!(format!("{err:#}").contains("min (5) > max (2)"));
    }

    #[test]
    fn from_yaml_defaults_to_synthetic() {
        let y = Yaml::parse(
            "num_requests: 10\nqps: 2.0\nprompt_len:\n  fixed: 8\noutput_len:\n  fixed: 4\nseed: 3\n",
        )
        .unwrap();
        let spec = WorkloadSpecV2::from_yaml(&y).unwrap();
        assert_eq!(spec.name, "synthetic");
        assert_eq!(spec.seed(), 3);
        assert_eq!(spec.generate().unwrap().len(), 10);
        let y = Yaml::parse("generator: bursty\nnum_requests: 10\nqps: 20.0\n").unwrap();
        let spec = WorkloadSpecV2::from_yaml(&y).unwrap();
        assert_eq!(spec.name, "bursty");
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn runtime_registration_shadows_builtins() {
        register_workload("test_shadow_synth", "test", build_synthetic);
        let spec = WorkloadSpecV2::new("test_shadow_synth")
            .with("num_requests", 3u32)
            .with("qps", 1.0)
            .with("prompt_len", ymap(vec![("fixed", Yaml::from(8u32))]))
            .with("output_len", ymap(vec![("fixed", Yaml::from(8u32))]));
        assert_eq!(spec.generate().unwrap().len(), 3);
        assert!(workload_generators()
            .iter()
            .any(|(n, _, _)| n == "test_shadow_synth"));
    }

    #[test]
    fn multi_tenant_slos_flow_through_the_registry() {
        let spec = WorkloadSpecV2::new("multi_tenant").with(
            "tenants",
            Yaml::List(vec![
                Yaml::parse("name: chat\nnum_requests: 5\nqps: 4.0\nttft: 2.0\nmtpot: 0.2\n")
                    .unwrap(),
                Yaml::parse("name: batch\nnum_requests: 5\nqps: 1.0\n").unwrap(),
            ]),
        );
        let generator = spec.build().unwrap();
        let slos = generator.tenant_slos();
        assert_eq!(slos.len(), 2);
        assert_eq!(slos[0].0, "chat");
        assert_eq!(slos[0].1.ttft, Some(2.0));
        assert_eq!(slos[1].1.ttft, None);
        let reqs = generator.generate().unwrap();
        assert!(reqs.iter().all(|r| r.tenant.is_some()));
    }
}
