//! Minimal YAML-subset parser (serde_yaml is unavailable offline).
//!
//! Supports the subset the paper's Fig-2-style configs need:
//! indentation-nested mappings, block lists (`- item` including inline
//! nested maps), scalars (string / f64 / bool / null), quoted strings,
//! and `#` comments. No anchors, no flow collections, no multi-line
//! scalars.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed YAML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Yaml {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    List(Vec<Yaml>),
    Map(BTreeMap<String, Yaml>),
}

impl Yaml {
    pub fn parse(text: &str) -> Result<Yaml> {
        let lines: Vec<Line> = text
            .lines()
            .enumerate()
            .filter_map(|(no, raw)| Line::new(no + 1, raw))
            .collect();
        if lines.is_empty() {
            return Ok(Yaml::Null);
        }
        let (v, used) = parse_block(&lines, 0, lines[0].indent)?;
        if used != lines.len() {
            bail!("line {}: unexpected dedent/content", lines[used].no);
        }
        Ok(v)
    }

    // ---- accessors -----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Yaml> {
        match self {
            Yaml::Map(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Yaml> {
        self.get(key)
            .with_context(|| format!("missing config key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Yaml::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Yaml::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Yaml::Num(n) if *n >= 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_u32(&self) -> Option<u32> {
        self.as_u64().map(|v| v as u32)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Yaml::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_list(&self) -> Option<&[Yaml]> {
        match self {
            Yaml::List(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_map(&self) -> Option<&BTreeMap<String, Yaml>> {
        match self {
            Yaml::Map(m) => Some(m),
            _ => None,
        }
    }

    // typed required accessors for config loading
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .with_context(|| format!("'{key}' must be a string"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .with_context(|| format!("'{key}' must be a number"))
    }

    pub fn req_u32(&self, key: &str) -> Result<u32> {
        self.req(key)?
            .as_u32()
            .with_context(|| format!("'{key}' must be a non-negative integer"))
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Yaml::as_f64).unwrap_or(default)
    }

    pub fn opt_u32(&self, key: &str, default: u32) -> u32 {
        self.get(key).and_then(Yaml::as_u32).unwrap_or(default)
    }

    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Yaml::as_bool).unwrap_or(default)
    }
}

// `From` impls so programmatic parameter maps (e.g.
// [`crate::scheduler::PolicySpec::with`]) read like YAML: `None`
// becomes `null`, integers become numbers.

impl From<bool> for Yaml {
    fn from(v: bool) -> Self {
        Yaml::Bool(v)
    }
}

impl From<u32> for Yaml {
    fn from(v: u32) -> Self {
        Yaml::Num(v as f64)
    }
}

impl From<u64> for Yaml {
    fn from(v: u64) -> Self {
        Yaml::Num(v as f64)
    }
}

impl From<f64> for Yaml {
    fn from(v: f64) -> Self {
        Yaml::Num(v)
    }
}

impl From<&str> for Yaml {
    fn from(v: &str) -> Self {
        Yaml::Str(v.to_string())
    }
}

impl From<String> for Yaml {
    fn from(v: String) -> Self {
        Yaml::Str(v)
    }
}

impl<T: Into<Yaml>> From<Option<T>> for Yaml {
    fn from(v: Option<T>) -> Self {
        match v {
            Some(x) => x.into(),
            None => Yaml::Null,
        }
    }
}

#[derive(Debug)]
struct Line {
    no: usize,
    indent: usize,
    /// Content with indentation stripped.
    text: String,
}

impl Line {
    fn new(no: usize, raw: &str) -> Option<Line> {
        let without_comment = strip_comment(raw);
        let trimmed = without_comment.trim_end();
        let indent = trimmed.len() - trimmed.trim_start().len();
        let text = trimmed.trim_start().to_string();
        if text.is_empty() {
            None
        } else {
            Some(Line { no, indent, text })
        }
    }
}

fn strip_comment(s: &str) -> String {
    let mut out = String::new();
    let mut in_sq = false;
    let mut in_dq = false;
    for c in s.chars() {
        match c {
            '\'' if !in_dq => in_sq = !in_sq,
            '"' if !in_sq => in_dq = !in_dq,
            '#' if !in_sq && !in_dq => break,
            _ => {}
        }
        out.push(c);
    }
    out
}

/// Parse a block starting at `start` whose items are indented `indent`.
/// Returns (value, next-line index).
fn parse_block(lines: &[Line], start: usize, indent: usize) -> Result<(Yaml, usize)> {
    if lines[start].text.starts_with("- ") || lines[start].text == "-" {
        parse_list(lines, start, indent)
    } else {
        parse_map(lines, start, indent)
    }
}

fn parse_list(lines: &[Line], start: usize, indent: usize) -> Result<(Yaml, usize)> {
    let mut items = Vec::new();
    let mut i = start;
    while i < lines.len() && lines[i].indent == indent {
        let line = &lines[i];
        if !(line.text.starts_with("- ") || line.text == "-") {
            break;
        }
        let rest = line.text[1..].trim_start();
        if rest.is_empty() {
            // nested block under the dash
            let (v, next) = if i + 1 < lines.len() && lines[i + 1].indent > indent {
                parse_block(lines, i + 1, lines[i + 1].indent)?
            } else {
                (Yaml::Null, i + 1)
            };
            items.push(v);
            i = next;
        } else if let Some((k, v)) = split_key(rest) {
            // "- key: value" starts an inline map item; subsequent deeper
            // lines belong to the same map
            let mut m = BTreeMap::new();
            let item_indent = indent + (line.text.len() - rest.len());
            if v.is_empty() {
                let (nested, next) = if i + 1 < lines.len() && lines[i + 1].indent > item_indent {
                    parse_block(lines, i + 1, lines[i + 1].indent)?
                } else {
                    (Yaml::Null, i + 1)
                };
                m.insert(k.to_string(), nested);
                i = next;
            } else {
                m.insert(k.to_string(), scalar(v));
                i += 1;
            }
            while i < lines.len() && lines[i].indent == item_indent {
                let Some((k2, v2)) = split_key(&lines[i].text) else {
                    bail!("line {}: expected 'key:' in list item", lines[i].no);
                };
                if v2.is_empty() {
                    let (nested, next) =
                        if i + 1 < lines.len() && lines[i + 1].indent > item_indent {
                            parse_block(lines, i + 1, lines[i + 1].indent)?
                        } else {
                            (Yaml::Null, i + 1)
                        };
                    m.insert(k2.to_string(), nested);
                    i = next;
                } else {
                    m.insert(k2.to_string(), scalar(v2));
                    i += 1;
                }
            }
            items.push(Yaml::Map(m));
        } else {
            items.push(scalar(rest));
            i += 1;
        }
    }
    Ok((Yaml::List(items), i))
}

fn parse_map(lines: &[Line], start: usize, indent: usize) -> Result<(Yaml, usize)> {
    let mut m = BTreeMap::new();
    let mut i = start;
    while i < lines.len() && lines[i].indent == indent {
        let line = &lines[i];
        let Some((k, v)) = split_key(&line.text) else {
            bail!("line {}: expected 'key: value'", line.no);
        };
        if v.is_empty() {
            // nested block (or empty value)
            if i + 1 < lines.len() && lines[i + 1].indent > indent {
                let (nested, next) = parse_block(lines, i + 1, lines[i + 1].indent)?;
                m.insert(k.to_string(), nested);
                i = next;
            } else {
                m.insert(k.to_string(), Yaml::Null);
                i += 1;
            }
        } else {
            m.insert(k.to_string(), scalar(v));
            i += 1;
        }
        if i < lines.len() && lines[i].indent > indent {
            bail!("line {}: unexpected indent", lines[i].no);
        }
    }
    Ok((Yaml::Map(m), i))
}

/// Split `key: value` (value may be empty). Returns None when the line
/// has no unquoted ':'.
fn split_key(text: &str) -> Option<(&str, &str)> {
    let mut in_sq = false;
    let mut in_dq = false;
    for (idx, c) in text.char_indices() {
        match c {
            '\'' if !in_dq => in_sq = !in_sq,
            '"' if !in_sq => in_dq = !in_dq,
            ':' if !in_sq && !in_dq => {
                let after = &text[idx + 1..];
                if after.is_empty() || after.starts_with(' ') {
                    return Some((text[..idx].trim(), after.trim()));
                }
            }
            _ => {}
        }
    }
    None
}

fn scalar(text: &str) -> Yaml {
    let t = text.trim();
    if (t.starts_with('"') && t.ends_with('"') && t.len() >= 2)
        || (t.starts_with('\'') && t.ends_with('\'') && t.len() >= 2)
    {
        return Yaml::Str(t[1..t.len() - 1].to_string());
    }
    match t {
        "null" | "~" | "" => return Yaml::Null,
        "true" => return Yaml::Bool(true),
        "false" => return Yaml::Bool(false),
        _ => {}
    }
    if let Ok(n) = t.parse::<f64>() {
        if t.chars()
            .next()
            .map(|c| c.is_ascii_digit() || c == '-' || c == '+' || c == '.')
            .unwrap_or(false)
        {
            return Yaml::Num(n);
        }
    }
    Yaml::Str(t.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        let y = Yaml::parse("a: 1\nb: 2.5\nc: hello\nd: \"quoted: x\"\ne: true\nf: null\n").unwrap();
        assert_eq!(y.req_f64("a").unwrap(), 1.0);
        assert_eq!(y.req_f64("b").unwrap(), 2.5);
        assert_eq!(y.req_str("c").unwrap(), "hello");
        assert_eq!(y.req_str("d").unwrap(), "quoted: x");
        assert_eq!(y.get("e").unwrap().as_bool(), Some(true));
        assert_eq!(y.get("f"), Some(&Yaml::Null));
    }

    #[test]
    fn nested_maps() {
        let y = Yaml::parse("outer:\n  inner:\n    x: 3\n  y: 4\nz: 5\n").unwrap();
        assert_eq!(
            y.get("outer").unwrap().get("inner").unwrap().req_f64("x").unwrap(),
            3.0
        );
        assert_eq!(y.get("outer").unwrap().req_f64("y").unwrap(), 4.0);
        assert_eq!(y.req_f64("z").unwrap(), 5.0);
    }

    #[test]
    fn list_of_maps_fig2_style() {
        let y = Yaml::parse(
            "workers:\n  - hardware: A100\n    quantity: 2\n    memory:\n      block_size: 16\n  - hardware: V100\n",
        )
        .unwrap();
        let ws = y.get("workers").unwrap().as_list().unwrap();
        assert_eq!(ws.len(), 2);
        assert_eq!(ws[0].req_str("hardware").unwrap(), "A100");
        assert_eq!(ws[0].req_u32("quantity").unwrap(), 2);
        assert_eq!(
            ws[0].get("memory").unwrap().req_u32("block_size").unwrap(),
            16
        );
        assert_eq!(ws[1].req_str("hardware").unwrap(), "V100");
    }

    #[test]
    fn list_of_scalars() {
        let y = Yaml::parse("xs:\n  - 1\n  - 2\n  - three\n").unwrap();
        let xs = y.get("xs").unwrap().as_list().unwrap();
        assert_eq!(xs[0], Yaml::Num(1.0));
        assert_eq!(xs[2], Yaml::Str("three".into()));
    }

    #[test]
    fn comments_stripped() {
        let y = Yaml::parse("a: 1 # comment\n# full line\nb: 'x # not comment'\n").unwrap();
        assert_eq!(y.req_f64("a").unwrap(), 1.0);
        assert_eq!(y.req_str("b").unwrap(), "x # not comment");
    }

    #[test]
    fn empty_is_null() {
        assert_eq!(Yaml::parse("").unwrap(), Yaml::Null);
        assert_eq!(Yaml::parse("# only comments\n").unwrap(), Yaml::Null);
    }

    #[test]
    fn typed_accessors_error_messages() {
        let y = Yaml::parse("a: x\n").unwrap();
        assert!(y.req_f64("a").is_err());
        assert!(y.req_str("missing").is_err());
        assert_eq!(y.opt_f64("missing", 7.0), 7.0);
        assert_eq!(y.opt_bool("missing", true), true);
    }

    #[test]
    fn bad_indent_rejected() {
        assert!(Yaml::parse("a: 1\n   b: 2\n").is_err());
    }

    #[test]
    fn numbers_vs_strings() {
        let y = Yaml::parse("a: 1e9\nb: v100\nc: -3\n").unwrap();
        assert_eq!(y.req_f64("a").unwrap(), 1e9);
        assert_eq!(y.req_str("b").unwrap(), "v100");
        assert_eq!(y.req_f64("c").unwrap(), -3.0);
    }
}
