//! Configuration system mirroring the paper's Fig 2: hardware config,
//! scheduler config, and model config compose into a cluster/simulation
//! config, loadable from YAML (in-tree subset parser — this build is
//! offline) and constructible programmatically.

pub mod yaml;

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::compute::ComputeSpec;
use crate::hardware::{HardwareSpec, LinkSpec};
use crate::memory::MemorySpec;
use crate::metrics::{MetricsMode, SloSpec};
use crate::model::ModelSpec;
use crate::network::NetworkSpec;
use crate::scheduler::PolicySpec;
use crate::workload::WorkloadSpecV2;

use yaml::Yaml;

/// One worker: hardware + role + local scheduler + memory manager.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerConfig {
    pub hardware: HardwareSpec,
    /// Identical replicas of this worker (Fig 2's `quantity`).
    pub quantity: u32,
    pub run_prefill: bool,
    pub run_decode: bool,
    /// Local scheduling policy, selected by registry name (see
    /// [`crate::scheduler::registry`] and docs/CONFIG.md).
    pub local_scheduler: PolicySpec,
    /// KV memory manager, selected by registry name (see
    /// [`crate::memory::registry`] and docs/CONFIG.md).
    pub memory: MemorySpec,
    /// Per-worker compute-model override (see
    /// [`crate::compute::registry`]); `None` inherits the simulation's
    /// top-level `compute` selection. Together with per-worker
    /// `hardware` this is what makes heterogeneous clusters (A100
    /// prefill / V100 decode, each under its own cost model)
    /// expressible in YAML.
    pub compute: Option<ComputeSpec>,
}

impl WorkerConfig {
    pub fn unified(hw: HardwareSpec, quantity: u32) -> Self {
        Self {
            hardware: hw,
            quantity,
            run_prefill: true,
            run_decode: true,
            local_scheduler: PolicySpec::local_default(),
            memory: MemorySpec::default(),
            compute: None,
        }
    }

    fn from_yaml(y: &Yaml) -> Result<Self> {
        let hardware = match y.req("hardware")? {
            Yaml::Str(name) => HardwareSpec::by_name(name)
                .with_context(|| format!("unknown hardware preset '{name}'"))?,
            inline @ Yaml::Map(_) => hardware_from_yaml(inline)?,
            other => bail!("'hardware' must be a preset name or map, got {other:?}"),
        };
        let local_scheduler = match y.get("local_scheduler") {
            Some(ls) => PolicySpec::from_yaml(ls)?,
            None => PolicySpec::local_default(),
        };
        // fail at parse time, not mid-simulation, on unknown policies
        // or bad parameters
        local_scheduler
            .build_local()
            .context("in 'local_scheduler'")?;
        let memory = match y.get("memory") {
            Some(m) => MemorySpec::from_yaml(m)?,
            None => MemorySpec::default(),
        };
        // fail at parse time, not mid-simulation, on unknown managers
        // or bad parameters
        memory.validate().context("in 'memory'")?;
        let compute = match y.get("compute") {
            Some(c) => {
                let spec = ComputeSpec::from_yaml(c)?;
                // fail at parse time on unknown models or bad parameters
                spec.validate().context("in worker 'compute'")?;
                Some(spec)
            }
            None => None,
        };
        Ok(Self {
            hardware,
            quantity: y.opt_u32("quantity", 1),
            run_prefill: y.opt_bool("run_prefill", true),
            run_decode: y.opt_bool("run_decode", true),
            local_scheduler,
            memory,
            compute,
        })
    }
}

fn hardware_from_yaml(y: &Yaml) -> Result<HardwareSpec> {
    Ok(HardwareSpec {
        name: y.req_str("name")?.to_string(),
        peak_flops: y.req_f64("peak_flops")?,
        efficiency: y.opt_f64("efficiency", 0.55),
        mem_bw: y.req_f64("mem_bw")?,
        mem_cap: y.req_f64("mem_cap")?,
        op_overhead: y.opt_f64("op_overhead", 4.5e-6),
        iter_overhead: y.opt_f64("iter_overhead", 2.0e-3),
        net_bw: y.opt_f64("net_bw", 300e9),
        price: y.opt_f64("price", 1.0),
    })
}

fn link_from_yaml(y: &Yaml) -> Result<LinkSpec> {
    match y {
        Yaml::Str(name) => {
            LinkSpec::by_name(name).with_context(|| format!("unknown link preset '{name}'"))
        }
        Yaml::Map(_) => Ok(LinkSpec {
            name: y.req_str("name")?.to_string(),
            bandwidth: y.req_f64("bandwidth")?,
            latency: y.req_f64("latency")?,
            buffer_depth: y.opt_u32("buffer_depth", 1),
        }),
        other => bail!("link must be a preset name or map, got {other:?}"),
    }
}

/// Scheduler section (Fig 2b).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedulerConfig {
    /// Global dispatch policy, selected by registry name (see
    /// [`crate::scheduler::registry`] and docs/CONFIG.md).
    pub global: PolicySpec,
    /// Interconnect between workers (KV transfers).
    pub interconnect: LinkSpec,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            global: PolicySpec::global_default(),
            interconnect: LinkSpec::nvlink(),
        }
    }
}

/// Cluster: the workers plus inter-worker scheduling.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub workers: Vec<WorkerConfig>,
    pub scheduler: SchedulerConfig,
}

/// How a fast-forwarded decode window is costed (`engine:
/// {window_cost: …}`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WindowCost {
    /// Replay one cost-model call per coalesced iteration — bit-exact,
    /// byte-identical to the event-per-iteration engine. Default.
    #[default]
    Replay,
    /// Fit the window's iteration times as an affine series from two
    /// model calls, verify the extrapolation at the window boundary
    /// with one more call, and stamp the boundaries arithmetically —
    /// O(1) model calls per window. Counts and token totals stay
    /// bit-equal to replay; per-iteration times agree only to float
    /// tolerance, so reports are *approximately* (not byte-)identical.
    /// Requires a model opting in via
    /// [`ComputeModel::decode_window_affine`]; others replay.
    ///
    /// [`ComputeModel::decode_window_affine`]: crate::compute::ComputeModel::decode_window_affine
    Affine,
}

impl WindowCost {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "replay" => Ok(Self::Replay),
            "affine" => Ok(Self::Affine),
            other => bail!("unknown window_cost '{other}' (known: replay, affine)"),
        }
    }
}

/// Event-engine tuning (`engine:` section).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineConfig {
    /// Decode fast-forwarding: when a worker's batch is *closed* (all
    /// decodes, whole running set, no external event before its next
    /// completion, KV growth within the pool) the driver coalesces the
    /// iterations up to the next state-changing boundary into a single
    /// event instead of one heap event per decode token. Reports are
    /// byte-identical either way (the CI determinism gate diffs
    /// `tokensim run --json` across both settings); the switch exists
    /// for A/B measurement and as an escape hatch for out-of-tree
    /// scheduler policies that violate the closed-batch contract
    /// ([`LocalScheduler::decode_fast_forwardable`]). Default: on.
    ///
    /// [`LocalScheduler::decode_fast_forwardable`]: crate::scheduler::LocalScheduler::decode_fast_forwardable
    pub fast_forward: bool,
    /// How coalesced decode windows are costed: `replay` (bit-exact,
    /// default) or `affine` (O(1) model calls per window, float-level
    /// agreement). Only consulted when `fast_forward` is on.
    pub window_cost: WindowCost,
    /// Invariant-audit sanitizer mode (`tokensim run --audit`): the
    /// driver re-checks conservation laws at event boundaries — token
    /// conservation, block/byte accounting at drain, event-time
    /// monotonicity, fast-forward window boundaries, batch composition
    /// (the `A…` codes of [`crate::lint::AUDIT_CHECKS`]) — and a
    /// violated invariant fails the run with a structured
    /// [`crate::lint::AuditViolation`] instead of silently corrupting
    /// the report. Reports are byte-identical with the mode on or off
    /// (every check is read-only); the cost is bounded per event, so
    /// leaving it on roughly doubles per-event bookkeeping but never
    /// changes complexity. Default: off.
    pub audit: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            fast_forward: true,
            window_cost: WindowCost::default(),
            audit: false,
        }
    }
}

impl EngineConfig {
    fn from_yaml(y: &Yaml) -> Result<Self> {
        let window_cost = match y.get("window_cost") {
            None => WindowCost::default(),
            Some(v) => WindowCost::parse(
                v.as_str()
                    .context("'window_cost' must be a string (replay|affine)")?,
            )?,
        };
        Ok(Self {
            fast_forward: y.opt_bool("fast_forward", true),
            window_cost,
            audit: y.opt_bool("audit", false),
        })
    }
}

/// Metric-aggregation tuning (`metrics:` section).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricsConfig {
    /// `exact` (default) keeps every request record and reproduces
    /// byte-identical reports; `sketch` folds records into fixed-size
    /// quantile sketches at completion time (bounded memory, quantiles
    /// within `sketch_error` relative error). The CI determinism gates
    /// byte-diff exact-mode output only; sketch mode is deterministic
    /// too, just not byte-identical to exact.
    pub mode: MetricsMode,
    /// Relative-error bound of sketch-mode quantiles (default 0.01,
    /// i.e. ±1%). Ignored in exact mode.
    pub sketch_error: f64,
}

impl Default for MetricsConfig {
    fn default() -> Self {
        Self {
            mode: MetricsMode::Exact,
            sketch_error: 0.01,
        }
    }
}

impl MetricsConfig {
    fn from_yaml(y: &Yaml) -> Result<Self> {
        let mode = match y.get("mode") {
            Some(m) => MetricsMode::parse(
                m.as_str().context("'mode' must be a string (exact|sketch)")?,
            )?,
            None => MetricsMode::Exact,
        };
        let sketch_error = y.opt_f64("sketch_error", 0.01);
        if !(sketch_error > 0.0 && sketch_error < 0.5) {
            bail!("'sketch_error' must be in (0, 0.5), got {sketch_error}");
        }
        Ok(Self { mode, sketch_error })
    }
}

/// Memory-pool cache section (Fig 14; disabled when absent).
#[derive(Debug, Clone, PartialEq)]
pub struct PoolCacheConfig {
    /// Capacity in KV blocks.
    pub capacity_blocks: u64,
    /// Retrieval link (default: 800 ns/block pool fabric).
    pub link: LinkSpec,
}

impl PoolCacheConfig {
    pub fn with_capacity(capacity_blocks: u64) -> Self {
        Self {
            capacity_blocks,
            link: LinkSpec::pool_fabric(),
        }
    }
}

/// The top-level simulation description.
#[derive(Debug, Clone, PartialEq)]
pub struct SimulationConfig {
    pub model: ModelSpec,
    pub cluster: ClusterConfig,
    /// Workload generator selection (see
    /// [`crate::workload::registry`] and docs/CONFIG.md). A plain
    /// [`WorkloadSpec`](crate::workload::WorkloadSpec) converts via
    /// `Into` (the `synthetic` generator).
    pub workload: WorkloadSpecV2,
    /// Cluster-wide compute-model selection (see
    /// [`crate::compute::registry`] and docs/CONFIG.md); workers may
    /// override it individually. A plain
    /// [`CostModelKind`](crate::compute::CostModelKind) converts via
    /// `Into`.
    pub compute: ComputeSpec,
    /// Artifacts directory ("" = auto-discover).
    pub artifacts_dir: String,
    pub slo: SloSpec,
    pub pool_cache: Option<PoolCacheConfig>,
    /// Memory-timeline sampling period (0 disables sampling).
    pub sample_period: f64,
    /// Event-engine tuning (decode fast-forwarding; on by default).
    pub engine: EngineConfig,
    /// Metric aggregation (exact records vs streaming sketches).
    pub metrics: MetricsConfig,
    /// Network topology selection (see [`crate::network::registry`] and
    /// docs/CONFIG.md). An absent `network:` section selects `flat`,
    /// which prices transfers exactly like the pre-registry driver.
    pub network: NetworkSpec,
}

impl SimulationConfig {
    /// One worker, continuous batching — the vLLM-like baseline setup.
    /// `workload` is anything convertible to a generator spec: a
    /// [`WorkloadSpec`](crate::workload::WorkloadSpec) or a
    /// [`WorkloadSpecV2`].
    pub fn single_worker(
        model: ModelSpec,
        hw: HardwareSpec,
        workload: impl Into<WorkloadSpecV2>,
    ) -> Self {
        Self {
            model,
            cluster: ClusterConfig {
                workers: vec![WorkerConfig::unified(hw, 1)],
                scheduler: SchedulerConfig::default(),
            },
            workload: workload.into(),
            compute: ComputeSpec::default(),
            artifacts_dir: String::new(),
            slo: SloSpec::paper_default(),
            pool_cache: None,
            sample_period: 0.0,
            engine: EngineConfig::default(),
            metrics: MetricsConfig::default(),
            network: NetworkSpec::default(),
        }
    }

    /// A prefill/decode-disaggregated cluster.
    pub fn disaggregated(
        model: ModelSpec,
        prefill_hw: HardwareSpec,
        n_prefill: u32,
        decode_hw: HardwareSpec,
        n_decode: u32,
        workload: impl Into<WorkloadSpecV2>,
    ) -> Self {
        let mut prefill = WorkerConfig::unified(prefill_hw, n_prefill);
        prefill.run_decode = false;
        let mut decode = WorkerConfig::unified(decode_hw, n_decode);
        decode.run_prefill = false;
        Self {
            model,
            cluster: ClusterConfig {
                workers: vec![prefill, decode],
                scheduler: SchedulerConfig::default(),
            },
            workload: workload.into(),
            compute: ComputeSpec::default(),
            artifacts_dir: String::new(),
            slo: SloSpec::paper_default(),
            pool_cache: None,
            sample_period: 0.0,
            engine: EngineConfig::default(),
            metrics: MetricsConfig::default(),
            network: NetworkSpec::default(),
        }
    }

    pub fn from_yaml_str(text: &str) -> Result<Self> {
        let y = Yaml::parse(text).context("parsing simulation config")?;
        Self::from_yaml(&y)
    }

    pub fn from_yaml_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_yaml_str(&text)
    }

    pub fn from_yaml(y: &Yaml) -> Result<Self> {
        let model = match y.req("model")? {
            Yaml::Str(name) => ModelSpec::by_name(name)
                .with_context(|| format!("unknown model preset '{name}'"))?,
            inline @ Yaml::Map(_) => ModelSpec {
                name: inline.req_str("name")?.to_string(),
                hidden: inline.req_u32("hidden")?,
                layers: inline.req_u32("layers")?,
                heads: inline.req_u32("heads")?,
                kv_heads: inline.opt_u32("kv_heads", inline.req_u32("heads")?),
                ffn: inline.req_u32("ffn")?,
                vocab: inline.req_u32("vocab")?,
                dtype_bytes: inline.opt_u32("dtype_bytes", 2),
                tp: inline.opt_u32("tp", 1),
            },
            other => bail!("'model' must be a preset name or map, got {other:?}"),
        };

        let cluster_y = y.req("cluster")?;
        let workers = cluster_y
            .req("workers")?
            .as_list()
            .context("'workers' must be a list")?
            .iter()
            .map(WorkerConfig::from_yaml)
            .collect::<Result<Vec<_>>>()?;
        let scheduler = match cluster_y.get("scheduler") {
            Some(s) => {
                let global = match s.get("global") {
                    Some(g) => PolicySpec::from_yaml(g)?,
                    None => PolicySpec::global_default(),
                };
                // validate the policy name/params at parse time
                global.build_global().context("in scheduler 'global'")?;
                SchedulerConfig {
                    global,
                    interconnect: match s.get("interconnect") {
                        Some(l) => link_from_yaml(l)?,
                        None => LinkSpec::nvlink(),
                    },
                }
            }
            None => SchedulerConfig::default(),
        };

        let slo = match y.get("slo") {
            Some(s) => SloSpec {
                ttft: s.get("ttft").and_then(Yaml::as_f64),
                mtpot: s.get("mtpot").and_then(Yaml::as_f64),
            },
            None => SloSpec::paper_default(),
        };

        let pool_cache = match y.get("pool_cache") {
            Some(pc) => Some(PoolCacheConfig {
                capacity_blocks: pc
                    .req("capacity_blocks")?
                    .as_u64()
                    .context("'capacity_blocks' must be an integer")?,
                link: match pc.get("link") {
                    Some(l) => link_from_yaml(l)?,
                    None => LinkSpec::pool_fabric(),
                },
            }),
            None => None,
        };

        // fail at parse time, not mid-simulation, on unknown generators
        // or bad parameters (trace files are read at generation time)
        let workload = WorkloadSpecV2::from_yaml(y.req("workload")?)?;
        workload.validate().context("in 'workload'")?;

        // the `compute:` section selects from the compute registry; the
        // pre-registry scalar `cost_model: <name>` keeps working and now
        // accepts any registered name
        let compute = match (y.get("compute"), y.get("cost_model")) {
            (Some(c), _) => ComputeSpec::from_yaml(c)?,
            (None, Some(k)) => ComputeSpec::new(
                k.as_str()
                    .context("'cost_model' must be a string (a compute-model name)")?,
            ),
            (None, None) => ComputeSpec::default(),
        };
        // fail at parse time, not mid-simulation, on unknown models or
        // bad parameters
        compute.validate().context("in 'compute'")?;

        // the `network:` section selects from the topology registry; an
        // absent section is the pre-registry flat single-link pricing
        let network = match y.get("network") {
            Some(n) => {
                let spec = NetworkSpec::from_yaml(n)?;
                spec.validate().context("in 'network'")?;
                spec
            }
            None => NetworkSpec::default(),
        };

        Ok(Self {
            model,
            cluster: ClusterConfig { workers, scheduler },
            workload,
            compute,
            artifacts_dir: y
                .get("artifacts_dir")
                .and_then(Yaml::as_str)
                .unwrap_or("")
                .to_string(),
            slo,
            pool_cache,
            sample_period: y.opt_f64("sample_period", 0.0),
            engine: match y.get("engine") {
                Some(e) => EngineConfig::from_yaml(e)?,
                None => EngineConfig::default(),
            },
            metrics: match y.get("metrics") {
                Some(m) => MetricsConfig::from_yaml(m)?,
                None => MetricsConfig::default(),
            },
            network,
        })
    }

    /// Total worker count after expanding `quantity`.
    pub fn total_workers(&self) -> u32 {
        self.cluster.workers.iter().map(|w| w.quantity).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadSpec;

    #[test]
    fn parses_fig2_style_config() {
        let yaml = r#"
model: llama2-7b
cluster:
  workers:
    - hardware: A100
      quantity: 2
      run_prefill: true
      run_decode: false
      local_scheduler:
        policy: continuous
        max_batched_tokens: 1000
        max_batch_size: 256
      memory:
        block_size: 16
        gpu_utilization: 0.8
        max_mem_ratio: 1.0
        watermark: 0.01
    - hardware: G6-AiM
      quantity: 6
      run_prefill: false
      run_decode: true
  scheduler:
    global:
      policy: round_robin
    interconnect: NVLink
workload:
  num_requests: 1000
  qps: 8.0
  arrival: poisson
  prompt_len:
    fixed: 64
  output_len:
    fixed: 64
  seed: 7
"#;
        let cfg = SimulationConfig::from_yaml_str(yaml).unwrap();
        assert_eq!(cfg.total_workers(), 8);
        assert_eq!(cfg.model.hidden, 4096);
        assert_eq!(cfg.cluster.workers[0].hardware.name, "A100");
        assert!(!cfg.cluster.workers[1].run_prefill);
        assert_eq!(cfg.cluster.scheduler.global.name, "round_robin");
        let local = &cfg.cluster.workers[0].local_scheduler;
        assert_eq!(local.name, "continuous");
        assert_eq!(local.params.opt_u32("max_batched_tokens", 0), 1000);
        assert_eq!(local.params.opt_u32("max_batch_size", 0), 256);
        assert_eq!(local.build_local().unwrap().name(), "continuous");
        let memory = &cfg.cluster.workers[0].memory;
        assert_eq!(memory.name, "paged", "bare memory sections stay paged");
        assert!((memory.params.opt_f64("gpu_utilization", 0.9) - 0.8).abs() < 1e-12);
        // a bare `workload:` section selects the synthetic generator
        assert_eq!(cfg.workload.name, "synthetic");
        assert_eq!(cfg.workload.seed(), 7);
        let reqs = cfg.workload.generate().unwrap();
        assert_eq!(reqs.len(), 1000);
        assert!(reqs.iter().all(|r| r.prompt_len == 64));
    }

    #[test]
    fn inline_model_and_hardware() {
        let yaml = r#"
model:
  name: custom
  hidden: 1024
  layers: 8
  heads: 16
  ffn: 4096
  vocab: 5000
cluster:
  workers:
    - hardware:
        name: widget
        peak_flops: 1e14
        mem_bw: 1e12
        mem_cap: 4e10
workload:
  num_requests: 10
  qps: 1.0
  prompt_len:
    fixed: 8
  output_len:
    uniform:
      min: 4
      max: 12
"#;
        let cfg = SimulationConfig::from_yaml_str(yaml).unwrap();
        assert_eq!(cfg.model.name, "custom");
        assert_eq!(cfg.model.kv_heads, 16, "kv_heads defaults to heads");
        assert_eq!(cfg.cluster.workers[0].hardware.name, "widget");
        let reqs = cfg.workload.generate().unwrap();
        assert!(reqs.iter().all(|r| (4..=12).contains(&r.output_len)));
    }

    #[test]
    fn unknown_presets_are_errors() {
        let bad = "model: gpt-9\ncluster:\n  workers:\n    - hardware: A100\nworkload:\n  num_requests: 1\n  qps: 1.0\n  prompt_len:\n    fixed: 8\n  output_len:\n    fixed: 8\n";
        assert!(SimulationConfig::from_yaml_str(bad).is_err());
        let bad_hw = bad.replace("gpt-9", "llama2-7b").replace("A100", "tpu-v9");
        assert!(SimulationConfig::from_yaml_str(&bad_hw).is_err());
    }

    #[test]
    fn defaults_applied() {
        let yaml = "model: tiny\ncluster:\n  workers:\n    - hardware: A100\nworkload:\n  num_requests: 10\n  qps: 1.0\n  prompt_len:\n    fixed: 8\n  output_len:\n    fixed: 8\n";
        let cfg = SimulationConfig::from_yaml_str(yaml).unwrap();
        assert_eq!(cfg.cluster.workers[0].quantity, 1);
        assert!(cfg.cluster.workers[0].run_prefill);
        assert_eq!(cfg.slo, SloSpec::paper_default());
        assert!(cfg.pool_cache.is_none());
        assert_eq!(cfg.compute, ComputeSpec::new("hlo"));
        assert!(cfg.cluster.workers[0].compute.is_none());
    }

    #[test]
    fn disaggregated_constructor_roles() {
        let cfg = SimulationConfig::disaggregated(
            ModelSpec::llama2_7b(),
            HardwareSpec::a100_80g(),
            2,
            HardwareSpec::gddr6_aim(),
            6,
            WorkloadSpec::fixed(10, 1.0, 64, 64),
        );
        assert_eq!(cfg.total_workers(), 8);
        assert!(cfg.cluster.workers[0].run_prefill && !cfg.cluster.workers[0].run_decode);
        assert!(!cfg.cluster.workers[1].run_prefill && cfg.cluster.workers[1].run_decode);
    }

    #[test]
    fn new_policies_selectable_from_yaml() {
        let yaml = r#"
model: tiny
cluster:
  workers:
    - hardware: A100
      local_scheduler:
        policy: chunked_prefill
        chunk_tokens: 256
    - hardware: A100
      local_scheduler:
        policy: sjf
        starvation_age: 5.0
  scheduler:
    global:
      policy: power_of_two
workload:
  num_requests: 10
  qps: 1.0
  prompt_len:
    fixed: 8
  output_len:
    fixed: 8
"#;
        let cfg = SimulationConfig::from_yaml_str(yaml).unwrap();
        assert_eq!(cfg.cluster.workers[0].local_scheduler.name, "chunked_prefill");
        assert_eq!(cfg.cluster.workers[1].local_scheduler.name, "sjf");
        assert_eq!(cfg.cluster.scheduler.global.name, "power_of_two");
    }

    #[test]
    fn memory_managers_selectable_from_yaml() {
        let yaml = r#"
model: tiny
cluster:
  workers:
    - hardware: A100
      memory:
        manager: swap
        swap_blocks: 5000
        preemption: swap
    - hardware: A100
      memory:
        manager: prefix_cache
        capacity_blocks: 10000
    - hardware: A100
      memory:
        manager: token_contiguous
workload:
  num_requests: 10
  qps: 1.0
  prompt_len:
    fixed: 8
  output_len:
    fixed: 8
"#;
        let cfg = SimulationConfig::from_yaml_str(yaml).unwrap();
        assert_eq!(cfg.cluster.workers[0].memory.name, "swap");
        assert_eq!(
            cfg.cluster.workers[0].memory.preemption().unwrap(),
            crate::memory::PreemptionPolicy::Swap
        );
        assert_eq!(cfg.cluster.workers[1].memory.name, "prefix_cache");
        assert_eq!(cfg.cluster.workers[2].memory.name, "token_contiguous");
    }

    #[test]
    fn unknown_memory_manager_is_a_parse_error() {
        let yaml = "model: tiny\ncluster:\n  workers:\n    - hardware: A100\n      memory:\n        manager: infinite\nworkload:\n  num_requests: 1\n  qps: 1.0\n  prompt_len:\n    fixed: 8\n  output_len:\n    fixed: 8\n";
        let err = SimulationConfig::from_yaml_str(yaml).unwrap_err();
        assert!(format!("{err:#}").contains("unknown memory manager"));
        let typo = yaml.replace("manager: infinite", "block_sze: 16");
        let err = SimulationConfig::from_yaml_str(&typo).unwrap_err();
        assert!(format!("{err:#}").contains("unknown parameter"));
    }

    #[test]
    fn unknown_scheduler_policy_is_a_parse_error() {
        let yaml = "model: tiny\ncluster:\n  workers:\n    - hardware: A100\n      local_scheduler:\n        policy: warp\nworkload:\n  num_requests: 1\n  qps: 1.0\n  prompt_len:\n    fixed: 8\n  output_len:\n    fixed: 8\n";
        let err = SimulationConfig::from_yaml_str(yaml).unwrap_err();
        assert!(format!("{err:#}").contains("unknown local scheduler policy"));
    }

    #[test]
    fn workload_generators_selectable_from_yaml() {
        let yaml = r#"
model: tiny
cluster:
  workers:
    - hardware: A100
workload:
  generator: bursty
  num_requests: 40
  qps: 20.0
  off_qps: 2.0
  on_s: 5.0
  off_s: 5.0
  seed: 3
"#;
        let cfg = SimulationConfig::from_yaml_str(yaml).unwrap();
        assert_eq!(cfg.workload.name, "bursty");
        assert_eq!(cfg.workload.generate().unwrap().len(), 40);
        let mt = yaml.replace(
            "  generator: bursty\n  num_requests: 40\n  qps: 20.0\n  off_qps: 2.0\n  on_s: 5.0\n  off_s: 5.0\n  seed: 3\n",
            "  generator: multi_tenant\n  tenants:\n    - name: chat\n      num_requests: 10\n      qps: 4.0\n      ttft: 2.0\n    - name: batch\n      num_requests: 5\n      qps: 1.0\n",
        );
        let cfg = SimulationConfig::from_yaml_str(&mt).unwrap();
        assert_eq!(cfg.workload.name, "multi_tenant");
        let reqs = cfg.workload.generate().unwrap();
        assert_eq!(reqs.len(), 15);
        assert!(reqs.iter().all(|r| r.tenant.is_some()));
    }

    #[test]
    fn unknown_workload_generator_is_a_parse_error() {
        let yaml = "model: tiny\ncluster:\n  workers:\n    - hardware: A100\nworkload:\n  generator: infinite\n  num_requests: 1\n  qps: 1.0\n";
        let err = SimulationConfig::from_yaml_str(yaml).unwrap_err();
        assert!(format!("{err:#}").contains("unknown workload generator"));
    }

    #[test]
    fn inverted_uniform_bounds_are_a_parse_error() {
        // regression: this used to parse fine and panic inside
        // `sample()` mid-simulation
        let yaml = "model: tiny\ncluster:\n  workers:\n    - hardware: A100\nworkload:\n  num_requests: 1\n  qps: 1.0\n  prompt_len:\n    uniform:\n      min: 9\n      max: 3\n  output_len:\n    fixed: 8\n";
        let err = SimulationConfig::from_yaml_str(yaml).unwrap_err();
        assert!(format!("{err:#}").contains("min (9) > max (3)"), "{err:#}");
    }

    #[test]
    fn slo_and_pool_sections() {
        let yaml = "model: tiny\ncluster:\n  workers:\n    - hardware: A100\nworkload:\n  num_requests: 10\n  qps: 1.0\n  prompt_len:\n    fixed: 8\n  output_len:\n    fixed: 8\nslo:\n  ttft: 10.0\n  mtpot: 0.25\npool_cache:\n  capacity_blocks: 5000\nsample_period: 0.5\ncost_model: table\n";
        let cfg = SimulationConfig::from_yaml_str(yaml).unwrap();
        assert_eq!(cfg.slo.ttft, Some(10.0));
        assert_eq!(cfg.slo.mtpot, Some(0.25));
        assert_eq!(cfg.pool_cache.unwrap().capacity_blocks, 5000);
        assert_eq!(cfg.sample_period, 0.5);
        assert_eq!(cfg.compute, ComputeSpec::new("table"));
    }

    #[test]
    fn engine_section_controls_fast_forward() {
        let base = "model: tiny\ncluster:\n  workers:\n    - hardware: A100\nworkload:\n  num_requests: 1\n  qps: 1.0\n  prompt_len:\n    fixed: 8\n  output_len:\n    fixed: 8\n";
        // absent section: fast-forwarding is on by default
        let cfg = SimulationConfig::from_yaml_str(base).unwrap();
        assert!(cfg.engine.fast_forward);
        assert_eq!(cfg.engine, EngineConfig::default());
        // explicit off switch
        let off = format!("{base}engine:\n  fast_forward: false\n");
        let cfg = SimulationConfig::from_yaml_str(&off).unwrap();
        assert!(!cfg.engine.fast_forward);
        // explicit on
        let on = format!("{base}engine:\n  fast_forward: true\n");
        assert!(SimulationConfig::from_yaml_str(&on).unwrap().engine.fast_forward);
    }

    #[test]
    fn engine_section_controls_window_cost() {
        let base = "model: tiny\ncluster:\n  workers:\n    - hardware: A100\nworkload:\n  num_requests: 1\n  qps: 1.0\n  prompt_len:\n    fixed: 8\n  output_len:\n    fixed: 8\n";
        // absent: bit-exact replay
        let cfg = SimulationConfig::from_yaml_str(base).unwrap();
        assert_eq!(cfg.engine.window_cost, WindowCost::Replay);
        let affine = format!("{base}engine:\n  window_cost: affine\n");
        let cfg = SimulationConfig::from_yaml_str(&affine).unwrap();
        assert_eq!(cfg.engine.window_cost, WindowCost::Affine);
        assert!(cfg.engine.fast_forward, "other engine keys keep defaults");
        // malformed values fail at parse time, not mid-simulation
        let bad = format!("{base}engine:\n  window_cost: oracle\n");
        let err = SimulationConfig::from_yaml_str(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("unknown window_cost"), "{err:#}");
        let worse = format!("{base}engine:\n  window_cost: 3\n");
        let err = SimulationConfig::from_yaml_str(&worse).unwrap_err();
        assert!(format!("{err:#}").contains("must be a string"), "{err:#}");
    }

    #[test]
    fn compute_section_and_per_worker_overrides() {
        let yaml = r#"
model: tiny
cluster:
  workers:
    - hardware: A100
      compute:
        model: table
        base: analytic
    - hardware: V100
      compute:
        model: roofline
workload:
  num_requests: 10
  qps: 1.0
  prompt_len:
    fixed: 8
  output_len:
    fixed: 8
compute:
  model: analytic
"#;
        let cfg = SimulationConfig::from_yaml_str(yaml).unwrap();
        assert_eq!(cfg.compute.name, "analytic");
        let w0 = cfg.cluster.workers[0].compute.as_ref().unwrap();
        assert_eq!(w0.name, "table");
        assert_eq!(w0.params.get("base").and_then(Yaml::as_str), Some("analytic"));
        assert_eq!(cfg.cluster.workers[1].compute.as_ref().unwrap().name, "roofline");
    }

    #[test]
    fn unknown_compute_model_is_a_parse_error() {
        let yaml = "model: tiny\ncluster:\n  workers:\n    - hardware: A100\nworkload:\n  num_requests: 1\n  qps: 1.0\n  prompt_len:\n    fixed: 8\n  output_len:\n    fixed: 8\ncompute:\n  model: quantum\n";
        let err = SimulationConfig::from_yaml_str(yaml).unwrap_err();
        assert!(format!("{err:#}").contains("unknown compute model"), "{err:#}");
        // legacy scalar key routes through the same registry
        let legacy = yaml.replace("compute:\n  model: quantum", "cost_model: quantum");
        let err = SimulationConfig::from_yaml_str(&legacy).unwrap_err();
        assert!(format!("{err:#}").contains("unknown compute model"), "{err:#}");
        // typo'd parameter keys are parse errors too
        let typo = yaml.replace("model: quantum", "model: table\n  bse: analytic");
        let err = SimulationConfig::from_yaml_str(&typo).unwrap_err();
        assert!(format!("{err:#}").contains("unknown parameter 'bse'"), "{err:#}");
    }

    #[test]
    fn metrics_section_parses_modes_and_rejects_bad_error_bounds() {
        use crate::metrics::MetricsMode;
        let base = "model: tiny\ncluster:\n  workers:\n    - hardware: A100\nworkload:\n  num_requests: 1\n  qps: 1.0\n  prompt_len:\n    fixed: 8\n  output_len:\n    fixed: 8\n";

        // absent section: exact mode, default error bound
        let cfg = SimulationConfig::from_yaml_str(base).unwrap();
        assert_eq!(cfg.metrics, MetricsConfig::default());
        assert_eq!(cfg.metrics.mode, MetricsMode::Exact);
        assert_eq!(cfg.metrics.sketch_error, 0.01);

        // explicit sketch mode with a custom bound
        let yaml = format!("{base}metrics:\n  mode: sketch\n  sketch_error: 0.02\n");
        let cfg = SimulationConfig::from_yaml_str(&yaml).unwrap();
        assert_eq!(cfg.metrics.mode, MetricsMode::Sketch);
        assert_eq!(cfg.metrics.sketch_error, 0.02);

        // mode alone: keeps the default bound
        let yaml = format!("{base}metrics:\n  mode: exact\n");
        let cfg = SimulationConfig::from_yaml_str(&yaml).unwrap();
        assert_eq!(cfg.metrics.mode, MetricsMode::Exact);
        assert_eq!(cfg.metrics.sketch_error, 0.01);

        // unknown mode and out-of-range bounds are parse errors
        let yaml = format!("{base}metrics:\n  mode: approximate\n");
        let err = SimulationConfig::from_yaml_str(&yaml).unwrap_err();
        assert!(format!("{err:#}").contains("approximate"), "{err:#}");
        for bad in ["0.0", "0.5", "-0.1"] {
            let yaml = format!("{base}metrics:\n  mode: sketch\n  sketch_error: {bad}\n");
            let err = SimulationConfig::from_yaml_str(&yaml).unwrap_err();
            assert!(format!("{err:#}").contains("sketch_error"), "{bad}: {err:#}");
        }
    }
}
