//! Artifact manifest parsing (`artifacts/manifest.json`).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::util::json::Json;

/// Version this runtime understands; bumped together with `aot.py`.
pub const SUPPORTED_VERSION: u64 = 3;

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    pub sha256: String,
    pub chars: u64,
}

/// `manifest.json` written by `python -m compile.aot`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u64,
    pub batch_slots: usize,
    pub model_dim: usize,
    pub hw_dim: usize,
    pub num_ops: usize,
    pub op_names: Vec<String>,
    pub artifacts: HashMap<String, ArtifactEntry>,
    pub jax_version: String,
}

impl Manifest {
    /// Load and validate the manifest from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let req_u = |k: &str| -> Result<u64> {
            j.req(k)?.as_u64().with_context(|| format!("'{k}' must be a number"))
        };
        let mut artifacts = HashMap::new();
        for (name, entry) in j
            .req("artifacts")?
            .as_obj()
            .context("'artifacts' must be an object")?
        {
            artifacts.insert(
                name.clone(),
                ArtifactEntry {
                    file: entry.req("file")?.as_str().context("file")?.to_string(),
                    sha256: entry
                        .get("sha256")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                    chars: entry.get("chars").and_then(Json::as_u64).unwrap_or(0),
                },
            );
        }
        let m = Manifest {
            version: req_u("version")?,
            batch_slots: req_u("batch_slots")? as usize,
            model_dim: req_u("model_dim")? as usize,
            hw_dim: req_u("hw_dim")? as usize,
            num_ops: req_u("num_ops")? as usize,
            op_names: j
                .req("op_names")?
                .as_arr()
                .context("'op_names' must be a list")?
                .iter()
                .filter_map(|v| v.as_str().map(String::from))
                .collect(),
            artifacts,
            jax_version: j
                .get("jax_version")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
        };

        ensure!(
            m.version == SUPPORTED_VERSION,
            "artifact version {} != supported {} — re-run `make artifacts`",
            m.version,
            SUPPORTED_VERSION
        );
        ensure!(m.num_ops == crate::compute::NUM_OPS, "op-table width mismatch");
        ensure!(m.model_dim == 8 && m.hw_dim == 6, "parameter vector mismatch");
        for (name, entry) in &m.artifacts {
            ensure!(
                dir.as_ref().join(&entry.file).exists(),
                "artifact {name} file {} missing",
                entry.file
            );
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;
    use std::io::Write;

    fn write_manifest(dir: &Path, version: u64) {
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        write!(
            f,
            r#"{{"version": {version}, "batch_slots": 64, "model_dim": 8,
                "hw_dim": 6, "num_ops": 10,
                "op_names": ["a","b","c","d","e","f","g","h","i","j"],
                "artifacts": {{"iter_cost": {{"file": "iter_cost.hlo.txt",
                 "sha256": "x", "chars": 1}}}}}}"#
        )
        .unwrap();
        std::fs::write(dir.join("iter_cost.hlo.txt"), "HloModule x").unwrap();
    }

    #[test]
    fn loads_valid_manifest() {
        let dir = TempDir::new().unwrap();
        write_manifest(dir.path(), SUPPORTED_VERSION);
        let m = Manifest::load(dir.path()).unwrap();
        assert_eq!(m.batch_slots, 64);
        assert!(m.artifacts.contains_key("iter_cost"));
        assert_eq!(m.op_names.len(), 10);
    }

    #[test]
    fn rejects_version_mismatch() {
        let dir = TempDir::new().unwrap();
        write_manifest(dir.path(), SUPPORTED_VERSION + 1);
        assert!(Manifest::load(dir.path()).is_err());
    }

    #[test]
    fn rejects_missing_file() {
        let dir = TempDir::new().unwrap();
        write_manifest(dir.path(), SUPPORTED_VERSION);
        std::fs::remove_file(dir.path().join("iter_cost.hlo.txt")).unwrap();
        assert!(Manifest::load(dir.path()).is_err());
    }
}
