//! PJRT runtime: load and execute the AOT-compiled cost artifacts.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU PJRT plugin):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. HLO **text** is the interchange format —
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that this XLA
//! rejects; the text parser reassigns ids (see `python/compile/aot.py`).
//!
//! The client is process-wide and created lazily; artifacts compile once
//! and are reusable for the whole simulation (Python never runs on the
//! request path).

mod artifacts;

pub use artifacts::{ArtifactEntry, Manifest};

use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{Context, Result};

thread_local! {
    static CLIENT: RefCell<Option<Rc<xla::PjRtClient>>> = const { RefCell::new(None) };
    /// Compiled-artifact cache: HLO parsing + PJRT compilation cost
    /// hundreds of ms, and simulations (SLO sweeps!) are constructed
    /// far more often than artifacts change.
    static ARTIFACTS: RefCell<std::collections::HashMap<PathBuf, Rc<CompiledArtifact>>> =
        RefCell::new(std::collections::HashMap::new());
}

/// Get (or lazily create) the thread's PJRT CPU client.
pub fn cpu_client() -> Result<Rc<xla::PjRtClient>> {
    CLIENT.with(|c| {
        let mut slot = c.borrow_mut();
        if slot.is_none() {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            *slot = Some(Rc::new(client));
        }
        Ok(slot.as_ref().unwrap().clone())
    })
}

/// A compiled HLO artifact ready for repeated execution.
pub struct CompiledArtifact {
    exe: xla::PjRtLoadedExecutable,
    path: PathBuf,
}

impl CompiledArtifact {
    /// Load (or fetch from the process-wide cache) a compiled artifact.
    pub fn load_cached(path: impl AsRef<Path>) -> Result<Rc<Self>> {
        let key = path.as_ref().to_path_buf();
        ARTIFACTS.with(|cache| {
            if let Some(hit) = cache.borrow().get(&key) {
                return Ok(hit.clone());
            }
            let compiled = Rc::new(Self::load(&key)?);
            cache.borrow_mut().insert(key, compiled.clone());
            Ok(compiled)
        })
    }

    /// Load HLO text from `path` and compile it on the CPU client.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let client = cpu_client()?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Self { exe, path })
    }

    /// Execute with f32 vector inputs; returns the flat f32 output.
    ///
    /// Artifacts are lowered with `return_tuple=True` and a single flat
    /// output vector, so the result is a 1-tuple we unwrap here.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| xla::Literal::vec1(v))
            .collect();
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.path.display()))?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1().context("unwrapping 1-tuple output")?;
        Ok(out.to_vec::<f32>()?)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Locate the artifacts directory: explicit argument, `$TOKENSIM_ARTIFACTS`,
/// or `artifacts/` relative to the crate root / current directory.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("TOKENSIM_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let manifest_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if manifest_dir.join("manifest.json").exists() {
        return manifest_dir;
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> Option<PathBuf> {
        let dir = default_artifacts_dir();
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn load_and_run_xfer_artifact() {
        let Some(dir) = artifacts_ready() else {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let art = CompiledArtifact::load(dir.join("xfer_cost.hlo.txt")).unwrap();
        let slots = manifest.batch_slots;
        let mut sizes = vec![0.0f32; slots];
        sizes[0] = 1e9; // 1 GB over a 1 GB/s link with zero latency
        let link = [1e9f32, 0.0, 1.0];
        let out = art.run_f32(&[&sizes, &link]).unwrap();
        assert_eq!(out.len(), 2 + slots);
        assert!((out[0] - 1.0).abs() < 1e-5, "t_seq={}", out[0]);
        assert!((out[1] - 1.0).abs() < 1e-5, "t_ovl={}", out[1]);
        assert!((out[2] - 1.0).abs() < 1e-5, "per_block[0]={}", out[2]);
    }

    #[test]
    fn artifact_reuse_many_executions() {
        let Some(dir) = artifacts_ready() else {
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let art = CompiledArtifact::load(dir.join("xfer_cost.hlo.txt")).unwrap();
        let sizes = vec![1024.0f32; manifest.batch_slots];
        let link = [64e9f32, 1e-5, 4.0];
        let first = art.run_f32(&[&sizes, &link]).unwrap();
        for _ in 0..10 {
            let again = art.run_f32(&[&sizes, &link]).unwrap();
            assert_eq!(first, again, "execution must be deterministic");
        }
    }
}
