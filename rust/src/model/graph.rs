//! Operator-graph view of a model with operator-level breakpoints.
//!
//! The paper's model config (Fig 2c) describes the decoder block as a
//! list of operators, each of which may carry *breakpoint* hooks
//! (`on_first_fin: put_kv()`, `on_st: get_kv()`, …) that invoke the
//! scheduler at operator granularity. The iteration *timing* comes from
//! the L2 cost artifact; this graph drives the hook/bookkeeping side:
//! which ops exist, where KV movement attaches, and where the default
//! end-of-iteration breakpoint sits.


use super::ModelSpec;

/// Operator kinds, mirroring `OP_NAMES` in the L1/L2 python layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    Embed,
    QkvGemm,
    Attention,
    Softmax,
    OutGemm,
    MlpUp,
    MlpDown,
    LayerNorm,
    AllReduce,
    Logits,
}

impl OpKind {
    /// Index in the `op_times` output of the cost artifact.
    pub fn artifact_index(self) -> usize {
        match self {
            OpKind::Embed => 0,
            OpKind::QkvGemm => 1,
            OpKind::Attention => 2,
            OpKind::Softmax => 3,
            OpKind::OutGemm => 4,
            OpKind::MlpUp => 5,
            OpKind::MlpDown => 6,
            OpKind::LayerNorm => 7,
            OpKind::AllReduce => 8,
            OpKind::Logits => 9,
        }
    }

    pub const ALL: [OpKind; 10] = [
        OpKind::Embed,
        OpKind::QkvGemm,
        OpKind::Attention,
        OpKind::Softmax,
        OpKind::OutGemm,
        OpKind::MlpUp,
        OpKind::MlpDown,
        OpKind::LayerNorm,
        OpKind::AllReduce,
        OpKind::Logits,
    ];

    /// Does this op run once per iteration (vs once per layer)?
    pub fn per_iteration(self) -> bool {
        matches!(self, OpKind::Embed | OpKind::Logits)
    }
}

/// Actions a breakpoint can trigger, the two-line disaggregation idiom of
/// the paper's §III-A being `PutKv` on the prefill side and `GetKv` on
/// the decode side.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakpointAction {
    /// Return the request to the global scheduler.
    SubmitGlobal,
    /// Export the request's KV cache (prefill side of disaggregation).
    PutKv,
    /// Import the request's KV cache before running (decode side).
    GetKv,
    /// Invoke the local scheduler (default end-of-iteration hook).
    InvokeLocal,
}

/// A breakpoint attached to an operator.
#[derive(Debug, Clone, PartialEq)]
pub struct Breakpoint {
    pub op: OpKind,
    /// Fire only when the op instance completes the *first* token/prefill
    /// (`on_first_fin` in the config) rather than on every iteration.
    pub first_finish_only: bool,
    pub action: BreakpointAction,
}

/// One operator node in the per-layer graph.
#[derive(Debug, Clone, PartialEq)]
pub struct OpNode {
    pub name: String,
    pub kind: OpKind,
    /// GEMM-style dims for documentation/validation (rows unknown at
    /// config time are encoded as 0).
    pub dims: Vec<u64>,
}

/// The operator graph of a model plus its breakpoints.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelGraph {
    pub ops: Vec<OpNode>,
    pub breakpoints: Vec<Breakpoint>,
}

impl ModelGraph {
    /// Standard decoder-block graph with the default end-of-iteration
    /// breakpoint (scheduler invoked after each token generation).
    pub fn standard(spec: &ModelSpec) -> Self {
        let h = spec.hidden as u64;
        let g = (spec.hidden * spec.kv_heads / spec.heads) as u64;
        let ffn = spec.ffn as u64;
        let ops = vec![
            OpNode { name: "embed".into(), kind: OpKind::Embed, dims: vec![spec.vocab as u64, h] },
            OpNode { name: "layer_norm".into(), kind: OpKind::LayerNorm, dims: vec![h] },
            OpNode { name: "qkv_gemm".into(), kind: OpKind::QkvGemm, dims: vec![h, h + 2 * g] },
            OpNode { name: "self_attn".into(), kind: OpKind::Attention, dims: vec![h] },
            OpNode { name: "softmax".into(), kind: OpKind::Softmax, dims: vec![spec.heads as u64] },
            OpNode { name: "out_gemm".into(), kind: OpKind::OutGemm, dims: vec![h, h] },
            OpNode { name: "mlp_up".into(), kind: OpKind::MlpUp, dims: vec![h, 2 * ffn] },
            OpNode { name: "mlp_down".into(), kind: OpKind::MlpDown, dims: vec![ffn, h] },
            OpNode { name: "all_reduce".into(), kind: OpKind::AllReduce, dims: vec![h] },
            OpNode { name: "logits".into(), kind: OpKind::Logits, dims: vec![h, spec.vocab as u64] },
        ];
        let breakpoints = vec![Breakpoint {
            op: OpKind::Logits,
            first_finish_only: false,
            action: BreakpointAction::InvokeLocal,
        }];
        Self { ops, breakpoints }
    }

    /// The disaggregation idiom: prefill workers export KV when the
    /// first token finishes; decode workers import KV before attention.
    pub fn with_disaggregation(spec: &ModelSpec) -> Self {
        let mut g = Self::standard(spec);
        g.breakpoints.push(Breakpoint {
            op: OpKind::Logits,
            first_finish_only: true,
            action: BreakpointAction::PutKv,
        });
        g.breakpoints.push(Breakpoint {
            op: OpKind::Attention,
            first_finish_only: true,
            action: BreakpointAction::GetKv,
        });
        g
    }

    /// Does any breakpoint request KV export (prefill→decode hand-off)?
    pub fn exports_kv(&self) -> bool {
        self.breakpoints
            .iter()
            .any(|b| b.action == BreakpointAction::PutKv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_graph_covers_all_op_kinds() {
        let g = ModelGraph::standard(&ModelSpec::llama2_7b());
        for kind in OpKind::ALL {
            assert!(
                g.ops.iter().any(|o| o.kind == kind),
                "missing op kind {kind:?}"
            );
        }
    }

    #[test]
    fn artifact_indices_are_dense_and_unique() {
        let mut seen = [false; 10];
        for k in OpKind::ALL {
            let i = k.artifact_index();
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn disaggregation_adds_two_breakpoints() {
        let spec = ModelSpec::llama2_7b();
        let std = ModelGraph::standard(&spec);
        let dis = ModelGraph::with_disaggregation(&spec);
        assert_eq!(dis.breakpoints.len(), std.breakpoints.len() + 2);
        assert!(dis.exports_kv());
        assert!(!std.exports_kv());
    }

    #[test]
    fn per_iteration_flags() {
        assert!(OpKind::Embed.per_iteration());
        assert!(OpKind::Logits.per_iteration());
        assert!(!OpKind::Attention.per_iteration());
        assert!(!OpKind::MlpUp.per_iteration());
    }
}
