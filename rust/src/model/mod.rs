//! Transformer model descriptions: parameter counts, KV-cache sizes, and
//! the operator-graph view (with breakpoints) the config system exposes.

mod graph;

pub use graph::{Breakpoint, BreakpointAction, ModelGraph, OpKind, OpNode};


/// Architecture description of a decoder-only transformer.
///
/// Mirrors the `MODEL_DIM` parameter vector consumed by the L2 cost
/// artifact (see `python/compile/kernels/ref.py`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub hidden: u32,
    pub layers: u32,
    pub heads: u32,
    pub kv_heads: u32,
    /// MLP intermediate size (gate/up width for LLaMA-style MLPs).
    pub ffn: u32,
    pub vocab: u32,
    /// Bytes per parameter / activation element (2 = fp16/bf16).
    pub dtype_bytes: u32,
    /// Tensor-parallel degree the model is served with.
    pub tp: u32,
}

impl ModelSpec {
    /// LLaMA2-7B — the paper's main validation model.
    pub fn llama2_7b() -> Self {
        Self {
            name: "llama2-7b".into(),
            hidden: 4096,
            layers: 32,
            heads: 32,
            kv_heads: 32,
            ffn: 11008,
            vocab: 32000,
            dtype_bytes: 2,
            tp: 1,
        }
    }

    /// OPT-13B — the second model of Fig 11.
    ///
    /// OPT uses an ungated 2-matrix MLP (up 4h, down 4h); the cost model
    /// assumes a LLaMA-style gated 3-matrix MLP, so we encode the
    /// FLOP/parameter-equivalent gated width `8h/3` (total MLP weights
    /// 3*h*ffn = 8h^2, matching OPT's 2*(h*4h)).
    pub fn opt_13b() -> Self {
        Self {
            name: "opt-13b".into(),
            hidden: 5120,
            layers: 40,
            heads: 40,
            kv_heads: 40,
            ffn: 8 * 5120 / 3,
            vocab: 50272,
            dtype_bytes: 2,
            tp: 1,
        }
    }

    /// LLaMA2-13B (used by extension studies).
    pub fn llama2_13b() -> Self {
        Self {
            name: "llama2-13b".into(),
            hidden: 5120,
            layers: 40,
            heads: 40,
            kv_heads: 40,
            ffn: 13824,
            vocab: 32000,
            dtype_bytes: 2,
            tp: 1,
        }
    }

    /// A tiny model for fast tests.
    pub fn tiny_test() -> Self {
        Self {
            name: "tiny".into(),
            hidden: 256,
            layers: 4,
            heads: 8,
            kv_heads: 8,
            ffn: 1024,
            vocab: 1000,
            dtype_bytes: 2,
            tp: 1,
        }
    }

    /// Look a preset up by name (config files / CLI).
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "llama2-7b" => Some(Self::llama2_7b()),
            "llama2-13b" => Some(Self::llama2_13b()),
            "opt-13b" => Some(Self::opt_13b()),
            "tiny" => Some(Self::tiny_test()),
            _ => None,
        }
    }

    /// Total parameter count (embedding + per-layer weights + LM head).
    pub fn param_count(&self) -> u64 {
        let h = self.hidden as u64;
        let g = self.kv_heads as u64;
        let heads = self.heads as u64;
        let h_kv = h * g / heads;
        let ffn = self.ffn as u64;
        let per_layer = h * (h + 2 * h_kv)   // qkv
            + h * h                           // out proj
            + 3 * h * ffn                     // gate/up/down (llama mlp)
            + 2 * h; // norms
        (self.vocab as u64) * h * 2 + (self.layers as u64) * per_layer
    }

    /// Bytes of weights resident on each TP shard.
    pub fn weight_bytes_per_shard(&self) -> u64 {
        self.param_count() * self.dtype_bytes as u64 / self.tp as u64
    }

    /// KV-cache bytes per token per TP shard (all layers, K and V).
    pub fn kv_bytes_per_token(&self) -> u64 {
        let h_kv = self.hidden as u64 * self.kv_heads as u64 / self.heads as u64;
        2 * h_kv * self.layers as u64 * self.dtype_bytes as u64 / self.tp as u64
    }

    /// The float32 parameter vector consumed by the HLO cost artifact.
    pub fn to_vec(&self) -> [f32; 8] {
        [
            self.hidden as f32,
            self.layers as f32,
            self.heads as f32,
            self.kv_heads as f32,
            self.ffn as f32,
            self.vocab as f32,
            self.dtype_bytes as f32,
            self.tp as f32,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama2_7b_params_about_7b() {
        let p = ModelSpec::llama2_7b().param_count() as f64;
        assert!((6.0e9..8.0e9).contains(&p), "param_count={p}");
    }

    #[test]
    fn opt_13b_params_about_13b() {
        let p = ModelSpec::opt_13b().param_count() as f64;
        assert!((11.5e9..14.5e9).contains(&p), "param_count={p}");
    }

    #[test]
    fn llama2_7b_kv_bytes() {
        // 2 (K,V) * 4096 * 32 layers * 2 bytes = 512 KiB per token
        assert_eq!(ModelSpec::llama2_7b().kv_bytes_per_token(), 524_288);
    }

    #[test]
    fn tp_splits_weights_and_kv() {
        let mut m = ModelSpec::llama2_7b();
        let w1 = m.weight_bytes_per_shard();
        let k1 = m.kv_bytes_per_token();
        m.tp = 4;
        assert_eq!(m.weight_bytes_per_shard(), w1 / 4);
        assert_eq!(m.kv_bytes_per_token(), k1 / 4);
    }

    #[test]
    fn presets_by_name() {
        assert!(ModelSpec::by_name("llama2-7b").is_some());
        assert!(ModelSpec::by_name("opt-13b").is_some());
        assert!(ModelSpec::by_name("nope").is_none());
    }

    #[test]
    fn vector_layout_matches_manifest() {
        let v = ModelSpec::llama2_7b().to_vec();
        assert_eq!(v[0], 4096.0);
        assert_eq!(v[1], 32.0);
        assert_eq!(v[6], 2.0);
        assert_eq!(v[7], 1.0);
    }
}
