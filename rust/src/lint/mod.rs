//! Static config analysis (`tokensim lint`) and the shared diagnostic
//! vocabulary of the engine's `audit` sanitizer mode.
//!
//! The four registries (scheduler, memory, workload, compute) plus the
//! engine/metrics mode switches span a configuration cross-product far
//! larger than what per-section YAML validation can police: a config
//! can parse cleanly and still be guaranteed to deadlock (a prompt that
//! never fits the KV pool), silently never engage a feature (a chunked
//! prefill whose chunk exceeds every prompt), or report numbers that
//! cannot mean what they claim (an SLO below the compute model's
//! physical per-iteration floor). [`lint_file`] cross-validates a
//! [`SimulationConfig`] against the registries *without running it* and
//! reports typed diagnostics; docs/LINTS.md is the rule catalog.
//!
//! The same vocabulary names the engine's runtime conservation checks
//! (`engine: audit: true` / `tokensim run --audit`): each violated
//! invariant surfaces as an `anyhow` error carrying an
//! [`AuditViolation`] with an `A…` code from [`AUDIT_CHECKS`].
//!
//! Out-of-tree subsystems register their own rules with
//! [`register_lint_rule`], mirroring the registries' `register_*`
//! hooks.

pub mod analyze;
mod rules;

use std::fmt;
use std::sync::{Mutex, OnceLock};

use anyhow::Context;

use crate::config::yaml::Yaml;
use crate::config::SimulationConfig;
use crate::request::Request;
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Diagnostics
// ---------------------------------------------------------------------------

/// Diagnostic severity. `Error` fails `tokensim lint`; `Warn` fails
/// under `--deny-warnings`; `Info` never fails.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warn,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Error => "error",
        })
    }
}

/// One typed finding: a stable code (see docs/LINTS.md), a severity, a
/// message naming the offending section/value, and an optional fix.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    pub code: String,
    pub severity: Severity,
    pub message: String,
    pub fix: Option<String>,
}

impl Diagnostic {
    pub fn new(code: &str, severity: Severity, message: impl Into<String>) -> Self {
        Self {
            code: code.to_string(),
            severity,
            message: message.into(),
            fix: None,
        }
    }

    pub fn error(code: &str, message: impl Into<String>) -> Self {
        Self::new(code, Severity::Error, message)
    }

    pub fn warn(code: &str, message: impl Into<String>) -> Self {
        Self::new(code, Severity::Warn, message)
    }

    pub fn info(code: &str, message: impl Into<String>) -> Self {
        Self::new(code, Severity::Info, message)
    }

    pub fn with_fix(mut self, fix: impl Into<String>) -> Self {
        self.fix = Some(fix.into());
        self
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("code", Json::str(self.code.clone())),
            ("severity", Json::str(self.severity.to_string())),
            ("message", Json::str(self.message.clone())),
        ];
        if let Some(fix) = &self.fix {
            pairs.push(("fix", Json::str(fix.clone())));
        }
        Json::obj(pairs)
    }
}

/// A violated engine invariant (`engine: audit: true`), carried inside
/// the `anyhow` error chain so callers can downcast for the structured
/// code instead of string-matching the rendered message.
#[derive(Debug, Clone)]
pub struct AuditViolation {
    /// An `A…` code from [`AUDIT_CHECKS`].
    pub code: &'static str,
    pub message: String,
}

impl AuditViolation {
    pub fn new(code: &'static str, message: impl Into<String>) -> Self {
        Self {
            code,
            message: message.into(),
        }
    }

    /// Shorthand for `Err(anyhow::Error::new(AuditViolation::new(..)))`.
    pub fn err<T>(code: &'static str, message: impl Into<String>) -> anyhow::Result<T> {
        Err(anyhow::Error::new(Self::new(code, message)))
    }
}

impl fmt::Display for AuditViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "audit violation [{}]: {}", self.code, self.message)
    }
}

impl std::error::Error for AuditViolation {}

// ---------------------------------------------------------------------------
// Rule catalog
// ---------------------------------------------------------------------------

/// Catalog entry: stable code, fixed severity, one-line summary (shown
/// by `tokensim list`; docs/LINTS.md carries the rationale + fixes).
#[derive(Debug, Clone, Copy)]
pub struct RuleInfo {
    pub code: &'static str,
    pub severity: Severity,
    pub summary: &'static str,
}

/// The built-in lint rules, in code order.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        code: "E001",
        severity: Severity::Error,
        summary: "config does not parse/build (YAML syntax, unknown preset, malformed value)",
    },
    RuleInfo {
        code: "E010",
        severity: Severity::Error,
        summary: "unknown scheduler policy (local or global), with did-you-mean",
    },
    RuleInfo {
        code: "E011",
        severity: Severity::Error,
        summary: "unknown memory manager, with did-you-mean",
    },
    RuleInfo {
        code: "E012",
        severity: Severity::Error,
        summary: "unknown workload generator, with did-you-mean",
    },
    RuleInfo {
        code: "E013",
        severity: Severity::Error,
        summary: "unknown compute model, with did-you-mean",
    },
    RuleInfo {
        code: "E014",
        severity: Severity::Error,
        summary: "unknown parameter key for a registry entry or engine/metrics section",
    },
    RuleInfo {
        code: "E020",
        severity: Severity::Error,
        summary: "table/memo compute layer over an incompatible base model",
    },
    RuleInfo {
        code: "E030",
        severity: Severity::Error,
        summary: "worst-case request KV cannot fit any decode-capable worker's pool (deadlock)",
    },
    RuleInfo {
        code: "E031",
        severity: Severity::Error,
        summary: "worst-case prompt exceeds every prefill worker's batch-token cap (deadlock)",
    },
    RuleInfo {
        code: "W032",
        severity: Severity::Warn,
        summary: "chunked-prefill chunk size >= largest prompt: chunking never engages",
    },
    RuleInfo {
        code: "E033",
        severity: Severity::Error,
        summary: "swap manager that can never swap (zero swap space or dead host link)",
    },
    RuleInfo {
        code: "W040",
        severity: Severity::Warn,
        summary: "window_cost: affine but no worker's compute model is affine-capable",
    },
    RuleInfo {
        code: "W041",
        severity: Severity::Warn,
        summary: "window_cost: affine with fast_forward: off is never consulted",
    },
    RuleInfo {
        code: "I042",
        severity: Severity::Info,
        summary: "sketch-mode metrics: quantiles are approximate, byte-diff gates do not apply",
    },
    RuleInfo {
        code: "E050",
        severity: Severity::Error,
        summary: "SLO target below the compute model's per-iteration floor (unattainable)",
    },
    RuleInfo {
        code: "E060",
        severity: Severity::Error,
        summary: "unknown network topology, with did-you-mean",
    },
    RuleInfo {
        code: "E061",
        severity: Severity::Error,
        summary: "unknown link preset in a network parameter, with did-you-mean",
    },
    RuleInfo {
        code: "W062",
        severity: Severity::Warn,
        summary: "network topology shape vs worker count: inter-group link never exercised",
    },
    RuleInfo {
        code: "E070",
        severity: Severity::Error,
        summary: "infeasible by construction: >=10% of requests provably exceed the SLO window",
    },
    RuleInfo {
        code: "W071",
        severity: Severity::Warn,
        summary: "compute saturation: utilization above 0.9 with a provable SLO overrun",
    },
    RuleInfo {
        code: "W072",
        severity: Severity::Warn,
        summary: "network saturation: a topology link asked to carry over 90% of its bandwidth",
    },
    RuleInfo {
        code: "W073",
        severity: Severity::Warn,
        summary: "memory infeasibility: expected concurrent KV residency exceeds the pool",
    },
    RuleInfo {
        code: "I074",
        severity: Severity::Info,
        summary: "static bound summary from tokensim analyze (command path only)",
    },
];

/// The engine's audit-mode invariants (`engine: audit: true`), named
/// with the same code scheme so `tokensim list` shows one vocabulary.
pub const AUDIT_CHECKS: &[RuleInfo] = &[
    RuleInfo {
        code: "A001",
        severity: Severity::Error,
        summary: "token conservation: generated == output_len and stamps monotone at finish",
    },
    RuleInfo {
        code: "A002",
        severity: Severity::Error,
        summary: "block/byte accounting: allocator self-consistent, empty at drain",
    },
    RuleInfo {
        code: "A003",
        severity: Severity::Error,
        summary: "event-time monotonicity: no event pops earlier than the clock",
    },
    RuleInfo {
        code: "A004",
        severity: Severity::Error,
        summary: "fast-forward window boundary: coalesced endpoint state equals replay's",
    },
    RuleInfo {
        code: "A005",
        severity: Severity::Error,
        summary: "batch composition: slot phases/token counts consistent at IterDone",
    },
    RuleInfo {
        code: "A006",
        severity: Severity::Error,
        summary: "metrics record consistency: completion stamps ordered, records == finished",
    },
    RuleInfo {
        code: "A007",
        severity: Severity::Error,
        summary: "link-occupancy conservation: transfers well-formed, busy-time released on time",
    },
];

// ---------------------------------------------------------------------------
// Runtime rule registration (library users; built-ins live in RULES)
// ---------------------------------------------------------------------------

/// Everything a registered rule may inspect: the raw YAML, the parsed
/// config, and the generated workload (empty when generation failed —
/// an `E001` is already reported in that case).
pub struct LintCtx<'a> {
    pub yaml: &'a Yaml,
    pub cfg: &'a SimulationConfig,
    pub requests: &'a [Request],
}

type DynCheck = Box<dyn Fn(&LintCtx) -> Vec<Diagnostic> + Send + Sync>;

struct DynRule {
    code: String,
    severity: Severity,
    summary: String,
    check: DynCheck,
}

fn extra_rules() -> &'static Mutex<Vec<DynRule>> {
    static EXTRA: OnceLock<Mutex<Vec<DynRule>>> = OnceLock::new();
    EXTRA.get_or_init(|| Mutex::new(Vec::new()))
}

/// Register an out-of-tree lint rule. `check` runs on every
/// successfully parsed config, after the built-in semantic rules;
/// returned diagnostics are appended to the report. Mirrors the
/// registries' `register_*` hooks so a subsystem that registers a
/// policy can ship its configuration rules alongside it.
pub fn register_lint_rule(
    code: &str,
    severity: Severity,
    summary: &str,
    check: impl Fn(&LintCtx) -> Vec<Diagnostic> + Send + Sync + 'static,
) {
    extra_rules().lock().unwrap().push(DynRule {
        code: code.to_string(),
        severity,
        summary: summary.to_string(),
        check: Box::new(check),
    });
}

/// Every selectable rule — built-ins plus runtime registrations — as
/// `(code, severity, summary)`, for `tokensim list`.
pub fn lint_rules() -> Vec<(String, Severity, String)> {
    let mut out: Vec<(String, Severity, String)> = RULES
        .iter()
        .map(|r| (r.code.to_string(), r.severity, r.summary.to_string()))
        .collect();
    for r in extra_rules().lock().unwrap().iter() {
        out.push((r.code.clone(), r.severity, r.summary.clone()));
    }
    out
}

// ---------------------------------------------------------------------------
// Did-you-mean
// ---------------------------------------------------------------------------

/// The closest candidate within an edit-distance budget (2, or a third
/// of the input for long names) — `None` when nothing is plausibly a
/// typo of `input`.
pub fn did_you_mean<'a>(
    input: &str,
    candidates: impl IntoIterator<Item = &'a str>,
) -> Option<&'a str> {
    let budget = 2.max(input.len() / 3);
    candidates
        .into_iter()
        .map(|c| (levenshtein(&input.to_ascii_lowercase(), &c.to_ascii_lowercase()), c))
        .filter(|&(d, _)| d <= budget)
        .min_by_key(|&(d, _)| d)
        .map(|(_, c)| c)
}

fn levenshtein(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

// ---------------------------------------------------------------------------
// Report
// ---------------------------------------------------------------------------

/// Lint findings for one config file.
#[derive(Debug)]
pub struct LintReport {
    /// The path (or label) the config came from.
    pub path: String,
    pub diagnostics: Vec<Diagnostic>,
}

impl LintReport {
    pub fn errors(&self) -> usize {
        self.count(Severity::Error)
    }

    pub fn warnings(&self) -> usize {
        self.count(Severity::Warn)
    }

    fn count(&self, s: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == s).count()
    }

    /// Does this report pass? Errors always fail; warnings fail under
    /// `deny_warnings`; infos never fail.
    pub fn passes(&self, deny_warnings: bool) -> bool {
        self.errors() == 0 && (!deny_warnings || self.warnings() == 0)
    }

    /// Machine-readable form (`tokensim lint --json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("path", Json::str(self.path.clone())),
            ("errors", Json::num(self.errors() as f64)),
            ("warnings", Json::num(self.warnings() as f64)),
            (
                "diagnostics",
                Json::Arr(self.diagnostics.iter().map(Diagnostic::to_json).collect()),
            ),
        ])
    }

    /// Human-readable lines (one per diagnostic, indent for fixes).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&format!(
                "{}: {}[{}] {}\n",
                self.path, d.severity, d.code, d.message
            ));
            if let Some(fix) = &d.fix {
                out.push_str(&format!("  fix: {fix}\n"));
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Runner
// ---------------------------------------------------------------------------

/// Lint a config file. IO errors surface as an `E001` diagnostic, not
/// a process error, so a multi-file invocation reports every file.
pub fn lint_file(path: &str) -> LintReport {
    match std::fs::read_to_string(path) {
        Ok(text) => lint_text(path, &text),
        Err(e) => LintReport {
            path: path.to_string(),
            diagnostics: vec![Diagnostic::error("E001", format!("cannot read file: {e}"))],
        },
    }
}

/// Lint config text. `label` names the source in the report (a path
/// for [`lint_file`], any tag for in-memory configs).
pub fn lint_text(label: &str, text: &str) -> LintReport {
    let mut diagnostics = Vec::new();
    let yaml = match Yaml::parse(text) {
        Ok(y) => y,
        Err(e) => {
            diagnostics.push(Diagnostic::error("E001", format!("YAML parse error: {e:#}")));
            return LintReport {
                path: label.to_string(),
                diagnostics,
            };
        }
    };

    // Pass 1 — structural: classify every unknown-name / unknown-key /
    // bad-layering error per section, with did-you-mean, instead of
    // stopping at the first like `SimulationConfig::from_yaml` must.
    structural(&yaml, &mut diagnostics);

    // Pass 2 — the real parse. Anything pass 1 could not classify
    // (missing required keys, bad presets, malformed scalars) lands
    // here as the E001 catch-all; when pass 1 already produced errors
    // the parse failure is the same root cause, so skip the duplicate.
    let cfg = match SimulationConfig::from_yaml(&yaml) {
        Ok(cfg) => cfg,
        Err(e) => {
            if !diagnostics.iter().any(|d| d.severity == Severity::Error) {
                diagnostics.push(Diagnostic::error("E001", format!("{e:#}")));
            }
            return LintReport {
                path: label.to_string(),
                diagnostics,
            };
        }
    };

    // Pass 3 — semantic cross-validation over the parsed config and
    // its generated workload.
    let requests = match cfg.workload.generate().context("generating workload") {
        Ok(r) => r,
        Err(e) => {
            diagnostics.push(Diagnostic::error("E001", format!("{e:#}")));
            Vec::new()
        }
    };
    let ctx = LintCtx {
        yaml: &yaml,
        cfg: &cfg,
        requests: &requests,
    };
    if !diagnostics.iter().any(|d| d.severity == Severity::Error) {
        rules::run(&ctx, &mut diagnostics);
        for rule in extra_rules().lock().unwrap().iter() {
            diagnostics.extend((rule.check)(&ctx));
        }
    }
    LintReport {
        path: label.to_string(),
        diagnostics,
    }
}

// ---------------------------------------------------------------------------
// Pass 1: structural classification
// ---------------------------------------------------------------------------

/// Which registry a spec came from (drives code + did-you-mean pool).
#[derive(Clone, Copy)]
enum Section {
    LocalPolicy,
    GlobalPolicy,
    Memory,
    Workload,
    Compute,
    Network,
}

impl Section {
    fn unknown_name_code(self) -> &'static str {
        match self {
            Section::LocalPolicy | Section::GlobalPolicy => "E010",
            Section::Memory => "E011",
            Section::Workload => "E012",
            Section::Compute => "E013",
            Section::Network => "E060",
        }
    }

    fn label(self) -> &'static str {
        match self {
            Section::LocalPolicy => "local scheduler policy",
            Section::GlobalPolicy => "global scheduler policy",
            Section::Memory => "memory manager",
            Section::Workload => "workload generator",
            Section::Compute => "compute model",
            Section::Network => "network topology",
        }
    }

    /// Every name + alias selectable from this section.
    fn known_names(self) -> Vec<&'static str> {
        let mut out = Vec::new();
        match self {
            Section::LocalPolicy => {
                for e in crate::scheduler::LOCAL_POLICIES {
                    out.push(e.name);
                    out.extend(e.aliases);
                }
            }
            Section::GlobalPolicy => {
                for e in crate::scheduler::GLOBAL_POLICIES {
                    out.push(e.name);
                    out.extend(e.aliases);
                }
            }
            Section::Memory => {
                for e in crate::memory::MEMORY_MANAGERS {
                    out.push(e.name);
                    out.extend(e.aliases);
                }
            }
            Section::Workload => {
                for e in crate::workload::WORKLOAD_GENERATORS {
                    out.push(e.name);
                    out.extend(e.aliases);
                }
            }
            Section::Compute => {
                for e in crate::compute::COMPUTE_MODELS {
                    out.push(e.name);
                    out.extend(e.aliases);
                }
            }
            Section::Network => {
                for e in crate::network::NETWORK_TOPOLOGIES {
                    out.push(e.name);
                    out.extend(e.aliases);
                }
            }
        }
        out
    }

    /// The accepted parameter keys of the entry `name` selects.
    fn params_of(self, name: &str) -> Option<&'static [&'static str]> {
        let matches = |n: &str, aliases: &[&str]| {
            name.eq_ignore_ascii_case(n) || aliases.iter().any(|a| name.eq_ignore_ascii_case(a))
        };
        match self {
            Section::LocalPolicy => crate::scheduler::LOCAL_POLICIES
                .iter()
                .find(|e| matches(e.name, e.aliases))
                .map(|e| e.params),
            Section::GlobalPolicy => crate::scheduler::GLOBAL_POLICIES
                .iter()
                .find(|e| matches(e.name, e.aliases))
                .map(|e| e.params),
            Section::Memory => crate::memory::MEMORY_MANAGERS
                .iter()
                .find(|e| matches(e.name, e.aliases))
                .map(|e| e.params),
            Section::Workload => crate::workload::WORKLOAD_GENERATORS
                .iter()
                .find(|e| matches(e.name, e.aliases))
                .map(|e| e.params),
            Section::Compute => crate::compute::COMPUTE_MODELS
                .iter()
                .find(|e| matches(e.name, e.aliases))
                .map(|e| e.params),
            Section::Network => crate::network::NETWORK_TOPOLOGIES
                .iter()
                .find(|e| matches(e.name, e.aliases))
                .map(|e| e.params),
        }
    }
}

/// Classify a registry validation error into a typed diagnostic.
fn classify(section: Section, name: &str, err: &anyhow::Error, out: &mut Vec<Diagnostic>) {
    let msg = format!("{err:#}");
    if msg.contains(&format!("unknown {}", section.label())) {
        let mut d = Diagnostic::error(
            section.unknown_name_code(),
            format!("unknown {} '{name}'", section.label()),
        );
        if let Some(sugg) = did_you_mean(name, section.known_names()) {
            d = d.with_fix(format!("did you mean '{sugg}'?"));
        }
        out.push(d);
        return;
    }
    // a link-typed network parameter naming a preset outside the
    // hardware catalog (the did-you-mean pool is the catalog itself)
    if matches!(section, Section::Network) && msg.contains("unknown link preset") {
        let bad = msg.split('\'').nth(1).unwrap_or("").to_string();
        let mut d = Diagnostic::error("E061", format!("{} '{name}': {msg}", section.label()));
        let mut pool: Vec<&'static str> = Vec::new();
        for e in crate::hardware::LINK_CATALOG {
            pool.push(e.name);
            pool.extend(e.aliases);
        }
        if let Some(sugg) = did_you_mean(&bad, pool) {
            d = d.with_fix(format!("did you mean '{sugg}'?"));
        }
        out.push(d);
        return;
    }
    if msg.contains("unknown parameter") || msg.contains("unknown tenant parameter") {
        let bad_key = msg.split('\'').nth(1).unwrap_or("").to_string();
        let mut d = Diagnostic::error("E014", format!("{} '{name}': {msg}", section.label()));
        if let Some(params) = section.params_of(name) {
            if let Some(sugg) = did_you_mean(&bad_key, params.iter().copied()) {
                d = d.with_fix(format!("did you mean '{sugg}'?"));
            }
        }
        out.push(d);
        return;
    }
    // table/memo layering refusals from the compute registry
    if matches!(section, Section::Compute)
        && (msg.contains("table base")
            || msg.contains("memo base")
            || msg.contains("cannot layer")
            || msg.contains("cannot cache")
            || msg.contains("linear-probe hook"))
    {
        out.push(
            Diagnostic::error("E020", format!("compute model '{name}': {msg}")).with_fix(
                "layer 'table' only over probe-able bases (hlo, analytic, roofline) and \
                 'memo' over any deterministic non-memo base",
            ),
        );
        return;
    }
    // anything else (malformed values, missing required keys): the
    // catch-all, still attributed to its section
    out.push(Diagnostic::error(
        "E001",
        format!("in {} '{name}': {msg}", section.label()),
    ));
}

/// Keys the `engine:` section consults; anything else is dead weight
/// that `EngineConfig::from_yaml` silently ignores.
const ENGINE_KEYS: &[&str] = &["fast_forward", "window_cost", "audit"];
/// Keys the `metrics:` section consults.
const METRICS_KEYS: &[&str] = &["mode", "sketch_error"];

fn check_section_keys(y: &Yaml, section: &str, known: &[&str], out: &mut Vec<Diagnostic>) {
    let Some(map) = y.as_map() else { return };
    for key in map.keys() {
        if !known.contains(&key.as_str()) {
            let mut d = Diagnostic::error(
                "E014",
                format!(
                    "unknown key '{key}' in '{section}:' section (accepted: {})",
                    known.join(", ")
                ),
            );
            if let Some(sugg) = did_you_mean(key, known.iter().copied()) {
                d = d.with_fix(format!("did you mean '{sugg}'?"));
            }
            out.push(d);
        }
    }
}

fn check_policy(y: &Yaml, section: Section, out: &mut Vec<Diagnostic>) {
    let spec = match crate::scheduler::PolicySpec::from_yaml(y) {
        Ok(s) => s,
        Err(_) => return, // missing 'policy:' key — pass 2's E001
    };
    let built = match section {
        Section::LocalPolicy => spec.build_local().map(|_| ()),
        _ => spec.build_global().map(|_| ()),
    };
    if let Err(e) = built {
        classify(section, &spec.name, &e, out);
    }
}

fn structural(y: &Yaml, out: &mut Vec<Diagnostic>) {
    if let Some(workers) = y
        .get("cluster")
        .and_then(|c| c.get("workers"))
        .and_then(Yaml::as_list)
    {
        for w in workers {
            if let Some(ls) = w.get("local_scheduler") {
                check_policy(ls, Section::LocalPolicy, out);
            }
            if let Some(m) = w.get("memory") {
                if let Ok(spec) = crate::memory::MemorySpec::from_yaml(m) {
                    if let Err(e) = spec.validate() {
                        classify(Section::Memory, &spec.name, &e, out);
                    }
                }
            }
            if let Some(c) = w.get("compute") {
                if let Ok(spec) = crate::compute::ComputeSpec::from_yaml(c) {
                    if let Err(e) = spec.validate() {
                        classify(Section::Compute, &spec.name, &e, out);
                    }
                }
            }
        }
    }
    if let Some(g) = y
        .get("cluster")
        .and_then(|c| c.get("scheduler"))
        .and_then(|s| s.get("global"))
    {
        check_policy(g, Section::GlobalPolicy, out);
    }
    if let Some(wl) = y.get("workload") {
        if let Ok(spec) = crate::workload::WorkloadSpecV2::from_yaml(wl) {
            if let Err(e) = spec.validate() {
                classify(Section::Workload, &spec.name, &e, out);
            }
        }
    }
    // top-level compute selection (either spelling)
    let compute_spec = match (y.get("compute"), y.get("cost_model")) {
        (Some(c), _) => crate::compute::ComputeSpec::from_yaml(c).ok(),
        (None, Some(k)) => k.as_str().map(crate::compute::ComputeSpec::new),
        (None, None) => None,
    };
    if let Some(spec) = compute_spec {
        if let Err(e) = spec.validate() {
            classify(Section::Compute, &spec.name, &e, out);
        }
    }
    if let Some(n) = y.get("network") {
        if let Ok(spec) = crate::network::NetworkSpec::from_yaml(n) {
            if let Err(e) = spec.validate() {
                classify(Section::Network, &spec.name, &e, out);
            }
        }
    }
    if let Some(e) = y.get("engine") {
        check_section_keys(e, "engine", ENGINE_KEYS, out);
    }
    if let Some(m) = y.get("metrics") {
        check_section_keys(m, "metrics", METRICS_KEYS, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BASE: &str = r#"
model: llama2-7b
cost_model: analytic
cluster:
  workers:
    - hardware: A100
workload:
  num_requests: 5
  qps: 10.0
  prompt_len:
    fixed: 64
  output_len:
    fixed: 8
  seed: 1
"#;

    fn codes(report: &LintReport) -> Vec<&str> {
        report.diagnostics.iter().map(|d| d.code.as_str()).collect()
    }

    #[test]
    fn clean_config_has_no_diagnostics() {
        let r = lint_text("base", BASE);
        assert!(r.diagnostics.is_empty(), "{:?}", r.diagnostics);
        assert!(r.passes(true));
    }

    #[test]
    fn yaml_syntax_error_is_e001() {
        let r = lint_text("bad", "model: [unclosed");
        assert_eq!(codes(&r), vec!["E001"]);
        assert!(!r.passes(false));
    }

    #[test]
    fn unknown_policy_is_e010_with_suggestion() {
        let text = BASE.replace(
            "    - hardware: A100",
            "    - hardware: A100\n      local_scheduler:\n        policy: continuos",
        );
        let r = lint_text("t", &text);
        assert_eq!(codes(&r), vec!["E010"]);
        assert!(r.diagnostics[0].fix.as_deref().unwrap().contains("continuous"));
    }

    #[test]
    fn unknown_global_policy_is_e010() {
        let yaml = r#"
model: llama2-7b
cost_model: analytic
cluster:
  workers:
    - hardware: A100
  scheduler:
    global:
      policy: round_robbin
workload:
  num_requests: 5
  qps: 10.0
  prompt_len:
    fixed: 64
  output_len:
    fixed: 8
  seed: 1
"#;
        let r = lint_text("t", yaml);
        assert_eq!(codes(&r), vec!["E010"]);
        assert!(r.diagnostics[0].fix.as_deref().unwrap().contains("round_robin"));
    }

    #[test]
    fn unknown_memory_manager_is_e011() {
        let text = BASE.replace(
            "    - hardware: A100",
            "    - hardware: A100\n      memory:\n        manager: pagd",
        );
        let r = lint_text("t", &text);
        assert_eq!(codes(&r), vec!["E011"]);
        assert!(r.diagnostics[0].fix.as_deref().unwrap().contains("paged"));
    }

    #[test]
    fn unknown_workload_generator_is_e012() {
        let text = BASE.replace("  num_requests: 5", "  generator: burstty\n  num_requests: 5");
        let r = lint_text("t", &text);
        assert_eq!(codes(&r), vec!["E012"]);
        assert!(r.diagnostics[0].fix.as_deref().unwrap().contains("bursty"));
    }

    #[test]
    fn unknown_compute_model_is_e013() {
        let text = BASE.replace("cost_model: analytic", "cost_model: analytics");
        let r = lint_text("t", &text);
        assert_eq!(codes(&r), vec!["E013"]);
        assert!(r.diagnostics[0].fix.as_deref().unwrap().contains("analytic"));
    }

    #[test]
    fn unknown_network_topology_is_e060() {
        let text = format!("{BASE}network:\n  topology: nvlink_iland\n");
        let r = lint_text("t", &text);
        assert_eq!(codes(&r), vec!["E060"]);
        assert!(r.diagnostics[0].fix.as_deref().unwrap().contains("nvlink_island"));
    }

    #[test]
    fn unknown_network_link_is_e061() {
        let text = format!("{BASE}network:\n  topology: ethernet\n  link: ethrnet\n");
        let r = lint_text("t", &text);
        assert_eq!(codes(&r), vec!["E061"]);
        assert!(r.diagnostics[0].fix.as_deref().unwrap().contains("ethernet"));
    }

    #[test]
    fn unknown_network_parameter_is_e014() {
        let text = format!("{BASE}network:\n  topology: nvlink_island\n  island_sz: 2\n");
        let r = lint_text("t", &text);
        assert_eq!(codes(&r), vec!["E014"]);
        assert!(r.diagnostics[0].fix.as_deref().unwrap().contains("island_size"));
    }

    #[test]
    fn unknown_parameter_is_e014_with_suggestion() {
        let text = BASE.replace(
            "    - hardware: A100",
            "    - hardware: A100\n      local_scheduler:\n        policy: continuous\n        max_batched_tokns: 512",
        );
        let r = lint_text("t", &text);
        assert_eq!(codes(&r), vec!["E014"]);
        assert!(
            r.diagnostics[0].fix.as_deref().unwrap().contains("max_batched_tokens"),
            "{:?}",
            r.diagnostics[0]
        );
    }

    #[test]
    fn unknown_engine_key_is_e014() {
        let text = format!("{BASE}engine:\n  fast_forwrad: true\n");
        let r = lint_text("t", &text);
        assert_eq!(codes(&r), vec!["E014"]);
        assert!(r.diagnostics[0].fix.as_deref().unwrap().contains("fast_forward"));
    }

    #[test]
    fn memo_over_oracle_is_e020() {
        let text = BASE.replace(
            "cost_model: analytic",
            "compute:\n  model: memo\n  base: oracle",
        );
        let r = lint_text("t", &text);
        assert_eq!(codes(&r), vec!["E020"]);
    }

    #[test]
    fn multiple_findings_in_one_file_are_all_reported() {
        let yaml = r#"
model: llama2-7b
cost_model: analytics
cluster:
  workers:
    - hardware: A100
      memory:
        manager: pagd
workload:
  num_requests: 5
  qps: 10.0
  prompt_len:
    fixed: 64
  output_len:
    fixed: 8
  seed: 1
"#;
        let r = lint_text("t", yaml);
        let mut c = codes(&r);
        c.sort();
        assert_eq!(c, vec!["E011", "E013"]);
    }

    #[test]
    fn json_output_round_trips() {
        let r = lint_text("bad.yaml", "model: [broken");
        let parsed = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("path").and_then(Json::as_str), Some("bad.yaml"));
        assert_eq!(parsed.get("errors").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn did_you_mean_respects_budget() {
        assert_eq!(did_you_mean("continuos", ["continuous", "static"]), Some("continuous"));
        assert_eq!(did_you_mean("zzzzzz", ["continuous", "static"]), None);
    }

    #[test]
    fn registered_rules_appear_in_listing_and_run() {
        // the rule keys off a marker so parallel tests linting other
        // configs in this process never see it fire
        register_lint_rule("X900", Severity::Warn, "test rule", |ctx| {
            if ctx.yaml.get("x900_marker").is_some() {
                vec![Diagnostic::warn("X900", "marker present")]
            } else {
                Vec::new()
            }
        });
        assert!(lint_rules().iter().any(|(c, _, _)| c == "X900"));
        let r = lint_text("t", &format!("{BASE}x900_marker: true\n"));
        assert!(codes(&r).contains(&"X900"), "{:?}", r.diagnostics);
        // registered warns fail only under --deny-warnings
        assert!(r.passes(false) && !r.passes(true));
    }

    #[test]
    fn rule_catalog_codes_are_unique_and_sorted() {
        let mut codes: Vec<&str> = RULES.iter().chain(AUDIT_CHECKS).map(|r| r.code).collect();
        let n = codes.len();
        codes.sort_unstable();
        codes.dedup();
        assert_eq!(codes.len(), n, "duplicate rule codes");
    }
}
