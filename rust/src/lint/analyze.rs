//! Static capacity analysis (`tokensim analyze`): closed-form
//! feasibility bounds over a parsed config, derived from O(1)
//! cost-model probe calls — never a simulation step.
//!
//! Four bound families (see [`BOUND_KINDS`]):
//!
//! * **compute saturation** — every iteration of a worker takes at
//!   least its probed single-token floor and serves at most a
//!   statically known token cap (policy batch caps, pool-implied
//!   concurrency, the request count), so `cap / floor` upper-bounds the
//!   worker's token service rate. Summed over the fleet and divided by
//!   the mean request length this yields a *sound* throughput upper
//!   bound: the simulator can never beat it. Offered rate over service
//!   rate is the utilization ρ.
//! * **memory feasibility** — Little's law: at the offered QPS, the
//!   expected concurrently resident KV (`qps × residency time × mean
//!   KV tokens`) must fit the decode fleet's pool capacity.
//! * **network saturation** — under strict prefill/decode
//!   disaggregation every request migrates its prompt KV once; routing
//!   that byte rate over the topology's links (discovered with probe
//!   transfers, never priced into a run) and comparing against per-link
//!   bandwidth flags the bottleneck hop.
//! * **SLO feasibility** — generalizes the E050 point check to a
//!   max-feasible-QPS band: zero when the SLO sits below the physical
//!   iteration floor, else the throughput upper bound.
//!
//! Every unprobeable or unbounded quantity degrades to `None` rather
//! than a guess — a reported bound is always *valid* (an over-, never
//! an under-estimate of what simulation can achieve), which the
//! property/integration suites assert against real runs. The same
//! machinery backs the E070/W071–W073 lint rules and the
//! [`prune`] hook experiment sweeps use to skip
//! statically-infeasible cells.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::compute::{BatchDesc, ComputeCtx, ComputeModel, CountingCost};
use crate::config::yaml::Yaml;
use crate::config::SimulationConfig;
use crate::network::{Endpoint, NetCtx};
use crate::request::Request;
use crate::util::json::Json;
use crate::workload::{offered_load, OfferedLoad};

use super::rules::{
    canonical_local, canonical_memory, chunk_tokens, floor_probeable, policy_token_cap,
};
use super::{Diagnostic, LintCtx, LintReport};

/// The analyzer's bound families, for `tokensim list`.
pub const BOUND_KINDS: &[(&str, &str)] = &[
    (
        "compute-saturation",
        "offered token rate vs probed service-rate cap: per-side utilization rho and a sound throughput upper bound",
    ),
    (
        "memory-feasibility",
        "Little's-law expected concurrent KV residency vs the decode fleet's pool capacity",
    ),
    (
        "network-saturation",
        "expected KV-migration byte rate routed over topology links vs per-link bandwidth (bottleneck hop)",
    ),
    (
        "slo-feasibility",
        "max-feasible-QPS band generalizing the E050 floor check",
    ),
];

/// Per-worker-config capacity facts (one entry per `workers:` item;
/// `quantity` scales its rates).
#[derive(Debug, Clone)]
pub struct WorkerBound {
    /// Index into `cluster.workers`.
    pub worker: usize,
    pub hardware: String,
    pub quantity: u32,
    pub run_prefill: bool,
    pub run_decode: bool,
    /// Whether the worker's compute model could be probed statically
    /// (hlo/analytic/roofline; trained and co-simulated models opt out).
    pub probeable: bool,
    /// Probed single-token iteration floor, seconds — no iteration of
    /// this worker can be faster.
    pub t_floor: Option<f64>,
    /// Probed decode floor at the smallest context, seconds.
    pub decode_floor: Option<f64>,
    /// Probed zero-queue prefill time of the smallest prompt, seconds.
    pub prefill_floor: Option<f64>,
    /// Max decode tokens one iteration can serve (policy batch cap,
    /// pool-implied concurrency, request count).
    pub decode_cap: Option<u64>,
    /// Max prefill tokens one iteration can admit (`max_batched_tokens`
    /// / `chunk_tokens`); `None` for uncapped policies.
    pub prefill_cap: Option<u64>,
    /// KV pool capacity of one instance, tokens.
    pub pool_tokens: Option<u64>,
}

/// Expected load on one topology link.
#[derive(Debug, Clone)]
pub struct LinkLoad {
    pub link: String,
    /// Link bandwidth, bytes/s.
    pub bandwidth: f64,
    /// Expected byte rate routed over this link, bytes/s.
    pub byte_rate: f64,
    /// `byte_rate / bandwidth`.
    pub utilization: f64,
}

/// The full static-analysis result for one config.
#[derive(Debug, Clone)]
pub struct Analysis {
    pub workers: Vec<WorkerBound>,
    /// Offered-load summary of the generated request table.
    pub offered: Option<OfferedLoad>,
    /// Fleet decode token service-rate bound, tokens/s.
    pub decode_token_rate: Option<f64>,
    /// Fleet prefill token service-rate bound, tokens/s.
    pub prefill_token_rate: Option<f64>,
    /// Decode-side throughput bound, requests/s.
    pub decode_bound: Option<f64>,
    /// Prefill-side throughput bound, requests/s.
    pub prefill_bound: Option<f64>,
    /// `min(decode_bound, prefill_bound)` — simulated throughput can
    /// never exceed this.
    pub throughput_ub: Option<f64>,
    /// Offered / service decode token rate.
    pub rho_decode: Option<f64>,
    /// Offered / service prefill token rate.
    pub rho_prefill: Option<f64>,
    /// Little's-law expected concurrently resident KV, tokens.
    pub kv_residency_tokens: Option<f64>,
    /// Total decode-fleet KV pool, tokens.
    pub kv_pool_tokens: Option<f64>,
    /// Whether the residency estimate applies (plain paged /
    /// token_contiguous decode fleet; swap and prefix sharing opt out).
    pub kv_bound_applicable: bool,
    /// Expected per-link byte rates (strict-disaggregation migration
    /// traffic over a contended topology; empty otherwise).
    pub links: Vec<LinkLoad>,
    /// Index into [`Self::links`] of the most utilized link.
    pub bottleneck: Option<usize>,
    /// SLO sits below the probed physical iteration floor (E050-grade).
    pub slo_floor_infeasible: bool,
    /// SLO feasibility band: 0 when the floor is violated, else the
    /// throughput upper bound.
    pub max_feasible_qps: Option<f64>,
    /// Cost-model probe calls issued — the proof the analysis stayed
    /// static (O(1) per worker config, zero simulation steps).
    pub probe_calls: usize,
}

fn empty_analysis() -> Analysis {
    Analysis {
        workers: Vec::new(),
        offered: None,
        decode_token_rate: None,
        prefill_token_rate: None,
        decode_bound: None,
        prefill_bound: None,
        throughput_ub: None,
        rho_decode: None,
        rho_prefill: None,
        kv_residency_tokens: None,
        kv_pool_tokens: None,
        kv_bound_applicable: false,
        links: Vec::new(),
        bottleneck: None,
        slo_floor_infeasible: false,
        max_feasible_qps: None,
        probe_calls: 0,
    }
}

/// Can this compute model be probed statically? Only probe-able models
/// (hlo/analytic/roofline, possibly memoized) yield finite bounds;
/// trained and co-simulated models degrade every bound to `None`.
pub fn probeable(spec: &crate::compute::ComputeSpec) -> bool {
    floor_probeable(spec)
}

/// Derive every static bound for `cfg` over its generated request
/// table. Issues at most 3 cost-model probe calls per worker config
/// and never steps the event engine.
pub fn analyze(cfg: &SimulationConfig, requests: &[Request]) -> Analysis {
    let Some(off) = offered_load(requests) else {
        return empty_analysis();
    };
    let calls = Arc::new(AtomicUsize::new(0));
    let n = off.requests as u64;
    let mut workers = Vec::with_capacity(cfg.cluster.workers.len());

    for (i, wc) in cfg.cluster.workers.iter().enumerate() {
        let spec = wc.compute.as_ref().unwrap_or(&cfg.compute);
        let mut wb = WorkerBound {
            worker: i,
            hardware: wc.hardware.name.clone(),
            quantity: wc.quantity,
            run_prefill: wc.run_prefill,
            run_decode: wc.run_decode,
            probeable: false,
            t_floor: None,
            decode_floor: None,
            prefill_floor: None,
            decode_cap: None,
            prefill_cap: None,
            pool_tokens: None,
        };
        if floor_probeable(spec) {
            if let Ok(inner) = spec.build(&ComputeCtx {
                model: &cfg.model,
                hw: &wc.hardware,
                artifacts_dir: &cfg.artifacts_dir,
                worker: 0,
            }) {
                let mut model = CountingCost::new(inner, Arc::clone(&calls));
                let mut b = BatchDesc::new();
                b.push(0, 1);
                let t = model.iter_time(&b);
                if t > 0.0 {
                    wb.probeable = true;
                    wb.t_floor = Some(t);
                    if wc.run_decode {
                        let mut b = BatchDesc::new();
                        b.push(off.min_prompt, 1);
                        wb.decode_floor = Some(model.iter_time(&b));
                    }
                    if wc.run_prefill {
                        let mut b = BatchDesc::new();
                        b.push(0, off.min_prompt.max(1));
                        wb.prefill_floor = Some(model.iter_time(&b));
                    }
                }
            }
        }

        // caps are registry facts, no probes needed
        let mem = wc.memory.build(&cfg.model, wc.hardware.mem_cap).ok();
        if let Some(mem) = &mem {
            wb.pool_tokens = Some(mem.total_blocks() * mem.block_size() as u64);
        }
        if wc.run_decode {
            let mut cap = n;
            match canonical_local(&wc.local_scheduler.name) {
                Some("continuous") | Some("priority") | Some("chunked_prefill") | Some("sjf") => {
                    if let Some(c) = wc
                        .local_scheduler
                        .params
                        .get("max_batch_size")
                        .and_then(Yaml::as_u64)
                    {
                        cap = cap.min(c);
                    }
                }
                Some("static") => {
                    if let Some(c) =
                        wc.local_scheduler.params.get("batch_size").and_then(Yaml::as_u64)
                    {
                        cap = cap.min(c);
                    }
                }
                _ => {}
            }
            // exclusive per-request block reservations bound resident
            // concurrency; prefix sharing breaks exclusivity, so it
            // opts out of the pool-implied cap
            if matches!(
                canonical_memory(&wc.memory.name),
                Some("paged") | Some("token_contiguous") | Some("swap")
            ) {
                if let Some(mem) = &mem {
                    let per = mem.blocks_for_tokens(off.min_prompt.max(1)).max(1);
                    cap = cap.min(mem.total_blocks() / per);
                }
            }
            wb.decode_cap = Some(cap);
        }
        if wc.run_prefill {
            wb.prefill_cap = match canonical_local(&wc.local_scheduler.name) {
                Some("continuous") | Some("priority") | Some("sjf") => {
                    policy_token_cap(&wc.local_scheduler).map(u64::from)
                }
                Some("chunked_prefill") => Some(u64::from(chunk_tokens(&wc.local_scheduler))),
                _ => None,
            };
        }
        workers.push(wb);
    }

    // ---- fleet service-rate bounds --------------------------------------
    let mut decode_token_rate = Some(0.0f64);
    let mut prefill_token_rate = Some(0.0f64);
    for wb in &workers {
        if wb.run_decode {
            match (wb.t_floor, wb.decode_cap, &mut decode_token_rate) {
                (Some(t), Some(cap), Some(r)) if t > 0.0 => {
                    *r += wb.quantity as f64 * cap as f64 / t;
                }
                _ => decode_token_rate = None,
            }
        }
        if wb.run_prefill {
            match (wb.t_floor, wb.prefill_cap, &mut prefill_token_rate) {
                (Some(t), Some(cap), Some(r)) if t > 0.0 => {
                    *r += wb.quantity as f64 * cap as f64 / t;
                }
                _ => prefill_token_rate = None,
            }
        }
    }
    // prefill work per request is lower-bounded by the uncached prompt
    // only when no KV can appear from outside the request itself
    let prefix_prefill = cfg.cluster.workers.iter().any(|wc| {
        wc.run_prefill && canonical_memory(&wc.memory.name) == Some("prefix_cache")
    });
    if cfg.pool_cache.is_some() || prefix_prefill {
        prefill_token_rate = None;
    }

    let decode_bound = match (decode_token_rate, off.mean_output) {
        (Some(r), m) if m > 0.0 => Some(r / m),
        _ => None,
    };
    let prefill_bound = match (prefill_token_rate, off.mean_prefill) {
        (Some(r), m) if m > 0.0 => Some(r / m),
        _ => None,
    };
    let throughput_ub = match (decode_bound, prefill_bound) {
        (Some(d), Some(p)) => Some(d.min(p)),
        (Some(d), None) => Some(d),
        (None, Some(p)) => Some(p),
        (None, None) => None,
    };

    let rho_decode = match (off.qps, decode_token_rate) {
        (Some(q), Some(r)) if r > 0.0 => Some(q * off.mean_output / r),
        _ => None,
    };
    let rho_prefill = match (off.qps, prefill_token_rate) {
        (Some(q), Some(r)) if r > 0.0 => Some(q * off.mean_prefill / r),
        _ => None,
    };

    // ---- Little's-law KV residency --------------------------------------
    let decode_workers: Vec<&WorkerBound> = workers.iter().filter(|w| w.run_decode).collect();
    let kv_bound_applicable = !decode_workers.is_empty()
        && decode_workers.iter().all(|w| {
            matches!(
                canonical_memory(&cfg.cluster.workers[w.worker].memory.name),
                Some("paged") | Some("token_contiguous")
            )
        });
    let all_contiguous = kv_bound_applicable
        && decode_workers.iter().all(|w| {
            canonical_memory(&cfg.cluster.workers[w.worker].memory.name)
                == Some("token_contiguous")
        });
    let min_decode_floor = decode_workers
        .iter()
        .filter_map(|w| w.decode_floor)
        .fold(None::<f64>, |acc, t| Some(acc.map_or(t, |a| a.min(t))));
    let kv_residency_tokens = match (off.qps, min_decode_floor) {
        (Some(q), Some(floor)) => {
            let residency_time = off.mean_output * floor;
            let mean_kv = if all_contiguous {
                off.mean_prompt + off.mean_output
            } else {
                off.mean_prompt + off.mean_output / 2.0
            };
            Some(q * residency_time * mean_kv)
        }
        _ => None,
    };
    let kv_pool_tokens = decode_workers
        .iter()
        .try_fold(0.0f64, |acc, w| {
            w.pool_tokens.map(|p| acc + w.quantity as f64 * p as f64)
        });

    // ---- network saturation ---------------------------------------------
    let (links, bottleneck) = network_load(cfg, &off);

    // ---- SLO feasibility band -------------------------------------------
    let min_prefill_floor = workers
        .iter()
        .filter(|w| w.run_prefill)
        .filter_map(|w| w.prefill_floor)
        .fold(None::<f64>, |acc, t| Some(acc.map_or(t, |a| a.min(t))));
    let mut slo_floor_infeasible = false;
    if let (Some(slo), Some(floor)) = (cfg.slo.mtpot, min_decode_floor) {
        slo_floor_infeasible |= slo < floor;
    }
    if let (Some(slo), Some(floor)) = (cfg.slo.ttft, min_prefill_floor) {
        slo_floor_infeasible |= slo < floor;
    }
    let max_feasible_qps = if slo_floor_infeasible {
        Some(0.0)
    } else {
        throughput_ub
    };

    Analysis {
        workers,
        offered: Some(off),
        decode_token_rate,
        prefill_token_rate,
        decode_bound,
        prefill_bound,
        throughput_ub,
        rho_decode,
        rho_prefill,
        kv_residency_tokens,
        kv_pool_tokens,
        kv_bound_applicable,
        links,
        bottleneck,
        slo_floor_infeasible,
        max_feasible_qps,
        probe_calls: calls.load(Ordering::Relaxed),
    }
}

/// Route the strict-disaggregation KV-migration byte rate over the
/// topology's links. Applies only when every worker config runs exactly
/// one role over a contended (non-flat) topology — then every request
/// provably migrates its prompt KV from a prefill to a decode instance.
fn network_load(cfg: &SimulationConfig, off: &OfferedLoad) -> (Vec<LinkLoad>, Option<usize>) {
    let Some(qps) = off.qps else {
        return (Vec::new(), None);
    };
    if cfg.network.is_flat() {
        return (Vec::new(), None);
    }
    let strict = cfg
        .cluster
        .workers
        .iter()
        .all(|wc| wc.run_prefill != wc.run_decode);
    if !strict {
        return (Vec::new(), None);
    }
    let mut prefill_idx = Vec::new();
    let mut decode_idx = Vec::new();
    let mut idx = 0usize;
    for wc in &cfg.cluster.workers {
        for _ in 0..wc.quantity {
            if wc.run_prefill {
                prefill_idx.push(idx);
            } else {
                decode_idx.push(idx);
            }
            idx += 1;
        }
    }
    if prefill_idx.is_empty() || decode_idx.is_empty() {
        return (Vec::new(), None);
    }
    let Ok(ctx) = NetCtx::for_config(cfg) else {
        return (Vec::new(), None);
    };
    let Ok(mut net) = cfg.network.build(&ctx) else {
        return (Vec::new(), None);
    };
    let specs = net.links();
    if specs.is_empty() {
        return (Vec::new(), None); // topology opts out of link reporting
    }
    // total migration byte rate, split uniformly over the (p, d) pairs
    // the global scheduler can choose from
    let bytes_per_req = off.mean_prompt * cfg.model.kv_bytes_per_token() as f64;
    let pairs = (prefill_idx.len() * decode_idx.len()) as f64;
    let per_pair_rate = qps * bytes_per_req / pairs;
    let mut by_link: HashMap<String, f64> = HashMap::new();
    for &p in &prefill_idx {
        for &d in &decode_idx {
            // a 1-block probe transfer discovers the path; occupancy on
            // this throwaway model is irrelevant
            let t = net.transfer(Endpoint::Worker(p), Endpoint::Worker(d), 1, 1, 0.0);
            for link in t.path {
                *by_link.entry(link).or_default() += per_pair_rate;
            }
        }
    }
    let links: Vec<LinkLoad> = specs
        .iter()
        .map(|s| {
            let rate = by_link.get(&s.name).copied().unwrap_or(0.0);
            LinkLoad {
                link: s.name.clone(),
                bandwidth: s.bandwidth,
                byte_rate: rate,
                utilization: if s.bandwidth > 0.0 { rate / s.bandwidth } else { 0.0 },
            }
        })
        .collect();
    let bottleneck = links
        .iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| a.utilization.total_cmp(&b.utilization))
        .map(|(i, _)| i);
    (links, bottleneck)
}

impl Analysis {
    /// The E070/W071/W072/W073 findings this analysis supports. I074
    /// (the bound summary) is appended only on the `tokensim analyze`
    /// command path, not by plain `lint`.
    pub fn lint_diagnostics(&self, cfg: &SimulationConfig) -> Vec<Diagnostic> {
        let mut out = Vec::new();
        let Some(off) = &self.offered else {
            return out;
        };

        // E070/W071: provable decode backlog vs the SLO window. Latency
        // of the k-th finisher is at least (sum of the k smallest
        // outputs)/R minus the arrival span; compare against the most
        // permissive per-request SLO allowance.
        let slack = match (cfg.slo.ttft, cfg.slo.mtpot) {
            (Some(ttft), Some(mtpot)) => Some(ttft + off.max_output as f64 * mtpot),
            _ => None,
        };
        if let (Some(r), Some(slack)) = (self.decode_token_rate, slack) {
            if r > 0.0 && !off.sorted_outputs.is_empty() {
                let n = off.sorted_outputs.len();
                // n - floor(n/10) >= ceil(0.9 n): if even the smallest
                // k90 outputs overrun the window, >= 10% of requests
                // provably violate their SLO
                let k90 = n - n / 10;
                let s90: f64 = off.sorted_outputs[..k90].iter().map(|&o| o as f64).sum();
                let sn: f64 = off.sorted_outputs.iter().map(|&o| o as f64).sum();
                if s90 / r - off.span > slack {
                    out.push(
                        Diagnostic::error(
                            "E070",
                            format!(
                                "infeasible by construction: serving even the smallest 90% of \
                                 the decode work ({s90:.0} tokens) takes at least {:.1}s against \
                                 the fleet's {r:.0} tok/s service-rate bound, so at least 10% of \
                                 requests provably exceed the SLO window ({slack:.1}s after the \
                                 {:.1}s arrival span)",
                                s90 / r,
                                off.span
                            ),
                        )
                        .with_fix(
                            "lower the workload qps / request count, add decode capacity, or \
                             relax the ttft/mtpot SLOs",
                        ),
                    );
                } else {
                    let rho = match (self.rho_decode, self.rho_prefill) {
                        (Some(a), Some(b)) => Some(a.max(b)),
                        (a, b) => a.or(b),
                    };
                    if let Some(rho) = rho {
                        if rho > 0.9 && sn / r - off.span > slack {
                            out.push(
                                Diagnostic::warn(
                                    "W071",
                                    format!(
                                        "compute saturation: utilization rho = {rho:.2} and the \
                                         total decode backlog ({sn:.0} tokens) provably pushes \
                                         the last request {:.1}s past the SLO window",
                                        sn / r - off.span - slack
                                    ),
                                )
                                .with_fix(
                                    "lower the offered load or add capacity; rho above 0.9 \
                                     leaves no headroom for burstiness",
                                ),
                            );
                        }
                    }
                }
            }
        }

        // W072: a link asked to carry more than 90% of its bandwidth
        if let Some(b) = self.bottleneck {
            let l = &self.links[b];
            if l.utilization > 0.9 {
                out.push(
                    Diagnostic::warn(
                        "W072",
                        format!(
                            "network saturation: link '{}' is asked to carry {:.1} GB/s of \
                             expected KV-migration traffic, {:.0}% of its {:.1} GB/s bandwidth \
                             — transfers will queue without bound",
                            l.link,
                            l.byte_rate / 1e9,
                            l.utilization * 100.0,
                            l.bandwidth / 1e9
                        ),
                    )
                    .with_fix(
                        "pick a faster link preset / topology, co-locate prefill and decode, \
                         or lower the offered load",
                    ),
                );
            }
        }

        // W073: expected resident KV exceeds the decode fleet's pool
        if self.kv_bound_applicable {
            if let (Some(l), Some(pool)) = (self.kv_residency_tokens, self.kv_pool_tokens) {
                if l > pool {
                    out.push(
                        Diagnostic::warn(
                            "W073",
                            format!(
                                "memory infeasibility: Little's-law expected concurrent KV \
                                 residency ({l:.0} tokens) exceeds the decode fleet's pool \
                                 capacity ({pool:.0} tokens) — sustained queueing or \
                                 preemption churn is guaranteed",
                            ),
                        )
                        .with_fix(
                            "lower qps, shorten contexts, raise mem_cap/gpu_utilization, or \
                             switch to a swap-capable manager",
                        ),
                    );
                }
            }
        }
        out
    }

    /// One-line bound summary, attached as I074 by the analyze command.
    pub fn summary(&self) -> String {
        let fmt_opt = |v: Option<f64>| match v {
            Some(v) => format!("{v:.2}"),
            None => "n/a".to_string(),
        };
        let bottleneck = self
            .bottleneck
            .and_then(|i| self.links.get(i))
            .map(|l| format!("{} at {:.0}%", l.link, l.utilization * 100.0))
            .unwrap_or_else(|| "n/a".to_string());
        format!(
            "static bounds: throughput <= {} req/s (decode {} tok/s, prefill {} tok/s), \
             rho decode {} / prefill {}, KV residency {} of {} pool tokens, bottleneck \
             link {}, max feasible qps {}, {} probe calls",
            fmt_opt(self.throughput_ub),
            fmt_opt(self.decode_token_rate),
            fmt_opt(self.prefill_token_rate),
            fmt_opt(self.rho_decode),
            fmt_opt(self.rho_prefill),
            fmt_opt(self.kv_residency_tokens),
            fmt_opt(self.kv_pool_tokens),
            bottleneck,
            fmt_opt(self.max_feasible_qps),
            self.probe_calls
        )
    }

    /// Machine-readable form (`tokensim analyze --json`).
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map_or(Json::Null, Json::num);
        let workers = self
            .workers
            .iter()
            .map(|w| {
                Json::obj(vec![
                    ("worker", Json::num(w.worker as f64)),
                    ("hardware", Json::str(w.hardware.clone())),
                    ("quantity", Json::num(w.quantity as f64)),
                    ("run_prefill", Json::num(f64::from(u8::from(w.run_prefill)))),
                    ("run_decode", Json::num(f64::from(u8::from(w.run_decode)))),
                    ("probeable", Json::num(f64::from(u8::from(w.probeable)))),
                    ("t_floor", opt(w.t_floor)),
                    ("decode_floor", opt(w.decode_floor)),
                    ("prefill_floor", opt(w.prefill_floor)),
                    ("decode_cap", opt(w.decode_cap.map(|c| c as f64))),
                    ("prefill_cap", opt(w.prefill_cap.map(|c| c as f64))),
                    ("pool_tokens", opt(w.pool_tokens.map(|c| c as f64))),
                ])
            })
            .collect();
        let links = self
            .links
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("link", Json::str(l.link.clone())),
                    ("bandwidth", Json::num(l.bandwidth)),
                    ("byte_rate", Json::num(l.byte_rate)),
                    ("utilization", Json::num(l.utilization)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("throughput_ub", opt(self.throughput_ub)),
            ("decode_token_rate", opt(self.decode_token_rate)),
            ("prefill_token_rate", opt(self.prefill_token_rate)),
            ("decode_bound", opt(self.decode_bound)),
            ("prefill_bound", opt(self.prefill_bound)),
            ("rho_decode", opt(self.rho_decode)),
            ("rho_prefill", opt(self.rho_prefill)),
            ("kv_residency_tokens", opt(self.kv_residency_tokens)),
            ("kv_pool_tokens", opt(self.kv_pool_tokens)),
            (
                "kv_bound_applicable",
                Json::num(f64::from(u8::from(self.kv_bound_applicable))),
            ),
            ("offered_qps", opt(self.offered.as_ref().and_then(|o| o.qps))),
            (
                "slo_floor_infeasible",
                Json::num(f64::from(u8::from(self.slo_floor_infeasible))),
            ),
            ("max_feasible_qps", opt(self.max_feasible_qps)),
            ("probe_calls", Json::num(self.probe_calls as f64)),
            ("workers", Json::Arr(workers)),
            ("links", Json::Arr(links)),
            (
                "bottleneck",
                self.bottleneck
                    .and_then(|i| self.links.get(i))
                    .map_or(Json::Null, |l| Json::str(l.link.clone())),
            ),
        ])
    }

    /// Human-readable bound report (the analyze command's per-file body).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let fmt = |v: Option<f64>| match v {
            Some(v) if v.abs() >= 1000.0 => format!("{v:.0}"),
            Some(v) => format!("{v:.3}"),
            None => "n/a".to_string(),
        };
        if let Some(off) = &self.offered {
            out.push_str(&format!(
                "  offered: {} requests, qps {}, mean prompt {:.0} / output {:.0} tokens\n",
                off.requests,
                fmt(off.qps),
                off.mean_prompt,
                off.mean_output
            ));
        }
        out.push_str(&format!(
            "  compute: throughput <= {} req/s (decode {} tok/s, prefill {} tok/s), \
             rho decode {} / prefill {}\n",
            fmt(self.throughput_ub),
            fmt(self.decode_token_rate),
            fmt(self.prefill_token_rate),
            fmt(self.rho_decode),
            fmt(self.rho_prefill)
        ));
        out.push_str(&format!(
            "  memory:  expected KV residency {} tokens vs {} pool tokens{}\n",
            fmt(self.kv_residency_tokens),
            fmt(self.kv_pool_tokens),
            if self.kv_bound_applicable { "" } else { " (bound not applicable)" }
        ));
        match self.bottleneck.and_then(|i| self.links.get(i)) {
            Some(l) => out.push_str(&format!(
                "  network: bottleneck link '{}' at {:.0}% ({:.2} GB/s of {:.2} GB/s)\n",
                l.link,
                l.utilization * 100.0,
                l.byte_rate / 1e9,
                l.bandwidth / 1e9
            )),
            None => out.push_str("  network: no migration traffic bound (flat topology or co-located roles)\n"),
        }
        out.push_str(&format!(
            "  slo:     max feasible qps {}{}\n",
            fmt(self.max_feasible_qps),
            if self.slo_floor_infeasible {
                " (SLO below the physical iteration floor)"
            } else {
                ""
            }
        ));
        out.push_str(&format!("  probes:  {} cost-model calls, 0 simulation steps\n", self.probe_calls));
        out
    }
}

// ---------------------------------------------------------------------------
// Lint-rule integration (E070/W071/W072/W073 inside `tokensim lint`)
// ---------------------------------------------------------------------------

/// The capacity-bounds lint rule: run the analyzer and append its
/// findings. Called from the semantic rule pass.
pub(crate) fn capacity_bounds(ctx: &LintCtx, out: &mut Vec<Diagnostic>) {
    let analysis = analyze(ctx.cfg, ctx.requests);
    out.extend(analysis.lint_diagnostics(ctx.cfg));
}

// ---------------------------------------------------------------------------
// Command path (`tokensim analyze`)
// ---------------------------------------------------------------------------

/// Analyze config text: the full lint report (including the E07x/W07x
/// capacity rules) plus the bound analysis, with an I074 summary
/// diagnostic appended when the config parses.
pub fn analyze_text(label: &str, text: &str) -> (LintReport, Option<Analysis>) {
    let mut report = super::lint_text(label, text);
    let analysis = SimulationConfig::from_yaml_str(text).ok().map(|cfg| {
        let requests = cfg.workload.generate().unwrap_or_default();
        analyze(&cfg, &requests)
    });
    if let Some(a) = &analysis {
        report.diagnostics.push(Diagnostic::info("I074", a.summary()));
    }
    (report, analysis)
}

/// [`analyze_text`] over a file; IO errors surface as E001 diagnostics.
pub fn analyze_file(path: &str) -> (LintReport, Option<Analysis>) {
    match std::fs::read_to_string(path) {
        Ok(text) => analyze_text(path, &text),
        Err(e) => (
            LintReport {
                path: path.to_string(),
                diagnostics: vec![Diagnostic::error("E001", format!("cannot read file: {e}"))],
            },
            None,
        ),
    }
}

// ---------------------------------------------------------------------------
// Sweep pruning
// ---------------------------------------------------------------------------

/// Should an experiment sweep skip this cell without simulating it?
/// Returns the reason when the config is *certainly* infeasible by a
/// qps-independent bound (E030 pool deadlock, E031 token-budget
/// deadlock, E050 SLO below the physical floor) — conditions no
/// scheduling outcome can escape, so the pruned frontier is provably
/// identical to the unpruned one. Load-dependent findings (E070, the
/// W07x saturation warnings) never prune: they flag doom, not
/// impossibility of producing a report.
pub fn prune(cfg: &SimulationConfig) -> Option<String> {
    let requests = cfg.workload.generate().ok()?;
    let yaml = Yaml::Map(Default::default());
    let ctx = LintCtx {
        yaml: &yaml,
        cfg,
        requests: &requests,
    };
    let mut diagnostics = Vec::new();
    super::rules::pool_capacity(&ctx, &mut diagnostics);
    super::rules::token_budget(&ctx, &mut diagnostics);
    super::rules::slo_floor(&ctx, &mut diagnostics);
    diagnostics
        .iter()
        .find(|d| matches!(d.code.as_str(), "E030" | "E031" | "E050"))
        .map(|d| format!("[{}] {}", d.code, d.message))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimulationConfig;

    fn analyzed(text: &str) -> (SimulationConfig, Analysis) {
        let cfg = SimulationConfig::from_yaml_str(text).unwrap();
        let requests = cfg.workload.generate().unwrap();
        let a = analyze(&cfg, &requests);
        (cfg, a)
    }

    const BASE: &str = r#"
model: llama2-7b
cost_model: analytic
cluster:
  workers:
    - hardware: A100
workload:
  num_requests: 50
  qps: 5.0
  prompt_len:
    fixed: 64
  output_len:
    fixed: 16
  seed: 1
"#;

    #[test]
    fn probe_budget_is_o1_per_worker_config() {
        let (cfg, a) = analyzed(BASE);
        assert!(a.probe_calls <= 3 * cfg.cluster.workers.len(), "{}", a.probe_calls);
        assert!(a.probe_calls >= 1);
    }

    #[test]
    fn healthy_config_has_finite_bounds_and_no_findings() {
        let (cfg, a) = analyzed(BASE);
        let t = a.throughput_ub.expect("bound should be derivable");
        assert!(t > 0.0 && t.is_finite());
        assert!(a.rho_decode.unwrap() < 0.9, "{:?}", a.rho_decode);
        assert!(a.lint_diagnostics(&cfg).is_empty());
        assert!(!a.slo_floor_infeasible);
        assert_eq!(a.max_feasible_qps, a.throughput_ub);
    }

    #[test]
    fn unprobeable_model_degrades_to_none_not_a_guess() {
        let text = BASE.replace("cost_model: analytic", "cost_model: oracle");
        let (cfg, a) = analyzed(&text);
        assert!(a.throughput_ub.is_none());
        assert!(!a.workers[0].probeable);
        assert_eq!(a.probe_calls, 0);
        assert!(a.lint_diagnostics(&cfg).is_empty());
    }

    #[test]
    fn overload_with_tight_slo_is_e070_suppressing_w071() {
        let text = r#"
model: llama2-7b
cost_model: analytic
cluster:
  workers:
    - hardware: A100
      local_scheduler:
        policy: continuous
        max_batch_size: 4
workload:
  num_requests: 4000
  qps: 4000.0
  prompt_len:
    fixed: 64
  output_len:
    fixed: 4
  seed: 1
slo:
  ttft: 0.3
  mtpot: 0.05
"#;
        let (cfg, a) = analyzed(text);
        assert!(a.rho_decode.unwrap() > 1.0, "{:?}", a.rho_decode);
        let codes: Vec<String> = a
            .lint_diagnostics(&cfg)
            .iter()
            .map(|d| d.code.clone())
            .collect();
        assert!(codes.contains(&"E070".to_string()), "{codes:?}");
        assert!(!codes.contains(&"W071".to_string()), "{codes:?}");
    }

    #[test]
    fn marginal_overload_is_w071_not_e070() {
        // rho just over 1: the 90%-backlog bound stays inside the SLO
        // window but the full backlog provably overruns it
        let text = r#"
model: llama2-7b
cost_model: analytic
cluster:
  workers:
    - hardware: A100
      local_scheduler:
        policy: continuous
        max_batch_size: 8
workload:
  num_requests: 600
  qps: 120.0
  prompt_len:
    fixed: 64
  output_len:
    fixed: 16
  seed: 1
slo:
  ttft: 1.0
  mtpot: 0.05
"#;
        let (cfg, a) = analyzed(text);
        let codes: Vec<String> = a
            .lint_diagnostics(&cfg)
            .iter()
            .map(|d| d.code.clone())
            .collect();
        assert!(
            codes.contains(&"W071".to_string()) || codes.contains(&"E070".to_string()),
            "{codes:?} rho={:?}",
            a.rho_decode
        );
    }

    #[test]
    fn kv_residency_overflow_is_w073() {
        let text = r#"
model: llama2-7b
cost_model: analytic
cluster:
  workers:
    - hardware:
        name: tight
        peak_flops: 312e12
        mem_bw: 2.0e12
        mem_cap: 16e9
workload:
  num_requests: 100
  qps: 50.0
  prompt_len:
    fixed: 256
  output_len:
    fixed: 64
  seed: 1
"#;
        let (cfg, a) = analyzed(text);
        assert!(a.kv_bound_applicable);
        let codes: Vec<String> = a
            .lint_diagnostics(&cfg)
            .iter()
            .map(|d| d.code.clone())
            .collect();
        assert!(codes.contains(&"W073".to_string()), "{codes:?} {a:?}");
    }

    #[test]
    fn swap_manager_opts_out_of_w073() {
        let text = r#"
model: llama2-7b
cost_model: analytic
cluster:
  workers:
    - hardware:
        name: tight
        peak_flops: 312e12
        mem_bw: 2.0e12
        mem_cap: 16e9
      memory:
        manager: swap
        swap_blocks: 4000
workload:
  num_requests: 100
  qps: 50.0
  prompt_len:
    fixed: 256
  output_len:
    fixed: 64
  seed: 1
"#;
        let (cfg, a) = analyzed(text);
        assert!(!a.kv_bound_applicable);
        assert!(a.lint_diagnostics(&cfg).iter().all(|d| d.code != "W073"));
    }

    #[test]
    fn saturated_shared_segment_is_w072_on_the_bottleneck() {
        let text = r#"
model: llama2-7b
cost_model: analytic
cluster:
  workers:
    - hardware: A100
      run_decode: false
    - hardware: A100
      run_prefill: false
workload:
  num_requests: 40
  qps: 16.0
  prompt_len:
    fixed: 2048
  output_len:
    fixed: 8
  seed: 1
network:
  topology: ethernet
"#;
        let (cfg, a) = analyzed(text);
        let b = a.bottleneck.expect("bottleneck link");
        assert_eq!(a.links[b].link, "segment");
        assert!(a.links[b].utilization > 0.9, "{:?}", a.links[b]);
        let diags = a.lint_diagnostics(&cfg);
        assert_eq!(diags.iter().filter(|d| d.code == "W072").count(), 1);
    }

    #[test]
    fn flat_topology_reports_no_link_loads() {
        let text = BASE.to_string();
        let (_, a) = analyzed(&text);
        assert!(a.links.is_empty());
        assert!(a.bottleneck.is_none());
    }

    #[test]
    fn slo_below_floor_zeroes_max_feasible_qps() {
        let text = format!("{BASE}slo:\n  mtpot: 0.0000001\n");
        let (_, a) = analyzed(&text);
        assert!(a.slo_floor_infeasible);
        assert_eq!(a.max_feasible_qps, Some(0.0));
    }

    #[test]
    fn prune_fires_only_on_certain_infeasibility() {
        let healthy = SimulationConfig::from_yaml_str(BASE).unwrap();
        assert_eq!(prune(&healthy), None);
        let doomed = SimulationConfig::from_yaml_str(&format!(
            "{BASE}slo:\n  mtpot: 0.0000001\n"
        ))
        .unwrap();
        let reason = prune(&doomed).expect("E050-certain cell must prune");
        assert!(reason.contains("E050"), "{reason}");
    }

    #[test]
    fn analyze_text_appends_i074_summary() {
        let (report, analysis) = analyze_text("t", BASE);
        assert!(analysis.is_some());
        assert!(report.diagnostics.iter().any(|d| d.code == "I074"));
        assert!(report.passes(true), "{:?}", report.diagnostics);
    }

    #[test]
    fn json_round_trips() {
        let (_, a) = analyzed(BASE);
        let parsed = Json::parse(&a.to_json().to_string()).unwrap();
        assert!(parsed.get("throughput_ub").and_then(Json::as_f64).unwrap() > 0.0);
        assert_eq!(
            parsed.get("probe_calls").and_then(Json::as_f64),
            Some(a.probe_calls as f64)
        );
    }
}
