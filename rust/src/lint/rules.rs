//! The built-in semantic lint rules: cross-section feasibility checks
//! over a successfully parsed config and its generated workload.
//!
//! Every rule here is *static* — it sizes pools, reads registry
//! parameters and (for the SLO floor) makes O(1) cost-model calls, but
//! never steps the event engine. Rationale and example fixes for each
//! code live in docs/LINTS.md.

use crate::compute::{BatchDesc, ComputeCtx, ComputeSpec};
use crate::config::yaml::Yaml;
use crate::config::{WindowCost, WorkerConfig};
use crate::hardware::LinkSpec;
use crate::memory::PreemptionPolicy;
use crate::metrics::MetricsMode;
use crate::network::NetCtx;
use crate::scheduler::PolicySpec;

use super::{Diagnostic, LintCtx};

/// Run every built-in semantic rule, appending findings to `out`.
pub(crate) fn run(ctx: &LintCtx, out: &mut Vec<Diagnostic>) {
    pool_capacity(ctx, out); // E030
    token_budget(ctx, out); // E031, W032
    swap_viability(ctx, out); // E033
    affine_window(ctx, out); // W040, W041
    sketch_metrics(ctx, out); // I042
    slo_floor(ctx, out); // E050
    network_shape(ctx, out); // W062
    super::analyze::capacity_bounds(ctx, out); // E070, W071, W072, W073
}

/// Canonical registry name for a possibly-aliased selection, `None`
/// for runtime-registered entries the static tables do not know.
pub(crate) fn canonical_local(name: &str) -> Option<&'static str> {
    crate::scheduler::LOCAL_POLICIES
        .iter()
        .find(|e| {
            name.eq_ignore_ascii_case(e.name)
                || e.aliases.iter().any(|a| name.eq_ignore_ascii_case(a))
        })
        .map(|e| e.name)
}

pub(crate) fn canonical_memory(name: &str) -> Option<&'static str> {
    crate::memory::MEMORY_MANAGERS
        .iter()
        .find(|e| {
            name.eq_ignore_ascii_case(e.name)
                || e.aliases.iter().any(|a| name.eq_ignore_ascii_case(a))
        })
        .map(|e| e.name)
}

pub(crate) fn canonical_compute(name: &str) -> Option<&'static str> {
    crate::compute::COMPUTE_MODELS
        .iter()
        .find(|e| {
            name.eq_ignore_ascii_case(e.name)
                || e.aliases.iter().any(|a| name.eq_ignore_ascii_case(a))
        })
        .map(|e| e.name)
}

/// The compute spec worker `wc` actually runs (per-worker override
/// beats the cluster-wide selection).
pub(crate) fn compute_of<'a>(ctx: &'a LintCtx, wc: &'a WorkerConfig) -> &'a ComputeSpec {
    wc.compute.as_ref().unwrap_or(&ctx.cfg.compute)
}

// ---------------------------------------------------------------------------
// E030: worst-case request KV vs every decode-capable pool
// ---------------------------------------------------------------------------

/// The scheduler admits, preempts and retries — but no amount of
/// scheduling fits a request whose *final* KV footprint exceeds the
/// whole pool. When that holds on every decode-capable worker the run
/// is a guaranteed drain-deadlock; catching it here saves the full
/// sweep the deadlock would otherwise burn.
pub(crate) fn pool_capacity(ctx: &LintCtx, out: &mut Vec<Diagnostic>) {
    let Some(worst) = ctx.requests.iter().map(|r| r.final_kv_tokens()).max() else {
        return;
    };
    let mut sized: Vec<(usize, u64, u64)> = Vec::new(); // (worker idx, need, have)
    for (i, wc) in ctx.cfg.cluster.workers.iter().enumerate() {
        if !wc.run_decode {
            continue;
        }
        let Ok(mem) = wc.memory.build(&ctx.cfg.model, wc.hardware.mem_cap) else {
            return; // build errors already surfaced in pass 1/2
        };
        sized.push((i, mem.blocks_for_tokens(worst), mem.total_blocks()));
    }
    if sized.is_empty() || sized.iter().any(|&(_, need, have)| need <= have) {
        return;
    }
    let detail: Vec<String> = sized
        .iter()
        .map(|(i, need, have)| format!("worker {i}: {need} blocks needed, {have} in pool"))
        .collect();
    out.push(
        Diagnostic::error(
            "E030",
            format!(
                "the workload's largest request ({worst} KV tokens) cannot fit any \
                 decode-capable worker's KV pool — guaranteed scheduling deadlock \
                 ({})",
                detail.join("; ")
            ),
        )
        .with_fix(
            "shrink the workload's max context, raise hardware mem_cap / memory \
             gpu_utilization, or use larger devices",
        ),
    );
}

// ---------------------------------------------------------------------------
// E031 / W032: prompt length vs the batch-token budget
// ---------------------------------------------------------------------------

/// The admission token budget this local policy enforces per batch,
/// `None` when the policy can serve arbitrarily long prompts (chunked
/// prefill splits them; static batching has no token cap; unknown =
/// runtime-registered policies are given the benefit of the doubt).
pub(crate) fn policy_token_cap(spec: &PolicySpec) -> Option<u32> {
    match canonical_local(&spec.name)? {
        "continuous" | "priority" | "sjf" => Some(spec.params.opt_u32("max_batched_tokens", 8192)),
        _ => None,
    }
}

/// A prompt larger than `max_batched_tokens` is *never* admitted by the
/// token-budget policies (the budget is per batch and prefills do not
/// split): if every prefill-capable worker enforces a cap below the
/// workload's largest prompt, that request deadlocks the drain.
///
/// The companion W032 flags the opposite mismatch: a chunked-prefill
/// chunk at least as large as every prompt never actually chunks.
pub(crate) fn token_budget(ctx: &LintCtx, out: &mut Vec<Diagnostic>) {
    let Some(worst_prompt) = ctx.requests.iter().map(|r| r.prompt_len).max() else {
        return;
    };
    let mut caps: Vec<(usize, u32)> = Vec::new();
    let mut any_uncapped = false;
    for (i, wc) in ctx.cfg.cluster.workers.iter().enumerate() {
        if !wc.run_prefill {
            continue;
        }
        match policy_token_cap(&wc.local_scheduler) {
            Some(cap) if cap < worst_prompt => caps.push((i, cap)),
            _ => any_uncapped = true,
        }
        if canonical_local(&wc.local_scheduler.name) == Some("chunked_prefill") {
            let chunk = chunk_tokens(&wc.local_scheduler);
            if chunk >= worst_prompt {
                out.push(
                    Diagnostic::warn(
                        "W032",
                        format!(
                            "worker {i}: chunked_prefill chunk_tokens ({chunk}) >= the \
                             workload's largest prompt ({worst_prompt}); chunking never \
                             engages and the policy degrades to plain continuous batching"
                        ),
                    )
                    .with_fix("lower chunk_tokens below typical prompt lengths (e.g. 256-512)"),
                );
            }
        }
    }
    if !any_uncapped && !caps.is_empty() {
        let detail: Vec<String> = caps
            .iter()
            .map(|(i, cap)| format!("worker {i}: max_batched_tokens {cap}"))
            .collect();
        out.push(
            Diagnostic::error(
                "E031",
                format!(
                    "the workload's largest prompt ({worst_prompt} tokens) exceeds the \
                     batch-token budget of every prefill-capable worker ({}); such a \
                     prompt is never admitted — guaranteed scheduling deadlock",
                    detail.join("; ")
                ),
            )
            .with_fix(
                "raise max_batched_tokens above the largest prompt, or switch the policy \
                 to chunked_prefill",
            ),
        );
    }
}

pub(crate) fn chunk_tokens(spec: &PolicySpec) -> u32 {
    spec.params
        .get("chunk_tokens")
        .or_else(|| spec.params.get("chunk_size"))
        .and_then(Yaml::as_u32)
        .unwrap_or(512)
}

// ---------------------------------------------------------------------------
// E033: swap manager that can never swap
// ---------------------------------------------------------------------------

/// Swap preemption with zero host swap space silently degrades to
/// recompute; a host link without bandwidth makes every swap take
/// forever (or divide by zero). Both are contradictions worth failing
/// on rather than quietly mis-measuring.
fn swap_viability(ctx: &LintCtx, out: &mut Vec<Diagnostic>) {
    for (i, wc) in ctx.cfg.cluster.workers.iter().enumerate() {
        if canonical_memory(&wc.memory.name) != Some("swap") {
            continue;
        }
        let swap_blocks = wc.memory.params.get("swap_blocks").and_then(Yaml::as_u64);
        if swap_blocks == Some(0) && wc.memory.preemption().ok() == Some(PreemptionPolicy::Swap) {
            out.push(
                Diagnostic::error(
                    "E033",
                    format!(
                        "worker {i}: swap manager with 'swap_blocks: 0' under swap \
                         preemption — every preemption silently degrades to recompute"
                    ),
                )
                .with_fix(
                    "give the manager host swap space (swap_blocks > 0) or select \
                     'preemption: recompute' explicitly",
                ),
            );
            continue;
        }
        if let Ok(mem) = wc.memory.build(&ctx.cfg.model, wc.hardware.mem_cap) {
            match mem.swap_link() {
                Some(link) if link.bandwidth > 0.0 => {}
                Some(link) => out.push(
                    Diagnostic::error(
                        "E033",
                        format!(
                            "worker {i}: swap manager's host link '{}' has no bandwidth \
                             ({} B/s) — swap traffic can never complete",
                            link.name, link.bandwidth
                        ),
                    )
                    .with_fix("configure 'link:' with a positive bandwidth (e.g. HostBus)"),
                ),
                None => out.push(
                    Diagnostic::error(
                        "E033",
                        format!(
                            "worker {i}: swap manager exposes no host link — swap traffic \
                             cannot be charged"
                        ),
                    )
                    .with_fix("configure 'link:' with a host-bus link preset"),
                ),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// W040 / W041: affine window costing that can never engage
// ---------------------------------------------------------------------------

/// Is this compute selection affine-capable (declares
/// [`decode_window_affine`](crate::compute::ComputeModel::decode_window_affine))?
/// Decided statically from the registry name: analytic / roofline /
/// table are; memo forwards its base; everything else (including
/// runtime registrations) is assumed not to be.
fn affine_capable(spec: &ComputeSpec) -> bool {
    match canonical_compute(&spec.name) {
        Some("analytic") | Some("roofline") | Some("table") => true,
        Some("memo") => {
            let base = spec.params.get("base").and_then(Yaml::as_str).unwrap_or("hlo");
            matches!(
                canonical_compute(base),
                Some("analytic") | Some("roofline") | Some("table")
            )
        }
        _ => false,
    }
}

fn affine_window(ctx: &LintCtx, out: &mut Vec<Diagnostic>) {
    if ctx.cfg.engine.window_cost != WindowCost::Affine {
        return;
    }
    if !ctx.cfg.engine.fast_forward {
        out.push(
            Diagnostic::warn(
                "W041",
                "'window_cost: affine' with 'fast_forward: false' — window costing is \
                 only consulted inside fast-forwarded decode windows, so the setting \
                 never engages",
            )
            .with_fix("enable fast_forward, or drop window_cost back to replay"),
        );
        return;
    }
    let names: Vec<String> = ctx
        .cfg
        .cluster
        .workers
        .iter()
        .map(|wc| compute_of(ctx, wc).name.clone())
        .collect();
    if ctx
        .cfg
        .cluster
        .workers
        .iter()
        .any(|wc| affine_capable(compute_of(ctx, wc)))
    {
        return;
    }
    out.push(
        Diagnostic::warn(
            "W040",
            format!(
                "'window_cost: affine' but no worker's compute model ({}) declares an \
                 affine decode window — every window silently falls back to replay",
                names.join(", ")
            ),
        )
        .with_fix(
            "select an affine-capable model (analytic, roofline, table) or drop \
             window_cost back to replay",
        ),
    );
}

// ---------------------------------------------------------------------------
// I042: sketch-mode metrics
// ---------------------------------------------------------------------------

/// Not a defect — a documented trade-off the reader of the report must
/// know about, surfaced so CI configs that byte-diff reports are not
/// pointed at sketch output by accident.
fn sketch_metrics(ctx: &LintCtx, out: &mut Vec<Diagnostic>) {
    if ctx.cfg.metrics.mode == MetricsMode::Sketch {
        out.push(Diagnostic::info(
            "I042",
            format!(
                "metrics mode 'sketch': quantiles are approximations within ±{} relative \
                 error and reports are not byte-comparable to exact-mode output",
                ctx.cfg.metrics.sketch_error
            ),
        ));
    }
}

// ---------------------------------------------------------------------------
// E050: SLO below the physical per-iteration floor
// ---------------------------------------------------------------------------

/// Models cheap enough to build and probe statically. `hlo` falls back
/// to the analytic mirror when artifacts are absent, so it stays cheap
/// either way; the trained/co-simulated models are skipped — building
/// them costs minutes, which a linter must never do.
pub(crate) fn floor_probeable(spec: &ComputeSpec) -> bool {
    matches!(
        canonical_compute(&spec.name),
        Some("hlo") | Some("analytic") | Some("roofline")
    )
}

/// An SLO below the cost model's single-request iteration time cannot
/// be attained by any schedule: the decode floor bounds TPOT, the
/// single-prompt prefill time bounds TTFT (both at zero queueing).
/// `slo_attainment` would simply report 0% after the sweep burned its
/// budget — fail at lint time instead.
pub(crate) fn slo_floor(ctx: &LintCtx, out: &mut Vec<Diagnostic>) {
    let (Some(min_prompt), true) = (
        ctx.requests.iter().map(|r| r.prompt_len).min(),
        ctx.cfg.slo.ttft.is_some() || ctx.cfg.slo.mtpot.is_some(),
    ) else {
        return;
    };
    // best case over workers: the floor the *fastest* capable worker sets
    let mut decode_floor: Option<f64> = None;
    let mut prefill_floor: Option<f64> = None;
    for wc in &ctx.cfg.cluster.workers {
        let spec = compute_of(ctx, wc);
        if !floor_probeable(spec) {
            continue;
        }
        let Ok(mut model) = spec.build(&ComputeCtx {
            model: &ctx.cfg.model,
            hw: &wc.hardware,
            artifacts_dir: &ctx.cfg.artifacts_dir,
            worker: 0,
        }) else {
            continue;
        };
        if wc.run_decode {
            let mut b = BatchDesc::new();
            b.push(min_prompt, 1);
            let t = model.iter_time(&b);
            decode_floor = Some(decode_floor.map_or(t, |f: f64| f.min(t)));
        }
        if wc.run_prefill {
            let mut b = BatchDesc::new();
            b.push(0, min_prompt);
            let t = model.iter_time(&b);
            prefill_floor = Some(prefill_floor.map_or(t, |f: f64| f.min(t)));
        }
    }
    if let (Some(slo), Some(floor)) = (ctx.cfg.slo.mtpot, decode_floor) {
        if slo < floor {
            out.push(
                Diagnostic::error(
                    "E050",
                    format!(
                        "SLO mtpot {slo}s is below the compute model's single-request \
                         decode iteration floor ({floor:.6}s) — 0% attainment is \
                         guaranteed before the first request is served"
                    ),
                )
                .with_fix("raise the mtpot SLO above the per-iteration floor"),
            );
        }
    }
    if let (Some(slo), Some(floor)) = (ctx.cfg.slo.ttft, prefill_floor) {
        if slo < floor {
            out.push(
                Diagnostic::error(
                    "E050",
                    format!(
                        "SLO ttft {slo}s is below the compute model's zero-queue prefill \
                         floor for the smallest prompt ({floor:.6}s) — 0% attainment is \
                         guaranteed"
                    ),
                )
                .with_fix("raise the ttft SLO above the prefill floor"),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// W062: network topology shape vs worker count
// ---------------------------------------------------------------------------

/// A grouped topology (NVLink islands / fat-tree leaves) sized so every
/// worker lands in one group prices all traffic on the intra-group
/// link: the inter-group bridge/uplink the selection implies is never
/// exercised, and the run silently measures a flat fabric. Ragged
/// groups are flagged too — topology-aware replica routing assumes
/// same-shaped groups.
fn network_shape(ctx: &LintCtx, out: &mut Vec<Diagnostic>) {
    let n = ctx.cfg.total_workers() as usize;
    let Ok(model) = ctx.cfg.network.build(&NetCtx::uniform(n, LinkSpec::nvlink())) else {
        return; // unknown topology / bad params: pass 1 already reported it
    };
    let groups = model.replica_groups();
    if groups <= 1 {
        if matches!(model.name(), "nvlink_island" | "fat_tree") {
            out.push(
                Diagnostic::warn(
                    "W062",
                    format!(
                        "network topology '{}' places all {n} workers in a single \
                         island/leaf — the inter-group link is never exercised and the \
                         topology degrades to 'flat'",
                        model.name()
                    ),
                )
                .with_fix("shrink island_size/arity below the worker count, or select 'flat'"),
            );
        }
        return;
    }
    if n % groups != 0 {
        out.push(
            Diagnostic::warn(
                "W062",
                format!(
                    "network topology '{}' splits {n} workers into {groups} uneven \
                     groups — the ragged last group skews topology-aware replica routing",
                    model.name()
                ),
            )
            .with_fix("size the cluster to a multiple of the island/leaf size"),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::super::lint_text;

    fn base_with(workload: &str, extra: &str) -> String {
        format!(
            r#"
model: llama2-7b
cost_model: analytic
cluster:
  workers:
    - hardware: A100
{extra}workload:
{workload}"#
        )
    }

    const SMALL_WL: &str = "  num_requests: 5\n  qps: 10.0\n  prompt_len:\n    fixed: 64\n  output_len:\n    fixed: 8\n  seed: 1\n";

    fn codes(text: &str) -> Vec<String> {
        lint_text("t", text)
            .diagnostics
            .iter()
            .map(|d| d.code.clone())
            .collect()
    }

    #[test]
    fn pool_capacity_deadlock_is_e030() {
        let yaml = r#"
model: llama2-7b
cost_model: analytic
cluster:
  workers:
    - hardware:
        name: tiny
        peak_flops: 312e12
        mem_bw: 2.0e12
        mem_cap: 16e9
workload:
  num_requests: 1
  qps: 1.0
  prompt_len:
    fixed: 100000
  output_len:
    fixed: 4
  seed: 1
"#;
        let c = codes(yaml);
        assert!(c.contains(&"E030".to_string()), "{c:?}");
    }

    #[test]
    fn token_cap_deadlock_is_e031() {
        let extra = "      local_scheduler:\n        policy: continuous\n        max_batched_tokens: 64\n";
        let wl = "  num_requests: 2\n  qps: 1.0\n  prompt_len:\n    fixed: 1000\n  output_len:\n    fixed: 4\n  seed: 1\n";
        let c = codes(&base_with(wl, extra));
        assert_eq!(c, vec!["E031"]);
    }

    #[test]
    fn chunked_prefill_lifts_e031() {
        let extra = "      local_scheduler:\n        policy: chunked_prefill\n        chunk_tokens: 64\n";
        let wl = "  num_requests: 2\n  qps: 1.0\n  prompt_len:\n    fixed: 1000\n  output_len:\n    fixed: 4\n  seed: 1\n";
        let c = codes(&base_with(wl, extra));
        assert!(c.is_empty(), "{c:?}");
    }

    #[test]
    fn oversized_chunk_is_w032() {
        let extra = "      local_scheduler:\n        policy: chunked_prefill\n        chunk_tokens: 8192\n";
        let c = codes(&base_with(SMALL_WL, extra));
        assert_eq!(c, vec!["W032"]);
    }

    #[test]
    fn zero_swap_space_is_e033() {
        let extra = "      memory:\n        manager: swap\n        swap_blocks: 0\n";
        let c = codes(&base_with(SMALL_WL, extra));
        assert_eq!(c, vec!["E033"]);
    }

    #[test]
    fn healthy_swap_config_is_clean() {
        let extra = "      memory:\n        manager: swap\n        swap_blocks: 1000\n";
        let c = codes(&base_with(SMALL_WL, extra));
        assert!(c.is_empty(), "{c:?}");
    }

    #[test]
    fn non_affine_model_under_affine_window_is_w040() {
        let yaml = format!(
            "{}engine:\n  window_cost: affine\n",
            base_with(SMALL_WL, "").replace("cost_model: analytic", "cost_model: oracle")
        );
        let c = codes(&yaml);
        assert_eq!(c, vec!["W040"]);
    }

    #[test]
    fn affine_without_fast_forward_is_w041() {
        let yaml = format!(
            "{}engine:\n  fast_forward: false\n  window_cost: affine\n",
            base_with(SMALL_WL, "")
        );
        let c = codes(&yaml);
        assert_eq!(c, vec!["W041"]);
    }

    #[test]
    fn affine_capable_model_is_clean() {
        let yaml = format!("{}engine:\n  window_cost: affine\n", base_with(SMALL_WL, ""));
        let c = codes(&yaml);
        assert!(c.is_empty(), "{c:?}");
    }

    #[test]
    fn sketch_metrics_is_info_only() {
        let yaml = format!("{}metrics:\n  mode: sketch\n", base_with(SMALL_WL, ""));
        let r = lint_text("t", &yaml);
        assert_eq!(r.diagnostics.len(), 1);
        assert_eq!(r.diagnostics[0].code, "I042");
        // info never fails, even under --deny-warnings
        assert!(r.passes(true));
    }

    #[test]
    fn unattainable_slo_is_e050() {
        let yaml = format!("{}slo:\n  mtpot: 0.0000001\n", base_with(SMALL_WL, ""));
        let c = codes(&yaml);
        assert_eq!(c, vec!["E050"]);
    }

    #[test]
    fn paper_default_slos_are_attainable() {
        let yaml = format!("{}slo:\n  ttft: 15.0\n  mtpot: 0.3\n", base_with(SMALL_WL, ""));
        let c = codes(&yaml);
        assert!(c.is_empty(), "{c:?}");
    }

    #[test]
    fn single_island_topology_is_w062() {
        let yaml = format!(
            "{}network:\n  topology: nvlink_island\n  island_size: 8\n",
            base_with(SMALL_WL, "")
        );
        let c = codes(&yaml);
        assert_eq!(c, vec!["W062"]);
    }

    #[test]
    fn ragged_islands_are_w062() {
        let yaml = format!(
            "{}network:\n  topology: nvlink_island\n  island_size: 2\n",
            base_with(SMALL_WL, "    - hardware: A100\n    - hardware: A100\n")
        );
        let c = codes(&yaml);
        assert_eq!(c, vec!["W062"]);
    }

    #[test]
    fn well_shaped_island_topology_is_clean() {
        let yaml = format!(
            "{}network:\n  topology: nvlink_island\n  island_size: 1\n",
            base_with(SMALL_WL, "    - hardware: A100\n")
        );
        let c = codes(&yaml);
        assert!(c.is_empty(), "{c:?}");
    }
}
