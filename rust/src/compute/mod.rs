//! Compute cost models: per-iteration latency of a worker.
//!
//! The trait boundary mirrors the paper's Fig 1: "once a batch is formed
//! by the scheduler for an iteration, relevant information is sent to a
//! compute simulator … to determine iteration time. The architecture
//! supports diverse compute simulators." Implementations:
//!
//! * [`HloCost`] — the three-layer hot path: executes the AOT-compiled
//!   JAX/Pallas cost artifact through PJRT ([`crate::runtime`]).
//! * [`AnalyticCost`] — pure-rust mirror of the artifact semantics
//!   (`python/compile/kernels/ref.py`, same formulas and f32 precision,
//!   accumulated over exact integer batch aggregates); the fallback
//!   when artifacts are absent and the cross-validation comparator.
//! * [`TableCost`] — coefficient table extracted by probing another
//!   model at startup; the §Perf optimization of the hot path,
//!   registered as a composable layer (`table` over any probe-able
//!   base).
//! * [`RooflineCost`] — a single `max(FLOPs/peak, bytes/bw)` per
//!   iteration; the cheap-and-cheerful reference point.
//! * [`MemoizedCost`] — a composable caching layer (`memo` over any
//!   base, or `memoize: true` on the expensive built-ins): replays
//!   previously computed `iter_time` results bit-for-bit, keyed on the
//!   exact batch aggregates when the base is
//!   [aggregate-exact](ComputeModel::aggregate_exact) and on the full
//!   batch composition otherwise.
//! * Oracle / baseline models live in [`crate::oracle`] and
//!   [`crate::baselines`] and are registered here as `oracle`,
//!   `vidur_like` and `llmservingsim_like`.
//!
//! Models are selected by registry name ([`ComputeSpec`], YAML
//! `compute: {model: …}`) — see [`registry`]; [`register_compute`] adds
//! new simulators at runtime.

pub(crate) mod analytic;
mod hlo;
mod memo;
pub mod registry;
mod roofline;
mod table;

pub use analytic::{AnalyticCost, ATTN_GATHER_EFF};
pub use hlo::HloCost;
pub use memo::{CacheStats, MemoizedCost};
pub use registry::{
    build_compute, compute_models, register_compute, ComputeCtx, ComputeEntry, ComputeSpec,
    COMPUTE_MODELS,
};
pub use roofline::RooflineCost;
pub use table::{CostProbe, TableCost};

use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;

/// Number of operator slots in the cost artifact (mirrors `ref.NUM_OPS`).
pub const NUM_OPS: usize = 10;

/// Composition of one iteration's batch: per-request `(ctx, new)` pairs.
///
/// `ctx[i]` tokens are already in KV cache; `new[i]` tokens are computed
/// this iteration (prompt length during prefill, 1 during decode).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BatchDesc {
    pub ctx: Vec<u32>,
    pub new: Vec<u32>,
}

impl BatchDesc {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, ctx: u32, new: u32) {
        self.ctx.push(ctx);
        self.new.push(new);
    }

    pub fn len(&self) -> usize {
        self.ctx.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ctx.is_empty() || self.total_new() == 0
    }

    /// Total new tokens computed this iteration.
    pub fn total_new(&self) -> u64 {
        self.new.iter().map(|&n| n as u64).sum()
    }

    /// Total context tokens attended over.
    pub fn total_ctx(&self) -> u64 {
        self.ctx.iter().map(|&c| c as u64).sum()
    }

    /// Active (non-empty) request slots.
    pub fn active_requests(&self) -> usize {
        self.new.iter().filter(|&&n| n > 0).count()
    }

    /// Sum of `new * (ctx + new)` — the attention work term.
    pub fn attn_work(&self) -> u64 {
        self.ctx
            .iter()
            .zip(&self.new)
            .map(|(&c, &n)| n as u64 * (c as u64 + n as u64))
            .sum()
    }

    /// Sum of `ctx + new` over **all** slots, including inactive
    /// (`new == 0`) ones. Inactive slots still pin KV residency, so
    /// models that charge KV-gather traffic per resident token (the
    /// analytic mirror) depend on this aggregate rather than the
    /// active-only sum.
    pub fn total_tokens(&self) -> u64 {
        self.ctx
            .iter()
            .zip(&self.new)
            .map(|(&c, &n)| c as u64 + n as u64)
            .sum()
    }

    /// Sum of `ctx + new` over the active (`new > 0`) slots — the `S`
    /// aggregate the probe/table layer fits against.
    pub fn active_tokens(&self) -> u64 {
        self.ctx
            .iter()
            .zip(&self.new)
            .filter(|&(_, &n)| n > 0)
            .map(|(&c, &n)| c as u64 + n as u64)
            .sum()
    }

    /// The five exact integer aggregates `(T, R, A, S_all, S_active)`
    /// that fully determine `iter_time` for
    /// [aggregate-exact](ComputeModel::aggregate_exact) models — the
    /// memoization key.
    pub fn aggregates(&self) -> (u64, u64, u64, u64, u64) {
        let mut t = 0u64;
        let mut r = 0u64;
        let mut a = 0u64;
        let mut s_all = 0u64;
        let mut s_active = 0u64;
        for (&c, &n) in self.ctx.iter().zip(&self.new) {
            let total = c as u64 + n as u64;
            t += n as u64;
            s_all += total;
            if n > 0 {
                r += 1;
                a += n as u64 * total;
                s_active += total;
            }
        }
        (t, r, a, s_all, s_active)
    }
}

/// Full result of a cost-model evaluation.
#[derive(Debug, Clone, PartialEq)]
pub struct IterCost {
    /// End-to-end iteration latency, seconds.
    pub iter_time: f64,
    /// Single-instance operator times (one layer / one call), seconds.
    pub op_times: [f64; NUM_OPS],
    /// Per-request attention time (diagnostics), seconds.
    pub per_req_attn: Vec<f64>,
}

/// A per-(model, hardware) iteration cost model.
pub trait ComputeModel {
    /// Latency of one iteration with the given batch composition.
    fn iter_time(&mut self, batch: &BatchDesc) -> f64;

    /// Detailed evaluation; default adapters may skip per-request detail.
    fn iter_cost(&mut self, batch: &BatchDesc) -> IterCost {
        IterCost {
            iter_time: self.iter_time(batch),
            op_times: [0.0; NUM_OPS],
            per_req_attn: Vec::new(),
        }
    }

    /// Human-readable name for logs and reports.
    fn name(&self) -> &str;

    /// One-time setup cost in *simulator wall-clock* seconds this model
    /// incurred before the run (Vidur's ~400 s pre-training in Fig 6).
    fn setup_cost(&self) -> f64 {
        0.0
    }

    /// Linear-probe hook: models whose per-op costs are affine in the
    /// batch aggregates return `Some(self)` so the `table` accelerator
    /// layer can extract their coefficients. Default: not probe-able.
    fn as_probe(&mut self) -> Option<&mut dyn CostProbe> {
        None
    }

    /// Is `iter_time` a *bit-exact* pure function of the five integer
    /// batch aggregates `(T, R, A, S_all, S_active)` (see
    /// [`BatchDesc::aggregates`])? When true, [`MemoizedCost`] may key
    /// its cache on the aggregate tuple — two batch compositions with
    /// equal aggregates are guaranteed the same result — which is what
    /// makes memoization pay off in decode windows. When false (the
    /// default, and the safe answer for any model with per-slot
    /// non-linear terms or external evaluation), memoization falls back
    /// to keying on the full `(ctx, new)` composition, which is still
    /// bit-safe but rarely recurs.
    fn aggregate_exact(&self) -> bool {
        false
    }

    /// May the engine cost a *closed decode window* (see
    /// `engine: {window_cost: affine}`) from two probe calls, treating
    /// `iter_time` as affine in the window step? Only meaningful for
    /// stateless models that are (piecewise-)affine in the batch
    /// aggregates; stochastic models (oracle) and learned/tiled models
    /// (vidur_like, llmservingsim_like) must answer `false`. The engine
    /// additionally *verifies* the affine extrapolation against a real
    /// model call at the window boundary and falls back to per-step
    /// replay when a roofline knee breaks linearity.
    fn decode_window_affine(&self) -> bool {
        false
    }

    /// Memoization statistics, when this model (or a wrapper layer)
    /// caches results. Surfaced per worker in `WorkerStats` and
    /// `tokensim run --json`.
    fn cache_stats(&self) -> Option<CacheStats> {
        None
    }
}

/// A transparent wrapper counting every [`ComputeModel::iter_time`]
/// call made through it — the probe hook `tokensim analyze` uses to
/// *prove* it stays static: the analyzer asserts O(1) probe calls per
/// worker config and zero simulation steps.
pub struct CountingCost {
    inner: Box<dyn ComputeModel>,
    calls: std::sync::Arc<std::sync::atomic::AtomicUsize>,
}

impl CountingCost {
    /// Wrap `inner`, bumping `calls` on every `iter_time` evaluation.
    pub fn new(
        inner: Box<dyn ComputeModel>,
        calls: std::sync::Arc<std::sync::atomic::AtomicUsize>,
    ) -> Self {
        Self { inner, calls }
    }
}

impl ComputeModel for CountingCost {
    fn iter_time(&mut self, batch: &BatchDesc) -> f64 {
        self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.inner.iter_time(batch)
    }

    fn iter_cost(&mut self, batch: &BatchDesc) -> IterCost {
        self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.inner.iter_cost(batch)
    }

    fn name(&self) -> &str {
        self.inner.name()
    }

    fn setup_cost(&self) -> f64 {
        self.inner.setup_cost()
    }

    fn as_probe(&mut self) -> Option<&mut dyn CostProbe> {
        self.inner.as_probe()
    }

    fn aggregate_exact(&self) -> bool {
        self.inner.aggregate_exact()
    }

    fn decode_window_affine(&self) -> bool {
        self.inner.decode_window_affine()
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        self.inner.cache_stats()
    }
}

/// The pre-registry closed cost-model selector, kept for API
/// compatibility. [`ComputeSpec`] replaces it in configs; it converts
/// losslessly (`ComputeSpec::from(kind)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CostModelKind {
    /// PJRT-executed AOT artifact (fall back to analytic if missing).
    #[default]
    Hlo,
    /// Pure-rust mirror of the artifact semantics.
    Analytic,
    /// Coefficient table extracted from the HLO artifact (perf path).
    Table,
}

/// Construct the configured cost model for a (model, hardware) pair —
/// the pre-registry entry point, now a thin shim over the compute
/// registry.
///
/// `Hlo` and `Table` gracefully degrade to [`AnalyticCost`] when the
/// artifacts directory is missing (e.g. in unit tests), with a warning —
/// the two paths are cross-validated to agree to ~1e-4 relative.
pub fn build_cost_model(
    kind: CostModelKind,
    model: &ModelSpec,
    hw: &HardwareSpec,
    artifacts_dir: &str,
) -> Box<dyn ComputeModel> {
    let ctx = ComputeCtx {
        model,
        hw,
        artifacts_dir,
        worker: 0,
    };
    match ComputeSpec::from(kind).build(&ctx) {
        Ok(m) => m,
        // unreachable for the unshadowed built-ins (they take no
        // parameters and cannot fail), but a library user may shadow
        // a built-in name with a fallible builder via
        // `register_compute` — degrade gracefully instead of panicking
        Err(e) => {
            eprintln!("warning: building {kind:?} cost model failed ({e:#}); using analytic mirror");
            Box::new(AnalyticCost::new(model, hw))
        }
    }
}

pub(crate) fn warn_once(msg: &str) {
    use std::sync::atomic::{AtomicBool, Ordering};
    static WARNED: AtomicBool = AtomicBool::new(false);
    if !WARNED.swap(true, Ordering::Relaxed) {
        eprintln!("warning: {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_desc_aggregates() {
        let mut b = BatchDesc::new();
        b.push(100, 1);
        b.push(0, 50);
        b.push(0, 0); // empty slot
        assert_eq!(b.len(), 3);
        assert_eq!(b.total_new(), 51);
        assert_eq!(b.total_ctx(), 100);
        assert_eq!(b.active_requests(), 2);
        assert_eq!(b.attn_work(), 101 + 2500);
        assert!(!b.is_empty());
        assert_eq!(b.total_tokens(), 101 + 50);
        assert_eq!(b.active_tokens(), 101 + 50);
        assert_eq!(b.aggregates(), (51, 2, 101 + 2500, 151, 151));
    }

    #[test]
    fn inactive_slots_count_toward_total_tokens_only() {
        let mut b = BatchDesc::new();
        b.push(100, 1); // active decode slot
        b.push(40, 0); // resident but inactive (e.g. chunked prefill)
        assert_eq!(b.total_tokens(), 141);
        assert_eq!(b.active_tokens(), 101);
        assert_eq!(b.aggregates(), (1, 1, 101, 141, 101));
    }

    #[test]
    fn empty_batch_detection() {
        assert!(BatchDesc::new().is_empty());
        let mut b = BatchDesc::new();
        b.push(10, 0);
        assert!(b.is_empty(), "no new tokens means nothing to run");
    }
}
