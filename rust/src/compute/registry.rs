//! String-keyed compute-model registry — the fourth plugin subsystem,
//! mirroring [`crate::scheduler::registry`], [`crate::memory::registry`]
//! and [`crate::workload::registry`]. This completes the paper's Fig 1:
//! "the architecture supports diverse compute simulators".
//!
//! A cost model is selected by name — from YAML (`compute: {model: …}`,
//! or per worker) or programmatically via [`ComputeSpec`] — and built
//! from its parameter map by a registered constructor. The cluster
//! driver only ever sees `Box<dyn ComputeModel>`, so plugging in a new
//! compute simulator never touches `cluster/mod.rs`: implement the
//! trait, then either add a [`ComputeEntry`] to the built-in table or
//! call [`register_compute`] at startup.
//!
//! `table` is registered as a *composable accelerator layer*, not a
//! hard-wired special case: `compute: {model: table, base: analytic}`
//! probes any base model exposing
//! [`ComputeModel::as_probe`](super::ComputeModel::as_probe) and
//! replaces its hot path with the extracted coefficient table.
//!
//! `memo` is the second composable layer: `compute: {model: memo,
//! base: …}` wraps any deterministic base in [`MemoizedCost`], and the
//! expensive built-ins (`hlo`, `vidur_like`, `llmservingsim_like`) are
//! wrapped **by default** — opt out with `memoize: false`. The
//! stochastic `oracle` is never wrapped (caching would freeze its noise
//! draws).

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Context, Result};

use crate::baselines::{LlmServingSimLike, VidurLike};
use crate::config::yaml::Yaml;
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;
use crate::oracle::{OracleCost, OracleParams};

use super::{
    warn_once, AnalyticCost, ComputeModel, CostModelKind, HloCost, MemoizedCost, RooflineCost,
    TableCost,
};

/// Context a compute model is built against: the served model, the
/// worker's hardware, where HLO artifacts live, and the worker index
/// (diversifies the RNG streams of stochastic models like `oracle`).
pub struct ComputeCtx<'a> {
    pub model: &'a ModelSpec,
    pub hw: &'a HardwareSpec,
    /// Artifacts directory ("" = auto-discover).
    pub artifacts_dir: &'a str,
    pub worker: usize,
}

impl<'a> ComputeCtx<'a> {
    /// A context with default artifact discovery for worker 0.
    pub fn new(model: &'a ModelSpec, hw: &'a HardwareSpec) -> Self {
        Self {
            model,
            hw,
            artifacts_dir: "",
            worker: 0,
        }
    }
}

/// A declarative, cloneable compute-model selection: a registry name
/// plus a parameter map (the YAML subtree, or a programmatically built
/// map). This is what configs store — the built `Box<dyn ComputeModel>`
/// is neither cloneable nor comparable, and every worker needs its own
/// instance built for its own hardware.
///
/// The closed `CostModelKind` enum it replaces converts losslessly
/// (`ComputeSpec::from(CostModelKind::Table)`), so pre-registry call
/// sites keep working through [`super::build_cost_model`].
///
/// # Examples
///
/// ```
/// use tokensim::compute::{ComputeCtx, ComputeSpec};
/// use tokensim::hardware::HardwareSpec;
/// use tokensim::model::ModelSpec;
///
/// let model = ModelSpec::llama2_7b();
/// let hw = HardwareSpec::a100_80g();
/// let spec = ComputeSpec::new("table").with("base", "analytic");
/// let cost = spec.build(&ComputeCtx::new(&model, &hw)).unwrap();
/// assert!(cost.name().starts_with("table["));
///
/// // unknown names are errors listing the known models
/// assert!(ComputeSpec::new("quantum").validate().is_err());
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct ComputeSpec {
    /// Registry name (case-insensitive; aliases accepted).
    pub name: String,
    /// Model parameters (a [`Yaml::Map`]).
    pub params: Yaml,
}

impl Default for ComputeSpec {
    /// The default model: `hlo` (PJRT artifact, analytic fallback).
    fn default() -> Self {
        Self::new("hlo")
    }
}

impl ComputeSpec {
    /// A spec with no parameters (registry defaults apply).
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            params: Yaml::Map(Default::default()),
        }
    }

    /// Builder-style parameter.
    pub fn with(mut self, key: &str, value: impl Into<Yaml>) -> Self {
        if let Yaml::Map(m) = &mut self.params {
            m.insert(key.to_string(), value.into());
        }
        self
    }

    /// Parse from a YAML map of the form `{model: <name>, <params>…}`,
    /// or a bare name string. A map without a `model` key selects `hlo`
    /// (the pre-registry default).
    pub fn from_yaml(y: &Yaml) -> Result<Self> {
        if let Some(name) = y.as_str() {
            // `compute: analytic` — scalar shorthand, no parameters
            return Ok(Self::new(name));
        }
        let name = match y.get("model") {
            None => "hlo".to_string(),
            Some(v) => v
                .as_str()
                .context("'model' must be a string (a compute-model name)")?
                .to_string(),
        };
        Ok(Self {
            name,
            params: y.clone(),
        })
    }

    /// Build the model this spec names for the given (model, hardware)
    /// pair.
    pub fn build(&self, ctx: &ComputeCtx) -> Result<Box<dyn ComputeModel>> {
        build_compute(self, ctx)
    }

    /// Check the spec without sizing it for real hardware: unknown
    /// names, typo'd parameter keys and malformed values are errors at
    /// parse time, not mid-simulation.
    pub fn validate(&self) -> Result<()> {
        let model = ModelSpec::tiny_test();
        let hw = HardwareSpec::a100_80g();
        self.build(&ComputeCtx::new(&model, &hw)).map(|_| ())
    }
}

impl From<CostModelKind> for ComputeSpec {
    /// Lossless conversion from the pre-registry enum: `Table` keeps
    /// its hard-wired meaning (a table layered over `hlo`).
    fn from(kind: CostModelKind) -> Self {
        match kind {
            CostModelKind::Hlo => Self::new("hlo"),
            CostModelKind::Analytic => Self::new("analytic"),
            CostModelKind::Table => Self::new("table"),
        }
    }
}

/// A built-in compute model: name, aliases, summary, parameter keys,
/// constructor.
pub struct ComputeEntry {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    /// One-line description (shown by `tokensim list`).
    pub summary: &'static str,
    /// Accepted parameter keys — anything else in the spec is an error
    /// (catches typo'd keys at parse time).
    pub params: &'static [&'static str],
    pub build: fn(&Yaml, &ComputeCtx) -> Result<Box<dyn ComputeModel>>,
}

// Strict optional accessors: a *missing* key takes the default, but a
// present-and-malformed value is an error rather than a silent default.

fn opt_u64_strict(p: &Yaml, key: &str, default: u64) -> Result<u64> {
    match p.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_u64()
            .with_context(|| format!("'{key}' must be a non-negative integer")),
    }
}

fn opt_f64_strict(p: &Yaml, key: &str, default: f64) -> Result<f64> {
    match p.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .with_context(|| format!("'{key}' must be a number")),
    }
}

fn opt_bool_strict(p: &Yaml, key: &str, default: bool) -> Result<bool> {
    match p.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_bool()
            .with_context(|| format!("'{key}' must be a boolean")),
    }
}

/// Per-worker seed mix, shared with the experiment harness's oracle
/// cost factory so registry-built and factory-built oracle workers
/// draw identical noise streams.
pub fn worker_seed(seed: u64, worker: usize) -> u64 {
    seed ^ (worker as u64).wrapping_mul(0x9E37_79B9)
}

fn build_hlo(_p: &Yaml, ctx: &ComputeCtx) -> Result<Box<dyn ComputeModel>> {
    match HloCost::load(ctx.model, ctx.hw, ctx.artifacts_dir) {
        Ok(m) => Ok(Box::new(m)),
        Err(e) => {
            warn_once(&format!(
                "HLO cost artifact unavailable ({e}); using analytic mirror"
            ));
            Ok(Box::new(AnalyticCost::new(ctx.model, ctx.hw)))
        }
    }
}

fn build_analytic(_p: &Yaml, ctx: &ComputeCtx) -> Result<Box<dyn ComputeModel>> {
    Ok(Box::new(AnalyticCost::new(ctx.model, ctx.hw)))
}

fn build_roofline(_p: &Yaml, ctx: &ComputeCtx) -> Result<Box<dyn ComputeModel>> {
    Ok(Box::new(RooflineCost::new(ctx.model, ctx.hw)))
}

thread_local! {
    /// Extracted-table cache keyed by (base model name, model vector,
    /// hardware vector): probing costs ~10 base-model executions, and
    /// SLO sweeps construct hundreds of simulations per (model, hw)
    /// pair.
    #[allow(clippy::type_complexity)]
    static TABLES: RefCell<HashMap<(String, [u32; 8], [u64; 6]), TableCost>> =
        RefCell::new(HashMap::new());

    /// Trained-forest cache for `vidur_like` (training profiles the
    /// oracle on ~1.5k batches; SLO searches rebuild workers per probe).
    #[allow(clippy::type_complexity)]
    static FORESTS: RefCell<HashMap<([u32; 8], [u64; 6], u64, u64), VidurLike>> =
        RefCell::new(HashMap::new());
}

fn hw_key(model: &ModelSpec, hw: &HardwareSpec) -> ([u32; 8], [u64; 6]) {
    let m = model.to_vec().map(|v| v.to_bits());
    let h = hw.to_vec().map(|v| (v as f64).to_bits());
    (m, h)
}

fn build_table(p: &Yaml, ctx: &ComputeCtx) -> Result<Box<dyn ComputeModel>> {
    let base_name = match p.get("base") {
        None => "hlo",
        Some(v) => v
            .as_str()
            .context("'base' must be a string (a compute-model name)")?,
    };
    // resolve the base exactly like build_compute: runtime-registered
    // models shadow built-ins, so a user's probe-able model works as a
    // table base too. Only immutable built-in bases are table-cached —
    // a registered name can be re-registered (latest wins), so a cached
    // extraction could silently serve the *previous* model's physics.
    let (canonical, build, cacheable): (String, DynBuild, bool) = match find_extra(base_name) {
        Some(build) => (base_name.to_ascii_lowercase(), build, false),
        None => {
            let entry = find_builtin(base_name).with_context(|| {
                format!(
                    "unknown table base '{base_name}' (probe-able built-ins: hlo, analytic, \
                     roofline; runtime-registered models also accepted)"
                )
            })?;
            if entry.name == "table" {
                bail!("'table' cannot layer over itself");
            }
            // a plain fn pointer already implements the Fn traits
            let build: DynBuild = Arc::new(entry.build);
            (entry.name.to_string(), build, true)
        }
    };
    let (mk, hk) = hw_key(ctx.model, ctx.hw);
    let key = (canonical.clone(), mk, hk);
    if cacheable {
        if let Some(t) = TABLES.with(|c| c.borrow().get(&key).cloned()) {
            return Ok(Box::new(t));
        }
    }
    // the base is built with its registry defaults (a probe-able model
    // is deterministic, so there is nothing else to configure)
    let mut base = (*build)(&Yaml::Map(Default::default()), ctx)
        .with_context(|| format!("building table base '{canonical}'"))?;
    let Some(probe) = base.as_probe() else {
        bail!(
            "compute model '{canonical}' exposes no linear-probe hook; 'table' can only \
             accelerate probe-able models (built-ins: hlo, analytic, roofline)"
        )
    };
    let table = TableCost::build(probe, ctx.model, ctx.hw);
    if cacheable {
        TABLES.with(|c| c.borrow_mut().insert(key, table.clone()));
    }
    Ok(Box::new(table))
}

fn build_memo(p: &Yaml, ctx: &ComputeCtx) -> Result<Box<dyn ComputeModel>> {
    let base_name = match p.get("base") {
        None => "hlo",
        Some(v) => v
            .as_str()
            .context("'base' must be a string (a compute-model name)")?,
    };
    // resolve like `table`: runtime-registered models shadow built-ins.
    // The raw entry builder is invoked directly, so a default-memoized
    // base ('hlo', …) is not wrapped twice.
    let build: DynBuild = match find_extra(base_name) {
        Some(build) => build,
        None => {
            let entry = find_builtin(base_name).with_context(|| {
                format!(
                    "unknown memo base '{base_name}' (any deterministic compute model; \
                     runtime-registered models also accepted)"
                )
            })?;
            if entry.name == "memo" {
                bail!("'memo' cannot layer over itself");
            }
            if entry.name == "oracle" {
                bail!(
                    "'memo' cannot cache the stochastic 'oracle' model: caching would freeze \
                     one noise draw per batch key and change the modeled distribution"
                );
            }
            Arc::new(entry.build)
        }
    };
    let base = (*build)(&Yaml::Map(Default::default()), ctx)
        .with_context(|| format!("building memo base '{base_name}'"))?;
    Ok(Box::new(MemoizedCost::new(base)))
}

fn build_oracle(p: &Yaml, ctx: &ComputeCtx) -> Result<Box<dyn ComputeModel>> {
    let mut params = match p.get("preset") {
        None => OracleParams::vllm(),
        Some(v) => match v.as_str() {
            Some("vllm") => OracleParams::vllm(),
            Some("distserve") => OracleParams::distserve(),
            Some(other) => bail!("unknown oracle preset '{other}' (known: vllm, distserve)"),
            None => bail!("'preset' must be a string (vllm or distserve)"),
        },
    };
    params.noise_sigma = opt_f64_strict(p, "noise_sigma", params.noise_sigma)?;
    let seed = worker_seed(opt_u64_strict(p, "seed", 0)?, ctx.worker);
    Ok(Box::new(OracleCost::new(ctx.model, ctx.hw, params, seed)))
}

fn build_vidur_like(p: &Yaml, ctx: &ComputeCtx) -> Result<Box<dyn ComputeModel>> {
    let samples = opt_u64_strict(p, "samples", 1500)?;
    let seed = opt_u64_strict(p, "seed", 42)?;
    let (mk, hk) = hw_key(ctx.model, ctx.hw);
    let key = (mk, hk, samples, seed);
    if let Some(v) = FORESTS.with(|c| c.borrow().get(&key).cloned()) {
        return Ok(Box::new(v));
    }
    let forest = VidurLike::train(ctx.model, ctx.hw, samples as usize, seed);
    FORESTS.with(|c| c.borrow_mut().insert(key, forest.clone()));
    Ok(Box::new(forest))
}

fn build_llmservingsim_like(_p: &Yaml, ctx: &ComputeCtx) -> Result<Box<dyn ComputeModel>> {
    Ok(Box::new(LlmServingSimLike::new(ctx.model, ctx.hw)))
}

/// Built-in compute models.
pub const COMPUTE_MODELS: &[ComputeEntry] = &[
    ComputeEntry {
        name: "hlo",
        aliases: &["pjrt", "artifact"],
        summary: "PJRT-executed AOT cost artifact (falls back to analytic when absent)",
        params: &["memoize"],
        build: build_hlo,
    },
    ComputeEntry {
        name: "analytic",
        aliases: &["mirror", "ref"],
        summary: "pure-rust mirror of the artifact semantics (aggregate-exact)",
        params: &[],
        build: build_analytic,
    },
    ComputeEntry {
        name: "table",
        aliases: &["extracted", "fast"],
        summary: "coefficient table extracted from a probe-able base model (perf path)",
        params: &["base"],
        build: build_table,
    },
    ComputeEntry {
        name: "memo",
        aliases: &["memoized", "cache"],
        summary: "bit-exact memoization layer over any deterministic base model",
        params: &["base"],
        build: build_memo,
    },
    ComputeEntry {
        name: "roofline",
        aliases: &["napkin"],
        summary: "single max(FLOPs/peak, bytes/bw) per iteration, no per-op breakdown",
        params: &[],
        build: build_roofline,
    },
    ComputeEntry {
        name: "oracle",
        aliases: &["reference"],
        summary: "high-fidelity reference executor (GEMM ramp, noise; the 'real system')",
        params: &["preset", "noise_sigma", "seed"],
        build: build_oracle,
    },
    ComputeEntry {
        name: "vidur_like",
        aliases: &["vidur", "forest"],
        summary: "Vidur-style learned regression (oracle-profiled random forest, ~400s setup)",
        params: &["samples", "seed", "memoize"],
        build: build_vidur_like,
    },
    ComputeEntry {
        name: "llmservingsim_like",
        aliases: &["llmservingsim", "cosim"],
        summary: "LLMServingSim-style tile-walking co-simulation (slow, short prompts only)",
        params: &["memoize"],
        build: build_llmservingsim_like,
    },
];

/// Built-ins expensive enough that [`MemoizedCost`] wraps them by
/// default (`memoize: false` opts out). Applied in [`build_compute`] —
/// *after* the entry builder — so composed layers (`table`/`memo` bases)
/// resolve the raw model and never double-wrap.
const MEMOIZE_BY_DEFAULT: &[&str] = &["hlo", "vidur_like", "llmservingsim_like"];

// ---------------------------------------------------------------------------
// Runtime registration (library users; built-ins live in the table)
// ---------------------------------------------------------------------------

/// Runtime builders live behind `Arc` so lookups can clone the handle
/// and release the registry lock *before* invoking the builder — a
/// builder is then free to compose other models by name (the pattern
/// the built-in `table` layer demonstrates) or even register more
/// models without deadlocking on the non-reentrant mutex.
type DynBuild = Arc<dyn Fn(&Yaml, &ComputeCtx) -> Result<Box<dyn ComputeModel>> + Send + Sync>;

struct DynComputeEntry {
    name: String,
    summary: String,
    build: DynBuild,
}

fn extra_computes() -> &'static Mutex<Vec<DynComputeEntry>> {
    static EXTRA: OnceLock<Mutex<Vec<DynComputeEntry>>> = OnceLock::new();
    EXTRA.get_or_init(|| Mutex::new(Vec::new()))
}

/// Clone the newest runtime-registered builder for `name`, holding the
/// registry lock only for the lookup.
fn find_extra(name: &str) -> Option<DynBuild> {
    let extras = extra_computes().lock().unwrap();
    extras
        .iter()
        .rev()
        .find(|e| name.eq_ignore_ascii_case(&e.name))
        .map(|e| Arc::clone(&e.build))
}

/// Register a compute model at runtime. Registered names take
/// precedence over built-ins, so a library user can also shadow a
/// built-in model.
///
/// # Examples
///
/// A "bring your own compute simulator" flow — any [`ComputeModel`]
/// implementation becomes selectable by name, including from YAML:
///
/// ```
/// use tokensim::compute::{register_compute, BatchDesc, ComputeCtx, ComputeModel, ComputeSpec};
/// use tokensim::hardware::HardwareSpec;
/// use tokensim::model::ModelSpec;
///
/// /// Fixed 1 ms per iteration (demo).
/// struct FlatMillisecond;
///
/// impl ComputeModel for FlatMillisecond {
///     fn iter_time(&mut self, batch: &BatchDesc) -> f64 {
///         if batch.is_empty() { 0.0 } else { 1e-3 }
///     }
///     fn name(&self) -> &str { "flat_ms" }
/// }
///
/// register_compute("flat_ms", "1 ms per iteration (demo)", |_params, _ctx| {
///     Ok(Box::new(FlatMillisecond))
/// });
///
/// let model = ModelSpec::tiny_test();
/// let hw = HardwareSpec::a100_80g();
/// let cost = ComputeSpec::new("flat_ms").build(&ComputeCtx::new(&model, &hw)).unwrap();
/// assert_eq!(cost.name(), "flat_ms");
/// ```
pub fn register_compute(
    name: &str,
    summary: &str,
    build: impl Fn(&Yaml, &ComputeCtx) -> Result<Box<dyn ComputeModel>> + Send + Sync + 'static,
) {
    extra_computes().lock().unwrap().push(DynComputeEntry {
        name: name.to_string(),
        summary: summary.to_string(),
        build: Arc::new(build),
    });
}

fn matches_name(candidate: &str, name: &str, aliases: &[&str]) -> bool {
    candidate.eq_ignore_ascii_case(name)
        || aliases.iter().any(|a| candidate.eq_ignore_ascii_case(a))
}

fn find_builtin(name: &str) -> Option<&'static ComputeEntry> {
    COMPUTE_MODELS
        .iter()
        .find(|e| matches_name(name, e.name, e.aliases))
}

/// Reject typo'd parameter keys for built-in models ("model" itself is
/// the selector key YAML specs carry). Runtime-registered models
/// validate their own params in their builder.
fn check_param_keys(spec: &ComputeSpec, known: &[&str]) -> Result<()> {
    if let Yaml::Map(m) = &spec.params {
        for key in m.keys() {
            if key != "model" && !known.contains(&key.as_str()) {
                bail!(
                    "unknown parameter '{key}' for compute model '{}' (accepted: {})",
                    spec.name,
                    if known.is_empty() {
                        "none".to_string()
                    } else {
                        known.join(", ")
                    }
                );
            }
        }
    }
    Ok(())
}

/// Build a compute model from a spec. Unknown names list the known
/// models in the error.
pub fn build_compute(spec: &ComputeSpec, ctx: &ComputeCtx) -> Result<Box<dyn ComputeModel>> {
    // the registry lock is released before the builder runs (see
    // [`DynBuild`]), so builders may recursively build by name
    if let Some(build) = find_extra(&spec.name) {
        return (*build)(&spec.params, ctx)
            .with_context(|| format!("building compute model '{}'", spec.name));
    }
    let entry = find_builtin(&spec.name).with_context(|| {
        format!(
            "unknown compute model '{}' (known: {})",
            spec.name,
            compute_models()
                .iter()
                .map(|(n, _, _)| n.as_str())
                .collect::<Vec<_>>()
                .join(", ")
        )
    })?;
    check_param_keys(spec, entry.params)?;
    let built = (entry.build)(&spec.params, ctx)
        .with_context(|| format!("building compute model '{}'", spec.name))?;
    let wrap = MEMOIZE_BY_DEFAULT.contains(&entry.name)
        && opt_bool_strict(&spec.params, "memoize", true)?;
    if wrap {
        return Ok(Box::new(MemoizedCost::new(built)));
    }
    Ok(built)
}

/// All registered compute models as `(name, summary, accepted-params)`,
/// built-ins first.
pub fn compute_models() -> Vec<(String, String, String)> {
    let mut out: Vec<(String, String, String)> = COMPUTE_MODELS
        .iter()
        .map(|e| {
            (
                e.name.to_string(),
                e.summary.to_string(),
                if e.params.is_empty() {
                    "(none)".to_string()
                } else {
                    e.params.join(", ")
                },
            )
        })
        .collect();
    for e in extra_computes().lock().unwrap().iter() {
        out.push((e.name.clone(), e.summary.clone(), "(model-defined)".to_string()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_parts() -> (ModelSpec, HardwareSpec) {
        (ModelSpec::llama2_7b(), HardwareSpec::a100_80g())
    }

    fn decode(n: usize, ctx_len: u32) -> crate::compute::BatchDesc {
        let mut b = crate::compute::BatchDesc::new();
        for _ in 0..n {
            b.push(ctx_len, 1);
        }
        b
    }

    #[test]
    fn builds_every_builtin_model() {
        let (model, hw) = ctx_parts();
        let ctx = ComputeCtx::new(&model, &hw);
        for e in COMPUTE_MODELS {
            // keep the smoke test fast: a small forest is still a forest
            let spec = if e.name == "vidur_like" {
                ComputeSpec::new(e.name).with("samples", 200u64)
            } else {
                ComputeSpec::new(e.name)
            };
            let mut m = spec
                .build(&ctx)
                .unwrap_or_else(|err| panic!("{}: {err:#}", e.name));
            assert!(m.iter_time(&decode(4, 64)) > 0.0, "{} must cost time", e.name);
        }
    }

    #[test]
    fn aliases_and_case_resolve() {
        let (model, hw) = ctx_parts();
        let ctx = ComputeCtx::new(&model, &hw);
        for (alias, expect_prefix) in [
            ("Mirror", "analytic["),
            ("NAPKIN", "roofline["),
            ("cosim", "memo[llmservingsim-like["),
            ("reference", "oracle"),
        ] {
            let m = ComputeSpec::new(alias).build(&ctx).unwrap();
            assert!(
                m.name().starts_with(expect_prefix),
                "{alias} -> {}",
                m.name()
            );
        }
    }

    #[test]
    fn unknown_model_is_an_error_listing_known() {
        let err = ComputeSpec::new("quantum").validate().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown compute model"), "{msg}");
        assert!(msg.contains("vidur_like"), "{msg}");
    }

    #[test]
    fn typod_or_malformed_params_are_errors() {
        let err = ComputeSpec::new("oracle")
            .with("noise_sgima", 0.0)
            .validate()
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown parameter 'noise_sgima'"));
        let err = ComputeSpec::new("oracle")
            .with("preset", "tgi")
            .validate()
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown oracle preset"));
        let err = ComputeSpec::new("analytic")
            .with("base", "hlo")
            .validate()
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown parameter 'base'"));
    }

    #[test]
    fn table_layers_over_probeable_bases_only() {
        let (model, hw) = ctx_parts();
        let ctx = ComputeCtx::new(&model, &hw);
        for base in ["analytic", "roofline", "hlo"] {
            let m = ComputeSpec::new("table").with("base", base).build(&ctx);
            assert!(m.is_ok(), "table over {base}: {:?}", m.err());
        }
        let err = ComputeSpec::new("table")
            .with("base", "vidur_like")
            .build(&ctx)
            .unwrap_err();
        assert!(format!("{err:#}").contains("no linear-probe hook"), "{err:#}");
        let err = ComputeSpec::new("table")
            .with("base", "table")
            .build(&ctx)
            .unwrap_err();
        assert!(format!("{err:#}").contains("cannot layer over itself"));
    }

    #[test]
    fn table_over_roofline_reconstructs_it_exactly() {
        let (model, hw) = ctx_parts();
        let ctx = ComputeCtx::new(&model, &hw);
        let mut table = ComputeSpec::new("table")
            .with("base", "roofline")
            .build(&ctx)
            .unwrap();
        let mut base = ComputeSpec::new("roofline").build(&ctx).unwrap();
        for batch in [decode(16, 512), decode(200, 2048), {
            let mut b = crate::compute::BatchDesc::new();
            b.push(0, 777);
            b.push(123, 1);
            b
        }] {
            let tt = table.iter_time(&batch);
            let tb = base.iter_time(&batch);
            assert!(((tt - tb) / tb).abs() < 1e-6, "{tt} vs {tb}");
        }
    }

    #[test]
    fn expensive_builtins_are_memoized_by_default() {
        let (model, hw) = ctx_parts();
        let ctx = ComputeCtx::new(&model, &hw);
        // hlo (-> analytic fallback here) is wrapped unless opted out
        let wrapped = ComputeSpec::new("hlo").build(&ctx).unwrap();
        assert!(wrapped.name().starts_with("memo["), "{}", wrapped.name());
        assert!(wrapped.cache_stats().is_some());
        let raw = ComputeSpec::new("hlo")
            .with("memoize", false)
            .build(&ctx)
            .unwrap();
        assert!(!raw.name().starts_with("memo["), "{}", raw.name());
        assert!(raw.cache_stats().is_none());
        // cheap models stay unwrapped
        let analytic = ComputeSpec::new("analytic").build(&ctx).unwrap();
        assert!(analytic.cache_stats().is_none());
        // malformed opt-out is an error, not a silent default
        let err = ComputeSpec::new("hlo")
            .with("memoize", "yes")
            .validate()
            .unwrap_err();
        assert!(format!("{err:#}").contains("must be a boolean"), "{err:#}");
    }

    #[test]
    fn memo_layers_and_matches_its_base_bit_for_bit() {
        let (model, hw) = ctx_parts();
        let ctx = ComputeCtx::new(&model, &hw);
        let mut memo = ComputeSpec::new("memo")
            .with("base", "analytic")
            .build(&ctx)
            .unwrap();
        assert!(memo.name().starts_with("memo[analytic["), "{}", memo.name());
        let mut base = ComputeSpec::new("analytic").build(&ctx).unwrap();
        for batch in [decode(16, 512), decode(16, 512), decode(200, 2048)] {
            assert_eq!(
                memo.iter_time(&batch).to_bits(),
                base.iter_time(&batch).to_bits()
            );
        }
        let stats = memo.cache_stats().unwrap();
        assert_eq!((stats.hits, stats.misses), (1, 2));
    }

    #[test]
    fn memo_rejects_unsafe_compositions() {
        let err = ComputeSpec::new("memo")
            .with("base", "memo")
            .validate()
            .unwrap_err();
        assert!(format!("{err:#}").contains("cannot layer over itself"));
        let err = ComputeSpec::new("memo")
            .with("base", "oracle")
            .validate()
            .unwrap_err();
        assert!(format!("{err:#}").contains("stochastic"), "{err:#}");
        let err = ComputeSpec::new("memo")
            .with("base", "quantum")
            .validate()
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown memo base"), "{err:#}");
    }

    #[test]
    fn memo_base_resolution_never_double_wraps() {
        let (model, hw) = ctx_parts();
        let ctx = ComputeCtx::new(&model, &hw);
        // hlo is memoized by default, but `memo over hlo` resolves the
        // raw entry builder: exactly one layer
        let m = ComputeSpec::new("memo")
            .with("base", "hlo")
            .build(&ctx)
            .unwrap();
        assert!(!m.name().contains("memo[memo["), "{}", m.name());
    }

    #[test]
    fn cost_model_kind_converts_losslessly() {
        assert_eq!(ComputeSpec::from(CostModelKind::Hlo), ComputeSpec::new("hlo"));
        assert_eq!(
            ComputeSpec::from(CostModelKind::Analytic),
            ComputeSpec::new("analytic")
        );
        assert_eq!(
            ComputeSpec::from(CostModelKind::Table),
            ComputeSpec::new("table")
        );
        assert_eq!(ComputeSpec::default(), CostModelKind::default().into());
    }

    #[test]
    fn oracle_seeds_diversify_per_worker_but_stay_deterministic() {
        let (model, hw) = ctx_parts();
        let spec = ComputeSpec::new("oracle");
        let build = |worker: usize| {
            let ctx = ComputeCtx {
                model: &model,
                hw: &hw,
                artifacts_dir: "",
                worker,
            };
            spec.build(&ctx).unwrap()
        };
        let batch = decode(8, 256);
        let (mut a, mut b, mut c) = (build(0), build(0), build(1));
        let ta = a.iter_time(&batch);
        assert_eq!(ta, b.iter_time(&batch), "same worker, same stream");
        assert_ne!(ta, c.iter_time(&batch), "workers draw distinct noise");
    }

    #[test]
    fn runtime_builders_can_compose_other_models_by_name() {
        // regression: the registry lock used to be held across builder
        // invocation, so a builder that built its base by name — the
        // composition pattern `table` demonstrates — deadlocked
        register_compute("test_composed_analytic", "composition demo", |_p, ctx| {
            ComputeSpec::new("analytic").build(ctx)
        });
        let (model, hw) = ctx_parts();
        let m = ComputeSpec::new("test_composed_analytic")
            .build(&ComputeCtx::new(&model, &hw))
            .unwrap();
        assert!(m.name().starts_with("analytic["));
    }

    #[test]
    fn table_layers_over_runtime_registered_probeable_bases() {
        // a user's registered model that exposes the probe hook is a
        // valid `base:`, exactly as the module docs promise
        register_compute("test_probeable_base", "registered roofline", |_p, ctx| {
            Ok(Box::new(RooflineCost::new(ctx.model, ctx.hw)))
        });
        let (model, hw) = ctx_parts();
        let ctx = ComputeCtx::new(&model, &hw);
        let mut table = ComputeSpec::new("table")
            .with("base", "test_probeable_base")
            .build(&ctx)
            .unwrap();
        let mut base = ComputeSpec::new("roofline").build(&ctx).unwrap();
        let b = decode(8, 128);
        let (tt, tb) = (table.iter_time(&b), base.iter_time(&b));
        assert!(((tt - tb) / tb).abs() < 1e-6, "{tt} vs {tb}");
    }

    #[test]
    fn runtime_registration_shadows_builtins() {
        register_compute("test_shadow_analytic", "test", build_analytic);
        let (model, hw) = ctx_parts();
        let m = ComputeSpec::new("test_shadow_analytic")
            .build(&ComputeCtx::new(&model, &hw))
            .unwrap();
        assert!(m.name().starts_with("analytic["));
        assert!(compute_models()
            .iter()
            .any(|(n, _, _)| n == "test_shadow_analytic"));
    }
}
