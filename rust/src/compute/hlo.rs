//! The three-layer hot path: iteration costs from the AOT JAX/Pallas
//! artifact, executed through PJRT.

use anyhow::{ensure, Result};

use super::{BatchDesc, ComputeModel, IterCost, NUM_OPS};
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;
use crate::runtime::{CompiledArtifact, Manifest};

/// Cost model backed by `artifacts/iter_cost.hlo.txt`.
///
/// The artifact has a fixed number of batch-descriptor slots
/// (`manifest.batch_slots`, default 1024). Batches beyond that are
/// folded: overflow requests are merged into synthetic slots preserving
/// the aggregate `(Σnew, Σ new*(ctx+new))` terms, which the iteration
/// time depends on (per-request detail is lost only for the overflow).
pub struct HloCost {
    name: String,
    artifact: std::rc::Rc<CompiledArtifact>,
    slots: usize,
    model_vec: [f32; 8],
    hw_vec: [f32; 6],
    // reusable input buffers (hot path: avoid per-call allocation)
    ctx_buf: Vec<f32>,
    new_buf: Vec<f32>,
    /// Number of artifact executions (exposed for perf accounting).
    pub evaluations: u64,
}

impl HloCost {
    /// Load the iter-cost artifact for a (model, hardware) pair.
    pub fn load(model: &ModelSpec, hw: &HardwareSpec, artifacts_dir: &str) -> Result<Self> {
        let dir = if artifacts_dir.is_empty() {
            crate::runtime::default_artifacts_dir()
        } else {
            artifacts_dir.into()
        };
        let manifest = Manifest::load(&dir)?;
        let entry = manifest
            .artifacts
            .get("iter_cost")
            .ok_or_else(|| anyhow::anyhow!("manifest lacks iter_cost"))?;
        let artifact = CompiledArtifact::load_cached(dir.join(&entry.file))?;
        ensure!(manifest.batch_slots >= 2, "need at least 2 batch slots");
        Ok(Self {
            name: format!("hlo[{}/{}]", model.name, hw.name),
            artifact,
            slots: manifest.batch_slots,
            model_vec: model.to_vec(),
            hw_vec: hw.to_vec(),
            ctx_buf: vec![0.0; manifest.batch_slots],
            new_buf: vec![0.0; manifest.batch_slots],
            evaluations: 0,
        })
    }

    /// Fill the slot buffers from a batch, folding overflow (see struct
    /// docs). Returns the number of live slots.
    ///
    /// Folding uses the last two slots: slot `S-2` carries
    /// `(ctx*, new*)` with `new* = Σnew` and `ctx* = ΣA/Σnew - new*`,
    /// preserving the total new tokens and the attention work term
    /// `Σ new·(ctx+new)`; slot `S-1` carries `(rest, 0)` — a zero-new
    /// context-only slot that restores the KV-read traffic `Σ (ctx+new)`
    /// (the artifact charges KV bytes for context-only slots but no
    /// FLOPs). Only the active-row count of the small logits GEMM is
    /// approximated.
    fn fill_slots(&mut self, batch: &BatchDesc) -> usize {
        self.ctx_buf.fill(0.0);
        self.new_buf.fill(0.0);
        let direct = batch.len().min(self.slots - 2);
        for i in 0..direct {
            self.ctx_buf[i] = batch.ctx[i] as f32;
            self.new_buf[i] = batch.new[i] as f32;
        }
        if batch.len() > direct {
            let mut sum_new = 0.0f64;
            let mut work = 0.0f64;
            let mut sum_total = 0.0f64;
            for i in direct..batch.len() {
                let c = batch.ctx[i] as f64;
                let n = batch.new[i] as f64;
                sum_new += n;
                work += n * (c + n);
                sum_total += c + n;
            }
            if sum_new > 0.0 {
                let ctx_star = (work / sum_new - sum_new).max(0.0);
                self.ctx_buf[self.slots - 2] = ctx_star as f32;
                self.new_buf[self.slots - 2] = sum_new as f32;
                let rest = (sum_total - (ctx_star + sum_new)).max(0.0);
                self.ctx_buf[self.slots - 1] = rest as f32;
                self.new_buf[self.slots - 1] = 0.0;
            }
            self.slots
        } else {
            direct
        }
    }

    /// Evaluate under an arbitrary hardware vector (probe support for
    /// [`super::TableCost`] coefficient extraction).
    pub fn evaluate_with_hw(&mut self, batch: &BatchDesc, hw_vec: [f32; 6]) -> Result<IterCost> {
        let saved = self.hw_vec;
        self.hw_vec = hw_vec;
        let out = self.evaluate(batch);
        self.hw_vec = saved;
        out
    }

    /// Raw artifact evaluation.
    pub fn evaluate(&mut self, batch: &BatchDesc) -> Result<IterCost> {
        let live = self.fill_slots(batch);
        self.evaluations += 1;
        let ctx = std::mem::take(&mut self.ctx_buf);
        let new = std::mem::take(&mut self.new_buf);
        let out = self
            .artifact
            .run_f32(&[&ctx, &new, &self.model_vec, &self.hw_vec]);
        self.ctx_buf = ctx;
        self.new_buf = new;
        let out = out?;
        ensure!(
            out.len() == 1 + NUM_OPS + self.slots,
            "artifact output length {} != {}",
            out.len(),
            1 + NUM_OPS + self.slots
        );
        let mut op_times = [0.0f64; NUM_OPS];
        for (i, t) in out[1..1 + NUM_OPS].iter().enumerate() {
            op_times[i] = *t as f64;
        }
        let per_req_attn = out[1 + NUM_OPS..1 + NUM_OPS + live.min(batch.len())]
            .iter()
            .map(|&t| t as f64)
            .collect();
        Ok(IterCost {
            iter_time: out[0] as f64,
            op_times,
            per_req_attn,
        })
    }
}

impl ComputeModel for HloCost {
    fn iter_time(&mut self, batch: &BatchDesc) -> f64 {
        if batch.is_empty() {
            return 0.0;
        }
        self.evaluate(batch)
            .expect("artifact execution failed")
            .iter_time
    }

    fn iter_cost(&mut self, batch: &BatchDesc) -> IterCost {
        self.evaluate(batch).expect("artifact execution failed")
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn as_probe(&mut self) -> Option<&mut dyn super::CostProbe> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::AnalyticCost;

    fn try_load() -> Option<HloCost> {
        let dir = crate::runtime::default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(
            HloCost::load(
                &ModelSpec::llama2_7b(),
                &HardwareSpec::a100_80g(),
                dir.to_str().unwrap(),
            )
            .unwrap(),
        )
    }

    fn mixed_batch() -> BatchDesc {
        let mut b = BatchDesc::new();
        b.push(0, 512); // prefill
        for i in 0..31 {
            b.push(100 + i * 37, 1); // decodes
        }
        b
    }

    #[test]
    fn hlo_matches_analytic_mirror() {
        let Some(mut hlo) = try_load() else { return };
        let analytic = AnalyticCost::new(&ModelSpec::llama2_7b(), &HardwareSpec::a100_80g());
        for batch in [mixed_batch(), {
            let mut b = BatchDesc::new();
            b.push(2048, 1);
            b
        }] {
            let h = hlo.evaluate(&batch).unwrap();
            let a = analytic.evaluate(&batch);
            let rel = (h.iter_time - a.iter_time).abs() / a.iter_time;
            assert!(rel < 1e-4, "iter_time rel err {rel}: {h:?} vs {a:?}");
            for i in 0..NUM_OPS {
                let (ht, at) = (h.op_times[i], a.op_times[i]);
                if at > 0.0 {
                    assert!(((ht - at) / at).abs() < 1e-3, "op {i}: {ht} vs {at}");
                }
            }
        }
    }

    #[test]
    fn overflow_folding_preserves_aggregates() {
        let Some(mut hlo) = try_load() else { return };
        // batch larger than slot count
        let mut big = BatchDesc::new();
        for i in 0..(hlo.slots + 500) {
            big.push((i % 1024) as u32, 1);
        }
        let t_big = hlo.iter_time(&big);
        assert!(t_big > 0.0);
        // folding preserves T and the attention work term exactly but
        // under-counts active rows for the (small) logits GEMM, so the
        // folded estimate sits within a few percent of the exact value
        let analytic = AnalyticCost::new(&ModelSpec::llama2_7b(), &HardwareSpec::a100_80g());
        let a = analytic.evaluate(&big).iter_time;
        assert!(((t_big - a) / a).abs() < 0.02, "{t_big} vs {a}");
    }

    #[test]
    fn empty_batch_short_circuits() {
        let Some(mut hlo) = try_load() else { return };
        assert_eq!(hlo.iter_time(&BatchDesc::new()), 0.0);
        assert_eq!(hlo.evaluations, 0, "no artifact call for empty batch");
    }
}
