//! Pure-rust mirror of the L2/L1 cost artifact semantics.
//!
//! Formula-for-formula identical to `python/compile/kernels/ref.py`,
//! with the same f32 precision — but the attention accumulators are
//! computed from the *exact integer batch aggregates* rather than a
//! per-slot f32 sum. The per-slot and aggregated forms are identical in
//! exact arithmetic (every attention term is linear in `(T, A, S_all)`),
//! and the aggregated form is what makes `iter_time` a bit-exact pure
//! function of the aggregates ([`ComputeModel::aggregate_exact`]) so
//! the memoization layer can key on them. The integration test-suite
//! cross-validates this mirror against the loaded HLO artifact (~1e-4
//! relative); keeping both lets unit tests and artifact-less builds run
//! the full simulator.

use super::{BatchDesc, ComputeModel, IterCost, NUM_OPS};
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;

const ALLREDUCE_IDX: usize = 8;
/// Paged-attention gather efficiency — mirrors `ref.ATTN_GATHER_EFF`.
pub const ATTN_GATHER_EFF: f32 = 0.7;
/// Ops that run once per iteration rather than once per layer
/// (`embed` = 0, `logits` = 9).
const PER_ITER: [bool; NUM_OPS] = [
    true, false, false, false, false, false, false, false, false, true,
];

/// Analytic roofline cost model (the `ref.py` mirror).
#[derive(Debug, Clone)]
pub struct AnalyticCost {
    name: String,
    model: [f32; 8],
    hw: [f32; 6],
}

impl AnalyticCost {
    pub fn new(model: &ModelSpec, hw: &HardwareSpec) -> Self {
        Self {
            name: format!("analytic[{}/{}]", model.name, hw.name),
            model: model.to_vec(),
            hw: hw.to_vec(),
        }
    }

    /// Per-request attention descriptors — mirror of `attn_cost_ref`.
    fn attn_descriptors(&self, ctx: f32, new: f32) -> (f32, f32, f32) {
        let h = self.model[0];
        let heads = self.model[2];
        let kv_heads = self.model[3];
        let dtype = self.model[6];
        let tp = self.model[7];
        let total = ctx + new;
        let h_kv = h * (kv_heads / heads);
        let flops = 4.0 * new * total * h / tp;
        let kv_bytes = (2.0 * total * h_kv / ATTN_GATHER_EFF + 2.0 * new * h_kv
            + 2.0 * new * h)
            * dtype
            / tp;
        let scores = new * total * heads / tp;
        (flops, kv_bytes, scores)
    }

    /// Roofline time — mirror of `roofline_time_ref`.
    #[inline]
    fn roofline(&self, flops: f32, bytes: f32, bw: f32) -> f32 {
        let peak = self.hw[0];
        let oh = self.hw[2];
        if flops > 0.0 || bytes > 0.0 {
            (flops / peak).max(bytes / bw) + oh
        } else {
            0.0
        }
    }

    /// Evaluate under an arbitrary hardware vector (probe support for
    /// [`super::TableCost`] extraction and the oracle's component
    /// decomposition); does not disturb the configured hardware.
    pub fn evaluate_with_hw(&self, batch: &BatchDesc, hw_vec: [f32; 6]) -> IterCost {
        let mut probe = self.clone();
        probe.hw = hw_vec;
        probe.evaluate(batch)
    }

    /// Full evaluation — mirror of `iter_cost_ref`.
    pub fn evaluate(&self, batch: &BatchDesc) -> IterCost {
        let bw = self.hw[1];
        let mut per_req = Vec::with_capacity(batch.len());
        for i in 0..batch.len() {
            let c = batch.ctx[i] as f32;
            let n = batch.new[i] as f32;
            let (f, b, _) = self.attn_descriptors(c, n);
            per_req.push(self.roofline(f, b, bw) as f64);
        }
        let (op_times, iter_time) = self.core(batch.aggregates());
        IterCost {
            iter_time,
            op_times,
            per_req_attn: per_req,
        }
    }

    /// Operator times + iteration latency from the exact integer batch
    /// aggregates `(T, R, A, S_all, _)` — the allocation-free core both
    /// [`Self::evaluate`] and the `iter_time` hot path share, which is
    /// what makes the two bit-identical and the model aggregate-exact.
    ///
    /// Every attention accumulator of `ref.py` is linear in the
    /// aggregates: `Σ 4·n·(c+n)·h/tp = 4·A·h/tp`,
    /// `Σ n·(c+n)·heads/tp = A·heads/tp`, and the KV-gather bytes sum to
    /// `(2·S_all·h_kv/eff + 2·T·h_kv + 2·T·h)·dtype/tp` — note `S_all`
    /// over **all** slots, because `attn_cost_ref` charges resident-KV
    /// gather traffic even for slots with `new == 0`.
    fn core(&self, aggregates: (u64, u64, u64, u64, u64)) -> ([f64; NUM_OPS], f64) {
        let (t_agg, r_agg, a_agg, s_all, _) = aggregates;
        let m = &self.model;
        let (h, layers, heads, kv_heads, ffn, vocab, dtype, tp) =
            (m[0], m[1], m[2], m[3], m[4], m[5], m[6], m[7]);
        let bw = self.hw[1];
        let iter_oh = self.hw[3];
        let net_bw = self.hw[4];

        let t_sum = t_agg as f32; // total new tokens
        let r_sum = r_agg as f32; // active requests
        let h_kv = h * (kv_heads / heads);
        let attn_flops = 4.0 * (a_agg as f32) * h / tp;
        let attn_bytes = (2.0 * (s_all as f32) * h_kv / ATTN_GATHER_EFF
            + 2.0 * t_sum * h_kv
            + 2.0 * t_sum * h)
            * dtype
            / tp;
        let score_elems = (a_agg as f32) * heads / tp;

        let g = kv_heads / heads;
        let qkv_out = h * (1.0 + 2.0 * g);
        let gemm = |m_rows: f32, k: f32, n: f32| -> (f32, f32) {
            let f = 2.0 * m_rows * k * n / tp;
            let b = (k * n / tp + m_rows * k + m_rows * n / tp) * dtype;
            (f, b)
        };

        let (qkv_f, qkv_b) = gemm(t_sum, h, qkv_out);
        let (out_f, out_b) = gemm(t_sum, h, h);
        let (up_f, up_b) = gemm(t_sum, h, 2.0 * ffn);
        let (down_f, down_b) = gemm(t_sum, ffn, h);
        let (logits_f, logits_b) = gemm(r_sum, h, vocab);

        let embed_b = t_sum * h * dtype;
        let softmax_f = 5.0 * score_elems;
        let softmax_b = 2.0 * score_elems * dtype;
        let ln_f = 2.0 * 4.0 * t_sum * h;
        let ln_b = 2.0 * 2.0 * t_sum * h * dtype;
        let ar_b = if tp > 1.0 {
            2.0 * 2.0 * (tp - 1.0) / tp * t_sum * h * dtype
        } else {
            0.0
        };

        let op_flops: [f32; NUM_OPS] = [
            0.0, qkv_f, attn_flops, softmax_f, out_f, up_f, down_f, ln_f, 0.0, logits_f,
        ];
        let op_bytes: [f32; NUM_OPS] = [
            embed_b, qkv_b, attn_bytes, softmax_b, out_b, up_b, down_b, ln_b, ar_b, logits_b,
        ];

        let mut op_times = [0.0f64; NUM_OPS];
        let mut per_layer = 0.0f32;
        let mut per_iter = 0.0f32;
        for i in 0..NUM_OPS {
            let eff_bw = if i == ALLREDUCE_IDX { net_bw } else { bw };
            let t = self.roofline(op_flops[i], op_bytes[i], eff_bw);
            op_times[i] = t as f64;
            if PER_ITER[i] {
                per_iter += t;
            } else {
                per_layer += t;
            }
        }

        let iter_time = if t_sum > 0.0 {
            (layers * per_layer + per_iter + iter_oh) as f64
        } else {
            0.0
        };
        (op_times, iter_time)
    }
}

impl ComputeModel for AnalyticCost {
    fn iter_time(&mut self, batch: &BatchDesc) -> f64 {
        // allocation-free fast path: same core as evaluate(), skipping
        // the per-request diagnostics vector
        self.core(batch.aggregates()).1
    }

    fn iter_cost(&mut self, batch: &BatchDesc) -> IterCost {
        self.evaluate(batch)
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn as_probe(&mut self) -> Option<&mut dyn super::CostProbe> {
        Some(self)
    }

    fn aggregate_exact(&self) -> bool {
        true
    }

    fn decode_window_affine(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> AnalyticCost {
        AnalyticCost::new(&ModelSpec::llama2_7b(), &HardwareSpec::a100_80g())
    }

    fn decode_batch(n: usize, ctx: u32) -> BatchDesc {
        let mut b = BatchDesc::new();
        for _ in 0..n {
            b.push(ctx, 1);
        }
        b
    }

    fn prefill_batch(prompt: u32) -> BatchDesc {
        let mut b = BatchDesc::new();
        b.push(0, prompt);
        b
    }

    #[test]
    fn empty_batch_is_free() {
        let mut m = setup();
        assert_eq!(m.iter_time(&BatchDesc::new()), 0.0);
    }

    #[test]
    fn decode_iteration_in_plausible_range() {
        let mut m = setup();
        let t = m.iter_time(&decode_batch(32, 512));
        // llama2-7b decode on A100 at batch 32: ~5-20 ms < t < 60 ms
        assert!((0.005..0.06).contains(&t), "t={t}");
    }

    #[test]
    fn prefill_2048_in_plausible_range() {
        let mut m = setup();
        let t = m.iter_time(&prefill_batch(2048));
        // 2*7e9*2048 flops / 171 TF ~ 0.17 s
        assert!((0.05..0.8).contains(&t), "t={t}");
    }

    #[test]
    fn decode_is_bandwidth_bound() {
        let model = ModelSpec::llama2_7b();
        let a100 = HardwareSpec::a100_80g();
        let mut base = AnalyticCost::new(&model, &a100);
        let mut fast_bw = AnalyticCost::new(&model, &a100.scale_bandwidth(2.0));
        let mut fast_fl = AnalyticCost::new(&model, &a100.scale_compute(2.0));
        let b = decode_batch(8, 512);
        let t0 = base.iter_time(&b);
        assert!(fast_bw.iter_time(&b) < 0.75 * t0);
        assert!(fast_fl.iter_time(&b) > 0.90 * t0);
    }

    #[test]
    fn prefill_is_compute_bound() {
        let model = ModelSpec::llama2_7b();
        let a100 = HardwareSpec::a100_80g();
        let mut base = AnalyticCost::new(&model, &a100);
        let mut fast_bw = AnalyticCost::new(&model, &a100.scale_bandwidth(2.0));
        let mut fast_fl = AnalyticCost::new(&model, &a100.scale_compute(2.0));
        let b = prefill_batch(2048);
        let t0 = base.iter_time(&b);
        assert!(fast_bw.iter_time(&b) > 0.95 * t0);
        assert!(fast_fl.iter_time(&b) < 0.62 * t0);
    }

    #[test]
    fn batched_decode_cheaper_than_serial() {
        let mut m = setup();
        let t32 = m.iter_time(&decode_batch(32, 256));
        let t1 = m.iter_time(&decode_batch(1, 256));
        assert!(t32 < 0.2 * 32.0 * t1, "t32={t32} t1={t1}");
    }

    #[test]
    fn iter_time_monotone_in_context() {
        let mut m = setup();
        let mut prev = 0.0;
        for ctx in [128, 512, 2048, 8192] {
            let t = m.iter_time(&decode_batch(16, ctx));
            assert!(t > prev);
            prev = t;
        }
    }

    #[test]
    fn iter_time_is_aggregate_exact() {
        let mut m = setup();
        // two different compositions with identical (T, R, A, S) tuples
        let mut b1 = BatchDesc::new();
        b1.push(100, 1);
        b1.push(300, 1);
        let mut b2 = BatchDesc::new();
        b2.push(200, 1);
        b2.push(200, 1);
        assert_eq!(b1.aggregates(), b2.aggregates());
        assert_eq!(m.iter_time(&b1).to_bits(), m.iter_time(&b2).to_bits());
        // the allocation-free fast path matches the full evaluation bit
        // for bit (they share `core`)
        assert_eq!(
            m.iter_time(&b1).to_bits(),
            m.evaluate(&b1).iter_time.to_bits()
        );
        assert!(m.aggregate_exact());
    }

    #[test]
    fn per_req_attn_len_matches_batch() {
        let mut m = setup();
        let mut b = decode_batch(5, 100);
        b.push(0, 0);
        let cost = m.iter_cost(&b);
        assert_eq!(cost.per_req_attn.len(), 6);
        assert_eq!(cost.per_req_attn[5], 0.0, "empty slot free");
    }

    #[test]
    fn op_times_attention_grows_with_ctx_only() {
        let mut m = setup();
        let c1 = m.iter_cost(&decode_batch(16, 128));
        let c2 = m.iter_cost(&decode_batch(16, 4096));
        // attention (idx 2) grows strongly with context
        assert!(c2.op_times[2] > 4.0 * c1.op_times[2]);
        // qkv gemm (idx 1) depends only on new tokens
        assert!((c2.op_times[1] - c1.op_times[1]).abs() < 1e-9);
    }

    #[test]
    fn tp_reduces_iter_time_and_adds_allreduce() {
        let mut m1 = ModelSpec::llama2_7b();
        let mut m4 = ModelSpec::llama2_7b();
        m1.tp = 1;
        m4.tp = 4;
        let hw = HardwareSpec::a100_80g();
        let mut c1 = AnalyticCost::new(&m1, &hw);
        let mut c4 = AnalyticCost::new(&m4, &hw);
        let b = decode_batch(16, 1024);
        let cost1 = c1.iter_cost(&b);
        let cost4 = c4.iter_cost(&b);
        assert!(cost4.iter_time < cost1.iter_time);
        assert_eq!(cost1.op_times[ALLREDUCE_IDX], 0.0);
        assert!(cost4.op_times[ALLREDUCE_IDX] > 0.0);
    }
}
