//! §Perf hot-path optimization: extract the cost artifact's linear
//! structure once at startup, then evaluate iterations in pure rust.
//!
//! Every operator's FLOP and byte counts in the L2 model are *affine* in
//! four batch aggregates — `T = Σ new`, `R = #active`,
//! `A = Σ new·(ctx+new)`, `S = Σ (ctx+new)` — plus a constant term (the
//! weight-read traffic of each GEMM), with coefficients fixed by the
//! (model, hardware) pair. Because the artifact takes the hardware
//! vector as an *input*, we can probe it with degenerate hardware
//! (`peak = 1, bw = ∞` → op times are exactly FLOPs; `bw = 1, peak = ∞`
//! → op times are exactly bytes) on five linearly-independent batches and
//! solve an exact 5×5 system per operator. After the 10 probe executions
//! the hot path is ~50 multiply-adds and ten `max`es — no PJRT call —
//! while remaining *derived from the artifact*, not from hand-written
//! formulas. Cross-validated against direct artifact execution in the
//! integration tests.

use super::{BatchDesc, ComputeModel, IterCost, NUM_OPS};
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;

const ALLREDUCE_IDX: usize = 8;
const PER_ITER: [bool; NUM_OPS] = [
    true, false, false, false, false, false, false, false, false, true,
];

/// A probe source: evaluates op times for a batch under an arbitrary
/// hardware parameter vector.
pub trait CostProbe {
    fn probe_op_times(&mut self, batch: &BatchDesc, hw_vec: [f32; 6]) -> [f64; NUM_OPS];
}

/// Per-op affine coefficients over the batch aggregates `(1, T, R, A, S)`.
#[derive(Debug, Clone, Copy, Default)]
struct LinCoef {
    k: f64,
    t: f64,
    r: f64,
    a: f64,
    s: f64,
}

impl LinCoef {
    #[inline]
    fn eval(&self, t: f64, r: f64, a: f64, s: f64) -> f64 {
        self.k + self.t * t + self.r * r + self.a * a + self.s * s
    }

    fn is_zero(&self) -> bool {
        self.k == 0.0 && self.t == 0.0 && self.r == 0.0 && self.a == 0.0 && self.s == 0.0
    }
}

/// The extracted table: 2 × NUM_OPS coefficient quintuples.
#[derive(Clone)]
pub struct TableCost {
    name: String,
    flops: [LinCoef; NUM_OPS],
    bytes: [LinCoef; NUM_OPS],
    layers: f64,
    peak: f64,
    bw: f64,
    net_bw: f64,
    op_oh: f64,
    iter_oh: f64,
    // per-request attention coefficients (for iter_cost detail)
    attn_flop_per_work: f64,
    attn_byte_s: f64,
    attn_byte_t: f64,
}

/// The five probe batches: aggregate rows (1, T, R, A, S) =
/// (1,1,1,1,1), (1,4,1,16,4), (1,1,1,9,9), (1,4,2,8,4), (1,8,1,64,8) —
/// linearly independent (all-decode batches satisfy A = S, so probes
/// must mix multi-token slots).
fn probe_batches() -> [BatchDesc; 5] {
    let mk = |pairs: &[(u32, u32)]| {
        let mut b = BatchDesc::new();
        for &(c, n) in pairs {
            b.push(c, n);
        }
        b
    };
    [
        mk(&[(0, 1)]),
        mk(&[(0, 4)]),
        mk(&[(8, 1)]),
        mk(&[(0, 2), (0, 2)]),
        mk(&[(0, 8)]),
    ]
}

/// Aggregates of a batch.
#[inline]
fn aggregates(batch: &BatchDesc) -> (f64, f64, f64, f64) {
    let mut t = 0.0;
    let mut r = 0.0;
    let mut a = 0.0;
    let mut s = 0.0;
    for i in 0..batch.len() {
        let c = batch.ctx[i] as f64;
        let n = batch.new[i] as f64;
        if n > 0.0 {
            t += n;
            r += 1.0;
            a += n * (c + n);
            s += c + n;
        }
    }
    (t, r, a, s)
}

/// Solve the N×N linear system `M x = y` by Gauss-Jordan elimination
/// with partial pivoting.
fn solve5(m: [[f64; 5]; 5], y: [f64; 5]) -> [f64; 5] {
    const N: usize = 5;
    let mut aug = [[0.0f64; N + 1]; N];
    for i in 0..N {
        aug[i][..N].copy_from_slice(&m[i]);
        aug[i][N] = y[i];
    }
    for col in 0..N {
        let piv = (col..N)
            .max_by(|&a, &b| aug[a][col].abs().partial_cmp(&aug[b][col].abs()).unwrap())
            .unwrap();
        aug.swap(col, piv);
        let p = aug[col][col];
        assert!(p.abs() > 1e-12, "singular probe system");
        for row in 0..N {
            if row != col {
                let f = aug[row][col] / p;
                for k in col..=N {
                    aug[row][k] -= f * aug[col][k];
                }
            }
        }
    }
    std::array::from_fn(|i| aug[i][N] / aug[i][i])
}

impl TableCost {
    /// Extract coefficients from `probe` for the given (model, hw) pair.
    pub fn build(probe: &mut dyn CostProbe, model: &ModelSpec, hw: &HardwareSpec) -> Self {
        // Degenerate hardware vectors: op time == flops, op time == bytes.
        let flops_hw: [f32; 6] = [1.0, 1e30, 0.0, 0.0, 1e30, 0.0];
        let bytes_hw: [f32; 6] = [1e30, 1.0, 0.0, 0.0, 1.0, 0.0];

        let batches = probe_batches();
        let mut mat = [[0.0f64; 5]; 5];
        let mut f_obs = [[0.0f64; 5]; NUM_OPS]; // [op][probe]
        let mut b_obs = [[0.0f64; 5]; NUM_OPS];
        for (p, batch) in batches.iter().enumerate() {
            let (t, r, a, s) = aggregates(batch);
            mat[p] = [1.0, t, r, a, s];
            let tf = probe.probe_op_times(batch, flops_hw);
            let tb = probe.probe_op_times(batch, bytes_hw);
            for op in 0..NUM_OPS {
                f_obs[op][p] = tf[op];
                b_obs[op][p] = tb[op];
            }
        }

        let mut flops = [LinCoef::default(); NUM_OPS];
        let mut bytes = [LinCoef::default(); NUM_OPS];
        for op in 0..NUM_OPS {
            let fc = solve5(mat, f_obs[op]);
            let bc = solve5(mat, b_obs[op]);
            // Snap tiny solver noise to zero so zero-work ops stay free.
            let clean = |v: [f64; 5]| LinCoef {
                k: if v[0].abs() < 1e-6 { 0.0 } else { v[0] },
                t: if v[1].abs() < 1e-6 { 0.0 } else { v[1] },
                r: if v[2].abs() < 1e-6 { 0.0 } else { v[2] },
                a: if v[3].abs() < 1e-6 { 0.0 } else { v[3] },
                s: if v[4].abs() < 1e-6 { 0.0 } else { v[4] },
            };
            flops[op] = clean(fc);
            bytes[op] = clean(bc);
        }

        // Per-request attention coefficients (analytic identities; used
        // only for diagnostics, not the iteration time).
        let h = model.hidden as f64;
        let tp = model.tp as f64;
        let h_kv = h * model.kv_heads as f64 / model.heads as f64;
        let dtype = model.dtype_bytes as f64;

        Self {
            name: format!("table[{}/{}]", model.name, hw.name),
            flops,
            bytes,
            layers: model.layers as f64,
            peak: hw.achievable_flops(),
            bw: hw.mem_bw,
            net_bw: hw.net_bw,
            op_oh: hw.op_overhead,
            iter_oh: hw.iter_overhead,
            attn_flop_per_work: 4.0 * h / tp,
            attn_byte_s: 2.0 * h_kv * dtype
                / (crate::compute::analytic::ATTN_GATHER_EFF as f64)
                / tp,
            attn_byte_t: (2.0 * h_kv + 2.0 * h) * dtype / tp,
        }
    }

    #[inline]
    fn op_time(&self, op: usize, t: f64, r: f64, a: f64, s: f64) -> f64 {
        if self.flops[op].is_zero() && self.bytes[op].is_zero() {
            return 0.0;
        }
        let f = self.flops[op].eval(t, r, a, s);
        let b = self.bytes[op].eval(t, r, a, s);
        if f > 1e-9 || b > 1e-9 {
            let bw = if op == ALLREDUCE_IDX { self.net_bw } else { self.bw };
            (f / self.peak).max(b / bw) + self.op_oh
        } else {
            0.0
        }
    }

    fn evaluate(&self, batch: &BatchDesc) -> IterCost {
        let (t, r, a, s) = aggregates(batch);
        if t == 0.0 {
            return IterCost {
                iter_time: 0.0,
                op_times: [0.0; NUM_OPS],
                per_req_attn: vec![0.0; batch.len()],
            };
        }
        let mut op_times = [0.0f64; NUM_OPS];
        let mut per_layer = 0.0;
        let mut per_iter = 0.0;
        for op in 0..NUM_OPS {
            let ot = self.op_time(op, t, r, a, s);
            op_times[op] = ot;
            if PER_ITER[op] {
                per_iter += ot;
            } else {
                per_layer += ot;
            }
        }
        let per_req_attn = (0..batch.len())
            .map(|i| {
                let c = batch.ctx[i] as f64;
                let n = batch.new[i] as f64;
                if n > 0.0 {
                    let f = self.attn_flop_per_work * n * (c + n);
                    let b = self.attn_byte_s * (c + n) + self.attn_byte_t * n;
                    (f / self.peak).max(b / self.bw) + self.op_oh
                } else {
                    0.0
                }
            })
            .collect();
        IterCost {
            iter_time: self.layers * per_layer + per_iter + self.iter_oh,
            op_times,
            per_req_attn,
        }
    }
}

impl ComputeModel for TableCost {
    fn iter_time(&mut self, batch: &BatchDesc) -> f64 {
        // Fast path: aggregate + 10 rooflines, no allocation.
        let (t, r, a, s) = aggregates(batch);
        if t == 0.0 {
            return 0.0;
        }
        let mut per_layer = 0.0;
        let mut per_iter = 0.0;
        for op in 0..NUM_OPS {
            let ot = self.op_time(op, t, r, a, s);
            if PER_ITER[op] {
                per_iter += ot;
            } else {
                per_layer += ot;
            }
        }
        self.layers * per_layer + per_iter + self.iter_oh
    }

    fn iter_cost(&mut self, batch: &BatchDesc) -> IterCost {
        self.evaluate(batch)
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn aggregate_exact(&self) -> bool {
        // every op_time is a function of the (t, r, a, s) aggregates,
        // themselves exact integer sums in f64
        true
    }

    fn decode_window_affine(&self) -> bool {
        // piecewise affine in the window step (roofline max + the
        // work-guard); the engine verifies linearity across the window
        true
    }
}

// ---- probe implementations -------------------------------------------

impl CostProbe for super::AnalyticCost {
    fn probe_op_times(&mut self, batch: &BatchDesc, hw_vec: [f32; 6]) -> [f64; NUM_OPS] {
        Self::evaluate_with_hw(self, batch, hw_vec).op_times
    }
}

impl CostProbe for super::HloCost {
    fn probe_op_times(&mut self, batch: &BatchDesc, hw_vec: [f32; 6]) -> [f64; NUM_OPS] {
        self.evaluate_with_hw(batch, hw_vec)
            .expect("probe execution failed")
            .op_times
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::AnalyticCost;

    fn build_from_analytic() -> (TableCost, AnalyticCost) {
        let model = ModelSpec::llama2_7b();
        let hw = HardwareSpec::a100_80g();
        let mut probe = AnalyticCost::new(&model, &hw);
        let table = TableCost::build(&mut probe, &model, &hw);
        (table, probe)
    }

    #[test]
    fn table_matches_probe_source() {
        let (mut table, mut analytic) = build_from_analytic();
        let batches = [
            {
                let mut b = BatchDesc::new();
                b.push(0, 512);
                b
            },
            {
                let mut b = BatchDesc::new();
                for i in 0..64 {
                    b.push(100 + i * 13, 1);
                }
                b
            },
            {
                let mut b = BatchDesc::new();
                b.push(0, 300);
                for i in 0..20 {
                    b.push(50 + i * 91, 1);
                }
                b
            },
        ];
        for b in &batches {
            let t_table = table.iter_time(b);
            let t_ref = analytic.iter_time(b);
            let rel = ((t_table - t_ref) / t_ref).abs();
            assert!(rel < 2e-3, "table={t_table} ref={t_ref} rel={rel}");
        }
    }

    #[test]
    fn empty_batch_free() {
        let (mut table, _) = build_from_analytic();
        assert_eq!(table.iter_time(&BatchDesc::new()), 0.0);
    }

    #[test]
    fn solve5_recovers_known_system() {
        let m = [
            [1.0, 1.0, 1.0, 1.0, 1.0],
            [1.0, 4.0, 1.0, 16.0, 4.0],
            [1.0, 1.0, 1.0, 9.0, 9.0],
            [1.0, 4.0, 2.0, 8.0, 4.0],
            [1.0, 8.0, 1.0, 64.0, 8.0],
        ];
        let x_true = [10.0, 3.0, -1.0, 0.5, 2.0];
        let y: [f64; 5] = std::array::from_fn(|i| {
            (0..5).map(|j| m[i][j] * x_true[j]).sum()
        });
        let x = solve5(m, y);
        for i in 0..5 {
            assert!((x[i] - x_true[i]).abs() < 1e-8, "{x:?}");
        }
    }

    #[test]
    fn probe_matrix_is_nonsingular() {
        // guard against future probe edits reintroducing singularity
        let batches = probe_batches();
        let mut mat = [[0.0f64; 5]; 5];
        for (p, b) in batches.iter().enumerate() {
            let (t, r, a, s) = aggregates(b);
            mat[p] = [1.0, t, r, a, s];
        }
        // identity solve must succeed for arbitrary rhs
        let x = solve5(mat, [1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn per_req_detail_present() {
        let (mut table, mut analytic) = build_from_analytic();
        let mut b = BatchDesc::new();
        b.push(500, 1);
        b.push(0, 128);
        let t = table.iter_cost(&b);
        let a = analytic.iter_cost(&b);
        assert_eq!(t.per_req_attn.len(), 2);
        for i in 0..2 {
            let rel = ((t.per_req_attn[i] - a.per_req_attn[i]) / a.per_req_attn[i]).abs();
            assert!(rel < 1e-3, "req {i}");
        }
    }
}
