//! Exact memoization layer for cost models.
//!
//! [`MemoizedCost`] wraps any [`ComputeModel`] and replays previously
//! computed `iter_time` results instead of re-evaluating the base. The
//! cache key depends on what the base guarantees:
//!
//! * **Aggregate keys** — when the base is
//!   [aggregate-exact](ComputeModel::aggregate_exact), `iter_time` is a
//!   bit-exact pure function of the five integer batch aggregates
//!   `(T, R, A, S_all, S_active)`, so the key is that tuple. Decode
//!   windows revisit the same aggregates constantly (every composition
//!   of `m` decode slots with the same total context collapses to one
//!   key), which is where the >100× call reductions come from.
//! * **Composition keys** — otherwise the key is the full `(ctx, new)`
//!   slot list. Still bit-safe for any *deterministic* base (the result
//!   is a pure function of the key), but recurrences are rare.
//!
//! Either way the cached value is exactly the value the base returned,
//! so a memoized run is **byte-identical** to an unmemoized one — the
//! byte-diff determinism gates stay green with memoization on.
//!
//! The cache is capacity-capped; on overflow it is cleared outright.
//! Because values are pure functions of keys, dropping entries can only
//! cost recomputation, never change a result.
//!
//! Do **not** memoize stochastic models (the `oracle` noise model draws
//! fresh RNG noise per call): caching would freeze one draw per key and
//! silently change the distribution. The registry refuses `memo` over
//! `oracle` for this reason.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

use super::{BatchDesc, ComputeModel, CostProbe, IterCost};

/// Cache-entry cap; the map is cleared when it would grow past this.
/// At ~56 bytes/entry for aggregate keys this bounds the cache to a few
/// tens of MiB, far below the simulator's request table at the scales
/// where memoization matters.
pub const MEMO_CAPACITY: usize = 1 << 20;

/// FxHash-style deterministic hasher. No external crates, and —
/// unlike `RandomState` — no per-process seed, though nothing observable
/// depends on hash order (the map is only ever probed by key).
#[derive(Default)]
struct FxHasher {
    hash: u64,
}

const FX_SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add(&mut self, v: u64) {
        self.hash = (self.hash.rotate_left(5) ^ v).wrapping_mul(FX_SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.add(b as u64);
        }
    }
    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }
    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

/// Memoization hit/miss counters (see [`ComputeModel::cache_stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// `iter_time` calls answered from the cache.
    pub hits: u64,
    /// `iter_time` calls that evaluated the base model.
    pub misses: u64,
}

impl CacheStats {
    /// Total `iter_time` calls observed.
    pub fn total(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of calls answered from the cache (0 when never called).
    pub fn hit_rate(&self) -> f64 {
        if self.hits + self.misses == 0 {
            0.0
        } else {
            self.hits as f64 / (self.hits + self.misses) as f64
        }
    }
}

#[derive(Hash, PartialEq, Eq)]
enum Key {
    /// `(T, R, A, S_all, S_active)` — aggregate-exact bases only.
    Agg(u64, u64, u64, u64, u64),
    /// Packed `(ctx << 32) | new` per slot — the full composition.
    Full(Box<[u64]>),
}

/// Caching layer over any deterministic [`ComputeModel`]; registered as
/// the composable `memo` entry (`compute: {model: memo, base: …}`) and
/// applied by default to the expensive built-ins (`hlo`, `vidur_like`,
/// `llmservingsim_like`) unless `memoize: false`.
pub struct MemoizedCost {
    inner: Box<dyn ComputeModel>,
    name: String,
    map: HashMap<Key, f64, BuildHasherDefault<FxHasher>>,
    capacity: usize,
    /// Key on aggregates (base is aggregate-exact) vs full composition.
    agg_keys: bool,
    hits: u64,
    misses: u64,
}

impl MemoizedCost {
    pub fn new(inner: Box<dyn ComputeModel>) -> Self {
        Self::with_capacity_limit(inner, MEMO_CAPACITY)
    }

    /// As [`Self::new`] with an explicit cache-entry cap (tests).
    pub fn with_capacity_limit(inner: Box<dyn ComputeModel>, capacity: usize) -> Self {
        assert!(capacity > 0, "memo capacity must be >= 1");
        let name = format!("memo[{}]", inner.name());
        let agg_keys = inner.aggregate_exact();
        Self {
            inner,
            name,
            map: HashMap::default(),
            capacity,
            agg_keys,
            hits: 0,
            misses: 0,
        }
    }

    fn key_for(&self, batch: &BatchDesc) -> Key {
        if self.agg_keys {
            let (t, r, a, s_all, s_active) = batch.aggregates();
            Key::Agg(t, r, a, s_all, s_active)
        } else {
            Key::Full(
                batch
                    .ctx
                    .iter()
                    .zip(&batch.new)
                    .map(|(&c, &n)| ((c as u64) << 32) | n as u64)
                    .collect(),
            )
        }
    }
}

impl ComputeModel for MemoizedCost {
    fn iter_time(&mut self, batch: &BatchDesc) -> f64 {
        let key = self.key_for(batch);
        if let Some(&t) = self.map.get(&key) {
            self.hits += 1;
            return t;
        }
        let t = self.inner.iter_time(batch);
        self.misses += 1;
        if self.map.len() >= self.capacity {
            // values are pure functions of keys: clearing only costs
            // recomputation, never correctness
            self.map.clear();
        }
        self.map.insert(key, t);
        t
    }

    fn iter_cost(&mut self, batch: &BatchDesc) -> IterCost {
        // per-request detail is not cached; delegate so diagnostics stay
        // exact (callers of iter_cost are off the hot path)
        self.inner.iter_cost(batch)
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn setup_cost(&self) -> f64 {
        self.inner.setup_cost()
    }

    fn as_probe(&mut self) -> Option<&mut dyn CostProbe> {
        self.inner.as_probe()
    }

    fn aggregate_exact(&self) -> bool {
        self.inner.aggregate_exact()
    }

    fn decode_window_affine(&self) -> bool {
        self.inner.decode_window_affine()
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        Some(CacheStats {
            hits: self.hits,
            misses: self.misses,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::AnalyticCost;
    use crate::hardware::HardwareSpec;
    use crate::model::ModelSpec;

    fn analytic() -> Box<dyn ComputeModel> {
        Box::new(AnalyticCost::new(
            &ModelSpec::llama2_7b(),
            &HardwareSpec::a100_80g(),
        ))
    }

    fn decode_batch(slots: &[(u32, u32)]) -> BatchDesc {
        let mut b = BatchDesc::new();
        for &(c, n) in slots {
            b.push(c, n);
        }
        b
    }

    /// A deterministic model that is NOT aggregate-exact: charges per
    /// slot non-linearly, and counts base evaluations.
    struct SlotQuadratic {
        calls: u64,
    }

    impl ComputeModel for SlotQuadratic {
        fn iter_time(&mut self, batch: &BatchDesc) -> f64 {
            self.calls += 1;
            batch
                .ctx
                .iter()
                .zip(&batch.new)
                .map(|(&c, &n)| (c as f64 + 1.0).sqrt() * n as f64)
                .sum::<f64>()
                .max(1e-9)
        }
        fn name(&self) -> &str {
            "slot-quadratic"
        }
    }

    #[test]
    fn repeat_batches_hit_and_are_bit_equal() {
        let mut m = MemoizedCost::new(analytic());
        let b = decode_batch(&[(100, 1), (200, 1)]);
        let t0 = m.iter_time(&b);
        let t1 = m.iter_time(&b);
        assert_eq!(t0.to_bits(), t1.to_bits());
        let stats = m.cache_stats().unwrap();
        assert_eq!((stats.hits, stats.misses), (1, 1));
        assert!((stats.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn aggregate_keys_collapse_equal_aggregate_compositions() {
        let mut m = MemoizedCost::new(analytic());
        assert!(m.aggregate_exact());
        let b1 = decode_batch(&[(100, 1), (300, 1)]);
        let b2 = decode_batch(&[(200, 1), (200, 1)]);
        assert_eq!(b1.aggregates(), b2.aggregates());
        let t1 = m.iter_time(&b1);
        let t2 = m.iter_time(&b2);
        assert_eq!(t1.to_bits(), t2.to_bits());
        let stats = m.cache_stats().unwrap();
        assert_eq!((stats.hits, stats.misses), (1, 1), "b2 was a hit");
    }

    #[test]
    fn composition_keys_distinguish_equal_aggregates() {
        let mut m = MemoizedCost::new(Box::new(SlotQuadratic { calls: 0 }));
        assert!(!m.aggregate_exact());
        let b1 = decode_batch(&[(100, 1), (300, 1)]);
        let b2 = decode_batch(&[(200, 1), (200, 1)]);
        assert_eq!(b1.aggregates(), b2.aggregates());
        let t1 = m.iter_time(&b1);
        let t2 = m.iter_time(&b2);
        assert_ne!(
            t1.to_bits(),
            t2.to_bits(),
            "slot-nonlinear model must not be collapsed by aggregates"
        );
        // but an exact repeat is still served from cache
        let t1b = m.iter_time(&b1);
        assert_eq!(t1.to_bits(), t1b.to_bits());
        let stats = m.cache_stats().unwrap();
        assert_eq!((stats.hits, stats.misses), (1, 2));
    }

    #[test]
    fn capacity_overflow_clears_but_stays_correct() {
        let mut m = MemoizedCost::with_capacity_limit(analytic(), 4);
        let mut reference = MemoizedCost::new(analytic());
        for round in 0..3 {
            for ctx in [10u32, 20, 30, 40, 50, 60] {
                let b = decode_batch(&[(ctx, 1)]);
                let t = m.iter_time(&b);
                let r = reference.iter_time(&b);
                assert_eq!(t.to_bits(), r.to_bits(), "round {round} ctx {ctx}");
            }
        }
        let stats = m.cache_stats().unwrap();
        assert_eq!(stats.total(), 18);
        assert!(stats.misses > 6, "clears force some re-misses");
    }

    #[test]
    fn name_and_delegation() {
        let mut m = MemoizedCost::new(analytic());
        assert!(m.name().starts_with("memo[analytic["));
        assert_eq!(m.setup_cost(), 0.0);
        assert!(m.decode_window_affine());
        assert!(m.as_probe().is_some(), "probe reaches through the layer");
        // iter_cost delegates: per-request detail intact
        let b = decode_batch(&[(64, 1), (128, 1)]);
        let cost = m.iter_cost(&b);
        assert_eq!(cost.per_req_attn.len(), 2);
        assert_eq!(cost.iter_time.to_bits(), m.iter_time(&b).to_bits());
    }
}
