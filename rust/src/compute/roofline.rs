//! The cheap-and-cheerful reference point: one `max(FLOPs/peak,
//! bytes/bw)` roofline over whole-iteration aggregates — no per-op
//! breakdown, no per-layer walk, no artifact.
//!
//! The iteration's total FLOP and byte counts are *affine* in the batch
//! aggregates `T = Σ new`, `R = #active`, `A = Σ new·(ctx+new)`,
//! `S = Σ (ctx+new)` (the same structure [`super::TableCost`] exploits),
//! so the whole model is seven coefficients fixed at construction. It is
//! deliberately coarser than [`super::AnalyticCost`]: no per-operator
//! launch overheads (one fused `op_overhead` per iteration), no
//! attention-gather inefficiency, no TP all-reduce term — the honest
//! lower bound a napkin calculation gives, useful as the sanity anchor
//! in cross-model sweeps (`tokensim exp hardware`).

use super::{BatchDesc, ComputeModel, CostProbe, NUM_OPS};
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;

/// Pure iteration-level roofline cost model.
#[derive(Debug, Clone)]
pub struct RooflineCost {
    name: String,
    /// FLOPs = `flop_t`·T + `flop_a`·A + `flop_r`·R.
    flop_t: f64,
    flop_a: f64,
    flop_r: f64,
    /// Bytes = `byte_k` + `byte_t`·T + `byte_s`·S.
    byte_k: f64,
    byte_t: f64,
    byte_s: f64,
    peak: f64,
    bw: f64,
    op_oh: f64,
    iter_oh: f64,
}

impl RooflineCost {
    pub fn new(model: &ModelSpec, hw: &HardwareSpec) -> Self {
        let h = model.hidden as f64;
        let h_kv = h * model.kv_heads as f64 / model.heads as f64;
        let ffn = model.ffn as f64;
        let vocab = model.vocab as f64;
        let dtype = model.dtype_bytes as f64;
        let tp = model.tp as f64;
        let layers = model.layers as f64;

        // per-layer GEMMs (qkv, out, gate+up, down) per new token
        let gemm_flops_per_tok = (2.0 * h * (h + 2.0 * h_kv)
            + 2.0 * h * h
            + 4.0 * h * ffn
            + 2.0 * ffn * h)
            / tp;
        // per-layer weight reads (the decode-side bandwidth floor)
        let weight_bytes = (h * (h + 2.0 * h_kv) + h * h + 2.0 * h * ffn + ffn * h) * dtype / tp;

        Self {
            name: format!("roofline[{}/{}]", model.name, hw.name),
            flop_t: layers * gemm_flops_per_tok,
            flop_a: layers * 4.0 * h / tp,
            flop_r: 2.0 * h * vocab / tp,
            byte_k: layers * weight_bytes + h * vocab * dtype / tp,
            byte_t: dtype * (h + layers * 2.0 * (h + ffn) / tp),
            byte_s: layers * 2.0 * h_kv * dtype / tp,
            peak: hw.achievable_flops(),
            bw: hw.mem_bw,
            op_oh: hw.op_overhead,
            iter_oh: hw.iter_overhead,
        }
    }

    /// `(T, R, A, S)` batch aggregates over active slots.
    fn aggregates(batch: &BatchDesc) -> (f64, f64, f64, f64) {
        let (mut t, mut r, mut a, mut s) = (0.0, 0.0, 0.0, 0.0);
        for i in 0..batch.len() {
            let n = batch.new[i] as f64;
            if n > 0.0 {
                let c = batch.ctx[i] as f64;
                t += n;
                r += 1.0;
                a += n * (c + n);
                s += c + n;
            }
        }
        (t, r, a, s)
    }

    /// Total iteration FLOPs and bytes for a batch.
    fn totals(&self, batch: &BatchDesc) -> Option<(f64, f64)> {
        let (t, r, a, s) = Self::aggregates(batch);
        if t == 0.0 {
            return None;
        }
        Some((
            self.flop_t * t + self.flop_a * a + self.flop_r * r,
            self.byte_k + self.byte_t * t + self.byte_s * s,
        ))
    }
}

impl ComputeModel for RooflineCost {
    fn iter_time(&mut self, batch: &BatchDesc) -> f64 {
        match self.totals(batch) {
            None => 0.0,
            Some((flops, bytes)) => {
                (flops / self.peak).max(bytes / self.bw) + self.op_oh + self.iter_oh
            }
        }
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn as_probe(&mut self) -> Option<&mut dyn CostProbe> {
        Some(self)
    }

    fn aggregate_exact(&self) -> bool {
        // totals() is computed from exact integer aggregate sums
        true
    }

    fn decode_window_affine(&self) -> bool {
        // max(FLOPs/peak, bytes/bw) is piecewise affine in the window
        // step; the engine verifies the window stays on one side of the
        // knee and replays otherwise
        true
    }
}

impl CostProbe for RooflineCost {
    /// The whole iteration reported as a single per-iteration op (slot
    /// 0), so a [`super::TableCost`] extracted from this probe
    /// reconstructs the model exactly.
    fn probe_op_times(&mut self, batch: &BatchDesc, hw_vec: [f32; 6]) -> [f64; NUM_OPS] {
        let mut ops = [0.0f64; NUM_OPS];
        if let Some((flops, bytes)) = self.totals(batch) {
            ops[0] = (flops / hw_vec[0] as f64).max(bytes / hw_vec[1] as f64) + hw_vec[2] as f64;
        }
        ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::AnalyticCost;

    fn setup() -> RooflineCost {
        RooflineCost::new(&ModelSpec::llama2_7b(), &HardwareSpec::a100_80g())
    }

    fn decode(n: usize, ctx: u32) -> BatchDesc {
        let mut b = BatchDesc::new();
        for _ in 0..n {
            b.push(ctx, 1);
        }
        b
    }

    #[test]
    fn empty_batch_free() {
        let mut m = setup();
        assert_eq!(m.iter_time(&BatchDesc::new()), 0.0);
    }

    #[test]
    fn decode_floor_is_the_weight_read() {
        // single-token decode: bytes ≈ weights (13.5 GB) / 2.039 TB/s
        let mut m = setup();
        let t = m.iter_time(&decode(1, 128));
        assert!((0.005..0.02).contains(&t), "t={t}");
    }

    #[test]
    fn tracks_analytic_within_a_factor() {
        // coarser, but the same physics: within 2x of the mirror on
        // representative batches
        let mut r = setup();
        let mut a = AnalyticCost::new(&ModelSpec::llama2_7b(), &HardwareSpec::a100_80g());
        for batch in [decode(32, 512), decode(128, 1024), {
            let mut b = BatchDesc::new();
            b.push(0, 1024);
            b
        }] {
            let tr = r.iter_time(&batch);
            let ta = a.iter_time(&batch);
            let ratio = tr / ta;
            assert!((0.3..2.0).contains(&ratio), "ratio={ratio} on {batch:?}");
        }
    }

    #[test]
    fn monotone_in_every_aggregate() {
        let mut m = setup();
        assert!(m.iter_time(&decode(2, 512)) > m.iter_time(&decode(1, 512)));
        assert!(m.iter_time(&decode(8, 2048)) > m.iter_time(&decode(8, 512)));
    }

    #[test]
    fn prefill_is_compute_bound_decode_is_not() {
        let model = ModelSpec::llama2_7b();
        let a100 = HardwareSpec::a100_80g();
        let mut base = RooflineCost::new(&model, &a100);
        let mut fast_fl = RooflineCost::new(&model, &a100.scale_compute(2.0));
        let mut prefill = BatchDesc::new();
        prefill.push(0, 2048);
        assert!(fast_fl.iter_time(&prefill) < 0.62 * base.iter_time(&prefill));
        let d = decode(4, 256);
        assert!(fast_fl.iter_time(&d) > 0.95 * base.iter_time(&d));
    }
}
