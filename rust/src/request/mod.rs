//! Request state: the unit of work flowing through the simulated system.

use crate::sim::SimTime;

/// Request identifier (index into the simulation's request table).
pub type RequestId = usize;

/// Conversation identifier for multi-round workloads.
pub type ConversationId = usize;

/// Lifecycle phase of a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Not yet arrived (future rounds of a conversation).
    Pending,
    /// In a scheduler queue (global or local), no KV allocated.
    Queued,
    /// Prompt tokens being processed (KV cache being built).
    Prefill,
    /// KV cache migrating between workers (disaggregation).
    Transferring,
    /// Autoregressive token generation.
    Decode,
    /// Preempted: KV released, waiting to be restarted (recompute).
    Preempted,
    /// Preempted by swap-out: KV parked in host memory, waiting to be
    /// swapped back in (no re-prefill needed).
    Swapped,
    /// All output tokens generated.
    Finished,
}

/// A single inference request (one round of a conversation).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub conversation: ConversationId,
    /// Round number within the conversation (0-based).
    pub round: usize,
    /// Prompt tokens for this round (including conversation history).
    pub prompt_len: u32,
    /// Prefix of `prompt_len` whose KV can come from the memory-pool
    /// cache (0 without caching; prior-round context when it hits).
    pub cached_prefix: u32,
    /// Number of output tokens this request will generate.
    pub output_len: u32,
    pub arrival: SimTime,
    /// Tenant class of a multi-tenant workload (None = single-tenant).
    /// Carried through to [`RequestRecord`](crate::metrics::RequestRecord)
    /// so reports can break out per-tenant percentiles.
    pub tenant: Option<String>,

    // ---- mutable execution state ----
    pub phase: Phase,
    /// Time this request last entered a worker's waiting queue
    /// (dispatch or preemption push-back); anchors linger deadlines.
    pub queued_at: SimTime,
    /// Tokens currently resident in this worker's KV cache.
    pub ctx_in_cache: u32,
    /// Prompt tokens already processed (chunked prefill / restart).
    pub prompt_done: u32,
    /// Output tokens generated so far.
    pub generated: u32,
    /// Worker currently owning the request, if any.
    pub worker: Option<usize>,
    /// Times the request was preempted (recompute or swap).
    pub preemptions: u32,
    /// Times the request was preempted by swap-out specifically.
    pub swaps: u32,
    /// Tokens whose KV had to be recomputed after recompute
    /// preemptions (the work swap preemption avoids).
    pub recomputed_tokens: u64,

    // ---- metric stamps ----
    pub first_scheduled: Option<SimTime>,
    pub first_token: Option<SimTime>,
    pub last_token: Option<SimTime>,
    /// Largest observed inter-token gap (drives the mTPOT SLO).
    pub max_token_gap: SimTime,
    pub finished_at: Option<SimTime>,
}

impl Request {
    pub fn new(
        id: RequestId,
        conversation: ConversationId,
        round: usize,
        prompt_len: u32,
        output_len: u32,
        arrival: SimTime,
    ) -> Self {
        assert!(prompt_len > 0, "prompt_len must be >= 1");
        assert!(output_len > 0, "output_len must be >= 1");
        Self {
            id,
            conversation,
            round,
            prompt_len,
            cached_prefix: 0,
            output_len,
            arrival,
            tenant: None,
            phase: Phase::Pending,
            queued_at: 0.0,
            ctx_in_cache: 0,
            prompt_done: 0,
            generated: 0,
            worker: None,
            preemptions: 0,
            swaps: 0,
            recomputed_tokens: 0,
            first_scheduled: None,
            first_token: None,
            last_token: None,
            max_token_gap: 0.0,
            finished_at: None,
        }
    }

    /// Prompt tokens still to be computed (prefill work left).
    #[inline]
    pub fn prompt_remaining(&self) -> u32 {
        self.prompt_len - self.prompt_done
    }

    /// Has the (re)prefill completed? After a recompute preemption the
    /// effective prompt includes already-generated tokens.
    #[inline]
    pub fn prefill_done(&self) -> bool {
        self.prompt_done >= self.effective_prompt_len()
    }

    /// Tokens the KV cache must hold when the request completes.
    #[inline]
    pub fn final_kv_tokens(&self) -> u32 {
        self.prompt_len + self.output_len
    }

    /// Total tokens currently needing KV residency.
    #[inline]
    pub fn live_kv_tokens(&self) -> u32 {
        self.ctx_in_cache
    }

    /// Is generation complete?
    #[inline]
    pub fn done(&self) -> bool {
        self.generated >= self.output_len
    }

    /// Record a token emission at `now`, updating gap statistics.
    pub fn stamp_token(&mut self, now: SimTime) {
        if self.first_token.is_none() {
            self.first_token = Some(now);
        } else if let Some(prev) = self.last_token {
            let gap = now - prev;
            if gap > self.max_token_gap {
                self.max_token_gap = gap;
            }
        }
        self.last_token = Some(now);
    }

    /// Reset execution state for a preemption-by-recompute: KV is
    /// dropped and the prompt (plus already-generated tokens) must be
    /// re-processed from scratch.
    pub fn reset_for_recompute(&mut self) {
        self.phase = Phase::Preempted;
        // every KV-resident token will be computed again
        self.recomputed_tokens += self.ctx_in_cache as u64;
        self.ctx_in_cache = 0;
        // Already generated tokens become part of the "prompt" to
        // recompute; they are not re-emitted to the user. A pool-cached
        // prefix no longer helps (accounting restarts from zero).
        self.prompt_done = 0;
        self.cached_prefix = 0;
        self.preemptions += 1;
        self.worker = None;
    }

    /// Mark a preemption-by-swap-out: the KV cache moves to host memory
    /// intact, so `ctx_in_cache` / `prompt_done` are preserved and the
    /// request resumes decoding after a swap-in (no re-prefill).
    pub fn mark_swapped(&mut self) {
        debug_assert_eq!(self.phase, Phase::Decode, "only completed prefills swap");
        self.phase = Phase::Swapped;
        self.preemptions += 1;
        self.swaps += 1;
    }

    /// Effective prompt length for (re)computation, counting generated
    /// tokens that must be re-prefilled after a recompute preemption.
    #[inline]
    pub fn effective_prompt_len(&self) -> u32 {
        self.prompt_len + self.generated
    }

    /// TTFT (time to first token), if the first token was produced.
    pub fn ttft(&self) -> Option<SimTime> {
        self.first_token.map(|t| t - self.arrival)
    }

    /// End-to-end latency, if finished.
    pub fn latency(&self) -> Option<SimTime> {
        self.finished_at.map(|t| t - self.arrival)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        Request::new(0, 0, 0, 100, 10, 1.0)
    }

    #[test]
    fn fresh_request_state() {
        let r = req();
        assert_eq!(r.phase, Phase::Pending);
        assert_eq!(r.prompt_remaining(), 100);
        assert!(!r.prefill_done());
        assert!(!r.done());
        assert_eq!(r.final_kv_tokens(), 110);
    }

    #[test]
    fn token_gap_tracking() {
        let mut r = req();
        r.stamp_token(2.0); // first token: no gap yet
        assert_eq!(r.max_token_gap, 0.0);
        r.stamp_token(2.1);
        r.stamp_token(2.9);
        assert!((r.max_token_gap - 0.8).abs() < 1e-12);
        assert_eq!(r.ttft(), Some(1.0));
    }

    #[test]
    fn recompute_preemption_resets_kv() {
        let mut r = req();
        r.prompt_done = 100;
        r.ctx_in_cache = 104;
        r.generated = 4;
        r.reset_for_recompute();
        assert_eq!(r.ctx_in_cache, 0);
        assert_eq!(r.prompt_done, 0);
        assert_eq!(r.generated, 4, "generated tokens are kept");
        assert_eq!(r.effective_prompt_len(), 104);
        assert_eq!(r.preemptions, 1);
        assert_eq!(r.recomputed_tokens, 104, "every resident token recomputes");
        assert_eq!(r.swaps, 0);
    }

    #[test]
    fn swap_preemption_preserves_kv_token_counts() {
        let mut r = req();
        r.phase = Phase::Decode;
        r.prompt_done = 100;
        r.ctx_in_cache = 104;
        r.generated = 4;
        r.mark_swapped();
        assert_eq!(r.phase, Phase::Swapped);
        assert_eq!(r.ctx_in_cache, 104, "KV tokens survive the swap");
        assert_eq!(r.prompt_done, 100);
        assert_eq!((r.preemptions, r.swaps), (1, 1));
        assert_eq!(r.recomputed_tokens, 0, "no re-prefill work incurred");
    }

    #[test]
    #[should_panic]
    fn zero_prompt_rejected() {
        Request::new(0, 0, 0, 0, 10, 0.0);
    }
}
