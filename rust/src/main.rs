//! TokenSim CLI — the L3 launcher.
//!
//! ```text
//! tokensim run --config cfg.yaml [--save-trace out.jsonl] [--json report.json]
//! tokensim exp <id>|all [--quick] [--out-dir results/] [--cost-model <name>]
//! tokensim list
//! tokensim validate-artifacts
//! ```
//!
//! (Hand-rolled argument parsing: this build environment is offline and
//! clap is unavailable — see Cargo.toml.)

use std::process::ExitCode;

use anyhow::{bail, Context, Result};

use tokensim::compute::ComputeSpec;
use tokensim::config::SimulationConfig;
use tokensim::experiments::{self, ExpOpts};
use tokensim::prelude::*;

fn usage() -> &'static str {
    "TokenSim — LLM inference system simulator (paper reproduction)\n\
     \n\
     USAGE:\n\
       tokensim run --config <file.yaml> [--save-trace <out.jsonl>] [--json <out.json>] [--cdf] [--fast-forward <on|off>] [--window-cost <replay|affine>] [--metrics <exact|sketch>] [--audit]\n\
       tokensim lint <file.yaml|dir>... [--json] [--deny-warnings]\n\
       tokensim analyze <file.yaml|dir>... [--json] [--deny-warnings]\n\
       tokensim exp <fig4|fig5|table2|fig6|...|fig15|policies|memory|workloads|hardware|scale|network|analyze|all> [--quick] [--out-dir <dir>] [--cost-model <name>]\n\
       tokensim list                 list experiments, policies, memory managers, workload generators, compute models, network topologies, lint rules, analyzer bounds, engine knobs, presets\n\
       tokensim validate-artifacts   load + cross-check the HLO artifacts\n\
       tokensim help\n\
     \n\
     `lint` statically cross-validates configs against the registries\n\
     (capacity, token budgets, swap links, SLO floors) without running;\n\
     `analyze` additionally derives closed-form capacity bounds (compute,\n\
     memory, network, SLO) from O(1) cost-model probes — still without\n\
     a single simulation step; `run --audit` re-checks engine\n\
     conservation laws at every event. A directory argument lints every\n\
     *.yaml directly inside it (fixtures/ subdirectories are skipped).\n"
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

/// Flags a command accepts: (name, takes-a-value).
type FlagSpec = &'static [(&'static str, bool)];

const RUN_FLAGS: FlagSpec = &[
    ("--config", true),
    ("--save-trace", true),
    ("--json", true),
    ("--cdf", false),
    ("--fast-forward", true),
    ("--window-cost", true),
    ("--metrics", true),
    ("--audit", false),
];
const LINT_FLAGS: FlagSpec = &[("--json", false), ("--deny-warnings", false)];
const ANALYZE_FLAGS: FlagSpec = &[("--json", false), ("--deny-warnings", false)];
const EXP_FLAGS: FlagSpec = &[("--quick", false), ("--out-dir", true), ("--cost-model", true)];

/// Strict argument validation: every `--flag` must be known to `cmd`,
/// value-taking flags must carry a value, and positional arguments are
/// only allowed where the command defines them. Unknown flags fail with
/// a did-you-mean hint instead of being silently ignored.
fn check_flags(cmd: &str, args: &[String], flags: FlagSpec, positionals: bool) -> Result<()> {
    let mut i = 0;
    while i < args.len() {
        let a = args[i].as_str();
        if let Some(&(name, takes_value)) = flags.iter().find(|(n, _)| *n == a) {
            if takes_value {
                match args.get(i + 1) {
                    Some(v) if !v.starts_with("--") => i += 1,
                    _ => bail!("{cmd}: flag {name} requires a value"),
                }
            }
        } else if a.starts_with("--") {
            let known = flags.iter().map(|&(n, _)| n);
            let hint = tokensim::lint::did_you_mean(a, known.clone())
                .map(|n| format!(" (did you mean '{n}'?)"))
                .unwrap_or_default();
            bail!(
                "{cmd}: unknown flag '{a}'{hint}; accepted: {}",
                known.collect::<Vec<_>>().join(", ")
            );
        } else if !positionals {
            bail!("{cmd}: unexpected argument '{a}'");
        }
        i += 1;
    }
    Ok(())
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(args),
        Some("lint") => cmd_lint(args),
        Some("analyze") => cmd_analyze(args),
        Some("exp") => cmd_exp(args),
        Some("list") => cmd_list(args),
        Some("validate-artifacts") => cmd_validate_artifacts(args),
        Some("help") | None => {
            println!("{}", usage());
            Ok(())
        }
        Some(other) => {
            let hint = tokensim::lint::did_you_mean(
                other,
                ["run", "lint", "analyze", "exp", "list", "validate-artifacts", "help"],
            )
            .map(|c| format!(" (did you mean '{c}'?)"))
            .unwrap_or_default();
            bail!("unknown command '{other}'{hint}\n\n{}", usage())
        }
    }
}

fn cmd_run(args: &[String]) -> Result<()> {
    check_flags("run", &args[1..], RUN_FLAGS, false)?;
    let config_path = flag_value(args, "--config").context("run requires --config <file>")?;
    let mut cfg = SimulationConfig::from_yaml_file(config_path)?;
    if let Some(v) = flag_value(args, "--fast-forward") {
        // CLI override of the YAML `engine: fast_forward` switch — what
        // the CI determinism gate uses to byte-diff both modes without
        // editing the config
        cfg.engine.fast_forward = match v {
            "on" | "true" => true,
            "off" | "false" => false,
            other => bail!("--fast-forward expects on|off, got '{other}'"),
        };
    }
    if let Some(v) = flag_value(args, "--window-cost") {
        // CLI override of the YAML `engine: window_cost:` key — replay
        // re-calls the cost model per coalesced iteration (bit-identical
        // to event-per-iteration), affine fits a closed-form series for
        // models that support it
        cfg.engine.window_cost = tokensim::config::WindowCost::parse(v)?;
    }
    if let Some(v) = flag_value(args, "--metrics") {
        // CLI override of the YAML `metrics: mode:` key — exact keeps
        // every record (byte-identical reports), sketch streams into
        // fixed-size quantile sketches (bounded memory)
        cfg.metrics.mode = tokensim::metrics::MetricsMode::parse(v)?;
    }
    if args.iter().any(|a| a == "--audit") {
        // CLI override of the YAML `engine: audit:` switch — re-check
        // conservation-law invariants at event boundaries. Checks are
        // read-only (reports stay byte-identical); a violation fails
        // the run carrying its A-code diagnostic
        cfg.engine.audit = true;
    }
    println!(
        "model={} workers={} workload={}",
        cfg.model.name,
        cfg.total_workers(),
        cfg.workload.name
    );
    if let Some(path) = flag_value(args, "--save-trace") {
        let requests = cfg.workload.generate()?;
        tokensim::workload::save_trace(path, &requests)?;
        println!("workload trace saved to {path}");
    }
    let report = Simulation::from_config(&cfg)?.run()?;
    println!("{}", report.summary());
    for w in &report.workers {
        println!(
            "  worker {} ({}, memory={}, compute={}): {} iterations, {:.1}% busy, {} KV blocks",
            w.id,
            w.hardware,
            w.manager,
            w.compute,
            w.iterations,
            100.0 * w.utilization,
            w.total_blocks
        );
    }
    if let Some(path) = flag_value(args, "--json") {
        // deterministic JSON (no wall-clock fields): two runs of the
        // same config diff byte-for-byte — the CI determinism gate
        std::fs::write(path, report.to_json().to_string())
            .with_context(|| format!("writing {path}"))?;
        println!("JSON report saved to {path}");
    }
    // multi-tenant workloads: per-class TTFT/TBT + per-class SLOs
    let slos = cfg.workload.build()?.tenant_slos();
    let m = report.view();
    let tenants = m.tenant_breakdown(&slos);
    if !tenants.is_empty() {
        println!("\nper-tenant breakdown:");
        for t in tenants {
            let slo = t
                .slo_attainment
                .map(|a| format!("{:.1}%", 100.0 * a))
                .unwrap_or_else(|| "-".to_string());
            println!(
                "  {:<12} {:>5} reqs | ttft p50 {:.3}s p99 {:.3}s | tbt p99 {:.3}s | slo {}",
                t.tenant, t.requests, t.ttft_p50, t.ttft_p99, t.tbt_p99, slo
            );
        }
    }
    if args.iter().any(|a| a == "--cdf") {
        println!("\nlatency CDF:");
        for q in [0.05, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0] {
            println!("  p{:<4} {:.3}s", q * 100.0, m.latency_percentile(q));
        }
    }
    Ok(())
}

/// Expand the positional arguments of `lint`/`analyze`: files pass
/// through; a directory expands to every `*.yaml` directly inside it,
/// sorted (subdirectories like `fixtures/` are deliberately not
/// recursed into — CI's all-configs gate is one invocation on the
/// configs dir without tripping over intentionally-broken fixtures).
fn expand_config_args(cmd: &str, args: &[String]) -> Result<Vec<String>> {
    let mut out = Vec::new();
    for a in args.iter().filter(|a| !a.starts_with("--")) {
        let path = std::path::Path::new(a.as_str());
        if path.is_dir() {
            let mut found = Vec::new();
            for entry in std::fs::read_dir(path).with_context(|| format!("reading {a}"))? {
                let p = entry?.path();
                if p.is_file() && p.extension().is_some_and(|e| e == "yaml") {
                    found.push(p.to_string_lossy().into_owned());
                }
            }
            anyhow::ensure!(!found.is_empty(), "{cmd}: no *.yaml files in directory '{a}'");
            found.sort();
            out.extend(found);
        } else {
            out.push(a.clone());
        }
    }
    anyhow::ensure!(
        !out.is_empty(),
        "{cmd} requires at least one <config.yaml> or directory \
         (usage: tokensim {cmd} <file|dir>... [--json] [--deny-warnings])"
    );
    Ok(out)
}

fn cmd_lint(args: &[String]) -> Result<()> {
    check_flags("lint", &args[1..], LINT_FLAGS, true)?;
    let json = args.iter().any(|a| a == "--json");
    let deny = args.iter().any(|a| a == "--deny-warnings");
    let files = expand_config_args("lint", &args[1..])?;
    let reports: Vec<_> = files.iter().map(|p| tokensim::lint::lint_file(p)).collect();
    let failed = reports.iter().filter(|r| !r.passes(deny)).count();
    if json {
        let arr = tokensim::util::json::Json::Arr(reports.iter().map(|r| r.to_json()).collect());
        println!("{}", arr.to_string());
    } else {
        for r in &reports {
            print!("{}", r.render());
        }
        let findings: usize = reports.iter().map(|r| r.diagnostics.len()).sum();
        println!(
            "{} config(s) linted, {findings} finding(s), {failed} failing{}",
            reports.len(),
            if deny { " (warnings denied)" } else { "" }
        );
    }
    if failed > 0 {
        bail!("{failed} of {} config(s) failed lint", reports.len());
    }
    Ok(())
}

fn cmd_analyze(args: &[String]) -> Result<()> {
    check_flags("analyze", &args[1..], ANALYZE_FLAGS, true)?;
    let json = args.iter().any(|a| a == "--json");
    let deny = args.iter().any(|a| a == "--deny-warnings");
    let files = expand_config_args("analyze", &args[1..])?;
    let results: Vec<_> = files
        .iter()
        .map(|p| tokensim::lint::analyze::analyze_file(p))
        .collect();
    let failed = results.iter().filter(|(r, _)| !r.passes(deny)).count();
    if json {
        let arr = tokensim::util::json::Json::Arr(
            results
                .iter()
                .map(|(r, a)| {
                    tokensim::util::json::Json::obj(vec![
                        ("report", r.to_json()),
                        (
                            "analysis",
                            a.as_ref().map_or(tokensim::util::json::Json::Null, |a| a.to_json()),
                        ),
                    ])
                })
                .collect(),
        );
        println!("{}", arr.to_string());
    } else {
        for (r, a) in &results {
            print!("{}", r.render());
            if let Some(a) = a {
                print!("{}", a.render());
            }
        }
        println!(
            "{} config(s) analyzed, {failed} failing{}",
            results.len(),
            if deny { " (warnings denied)" } else { "" }
        );
    }
    if failed > 0 {
        bail!("{failed} of {} config(s) failed analysis", results.len());
    }
    Ok(())
}

fn cmd_exp(args: &[String]) -> Result<()> {
    check_flags("exp", &args[1..], EXP_FLAGS, true)?;
    let id = args.get(1).context("exp requires an experiment id")?;
    let mut opts = if args.iter().any(|a| a == "--quick") {
        ExpOpts::quick()
    } else {
        ExpOpts::full()
    };
    if let Some(dir) = flag_value(args, "--out-dir") {
        opts.out_dir = Some(dir.into());
    }
    if let Some(name) = flag_value(args, "--cost-model") {
        // any registered compute model is selectable; unknown names
        // fail here instead of mid-experiment
        let spec = ComputeSpec::new(name);
        spec.validate()?;
        opts.compute = spec;
    }
    if id == "all" {
        for id in experiments::ALL {
            eprintln!("=== running {id} ===");
            let out = experiments::run(id, &opts)?;
            println!("{out}");
        }
        return Ok(());
    }
    let out = experiments::run(id, &opts)?;
    println!("{out}");
    Ok(())
}

fn cmd_list(args: &[String]) -> Result<()> {
    check_flags("list", &args[1..], &[], false)?;
    println!("experiments: {}", experiments::ALL.join(", "));
    println!("\nlocal scheduler policies (worker `local_scheduler: policy:`):");
    for (name, summary) in tokensim::scheduler::local_policies() {
        println!("  {name:<16} {summary}");
    }
    println!("\nglobal scheduler policies (cluster `scheduler: global: policy:`):");
    for (name, summary) in tokensim::scheduler::global_policies() {
        println!("  {name:<16} {summary}");
    }
    println!("\nmemory managers (worker `memory: manager:`):");
    for (name, summary, params) in tokensim::memory::memory_managers() {
        println!("  {name:<16} {summary}");
        println!("  {:<16}   params: {params}", "");
    }
    println!("\nworkload generators (`workload: generator:`):");
    for (name, summary, params) in tokensim::workload::workload_generators() {
        println!("  {name:<16} {summary}");
        println!("  {:<16}   params: {params}", "");
    }
    println!("\ncompute models (`compute: model:`, per-worker overridable):");
    for (name, summary, params) in tokensim::compute::compute_models() {
        println!("  {name:<18} {summary}");
        println!("  {:<18}   params: {params}", "");
    }
    println!("\nnetwork topologies (`network: topology:`):");
    for (name, summary, params) in tokensim::network::network_topologies() {
        println!("  {name:<16} {summary}");
        println!("  {:<16}   params: {params}", "");
    }
    println!("\nlint rules (`tokensim lint <config.yaml>`):");
    for (code, severity, summary) in tokensim::lint::lint_rules() {
        let sev = severity.to_string();
        println!("  {code:<6} {sev:<5} {summary}");
    }
    println!("\nstatic analyzer bound kinds (`tokensim analyze <config.yaml>`):");
    for (name, summary) in tokensim::lint::analyze::BOUND_KINDS {
        println!("  {name:<20} {summary}");
    }
    println!("\nengine audit checks (`engine: audit: true` / `run --audit`):");
    for c in tokensim::lint::AUDIT_CHECKS {
        println!("  {:<6} {}", c.code, c.summary);
    }
    println!("\nengine knobs (`engine:`):");
    println!("  fast_forward <bool>      coalesce closed decode batches (default true)");
    println!("  window_cost <replay|affine>  how coalesced windows are costed");
    println!("  audit <bool>             invariant re-checking at event boundaries");
    println!("\nmetrics knobs (`metrics:`):");
    println!("  mode <exact|sketch>      per-request records vs streaming sketches");
    println!("  sketch_error <f64>       sketch relative-error target (default 0.01)");
    println!("\nmodel presets: llama2-7b, llama2-13b, opt-13b, tiny");
    println!("hardware presets: A100, V100, G6-AiM, A100-1/4T");
    println!("\nlink presets (catalog-driven; accepted by every `*_link:` key):");
    for e in tokensim::hardware::LINK_CATALOG {
        let aliases = if e.aliases.is_empty() {
            String::new()
        } else {
            format!(" (aliases: {})", e.aliases.join(", "))
        };
        println!("  {:<16} {}{aliases}", e.name, e.summary);
    }
    Ok(())
}

fn cmd_validate_artifacts(args: &[String]) -> Result<()> {
    check_flags("validate-artifacts", &args[1..], &[], false)?;
    let dir = tokensim::runtime::default_artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    let manifest = tokensim::runtime::Manifest::load(&dir)?;
    println!(
        "manifest v{} (jax {}), {} slots, {} ops",
        manifest.version, manifest.jax_version, manifest.batch_slots, manifest.num_ops
    );
    let model = ModelSpec::llama2_7b();
    let hw = HardwareSpec::a100_80g();
    let mut hlo = tokensim::compute::HloCost::load(&model, &hw, dir.to_str().unwrap())?;
    let analytic = tokensim::compute::AnalyticCost::new(&model, &hw);
    let mut batch = BatchDesc::new();
    batch.push(0, 512);
    for i in 0..31 {
        batch.push(100 + i * 64, 1);
    }
    let t_hlo = hlo.evaluate(&batch)?.iter_time;
    let t_ana = analytic.evaluate(&batch).iter_time;
    let rel = ((t_hlo - t_ana) / t_ana).abs();
    println!("iter_cost: hlo={t_hlo:.6}s analytic={t_ana:.6}s rel-err={rel:.2e}");
    anyhow::ensure!(rel < 1e-3, "artifact/mirror divergence");
    println!("artifacts OK");
    Ok(())
}
