//! Fig 13: GPU memory-footprint heatmaps over time for prefill vs
//! decode workers in a disaggregated node — and the effect of halving
//! the prefill workers' memory.
//!
//! Input 128 / output 1024 tokens, 10k requests, observation window
//! [5, 65] s, memory sampled throughout.

use anyhow::Result;

use crate::config::SimulationConfig;
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;
use crate::workload::WorkloadSpec;

use super::common::*;

const WINDOW: (f64, f64) = (5.0, 65.0);
const BINS: usize = 12;

fn cfg(
    n_req: usize,
    qps: f64,
    prefill_mem_cap: f64,
    cost: &crate::compute::ComputeSpec,
) -> SimulationConfig {
    let mut prefill_hw = HardwareSpec::a100_80g();
    prefill_hw.mem_cap = prefill_mem_cap;
    let mut cfg = SimulationConfig::disaggregated(
        ModelSpec::llama2_7b(),
        prefill_hw,
        1,
        HardwareSpec::a100_80g(),
        7,
        WorkloadSpec::fixed(n_req, qps, 128, 1024),
    );
    cfg.compute = cost.clone();
    cfg.sample_period = 0.25;
    cfg
}

fn shade(u: Option<f64>) -> char {
    match u {
        None => ' ',
        Some(v) if v < 0.125 => '.',
        Some(v) if v < 0.375 => '-',
        Some(v) if v < 0.625 => '=',
        Some(v) if v < 0.875 => '#',
        Some(_) => '@',
    }
}

fn heatmap(report: &crate::cluster::SimulationReport, title: &str) -> String {
    let mut out = format!("{title}\n");
    for w in &report.workers {
        let row = report.timeline.heatmap_row(w.id, WINDOW.0, WINDOW.1, BINS);
        let cells: String = row.iter().map(|&u| shade(u)).collect();
        let mean = report.timeline.mean_utilization(w.id, WINDOW.0, WINDOW.1);
        out.push_str(&format!(
            "  worker {} ({:>6}) [{cells}]  mean {:.2}\n",
            w.id, w.hardware, mean
        ));
    }
    out
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    // the paper launches 10,000 requests *within* the [5,65]s window —
    // a flood that keeps the decode side under sustained memory load
    let n_req = opts.size(10_000, 400);
    let qps = n_req as f64 / 60.0;

    let full = run_tokensim(&cfg(n_req, qps, 80e9, &opts.compute))?;
    let half = run_tokensim(&cfg(n_req, qps, 40e9, &opts.compute))?;

    let mut out = String::from(
        "Fig 13 — memory-footprint heatmaps, window [5,65]s (.=idle @=full)\n\n",
    );
    out.push_str(&heatmap(&full, "(a) original memory allocation"));
    out.push_str(&format!(
        "    throughput: {:.2} req/s\n\n",
        full.request_throughput()
    ));
    out.push_str(&heatmap(&half, "(b) prefill GPU memory halved"));
    out.push_str(&format!(
        "    throughput: {:.2} req/s\n",
        half.request_throughput()
    ));
    out.push_str(
        "\nshape target: prefill worker (worker 0) runs at far lower utilization than\n\
         the decode workers; halving its memory leaves throughput essentially\n\
         unchanged while raising its utilization.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_uses_less_memory_and_halving_is_free() {
        let opts = ExpOpts::quick();
        let full = run_tokensim(&cfg(240, 4.0, 80e9, &opts.compute)).unwrap();
        let (t0, t1) = WINDOW;
        let prefill_mean = full.timeline.mean_utilization(0, t0, t1);
        let decode_mean: f64 = (1..8)
            .map(|w| full.timeline.mean_utilization(w, t0, t1))
            .sum::<f64>()
            / 7.0;
        assert!(
            prefill_mean < decode_mean,
            "prefill {prefill_mean} !< decode {decode_mean}"
        );

        let half = run_tokensim(&cfg(240, 4.0, 40e9, &opts.compute)).unwrap();
        let rel = (half.request_throughput() - full.request_throughput()).abs()
            / full.request_throughput();
        assert!(rel < 0.05, "halving prefill memory changed throughput by {rel}");
    }
}
