//! `tokensim exp workloads` — the serving-scenario comparison the
//! pluggable workload registry enables: every built-in generator on
//! one fixed cluster (LLaMA2-7B on A100, continuous batching), run
//! through the parallel sweep runner, plus a per-tenant service-quality
//! breakdown for the `multi_tenant` scenario.
//!
//! Not a figure of the paper — this is the "handles as many scenarios
//! as you can imagine" axis of the ROADMAP made measurable: one table
//! shows how the same cluster behaves under ShareGPT-style, replayed,
//! bursty, multi-tenant and long-context traffic.

use anyhow::{Context, Result};

use crate::config::SimulationConfig;
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;
use crate::util::TempDir;
use crate::workload::{save_trace, WorkloadGenerator as _, WorkloadSpec, WorkloadSpecV2};

use super::common::*;

fn cfg(workload: WorkloadSpecV2, cost: &crate::compute::ComputeSpec) -> SimulationConfig {
    let mut cfg = SimulationConfig::single_worker(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        workload,
    );
    cfg.compute = cost.clone();
    cfg
}

/// The scenario roster: one representative spec per built-in generator.
/// The trace scenario replays an archived copy of the synthetic one
/// (written into `dir`), closing the save→replay loop end to end.
fn scenarios(n: usize, dir: &TempDir) -> Result<Vec<(&'static str, WorkloadSpecV2)>> {
    let synthetic = WorkloadSpec::sharegpt(n, 10.0).with_seed(7);
    let trace_path = dir.path().join("sharegpt.jsonl");
    save_trace(&trace_path, &synthetic.generate()).context("archiving the synthetic trace")?;
    let tenants = crate::config::yaml::Yaml::List(vec![
        crate::config::yaml::Yaml::parse(&format!(
            "name: chat\nnum_requests: {}\nqps: 8.0\nttft: 2.0\nmtpot: 0.3\n",
            n * 2 / 3
        ))?,
        crate::config::yaml::Yaml::parse(&format!(
            "name: batch\nnum_requests: {}\nqps: 3.0\nprompt_len:\n  log_normal:\n    median: 512.0\n    sigma: 0.6\n    min: 64\n    max: 4096\noutput_len:\n  fixed: 256\n",
            n / 3
        ))?,
    ]);
    Ok(vec![
        ("synthetic", synthetic.into()),
        (
            "trace",
            WorkloadSpecV2::new("trace").with("path", trace_path.to_str().unwrap()),
        ),
        (
            "bursty",
            WorkloadSpecV2::new("bursty")
                .with("num_requests", n as u64)
                .with("qps", 25.0)
                .with("off_qps", 2.0)
                .with("on_s", 20.0)
                .with("off_s", 20.0)
                .with("cv", 2.0)
                .with("seed", 7u64),
        ),
        (
            "multi_tenant",
            WorkloadSpecV2::new("multi_tenant")
                .with("tenants", tenants)
                .with("seed", 7u64),
        ),
        (
            "long_context",
            WorkloadSpecV2::new("long_context")
                .with("num_requests", (n / 2) as u64)
                .with("qps", 4.0)
                .with("long_fraction", 0.3)
                .with("seed", 7u64),
        ),
    ])
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    let n = opts.size(3000, 150);
    let dir = TempDir::new()?;
    let roster = scenarios(n, &dir)?;

    // every scenario is an independent simulation: sweep across cores
    let cfgs: Vec<SimulationConfig> = roster
        .iter()
        .map(|(_, spec)| cfg(spec.clone(), &opts.compute))
        .collect();
    let reports = parallel_sweep(&cfgs, run_tokensim);
    let reports = reports.into_iter().collect::<Result<Vec<_>>>()?;

    let mut out = String::from(
        "Workload-generator comparison — one cluster (LLaMA2-7B/A100, continuous\n\
         batching), every registered scenario generator\n\n",
    );
    let mut table = Table::new(&[
        "generator",
        "requests",
        "req/s",
        "tok/s",
        "p50 (s)",
        "p99 (s)",
        "ttft p99",
        "tbt p99",
    ]);
    for ((label, _), report) in roster.iter().zip(&reports) {
        let m = report.metrics();
        table.row(&[
            label.to_string(),
            report.records.len().to_string(),
            f3(m.request_throughput()),
            f1(m.token_throughput()),
            f3(m.latency_percentile(0.50)),
            f3(m.latency_percentile(0.99)),
            f3(m.ttft_percentile(0.99)),
            f3(m.tbt_percentile(0.99)),
        ]);
    }
    out.push_str(&table.finish());

    // per-tenant breakdown for the multi-tenant scenario, scored
    // against each class's own SLOs from the generator
    let (idx, mt_spec) = roster
        .iter()
        .enumerate()
        .find_map(|(i, (label, spec))| (*label == "multi_tenant").then_some((i, spec)))
        .expect("roster contains multi_tenant");
    let slos = mt_spec.build()?.tenant_slos();
    let breakdown = reports[idx].metrics().tenant_breakdown(&slos);
    out.push_str("\nmulti_tenant: per-tenant service quality (per-class SLOs)\n");
    let mut table = Table::new(&["tenant", "requests", "ttft p50", "ttft p99", "tbt p99", "slo att."]);
    for t in &breakdown {
        table.row(&[
            t.tenant.clone(),
            t.requests.to_string(),
            f3(t.ttft_p50),
            f3(t.ttft_p99),
            f3(t.tbt_p99),
            t.slo_attainment
                .map(pct)
                .unwrap_or_else(|| "-".to_string()),
        ]);
    }
    out.push_str(&table.finish());

    out.push_str(
        "\nshape targets: trace replays its synthetic source (identical rows); bursty\n\
         degrades tails vs synthetic at the same mean rate; long_context stresses\n\
         prefill (highest ttft p99 per request served); the chat tenant's TBT stays\n\
         bounded while the batch tenant absorbs the long-prompt latency.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_covers_every_builtin_generator_and_tenants() {
        let out = run(&ExpOpts::quick()).unwrap();
        for label in [
            "synthetic",
            "trace",
            "bursty",
            "multi_tenant",
            "long_context",
        ] {
            assert!(out.contains(label), "missing {label} in:\n{out}");
        }
        for tenant in ["chat", "batch"] {
            assert!(out.contains(tenant), "missing tenant {tenant} in:\n{out}");
        }
    }

    #[test]
    fn trace_scenario_replays_the_synthetic_one_identically() {
        let opts = ExpOpts::quick();
        let dir = TempDir::new().unwrap();
        let roster = scenarios(60, &dir).unwrap();
        let get = |name: &str| {
            roster
                .iter()
                .find(|(label, _)| *label == name)
                .map(|(_, spec)| cfg(spec.clone(), &opts.compute))
                .unwrap()
        };
        let synth = run_tokensim(&get("synthetic")).unwrap();
        let trace = run_tokensim(&get("trace")).unwrap();
        assert_eq!(synth.records.len(), trace.records.len());
        let (a, b) = (
            synth.metrics().latency_percentile(0.9),
            trace.metrics().latency_percentile(0.9),
        );
        assert!((a - b).abs() < 1e-9, "replay diverged: {a} vs {b}");
    }
}
