//! Fig 5: request-latency CDF alignment at different QPS.
//!
//! Same setup as Fig 4; plot (print) the latency CDF of the reference
//! system and TokenSim at several request rates and report the maximum
//! CDF gap (Kolmogorov-Smirnov distance) per rate.

use anyhow::Result;

use crate::cluster::SimulationReport;
use crate::config::SimulationConfig;
use crate::hardware::HardwareSpec;
use crate::metrics::MetricSet;
use crate::model::ModelSpec;
use crate::oracle::OracleParams;
use crate::workload::WorkloadSpec;

use super::common::*;

/// KS distance between two empirical CDFs given as sorted samples.
fn ks_distance(mut a: Vec<f64>, mut b: Vec<f64>) -> f64 {
    a.sort_by(|x, y| x.total_cmp(y));
    b.sort_by(|x, y| x.total_cmp(y));
    let mut i = 0;
    let mut j = 0;
    let mut d: f64 = 0.0;
    while i < a.len() && j < b.len() {
        match a[i].total_cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                let v = a[i];
                while i < a.len() && a[i] == v {
                    i += 1;
                }
                while j < b.len() && b[j] == v {
                    j += 1;
                }
            }
        }
        let fa = i as f64 / a.len() as f64;
        let fb = j as f64 / b.len() as f64;
        d = d.max((fa - fb).abs());
    }
    d
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    let n = opts.size(2000, 150);
    let qps_list: &[f64] = if opts.quick {
        &[8.0]
    } else {
        &[4.0, 8.0, 16.0, 24.0]
    };
    let params = OracleParams::vllm();
    let quantiles = [0.05, 0.10, 0.25, 0.50, 0.75, 0.90, 0.95, 0.99];

    let mut out = String::from("Fig 5 — latency CDF alignment (dashed=vLLM ref, solid=TokenSim)\n");
    // every (qps, side) cell is an independent simulation: sweep the
    // oracle + calibrated-sim pairs across cores
    let pairs: Vec<Result<(SimulationReport, SimulationReport)>> =
        parallel_sweep(qps_list, |&qps| {
            let workload = WorkloadSpec::sharegpt(n, qps);
            let mut base = SimulationConfig::single_worker(
                ModelSpec::llama2_7b(),
                HardwareSpec::a100_80g(),
                workload,
            );
            base.compute = opts.compute.clone();
            let real = run_oracle(&base, &params, 0xF16_5)?;
            let sim = run_tokensim(&calibrated_config(&base, &params))?;
            Ok((real, sim))
        });
    let pairs = pairs.into_iter().collect::<Result<Vec<_>>>()?;
    for (&qps, (real, sim)) in qps_list.iter().zip(&pairs) {
        let rm = MetricSet::new(&real.records);
        let sm = MetricSet::new(&sim.records);
        let mut table = Table::new(&["quantile", "ref-lat", "sim-lat"]);
        for &q in &quantiles {
            table.row(&[
                format!("p{:02.0}", q * 100.0),
                f3(rm.latency_percentile(q)),
                f3(sm.latency_percentile(q)),
            ]);
        }
        let ks = ks_distance(
            real.records.iter().map(|r| r.latency()).collect(),
            sim.records.iter().map(|r| r.latency()).collect(),
        );
        out.push_str(&format!("\nQPS = {qps}\n"));
        out.push_str(&table.finish());
        out.push_str(&format!("KS distance = {:.4}\n", ks));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ks_of_identical_samples_is_zero() {
        let a = vec![1.0, 2.0, 3.0];
        assert_eq!(ks_distance(a.clone(), a), 0.0);
    }

    #[test]
    fn ks_of_disjoint_samples_is_one() {
        let d = ks_distance(vec![1.0, 2.0], vec![10.0, 20.0]);
        assert!((d - 1.0).abs() < 1e-12);
    }

    #[test]
    fn quick_run_cdf_aligns() {
        let out = run(&ExpOpts::quick()).unwrap();
        let ks_line = out.lines().find(|l| l.starts_with("KS distance")).unwrap();
        let ks: f64 = ks_line.split('=').nth(1).unwrap().trim().parse().unwrap();
        assert!(ks < 0.35, "CDFs diverged: KS={ks}");
    }
}
