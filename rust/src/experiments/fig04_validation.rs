//! Fig 4: vLLM throughput and latency validation.
//!
//! LLaMA2-7B on one A100, 2000 ShareGPT requests, sweeping request
//! rate; compare TokenSim's throughput and P50/P99/max request latency
//! against the reference system (oracle = vLLM stand-in), reporting the
//! geometric-mean errors the paper quotes (0.109 % throughput; 0.6 %,
//! 0.254 %, 0.337 % for P50/P99/max).

use anyhow::Result;

use crate::config::SimulationConfig;
use crate::hardware::HardwareSpec;
use crate::metrics::MetricSet;
use crate::model::ModelSpec;
use crate::oracle::OracleParams;
use crate::workload::WorkloadSpec;

use super::common::*;

pub fn run(opts: &ExpOpts) -> Result<String> {
    let n = opts.size(2000, 150);
    let qps_list: &[f64] = if opts.quick {
        &[4.0, 16.0]
    } else {
        &[2.0, 4.0, 8.0, 16.0, 24.0, 32.0]
    };
    let params = OracleParams::vllm();

    let mut table = Table::new(&[
        "qps", "V-Thr", "T-Thr", "V-p50", "T-p50", "V-p99", "T-p99", "V-max", "T-max",
    ]);
    let mut thr_pairs = Vec::new();
    let mut p50_pairs = Vec::new();
    let mut p99_pairs = Vec::new();
    let mut max_pairs = Vec::new();

    for &qps in qps_list {
        let workload = WorkloadSpec::sharegpt(n, qps);
        let mut base = SimulationConfig::single_worker(
            ModelSpec::llama2_7b(),
            HardwareSpec::a100_80g(),
            workload,
        );
        base.compute = opts.compute.clone();

        // "real system": oracle at full fidelity
        let real = run_oracle(&base, &params, 0xF16_4)?;
        // TokenSim configured with measured (calibrated) hardware
        let sim_cfg = calibrated_config(&base, &params);
        let sim = run_tokensim(&sim_cfg)?;

        let (rm, sm) = (MetricSet::new(&real.records), MetricSet::new(&sim.records));
        let cells = [
            f1(qps),
            f3(rm.request_throughput()),
            f3(sm.request_throughput()),
            f3(rm.latency_percentile(0.50)),
            f3(sm.latency_percentile(0.50)),
            f3(rm.latency_percentile(0.99)),
            f3(sm.latency_percentile(0.99)),
            f3(rm.latency_percentile(1.0)),
            f3(sm.latency_percentile(1.0)),
        ];
        table.row(&cells);
        thr_pairs.push((sm.request_throughput(), rm.request_throughput()));
        p50_pairs.push((sm.latency_percentile(0.50), rm.latency_percentile(0.50)));
        p99_pairs.push((sm.latency_percentile(0.99), rm.latency_percentile(0.99)));
        max_pairs.push((sm.latency_percentile(1.0), rm.latency_percentile(1.0)));
    }

    let mut out = String::from(
        "Fig 4 — vLLM throughput/latency validation (V- = reference system, T- = TokenSim)\n",
    );
    out.push_str(&table.finish());
    out.push_str(&format!(
        "\ngeomean errors: throughput {}, p50 {}, p99 {}, max {}\n\
         paper reports:  throughput 0.109%, p50 0.600%, p99 0.254%, max 0.337%\n",
        pct(geomean_rel_err(&thr_pairs)),
        pct(geomean_rel_err(&p50_pairs)),
        pct(geomean_rel_err(&p99_pairs)),
        pct(geomean_rel_err(&max_pairs)),
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_produces_errors_below_threshold() {
        let out = run(&ExpOpts::quick()).unwrap();
        assert!(out.contains("geomean errors"));
        // parse the throughput geomean error and require it small
        let line = out.lines().find(|l| l.starts_with("geomean")).unwrap();
        let thr: f64 = line
            .split_whitespace()
            .nth(3)
            .unwrap()
            .trim_end_matches("%,")
            .parse()
            .unwrap();
        assert!(thr < 2.0, "throughput geomean err {thr}% too large");
    }
}
