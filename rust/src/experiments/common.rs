//! Shared experiment machinery: run options, oracle/simulator run
//! helpers, the parallel sweep runner, SLO-throughput search, table
//! formatting.

use std::fmt::Write as _;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{Context as _, Result};

use crate::cluster::{Simulation, SimulationReport};
use crate::compute::ComputeSpec;
use crate::config::SimulationConfig;
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;
use crate::oracle::{calibrated_hardware, OracleCost, OracleParams};

/// Options every experiment takes.
#[derive(Debug, Clone)]
pub struct ExpOpts {
    /// Shrink workloads/grids for smoke tests and quick CI runs.
    pub quick: bool,
    /// Where to also write the report text.
    pub out_dir: Option<PathBuf>,
    /// Compute model for the TokenSim side of comparisons (any
    /// registered name — see [`crate::compute::registry`]).
    pub compute: ComputeSpec,
    /// Skip sweep cells the static analyzer proves infeasible
    /// ([`crate::lint::analyze::prune`]). On by default; set
    /// `TOKENSIM_PRUNE=0` to disable. Pruned cells are always reported,
    /// never silently dropped, and pruning only fires on
    /// qps-independent certainties, so the frontier is unchanged.
    pub prune: bool,
}

fn prune_default() -> bool {
    std::env::var("TOKENSIM_PRUNE").map(|v| v != "0").unwrap_or(true)
}

impl ExpOpts {
    pub fn full() -> Self {
        Self {
            quick: false,
            out_dir: None,
            compute: ComputeSpec::new("table"),
            prune: prune_default(),
        }
    }

    pub fn quick() -> Self {
        Self {
            quick: true,
            out_dir: None,
            // quick paths avoid artifact loading so unit tests run
            // without `make artifacts`
            compute: ComputeSpec::new("analytic"),
            prune: prune_default(),
        }
    }

    /// Pick a size by mode.
    pub fn size(&self, full: usize, quick: usize) -> usize {
        if self.quick {
            quick
        } else {
            full
        }
    }
}

/// Fan a sweep of independent jobs across CPU cores and return the
/// results in input order — the shape every figure-style experiment
/// has: a grid of `Simulation::run` calls with no cross-cell
/// dependencies.
///
/// This is the in-tree substitute for rayon's `par_iter` (the offline
/// build policy allows no new crates — see Cargo.toml): scoped threads
/// pull item indices off a shared counter and write each result into
/// its input slot. Output order is therefore index-determined, and
/// because every simulation seeds its own [`crate::sim::SimRng`]
/// streams from its config alone, the results are **bit-identical** to
/// the sequential `items.iter().map(f)` path (asserted by the
/// integration test `parallel_sweep_is_bit_identical_to_sequential`) —
/// only wall-clock fields differ.
///
/// `TOKENSIM_SWEEP_THREADS` overrides the worker count; `=1` forces the
/// sequential path. (Timing-sensitive experiments — fig 6 measures
/// wall-clock seconds — stay sequential unless that variable is set
/// explicitly.) A panic inside `f` is re-raised on the calling thread
/// with its original payload.
pub fn parallel_sweep<I, T, F>(items: &[I], f: F) -> Vec<T>
where
    I: Sync,
    T: Send,
    F: Fn(&I) -> T + Sync,
{
    let threads = std::env::var("TOKENSIM_SWEEP_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .min(items.len());
    if threads <= 1 {
        return items.iter().map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = items.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    let Some(item) = items.get(i) else { break };
                    let out = f(item);
                    *slots[i].lock().unwrap() = Some(out);
                })
            })
            .collect();
        for h in handles {
            if let Err(panic) = h.join() {
                std::panic::resume_unwind(panic);
            }
        }
    });
    slots
        .into_iter()
        .map(|s| {
            s.into_inner()
                .expect("no thread panicked while writing a slot")
                .expect("every sweep slot is filled before join")
        })
        .collect()
}

/// Two-dimensional [`parallel_sweep`]: evaluate `f` over the
/// `rows × cols` cross product and return the results grouped per row
/// (row-major), so table emitters never hand-roll stride arithmetic —
/// a transposed `i * len + j` index was an easy silent bug.
pub fn sweep_grid<R, C, T, F>(rows: &[R], cols: &[C], f: F) -> Vec<Vec<T>>
where
    R: Sync,
    C: Sync,
    T: Send,
    F: Fn(&R, &C) -> T + Sync,
{
    let cells: Vec<(&R, &C)> = rows
        .iter()
        .flat_map(|r| cols.iter().map(move |c| (r, c)))
        .collect();
    let mut flat = parallel_sweep(&cells, |&(r, c)| f(r, c)).into_iter();
    let mut out = Vec::with_capacity(rows.len());
    for _ in 0..rows.len() {
        out.push(
            (0..cols.len())
                .map(|_| flat.next().expect("sweep returns one result per cell"))
                .collect(),
        );
    }
    out
}

/// Partition sweep jobs by the static analyzer's verdict: jobs whose
/// config is *provably* infeasible (see [`crate::lint::analyze::prune`])
/// are moved to the pruned list as `(label, reason)` instead of being
/// simulated. With `enabled == false` every job is kept — the unpruned
/// baseline the frontier-preservation test compares against. The check
/// itself is deterministic and sequential, so pruned output never
/// depends on sweep thread scheduling.
pub fn prune_jobs<J>(
    enabled: bool,
    jobs: Vec<J>,
    cfg_of: impl Fn(&J) -> SimulationConfig,
    label_of: impl Fn(&J) -> String,
) -> (Vec<J>, Vec<(String, String)>) {
    if !enabled {
        return (jobs, Vec::new());
    }
    let mut kept = Vec::with_capacity(jobs.len());
    let mut pruned = Vec::new();
    for job in jobs {
        match crate::lint::analyze::prune(&cfg_of(&job)) {
            Some(reason) => pruned.push((label_of(&job), reason)),
            None => kept.push(job),
        }
    }
    (kept, pruned)
}

/// The report section every pruning sweep appends: which cells were
/// skipped and why — pruning is logged, never silent.
pub fn pruning_section(enabled: bool, pruned: &[(String, String)], total: usize) -> String {
    if !enabled {
        return "\nstatic pruning: disabled (TOKENSIM_PRUNE=0)\n".to_string();
    }
    let mut out = format!(
        "\nstatic pruning: skipped {} of {total} cells (analyze bounds; frontier-preserving):\n",
        pruned.len()
    );
    if pruned.is_empty() {
        out.push_str("  (none — every cell is statically feasible)\n");
    }
    for (label, reason) in pruned {
        out.push_str(&format!("  {label}: {reason}\n"));
    }
    out
}

/// Run TokenSim proper on a config (the simulator under evaluation).
/// Experiment configs are code-authored, so a *build* failure is a bug
/// and still panics; a drained-deadlock at *run* time is propagated as
/// an `Err` so a single pathological grid cell fails its experiment
/// with a diagnostic instead of poisoning the whole
/// [`parallel_sweep`] via an unwound panic.
pub fn run_tokensim(cfg: &SimulationConfig) -> Result<SimulationReport> {
    Simulation::from_config(cfg)
        .expect("experiment config must build")
        .run()
        .context("running TokenSim cell")
}

/// Run the oracle ("real system") on the same workload/cluster: same
/// driver, oracle cost model, per-worker noise streams (the same
/// [`worker_seed`](crate::compute::registry::worker_seed) mix the
/// registry's `oracle` entry uses, so both paths draw identical noise).
pub fn run_oracle(
    cfg: &SimulationConfig,
    params: &OracleParams,
    seed: u64,
) -> Result<SimulationReport> {
    let params = params.clone();
    let factory = move |model: &ModelSpec, hw: &HardwareSpec, worker: usize| {
        Box::new(OracleCost::new(
            model,
            hw,
            params.clone(),
            crate::compute::registry::worker_seed(seed, worker),
        )) as Box<dyn crate::compute::ComputeModel>
    };
    Simulation::with_cost_factory(cfg, &factory)
        .expect("experiment config must build")
        .run()
        .context("running oracle cell")
}

/// The validation setup of Figs 4/5/7: TokenSim is configured with
/// hardware parameters *measured from the target system* (the oracle),
/// exactly like the paper configures TokenSim from real measurements.
pub fn calibrated_config(cfg: &SimulationConfig, params: &OracleParams) -> SimulationConfig {
    let mut out = cfg.clone();
    for w in &mut out.cluster.workers {
        w.hardware = calibrated_hardware(&cfg.model, &w.hardware, params);
    }
    out
}

/// Binary-search the maximum request rate whose SLO attainment stays
/// >= `target` (the paper's "maximum throughput without violating the
/// SLO"). `build` maps a qps to a full simulation config. Returns
/// (qps, goodput req/s) at the found operating point; a probe whose
/// simulation deadlocks propagates its diagnostic.
pub fn max_slo_throughput(
    build: &dyn Fn(f64) -> SimulationConfig,
    target_attainment: f64,
    qps_hi_start: f64,
) -> Result<(f64, f64)> {
    let attainment = |qps: f64| -> Result<(f64, f64)> {
        let report = run_tokensim(&build(qps))?;
        Ok((report.slo_attainment(), report.slo_throughput()))
    };
    // grow the bracket until attainment falls below target
    let mut lo = 0.0;
    let mut lo_good = 0.0;
    let mut hi = qps_hi_start.max(0.5);
    let mut hi_res = attainment(hi)?;
    let mut grow = 0;
    while hi_res.0 >= target_attainment && grow < 8 {
        lo = hi;
        lo_good = hi_res.1;
        hi *= 2.0;
        hi_res = attainment(hi)?;
        grow += 1;
    }
    if hi_res.0 >= target_attainment {
        return Ok((hi, hi_res.1));
    }
    // bisect
    for _ in 0..5 {
        let mid = 0.5 * (lo + hi);
        let (att, good) = attainment(mid)?;
        if att >= target_attainment {
            lo = mid;
            lo_good = good;
        } else {
            hi = mid;
        }
    }
    Ok((lo, lo_good))
}

/// Geometric mean of |a/b - 1| error terms (the paper's error metric).
pub fn geomean_rel_err(pairs: &[(f64, f64)]) -> f64 {
    let mut log_sum = 0.0;
    let mut n = 0;
    for &(a, b) in pairs {
        if b == 0.0 {
            continue;
        }
        let e = ((a - b) / b).abs().max(1e-9);
        log_sum += e.ln();
        n += 1;
    }
    if n == 0 {
        return 0.0;
    }
    (log_sum / n as f64).exp()
}

/// Simple fixed-width table writer.
pub struct Table {
    out: String,
    widths: Vec<usize>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        let widths: Vec<usize> = headers.iter().map(|h| h.len().max(10)).collect();
        let mut t = Table {
            out: String::new(),
            widths,
        };
        t.row(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
        let rule: Vec<String> = t.widths.iter().map(|w| "-".repeat(*w)).collect();
        t.row(&rule);
        t
    }

    pub fn row(&mut self, cells: &[String]) {
        for (i, c) in cells.iter().enumerate() {
            let w = self.widths.get(i).copied().unwrap_or(10);
            let _ = write!(self.out, "{c:>w$}  ");
        }
        let _ = writeln!(self.out);
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// Format a float with 3 significant decimals.
pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

pub fn f1(v: f64) -> String {
    format!("{v:.1}")
}

pub fn pct(v: f64) -> String {
    format!("{:.3}%", v * 100.0)
}

/// Total simulated runtime (first arrival to last completion) helper.
pub fn total_runtime(report: &SimulationReport) -> f64 {
    report.makespan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadSpec;

    #[test]
    fn parallel_sweep_preserves_input_order() {
        let items: Vec<u64> = (0..64).collect();
        let out = parallel_sweep(&items, |&i| i * i);
        assert_eq!(out, items.iter().map(|&i| i * i).collect::<Vec<_>>());
        // empty and single-item sweeps take the sequential path
        assert!(parallel_sweep(&Vec::<u64>::new(), |&i| i).is_empty());
        assert_eq!(parallel_sweep(&[7u64], |&i| i + 1), vec![8]);
    }

    #[test]
    fn sweep_grid_is_row_major() {
        let rows = [10u64, 20];
        let cols = [1u64, 2, 3];
        let grid = sweep_grid(&rows, &cols, |&r, &c| r + c);
        assert_eq!(grid, vec![vec![11, 12, 13], vec![21, 22, 23]]);
        let empty = sweep_grid(&rows, &[] as &[u64], |&r, &c| r + c);
        assert_eq!(empty, vec![Vec::<u64>::new(), Vec::new()]);
    }

    #[test]
    fn parallel_sweep_matches_sequential_simulations() {
        let cfgs: Vec<SimulationConfig> = [4.0, 12.0, 24.0]
            .iter()
            .map(|&qps| {
                let mut cfg = SimulationConfig::single_worker(
                    ModelSpec::llama2_7b(),
                    HardwareSpec::a100_80g(),
                    WorkloadSpec::fixed(40, qps, 64, 16),
                );
                cfg.compute = ComputeSpec::new("analytic");
                cfg
            })
            .collect();
        let seq: Vec<SimulationReport> =
            cfgs.iter().map(|c| run_tokensim(c).unwrap()).collect();
        let par = parallel_sweep(&cfgs, |c| run_tokensim(c).unwrap());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.records, b.records, "sweep must be bit-deterministic");
            assert_eq!(a.events_processed, b.events_processed);
        }
    }

    #[test]
    fn geomean_of_known_errors() {
        // errors 1% and 4% -> geomean 2%
        let g = geomean_rel_err(&[(1.01, 1.0), (1.04, 1.0)]);
        assert!((g - 0.02).abs() < 1e-9, "{g}");
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let s = t.finish();
        assert!(s.contains('a') && s.contains('2'));
    }

    #[test]
    fn slo_search_finds_knee() {
        // tiny model: the search must return a finite, positive rate
        let build = |qps: f64| {
            let mut cfg = SimulationConfig::single_worker(
                ModelSpec::llama2_7b(),
                HardwareSpec::a100_80g(),
                WorkloadSpec::fixed(60, qps, 64, 16),
            );
            cfg.compute = ComputeSpec::new("analytic");
            cfg
        };
        let (qps, goodput) = max_slo_throughput(&build, 0.9, 4.0).unwrap();
        assert!(qps > 0.0 && qps.is_finite());
        assert!(goodput > 0.0);
        // at the found point attainment holds; well beyond it, it fails
        let report = run_tokensim(&build(qps * 8.0)).unwrap();
        assert!(report.slo_attainment() < 0.9 || qps * 8.0 > 1000.0);
    }
}
