//! `tokensim exp network` — the topology exploration the fifth
//! (network) registry enables: communication topologies ×
//! prefill/decode splits × replica counts, each cell binary-searching
//! its max-SLO throughput with every KV movement priced and queued by
//! the selected topology. The per-topology PD-split frontier makes
//! contention visible: where a contended topology's optimal split
//! differs from `flat`'s (the uncontended pre-registry pricing), link
//! queueing — not compute — moved the operating point.

use anyhow::Result;

use crate::compute::ComputeSpec;
use crate::config::SimulationConfig;
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;
use crate::network::NetworkSpec;
use crate::workload::WorkloadSpec;

use super::common::*;
use super::exp_scale::emit_bench_row;

/// Workers per replica group: P prefill + (GROUP - P) decode.
const GROUP: u32 = 4;

/// The topology axis: every built-in, shaped so a 4-worker replica
/// group splits into two islands / leaves (bridge and uplink traffic
/// exists at every PD split).
fn topologies() -> Vec<(&'static str, NetworkSpec)> {
    vec![
        ("flat", NetworkSpec::new("flat")),
        ("nvlink_island", NetworkSpec::new("nvlink_island").with("island_size", 2u64)),
        ("fat_tree", NetworkSpec::new("fat_tree").with("arity", 2u64)),
        ("ethernet", NetworkSpec::new("ethernet")),
    ]
}

fn cfg(
    spec: &NetworkSpec,
    replicas: u32,
    np: u32,
    n_req: usize,
    qps: f64,
    compute: &ComputeSpec,
) -> SimulationConfig {
    let mut cfg = SimulationConfig::disaggregated(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        np * replicas,
        HardwareSpec::a100_80g(),
        (GROUP - np) * replicas,
        // prefill-heavy prompts: each hand-off migrates a large KV, so
        // slow or shared links show up as queueing, not noise
        WorkloadSpec::mean_lengths(n_req, qps, 256, 64),
    );
    cfg.compute = compute.clone();
    cfg.network = spec.clone();
    cfg
}

struct Cell {
    topo: &'static str,
    replicas: u32,
    np: u32,
    qps: f64,
    goodput: f64,
    wall: f64,
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    let n_req = opts.size(600, 80);
    let replica_counts: &[u32] = if opts.quick { &[1] } else { &[1, 2] };
    let splits: &[u32] = &[1, 2, 3];
    let topos = topologies();

    let jobs: Vec<(&'static str, NetworkSpec, u32, u32)> = {
        let mut v = Vec::new();
        for (name, spec) in &topos {
            for &r in replica_counts {
                for &np in splits {
                    v.push((*name, spec.clone(), r, np));
                }
            }
        }
        v
    };

    let total_cells = jobs.len();
    let (jobs, pruned) = prune_jobs(
        opts.prune,
        jobs,
        |(_, spec, r, np)| cfg(spec, *r, *np, n_req, 4.0, &opts.compute),
        |(name, _, r, np)| format!("{name} replicas={r} P{np}D{}", GROUP - np),
    );

    let cells: Vec<Result<Cell>> = parallel_sweep(&jobs, |(name, spec, r, np)| {
        let t0 = std::time::Instant::now();
        let build = |qps: f64| cfg(spec, *r, *np, n_req, qps, &opts.compute);
        let (qps, goodput) = max_slo_throughput(&build, 0.9, 4.0)?;
        Ok(Cell {
            topo: *name,
            replicas: *r,
            np: *np,
            qps,
            goodput,
            wall: t0.elapsed().as_secs_f64(),
        })
    });
    let cells = cells.into_iter().collect::<Result<Vec<_>>>()?;

    // one bench row per topology (same JSON-lines schema as the scale
    // experiment, so the CI artifact assembler needs no special case)
    for (name, _) in &topos {
        let wall: f64 = cells.iter().filter(|c| c.topo == *name).map(|c| c.wall).sum();
        let n = cells.iter().filter(|c| c.topo == *name).count();
        emit_bench_row(&format!("exp_network/{name}"), wall, n as f64 / wall.max(1e-9), None);
    }

    let mut out = String::from(
        "Network exploration — topology x PD split x replica count\n\
         (4 A100 workers per replica group: P prefill + (4-P) decode; every KV\n\
         migration, swap and pool fetch is priced and queued by the selected\n\
         topology; each cell binary-searches its max-SLO throughput)\n\n",
    );
    let mut table = Table::new(&["topology", "replicas", "split", "qps*", "max SLO thr"]);
    for c in &cells {
        table.row(&[
            c.topo.to_string(),
            c.replicas.to_string(),
            format!("P{}D{}", c.np, GROUP - c.np),
            f1(c.qps),
            f1(c.goodput),
        ]);
    }
    out.push_str(&table.finish());
    out.push_str(&pruning_section(opts.prune, &pruned, total_cells));

    out.push_str("\nPD-split frontier (best split per topology x replica count):\n");
    for (name, _) in &topos {
        for &r in replica_counts {
            let best = cells
                .iter()
                .filter(|c| c.topo == *name && c.replicas == r)
                .max_by(|a, b| a.goodput.total_cmp(&b.goodput));
            let Some(c) = best else { continue };
            let flat_best = cells
                .iter()
                .filter(|x| x.topo == "flat" && x.replicas == r)
                .max_by(|a, b| a.goodput.total_cmp(&b.goodput));
            let shifted = flat_best.map(|f| f.np != c.np).unwrap_or(false);
            let marker = if shifted {
                "  <- contention shifts the optimum vs flat"
            } else {
                ""
            };
            out.push_str(&format!(
                "  {:<14} replicas={r}: P{}D{} at {} req/s{marker}\n",
                c.topo,
                c.np,
                GROUP - c.np,
                f1(c.goodput)
            ));
        }
    }
    out.push_str(
        "\nshape targets: flat reproduces the pre-registry numbers (no queueing);\n\
         the shared ethernet segment serializes concurrent migrations and drags\n\
         the frontier down hardest; island/leaf topologies sit between, paying\n\
         only for cross-island (bridge / uplink) hops.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_report_covers_every_topology() {
        let out = run(&ExpOpts::quick()).unwrap();
        for t in ["flat", "nvlink_island", "fat_tree", "ethernet"] {
            assert!(out.contains(t), "missing {t} in:\n{out}");
        }
        assert!(out.contains("frontier"), "{out}");
    }

    #[test]
    fn contended_topology_slows_the_hand_off() {
        // every prefill->decode migration crosses the shared 12.5 GB/s
        // segment instead of an uncontended NVLink, so the run must
        // stretch measurably
        let compute = ExpOpts::quick().compute;
        let flat = run_tokensim(&cfg(&NetworkSpec::new("flat"), 1, 2, 40, 2.0, &compute)).unwrap();
        let eth = run_tokensim(&cfg(&NetworkSpec::new("ethernet"), 1, 2, 40, 2.0, &compute))
            .unwrap();
        assert!(
            eth.makespan > flat.makespan,
            "shared-segment migrations must stretch the run: {} vs {}",
            eth.makespan,
            flat.makespan
        );
    }
}
