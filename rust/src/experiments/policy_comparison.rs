//! `policies`: scheduler-policy shoot-out on the Fig 9 workload
//! (LLaMA2-7B on A100, ShareGPT-distributed requests).
//!
//! Not a figure of the paper — this experiment exercises the pluggable
//! scheduler subsystem the paper's §III-A design enables: every local
//! policy on one worker across request rates, then every global policy
//! on a 4-worker cluster. New policies registered in
//! [`crate::scheduler::registry`] only need a row here (or none: the
//! harness iterates the given specs).

use anyhow::Result;

use crate::config::SimulationConfig;
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;
use crate::scheduler::PolicySpec;
use crate::workload::WorkloadSpec;

use super::common::*;

/// The local policies under comparison (label, spec). Batch caps are
/// matched (16) so the comparison isolates the batching discipline.
fn local_contenders() -> Vec<(&'static str, PolicySpec)> {
    vec![
        (
            "static-16",
            PolicySpec::new("static")
                .with("batch_size", 16u32)
                .with("max_linger", 2.0),
        ),
        (
            "cont-16",
            PolicySpec::new("continuous")
                .with("max_batched_tokens", 8192u32)
                .with("max_batch_size", 16u32),
        ),
        (
            "chunked-512",
            PolicySpec::new("chunked_prefill")
                .with("chunk_tokens", 512u32)
                .with("max_batch_size", 16u32),
        ),
        (
            "sjf",
            PolicySpec::new("sjf")
                .with("max_batched_tokens", 8192u32)
                .with("max_batch_size", 16u32)
                .with("starvation_age", 10.0),
        ),
        (
            "prio-short",
            PolicySpec::new("priority")
                .with("max_batched_tokens", 8192u32)
                .with("max_batch_size", 16u32)
                .with("by", "shortest_prompt"),
        ),
    ]
}

fn global_contenders() -> Vec<(&'static str, PolicySpec)> {
    vec![
        ("round_robin", PolicySpec::new("round_robin")),
        ("least_loaded", PolicySpec::new("least_loaded")),
        ("random", PolicySpec::new("random")),
        ("po2", PolicySpec::new("power_of_two")),
    ]
}

fn local_cfg(
    n: usize,
    qps: f64,
    policy: PolicySpec,
    cost: &crate::compute::ComputeSpec,
) -> SimulationConfig {
    let mut cfg = SimulationConfig::single_worker(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        WorkloadSpec::sharegpt(n, qps),
    );
    cfg.cluster.workers[0].local_scheduler = policy;
    cfg.compute = cost.clone();
    cfg
}

fn cluster_cfg(
    n: usize,
    qps: f64,
    global: PolicySpec,
    cost: &crate::compute::ComputeSpec,
) -> SimulationConfig {
    let mut cfg = SimulationConfig::single_worker(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        WorkloadSpec::sharegpt(n, qps),
    );
    cfg.cluster.workers[0].quantity = 4;
    cfg.cluster.scheduler.global = global;
    cfg.compute = cost.clone();
    cfg
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    let n = opts.size(5_000, 200);
    let rates: &[f64] = if opts.quick {
        &[2.0, 8.0]
    } else {
        &[2.0, 6.0, 10.0, 14.0, 18.0]
    };

    let mut out = String::from(
        "policies — scheduler-policy comparison, Fig 9 workload (ShareGPT, LLaMA2-7B/A100)\n\n",
    );

    // ---- local policies, one worker ------------------------------------
    out.push_str("local policies, 1 worker: mean normalized latency (s/token) | p99 TTFT (s)\n");
    let locals = local_contenders();
    let mut headers = vec!["qps".to_string()];
    headers.extend(locals.iter().map(|(label, _)| label.to_string()));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr_refs);
    // independent (qps x policy) cells: sweep across cores
    let results: Vec<Vec<Result<String>>> = sweep_grid(rates, &locals, |&qps, (_, spec)| {
        let report = run_tokensim(&local_cfg(n, qps, spec.clone(), &opts.compute))?;
        let m = report.metrics();
        Ok(format!(
            "{}|{}",
            f3(m.mean_normalized_latency()),
            f3(m.ttft_percentile(0.99))
        ))
    });
    for (&qps, row) in rates.iter().zip(results) {
        let mut cells = vec![f1(qps)];
        for cell in row {
            cells.push(cell?);
        }
        table.row(&cells);
    }
    out.push_str(&table.finish());

    // ---- global policies, 4 workers ------------------------------------
    let cluster_qps: &[f64] = if opts.quick { &[16.0] } else { &[16.0, 32.0, 48.0] };
    out.push_str(
        "\nglobal policies, 4 unified workers: mean normalized latency (s/token) | p99 TTFT (s)\n",
    );
    let globals = global_contenders();
    let mut headers = vec!["qps".to_string()];
    headers.extend(globals.iter().map(|(label, _)| label.to_string()));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr_refs);
    let results: Vec<Vec<Result<String>>> = sweep_grid(cluster_qps, &globals, |&qps, (_, spec)| {
        let report = run_tokensim(&cluster_cfg(n, qps, spec.clone(), &opts.compute))?;
        let m = report.metrics();
        Ok(format!(
            "{}|{}",
            f3(m.mean_normalized_latency()),
            f3(m.ttft_percentile(0.99))
        ))
    });
    for (&qps, row) in cluster_qps.iter().zip(results) {
        let mut cells = vec![f1(qps)];
        for cell in row {
            cells.push(cell?);
        }
        table.row(&cells);
    }
    out.push_str(&table.finish());

    out.push_str(
        "\nshape targets: continuous-family policies dominate static at load; chunked\n\
         prefill trims p99 TTFT under long-prompt contention; sjf minimizes mean\n\
         normalized latency; least_loaded and po2 beat random dispatch, with po2\n\
         close to least_loaded at a fraction of the state inspections.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::ComputeSpec;

    #[test]
    fn chunked_prefill_completes_fig9_workload() {
        let spec = PolicySpec::new("chunked_prefill")
            .with("chunk_tokens", 256u32)
            .with("max_batch_size", 16u32);
        let report =
            run_tokensim(&local_cfg(150, 8.0, spec, &ComputeSpec::new("analytic"))).unwrap();
        assert_eq!(report.records.len(), 150);
    }

    #[test]
    fn sjf_completes_and_helps_mean_latency_vs_fifo() {
        let sjf = PolicySpec::new("sjf")
            .with("max_batched_tokens", 2048u32)
            .with("max_batch_size", 8u32);
        let fifo = PolicySpec::new("continuous")
            .with("max_batched_tokens", 2048u32)
            .with("max_batch_size", 8u32);
        let rs = run_tokensim(&local_cfg(250, 12.0, sjf, &ComputeSpec::new("analytic"))).unwrap();
        let rf =
            run_tokensim(&local_cfg(250, 12.0, fifo, &ComputeSpec::new("analytic"))).unwrap();
        assert_eq!(rs.records.len(), 250);
        // SJF must not be (much) worse than FIFO on mean normalized
        // latency — its entire reason to exist
        let (ms, mf) = (
            rs.metrics().mean_normalized_latency(),
            rf.metrics().mean_normalized_latency(),
        );
        assert!(ms <= mf * 1.10, "sjf {ms} vs fifo {mf}");
    }

    #[test]
    fn power_of_two_completes_on_cluster() {
        let report = run_tokensim(&cluster_cfg(
            200,
            24.0,
            PolicySpec::new("power_of_two"),
            &ComputeSpec::new("analytic"),
        ))
        .unwrap();
        assert_eq!(report.records.len(), 200);
        // all four workers must have seen work
        assert!(report.workers.iter().all(|w| w.iterations > 0));
    }

    #[test]
    fn report_contains_all_policy_columns() {
        let out = run(&ExpOpts::quick()).unwrap();
        for label in ["static-16", "cont-16", "chunked-512", "sjf", "prio-short"] {
            assert!(out.contains(label), "missing {label} in:\n{out}");
        }
        for label in ["round_robin", "least_loaded", "random", "po2"] {
            assert!(out.contains(label), "missing {label} in:\n{out}");
        }
    }
}
