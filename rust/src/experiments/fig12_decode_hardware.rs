//! Fig 12: decode-hardware substitution in a disaggregated node.
//!
//! Fixed 8 device slots; A100s serve prefill and the decode side is
//! populated with V100s ("V"), GDDR6-AiM PIM chips ("G"), A100s ("A"),
//! or quarter-FLOPS A100s ("AL"). Reports max SLO throughput and the
//! configuration price (A100 = 1.0).

use anyhow::Result;

use crate::config::SimulationConfig;
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;
use crate::workload::WorkloadSpec;

use super::common::*;

fn cfg(
    n_prefill: u32,
    decode_hw: HardwareSpec,
    n_decode: u32,
    n_req: usize,
    qps: f64,
    cost: &crate::compute::ComputeSpec,
) -> SimulationConfig {
    let mut cfg = SimulationConfig::disaggregated(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        n_prefill,
        decode_hw,
        n_decode,
        WorkloadSpec::mean_lengths(n_req, qps, 128, 128),
    );
    cfg.compute = cost.clone();
    cfg
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    let n_req = opts.size(2000, 120);
    // (label, decode hardware, #prefill, #decode)
    let a100 = HardwareSpec::a100_80g();
    let setups: Vec<(String, HardwareSpec, u32, u32)> = {
        let mut v = Vec::new();
        let variants: &[(&str, HardwareSpec)] = &[
            ("A", HardwareSpec::a100_80g()),
            ("G", HardwareSpec::gddr6_aim()),
            ("V", HardwareSpec::v100_32g()),
            ("AL", HardwareSpec::a100_quarter_flops()),
        ];
        let prefills: &[u32] = if opts.quick { &[1] } else { &[1, 2] };
        for &np in prefills {
            let nd = 8 - np;
            for (label, hw) in variants {
                v.push((format!("{label}{nd} (P{np})"), hw.clone(), np, nd));
            }
        }
        v
    };

    let mut table = Table::new(&["config", "price", "max SLO thr (req/s)"]);
    // every setup runs its own SLO-throughput search: sweep across cores
    let goodputs = parallel_sweep(&setups, |(_, hw, np, nd)| {
        let build = |qps: f64| cfg(*np, hw.clone(), *nd, n_req, qps, &opts.compute);
        max_slo_throughput(&build, 0.9, 4.0).map(|(_, goodput)| goodput)
    });
    for ((label, hw, np, nd), goodput) in setups.iter().zip(goodputs) {
        let price = *np as f64 * a100.price + *nd as f64 * hw.price;
        table.row(&[label.clone(), format!("{price:.2}"), f1(goodput?)]);
    }

    let mut out = String::from(
        "Fig 12 — decode-hardware substitution (8 slots; A=A100, G=GDDR6-AiM,\n\
         V=V100, AL=A100 with 1/4 FLOPS; price in A100 units)\n",
    );
    out.push_str(&table.finish());
    out.push_str(
        "\nshape target: at a ~4.5-unit budget, 1xA100 prefill + 7xG6-AiM decode\n\
         approaches the all-A100 throughput at roughly half the decode cost; V100\n\
         decode lags (bandwidth-starved); AL shows decode is not compute-free.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aim_decode_beats_v100_decode() {
        let opts = ExpOpts::quick();
        let build_g = |qps: f64| cfg(1, HardwareSpec::gddr6_aim(), 7, 120, qps, &opts.compute);
        let build_v = |qps: f64| cfg(1, HardwareSpec::v100_32g(), 7, 120, qps, &opts.compute);
        let (_, g) = max_slo_throughput(&build_g, 0.9, 4.0).unwrap();
        let (_, v) = max_slo_throughput(&build_v, 0.9, 4.0).unwrap();
        assert!(g > v, "G6-AiM decode ({g}) must beat V100 decode ({v})");
    }
}
