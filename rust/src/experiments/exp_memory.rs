//! `tokensim exp memory` — the memory-subsystem design-space study the
//! pluggable manager registry enables: every registered manager crossed
//! with both preemption policies on the paper's memory-stress
//! workloads.
//!
//! Part A replays the Fig 10 setting (ShareGPT mix on a
//! memory-constrained card) for each manager × {recompute, swap} and
//! reports goodput, tail latency, preemption counts, swap traffic and
//! re-prefilled tokens — swap preemption must replace recompute work
//! with host-link transfers. Part B replays the Fig 14 chatbot
//! workload with the cross-request cache as a *memory-manager choice*
//! (`prefix_cache`) instead of a cluster special case, reproducing the
//! cache-on/off P99 gap and the pool hit-rate behaviour through the
//! registry path.

use anyhow::{Context as _, Result};

use crate::cluster::Simulation;
use crate::config::SimulationConfig;
use crate::hardware::HardwareSpec;
use crate::memory::{MemorySpec, MEMORY_MANAGERS};
use crate::model::ModelSpec;
use crate::workload::{ConversationSpec, LengthDistribution, WorkloadSpec};

use super::common::*;

/// Fig 10-style memory-stress config (ShareGPT mix on a small-memory
/// card), with the worker's memory manager swapped in from `memory`.
/// The length tails are clamped to 512 so even the largest request's
/// *final* footprint fits the deliberately tiny pool — a hard
/// requirement for `token_contiguous`, which reserves prompt + output
/// up front and would otherwise never admit an oversized request.
fn stress_cfg(
    n: usize,
    qps: f64,
    memory: MemorySpec,
    cost: &crate::compute::ComputeSpec,
) -> SimulationConfig {
    let mut workload = WorkloadSpec::sharegpt(n, qps);
    workload.prompt_len = LengthDistribution::LogNormal {
        median: 96.0,
        sigma: 1.1,
        min: 4,
        max: 512,
    };
    workload.output_len = LengthDistribution::LogNormal {
        median: 128.0,
        sigma: 1.0,
        min: 4,
        max: 512,
    };
    let mut cfg = SimulationConfig::single_worker(
        ModelSpec::llama2_7b(),
        {
            let mut hw = HardwareSpec::a100_80g();
            hw.mem_cap = 16e9; // weights 13.5 GB -> tight KV pool
            hw
        },
        workload,
    );
    cfg.cluster.workers[0].memory = memory;
    cfg.compute = cost.clone();
    cfg
}

/// Fig 14-style chatbot config with the prefix cache as a manager.
fn chatbot_cfg(memory: MemorySpec, cost: &crate::compute::ComputeSpec) -> SimulationConfig {
    let mut cfg = SimulationConfig::single_worker(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        // workload field unused for conversation runs; keep a stub
        WorkloadSpec::fixed(1, 1.0, 8, 8),
    );
    cfg.cluster.workers[0].memory = memory;
    cfg.compute = cost.clone();
    cfg
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    let mut out = String::from(
        "Memory-subsystem study — every registered manager x preemption policy\n",
    );

    // ---- Part A: allocator x preemption on the Fig 10 workload -------
    let n = opts.size(3000, 250);
    let qps = 20.0;
    let mut table = Table::new(&[
        "manager",
        "preempt",
        "req/s",
        "p99 (s)",
        "preempts",
        "swaps",
        "reprefill-tok",
        "swap-blk",
    ]);
    // manager x preemption rows are independent simulations: sweep them
    let grid: Vec<(&str, &str)> = MEMORY_MANAGERS
        .iter()
        .flat_map(|entry| ["recompute", "swap"].map(|policy| (entry.name, policy)))
        .collect();
    let reports = parallel_sweep(&grid, |&(manager, policy)| {
        let memory = MemorySpec::new(manager).with("preemption", policy);
        run_tokensim(&stress_cfg(n, qps, memory, &opts.compute))
            .with_context(|| format!("memory cell {manager}/{policy}"))
    });
    let reports = reports.into_iter().collect::<Result<Vec<_>>>()?;
    for (&(manager, policy), report) in grid.iter().zip(&reports) {
        let m = report.metrics();
        let swap = report.swap_totals();
        table.row(&[
            manager.to_string(),
            policy.to_string(),
            f3(report.request_throughput()),
            f3(report.latency_percentile(0.99)),
            m.total_preemptions().to_string(),
            m.total_swaps().to_string(),
            m.total_recomputed_tokens().to_string(),
            swap.blocks_out.to_string(),
        ]);
    }
    out.push_str("\n(a) Fig 10 workload: ShareGPT @ 16 GB card (tight KV pool)\n");
    out.push_str(&table.finish());

    // ---- Part B: prefix cache through the registry (Fig 14) ----------
    let n_conv = opts.size(1500, 150);
    let conv_qps = 10.0;
    let convs = ConversationSpec::chatbot(n_conv, conv_qps, 128, 64).generate();
    let mut table = Table::new(&["manager", "p99 (s)", "hit-rate", "pool-hits"]);
    let managers = [
        MemorySpec::new("paged"),
        MemorySpec::new("prefix_cache").with("capacity_blocks", 2_000_000u64),
    ];
    let reports = parallel_sweep(&managers, |memory| {
        Simulation::from_conversations(&chatbot_cfg(memory.clone(), &opts.compute), &convs)
            .expect("experiment config must build")
            .run()
            .with_context(|| format!("chatbot cell {}", memory.name))
    });
    let reports = reports.into_iter().collect::<Result<Vec<_>>>()?;
    for (memory, report) in managers.iter().zip(&reports) {
        table.row(&[
            memory.name.clone(),
            f3(report.latency_percentile(0.99)),
            f3(report.pool_hit_rate()),
            report.pool_hits.to_string(),
        ]);
    }
    out.push_str("\n(b) Fig 14 workload: chatbot conversations, cache as a manager choice\n");
    out.push_str(&table.finish());

    out.push_str(
        "\nshape targets: token_contiguous admits fewest requests but never preempts\n\
         (reprefill = 0 by construction); paged+recompute preempts under pressure and\n\
         re-prefills; swap preemption converts that recompute work into host-link\n\
         transfers (swaps > 0, strictly fewer re-prefilled tokens); prefix_cache\n\
         reproduces the Fig 14 cache win (hit-rate > 0, lower P99 than paged)\n\
         through the registry path alone.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn swap_preemption_strictly_reduces_reprefill_on_fig10_workload() {
        let cost = ExpOpts::quick().compute;
        let recompute = run_tokensim(&stress_cfg(
            200,
            20.0,
            MemorySpec::new("swap").with("preemption", "recompute"),
            &cost,
        ))
        .unwrap();
        let swap =
            run_tokensim(&stress_cfg(200, 20.0, MemorySpec::new("swap"), &cost)).unwrap();
        let (mr, ms) = (recompute.metrics(), swap.metrics());
        assert!(mr.total_preemptions() > 0, "workload must stress memory");
        assert!(ms.total_swaps() > 0);
        assert!(
            ms.total_recomputed_tokens() < mr.total_recomputed_tokens(),
            "swap must reduce re-prefill: {} vs {}",
            ms.total_recomputed_tokens(),
            mr.total_recomputed_tokens()
        );
    }

    #[test]
    fn prefix_cache_reproduces_fig14_hit_behaviour_via_registry() {
        let cost = ExpOpts::quick().compute;
        let convs = ConversationSpec::chatbot(200, 10.0, 128, 64).generate();
        let run = |memory: MemorySpec| {
            Simulation::from_conversations(&chatbot_cfg(memory, &cost), &convs)
                .unwrap()
                .run()
                .unwrap()
        };
        let off = run(MemorySpec::new("paged"));
        let on = run(MemorySpec::new("prefix_cache").with("capacity_blocks", 2_000_000u64));
        assert_eq!(off.pool_hits, 0);
        assert!(on.pool_hits > 0, "manager-layer cache must hit");
        assert!(on.pool_hit_rate() > 0.2, "chatbot rounds mostly hit");
        assert!(
            on.latency_percentile(0.99) < off.latency_percentile(0.99),
            "cache must lower P99 under load"
        );
    }
}
