//! Fig 6: simulator execution-time comparison.
//!
//! Wall-clock runtime of TokenSim vs the Vidur-like baseline (which
//! pays ~400 s of pre-training before every run) and the
//! LLMServingSim-like co-simulator (structurally slow; 10-token cap),
//! over the Table-II workloads.
//!
//! Two extra labeled series show the engine's cost-model layers on the
//! same workload: TokenSim with the `memo` caching layer, and with
//! `engine: window_cost: affine`. Rows stay sequential by default so
//! every wall-clock cell is measured on an otherwise idle process.

use anyhow::Result;

use crate::baselines::{LlmServingSimLike, VidurLike};
use crate::cluster::Simulation;
use crate::compute::{ComputeModel, ComputeSpec};
use crate::config::{SimulationConfig, WindowCost};
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;
use crate::workload::WorkloadSpec;

use super::common::*;

fn cfg(n: usize, cost: &crate::compute::ComputeSpec) -> SimulationConfig {
    let mut cfg = SimulationConfig::single_worker(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        WorkloadSpec::fixed(n, 40.0, 10, 10),
    );
    cfg.compute = cost.clone();
    cfg
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    let counts: &[usize] = if opts.quick {
        &[100]
    } else {
        &[100, 200, 300, 400, 500]
    };

    let mut table = Table::new(&[
        "Request num",
        "TokenSim(s)",
        "Vidur run(s)",
        "Vidur +pretrain(s)",
        "LLMServingSim(s)",
        "TokenSim+memo(s)",
        "TokenSim+affine(s)",
    ]);

    // the engine-layer series run the same workload as the plain
    // TokenSim column: `memo` wraps the experiment's cost model in the
    // exact-key cache (aggregate-exact models only; anything else is
    // already memoized by default or incompatible), `affine` switches
    // the decode-window costing to the closed-form series
    let memo_spec = match opts.compute.name.as_str() {
        "analytic" | "roofline" | "table" => {
            ComputeSpec::new("memo").with("base", opts.compute.name.as_str())
        }
        _ => opts.compute.clone(),
    };

    // this figure's OUTPUT is wall-clock seconds, so rows default to
    // the sequential path (concurrent rows would inflate each other's
    // timings); setting TOKENSIM_SWEEP_THREADS explicitly opts into
    // parallel rows — each row's three measurements still share one
    // thread, preserving the within-row ranking the figure reports
    let time_row = |&n: &usize| {
        let base = cfg(n, &opts.compute);

        let t0 = std::time::Instant::now();
        let _ = run_tokensim(&base).expect("fig6 workload must complete");
        let tokensim_wall = t0.elapsed().as_secs_f64();

        // Vidur: training happens once per run in the original; we time
        // the in-process training and add the paper's orchestration
        // constant reported by setup_cost().
        let t0 = std::time::Instant::now();
        let samples = if opts.quick { 300 } else { 1200 };
        let pretrain_const;
        let vidur_factory = |model: &ModelSpec, hw: &HardwareSpec, _w: usize| {
            Box::new(VidurLike::train(model, hw, samples, 42)) as Box<dyn ComputeModel>
        };
        {
            let probe = VidurLike::train(
                &ModelSpec::llama2_7b(),
                &HardwareSpec::a100_80g(),
                8,
                42,
            );
            pretrain_const = probe.setup_cost();
        }
        let _ = Simulation::with_cost_factory(&base, &vidur_factory)
            .expect("experiment config must build")
            .run()
            .expect("fig6 workload must complete");
        let vidur_wall = t0.elapsed().as_secs_f64();

        let t0 = std::time::Instant::now();
        let co_factory = |model: &ModelSpec, hw: &HardwareSpec, _w: usize| {
            Box::new(LlmServingSimLike::new(model, hw)) as Box<dyn ComputeModel>
        };
        let _ = Simulation::with_cost_factory(&base, &co_factory)
            .expect("experiment config must build")
            .run()
            .expect("fig6 workload must complete");
        let co_wall = t0.elapsed().as_secs_f64();

        let t0 = std::time::Instant::now();
        let _ = run_tokensim(&cfg(n, &memo_spec)).expect("fig6 workload must complete");
        let memo_wall = t0.elapsed().as_secs_f64();

        let t0 = std::time::Instant::now();
        let mut affine = cfg(n, &opts.compute);
        affine.engine.window_cost = WindowCost::Affine;
        let _ = run_tokensim(&affine).expect("fig6 workload must complete");
        let affine_wall = t0.elapsed().as_secs_f64();

        (n, tokensim_wall, vidur_wall, pretrain_const, co_wall, memo_wall, affine_wall)
    };
    let rows: Vec<(usize, f64, f64, f64, f64, f64, f64)> =
        if std::env::var("TOKENSIM_SWEEP_THREADS").is_ok() {
            parallel_sweep(counts, time_row)
        } else {
            counts.iter().map(time_row).collect()
        };
    for (n, tokensim_wall, vidur_wall, pretrain_const, co_wall, memo_wall, affine_wall) in rows {
        table.row(&[
            n.to_string(),
            format!("{tokensim_wall:.3}"),
            format!("{vidur_wall:.3}"),
            format!("{:.1}", vidur_wall + pretrain_const),
            format!("{co_wall:.3}"),
            format!("{memo_wall:.3}"),
            format!("{affine_wall:.3}"),
        ]);
    }

    let mut out = String::from(
        "Fig 6 — simulator execution time (Vidur pays ~400 s pre-training per run;\n\
         LLMServingSim capped at 10 tokens and structurally slow)\n",
    );
    out.push_str(&table.finish());
    out.push_str(
        "\nshape target: TokenSim comparable to Vidur's post-training run time but\n\
         without the pre-training; LLMServingSim slowest per simulated token.\n\
         TokenSim+memo / TokenSim+affine are the same engine with the cost-model\n\
         cache and the closed-form window costing enabled (sequential timing).\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_speed_comparison_ranks_correctly() {
        let out = run(&ExpOpts::quick()).unwrap();
        let row = out
            .lines()
            .find(|l| l.trim_start().starts_with("100"))
            .unwrap();
        let cells: Vec<f64> = row
            .split_whitespace()
            .skip(1)
            .map(|c| c.parse().unwrap())
            .collect();
        let (tokensim, _vidur_run, vidur_total, co) = (cells[0], cells[1], cells[2], cells[3]);
        assert!(vidur_total >= 400.0, "pretrain constant missing");
        assert!(
            co > tokensim,
            "co-simulation must be slower: {co} vs {tokensim}"
        );
        // the engine-layer series are appended after the baselines
        assert!(out.contains("TokenSim+memo(s)"), "memo column missing");
        assert!(out.contains("TokenSim+affine(s)"), "affine column missing");
        assert_eq!(cells.len(), 6, "expected six timing columns");
        assert!(cells[4] > 0.0 && cells[5] > 0.0, "engine series not timed");
    }
}
