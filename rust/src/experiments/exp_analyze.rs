//! `tokensim exp analyze` — the static-capacity-analysis study: for a
//! grid of offered loads × PD splits, derive the analyzer's closed-form
//! throughput upper bound (O(1) cost-model probes, zero simulation
//! steps), then run the real simulation and report how the achieved
//! throughput sits under the bound. The table makes two properties
//! visible at once: *validity* (the bound is never exceeded — also
//! asserted by the property/integration suites) and *tightness* (how
//! much headroom the closed form leaves at each operating point). A
//! deliberately starved decode cell demonstrates the sweep-pruning
//! hook: the analyzer proves it infeasible and it is skipped + logged
//! instead of simulated.

use anyhow::Result;

use crate::compute::ComputeSpec;
use crate::config::SimulationConfig;
use crate::hardware::HardwareSpec;
use crate::lint::analyze;
use crate::model::ModelSpec;
use crate::workload::WorkloadSpec;

use super::common::*;

/// 4 workers per cell: P prefill + (4-P) decode.
const GROUP: u32 = 4;

fn cfg(np: u32, decode_hw: &HardwareSpec, n_req: usize, qps: f64, compute: &ComputeSpec) -> SimulationConfig {
    let mut cfg = SimulationConfig::disaggregated(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        np,
        decode_hw.clone(),
        GROUP - np,
        WorkloadSpec::mean_lengths(n_req, qps, 128, 64),
    );
    cfg.compute = compute.clone();
    cfg
}

struct Cell {
    label: String,
    qps: f64,
    rho: Option<f64>,
    bound: Option<f64>,
    achieved: f64,
    probes: usize,
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    // this study is *about* the closed-form bounds, which need a
    // probe-able cost model; fall back to the artifact-free analytic
    // model when the selected compute (e.g. the full-mode default
    // `table`) cannot be probed statically
    let compute = if analyze::probeable(&opts.compute) {
        opts.compute.clone()
    } else {
        ComputeSpec::new("analytic")
    };
    let n_req = opts.size(400, 60);
    let qps_grid: &[f64] = if opts.quick { &[2.0, 8.0, 32.0] } else { &[2.0, 8.0, 32.0, 64.0] };
    let splits: &[u32] = &[1, 2];
    let a100 = HardwareSpec::a100_80g();
    // the starved decode card the analyzer must prune (decode floor
    // above the paper-default TBT SLO — same cell exp_hardware prunes)
    let starved = HardwareSpec::v100_32g().scale_bandwidth(0.02);

    let jobs: Vec<(String, u32, HardwareSpec, f64)> = {
        let mut v = Vec::new();
        for &np in splits {
            for &qps in qps_grid {
                v.push((format!("P{np}D{} qps={qps}", GROUP - np), np, a100.clone(), qps));
            }
        }
        v.push((
            format!("P1D{} starved qps={}", GROUP - 1, qps_grid[0]),
            1,
            starved,
            qps_grid[0],
        ));
        v
    };

    let total_cells = jobs.len();
    let (jobs, pruned) = prune_jobs(
        opts.prune,
        jobs,
        |(_, np, hw, qps)| cfg(*np, hw, n_req, *qps, &compute),
        |(label, ..)| label.clone(),
    );

    let cells: Vec<Result<Cell>> = parallel_sweep(&jobs, |(label, np, hw, qps)| {
        let c = cfg(*np, hw, n_req, *qps, &compute);
        let requests = c.workload.generate()?;
        let a = analyze::analyze(&c, &requests);
        let report = run_tokensim(&c)?;
        let achieved = report.records.len() as f64 / report.makespan.max(1e-9);
        Ok(Cell {
            label: label.clone(),
            qps: *qps,
            rho: a.rho_decode,
            bound: a.throughput_ub,
            achieved,
            probes: a.probe_calls,
        })
    });
    let cells = cells.into_iter().collect::<Result<Vec<_>>>()?;

    let mut out = String::from(
        "Static capacity analysis — closed-form bound vs simulated throughput\n\
         (4 A100-class workers per cell: P prefill + (4-P) decode; the bound comes\n\
         from O(1) cost-model probes per worker config, never a simulation step;\n\
         tightness = achieved / bound, valid while <= 1)\n\n",
    );
    let mut table = Table::new(&["cell", "qps", "rho_dec", "bound req/s", "achieved", "tightness", "probes"]);
    let mut holds = 0usize;
    let mut bounded = 0usize;
    for c in &cells {
        let (bound_s, tight_s) = match c.bound {
            Some(b) => {
                bounded += 1;
                if c.achieved <= b {
                    holds += 1;
                }
                (f1(b), f3(c.achieved / b))
            }
            None => ("n/a".to_string(), "n/a".to_string()),
        };
        table.row(&[
            c.label.clone(),
            f1(c.qps),
            c.rho.map(f3).unwrap_or_else(|| "n/a".to_string()),
            bound_s,
            f3(c.achieved),
            tight_s,
            c.probes.to_string(),
        ]);
    }
    out.push_str(&table.finish());
    out.push_str(&format!(
        "\nbound validity: holds in {holds}/{bounded} bounded cells\n"
    ));
    out.push_str(&pruning_section(opts.prune, &pruned, total_cells));
    out.push_str(
        "\nshape targets: tightness grows with offered load (the fleet approaches\n\
         its service-rate cap) and never crosses 1; the starved decode cell is\n\
         pruned by the same qps-independent certainty exp hardware/network use.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_report_bounds_every_cell() {
        let out = run(&ExpOpts::quick()).unwrap();
        assert!(out.contains("bound validity: holds in 6/6"), "{out}");
        assert!(out.contains("static pruning: skipped 1 of 7"), "{out}");
        assert!(out.contains("starved"), "{out}");
    }

    #[test]
    fn bound_exceeds_simulated_throughput_per_cell() {
        let compute = ExpOpts::quick().compute;
        let c = cfg(1, &HardwareSpec::a100_80g(), 60, 32.0, &compute);
        let requests = c.workload.generate().unwrap();
        let a = analyze::analyze(&c, &requests);
        let report = run_tokensim(&c).unwrap();
        let achieved = report.records.len() as f64 / report.makespan;
        let bound = a.throughput_ub.unwrap();
        assert!(
            achieved <= bound,
            "static bound must be a true upper bound: {achieved} > {bound}"
        );
    }
}
