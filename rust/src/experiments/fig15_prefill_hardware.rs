//! Fig 15: prefill-device hardware sensitivity in a disaggregated
//! 8-device node — sweep compute (T), memory bandwidth (B) and memory
//! capacity (C) multipliers of the prefill GPU for P1-D7 / P2-D6 /
//! P3-D5 splits, reporting max SLO throughput.

use anyhow::Result;

use crate::config::SimulationConfig;
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;
use crate::workload::WorkloadSpec;

use super::common::*;

fn cfg(
    prefill_hw: HardwareSpec,
    np: u32,
    n_req: usize,
    qps: f64,
    cost: &crate::compute::ComputeSpec,
) -> SimulationConfig {
    let mut cfg = SimulationConfig::disaggregated(
        ModelSpec::llama2_7b(),
        prefill_hw,
        np,
        HardwareSpec::a100_80g(),
        8 - np,
        WorkloadSpec::sharegpt(n_req, qps),
    );
    cfg.compute = cost.clone();
    cfg
}

pub(super) fn max_thr(
    prefill_hw: HardwareSpec,
    np: u32,
    n_req: usize,
    cost: &crate::compute::ComputeSpec,
) -> Result<f64> {
    let build = |qps: f64| cfg(prefill_hw.clone(), np, n_req, qps, cost);
    Ok(max_slo_throughput(&build, 0.9, 4.0)?.1)
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    let n_req = opts.size(8_000, 120); // scaled from the paper's 50k (see fig9 note)
    let splits: &[u32] = if opts.quick { &[1] } else { &[1, 2, 3] };
    let a100 = HardwareSpec::a100_80g();

    // (label, prefill hardware variant)
    let mut variants: Vec<(String, HardwareSpec)> = vec![("Ori".into(), a100.clone())];
    let t_scales: &[f64] = if opts.quick { &[0.5, 2.0] } else { &[0.25, 0.5, 2.0, 4.0] };
    let b_scales: &[f64] = if opts.quick { &[0.25] } else { &[0.125, 0.25, 0.5, 2.0, 4.0] };
    let c_scales: &[f64] = if opts.quick { &[0.5] } else { &[0.25, 0.5, 2.0, 4.0] };
    for &s in t_scales {
        variants.push((format!("T{s}"), a100.scale_compute(s)));
    }
    for &s in b_scales {
        variants.push((format!("B{s}"), a100.scale_bandwidth(s)));
    }
    for &s in c_scales {
        variants.push((format!("C{s}"), a100.scale_capacity(s)));
    }

    let mut headers = vec!["variant".to_string()];
    headers.extend(splits.iter().map(|p| format!("P{p}-D{}", 8 - p)));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr);
    for (label, hw) in &variants {
        let mut cells = vec![label.clone()];
        for &np in splits {
            cells.push(f1(max_thr(hw.clone(), np, n_req, &opts.compute)?));
        }
        table.row(&cells);
    }

    let mut out = String::from(
        "Fig 15 — prefill-GPU parameter sensitivity (max SLO throughput, req/s)\n\
         T = compute scale, B = bandwidth scale, C = capacity scale vs original A100\n",
    );
    out.push_str(&table.finish());
    out.push_str(
        "\nshape target: B and C scaling barely move throughput (prefill is\n\
         compute-bound and memory-light); T scaling moves it strongly until the\n\
         aggregate prefill compute saturates the decode side's capability.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_compute_matters_bandwidth_does_not() {
        let cost = ExpOpts::quick().compute;
        let a100 = HardwareSpec::a100_80g();
        let base = max_thr(a100.clone(), 1, 120, &cost).unwrap();
        let slow_t = max_thr(a100.scale_compute(0.25), 1, 120, &cost).unwrap();
        let slow_b = max_thr(a100.scale_bandwidth(0.25), 1, 120, &cost).unwrap();
        assert!(
            slow_t < 0.8 * base,
            "1/4 compute should hurt: {slow_t} vs {base}"
        );
        assert!(
            slow_b > 0.8 * base,
            "1/4 bandwidth should not: {slow_b} vs {base}"
        );
    }
}
