//! `tokensim exp scale` — the million-request engine benchmark behind
//! the ROADMAP's "heavy traffic from millions of users" north star.
//!
//! Sweeps request counts (10k / 100k / 1M in full mode) over a
//! decode-heavy workload with decode fast-forwarding off and on,
//! reporting wall-clock seconds, heap events processed and events/sec
//! for each cell — the first tracked perf baseline of the repo's BENCH
//! trajectory. Each pair of runs is also cross-checked: the coalesced
//! report must be byte-identical to the event-per-iteration one, so
//! this experiment doubles as a determinism gate at scale.
//!
//! A second tier exercises **sketch metrics mode** (`metrics: mode:
//! sketch`): one run re-executes the largest exact cell and asserts
//! every reported quantile lands within the sketch's relative-error
//! bound of the exact order statistics (plus bit-equality of the
//! count/ratio aggregates), then a 10M-request cell (10k in `--quick`)
//! runs with fast-forwarding on and fixed-size metric state — no
//! O(requests) sample `Vec`s — reporting wall clock, events/sec and a
//! peak-RSS estimate.
//!
//! A third, **cost-model tier** exercises the two per-iteration
//! cost-elimination layers: `hlo` with and without its default `memo`
//! layer (reports must byte-diff clean once the memo layer's own name
//! and counters are stripped — see
//! [`strip_compute_identity`](crate::cluster::strip_compute_identity) —
//! with a ≥3× wall-clock bar in full mode against the artifact-backed
//! HLO model), and `engine: window_cost: affine` against the replay
//! reference (counts bit-equal, time metrics within 1e-3 relative).
//! The 10M sketch cell runs memoized **and** affine and asserts, in
//! full mode, that the run needs ≥100× fewer base-model evaluations
//! than it has logical iterations.
//!
//! Like fig 6, the *output* of this experiment is wall-clock time, so
//! rows run sequentially by default; setting `TOKENSIM_SWEEP_THREADS`
//! explicitly opts into parallel rows (each row's off/on pair still
//! shares one thread, preserving the within-row comparison).
//!
//! With `TOKENSIM_BENCH_JSON=<path>` set, every cell appends one JSON
//! line in the bench-harness schema (`{"name", "iters", "mean_ns",
//! "p50_ns", "p99_ns", "per_sec"}` — sketch cells add
//! `"peak_rss_bytes"`, which the artifact assembler tolerates), so CI
//! folds the scale rows into the uploaded `BENCH_ci.json` artifact
//! alongside the `cargo bench` cases.

use std::io::Write as _;

use anyhow::{ensure, Context, Result};

use crate::cluster::{strip_compute_identity, Simulation, SimulationReport};
use crate::compute::ComputeSpec;
use crate::config::{SimulationConfig, WindowCost};
use crate::hardware::HardwareSpec;
use crate::metrics::MetricsMode;
use crate::model::ModelSpec;
use crate::workload::WorkloadSpec;

use super::common::*;

/// Decode-heavy workload: short prompts, long outputs, an arrival rate
/// that keeps batches busy while leaving long closed-batch windows —
/// the regime iteration-coalescing targets (and the regime a chatbot
/// fleet actually serves: most tokens are decode tokens).
fn cfg(n: usize, cost: &crate::compute::ComputeSpec) -> SimulationConfig {
    let mut cfg = SimulationConfig::single_worker(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        WorkloadSpec::fixed(n, 4.0, 32, 256),
    );
    cfg.compute = cost.clone();
    cfg
}

struct CellResult {
    wall: f64,
    events: u64,
    report: SimulationReport,
}

fn run_cell(n: usize, fast_forward: bool, sketch: bool, opts: &ExpOpts) -> Result<CellResult> {
    run_cell_with(n, &opts.compute, fast_forward, WindowCost::Replay, sketch)
}

fn run_cell_with(
    n: usize,
    spec: &ComputeSpec,
    fast_forward: bool,
    window_cost: WindowCost,
    sketch: bool,
) -> Result<CellResult> {
    let mut cfg = cfg(n, spec);
    cfg.engine.fast_forward = fast_forward;
    cfg.engine.window_cost = window_cost;
    if sketch {
        cfg.metrics.mode = MetricsMode::Sketch;
    }
    // build first, time only the event loop: charging 1M-request
    // workload generation to both rows would dilute the very off/on
    // engine comparison this experiment exists to measure
    let sim = Simulation::from_config(&cfg).expect("experiment config must build");
    let t0 = std::time::Instant::now();
    let report = sim.run().with_context(|| {
        format!("scale cell n={n} fast_forward={fast_forward} sketch={sketch}")
    })?;
    Ok(CellResult {
        wall: t0.elapsed().as_secs_f64(),
        events: report.events_processed,
        report,
    })
}

/// The compute spec for the memoized tiers: the expensive built-ins are
/// memoized by default already; the cheap exact models get an explicit
/// `memo` layer so the tier can count cache traffic. Anything else
/// (`oracle` is stochastic and must never be cached) runs as
/// configured, and the cache assertions are skipped downstream when no
/// cache layer reports stats.
fn memoized_spec(spec: &ComputeSpec) -> ComputeSpec {
    match spec.name.as_str() {
        "analytic" | "roofline" | "table" => {
            ComputeSpec::new("memo").with("base", spec.name.as_str())
        }
        _ => spec.clone(),
    }
}

/// Relative agreement bound for the affine-vs-replay comparison. The
/// engine verifies each affine window at its boundary to 1e-4 relative
/// (`cluster::AFFINE_REL_TOL`); whole-run aggregates accumulate those
/// per-window errors but stay well inside 1e-3 — the documented
/// tolerance for `engine: window_cost: affine` reports.
const AFFINE_REPORT_TOL: f64 = 1e-3;

fn rel_close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * a.abs().max(b.abs()).max(1e-12)
}

/// Append one bench-artifact line per cell (no-op when
/// `TOKENSIM_BENCH_JSON` is unset) — the same JSON-lines schema
/// `benches/harness.rs` emits, so the CI artifact assembler needs no
/// special case for the scale rows. Sketch cells append their
/// peak-RSS estimate as an extra field.
pub(super) fn emit_bench_row(name: &str, wall: f64, events_per_sec: f64, peak_rss: Option<u64>) {
    let Ok(path) = std::env::var("TOKENSIM_BENCH_JSON") else {
        return;
    };
    let ns = wall * 1e9;
    let rss = peak_rss
        .map(|b| format!(",\"peak_rss_bytes\":{b}"))
        .unwrap_or_default();
    let line = format!(
        "{{\"name\":\"{name}\",\"iters\":1,\"mean_ns\":{ns:.1},\"p50_ns\":{ns:.1},\"p99_ns\":{ns:.1},\"per_sec\":{events_per_sec:.3}{rss}}}\n",
    );
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .and_then(|mut f| f.write_all(line.as_bytes()));
    if let Err(e) = appended {
        eprintln!("warning: TOKENSIM_BENCH_JSON={path}: {e}");
    }
}

/// Assert `est` lies in the documented sketch error window around the
/// exact order statistics: `sorted[floor(pos)] * (1 - eps) <= est <=
/// sorted[ceil(pos)] * (1 + eps)` with `pos = q * (n - 1)`.
fn check_window(sorted: &[f64], q: f64, est: f64, eps: f64) -> Result<()> {
    let pos = q * (sorted.len() - 1) as f64;
    let lo = sorted[pos.floor() as usize] * (1.0 - eps) - 1e-12;
    let hi = sorted[pos.ceil() as usize] * (1.0 + eps) + 1e-12;
    ensure!(
        est >= lo && est <= hi,
        "sketch quantile {est} outside [{lo}, {hi}] at q={q}"
    );
    Ok(())
}

/// The exact-vs-sketch acceptance check: same simulation, two metric
/// modes. Counts, makespan, goodput and attainment must be equal bit
/// for bit (they are counts, min/max folds and integer sums); every
/// reported quantile must land in the sketch's error window.
fn assert_sketch_matches_exact(exact: &SimulationReport, sketch: &SimulationReport) -> Result<()> {
    ensure!(
        sketch.records.is_empty(),
        "sketch mode must not retain per-request records"
    );
    let stream = sketch
        .stream
        .as_ref()
        .context("sketch report carries streaming metrics")?;
    let eps = stream.relative_error();
    ensure!(exact.records.len() == stream.len(), "request counts differ");
    ensure!(exact.makespan == sketch.makespan, "makespan diverged");
    ensure!(
        exact.token_throughput() == sketch.token_throughput(),
        "token throughput diverged"
    );
    ensure!(
        exact.slo_attainment() == sketch.slo_attainment(),
        "SLO attainment diverged"
    );
    ensure!(
        exact.slo_throughput() == sketch.slo_throughput(),
        "goodput diverged"
    );
    let mut lats: Vec<f64> = exact.records.iter().map(|r| r.latency()).collect();
    let mut ttfts: Vec<f64> = exact.records.iter().map(|r| r.ttft()).collect();
    let mut tbts: Vec<f64> = exact.records.iter().map(|r| r.max_token_gap).collect();
    for v in [&mut lats, &mut ttfts, &mut tbts] {
        v.sort_by(|a, b| a.total_cmp(b));
    }
    for q in [0.5, 0.9, 0.99, 0.999] {
        check_window(&lats, q, stream.latency_quantile(q), eps)
            .with_context(|| format!("latency vs exact p{}", q * 100.0))?;
        check_window(&ttfts, q, stream.ttft_quantile(q), eps)
            .with_context(|| format!("ttft vs exact p{}", q * 100.0))?;
        check_window(&tbts, q, stream.tbt_quantile(q), eps)
            .with_context(|| format!("tbt vs exact p{}", q * 100.0))?;
    }
    Ok(())
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    let counts: &[usize] = if opts.quick {
        &[1_000, 5_000]
    } else {
        &[10_000, 100_000, 1_000_000]
    };
    // the largest cell that keeps exact records around for comparison
    let cmp_n: usize = if opts.quick { 5_000 } else { 100_000 };
    // the bounded-memory tier: sketch mode only, fast-forward on
    let big_n: usize = if opts.quick { 10_000 } else { 10_000_000 };

    let mut table = Table::new(&[
        "requests",
        "ff",
        "wall (s)",
        "events",
        "events/sec",
        "sim (s)",
        "identical",
    ]);

    // each row measures its own wall clock: sequential by default,
    // parallel only on explicit TOKENSIM_SWEEP_THREADS (fig 6 idiom)
    let time_row = |&n: &usize| -> Result<(usize, CellResult, CellResult)> {
        let off = run_cell(n, false, false, opts)?;
        let on = run_cell(n, true, false, opts)?;
        Ok((n, off, on))
    };
    let rows: Vec<Result<(usize, CellResult, CellResult)>> =
        if std::env::var("TOKENSIM_SWEEP_THREADS").is_ok() {
            parallel_sweep(counts, time_row)
        } else {
            counts.iter().map(time_row).collect()
        };

    let mut min_ratio = f64::INFINITY;
    let mut cmp_exact: Option<SimulationReport> = None;
    for row in rows {
        let (n, off, on) = row?;
        // the tentpole contract: coalescing must not change anything
        // simulated — compare the deterministic reports (per-request
        // records and per-worker stats always; the full JSON rendering
        // too, except at 1M requests where the two ~100 MB strings are
        // pure memory overhead on top of the structural comparison)
        let identical = off.report.records == on.report.records
            && off.report.workers.len() == on.report.workers.len()
            && off
                .report
                .workers
                .iter()
                .zip(&on.report.workers)
                .all(|(a, b)| a.simulated_eq(b))
            && (n > 100_000
                || off.report.to_json().to_string() == on.report.to_json().to_string());
        ensure!(
            identical,
            "fast-forward diverged from the event-per-iteration run at n={n}"
        );
        for (label, cell) in [("off", &off), ("on", &on)] {
            let eps = cell.events as f64 / cell.wall.max(1e-9);
            table.row(&[
                n.to_string(),
                label.to_string(),
                f3(cell.wall),
                cell.events.to_string(),
                format!("{eps:.0}"),
                f1(cell.report.sim_end),
                "yes".to_string(),
            ]);
            emit_bench_row(&format!("exp_scale/n={n}/ff={label}"), cell.wall, eps, None);
        }
        min_ratio = min_ratio.min(off.events as f64 / on.events.max(1) as f64);
        if n == cmp_n {
            cmp_exact = Some(on.report);
        }
    }

    // the acceptance bar is enforced here, not just in a unit test, so
    // the CI smoke step fails if coalescing regresses on the defined
    // quick workload even while reports stay byte-identical
    if opts.quick {
        ensure!(
            min_ratio >= 5.0,
            "fast-forward coalesced only {min_ratio:.1}x fewer events on the \
             decode-heavy quick workload (acceptance bar: >=5x)"
        );
    }

    // ---- memoization tier ----------------------------------------------
    // Same workload, `hlo` with and without its default memo layer.
    // Memoization is bit-exact by construction (cached values *are* the
    // base model's values), so the two reports must agree byte-for-byte
    // once the memo layer's own traces — the compute name and the
    // cache counters — are stripped.
    let memo_n: usize = if opts.quick { 2_000 } else { 1_000_000 };
    let plain_spec = ComputeSpec::new("hlo").with("memoize", false);
    let plain = run_cell_with(memo_n, &plain_spec, true, WindowCost::Replay, false)?;
    let memo = run_cell_with(memo_n, &ComputeSpec::new("hlo"), true, WindowCost::Replay, false)?;
    ensure!(
        plain.report.records == memo.report.records,
        "memoization changed simulated records at n={memo_n}"
    );
    for (a, b) in plain.report.workers.iter().zip(&memo.report.workers) {
        ensure!(
            a.iterations == b.iterations && a.busy_time == b.busy_time && a.swap == b.swap,
            "memoization changed per-worker stats"
        );
    }
    if memo_n <= 100_000 {
        // full-JSON byte diff modulo the memo layer's identity (at 1M
        // the two ~100 MB strings add nothing over the record/stat
        // comparison above)
        ensure!(
            strip_compute_identity(&plain.report.to_json().to_string())
                == strip_compute_identity(&memo.report.to_json().to_string()),
            "memoized JSON report differs beyond the compute name and cache counters"
        );
    }
    let memo_stats = memo.report.workers[0].cache.unwrap_or_default();
    ensure!(memo_stats.total() > 0, "memo layer saw no iter_time calls");
    let memo_ratio = plain.wall / memo.wall.max(1e-9);
    // the >=3x wall-clock acceptance bar binds against the *artifact*
    // HLO model (whose per-call interpolation is what memoization
    // amortizes); when the artifacts are absent `hlo` falls back to the
    // cheap analytic mirror, where the cache can only win its own
    // overhead back and the ratio is reported, not asserted
    let real_hlo = plain.report.workers[0].compute.starts_with("hlo[");
    if !opts.quick && real_hlo {
        ensure!(
            memo_ratio >= 3.0,
            "memoized hlo sped wall clock up only {memo_ratio:.2}x at n={memo_n} \
             (acceptance bar: >=3x)"
        );
    }
    let mut cm_table = Table::new(&[
        "tier",
        "requests",
        "wall (s)",
        "cache hits",
        "misses",
        "hit rate",
        "check",
    ]);
    cm_table.row(&[
        "hlo unmemoized".to_string(),
        memo_n.to_string(),
        f3(plain.wall),
        "-".to_string(),
        "-".to_string(),
        "-".to_string(),
        "reference".to_string(),
    ]);
    cm_table.row(&[
        "hlo memoized".to_string(),
        memo_n.to_string(),
        f3(memo.wall),
        memo_stats.hits.to_string(),
        memo_stats.misses.to_string(),
        format!("{:.1}%", 100.0 * memo_stats.hit_rate()),
        format!("byte-identical, {memo_ratio:.2}x wall"),
    ]);
    emit_bench_row(
        &format!("exp_scale/n={memo_n}/hlo-plain"),
        plain.wall,
        plain.events as f64 / plain.wall.max(1e-9),
        None,
    );
    emit_bench_row(
        &format!("exp_scale/n={memo_n}/hlo-memo"),
        memo.wall,
        memo.events as f64 / memo.wall.max(1e-9),
        None,
    );
    drop(plain);
    drop(memo);

    // ---- affine window-costing tier ------------------------------------
    // Replay reference: the ff=on exact run at cmp_n from the main
    // table. Affine costing keeps every simulated *count* and agrees on
    // times to the documented tolerance; it is not byte-exact, which is
    // why replay stays the default.
    let affine = run_cell_with(cmp_n, &opts.compute, true, WindowCost::Affine, false)?;
    {
        let replay_ref = cmp_exact.as_ref().context("comparison cell must have run")?;
        ensure!(
            affine.report.records.len() == replay_ref.records.len(),
            "affine window costing lost requests"
        );
        let am = affine.report.view();
        let rm = replay_ref.view();
        ensure!(
            am.total_preemptions() == rm.total_preemptions()
                && am.total_swaps() == rm.total_swaps(),
            "affine window costing changed preemption/swap counts"
        );
        for (what, a, b) in [
            ("makespan", affine.report.makespan, replay_ref.makespan),
            ("latency p50", am.latency_percentile(0.50), rm.latency_percentile(0.50)),
            ("latency p99", am.latency_percentile(0.99), rm.latency_percentile(0.99)),
            ("token throughput", am.token_throughput(), rm.token_throughput()),
        ] {
            ensure!(
                rel_close(a, b, AFFINE_REPORT_TOL),
                "affine {what} {a} vs replay {b} outside {AFFINE_REPORT_TOL:e} relative"
            );
        }
    }
    let aw = &affine.report.workers[0];
    ensure!(
        aw.affine_windows > 0 && aw.window_calls_saved > 0,
        "affine window costing never engaged on the decode-heavy workload"
    );
    cm_table.row(&[
        "affine windows".to_string(),
        cmp_n.to_string(),
        f3(affine.wall),
        "-".to_string(),
        "-".to_string(),
        "-".to_string(),
        format!(
            "{} windows, {} calls saved, metrics within {AFFINE_REPORT_TOL:e}",
            aw.affine_windows, aw.window_calls_saved
        ),
    ]);
    emit_bench_row(
        &format!("exp_scale/n={cmp_n}/affine"),
        affine.wall,
        affine.events as f64 / affine.wall.max(1e-9),
        None,
    );
    drop(affine);

    // ---- sketch tier ---------------------------------------------------
    let mut sk_table = Table::new(&[
        "requests",
        "wall (s)",
        "events",
        "events/sec",
        "peak RSS (MB)",
        "check",
    ]);
    let rss_mb = || {
        crate::util::peak_rss_bytes()
            .map(|b| format!("{:.0}", b as f64 / (1024.0 * 1024.0)))
            .unwrap_or_else(|| "-".to_string())
    };

    let sk_cmp = run_cell(cmp_n, true, true, opts)?;
    let exact = cmp_exact.context("comparison cell must have run")?;
    assert_sketch_matches_exact(&exact, &sk_cmp.report)
        .with_context(|| format!("sketch vs exact at n={cmp_n}"))?;
    let sketch_eps = sk_cmp
        .report
        .stream
        .as_ref()
        .map(|s| s.relative_error())
        .unwrap_or(0.0);
    drop(exact); // 100k exact records are dead weight past this point
    let cmp_eps = sk_cmp.events as f64 / sk_cmp.wall.max(1e-9);
    sk_table.row(&[
        cmp_n.to_string(),
        f3(sk_cmp.wall),
        sk_cmp.events.to_string(),
        format!("{cmp_eps:.0}"),
        rss_mb(),
        format!("quantiles within ±{:.1}% of exact", 100.0 * sketch_eps),
    ]);
    emit_bench_row(
        &format!("exp_scale/n={cmp_n}/sketch"),
        sk_cmp.wall,
        cmp_eps,
        crate::util::peak_rss_bytes(),
    );

    // the bounded-memory tier doubles as the cost-model call-budget
    // check: memoize the configured model (the expensive built-ins
    // already are) and cost decode windows with the affine series, then
    // count how many base-model evaluations the run actually needed
    let big_spec = memoized_spec(&opts.compute);
    let big = run_cell_with(big_n, &big_spec, true, WindowCost::Affine, true)?;
    ensure!(
        big.report.records.is_empty(),
        "bounded-memory tier must not accumulate records"
    );
    let big_w = &big.report.workers[0];
    let call_reduction = big_w
        .cache
        .map(|cs| big_w.iterations as f64 / cs.misses.max(1) as f64);
    if opts.quick {
        // the deterministic quick-mode bar: the affine path must engage
        // (every drained run ends in long closed decode windows) and the
        // memo layer must be live
        ensure!(
            big_w.window_calls_saved > 0,
            "affine window costing saved no calls on the 10k sketch tier"
        );
        ensure!(call_reduction.is_some(), "memo layer missing on the sketch tier");
    } else if call_reduction.is_some() {
        // the full-mode acceptance bar: logical decode iterations per
        // base-model evaluation — memoization collapses the steady
        // state's recurring aggregates and the affine series never asks
        // for mid-window iterations at all
        let r = call_reduction.unwrap_or(1.0);
        ensure!(
            r >= 100.0,
            "10M tier evaluated the base model every {r:.1} iterations \
             (acceptance bar: >=100x reduction)"
        );
    }
    ensure!(
        big.report.view().len() == big_n,
        "bounded-memory tier lost requests"
    );
    let metric_bytes = big
        .report
        .stream
        .as_ref()
        .map(|s| s.memory_bytes())
        .unwrap_or(0);
    let big_eps = big.events as f64 / big.wall.max(1e-9);
    sk_table.row(&[
        big_n.to_string(),
        f3(big.wall),
        big.events.to_string(),
        format!("{big_eps:.0}"),
        rss_mb(),
        format!("metric state {:.0} KiB (fixed)", metric_bytes as f64 / 1024.0),
    ]);
    emit_bench_row(
        &format!("exp_scale/n={big_n}/sketch"),
        big.wall,
        big_eps,
        crate::util::peak_rss_bytes(),
    );

    let mut out = String::from(
        "exp scale — engine throughput at fleet scale (decode-heavy workload;\n\
         ff = decode fast-forwarding; 'identical' = byte-identical JSON reports)\n",
    );
    out.push_str(&table.finish());
    out.push_str(&format!(
        "\nevent coalescing: >= {min_ratio:.1}x fewer heap events with fast-forward on\n\
         (closed decode batches advance to the next completion / external event /\n\
         memory boundary in one event instead of one per generated token).\n",
    ));
    out.push_str(&format!(
        "\ncost-model tier — exact memoization (`memo` layer, on by default for\n\
         hlo/vidur_like/llmservingsim_like) and closed-form affine window costing\n\
         (`engine: window_cost: affine`):\n{}",
        cm_table.finish(),
    ));
    if let Some(r) = call_reduction {
        out.push_str(&format!(
            "\n10M-tier cost-model budget: {:.0} logical iterations per base-model\n\
             evaluation ({} evaluated, {} cache hits, {} calls never made thanks\n\
             to the affine series).\n",
            r,
            big_w.cache.map(|c| c.misses).unwrap_or(0),
            big_w.cache.map(|c| c.hits).unwrap_or(0),
            big_w.window_calls_saved,
        ));
    }
    out.push_str(&format!(
        "\nsketch tier — streaming metrics, fast-forward on (peak RSS is the\n\
         process high-water mark from /proc or getrusage, cumulative across\n\
         cells):\n{}\
         \nsketch quantiles verified within ±{:.1}% relative error of the exact\n\
         run at n={cmp_n}; counts, makespan, goodput and attainment equal bit-for-bit.\n",
        sk_table.finish(),
        100.0 * sketch_eps,
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_scale_run_coalesces_and_stays_identical() {
        let out = run(&ExpOpts::quick()).unwrap();
        // the acceptance bar: >=5x fewer processed events on the
        // decode-heavy quick workload (the report prints the minimum
        // off/on ratio across rows)
        let line = out
            .lines()
            .find(|l| l.starts_with("event coalescing"))
            .unwrap();
        let ratio: f64 = line
            .split(">= ")
            .nth(1)
            .unwrap()
            .split('x')
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(ratio >= 5.0, "expected >=5x event reduction, got {ratio}x");
        assert!(out.contains("yes"), "identity column missing:\n{out}");
        assert!(out.contains("sketch tier"), "sketch tier missing:\n{out}");
        assert!(
            out.contains("verified within"),
            "quantile check line missing:\n{out}"
        );
        assert!(
            out.contains("cost-model tier"),
            "memo/affine tier missing:\n{out}"
        );
        assert!(out.contains("hlo memoized"), "memo row missing:\n{out}");
        assert!(
            out.contains("affine windows"),
            "affine row missing:\n{out}"
        );
    }

    #[test]
    fn memoized_cells_match_unmemoized_bit_for_bit() {
        let plain_spec = ComputeSpec::new("hlo").with("memoize", false);
        let plain = run_cell_with(500, &plain_spec, true, WindowCost::Replay, false).unwrap();
        let memo =
            run_cell_with(500, &ComputeSpec::new("hlo"), true, WindowCost::Replay, false).unwrap();
        assert_eq!(plain.report.records, memo.report.records);
        assert_eq!(
            strip_compute_identity(&plain.report.to_json().to_string()),
            strip_compute_identity(&memo.report.to_json().to_string())
        );
        let cs = memo.report.workers[0].cache.unwrap();
        assert!(cs.total() > 0, "memo layer saw no calls");
        assert!(plain.report.workers[0].cache.is_none(), "memoize: false obeyed");
    }

    #[test]
    fn affine_windows_track_replay_within_tolerance() {
        let spec = ComputeSpec::new("analytic");
        let replay = run_cell_with(500, &spec, true, WindowCost::Replay, false).unwrap();
        let affine = run_cell_with(500, &spec, true, WindowCost::Affine, false).unwrap();
        assert_eq!(replay.report.records.len(), affine.report.records.len());
        let aw = &affine.report.workers[0];
        assert!(aw.affine_windows > 0, "affine never engaged");
        assert!(aw.window_calls_saved > 0);
        assert_eq!(replay.report.workers[0].affine_windows, 0, "replay stays replay");
        assert!(rel_close(
            replay.report.makespan,
            affine.report.makespan,
            AFFINE_REPORT_TOL
        ));
        let rm = replay.report.view();
        let am = affine.report.view();
        for q in [0.5, 0.99] {
            assert!(rel_close(
                rm.latency_percentile(q),
                am.latency_percentile(q),
                AFFINE_REPORT_TOL
            ));
        }
    }

    #[test]
    fn cells_report_events_and_finish() {
        let off = run_cell(300, false, false, &ExpOpts::quick()).unwrap();
        let on = run_cell(300, true, false, &ExpOpts::quick()).unwrap();
        assert_eq!(off.report.records.len(), 300);
        assert_eq!(on.report.records.len(), 300);
        assert!(on.events < off.events, "{} !< {}", on.events, off.events);
    }

    #[test]
    fn sketch_cell_bounds_memory_and_matches_exact() {
        let exact = run_cell(400, true, false, &ExpOpts::quick()).unwrap();
        let sketch = run_cell(400, true, true, &ExpOpts::quick()).unwrap();
        assert_sketch_matches_exact(&exact.report, &sketch.report).unwrap();
        assert!(sketch.report.records.is_empty());
        assert_eq!(sketch.report.view().len(), 400);
        let s = sketch.report.stream.as_ref().unwrap();
        assert!(s.memory_bytes() < 1024 * 1024, "{}", s.memory_bytes());
    }
}
