//! `tokensim exp scale` — the million-request engine benchmark behind
//! the ROADMAP's "heavy traffic from millions of users" north star.
//!
//! Sweeps request counts (10k / 100k / 1M in full mode) over a
//! decode-heavy workload with decode fast-forwarding off and on,
//! reporting wall-clock seconds, heap events processed and events/sec
//! for each cell — the first tracked perf baseline of the repo's BENCH
//! trajectory. Each pair of runs is also cross-checked: the coalesced
//! report must be byte-identical to the event-per-iteration one, so
//! this experiment doubles as a determinism gate at scale.
//!
//! Like fig 6, the *output* of this experiment is wall-clock time, so
//! rows run sequentially by default; setting `TOKENSIM_SWEEP_THREADS`
//! explicitly opts into parallel rows (each row's off/on pair still
//! shares one thread, preserving the within-row comparison).
//!
//! With `TOKENSIM_BENCH_JSON=<path>` set, every cell appends one JSON
//! line in the bench-harness schema (`{"name", "iters", "mean_ns",
//! "p50_ns", "p99_ns", "per_sec"}`), so CI folds the scale rows into
//! the uploaded `BENCH_ci.json` artifact alongside the `cargo bench`
//! cases.

use std::io::Write as _;

use anyhow::{ensure, Context, Result};

use crate::cluster::{Simulation, SimulationReport};
use crate::config::SimulationConfig;
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;
use crate::workload::WorkloadSpec;

use super::common::*;

/// Decode-heavy workload: short prompts, long outputs, an arrival rate
/// that keeps batches busy while leaving long closed-batch windows —
/// the regime iteration-coalescing targets (and the regime a chatbot
/// fleet actually serves: most tokens are decode tokens).
fn cfg(n: usize, cost: &crate::compute::ComputeSpec) -> SimulationConfig {
    let mut cfg = SimulationConfig::single_worker(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        WorkloadSpec::fixed(n, 4.0, 32, 256),
    );
    cfg.compute = cost.clone();
    cfg
}

struct CellResult {
    wall: f64,
    events: u64,
    report: SimulationReport,
}

fn run_cell(n: usize, fast_forward: bool, opts: &ExpOpts) -> Result<CellResult> {
    let mut cfg = cfg(n, &opts.compute);
    cfg.engine.fast_forward = fast_forward;
    // build first, time only the event loop: charging 1M-request
    // workload generation to both rows would dilute the very off/on
    // engine comparison this experiment exists to measure
    let sim = Simulation::from_config(&cfg).expect("experiment config must build");
    let t0 = std::time::Instant::now();
    let report = sim
        .run()
        .with_context(|| format!("scale cell n={n} fast_forward={fast_forward}"))?;
    Ok(CellResult {
        wall: t0.elapsed().as_secs_f64(),
        events: report.events_processed,
        report,
    })
}

/// Append one bench-artifact line per cell (no-op when
/// `TOKENSIM_BENCH_JSON` is unset) — the same JSON-lines schema
/// `benches/harness.rs` emits, so the CI artifact assembler needs no
/// special case for the scale rows.
fn emit_bench_row(name: &str, wall: f64, events_per_sec: f64) {
    let Ok(path) = std::env::var("TOKENSIM_BENCH_JSON") else {
        return;
    };
    let ns = wall * 1e9;
    let line = format!(
        "{{\"name\":\"{name}\",\"iters\":1,\"mean_ns\":{ns:.1},\"p50_ns\":{ns:.1},\"p99_ns\":{ns:.1},\"per_sec\":{events_per_sec:.3}}}\n",
    );
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .and_then(|mut f| f.write_all(line.as_bytes()));
    if let Err(e) = appended {
        eprintln!("warning: TOKENSIM_BENCH_JSON={path}: {e}");
    }
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    let counts: &[usize] = if opts.quick {
        &[1_000, 5_000]
    } else {
        &[10_000, 100_000, 1_000_000]
    };

    let mut table = Table::new(&[
        "requests",
        "ff",
        "wall (s)",
        "events",
        "events/sec",
        "sim (s)",
        "identical",
    ]);

    // each row measures its own wall clock: sequential by default,
    // parallel only on explicit TOKENSIM_SWEEP_THREADS (fig 6 idiom)
    let time_row = |&n: &usize| -> Result<(usize, CellResult, CellResult)> {
        let off = run_cell(n, false, opts)?;
        let on = run_cell(n, true, opts)?;
        Ok((n, off, on))
    };
    let rows: Vec<Result<(usize, CellResult, CellResult)>> =
        if std::env::var("TOKENSIM_SWEEP_THREADS").is_ok() {
            parallel_sweep(counts, time_row)
        } else {
            counts.iter().map(time_row).collect()
        };

    let mut min_ratio = f64::INFINITY;
    for row in rows {
        let (n, off, on) = row?;
        // the tentpole contract: coalescing must not change anything
        // simulated — compare the deterministic reports (per-request
        // records and per-worker stats always; the full JSON rendering
        // too, except at 1M requests where the two ~100 MB strings are
        // pure memory overhead on top of the structural comparison)
        let identical = off.report.records == on.report.records
            && off.report.workers == on.report.workers
            && (n > 100_000
                || off.report.to_json().to_string() == on.report.to_json().to_string());
        ensure!(
            identical,
            "fast-forward diverged from the event-per-iteration run at n={n}"
        );
        for (label, cell) in [("off", &off), ("on", &on)] {
            let eps = cell.events as f64 / cell.wall.max(1e-9);
            table.row(&[
                n.to_string(),
                label.to_string(),
                f3(cell.wall),
                cell.events.to_string(),
                format!("{eps:.0}"),
                f1(cell.report.sim_end),
                "yes".to_string(),
            ]);
            emit_bench_row(&format!("exp_scale/n={n}/ff={label}"), cell.wall, eps);
        }
        min_ratio = min_ratio.min(off.events as f64 / on.events.max(1) as f64);
    }

    // the acceptance bar is enforced here, not just in a unit test, so
    // the CI smoke step fails if coalescing regresses on the defined
    // quick workload even while reports stay byte-identical
    if opts.quick {
        ensure!(
            min_ratio >= 5.0,
            "fast-forward coalesced only {min_ratio:.1}x fewer events on the \
             decode-heavy quick workload (acceptance bar: >=5x)"
        );
    }

    let mut out = String::from(
        "exp scale — engine throughput at fleet scale (decode-heavy workload;\n\
         ff = decode fast-forwarding; 'identical' = byte-identical JSON reports)\n",
    );
    out.push_str(&table.finish());
    out.push_str(&format!(
        "\nevent coalescing: >= {min_ratio:.1}x fewer heap events with fast-forward on\n\
         (closed decode batches advance to the next completion / external event /\n\
         memory boundary in one event instead of one per generated token).\n",
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_scale_run_coalesces_and_stays_identical() {
        let out = run(&ExpOpts::quick()).unwrap();
        // the acceptance bar: >=5x fewer processed events on the
        // decode-heavy quick workload (the report prints the minimum
        // off/on ratio across rows)
        let line = out
            .lines()
            .find(|l| l.starts_with("event coalescing"))
            .unwrap();
        let ratio: f64 = line
            .split(">= ")
            .nth(1)
            .unwrap()
            .split('x')
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(ratio >= 5.0, "expected >=5x event reduction, got {ratio}x");
        assert!(out.contains("yes"), "identity column missing:\n{out}");
    }

    #[test]
    fn cells_report_events_and_finish() {
        let off = run_cell(300, false, &ExpOpts::quick()).unwrap();
        let on = run_cell(300, true, &ExpOpts::quick()).unwrap();
        assert_eq!(off.report.records.len(), 300);
        assert_eq!(on.report.records.len(), 300);
        assert!(on.events < off.events, "{} !< {}", on.events, off.events);
    }
}
