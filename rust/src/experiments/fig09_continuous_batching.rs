//! Fig 9: normalized latency — static vs continuous batching across
//! request rates and batch-size caps (8/16/32/inf), LLaMA2-7B on A100
//! with ShareGPT requests (50k in the paper; scaled here by --quick).

use anyhow::Result;

use crate::config::SimulationConfig;
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;
use crate::scheduler::PolicySpec;
use crate::workload::WorkloadSpec;

use super::common::*;

fn cfg(
    n: usize,
    qps: f64,
    policy: PolicySpec,
    cost: &crate::compute::ComputeSpec,
) -> SimulationConfig {
    let mut cfg = SimulationConfig::single_worker(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        WorkloadSpec::sharegpt(n, qps),
    );
    cfg.cluster.workers[0].local_scheduler = policy;
    cfg.compute = cost.clone();
    cfg
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    // paper sweeps 50k requests; 20k keeps the full suite fast and the
    // distribution-level metrics are size-stable at this scale
    let n = opts.size(20_000, 400);
    let rates: &[f64] = if opts.quick {
        &[1.0, 4.0, 10.0]
    } else {
        &[1.0, 2.0, 4.0, 8.0, 12.0, 16.0, 20.0, 24.0, 28.0, 32.0]
    };
    let caps: &[(Option<u32>, &str)] = if opts.quick {
        &[(Some(8), "8"), (None, "inf")]
    } else {
        &[(Some(8), "8"), (Some(16), "16"), (Some(32), "32"), (None, "inf")]
    };

    let mut headers = vec!["qps".to_string()];
    for (_, label) in caps {
        headers.push(format!("static-{label}"));
        headers.push(format!("cont-{label}"));
    }
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr_refs);

    // sweep the (qps x cap) grid across cores; each cell runs its
    // static + continuous pair
    let results: Vec<Vec<Result<(f64, f64)>>> = sweep_grid(rates, caps, |&qps, &(cap, _)| {
        // static batching cap: 'inf' static means a huge fixed batch
        let static_policy = PolicySpec::new("static")
            .with("batch_size", cap.unwrap_or(512))
            .with("max_linger", 2.0);
        let cont_policy = PolicySpec::new("continuous")
            .with("max_batched_tokens", 8192u32)
            .with("max_batch_size", cap);
        let s = run_tokensim(&cfg(n, qps, static_policy, &opts.compute))?;
        let c = run_tokensim(&cfg(n, qps, cont_policy, &opts.compute))?;
        Ok((
            s.metrics().mean_normalized_latency(),
            c.metrics().mean_normalized_latency(),
        ))
    });
    for (&qps, row) in rates.iter().zip(results) {
        let mut cells = vec![f1(qps)];
        for cell in row {
            let (s, c) = cell?;
            cells.push(f3(s));
            cells.push(f3(c));
        }
        table.row(&cells);
    }

    let mut out = String::from(
        "Fig 9 — mean normalized latency (s/token): static (dashed) vs continuous (solid)\n",
    );
    out.push_str(&table.finish());
    out.push_str(
        "\nshape target: continuous batching's latency rises slower and later than\n\
         static's at every batch cap; 'inf' continuous is the lower envelope.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn continuous_beats_static_at_load() {
        let opts = ExpOpts::quick();
        let n = 200;
        let qps = 8.0;
        let s = run_tokensim(&cfg(
            n,
            qps,
            PolicySpec::new("static")
                .with("batch_size", 8u32)
                .with("max_linger", 2.0),
            &opts.compute,
        ))
        .unwrap();
        let c = run_tokensim(&cfg(
            n,
            qps,
            PolicySpec::new("continuous")
                .with("max_batched_tokens", 8192u32)
                .with("max_batch_size", 8u32),
            &opts.compute,
        ))
        .unwrap();
        assert!(
            c.metrics().mean_normalized_latency() < s.metrics().mean_normalized_latency(),
            "continuous {} !< static {}",
            c.metrics().mean_normalized_latency(),
            s.metrics().mean_normalized_latency()
        );
    }

    #[test]
    fn larger_cap_helps_continuous() {
        let opts = ExpOpts::quick();
        let c8 = run_tokensim(&cfg(
            200,
            10.0,
            PolicySpec::new("continuous")
                .with("max_batched_tokens", 8192u32)
                .with("max_batch_size", 4u32),
            &opts.compute,
        ))
        .unwrap();
        let cinf = run_tokensim(&cfg(
            200,
            10.0,
            PolicySpec::new("continuous")
                .with("max_batched_tokens", 8192u32)
                .with("max_batch_size", Option::<u32>::None),
            &opts.compute,
        ))
        .unwrap();
        assert!(
            cinf.metrics().mean_normalized_latency()
                <= c8.metrics().mean_normalized_latency() * 1.05
        );
    }
}
