//! `tokensim exp hardware` — the cost-efficiency exploration the
//! pluggable compute registry enables: hardware catalog × compute
//! models × prefill/decode-disaggregation splits, reporting
//! price-normalized max-SLO throughput and TTFT/TBT SLO attainment at
//! the found operating point — the paper's Fig 12/15 frontier loop as
//! one command.
//!
//! Every cell runs its own SLO-throughput search through the parallel
//! sweep runner. The compute-model axis is what the fourth registry
//! adds over fig12: the same cluster sweep is repeated under the
//! primary model (`--cost-model`, default table/analytic), the
//! `roofline` napkin bound, and — in full mode — the `vidur_like`
//! learned baseline, so disagreements between simulators are visible in
//! one table. (`llmservingsim_like` is excluded: its tile-walking is
//! structurally too slow for a sweep and it truncates prompts.)

use anyhow::Result;

use crate::compute::ComputeSpec;
use crate::config::SimulationConfig;
use crate::hardware::HardwareSpec;
use crate::metrics::SloSpec;
use crate::model::ModelSpec;
use crate::workload::WorkloadSpec;

use super::common::*;

fn cfg(
    n_prefill: u32,
    decode_hw: &HardwareSpec,
    n_decode: u32,
    n_req: usize,
    qps: f64,
    compute: &ComputeSpec,
) -> SimulationConfig {
    let mut cfg = SimulationConfig::disaggregated(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        n_prefill,
        decode_hw.clone(),
        n_decode,
        WorkloadSpec::mean_lengths(n_req, qps, 128, 128),
    );
    cfg.compute = compute.clone();
    cfg
}

/// Fraction of requests meeting the TTFT bound and the TBT bound
/// separately (the combined attainment is what the search optimizes).
fn split_attainment(report: &crate::cluster::SimulationReport, slo: &SloSpec) -> (f64, f64) {
    if report.records.is_empty() {
        return (0.0, 0.0);
    }
    let n = report.records.len() as f64;
    let ttft_ok = report
        .records
        .iter()
        .filter(|r| slo.ttft.map(|b| r.ttft() <= b).unwrap_or(true))
        .count() as f64;
    let tbt_ok = report
        .records
        .iter()
        .filter(|r| slo.mtpot.map(|b| r.max_token_gap <= b).unwrap_or(true))
        .count() as f64;
    (ttft_ok / n, tbt_ok / n)
}

struct Cell {
    model_label: String,
    config_label: String,
    price: f64,
    qps: f64,
    goodput: f64,
    ttft_att: f64,
    tbt_att: f64,
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    let n_req = opts.size(1500, 100);
    let a100 = HardwareSpec::a100_80g();

    // hardware catalog: the decode-side substitutions of Fig 12, plus
    // a deliberately starved V100 (1/50th memory bandwidth) whose
    // decode floor sits above the paper-default TBT SLO — the cell the
    // static analyzer proves infeasible and prunes before simulating
    let catalog: &[(&str, HardwareSpec)] = &[
        ("A", HardwareSpec::a100_80g()),
        ("G", HardwareSpec::gddr6_aim()),
        ("V", HardwareSpec::v100_32g()),
        ("AL", HardwareSpec::a100_quarter_flops()),
        ("C", HardwareSpec::v100_32g().scale_bandwidth(0.02)),
    ];
    let splits: &[u32] = if opts.quick { &[1] } else { &[1, 2] };

    // compute-model axis: the configured primary plus the registry's
    // cheap and learned alternates (skipping duplicates of the primary)
    let mut models: Vec<ComputeSpec> = vec![opts.compute.clone()];
    let mut alternates = vec![ComputeSpec::new("roofline")];
    if !opts.quick {
        alternates.push(ComputeSpec::new("vidur_like"));
    }
    for alt in alternates {
        if !alt.name.eq_ignore_ascii_case(&models[0].name) {
            models.push(alt);
        }
    }

    // the full cross product; every cell runs its own SLO search
    let jobs: Vec<(ComputeSpec, String, HardwareSpec, u32, u32, f64)> = {
        let mut v = Vec::new();
        for compute in &models {
            for &np in splits {
                let nd = 8 - np;
                for (label, hw) in catalog {
                    let price = np as f64 * a100.price + nd as f64 * hw.price;
                    v.push((
                        compute.clone(),
                        format!("{label}{nd} (P{np})"),
                        hw.clone(),
                        np,
                        nd,
                        price,
                    ));
                }
            }
        }
        v
    };

    let total_cells = jobs.len();
    let (jobs, pruned) = prune_jobs(
        opts.prune,
        jobs,
        |(compute, _, hw, np, nd, _)| cfg(*np, hw, *nd, n_req, 4.0, compute),
        |(compute, label, ..)| format!("{} {label}", compute.name),
    );

    let cells: Vec<Result<Cell>> = parallel_sweep(&jobs, |(compute, label, hw, np, nd, price)| {
        let build = |qps: f64| cfg(*np, hw, *nd, n_req, qps, compute);
        let (qps, goodput) = max_slo_throughput(&build, 0.9, 4.0)?;
        let report = run_tokensim(&build(qps))?;
        let (ttft_att, tbt_att) = split_attainment(&report, &report.slo);
        Ok(Cell {
            model_label: compute.name.clone(),
            config_label: label.clone(),
            price: *price,
            qps,
            goodput,
            ttft_att,
            tbt_att,
        })
    });
    let cells = cells.into_iter().collect::<Result<Vec<_>>>()?;

    let mut out = String::from(
        "Hardware exploration — decode-hardware catalog x compute models x PD splits\n\
         (8 slots; A=A100, G=GDDR6-AiM, V=V100, AL=A100 with 1/4 FLOPS, C=V100 with\n\
         1/50 bandwidth; price in A100 units; attainment measured at the found\n\
         max-SLO operating point; statically infeasible cells are pruned + logged)\n\n",
    );
    let mut table = Table::new(&[
        "model",
        "config",
        "price",
        "qps*",
        "max SLO thr",
        "thr/price",
        "ttft att",
        "tbt att",
    ]);
    for c in &cells {
        table.row(&[
            c.model_label.clone(),
            c.config_label.clone(),
            format!("{:.2}", c.price),
            f1(c.qps),
            f1(c.goodput),
            f3(c.goodput / c.price),
            pct(c.ttft_att),
            pct(c.tbt_att),
        ]);
    }
    out.push_str(&table.finish());
    out.push_str(&pruning_section(opts.prune, &pruned, total_cells));

    // the frontier: best price-normalized configuration per model
    out.push_str("\ncost-efficiency frontier (best thr/price per compute model):\n");
    for compute in &models {
        let best = cells
            .iter()
            .filter(|c| c.model_label == compute.name)
            .max_by(|a, b| {
                (a.goodput / a.price).total_cmp(&(b.goodput / b.price))
            });
        if let Some(c) = best {
            out.push_str(&format!(
                "  {:<18} {} at {:.3} req/s per price unit\n",
                c.model_label,
                c.config_label,
                c.goodput / c.price
            ));
        }
    }
    out.push_str(
        "\nshape targets: G6-AiM decode dominates the frontier (bandwidth-rich,\n\
         half price); the roofline bound tracks the primary model's ordering while\n\
         flattering absolute numbers (no per-op overheads); heterogeneous per-worker\n\
         compute overrides are exercised by configs/hetero_pd.yaml.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_report_covers_models_and_catalog() {
        let out = run(&ExpOpts::quick()).unwrap();
        for label in ["analytic", "roofline", "A7 (P1)", "G7 (P1)", "V7 (P1)", "AL7 (P1)"] {
            assert!(out.contains(label), "missing {label} in:\n{out}");
        }
        assert!(out.contains("frontier"), "{out}");
        // the starved-V100 cell is provably SLO-infeasible: the
        // analyzer must prune it (logged, not silent) for every
        // probeable compute model
        assert!(out.contains("static pruning: skipped"), "{out}");
        assert!(out.contains("C7 (P1)"), "{out}");
        assert!(out.contains("E050"), "{out}");
    }

    #[test]
    fn pruning_preserves_the_frontier_with_fewer_cells() {
        let mut on = ExpOpts::quick();
        on.prune = true;
        let mut off = on.clone();
        off.prune = false;
        let out_on = run(&on).unwrap();
        let out_off = run(&off).unwrap();
        let frontier = |s: &str| {
            s.lines()
                .skip_while(|l| !l.contains("cost-efficiency frontier"))
                .take_while(|l| !l.is_empty())
                .collect::<Vec<_>>()
                .join("\n")
        };
        assert_eq!(
            frontier(&out_on),
            frontier(&out_off),
            "pruning must not change the frontier"
        );
        let evaluated = |s: &str| s.matches("(P1)").count();
        assert!(
            evaluated(&out_off) > 0
                && out_on.contains("static pruning: skipped")
                && !out_on.contains("skipped 0 of"),
            "pruned run must skip at least one cell:\n{out_on}"
        );
        assert!(out_off.contains("static pruning: disabled"), "{out_off}");
    }

    #[test]
    fn price_normalization_favors_aim_over_all_a100() {
        // the Fig 12 finding, reproduced through the sweep machinery:
        // per price unit, G6-AiM decode beats the all-A100 node
        let compute = ExpOpts::quick().compute;
        let search = |hw: HardwareSpec, price: f64| {
            let build = |qps: f64| cfg(1, &hw, 7, 100, qps, &compute);
            let (_, goodput) = max_slo_throughput(&build, 0.9, 4.0).unwrap();
            goodput / price
        };
        let a = search(HardwareSpec::a100_80g(), 8.0);
        let g = search(HardwareSpec::gddr6_aim(), 1.0 + 7.0 * 0.5);
        assert!(g > a, "G6-AiM must win per price unit: {g} vs {a}");
    }
}
