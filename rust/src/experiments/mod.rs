//! Experiment harnesses: one per table/figure of the paper's evaluation
//! (DESIGN.md's per-experiment index). Each harness regenerates the
//! rows/series its figure reports and prints them; `tokensim exp <id>`
//! is the CLI entry point.
//!
//! Absolute numbers come from this repo's oracle substrate rather than
//! the authors' A100 testbed (DESIGN.md §Substitutions); the *shape* —
//! who wins, by what factor, where crossovers fall — is the
//! reproduction target recorded in EXPERIMENTS.md.

mod common;
mod exp_analyze;
mod exp_hardware;
mod exp_memory;
mod exp_network;
mod exp_scale;
mod exp_workloads;
mod fig04_validation;
mod fig05_cdf;
mod fig06_simspeed;
mod fig07_disagg_validation;
mod fig08_batching_diagram;
mod fig09_continuous_batching;
mod fig10_mem_ratio;
mod fig11_pd_ratio;
mod fig12_decode_hardware;
mod fig13_memory_footprint;
mod fig14_memory_cache;
mod fig15_prefill_hardware;
mod policy_comparison;
mod table2_accuracy;

pub use common::{parallel_sweep, ExpOpts};

use anyhow::{bail, Result};

/// All experiment ids: the paper's figures in paper order, then the
/// repo's own studies ("policies" compares scheduler plugins, "memory"
/// compares memory managers x preemption policies, "workloads"
/// compares workload generators and per-tenant service quality,
/// "hardware" sweeps the hardware catalog x compute models x PD splits
/// for the price-normalized frontier, "scale" benchmarks the event
/// engine at 10k–1M requests with decode fast-forwarding off/on,
/// "network" sweeps communication topologies x PD splits x replica
/// counts for the contention-aware frontier, "analyze" checks the
/// static capacity analyzer's closed-form throughput bound against
/// simulated throughput across an offered-load grid).
pub const ALL: &[&str] = &[
    "fig4", "fig5", "table2", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13",
    "fig14", "fig15", "policies", "memory", "workloads", "hardware", "scale", "network", "analyze",
];

/// Run one experiment by id, returning its printed report.
pub fn run(id: &str, opts: &ExpOpts) -> Result<String> {
    let out = match id {
        "fig4" => fig04_validation::run(opts),
        "fig5" => fig05_cdf::run(opts),
        "table2" => table2_accuracy::run(opts),
        "fig6" => fig06_simspeed::run(opts),
        "fig7" => fig07_disagg_validation::run(opts),
        "fig8" => fig08_batching_diagram::run(opts),
        "fig9" => fig09_continuous_batching::run(opts),
        "fig10" => fig10_mem_ratio::run(opts),
        "fig11" => fig11_pd_ratio::run(opts),
        "fig12" => fig12_decode_hardware::run(opts),
        "fig13" => fig13_memory_footprint::run(opts),
        "fig14" => fig14_memory_cache::run(opts),
        "fig15" => fig15_prefill_hardware::run(opts),
        "policies" => policy_comparison::run(opts),
        "memory" => exp_memory::run(opts),
        "workloads" => exp_workloads::run(opts),
        "hardware" => exp_hardware::run(opts),
        "scale" => exp_scale::run(opts),
        "network" => exp_network::run(opts),
        "analyze" => exp_analyze::run(opts),
        other => bail!("unknown experiment '{other}' (known: {})", ALL.join(", ")),
    }?;
    if let Some(dir) = &opts.out_dir {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{id}.txt")), &out)?;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_rejected() {
        assert!(run("fig99", &ExpOpts::quick()).is_err());
    }

    #[test]
    fn all_ids_resolve() {
        // only check dispatch wiring (cheap smoke experiments run in
        // the integration suite)
        for id in ALL {
            assert!(ALL.contains(id));
        }
    }
}
