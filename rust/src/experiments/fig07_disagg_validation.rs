//! Fig 7: disaggregated prefill/decode validation against DistServe.
//!
//! Two A100s (1 prefill + 1 decode), 64-token inputs and outputs at
//! QPS 8, request counts 1000–10000; compare total runtime of the
//! DistServe stand-in (oracle with SwiftTransformer-style runtime
//! factor and measured-bandwidth KV link) against TokenSim configured
//! with the measured bandwidth.

use anyhow::Result;

use crate::config::SimulationConfig;
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;
use crate::oracle::OracleParams;
use crate::workload::WorkloadSpec;

use super::common::*;

fn cfg(n: usize, cost: &crate::compute::ComputeSpec) -> SimulationConfig {
    let mut cfg = SimulationConfig::disaggregated(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        1,
        HardwareSpec::a100_80g(),
        1,
        WorkloadSpec::fixed(n, 8.0, 64, 64),
    );
    // "we measure the actual communication bandwidth and use this data"
    cfg.cluster.scheduler.interconnect = crate::hardware::LinkSpec::nvlink()
        .with_measured_bandwidth(430e9);
    cfg.compute = cost.clone();
    cfg
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    let counts: &[usize] = if opts.quick {
        &[200, 500]
    } else {
        &[1000, 2000, 4000, 6000, 8000, 10000]
    };
    let params = OracleParams::distserve();

    let mut table = Table::new(&["requests", "DistServe(s)", "TokenSim(s)", "err%"]);
    let mut pairs = Vec::new();
    for &n in counts {
        let base = cfg(n, &opts.compute);
        let real = run_oracle(&base, &params, 0xD157)?;
        let sim = run_tokensim(&calibrated_config(&base, &params))?;
        let (tr, ts) = (total_runtime(&real), total_runtime(&sim));
        pairs.push((ts, tr));
        table.row(&[
            n.to_string(),
            f3(tr),
            f3(ts),
            format!("{:.2}", 100.0 * ((ts - tr) / tr).abs()),
        ]);
    }
    let mut out = String::from(
        "Fig 7 — disaggregated prefill/decode runtime vs DistServe (2xA100, 64/64 tokens, QPS 8)\n",
    );
    out.push_str(&table.finish());
    out.push_str(&format!(
        "\ngeomean runtime error: {} (paper: single-digit %, larger at low request counts\n\
         where the SwiftTransformer runtime difference dominates)\n",
        pct(geomean_rel_err(&pairs))
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_disagg_validation_tracks() {
        let out = run(&ExpOpts::quick()).unwrap();
        for line in out.lines().filter(|l| {
            l.trim_start()
                .chars()
                .next()
                .map(|c| c.is_ascii_digit())
                .unwrap_or(false)
        }) {
            let err: f64 = line.split_whitespace().last().unwrap().parse().unwrap();
            assert!(err < 20.0, "disagg error {err}% too large: {line}");
        }
    }
}
