//! Fig 14: P99 request latency with/without the cross-round KV memory
//! cache (CachedAttention/MemServe style), across input/output length
//! mixes and request rates.
//!
//! Chatbot workload: half the conversations single-round, half 2–7
//! rounds; pool retrieval at 800 ns/block.

use anyhow::Result;

use crate::cluster::Simulation;
use crate::config::{PoolCacheConfig, SimulationConfig};
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;
use crate::workload::{ConversationSpec, WorkloadSpec};

use super::common::*;

fn cfg(cache: bool, cost: &crate::compute::ComputeSpec) -> SimulationConfig {
    let mut cfg = SimulationConfig::single_worker(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        // workload field unused for conversation runs; keep a stub
        WorkloadSpec::fixed(1, 1.0, 8, 8),
    );
    if cache {
        cfg.pool_cache = Some(PoolCacheConfig::with_capacity(2_000_000));
    }
    cfg.compute = cost.clone();
    cfg
}

pub(super) fn p99_latency(
    input_mean: u32,
    output_mean: u32,
    n_conv: usize,
    qps: f64,
    cache: bool,
    cost: &crate::compute::ComputeSpec,
) -> Result<f64> {
    let convs = ConversationSpec::chatbot(n_conv, qps, input_mean, output_mean).generate();
    let report = Simulation::from_conversations(&cfg(cache, cost), &convs)
        .expect("experiment config must build")
        .run()?;
    Ok(report.latency_percentile(0.99))
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    let n_conv = opts.size(3000, 150);
    let rates: &[f64] = if opts.quick {
        &[4.0, 10.0]
    } else {
        &[2.0, 4.0, 8.0, 12.0, 16.0, 20.0]
    };
    let mixes: &[(u32, u32)] = if opts.quick {
        &[(128, 64)]
    } else {
        &[(128, 32), (128, 64), (256, 64), (256, 32)]
    };

    let mut headers = vec!["qps".to_string()];
    for (i, o) in mixes {
        headers.push(format!("{i}-{o} off"));
        headers.push(format!("{i}-{o} on"));
    }
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr);

    for &qps in rates {
        let mut cells = vec![f1(qps)];
        for &(input, output) in mixes {
            cells.push(f3(p99_latency(input, output, n_conv, qps, false, &opts.compute)?));
            cells.push(f3(p99_latency(input, output, n_conv, qps, true, &opts.compute)?));
        }
        table.row(&cells);
    }

    let mut out = String::from(
        "Fig 14 — P99 latency, memory cache off/on ('i-o' = input/output lengths)\n",
    );
    out.push_str(&table.finish());
    out.push_str(
        "\nshape target: the cache lowers P99 at every point, with the largest relative\n\
         gain around 64-token outputs at high request rates (~2x rate at equal P99);\n\
         gains shrink for very short outputs (<=32).\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_reduces_p99_under_load() {
        let cost = ExpOpts::quick().compute;
        let off = p99_latency(128, 64, 200, 10.0, false, &cost).unwrap();
        let on = p99_latency(128, 64, 200, 10.0, true, &cost).unwrap();
        assert!(on < off, "cache must reduce P99: on={on} off={off}");
    }
}
