//! Table II: percentage difference in total latency between the real
//! system and each simulator, for 10-output-token requests at request
//! counts 100–500.
//!
//! Rows: Local (a second measurement of the real system — run-to-run
//! variance), Vidur-like, TokenSim, LLMServingSim-like. Prompts are
//! kept short (10 tokens) so the LLMServingSim-like baseline's
//! short-request limitation does not distort its row, mirroring the
//! paper's setup.

use anyhow::Result;

use crate::baselines::{LlmServingSimLike, VidurLike};
use crate::cluster::Simulation;
use crate::compute::ComputeModel;
use crate::config::SimulationConfig;
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;
use crate::oracle::OracleParams;
use crate::workload::WorkloadSpec;

use super::common::*;

fn cfg(n: usize, qps: f64, cost: &crate::compute::ComputeSpec) -> SimulationConfig {
    let mut cfg = SimulationConfig::single_worker(
        ModelSpec::llama2_7b(),
        HardwareSpec::a100_80g(),
        WorkloadSpec::fixed(n, qps, 10, 10),
    );
    cfg.compute = cost.clone();
    cfg
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    // the paper finds the 40-QPS operating point first; short requests
    // on an A100 sustain well beyond that, so 40 is the paper's value
    let qps = 40.0;
    let counts: &[usize] = if opts.quick {
        &[100, 200]
    } else {
        &[100, 200, 300, 400, 500]
    };
    let params = OracleParams::vllm();

    let mut table = Table::new(&["Request num", "Local", "Vidur", "TokenSim", "LLMServingSim"]);
    let mut out = String::from(
        "Table II — % latency difference vs the reference system, 10 output tokens\n",
    );

    for &n in counts {
        let base = cfg(n, qps, &opts.compute);
        // ground truth ("real hardware"): oracle, seed A
        let real = run_oracle(&base, &params, 0x7AB1E_A)?;
        let t_real = total_runtime(&real);

        // Local: the real system measured again (different noise seed)
        let local = run_oracle(&base, &params, 0x7AB1E_B)?;
        let t_local = total_runtime(&local);

        // TokenSim (calibrated, as in Figs 4/5)
        let sim = run_tokensim(&calibrated_config(&base, &params))?;
        let t_tokensim = total_runtime(&sim);

        // Vidur-like: learned regression over oracle profiles
        let vidur_factory = |model: &ModelSpec, hw: &HardwareSpec, _w: usize| {
            Box::new(VidurLike::train(model, hw, 1200, 42)) as Box<dyn ComputeModel>
        };
        let vidur = Simulation::with_cost_factory(&base, &vidur_factory)
            .expect("experiment config must build")
            .run()?;
        let t_vidur = total_runtime(&vidur);

        // LLMServingSim-like: co-simulation (short prompts, so exact)
        let co_factory = |model: &ModelSpec, hw: &HardwareSpec, _w: usize| {
            Box::new(LlmServingSimLike::new(model, hw)) as Box<dyn ComputeModel>
        };
        let co = Simulation::with_cost_factory(&base, &co_factory)
            .expect("experiment config must build")
            .run()?;
        let t_co = total_runtime(&co);

        let diff = |t: f64| format!("{:.3}", 100.0 * ((t - t_real) / t_real).abs());
        table.row(&[
            n.to_string(),
            diff(t_local),
            diff(t_vidur),
            diff(t_tokensim),
            diff(t_co),
        ]);
    }
    out.push_str(&table.finish());
    out.push_str(
        "\npaper (500 reqs): Local 12.98, Vidur 12.12, TokenSim 12.59, LLMServingSim 12.56\n\
         shape target: all simulators land within the run-to-run (Local) variance band.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_table_has_all_rows() {
        let out = run(&ExpOpts::quick()).unwrap();
        assert!(out.contains("100"));
        assert!(out.contains("TokenSim"));
        // every simulator's error must be bounded (within 30% — the
        // paper's worst case is ~13%)
        for line in out.lines().skip(3).take(2) {
            for cell in line.split_whitespace().skip(1) {
                let v: f64 = cell.parse().unwrap();
                assert!(v < 30.0, "error {v}% out of band: {line}");
            }
        }
    }
}
