//! Fig 10: SLO-constrained throughput when capping the GPU-memory
//! ratio available to *new* requests (reserving headroom for running
//! ones reduces preemptions → better tail latency).
//!
//! TTFT SLO 15 s, mTPOT SLO 0.3 s; (a) decode-only SLO, (b) both SLOs.

use anyhow::Result;

use crate::config::SimulationConfig;
use crate::hardware::HardwareSpec;
use crate::memory::MemorySpec;
use crate::metrics::SloSpec;
use crate::model::ModelSpec;
use crate::workload::WorkloadSpec;

use super::common::*;

fn cfg(
    n: usize,
    qps: f64,
    max_mem_ratio: f64,
    slo: SloSpec,
    cost: &crate::compute::ComputeSpec,
) -> SimulationConfig {
    let mut cfg = SimulationConfig::single_worker(
        ModelSpec::llama2_7b(),
        {
            // smaller KV pool accentuates preemption pressure (the
            // paper's ShareGPT mix has long outputs); use a 40 GB card
            let mut hw = HardwareSpec::a100_80g();
            hw.mem_cap = 40e9;
            hw
        },
        WorkloadSpec::sharegpt(n, qps),
    );
    cfg.cluster.workers[0].memory = MemorySpec::default().with("max_mem_ratio", max_mem_ratio);
    cfg.slo = slo;
    cfg.compute = cost.clone();
    cfg
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    let n = opts.size(12_000, 400); // scaled from the paper's 50k (see fig9 note)
    let rates: &[f64] = if opts.quick {
        &[4.0, 8.0]
    } else {
        &[2.0, 4.0, 6.0, 8.0, 10.0, 12.0, 14.0]
    };
    let ratios: &[f64] = if opts.quick {
        &[0.5, 1.0]
    } else {
        &[0.2, 0.4, 0.6, 0.8, 0.9, 1.0]
    };

    let mut out = String::from("Fig 10 — throughput under max-mem-ratio caps\n");
    for (title, slo) in [
        ("(a) Decode SLO only (mTPOT 0.3 s)", SloSpec::decode_only()),
        ("(b) Prompt & Decode SLO (TTFT 15 s + mTPOT 0.3 s)", SloSpec::paper_default()),
    ] {
        let mut headers = vec!["qps".to_string()];
        headers.extend(ratios.iter().map(|r| format!("ratio-{r}")));
        let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(&hdr);
        // independent (qps x ratio) cells: sweep across cores
        let goodputs = sweep_grid(rates, ratios, |&qps, &ratio| {
            run_tokensim(&cfg(n, qps, ratio, slo, &opts.compute)).map(|r| r.slo_throughput())
        });
        for (&qps, row) in rates.iter().zip(goodputs) {
            let mut cells = vec![f1(qps)];
            for g in row {
                cells.push(f3(g?));
            }
            table.row(&cells);
        }
        out.push_str(&format!("\n{title}\n"));
        out.push_str(&table.finish());
    }
    out.push_str(
        "\nshape target: at high request rates an intermediate ratio (~0.8-0.9) beats\n\
         ratio 1.0 — reserving memory for running requests avoids preemption-driven\n\
         mTPOT violations, even though it admits fewer new requests.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capping_ratio_reduces_preemptions() {
        let opts = ExpOpts::quick();
        let full =
            run_tokensim(&cfg(250, 20.0, 1.0, SloSpec::paper_default(), &opts.compute)).unwrap();
        let capped =
            run_tokensim(&cfg(250, 20.0, 0.7, SloSpec::paper_default(), &opts.compute)).unwrap();
        assert!(
            capped.metrics().total_preemptions() <= full.metrics().total_preemptions(),
            "cap must not increase preemptions: {} vs {}",
            capped.metrics().total_preemptions(),
            full.metrics().total_preemptions()
        );
    }
}
