//! Fig 8: static vs continuous batching iteration diagram.
//!
//! Reproduces the paper's illustration by *running* both schedulers on
//! the same small request set (batch capacity 4/5) and rendering each
//! request slot's occupancy per iteration — yellow (P) prefill, blue
//! (D) decode, END markers, and white bubbles.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::compute::{AnalyticCost, ComputeModel};
use crate::hardware::HardwareSpec;
use crate::memory::{PagedBlockManager, PreemptionPolicy};
use crate::model::ModelSpec;
use crate::request::{Phase, Request};
use crate::scheduler::{LocalSchedCtx, LocalScheduler};

use super::common::ExpOpts;

/// Drive a single worker's local scheduler directly, recording slot
/// occupancy per iteration. Arrivals: 4 requests at t=0, 4 more during
/// the run (like the figure's R5..R8).
fn trace(
    policy: &mut dyn LocalScheduler,
    iterations: usize,
) -> Vec<BTreeMap<usize, &'static str>> {
    let model = ModelSpec::tiny_test();
    let hw = HardwareSpec::a100_80g();
    let mut cost = AnalyticCost::new(&model, &hw);
    // outputs chosen to match the figure's finish pattern
    let outs = [6u32, 4, 5, 8, 5, 5, 4, 3, 2, 2];
    let mut requests: Vec<Request> = outs
        .iter()
        .enumerate()
        .map(|(i, &o)| Request::new(i, i, 0, 8, o, 0.0))
        .collect();
    let mut waiting: std::collections::VecDeque<usize> = (0..4).collect();
    let mut pending: std::collections::VecDeque<usize> = (4..10).collect();
    let mut running = Vec::new();
    let mut mem = PagedBlockManager::with_blocks(10_000, 16, 1024);

    let mut frames = Vec::new();
    for iter in 0..iterations {
        // one new arrival every other iteration once the run started
        if iter >= 2 && iter % 1 == 0 {
            if let Some(r) = pending.pop_front() {
                waiting.push_back(r);
            }
        }
        let mut ctx = LocalSchedCtx {
            requests: &mut requests,
            waiting: &mut waiting,
            running: &mut running,
            mem: &mut mem,
            now: iter as f64,
            draining: false,
            oldest_wait: Some(iter as f64),
            preemption: PreemptionPolicy::Recompute,
        };
        let plan = policy.form_batch(&mut ctx);
        let mut frame = BTreeMap::new();
        if plan.is_empty() {
            frames.push(frame);
            continue;
        }
        let _ = cost.iter_time(&plan.batch);
        let mut finished = Vec::new();
        for (slot, &rid) in plan.members.iter().enumerate() {
            let new = plan.batch.new[slot];
            let r = &mut requests[rid];
            let label = match r.phase {
                Phase::Prefill => "P",
                _ => "D",
            };
            match r.phase {
                Phase::Prefill => {
                    r.prompt_done += new;
                    r.ctx_in_cache += new;
                    if r.prefill_done() {
                        r.generated += 1;
                        r.phase = Phase::Decode;
                    }
                }
                Phase::Decode => {
                    r.generated += 1;
                    r.ctx_in_cache += 1;
                }
                _ => {}
            }
            let label = if requests[rid].done() { "E" } else { label };
            frame.insert(rid, label);
            if requests[rid].done() {
                finished.push(rid);
            }
        }
        for rid in finished {
            requests[rid].phase = Phase::Finished;
            running.retain(|&x| x != rid);
            mem.release(rid);
        }
        frames.push(frame);
    }
    frames
}

fn render(title: &str, frames: &[BTreeMap<usize, &'static str>]) -> String {
    let mut out = format!("{title}\n");
    // rows = request ids that ever appear
    let mut ids: Vec<usize> = frames
        .iter()
        .flat_map(|f| f.keys().copied())
        .collect::<std::collections::BTreeSet<_>>()
        .into_iter()
        .collect();
    ids.sort_unstable();
    out.push_str("        ");
    for i in 0..frames.len() {
        out.push_str(&format!("it{:<3}", i + 1));
    }
    out.push('\n');
    for id in ids {
        out.push_str(&format!("  R{:<3}  ", id + 1));
        for f in frames {
            let c = f.get(&id).copied().unwrap_or(".");
            out.push_str(&format!("{c:<5}"));
        }
        out.push('\n');
    }
    out
}

pub fn run(_opts: &ExpOpts) -> Result<String> {
    let iterations = 14;
    let static_frames = trace(
        &mut crate::scheduler::StaticBatching {
            batch_size: 4,
            max_linger: 0.0,
        },
        iterations,
    );
    let cont_frames = trace(
        &mut crate::scheduler::ContinuousBatching {
            max_batched_tokens: 1 << 20,
            max_batch_size: Some(5),
            mixed_batching: true,
        },
        iterations,
    );

    let mut out = String::from(
        "Fig 8 — static vs continuous batching (P=prefill, D=decode, E=finish, .=bubble)\n\n",
    );
    out.push_str(&render("Static batching:", &static_frames));
    out.push('\n');
    out.push_str(&render("Continuous batching:", &cont_frames));
    out.push_str(
        "\nshape target: static leaves '.' bubbles after early finishers until the whole\n\
         batch drains; continuous refills slots immediately.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_has_bubbles_continuous_refills() {
        let out = run(&ExpOpts::quick()).unwrap();
        let static_part: String = out
            .lines()
            .skip_while(|l| !l.starts_with("Static"))
            .take_while(|l| !l.starts_with("Continuous"))
            .collect::<Vec<_>>()
            .join("\n");
        let cont_part: String = out
            .lines()
            .skip_while(|l| !l.starts_with("Continuous"))
            .collect::<Vec<_>>()
            .join("\n");
        // static: later requests only start after the batch drains
        assert!(static_part.contains('.'), "static must show bubbles");
        // continuous keeps slots productive: more P/D/E cells overall
        let work = |s: &str| {
            s.matches('P').count() + s.matches('D').count() + s.matches('E').count()
        };
        assert!(
            work(&cont_part) > work(&static_part),
            "continuous {} !> static {}",
            work(&cont_part),
            work(&static_part)
        );
    }
}
