//! Fig 11: best prefill/decode device ratio on an 8×A100 node across
//! average input/output length combinations, for LLaMA2-7B and OPT-13B.
//!
//! Cell value = the P/D split maximizing SLO-constrained throughput,
//! annotated with that throughput.

use anyhow::Result;

use crate::config::SimulationConfig;
use crate::hardware::HardwareSpec;
use crate::model::ModelSpec;
use crate::workload::WorkloadSpec;

use super::common::*;

pub(super) fn disagg_cfg(
    model: &ModelSpec,
    n_prefill: u32,
    n_decode: u32,
    n_req: usize,
    qps: f64,
    input_mean: u32,
    output_mean: u32,
    cost: &crate::compute::ComputeSpec,
) -> SimulationConfig {
    let mut cfg = SimulationConfig::disaggregated(
        model.clone(),
        HardwareSpec::a100_80g(),
        n_prefill,
        HardwareSpec::a100_80g(),
        n_decode,
        WorkloadSpec::mean_lengths(n_req, qps, input_mean, output_mean),
    );
    cfg.compute = cost.clone();
    cfg
}

/// Find the best split and its max SLO throughput for one workload cell.
pub(super) fn best_split(
    model: &ModelSpec,
    n_req: usize,
    input_mean: u32,
    output_mean: u32,
    splits: &[(u32, u32)],
    cost: &crate::compute::ComputeSpec,
) -> Result<((u32, u32), f64)> {
    let mut best = ((0, 0), -1.0f64);
    for &(p, d) in splits {
        let build = |qps: f64| disagg_cfg(model, p, d, n_req, qps, input_mean, output_mean, cost);
        let (_, goodput) = max_slo_throughput(&build, 0.9, 4.0)?;
        if goodput > best.1 {
            best = ((p, d), goodput);
        }
    }
    Ok(best)
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    let n_req = opts.size(1500, 120);
    let inputs: &[u32] = if opts.quick { &[64, 512] } else { &[64, 128, 512, 1024] };
    let outputs: &[u32] = if opts.quick { &[32, 256] } else { &[32, 64, 128, 512] };
    let splits: &[(u32, u32)] = if opts.quick {
        &[(1, 7), (2, 6), (4, 4)]
    } else {
        &[(1, 7), (2, 6), (3, 5), (4, 4), (5, 3), (6, 2)]
    };

    let mut out = String::from(
        "Fig 11 — best P/D split (8xA100), cell = split @ max SLO throughput (req/s)\n",
    );
    for model in [ModelSpec::llama2_7b(), ModelSpec::opt_13b()] {
        out.push_str(&format!("\n{}:\n", model.name));
        let mut headers = vec!["in\\out".to_string()];
        headers.extend(outputs.iter().map(|o| o.to_string()));
        let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(&hdr);
        // every (input, output) cell runs its own SLO-throughput search
        // over all splits: sweep the cells across cores
        let cells = sweep_grid(inputs, outputs, |&input, &output| {
            best_split(&model, n_req, input, output, splits, &opts.compute)
        });
        for (&input, results) in inputs.iter().zip(cells) {
            let mut row = vec![input.to_string()];
            for result in results {
                let ((p, d), thr) = result?;
                row.push(format!("P{p}D{d}@{thr:.1}"));
            }
            table.row(&row);
        }
        out.push_str(&table.finish());
    }
    out.push_str(
        "\nshape target: longer outputs shift the optimum toward fewer prefill devices\n\
         (more decode capacity); at long outputs short inputs free further prefill\n\
         devices for decoding.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn long_outputs_prefer_fewer_prefill_devices() {
        let cost = ExpOpts::quick().compute;
        let model = ModelSpec::llama2_7b();
        let splits = [(1u32, 7u32), (4, 4)];
        // decode-heavy workload: long outputs, short inputs
        let ((p_long, _), _) = best_split(&model, 100, 64, 256, &splits, &cost).unwrap();
        // prefill-heavy workload: long inputs, tiny outputs
        let ((p_short, _), _) = best_split(&model, 100, 1024, 8, &splits, &cost).unwrap();
        assert!(p_long <= p_short, "long outputs got {p_long} prefill, short got {p_short}");
    }
}
