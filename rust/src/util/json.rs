//! Minimal JSON parser/serializer (serde_json is unavailable offline).
//!
//! Supports the full JSON grammar except for exotic number forms; good
//! enough for `artifacts/manifest.json`, JSONL traces and experiment
//! result dumps. Not performance-critical.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- accessors -----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .with_context(|| format!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // ---- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                other => bail!("expected , or }} (found {other:?})"),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                other => bail!("expected , or ] (found {other:?})"),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .context("truncated \\u escape")?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().context("bad number")?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(
            v.get("b").unwrap().get("c").unwrap().as_str(),
            Some("x\ny")
        );
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(1.5).to_string(), "1.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn req_errors_on_missing() {
        let v = Json::parse(r#"{"x": 1}"#).unwrap();
        assert!(v.req("x").is_ok());
        assert!(v.req("y").is_err());
    }
}
