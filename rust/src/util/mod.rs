//! Small in-tree utilities replacing unavailable third-party crates
//! (this build environment is offline; see Cargo.toml).

pub mod json;

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

/// Relative-tolerance float comparison for tests.
pub fn close(a: f64, b: f64, rtol: f64) -> bool {
    if a == b {
        return true;
    }
    let scale = a.abs().max(b.abs()).max(1e-300);
    (a - b).abs() / scale <= rtol
}

/// Assert two floats agree to a relative tolerance.
#[macro_export]
macro_rules! assert_close {
    ($a:expr, $b:expr, $rtol:expr) => {{
        let (a, b) = ($a as f64, $b as f64);
        assert!(
            $crate::util::close(a, b, $rtol),
            "assert_close failed: {a} vs {b} (rtol {})",
            $rtol
        );
    }};
    ($a:expr, $b:expr) => {
        $crate::assert_close!($a, $b, 1e-9)
    };
}

/// Peak resident-set size of this process in bytes (`VmHWM` from
/// `/proc/self/status`). `None` off Linux or when procfs is
/// unavailable — callers report it as an estimate, never depend on it.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    // format: "VmHWM:    123456 kB"
    let kb: u64 = line
        .split_whitespace()
        .nth(1)
        .and_then(|v| v.parse().ok())?;
    Some(kb * 1024)
}

static TEMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// A unique scratch directory removed on drop (tempfile replacement).
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new() -> std::io::Result<Self> {
        let n = TEMP_COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "tokensim-{}-{}-{n}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&path)?;
        Ok(Self { path })
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn close_handles_zero_and_scale() {
        assert!(close(0.0, 0.0, 1e-9));
        assert!(close(1e12, 1e12 * (1.0 + 1e-10), 1e-9));
        assert!(!close(1.0, 1.1, 1e-3));
    }

    #[test]
    fn peak_rss_is_plausible_on_linux() {
        if let Some(bytes) = peak_rss_bytes() {
            // a running test binary occupies at least a few pages and
            // (sanity) fewer than 1 TiB
            assert!(bytes > 4096, "{bytes}");
            assert!(bytes < (1 << 40), "{bytes}");
        }
    }

    #[test]
    fn tempdir_lifecycle() {
        let p;
        {
            let d = TempDir::new().unwrap();
            p = d.path().to_path_buf();
            std::fs::write(p.join("x"), "y").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists(), "removed on drop");
    }
}
