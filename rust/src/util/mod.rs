//! Small in-tree utilities replacing unavailable third-party crates
//! (this build environment is offline; see Cargo.toml).

pub mod json;

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

/// Relative-tolerance float comparison for tests.
pub fn close(a: f64, b: f64, rtol: f64) -> bool {
    if a == b {
        return true;
    }
    let scale = a.abs().max(b.abs()).max(1e-300);
    (a - b).abs() / scale <= rtol
}

/// Assert two floats agree to a relative tolerance.
#[macro_export]
macro_rules! assert_close {
    ($a:expr, $b:expr, $rtol:expr) => {{
        let (a, b) = ($a as f64, $b as f64);
        assert!(
            $crate::util::close(a, b, $rtol),
            "assert_close failed: {a} vs {b} (rtol {})",
            $rtol
        );
    }};
    ($a:expr, $b:expr) => {
        $crate::assert_close!($a, $b, 1e-9)
    };
}

/// Peak resident-set size of this process in bytes. Primary source is
/// `VmHWM` from `/proc/self/status`; where procfs is unavailable (e.g.
/// macOS) falls back to `getrusage(RUSAGE_SELF)`. `None` only when both
/// fail — callers report it as an estimate, never depend on it.
pub fn peak_rss_bytes() -> Option<u64> {
    peak_rss_procfs().or_else(peak_rss_getrusage)
}

fn peak_rss_procfs() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    // format: "VmHWM:    123456 kB"
    let kb: u64 = line
        .split_whitespace()
        .nth(1)
        .and_then(|v| v.parse().ok())?;
    Some(kb * 1024)
}

/// `getrusage(RUSAGE_SELF).ru_maxrss` via a raw libc binding (the libc
/// crate is unavailable offline). The layout below matches `struct
/// rusage` on both Linux and macOS 64-bit: two `timeval`s followed by
/// 14 long integers, of which `ru_maxrss` is the first.
pub fn peak_rss_getrusage() -> Option<u64> {
    #[repr(C)]
    struct Timeval {
        tv_sec: i64,
        tv_usec: i64,
    }
    #[repr(C)]
    struct Rusage {
        ru_utime: Timeval,
        ru_stime: Timeval,
        ru_maxrss: i64,
        _pad: [i64; 13],
    }
    extern "C" {
        fn getrusage(who: i32, usage: *mut Rusage) -> i32;
    }
    const RUSAGE_SELF: i32 = 0;
    let mut usage = Rusage {
        ru_utime: Timeval { tv_sec: 0, tv_usec: 0 },
        ru_stime: Timeval { tv_sec: 0, tv_usec: 0 },
        ru_maxrss: 0,
        _pad: [0; 13],
    };
    // SAFETY: `usage` is a valid, writable struct of the platform's
    // rusage size (we over-reserve trailing longs via `_pad`).
    let rc = unsafe { getrusage(RUSAGE_SELF, &mut usage) };
    if rc != 0 || usage.ru_maxrss <= 0 {
        return None;
    }
    // Linux reports ru_maxrss in KiB, macOS in bytes.
    let scale = if cfg!(target_os = "macos") { 1 } else { 1024 };
    Some(usage.ru_maxrss as u64 * scale)
}

static TEMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// A unique scratch directory removed on drop (tempfile replacement).
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new() -> std::io::Result<Self> {
        let n = TEMP_COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "tokensim-{}-{}-{n}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&path)?;
        Ok(Self { path })
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn close_handles_zero_and_scale() {
        assert!(close(0.0, 0.0, 1e-9));
        assert!(close(1e12, 1e12 * (1.0 + 1e-10), 1e-9));
        assert!(!close(1.0, 1.1, 1e-3));
    }

    #[test]
    fn peak_rss_is_plausible_on_linux() {
        if let Some(bytes) = peak_rss_bytes() {
            // a running test binary occupies at least a few pages and
            // (sanity) fewer than 1 TiB
            assert!(bytes > 4096, "{bytes}");
            assert!(bytes < (1 << 40), "{bytes}");
        }
    }

    #[test]
    fn getrusage_fallback_agrees_with_procfs() {
        let rusage = peak_rss_getrusage();
        if cfg!(target_os = "linux") {
            // both sources must work on Linux and measure the same
            // process high-water mark — within 2× covers procfs/kernel
            // accounting differences (huge pages, sampling granularity)
            let proc_bytes = peak_rss_procfs().expect("procfs available on Linux");
            let ru_bytes = rusage.expect("getrusage available on Linux");
            assert!(ru_bytes > 4096, "{ru_bytes}");
            let (lo, hi) = (proc_bytes.min(ru_bytes), proc_bytes.max(ru_bytes));
            assert!(
                hi <= lo.saturating_mul(2),
                "procfs {proc_bytes} vs getrusage {ru_bytes} disagree by >2x"
            );
        } else if let Some(ru_bytes) = rusage {
            assert!(ru_bytes > 4096, "{ru_bytes}");
            assert!(ru_bytes < (1 << 40), "{ru_bytes}");
        }
    }

    #[test]
    fn tempdir_lifecycle() {
        let p;
        {
            let d = TempDir::new().unwrap();
            p = d.path().to_path_buf();
            std::fs::write(p.join("x"), "y").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists(), "removed on drop");
    }
}
