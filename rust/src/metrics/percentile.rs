//! Percentile / CDF extraction helpers.
//!
//! NaN handling (matching the PR 5 `Event::cmp` total-order fix): all
//! sorting here uses [`f64::total_cmp`], which places NaNs after every
//! finite value, so NaN inputs deterministically surface in the
//! highest quantiles instead of poisoning the sort. A NaN *quantile
//! argument* is treated as `q = 0` rather than relying on
//! `clamp(NaN)`'s NaN propagation and a NaN-as-usize cast.

/// Percentile with linear interpolation; `q` in `[0, 1]`.
/// Returns 0.0 for an empty iterator.
pub fn percentile(values: impl IntoIterator<Item = f64>, q: f64) -> f64 {
    let mut v: Vec<f64> = values.into_iter().collect();
    v.sort_by(|a, b| a.total_cmp(b));
    percentile_of_sorted(&v, q)
}

/// [`percentile`] over an already-sorted slice (ascending). The
/// single-sort building block for callers that extract several
/// quantiles from the same values — sorting once and indexing is what
/// keeps per-sweep-cell reporting off the O(n log n)-per-quantile path.
///
/// `q` outside `[0, 1]` is clamped; a NaN `q` reads as 0. A
/// single-element slice returns that element for every `q`.
pub fn percentile_of_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 1.0) };
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Several percentiles of the same values with a single sort. Returns
/// one entry per requested `q`, each identical to what
/// [`percentile`] would return for that `q` alone.
pub fn percentiles(values: impl IntoIterator<Item = f64>, qs: &[f64]) -> Vec<f64> {
    let mut v: Vec<f64> = values.into_iter().collect();
    v.sort_by(|a, b| a.total_cmp(b));
    qs.iter().map(|&q| percentile_of_sorted(&v, q)).collect()
}

/// Empirical CDF points: sorted `(value, fraction ≤ value)`. NaN
/// values order last (total order), so they occupy the top fractions
/// deterministically rather than scrambling the sort.
pub fn cdf_points(values: impl IntoIterator<Item = f64>) -> Vec<(f64, f64)> {
    let mut v: Vec<f64> = values.into_iter().collect();
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len() as f64;
    v.into_iter()
        .enumerate()
        .map(|(i, x)| (x, (i + 1) as f64 / n))
        .collect()
}

/// Five-number summary plus mean, reused by experiment reports.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
    pub min: f64,
}

impl Summary {
    pub fn of(values: &[f64]) -> Self {
        if values.is_empty() {
            return Self {
                mean: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
                min: 0.0,
            };
        }
        // one sorted copy serves every order statistic: the old path
        // cloned and sorted the same slice once per percentile call,
        // which sat on the per-sweep-cell reporting hot path
        let mut sorted = values.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        Self {
            mean: values.iter().sum::<f64>() / values.len() as f64,
            p50: percentile_of_sorted(&sorted, 0.50),
            p90: percentile_of_sorted(&sorted, 0.90),
            p99: percentile_of_sorted(&sorted, 0.99),
            max: sorted[sorted.len() - 1],
            min: sorted[0],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let v = vec![0.0, 10.0];
        assert_eq!(percentile(v.clone(), 0.5), 5.0);
        assert_eq!(percentile(v.clone(), 0.0), 0.0);
        assert_eq!(percentile(v, 1.0), 10.0);
    }

    #[test]
    fn percentile_of_empty_is_zero() {
        assert_eq!(percentile(std::iter::empty(), 0.9), 0.0);
    }

    #[test]
    fn percentile_is_order_invariant() {
        let a = percentile(vec![3.0, 1.0, 2.0], 0.5);
        let b = percentile(vec![1.0, 2.0, 3.0], 0.5);
        assert_eq!(a, b);
        assert_eq!(a, 2.0);
    }

    #[test]
    fn cdf_monotone_and_normalized() {
        let pts = cdf_points(vec![5.0, 1.0, 3.0, 3.0]);
        assert_eq!(pts.len(), 4);
        assert!((pts.last().unwrap().1 - 1.0).abs() < 1e-12);
        for w in pts.windows(2) {
            assert!(w[1].0 >= w[0].0);
            assert!(w[1].1 >= w[0].1);
        }
    }

    #[test]
    fn sorted_helpers_match_the_sorting_path() {
        let values = vec![9.0, -3.5, 0.0, 7.25, 2.0, 2.0, 11.0, -0.5];
        let mut sorted = values.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        for q in [0.0, 0.1, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(
                percentile(values.iter().copied(), q),
                percentile_of_sorted(&sorted, q),
                "q={q}"
            );
        }
        let qs = [0.5, 0.9, 0.99];
        let multi = percentiles(values.iter().copied(), &qs);
        for (i, &q) in qs.iter().enumerate() {
            assert_eq!(multi[i], percentile(values.iter().copied(), q));
        }
        assert_eq!(percentile_of_sorted(&[], 0.5), 0.0);
        assert!(percentiles(std::iter::empty(), &qs).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn summary_single_sort_is_identical_to_per_quantile_sorts() {
        // regression for the sort-once rewrite: every field must equal
        // the old clone-and-sort-per-call path bit for bit
        let values: Vec<f64> = (0..257)
            .map(|i| ((i * 73 % 257) as f64 - 60.0) * 0.37)
            .collect();
        let s = Summary::of(&values);
        assert_eq!(s.mean, values.iter().sum::<f64>() / values.len() as f64);
        assert_eq!(s.p50, percentile(values.iter().copied(), 0.50));
        assert_eq!(s.p90, percentile(values.iter().copied(), 0.90));
        assert_eq!(s.p99, percentile(values.iter().copied(), 0.99));
        assert_eq!(s.max, values.iter().copied().fold(f64::MIN, f64::max));
        assert_eq!(s.min, values.iter().copied().fold(f64::MAX, f64::min));
        // single element: every order statistic collapses onto it
        let one = Summary::of(&[4.25]);
        assert_eq!((one.min, one.p50, one.p99, one.max), (4.25, 4.25, 4.25, 4.25));
    }

    #[test]
    fn nan_values_order_last_and_surface_in_high_quantiles() {
        let v = vec![1.0, f64::NAN, 2.0];
        assert_eq!(percentile(v.clone(), 0.0), 1.0);
        assert_eq!(percentile(v.clone(), 0.5), 2.0);
        assert!(percentile(v, 1.0).is_nan(), "NaN sorts after every value");
        let pts = cdf_points(vec![f64::NAN, 3.0]);
        assert_eq!(pts[0].0, 3.0);
        assert!(pts[1].0.is_nan());
        assert_eq!(pts[1].1, 1.0);
    }

    #[test]
    fn single_element_and_edge_quantile_args() {
        // single-element slice: every q collapses onto the one value
        for q in [0.0, 0.5, 1.0] {
            assert_eq!(percentile_of_sorted(&[7.5], q), 7.5, "q={q}");
        }
        // out-of-range q clamps; NaN q reads as q = 0
        assert_eq!(percentile_of_sorted(&[1.0, 2.0], -3.0), 1.0);
        assert_eq!(percentile_of_sorted(&[1.0, 2.0], 7.0), 2.0);
        assert_eq!(percentile_of_sorted(&[1.0, 2.0], f64::NAN), 1.0);
        assert_eq!(percentile_of_sorted(&[], f64::NAN), 0.0);
    }

    #[test]
    fn summary_of_known_values() {
        let v: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let s = Summary::of(&v);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.min, 1.0);
        assert!((s.p99 - 99.01).abs() < 0.1);
    }
}
