//! Percentile / CDF extraction helpers.

/// Percentile with linear interpolation; `q` in `[0, 1]`.
/// Returns 0.0 for an empty iterator.
pub fn percentile(values: impl IntoIterator<Item = f64>, q: f64) -> f64 {
    let mut v: Vec<f64> = values.into_iter().collect();
    if v.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 1.0);
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Empirical CDF points: sorted `(value, fraction ≤ value)`.
pub fn cdf_points(values: impl IntoIterator<Item = f64>) -> Vec<(f64, f64)> {
    let mut v: Vec<f64> = values.into_iter().collect();
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len() as f64;
    v.into_iter()
        .enumerate()
        .map(|(i, x)| (x, (i + 1) as f64 / n))
        .collect()
}

/// Five-number summary plus mean, reused by experiment reports.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
    pub min: f64,
}

impl Summary {
    pub fn of(values: &[f64]) -> Self {
        if values.is_empty() {
            return Self {
                mean: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
                min: 0.0,
            };
        }
        Self {
            mean: values.iter().sum::<f64>() / values.len() as f64,
            p50: percentile(values.iter().copied(), 0.50),
            p90: percentile(values.iter().copied(), 0.90),
            p99: percentile(values.iter().copied(), 0.99),
            max: values.iter().copied().fold(f64::MIN, f64::max),
            min: values.iter().copied().fold(f64::MAX, f64::min),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let v = vec![0.0, 10.0];
        assert_eq!(percentile(v.clone(), 0.5), 5.0);
        assert_eq!(percentile(v.clone(), 0.0), 0.0);
        assert_eq!(percentile(v, 1.0), 10.0);
    }

    #[test]
    fn percentile_of_empty_is_zero() {
        assert_eq!(percentile(std::iter::empty(), 0.9), 0.0);
    }

    #[test]
    fn percentile_is_order_invariant() {
        let a = percentile(vec![3.0, 1.0, 2.0], 0.5);
        let b = percentile(vec![1.0, 2.0, 3.0], 0.5);
        assert_eq!(a, b);
        assert_eq!(a, 2.0);
    }

    #[test]
    fn cdf_monotone_and_normalized() {
        let pts = cdf_points(vec![5.0, 1.0, 3.0, 3.0]);
        assert_eq!(pts.len(), 4);
        assert!((pts.last().unwrap().1 - 1.0).abs() < 1e-12);
        for w in pts.windows(2) {
            assert!(w[1].0 >= w[0].0);
            assert!(w[1].1 >= w[0].1);
        }
    }

    #[test]
    fn summary_of_known_values() {
        let v: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let s = Summary::of(&v);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.min, 1.0);
        assert!((s.p99 - 99.01).abs() < 0.1);
    }
}
