//! Memory-usage-over-time sampling (the Fig 13 heatmaps).


/// One sample of a worker's KV-pool occupancy, reported at the paper's
/// three granularities (block / token / byte — §III-B).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemorySample {
    pub time: f64,
    pub worker: usize,
    pub used_blocks: u64,
    pub total_blocks: u64,
    /// Token-granularity view of `used_blocks`.
    pub used_tokens: u64,
    /// Byte-granularity view of `used_blocks`.
    pub used_bytes: u64,
}

impl MemorySample {
    pub fn utilization(&self) -> f64 {
        if self.total_blocks == 0 {
            return 1.0;
        }
        self.used_blocks as f64 / self.total_blocks as f64
    }
}

/// A per-worker memory timeline collected during a run.
#[derive(Debug, Clone, Default)]
pub struct MemoryTimeline {
    pub samples: Vec<MemorySample>,
}

impl MemoryTimeline {
    pub fn record(&mut self, sample: MemorySample) {
        self.samples.push(sample);
    }

    /// Samples of one worker, time-ordered.
    pub fn worker(&self, worker: usize) -> Vec<&MemorySample> {
        self.samples.iter().filter(|s| s.worker == worker).collect()
    }

    /// Mean utilization of a worker within `[t0, t1]`.
    pub fn mean_utilization(&self, worker: usize, t0: f64, t1: f64) -> f64 {
        let samples: Vec<f64> = self
            .samples
            .iter()
            .filter(|s| s.worker == worker && s.time >= t0 && s.time <= t1)
            .map(|s| s.utilization())
            .collect();
        if samples.is_empty() {
            return 0.0;
        }
        samples.iter().sum::<f64>() / samples.len() as f64
    }

    /// Peak utilization of a worker within `[t0, t1]`.
    pub fn peak_utilization(&self, worker: usize, t0: f64, t1: f64) -> f64 {
        self.samples
            .iter()
            .filter(|s| s.worker == worker && s.time >= t0 && s.time <= t1)
            .map(|s| s.utilization())
            .fold(0.0, f64::max)
    }

    /// Bucketed heatmap row for one worker: mean utilization in each of
    /// `bins` equal time buckets spanning `[t0, t1]` (None = no sample).
    pub fn heatmap_row(&self, worker: usize, t0: f64, t1: f64, bins: usize) -> Vec<Option<f64>> {
        let mut acc = vec![(0.0f64, 0usize); bins];
        let width = (t1 - t0) / bins as f64;
        for s in self.samples.iter().filter(|s| s.worker == worker) {
            if s.time < t0 || s.time >= t1 {
                continue;
            }
            let b = (((s.time - t0) / width) as usize).min(bins - 1);
            acc[b].0 += s.utilization();
            acc[b].1 += 1;
        }
        acc.into_iter()
            .map(|(sum, n)| if n > 0 { Some(sum / n as f64) } else { None })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(time: f64, worker: usize, used_blocks: u64, total_blocks: u64) -> MemorySample {
        MemorySample {
            time,
            worker,
            used_blocks,
            total_blocks,
            used_tokens: used_blocks * 16,
            used_bytes: used_blocks * 1024,
        }
    }

    fn tl() -> MemoryTimeline {
        let mut t = MemoryTimeline::default();
        for i in 0..10 {
            t.record(sample(i as f64, 0, i * 10, 100));
            t.record(sample(i as f64, 1, 50, 100));
        }
        t
    }

    #[test]
    fn per_worker_filtering() {
        let t = tl();
        assert_eq!(t.worker(0).len(), 10);
        assert_eq!(t.worker(1).len(), 10);
        assert_eq!(t.worker(2).len(), 0);
    }

    #[test]
    fn mean_and_peak() {
        let t = tl();
        assert!((t.mean_utilization(1, 0.0, 10.0) - 0.5).abs() < 1e-12);
        assert!((t.peak_utilization(0, 0.0, 10.0) - 0.9).abs() < 1e-12);
        assert_eq!(t.mean_utilization(0, 100.0, 200.0), 0.0);
    }

    #[test]
    fn heatmap_buckets() {
        let t = tl();
        let row = t.heatmap_row(0, 0.0, 10.0, 5);
        assert_eq!(row.len(), 5);
        // bucket 0 covers t=0,1 -> mean of 0.0 and 0.1
        assert!((row[0].unwrap() - 0.05).abs() < 1e-12);
        // increasing utilization over buckets
        let vals: Vec<f64> = row.iter().map(|v| v.unwrap()).collect();
        for w in vals.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn zero_capacity_reads_full() {
        let s = sample(0.0, 0, 0, 0);
        assert_eq!(s.utilization(), 1.0);
    }
}
