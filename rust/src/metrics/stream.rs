//! Streaming (sketch-mode) metric aggregation and the record store
//! that feeds it.
//!
//! Exact mode keeps every [`RequestRecord`] and computes percentiles
//! by sorting at report time — byte-identical outputs, O(requests)
//! memory. Sketch mode folds each record into [`StreamingMetrics`] at
//! completion time and drops it: fixed memory regardless of request
//! count, quantiles within the [`QuantileSketch`] error bound, and
//! everything else (counts, goodput, makespan, tenant sets, memory
//! timelines) identical to exact mode because those are plain counts,
//! min/max folds, and integer-valued sums that do not depend on
//! accumulation order.

use anyhow::Result;

use super::{MetricSet, QuantileSketch, RequestRecord, SloSpec, TenantSummary};
use crate::request::Request;

/// How per-request metrics are aggregated (the `metrics: mode:` config
/// key and `--metrics` CLI flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MetricsMode {
    /// Keep every record; reports are byte-identical and O(requests)
    /// in memory. The default — all determinism gates run in this mode.
    #[default]
    Exact,
    /// Fold records into fixed-size quantile sketches at completion
    /// time; bounded memory, quantiles within the documented
    /// relative-error bound.
    Sketch,
}

impl MetricsMode {
    /// Parse the config/CLI spelling.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "exact" => Ok(MetricsMode::Exact),
            "sketch" => Ok(MetricsMode::Sketch),
            other => anyhow::bail!("unknown metrics mode '{other}' (expected exact|sketch)"),
        }
    }
}

/// Per-tenant streaming aggregates (the sketch-mode counterpart of
/// [`MetricSet::tenant_breakdown`] filtering).
#[derive(Debug, Clone)]
struct TenantAgg {
    name: String,
    /// Smallest request id seen: sorting tenants by this reproduces
    /// exact mode's first-appearance-over-id-sorted-records order.
    min_id: usize,
    requests: u64,
    slo: Option<SloSpec>,
    slo_ok: u64,
    ttft: QuantileSketch,
    tbt: QuantileSketch,
}

/// Incrementally aggregated metrics, fed one [`RequestRecord`] at a
/// time as requests complete. Mirrors the [`MetricSet`] surface that
/// reporting paths consume, without retaining records.
#[derive(Debug, Clone)]
pub struct StreamingMetrics {
    eps: f64,
    slo: SloSpec,
    /// Per-class SLOs captured at build time (exact mode receives them
    /// as a `tenant_breakdown` argument instead).
    tenant_slos: Vec<(String, SloSpec)>,
    count: u64,
    first_arrival: f64,
    last_finished: f64,
    output_tokens: u64,
    norm_latency_sum: f64,
    slo_ok: u64,
    preemptions: u64,
    swaps: u64,
    recomputed_tokens: u64,
    latency: QuantileSketch,
    ttft: QuantileSketch,
    tbt: QuantileSketch,
    tenants: Vec<TenantAgg>,
}

impl StreamingMetrics {
    pub fn new(slo: SloSpec, tenant_slos: Vec<(String, SloSpec)>, eps: f64) -> Self {
        Self {
            eps,
            slo,
            tenant_slos,
            count: 0,
            first_arrival: f64::INFINITY,
            last_finished: 0.0,
            output_tokens: 0,
            norm_latency_sum: 0.0,
            slo_ok: 0,
            preemptions: 0,
            swaps: 0,
            recomputed_tokens: 0,
            latency: QuantileSketch::new(eps),
            ttft: QuantileSketch::new(eps),
            tbt: QuantileSketch::new(eps),
            tenants: Vec::new(),
        }
    }

    /// Fold one finished request into the aggregates.
    pub fn push(&mut self, rec: &RequestRecord) {
        self.count += 1;
        self.first_arrival = self.first_arrival.min(rec.arrival);
        self.last_finished = self.last_finished.max(rec.finished);
        self.output_tokens += rec.output_len as u64;
        self.norm_latency_sum += rec.normalized_latency();
        if self.slo.satisfied(rec) {
            self.slo_ok += 1;
        }
        self.preemptions += rec.preemptions as u64;
        self.swaps += rec.swaps as u64;
        self.recomputed_tokens += rec.recomputed_tokens;
        self.latency.add(rec.latency());
        self.ttft.add(rec.ttft());
        self.tbt.add(rec.max_token_gap);
        if let Some(name) = rec.tenant.as_deref() {
            let idx = match self.tenants.iter().position(|t| t.name == name) {
                Some(i) => i,
                None => {
                    let slo = self
                        .tenant_slos
                        .iter()
                        .find(|(n, _)| n == name)
                        .map(|(_, s)| *s);
                    self.tenants.push(TenantAgg {
                        name: name.to_string(),
                        min_id: rec.id,
                        requests: 0,
                        slo,
                        slo_ok: 0,
                        ttft: QuantileSketch::new(self.eps),
                        tbt: QuantileSketch::new(self.eps),
                    });
                    self.tenants.len() - 1
                }
            };
            let t = &mut self.tenants[idx];
            t.min_id = t.min_id.min(rec.id);
            t.requests += 1;
            if let Some(s) = t.slo {
                if s.satisfied(rec) {
                    t.slo_ok += 1;
                }
            }
            t.ttft.add(rec.ttft());
            t.tbt.add(rec.max_token_gap);
        }
    }

    /// The configured relative-error bound of every quantile reported.
    pub fn relative_error(&self) -> f64 {
        self.eps
    }

    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Makespan: first arrival to last completion. Min/max folds are
    /// order-invariant, so this equals [`MetricSet::makespan`] exactly.
    pub fn makespan(&self) -> f64 {
        (self.last_finished - self.first_arrival).max(0.0)
    }

    pub fn request_throughput(&self) -> f64 {
        let span = self.makespan();
        if span <= 0.0 {
            return 0.0;
        }
        self.count as f64 / span
    }

    /// Output tokens/s. The token count is an integer sum, so this
    /// equals the exact-mode value bit for bit.
    pub fn token_throughput(&self) -> f64 {
        let span = self.makespan();
        if span <= 0.0 {
            return 0.0;
        }
        self.output_tokens as f64 / span
    }

    pub fn latency_quantile(&self, q: f64) -> f64 {
        self.latency.quantile(q)
    }

    pub fn latency_quantiles(&self, qs: &[f64]) -> Vec<f64> {
        qs.iter().map(|&q| self.latency.quantile(q)).collect()
    }

    pub fn ttft_quantile(&self, q: f64) -> f64 {
        self.ttft.quantile(q)
    }

    pub fn tbt_quantile(&self, q: f64) -> f64 {
        self.tbt.quantile(q)
    }

    /// Mean normalized latency (s/token). The only aggregate whose
    /// floating-point rounding may differ from exact mode: the sum runs
    /// in completion order rather than id order.
    pub fn mean_normalized_latency(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.norm_latency_sum / self.count as f64
    }

    /// Approximate latency CDF: the sketch quantile at each percent
    /// point, as `(latency, fraction)` pairs like
    /// [`MetricSet::latency_cdf`] (101 points instead of one per
    /// request).
    pub fn latency_cdf(&self) -> Vec<(f64, f64)> {
        (0..=100)
            .map(|i| {
                let q = i as f64 / 100.0;
                (self.latency.quantile(q), q)
            })
            .collect()
    }

    /// Fraction of requests meeting the SLO captured at build time.
    pub fn slo_attainment(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.slo_ok as f64 / self.count as f64
    }

    /// Goodput against the SLO captured at build time. A count ratio,
    /// so it equals the exact-mode value bit for bit.
    pub fn slo_throughput(&self) -> f64 {
        let span = self.makespan();
        if span <= 0.0 {
            return 0.0;
        }
        self.slo_ok as f64 / span
    }

    pub fn total_preemptions(&self) -> u64 {
        self.preemptions
    }

    pub fn total_swaps(&self) -> u64 {
        self.swaps
    }

    pub fn total_recomputed_tokens(&self) -> u64 {
        self.recomputed_tokens
    }

    /// Per-tenant breakdown in the same order exact mode produces
    /// (ascending minimum request id == first appearance over
    /// id-sorted records). Quantiles carry the sketch error bound;
    /// request counts and attainment ratios are exact.
    pub fn tenant_breakdown(&self) -> Vec<TenantSummary> {
        let mut idx: Vec<usize> = (0..self.tenants.len()).collect();
        idx.sort_by_key(|&i| self.tenants[i].min_id);
        idx.into_iter()
            .map(|i| {
                let t = &self.tenants[i];
                TenantSummary {
                    tenant: t.name.clone(),
                    requests: t.requests as usize,
                    ttft_p50: t.ttft.quantile(0.50),
                    ttft_p99: t.ttft.quantile(0.99),
                    tbt_p99: t.tbt.quantile(0.99),
                    slo_attainment: t.slo.map(|_| t.slo_ok as f64 / t.requests as f64),
                }
            })
            .collect()
    }

    /// Fixed sketch memory currently held (all sketches, including
    /// per-tenant ones).
    pub fn memory_bytes(&self) -> usize {
        let base = self.latency.memory_bytes() + self.ttft.memory_bytes() + self.tbt.memory_bytes();
        let tenants: usize = self
            .tenants
            .iter()
            .map(|t| t.ttft.memory_bytes() + t.tbt.memory_bytes())
            .sum();
        base + tenants
    }
}

/// Where completed requests go: an id-indexed slab of full records
/// (exact mode) or a fixed-size streaming aggregate (sketch mode).
#[derive(Debug, Clone)]
pub enum RecordStore {
    /// Id-indexed slab. Request ids are dense (they index the
    /// simulation's request table), so `slab[id] = record` replaces the
    /// old push-then-sort while producing the identical id-ascending
    /// record vector.
    Exact(Vec<Option<RequestRecord>>),
    Sketch(Box<StreamingMetrics>),
}

impl RecordStore {
    pub fn exact() -> Self {
        RecordStore::Exact(Vec::new())
    }

    pub fn sketch(stream: StreamingMetrics) -> Self {
        RecordStore::Sketch(Box::new(stream))
    }

    /// Store one completed record.
    pub fn push(&mut self, rec: RequestRecord) {
        match self {
            RecordStore::Exact(slab) => {
                let id = rec.id;
                if id >= slab.len() {
                    slab.resize_with(id + 1, || None);
                }
                debug_assert!(slab[id].is_none(), "request {id} completed twice");
                slab[id] = Some(rec);
            }
            RecordStore::Sketch(s) => s.push(&rec),
        }
    }

    /// Convert a finished request and store it — the completion hook.
    /// Fails (instead of panicking) when the request never produced a
    /// token or never finished, so a corrupted completion fails its
    /// experiment cell rather than aborting a whole sweep.
    pub fn push_request(&mut self, r: &Request) -> Result<()> {
        self.push(RequestRecord::try_from_request(r)?);
        Ok(())
    }

    pub fn len(&self) -> usize {
        match self {
            RecordStore::Exact(slab) => slab.iter().filter(|r| r.is_some()).count(),
            RecordStore::Sketch(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Audit-mode consistency check ([`crate::lint::AUDIT_CHECKS`]
    /// A006): the store holds exactly `finished` completions, and (in
    /// exact mode, where per-record data survives) every record carries
    /// ordered timestamps. Read-only — audited reports stay
    /// byte-identical.
    pub fn audit_check(&self, finished: usize) -> Result<(), String> {
        if self.len() != finished {
            return Err(format!(
                "record store holds {} records for {finished} finished requests",
                self.len()
            ));
        }
        if let RecordStore::Exact(slab) = self {
            for rec in slab.iter().flatten() {
                if !(rec.arrival <= rec.first_token && rec.first_token <= rec.finished) {
                    return Err(format!(
                        "record {}: timestamps out of order (arrival {}, first token {}, \
                         finished {})",
                        rec.id, rec.arrival, rec.first_token, rec.finished
                    ));
                }
            }
        }
        Ok(())
    }

    /// Tear down into the report representation: id-ascending records
    /// (exact) or the streaming aggregate (sketch).
    pub fn into_parts(self) -> (Vec<RequestRecord>, Option<StreamingMetrics>) {
        match self {
            RecordStore::Exact(slab) => (slab.into_iter().flatten().collect(), None),
            RecordStore::Sketch(s) => (Vec::new(), Some(*s)),
        }
    }
}

impl From<Vec<RequestRecord>> for RecordStore {
    /// Build an exact store from unordered records (test ergonomics).
    fn from(records: Vec<RequestRecord>) -> Self {
        let mut store = RecordStore::exact();
        for r in records {
            store.push(r);
        }
        store
    }
}

/// A unified read API over exact records or streaming sketches, so the
/// CLI and experiment reporting paths are mode-agnostic. In exact mode
/// every method delegates to [`MetricSet`] and returns bit-identical
/// values; in sketch mode quantile-valued methods carry the sketch
/// error bound and `slo`-taking methods use the SLOs captured at build
/// time (the argument is ignored — it exists so exact mode needs no
/// stored SLO state).
pub enum MetricsView<'a> {
    Exact(MetricSet<'a>),
    Sketch(&'a StreamingMetrics),
}

impl MetricsView<'_> {
    pub fn len(&self) -> usize {
        match self {
            MetricsView::Exact(m) => m.len(),
            MetricsView::Sketch(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn makespan(&self) -> f64 {
        match self {
            MetricsView::Exact(m) => m.makespan(),
            MetricsView::Sketch(s) => s.makespan(),
        }
    }

    pub fn request_throughput(&self) -> f64 {
        match self {
            MetricsView::Exact(m) => m.request_throughput(),
            MetricsView::Sketch(s) => s.request_throughput(),
        }
    }

    pub fn token_throughput(&self) -> f64 {
        match self {
            MetricsView::Exact(m) => m.token_throughput(),
            MetricsView::Sketch(s) => s.token_throughput(),
        }
    }

    pub fn latency_percentile(&self, q: f64) -> f64 {
        match self {
            MetricsView::Exact(m) => m.latency_percentile(q),
            MetricsView::Sketch(s) => s.latency_quantile(q),
        }
    }

    pub fn latency_percentiles(&self, qs: &[f64]) -> Vec<f64> {
        match self {
            MetricsView::Exact(m) => m.latency_percentiles(qs),
            MetricsView::Sketch(s) => s.latency_quantiles(qs),
        }
    }

    pub fn ttft_percentile(&self, q: f64) -> f64 {
        match self {
            MetricsView::Exact(m) => m.ttft_percentile(q),
            MetricsView::Sketch(s) => s.ttft_quantile(q),
        }
    }

    pub fn tbt_percentile(&self, q: f64) -> f64 {
        match self {
            MetricsView::Exact(m) => m.tbt_percentile(q),
            MetricsView::Sketch(s) => s.tbt_quantile(q),
        }
    }

    pub fn mean_normalized_latency(&self) -> f64 {
        match self {
            MetricsView::Exact(m) => m.mean_normalized_latency(),
            MetricsView::Sketch(s) => s.mean_normalized_latency(),
        }
    }

    pub fn latency_cdf(&self) -> Vec<(f64, f64)> {
        match self {
            MetricsView::Exact(m) => m.latency_cdf(),
            MetricsView::Sketch(s) => s.latency_cdf(),
        }
    }

    /// Sketch mode scores against the SLO captured at build time and
    /// ignores `slo` (both are the report's configured SLO in
    /// practice).
    pub fn slo_attainment(&self, slo: &SloSpec) -> f64 {
        match self {
            MetricsView::Exact(m) => m.slo_attainment(slo),
            MetricsView::Sketch(s) => s.slo_attainment(),
        }
    }

    /// See [`MetricsView::slo_attainment`] on the `slo` argument.
    pub fn slo_throughput(&self, slo: &SloSpec) -> f64 {
        match self {
            MetricsView::Exact(m) => m.slo_throughput(slo),
            MetricsView::Sketch(s) => s.slo_throughput(),
        }
    }

    pub fn total_preemptions(&self) -> u64 {
        match self {
            MetricsView::Exact(m) => m.total_preemptions(),
            MetricsView::Sketch(s) => s.total_preemptions(),
        }
    }

    pub fn total_swaps(&self) -> u64 {
        match self {
            MetricsView::Exact(m) => m.total_swaps(),
            MetricsView::Sketch(s) => s.total_swaps(),
        }
    }

    pub fn total_recomputed_tokens(&self) -> u64 {
        match self {
            MetricsView::Exact(m) => m.total_recomputed_tokens(),
            MetricsView::Sketch(s) => s.total_recomputed_tokens(),
        }
    }

    /// Sketch mode uses the per-tenant SLOs captured at build time and
    /// ignores `slos` (see the type-level note).
    pub fn tenant_breakdown(&self, slos: &[(String, SloSpec)]) -> Vec<TenantSummary> {
        match self {
            MetricsView::Exact(m) => m.tenant_breakdown(slos),
            MetricsView::Sketch(s) => s.tenant_breakdown(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: usize, tenant: Option<&str>, arrival: f64, first: f64, fin: f64) -> RequestRecord {
        RequestRecord {
            id,
            conversation: id,
            round: 0,
            tenant: tenant.map(|t| t.to_string()),
            prompt_len: 32,
            output_len: 8,
            cached_prefix: 0,
            arrival,
            first_token: first,
            finished: fin,
            max_token_gap: 0.05,
            preemptions: 1,
            swaps: 0,
            recomputed_tokens: 3,
        }
    }

    fn records() -> Vec<RequestRecord> {
        (0..50)
            .map(|i| {
                let tenant = if i % 3 == 0 { Some("chat") } else { Some("batch") };
                let a = i as f64 * 0.1;
                rec(i, tenant, a, a + 0.2 + (i % 5) as f64 * 0.03, a + 1.0 + (i % 7) as f64 * 0.2)
            })
            .collect()
    }

    fn stream_of(recs: &[RequestRecord]) -> StreamingMetrics {
        let slos = vec![("chat".to_string(), SloSpec::paper_default())];
        let mut s = StreamingMetrics::new(SloSpec::paper_default(), slos, 0.01);
        for r in recs {
            s.push(r);
        }
        s
    }

    #[test]
    fn audit_check_flags_count_mismatch_and_bad_stamps() {
        let mut store = RecordStore::exact();
        store.push(rec(0, None, 1.0, 1.5, 2.0));
        assert_eq!(store.audit_check(1), Ok(()));
        let err = store.audit_check(2).unwrap_err();
        assert!(err.contains("1 records for 2 finished"), "{err}");
        // a first token stamped before arrival is a consistency breach
        store.push(rec(1, None, 5.0, 4.0, 6.0));
        let err = store.audit_check(2).unwrap_err();
        assert!(err.contains("timestamps out of order"), "{err}");
        // sketch mode retains only aggregates: the count check remains
        let sketch = RecordStore::sketch(stream_of(&records()));
        assert_eq!(sketch.audit_check(50), Ok(()));
        assert!(sketch.audit_check(49).is_err());
    }

    #[test]
    fn exact_invariant_aggregates_match_metric_set() {
        let recs = records();
        let s = stream_of(&recs);
        let m = MetricSet::new(&recs);
        assert_eq!(s.len(), m.len());
        assert_eq!(s.makespan(), m.makespan());
        assert_eq!(s.request_throughput(), m.request_throughput());
        assert_eq!(s.token_throughput(), m.token_throughput());
        let slo = SloSpec::paper_default();
        assert_eq!(s.slo_attainment(), m.slo_attainment(&slo));
        assert_eq!(s.slo_throughput(), m.slo_throughput(&slo));
        assert_eq!(s.total_preemptions(), m.total_preemptions());
        assert_eq!(s.total_swaps(), m.total_swaps());
        assert_eq!(s.total_recomputed_tokens(), m.total_recomputed_tokens());
    }

    #[test]
    fn streaming_quantiles_track_exact_within_bound() {
        let recs = records();
        let s = stream_of(&recs);
        let eps = s.relative_error();
        let mut lats: Vec<f64> = recs.iter().map(|r| r.latency()).collect();
        lats.sort_by(|a, b| a.total_cmp(b));
        for q in [0.5, 0.9, 0.99] {
            let est = s.latency_quantile(q);
            let pos = q * (lats.len() - 1) as f64;
            let lo = lats[pos.floor() as usize] * (1.0 - eps) - 1e-12;
            let hi = lats[pos.ceil() as usize] * (1.0 + eps) + 1e-12;
            assert!(est >= lo && est <= hi, "q={q}: {est} outside [{lo}, {hi}]");
        }
    }

    #[test]
    fn tenant_breakdown_matches_exact_order_counts_and_attainment() {
        let recs = records();
        let s = stream_of(&recs);
        let slos = vec![("chat".to_string(), SloSpec::paper_default())];
        let exact = MetricSet::new(&recs).tenant_breakdown(&slos);
        let stream = s.tenant_breakdown();
        assert_eq!(exact.len(), stream.len());
        for (e, st) in exact.iter().zip(&stream) {
            assert_eq!(e.tenant, st.tenant);
            assert_eq!(e.requests, st.requests);
            assert_eq!(e.slo_attainment, st.slo_attainment);
        }
    }

    #[test]
    fn exact_store_is_an_id_ordered_slab() {
        let mut store = RecordStore::exact();
        store.push(rec(2, None, 0.2, 0.5, 1.2));
        store.push(rec(0, None, 0.0, 0.3, 1.0));
        store.push(rec(1, None, 0.1, 0.4, 1.1));
        assert_eq!(store.len(), 3);
        let (records, stream) = store.into_parts();
        assert!(stream.is_none());
        let ids: Vec<usize> = records.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn sketch_store_retains_no_records() {
        let mut store = RecordStore::sketch(StreamingMetrics::new(
            SloSpec::paper_default(),
            Vec::new(),
            0.01,
        ));
        for r in records() {
            store.push(r);
        }
        assert_eq!(store.len(), 50);
        let (records, stream) = store.into_parts();
        assert!(records.is_empty());
        assert_eq!(stream.expect("sketch store yields a stream").len(), 50);
    }

    #[test]
    fn push_request_propagates_unfinished_request_error() {
        let mut store = RecordStore::exact();
        let r = Request::new(7, 0, 0, 16, 4, 0.5);
        let err = store.push_request(&r).expect_err("unfinished request");
        assert!(err.to_string().contains("request 7"), "{err}");
        assert!(store.is_empty());
    }

    #[test]
    fn sketch_cdf_is_monotone_and_spans_min_to_max() {
        let recs = records();
        let s = stream_of(&recs);
        let cdf = s.latency_cdf();
        assert_eq!(cdf.len(), 101);
        assert_eq!(cdf[0].1, 0.0);
        assert_eq!(cdf[100].1, 1.0);
        for w in cdf.windows(2) {
            assert!(w[1].0 >= w[0].0, "latency grid must be monotone");
            assert!(w[1].1 > w[0].1);
        }
    }

    #[test]
    fn metrics_mode_parses_config_spellings() {
        assert_eq!(MetricsMode::parse("exact").unwrap(), MetricsMode::Exact);
        assert_eq!(MetricsMode::parse("sketch").unwrap(), MetricsMode::Sketch);
        assert!(MetricsMode::parse("approximate").is_err());
        assert_eq!(MetricsMode::default(), MetricsMode::Exact);
    }

    #[test]
    fn bounded_memory_reporting() {
        let s = stream_of(&records());
        // 3 global + 2 tenants x 2 sketches, each ~19 KiB at eps=0.01
        assert!(s.memory_bytes() > 0);
        assert!(s.memory_bytes() < 1024 * 1024);
    }
}
