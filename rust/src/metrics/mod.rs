//! QoS metrics: request records, latency percentiles and CDFs,
//! normalized latency, SLO attainment, goodput, memory timelines.
//!
//! These are exactly the "detailed performance results, including the
//! latency distribution and memory usage over time" that distinguish
//! TokenSim from single-batch simulators.

mod percentile;
mod sketch;
mod stream;
mod timeline;

pub use percentile::{cdf_points, percentile, percentile_of_sorted, percentiles, Summary};
pub use sketch::QuantileSketch;
pub use stream::{MetricsMode, MetricsView, RecordStore, StreamingMetrics};
pub use timeline::{MemorySample, MemoryTimeline};

use anyhow::{Context, Result};

use crate::request::Request;
use crate::sim::SimTime;

/// Immutable record of a finished (or failed) request.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestRecord {
    pub id: usize,
    pub conversation: usize,
    pub round: usize,
    /// Tenant class of a multi-tenant workload (None = single-tenant).
    pub tenant: Option<String>,
    pub prompt_len: u32,
    pub output_len: u32,
    pub cached_prefix: u32,
    pub arrival: SimTime,
    pub first_token: SimTime,
    pub finished: SimTime,
    pub max_token_gap: SimTime,
    /// Times the request was preempted (recompute or swap).
    pub preemptions: u32,
    /// Times the request was preempted by swap-out specifically.
    pub swaps: u32,
    /// Tokens re-prefilled after recompute preemptions.
    pub recomputed_tokens: u64,
}

impl RequestRecord {
    /// Build from a finished request. Returns an error — not a panic —
    /// when the request never produced a token or never finished, so a
    /// corrupted completion fails its own experiment cell instead of
    /// aborting a whole `parallel_sweep`.
    pub fn try_from_request(r: &Request) -> Result<Self> {
        let first_token = r.first_token.with_context(|| {
            format!(
                "request {} reached record construction without producing a token (phase {:?})",
                r.id, r.phase
            )
        })?;
        let finished = r.finished_at.with_context(|| {
            format!(
                "request {} reached record construction unfinished (phase {:?}, {}/{} output tokens)",
                r.id, r.phase, r.generated, r.output_len
            )
        })?;
        Ok(Self {
            id: r.id,
            conversation: r.conversation,
            round: r.round,
            tenant: r.tenant.clone(),
            prompt_len: r.prompt_len,
            output_len: r.output_len,
            cached_prefix: r.cached_prefix,
            arrival: r.arrival,
            first_token,
            finished,
            max_token_gap: r.max_token_gap,
            preemptions: r.preemptions,
            swaps: r.swaps,
            recomputed_tokens: r.recomputed_tokens,
        })
    }

    #[inline]
    pub fn latency(&self) -> f64 {
        self.finished - self.arrival
    }

    #[inline]
    pub fn ttft(&self) -> f64 {
        self.first_token - self.arrival
    }

    /// Mean time-per-output-token after the first token.
    #[inline]
    pub fn tpot(&self) -> f64 {
        if self.output_len <= 1 {
            return 0.0;
        }
        (self.finished - self.first_token) / (self.output_len - 1) as f64
    }

    /// vLLM's normalized latency: end-to-end latency / output tokens.
    #[inline]
    pub fn normalized_latency(&self) -> f64 {
        self.latency() / self.output_len as f64
    }
}

/// Service-level objectives (the paper's Fig 10: TTFT 15 s, mTPOT 0.3 s).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    /// Time-to-first-token bound, seconds (None = unconstrained).
    pub ttft: Option<f64>,
    /// Max token-processing-over-time: no inter-token gap may exceed
    /// this (None = unconstrained).
    pub mtpot: Option<f64>,
}

impl SloSpec {
    pub const fn paper_default() -> Self {
        Self {
            ttft: Some(15.0),
            mtpot: Some(0.3),
        }
    }

    pub const fn decode_only() -> Self {
        Self {
            ttft: None,
            mtpot: Some(0.3),
        }
    }

    pub const fn none() -> Self {
        Self {
            ttft: None,
            mtpot: None,
        }
    }

    /// Does `rec` satisfy every configured objective?
    pub fn satisfied(&self, rec: &RequestRecord) -> bool {
        if let Some(bound) = self.ttft {
            if rec.ttft() > bound {
                return false;
            }
        }
        if let Some(bound) = self.mtpot {
            if rec.max_token_gap > bound {
                return false;
            }
        }
        true
    }
}

/// Aggregated metrics over a set of request records.
pub struct MetricSet<'a> {
    records: &'a [RequestRecord],
}

impl<'a> MetricSet<'a> {
    pub fn new(records: &'a [RequestRecord]) -> Self {
        Self { records }
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Makespan: first arrival to last completion.
    pub fn makespan(&self) -> f64 {
        let start = self
            .records
            .iter()
            .map(|r| r.arrival)
            .fold(f64::INFINITY, f64::min);
        let end = self
            .records
            .iter()
            .map(|r| r.finished)
            .fold(0.0f64, f64::max);
        (end - start).max(0.0)
    }

    /// Requests per second over the makespan.
    pub fn request_throughput(&self) -> f64 {
        let span = self.makespan();
        if span <= 0.0 {
            return 0.0;
        }
        self.records.len() as f64 / span
    }

    /// Output tokens per second over the makespan.
    pub fn token_throughput(&self) -> f64 {
        let span = self.makespan();
        if span <= 0.0 {
            return 0.0;
        }
        self.records.iter().map(|r| r.output_len as f64).sum::<f64>() / span
    }

    /// Latency percentile (q in [0, 1]).
    pub fn latency_percentile(&self, q: f64) -> f64 {
        percentile(self.records.iter().map(|r| r.latency()), q)
    }

    /// Several latency percentiles with a single collect-and-sort —
    /// identical values to calling [`latency_percentile`] per `q`,
    /// without re-sorting the record set each time (the per-report /
    /// per-sweep-cell hot path).
    ///
    /// [`latency_percentile`]: MetricSet::latency_percentile
    pub fn latency_percentiles(&self, qs: &[f64]) -> Vec<f64> {
        percentiles(self.records.iter().map(|r| r.latency()), qs)
    }

    pub fn ttft_percentile(&self, q: f64) -> f64 {
        percentile(self.records.iter().map(|r| r.ttft()), q)
    }

    /// Several TTFT percentiles with a single collect-and-sort.
    pub fn ttft_percentiles(&self, qs: &[f64]) -> Vec<f64> {
        percentiles(self.records.iter().map(|r| r.ttft()), qs)
    }

    /// Percentile of the per-request worst inter-token gap (the TBT
    /// figure the mTPOT SLO constrains).
    pub fn tbt_percentile(&self, q: f64) -> f64 {
        percentile(self.records.iter().map(|r| r.max_token_gap), q)
    }

    /// Mean normalized latency (s/token) — vLLM's serving metric.
    pub fn mean_normalized_latency(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records
            .iter()
            .map(|r| r.normalized_latency())
            .sum::<f64>()
            / self.records.len() as f64
    }

    /// Latency CDF points (sorted (latency, cumulative fraction)).
    pub fn latency_cdf(&self) -> Vec<(f64, f64)> {
        cdf_points(self.records.iter().map(|r| r.latency()))
    }

    /// Fraction of requests meeting `slo`.
    pub fn slo_attainment(&self, slo: &SloSpec) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        let ok = self.records.iter().filter(|r| slo.satisfied(r)).count();
        ok as f64 / self.records.len() as f64
    }

    /// Goodput: requests/s counting only SLO-satisfying requests (the
    /// paper's "throughput considering SLOs").
    pub fn slo_throughput(&self, slo: &SloSpec) -> f64 {
        let span = self.makespan();
        if span <= 0.0 {
            return 0.0;
        }
        self.records.iter().filter(|r| slo.satisfied(r)).count() as f64 / span
    }

    pub fn total_preemptions(&self) -> u64 {
        self.records.iter().map(|r| r.preemptions as u64).sum()
    }

    /// Preemptions serviced by swap-out (no recompute work).
    pub fn total_swaps(&self) -> u64 {
        self.records.iter().map(|r| r.swaps as u64).sum()
    }

    /// Tokens re-prefilled because of recompute preemptions — the
    /// wasted compute the swap policy trades for host-link traffic.
    pub fn total_recomputed_tokens(&self) -> u64 {
        self.records.iter().map(|r| r.recomputed_tokens).sum()
    }

    /// Per-tenant TTFT/TBT percentiles for multi-tenant workloads, in
    /// first-appearance order (records are id-sorted, so this is the
    /// dispatch order and deterministic). `slos` supplies per-class
    /// objectives (e.g. from
    /// [`WorkloadGenerator::tenant_slos`](crate::workload::WorkloadGenerator::tenant_slos));
    /// attainment is `None` for tenants without an entry. Empty when no
    /// record carries a tenant tag.
    pub fn tenant_breakdown(&self, slos: &[(String, SloSpec)]) -> Vec<TenantSummary> {
        let mut names: Vec<&str> = Vec::new();
        for r in self.records {
            if let Some(t) = r.tenant.as_deref() {
                if !names.contains(&t) {
                    names.push(t);
                }
            }
        }
        names
            .into_iter()
            .map(|name| {
                let recs: Vec<&RequestRecord> = self
                    .records
                    .iter()
                    .filter(|r| r.tenant.as_deref() == Some(name))
                    .collect();
                let slo = slos.iter().find(|(n, _)| n == name).map(|(_, s)| *s);
                let attainment = slo.map(|s| {
                    recs.iter().filter(|r| s.satisfied(r)).count() as f64 / recs.len() as f64
                });
                let ttft = percentiles(recs.iter().map(|r| r.ttft()), &[0.50, 0.99]);
                TenantSummary {
                    tenant: name.to_string(),
                    requests: recs.len(),
                    ttft_p50: ttft[0],
                    ttft_p99: ttft[1],
                    tbt_p99: percentile(recs.iter().map(|r| r.max_token_gap), 0.99),
                    slo_attainment: attainment,
                }
            })
            .collect()
    }
}

/// One tenant's aggregate service quality (see
/// [`MetricSet::tenant_breakdown`]).
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSummary {
    pub tenant: String,
    pub requests: usize,
    pub ttft_p50: f64,
    pub ttft_p99: f64,
    /// P99 of the per-request worst inter-token gap.
    pub tbt_p99: f64,
    /// Fraction of this tenant's requests meeting its own SLO (None
    /// when no SLO was supplied for it).
    pub slo_attainment: Option<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: usize, arrival: f64, first: f64, fin: f64, out: u32, gap: f64) -> RequestRecord {
        RequestRecord {
            id,
            conversation: id,
            round: 0,
            tenant: None,
            prompt_len: 32,
            output_len: out,
            cached_prefix: 0,
            arrival,
            first_token: first,
            finished: fin,
            max_token_gap: gap,
            preemptions: 0,
            swaps: 0,
            recomputed_tokens: 0,
        }
    }

    #[test]
    fn try_from_request_rejects_unfinished_and_accepts_finished() {
        let mut r = Request::new(3, 0, 0, 16, 4, 1.0);
        let err = RequestRecord::try_from_request(&r).unwrap_err();
        assert!(err.to_string().contains("without producing a token"), "{err}");
        r.stamp_token(2.0);
        let err = RequestRecord::try_from_request(&r).unwrap_err();
        assert!(err.to_string().contains("unfinished"), "{err}");
        r.finished_at = Some(3.0);
        let rec = RequestRecord::try_from_request(&r).expect("finished request converts");
        assert_eq!((rec.id, rec.first_token, rec.finished), (3, 2.0, 3.0));
    }

    #[test]
    fn derived_quantities() {
        let r = rec(0, 1.0, 2.0, 11.0, 11, 0.1);
        assert_eq!(r.ttft(), 1.0);
        assert_eq!(r.latency(), 10.0);
        assert!((r.tpot() - 0.9).abs() < 1e-12);
        assert!((r.normalized_latency() - 10.0 / 11.0).abs() < 1e-12);
    }

    #[test]
    fn slo_checks() {
        let slo = SloSpec {
            ttft: Some(2.0),
            mtpot: Some(0.2),
        };
        assert!(slo.satisfied(&rec(0, 0.0, 1.0, 5.0, 10, 0.1)));
        assert!(!slo.satisfied(&rec(0, 0.0, 3.0, 5.0, 10, 0.1)), "ttft");
        assert!(!slo.satisfied(&rec(0, 0.0, 1.0, 5.0, 10, 0.5)), "mtpot");
        assert!(SloSpec::none().satisfied(&rec(0, 0.0, 9.0, 99.0, 10, 9.0)));
    }

    #[test]
    fn throughput_over_makespan() {
        let recs = vec![
            rec(0, 0.0, 1.0, 2.0, 10, 0.0),
            rec(1, 1.0, 2.0, 10.0, 30, 0.0),
        ];
        let m = MetricSet::new(&recs);
        assert_eq!(m.makespan(), 10.0);
        assert!((m.request_throughput() - 0.2).abs() < 1e-12);
        assert!((m.token_throughput() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn tenant_breakdown_groups_and_scores_per_class() {
        let mut a = rec(0, 0.0, 0.5, 5.0, 10, 0.1);
        a.tenant = Some("chat".into());
        let mut b = rec(1, 0.0, 4.0, 9.0, 10, 0.1);
        b.tenant = Some("chat".into());
        let mut c = rec(2, 0.0, 8.0, 20.0, 10, 0.4);
        c.tenant = Some("batch".into());
        let recs = vec![a, b, c];
        let m = MetricSet::new(&recs);
        let slos = vec![(
            "chat".to_string(),
            SloSpec {
                ttft: Some(2.0),
                mtpot: Some(0.2),
            },
        )];
        let out = m.tenant_breakdown(&slos);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].tenant, "chat");
        assert_eq!(out[0].requests, 2);
        // one of the two chat requests misses the 2 s TTFT bound
        assert_eq!(out[0].slo_attainment, Some(0.5));
        assert!(out[0].ttft_p99 >= out[0].ttft_p50);
        assert_eq!(out[1].tenant, "batch");
        assert_eq!(out[1].slo_attainment, None, "no SLO supplied for batch");
        assert!((out[1].tbt_p99 - 0.4).abs() < 1e-12);
        // untagged records produce no breakdown at all
        assert!(MetricSet::new(&[rec(0, 0.0, 1.0, 2.0, 5, 0.0)])
            .tenant_breakdown(&[])
            .is_empty());
    }

    #[test]
    fn multi_percentile_paths_match_single_percentile_calls() {
        let recs: Vec<RequestRecord> = (0..40)
            .map(|i| {
                let a = i as f64 * 0.13;
                rec(i, a, a + 0.2 + (i % 7) as f64 * 0.05, a + 1.0 + (i % 5) as f64, 8, 0.01)
            })
            .collect();
        let m = MetricSet::new(&recs);
        let qs = [0.5, 0.9, 0.99, 1.0];
        let lat = m.latency_percentiles(&qs);
        let ttft = m.ttft_percentiles(&qs);
        for (i, &q) in qs.iter().enumerate() {
            assert_eq!(lat[i], m.latency_percentile(q), "latency q={q}");
            assert_eq!(ttft[i], m.ttft_percentile(q), "ttft q={q}");
        }
    }

    #[test]
    fn goodput_counts_only_satisfying() {
        let recs = vec![
            rec(0, 0.0, 1.0, 2.0, 10, 0.0),
            rec(1, 0.0, 20.0, 30.0, 10, 0.0), // ttft violation
        ];
        let m = MetricSet::new(&recs);
        let slo = SloSpec::paper_default();
        assert!((m.slo_attainment(&slo) - 0.5).abs() < 1e-12);
        assert!((m.slo_throughput(&slo) - 1.0 / 30.0).abs() < 1e-12);
    }
}
