//! Streaming quantile sketch: a DDSketch-style log-bucketed histogram
//! with a guaranteed relative-error bound.
//!
//! Design notes (why this over the alternatives named in the roadmap):
//!
//! - **P²** keeps five markers and is O(1), but two P² estimators
//!   cannot be merged, which kills per-tenant + overall aggregation and
//!   any future parallel-sweep reduction.
//! - **t-digest** merges, but its error bound is in *rank* space
//!   (tight at the tails, loose in the middle) and depends on
//!   compression heuristics, so a property test over adversarial
//!   streams cannot assert a closed-form bound.
//! - A **log-bucketed histogram** (the DDSketch idea) gives a provable
//!   *relative-error* bound on the value returned for any quantile,
//!   merges exactly (element-wise count addition, order-invariant), and
//!   is trivially deterministic — the right trade for latency metrics
//!   whose scale spans ~1 ms .. ~1 h.
//!
//! ## Error bound
//!
//! For a sketch built with error parameter `eps` over `n` values, let
//! `sorted` be the values in ascending order and `pos = q * (n - 1)`
//! (the same convention as
//! [`percentile_of_sorted`](super::percentile_of_sorted)). Then
//!
//! ```text
//! sorted[floor(pos)] * (1 - eps) <= quantile(q) <= sorted[ceil(pos)] * (1 + eps)
//! ```
//!
//! i.e. the estimate is within `eps` *relative* error of an order
//! statistic adjacent to the interpolation position. (The exact helpers
//! interpolate between the two order statistics; for duplicate-heavy or
//! adversarial streams the window form above is the bound that actually
//! holds, and it is what the property tests assert.)
//!
//! Values are assumed non-negative (latencies, TTFTs, token gaps).
//! Values at or below [`MIN_TRACKED`] — including zeros — land in a
//! dedicated low bucket and are reported as the stream minimum; values
//! above the last bucket's upper edge (`~1e12`) saturate into it and
//! are clamped to the stream maximum. NaN values are ignored (the
//! record paths never produce them; see the NaN notes on
//! [`percentile_of_sorted`](super::percentile_of_sorted)).

/// Values at or below this threshold (seconds) are exact-counted in a
/// low bucket instead of log-bucketed. 1 ns is far below any simulated
/// latency, so the relative-error guarantee is unaffected in practice.
pub const MIN_TRACKED: f64 = 1e-9;

/// Upper edge of the tracked value range (seconds). ~31,000 years:
/// nothing a simulation produces exceeds it, but the cap keeps the
/// bucket array finite.
const MAX_TRACKED: f64 = 1e12;

/// A mergeable streaming quantile sketch with bounded relative error
/// and fixed memory (~19 KiB at `eps = 0.01`, independent of the
/// number of values added).
///
/// ```
/// use tokensim::metrics::QuantileSketch;
///
/// let mut s = QuantileSketch::new(0.01);
/// for i in 1..=1000 {
///     s.add(i as f64);
/// }
/// let p50 = s.quantile(0.5);
/// assert!((p50 - 500.0).abs() / 500.0 < 0.02);
/// assert_eq!(s.count(), 1000);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct QuantileSketch {
    eps: f64,
    gamma: f64,
    inv_log_gamma: f64,
    count: u64,
    /// Count of values `<= MIN_TRACKED` (zeros and denormally small).
    low: u64,
    min: f64,
    max: f64,
    buckets: Vec<u64>,
}

impl QuantileSketch {
    /// Create a sketch with relative-error bound `eps` (e.g. `0.01`
    /// for ±1%). Panics if `eps` is outside `(0, 0.5)`.
    pub fn new(eps: f64) -> Self {
        assert!(
            eps > 0.0 && eps < 0.5,
            "sketch relative error must be in (0, 0.5), got {eps}"
        );
        let gamma = (1.0 + eps) / (1.0 - eps);
        let log_gamma = gamma.ln();
        // enough buckets to cover (MIN_TRACKED, MAX_TRACKED]
        let n_buckets = ((MAX_TRACKED / MIN_TRACKED).ln() / log_gamma).ceil() as usize + 1;
        Self {
            eps,
            gamma,
            inv_log_gamma: 1.0 / log_gamma,
            count: 0,
            low: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            buckets: vec![0; n_buckets],
        }
    }

    /// The configured relative-error bound.
    pub fn relative_error(&self) -> f64 {
        self.eps
    }

    /// Number of values added.
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Smallest value added (0.0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest value added (0.0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Add one value. NaN is ignored; values `<= MIN_TRACKED`
    /// (including zeros and, defensively, negatives) are exact-counted
    /// in the low bucket.
    pub fn add(&mut self, v: f64) {
        if v.is_nan() {
            return;
        }
        self.count += 1;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if v <= MIN_TRACKED {
            self.low += 1;
            return;
        }
        let idx = ((v / MIN_TRACKED).ln() * self.inv_log_gamma).floor() as usize;
        let idx = idx.min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
    }

    /// Quantile estimate for `q` in `[0, 1]` (clamped), subject to the
    /// module-level error bound. Returns 0.0 on an empty sketch,
    /// mirroring [`percentile_of_sorted`](super::percentile_of_sorted).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 1.0) };
        // same interpolation position as percentile_of_sorted, rounded
        // to the nearest order statistic
        let rank = (q * (self.count - 1) as f64).round() as u64;
        // the extreme order statistics are tracked exactly
        if rank == 0 {
            return self.min;
        }
        if rank >= self.count - 1 {
            return self.max;
        }
        let mut cum = self.low;
        if rank < cum {
            return self.min;
        }
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if rank < cum {
                // midpoint (in relative terms) of bucket i, whose value
                // range is (MIN_TRACKED * gamma^i, MIN_TRACKED * gamma^(i+1)]
                let est = MIN_TRACKED * self.gamma.powi(i as i32) * (2.0 * self.gamma)
                    / (self.gamma + 1.0);
                return est.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Merge another sketch into this one. Exact: element-wise count
    /// addition, so `a.merge(&b)` equals sketching the concatenated
    /// stream, independent of insertion order. Panics if the sketches
    /// were built with different `eps`.
    pub fn merge(&mut self, other: &QuantileSketch) {
        assert_eq!(
            self.eps, other.eps,
            "cannot merge sketches with different error bounds"
        );
        self.count += other.count;
        self.low += other.low;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for (b, &o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
    }

    /// Fixed memory footprint of the bucket array in bytes (the figure
    /// that replaces the old O(requests) sample `Vec`s).
    pub fn memory_bytes(&self) -> usize {
        self.buckets.len() * std::mem::size_of::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::percentile_of_sorted;

    fn assert_within_window(sorted: &[f64], q: f64, est: f64, eps: f64, ctx: &str) {
        let pos = q * (sorted.len() - 1) as f64;
        let lo = sorted[pos.floor() as usize] * (1.0 - eps) - 1e-12;
        let hi = sorted[pos.ceil() as usize] * (1.0 + eps) + 1e-12;
        assert!(
            est >= lo && est <= hi,
            "{ctx}: q={q} estimate {est} outside [{lo}, {hi}]"
        );
    }

    #[test]
    fn empty_sketch_mirrors_percentile_of_sorted() {
        let s = QuantileSketch::new(0.01);
        assert_eq!(s.quantile(0.5), 0.0);
        assert_eq!(percentile_of_sorted(&[], 0.5), 0.0);
        assert_eq!(s.count(), 0);
        assert!(s.is_empty());
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
    }

    #[test]
    fn single_value_collapses_every_quantile() {
        let mut s = QuantileSketch::new(0.01);
        s.add(3.75);
        // a single value is both the exact min and the exact max
        for q in [0.0, 0.5, 1.0] {
            assert_eq!(s.quantile(q), 3.75, "q={q}");
        }
    }

    #[test]
    fn uniform_ramp_within_bound() {
        let eps = 0.01;
        let mut s = QuantileSketch::new(eps);
        let mut vals: Vec<f64> = (1..=10_000).map(|i| i as f64 * 1e-3).collect();
        for &v in &vals {
            s.add(v);
        }
        vals.sort_by(|a, b| a.total_cmp(b));
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
            assert_within_window(&vals, q, s.quantile(q), eps, "ramp");
        }
    }

    #[test]
    fn zeros_and_tiny_values_report_as_minimum() {
        let mut s = QuantileSketch::new(0.02);
        for _ in 0..10 {
            s.add(0.0);
        }
        s.add(5.0);
        assert_eq!(s.quantile(0.0), 0.0);
        assert_eq!(s.quantile(0.5), 0.0);
        assert_eq!(s.quantile(1.0), 5.0);
    }

    #[test]
    fn nan_values_are_ignored() {
        let mut s = QuantileSketch::new(0.01);
        s.add(f64::NAN);
        s.add(2.0);
        s.add(f64::NAN);
        assert_eq!(s.count(), 1);
        assert_eq!(s.quantile(0.5), 2.0);
    }

    #[test]
    fn out_of_range_and_nan_quantile_args_clamp() {
        let mut s = QuantileSketch::new(0.01);
        s.add(1.0);
        s.add(2.0);
        assert_eq!(s.quantile(-3.0), s.quantile(0.0));
        assert_eq!(s.quantile(7.0), s.quantile(1.0));
        assert_eq!(s.quantile(f64::NAN), s.quantile(0.0));
    }

    #[test]
    fn merge_is_exact_count_addition() {
        let mut a = QuantileSketch::new(0.01);
        let mut b = QuantileSketch::new(0.01);
        let mut both = QuantileSketch::new(0.01);
        for i in 0..500 {
            let v = 0.01 + (i % 37) as f64 * 0.5;
            a.add(v);
            both.add(v);
        }
        for i in 0..300 {
            let v = 100.0 + i as f64;
            b.add(v);
            both.add(v);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged, both);
    }

    #[test]
    #[should_panic]
    fn merge_rejects_mismatched_error_bounds() {
        let mut a = QuantileSketch::new(0.01);
        let b = QuantileSketch::new(0.02);
        a.merge(&b);
    }

    #[test]
    #[should_panic]
    fn zero_eps_rejected() {
        QuantileSketch::new(0.0);
    }

    #[test]
    fn memory_is_fixed_and_small() {
        let mut s = QuantileSketch::new(0.01);
        let before = s.memory_bytes();
        for i in 0..100_000 {
            s.add(1e-3 * (1 + i % 977) as f64);
        }
        assert_eq!(s.memory_bytes(), before, "no growth with stream length");
        assert!(before < 64 * 1024, "bucket array stays under 64 KiB");
    }
}
