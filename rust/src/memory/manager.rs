//! The [`MemoryManager`] trait: the pluggable allocator surface every
//! worker's KV-cache manager implements.
//!
//! Mirrors the paper's §III-B: "TokenSim implements memory managers for
//! various worker types … to monitor memory utilization at any
//! granularity — by block, token, or byte — supporting user-defined
//! scheduler behaviors." The cluster driver and the local schedulers
//! only ever see `&mut dyn MemoryManager`, so a new allocation policy is
//! additive: implement this trait, register it
//! ([`register_memory`](crate::memory::register_memory)), select it by
//! name ([`MemorySpec`](crate::memory::MemorySpec)).
//!
//! Built-in managers: `paged` ([`PagedBlockManager`]), `token_contiguous`
//! ([`TokenContiguousManager`]), `swap` ([`SwapMemoryManager`]) and
//! `prefix_cache` ([`PrefixCacheManager`]).
//!
//! [`PagedBlockManager`]: crate::memory::PagedBlockManager
//! [`TokenContiguousManager`]: crate::memory::TokenContiguousManager
//! [`SwapMemoryManager`]: crate::memory::SwapMemoryManager
//! [`PrefixCacheManager`]: crate::memory::PrefixCacheManager

use crate::hardware::LinkSpec;
use crate::request::{ConversationId, Request, RequestId};

use super::{AllocOutcome, Granularity, PoolHit};

/// What a local scheduler does with a decode request whose KV cache can
/// no longer grow (the second axis of the paper's memory design space).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PreemptionPolicy {
    /// vLLM-style: drop the victim's KV and re-prefill it later (its
    /// already-generated tokens are recomputed as prompt).
    #[default]
    Recompute,
    /// Move the victim's KV to host swap space over the host↔device
    /// link; it resumes by swapping back in, with no re-prefill. Only
    /// meaningful for managers with swap space ([`MemoryManager::swap_out`]
    /// returning `None` falls back to recompute).
    Swap,
}

/// Cumulative swap traffic of a manager (zeros when swap is unsupported).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SwapStats {
    /// Swap-out events (preemptions serviced by the host).
    pub swap_outs: u64,
    /// Swap-in events (restorations).
    pub swap_ins: u64,
    /// Blocks moved device → host.
    pub blocks_out: u64,
    /// Blocks moved host → device.
    pub blocks_in: u64,
}

/// Cumulative prefix-cache activity of a manager (zeros when the
/// manager has no cross-request cache layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PoolStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// A worker's KV-cache memory manager (the paper's §III-B component).
///
/// The allocator surface (reserve / release / admission) is what the
/// local schedulers drive every iteration; the swap and prefix-cache
/// hooks are optional capabilities with inert defaults, so simple
/// managers implement only the allocator core.
///
/// All accounting is in *blocks* of [`block_size`](Self::block_size)
/// tokens ([`block_bytes`](Self::block_bytes) bytes); token- and
/// byte-granularity views derive from them via [`used`](Self::used) /
/// [`capacity`](Self::capacity).
///
/// # Examples
///
/// Building the default paged manager through the registry and driving
/// it directly:
///
/// ```
/// use tokensim::memory::{AllocOutcome, MemoryManager, MemorySpec};
/// use tokensim::model::ModelSpec;
///
/// let mut mem = MemorySpec::new("paged")
///     .with("block_size", 16u32)
///     .build(&ModelSpec::llama2_7b(), 80e9)
///     .unwrap();
/// assert_eq!(mem.name(), "paged");
/// assert_eq!(mem.reserve(0, 100), AllocOutcome::Ok); // 7 blocks
/// assert_eq!(mem.blocks_held(0), 7);
/// mem.release(0);
/// assert!(mem.check_invariants());
/// ```
pub trait MemoryManager: Send {
    /// Registry name of this manager (stable, lowercase).
    fn name(&self) -> &'static str;

    /// Tokens per allocation block (1 for token-granularity managers).
    fn block_size(&self) -> u32;

    /// Bytes of KV per block.
    fn block_bytes(&self) -> u64;

    /// Total device KV pool size in blocks.
    fn total_blocks(&self) -> u64;

    /// Free device blocks.
    fn free_blocks(&self) -> u64;

    /// Device blocks currently held by `req`.
    fn blocks_held(&self, req: RequestId) -> u64;

    /// Can a new request with `tokens` of KV be admitted, with `pending`
    /// blocks already promised to earlier admissions in the same
    /// batch-formation pass? Enforces the manager's admission cap
    /// (Fig 10's `max_mem_ratio`) and low-watermark headroom.
    fn can_admit_with_pending(&self, tokens: u32, pending: u64) -> bool;

    /// Reserve blocks so `req` holds `tokens` total KV tokens (growing
    /// an existing reservation only allocates the delta).
    fn reserve(&mut self, req: RequestId, tokens: u32) -> AllocOutcome;

    /// Release all device blocks of `req` (finish or hand-off). Returns
    /// the number of blocks freed.
    fn release(&mut self, req: RequestId) -> u64;

    /// Release due to preemption (tracked in
    /// [`preemption_frees`](Self::preemption_frees)).
    fn release_preempted(&mut self, req: RequestId) -> u64;

    /// Cumulative blocks freed by preemption (recompute and swap-out).
    fn preemption_frees(&self) -> u64;

    /// Requests with live state in this manager (device or swap).
    fn live_requests(&self) -> usize;

    /// Allocator bookkeeping is self-consistent (property tests).
    fn check_invariants(&self) -> bool;

    // ---- provided: derived views ------------------------------------

    /// Device blocks in use.
    fn used_blocks(&self) -> u64 {
        self.total_blocks() - self.free_blocks()
    }

    /// Blocks needed for `tokens` KV tokens.
    fn blocks_for_tokens(&self, tokens: u32) -> u64 {
        (tokens as u64).div_ceil(self.block_size().max(1) as u64)
    }

    /// Device utilization in `[0, 1]` (1.0 for an empty pool).
    fn utilization(&self) -> f64 {
        if self.total_blocks() == 0 {
            return 1.0;
        }
        self.used_blocks() as f64 / self.total_blocks() as f64
    }

    /// Usage at the requested granularity (paper §III-B: "by block,
    /// token, or byte").
    fn used(&self, g: Granularity) -> u64 {
        match g {
            Granularity::Block => self.used_blocks(),
            Granularity::Token => self.used_blocks() * self.block_size() as u64,
            Granularity::Byte => self.used_blocks() * self.block_bytes(),
        }
    }

    /// Capacity at the requested granularity.
    fn capacity(&self, g: Granularity) -> u64 {
        match g {
            Granularity::Block => self.total_blocks(),
            Granularity::Token => self.total_blocks() * self.block_size() as u64,
            Granularity::Byte => self.total_blocks() * self.block_bytes(),
        }
    }

    /// The native accounting granularity of this manager.
    fn granularity(&self) -> Granularity {
        Granularity::Block
    }

    /// [`can_admit_with_pending`](Self::can_admit_with_pending) with no
    /// pending promises.
    fn can_admit(&self, tokens: u32) -> bool {
        self.can_admit_with_pending(tokens, 0)
    }

    /// Bulk-step decode growth headroom: the largest `j <= max_steps`
    /// such that growing **every** member from its current context
    /// `ctx` to `ctx + j` tokens fits in the free pool — i.e. how many
    /// consecutive single-token decode iterations the whole batch can
    /// take before an allocation would fail and force a preemption.
    ///
    /// This is the memory-exhaustion boundary of the cluster driver's
    /// decode fast-forward: the driver coalesces at most this many
    /// iterations and replaces the per-iteration `reserve(req, ctx+1)`
    /// growth calls with one bulk [`reserve`](Self::reserve) to the
    /// final size, which is state-identical because reservations are
    /// delta-based. Growth ignores admission caps and watermarks by
    /// design (exactly like the per-iteration path, which goes through
    /// raw `reserve`, not `can_admit`).
    ///
    /// `members` pairs each running request with its current KV context
    /// in tokens. The caller guarantees every member already holds a
    /// reservation covering `ctx + 1` (its in-flight iteration), so the
    /// answer is at least 1 whenever `max_steps >= 1`. Managers that
    /// pre-pay the final footprint (`token_contiguous`) need no blocks
    /// for growth and report `max_steps` unchanged.
    ///
    /// # Examples
    ///
    /// ```
    /// use tokensim::memory::{AllocOutcome, MemoryManager, PagedBlockManager};
    ///
    /// // 8 blocks of 16 tokens; one request holding 2 blocks (17 tokens
    /// // reserved for its in-flight iteration)
    /// let mut mem = PagedBlockManager::with_blocks(8, 16, 1024);
    /// assert_eq!(mem.reserve(0, 17), AllocOutcome::Ok);
    /// // 6 free blocks = 96 more tokens once the current block fills:
    /// // ctx 16 can grow to 16 + j while ceil((16+j)/16) - 2 <= 6
    /// assert_eq!(mem.decode_growth_headroom(&[(0, 16)], 1_000), 112);
    /// // bounded by the caller's own limit
    /// assert_eq!(mem.decode_growth_headroom(&[(0, 16)], 5), 5);
    /// ```
    fn decode_growth_headroom(&self, members: &[(RequestId, u32)], max_steps: u32) -> u32 {
        if max_steps <= 1 {
            return max_steps;
        }
        let fits = |j: u32| -> bool {
            let mut delta = 0u64;
            for &(req, ctx) in members {
                delta += self
                    .blocks_for_tokens(ctx.saturating_add(j))
                    .saturating_sub(self.blocks_held(req));
            }
            delta <= self.free_blocks()
        };
        if fits(max_steps) {
            return max_steps;
        }
        // fits is monotone decreasing in j and fits(1) holds (the
        // caller already reserved ctx + 1): bisect for the largest
        // feasible step count
        let (mut lo, mut hi) = (1u32, max_steps);
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            if fits(mid) {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// Tokens to reserve when admitting request `r`. Paged managers
    /// reserve the (effective) prompt and grow per token; contiguous
    /// managers over-reserve the final footprint up front.
    fn admission_tokens(&self, r: &Request) -> u32 {
        r.effective_prompt_len()
    }

    // ---- provided: swap capability (inert by default) ----------------

    /// Move the device KV of `req` to host swap space, freeing its
    /// device blocks. Returns the blocks swapped out, or `None` when the
    /// manager has no swap space (or it is full) — callers fall back to
    /// recompute preemption.
    fn swap_out(&mut self, _req: RequestId) -> Option<u64> {
        None
    }

    /// Bring `req` back from swap space, reserving device blocks for
    /// `tokens` total KV tokens. `OutOfMemory` leaves the host copy
    /// intact for a later retry.
    fn swap_in(&mut self, _req: RequestId, _tokens: u32) -> AllocOutcome {
        AllocOutcome::OutOfMemory
    }

    /// Drop the host copy of a swapped-out request (it will be
    /// recomputed instead). Returns the swap blocks freed.
    fn discard_swapped(&mut self, _req: RequestId) -> u64 {
        0
    }

    /// Host swap blocks currently held by `req` (0 when not swapped).
    fn swapped_blocks(&self, _req: RequestId) -> u64 {
        0
    }

    /// The host↔device link swap traffic is charged through.
    fn swap_link(&self) -> Option<&LinkSpec> {
        None
    }

    /// Cumulative swap traffic.
    fn swap_stats(&self) -> SwapStats {
        SwapStats::default()
    }

    // ---- provided: prefix-cache capability (inert by default) --------

    /// Does this manager carry a cross-request prefix-cache layer? The
    /// cluster driver anchors *conversation affinity* on this: when a
    /// finished round stores KV in a worker-local layer, follow-up
    /// rounds are routed back to that worker instead of through the
    /// global dispatch policy — on any other worker the guaranteed hit
    /// would silently become a miss.
    fn has_prefix_layer(&self) -> bool {
        false
    }

    /// Look up the cached KV prefix of `conv` for a round whose prompt
    /// is `prompt_len` tokens (layered cross-request cache managers).
    fn prefix_lookup(&mut self, _conv: ConversationId, _prompt_len: u32) -> Option<PoolHit> {
        None
    }

    /// Store the finished context of `conv` (`tokens` KV tokens) in the
    /// cache layer.
    fn prefix_store(&mut self, _conv: ConversationId, _tokens: u32) {}

    /// Drop `conv` from the cache layer (conversation ended).
    fn prefix_invalidate(&mut self, _conv: ConversationId) {}

    /// Seconds to fetch `blocks` cached blocks into device memory.
    fn prefix_fetch_time(&self, _blocks: u64) -> f64 {
        0.0
    }

    /// Cumulative prefix-cache activity.
    fn pool_stats(&self) -> PoolStats {
        PoolStats::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::PagedBlockManager;

    #[test]
    fn derived_views_consistent_through_trait_object() {
        let mut paged = PagedBlockManager::with_blocks(10, 16, 1024);
        let mem: &mut dyn MemoryManager = &mut paged;
        assert_eq!(mem.reserve(1, 32), AllocOutcome::Ok);
        assert_eq!(mem.used(Granularity::Block), 2);
        assert_eq!(mem.used(Granularity::Token), 32);
        assert_eq!(mem.used(Granularity::Byte), 2 * 1024);
        assert_eq!(mem.capacity(Granularity::Token), 160);
        assert!((mem.utilization() - 0.2).abs() < 1e-12);
        // inert defaults: no swap, no prefix cache
        assert!(mem.swap_out(1).is_none());
        assert_eq!(mem.swap_in(1, 32), AllocOutcome::OutOfMemory);
        assert!(!mem.has_prefix_layer());
        assert!(mem.prefix_lookup(0, 100).is_none());
        assert_eq!(mem.swap_stats(), SwapStats::default());
        assert_eq!(mem.pool_stats(), PoolStats::default());
    }

    #[test]
    fn default_preemption_is_recompute() {
        assert_eq!(PreemptionPolicy::default(), PreemptionPolicy::Recompute);
    }

    #[test]
    fn growth_headroom_matches_step_by_step_reservation() {
        // the bulk answer must equal what per-iteration reserve calls
        // would discover the slow way, for a mixed-context batch
        let mk = || {
            let mut m = PagedBlockManager::with_blocks(12, 16, 1024);
            assert_eq!(m.reserve(0, 40), AllocOutcome::Ok); // 3 blocks, ctx 39
            assert_eq!(m.reserve(1, 18), AllocOutcome::Ok); // 2 blocks, ctx 17
            m
        };
        let members = [(0usize, 39u32), (1usize, 17u32)];
        let bulk = mk().decode_growth_headroom(&members, 10_000);
        // replay: grow every member one token per step until a step fails
        let mut m = mk();
        let mut steps = 0u32;
        'outer: loop {
            for &(req, ctx) in &members {
                if m.reserve(req, ctx + steps + 2) == AllocOutcome::OutOfMemory {
                    break 'outer;
                }
            }
            steps += 1;
        }
        assert_eq!(bulk, steps + 1, "bulk counts the already-reserved step");
        assert!(bulk > 1);
        // caller bound wins when smaller; degenerate bounds echo back
        assert_eq!(mk().decode_growth_headroom(&members, 3), 3);
        assert_eq!(mk().decode_growth_headroom(&members, 1), 1);
        assert_eq!(mk().decode_growth_headroom(&members, 0), 0);
    }
}
