//! Memory management as a pluggable subsystem: the [`MemoryManager`]
//! trait, a string-keyed [registry](crate::memory::registry) selecting
//! managers by name from YAML or code, and the built-in plugins —
//! `paged` (PagedAttention blocks), `token_contiguous`
//! (Orca/FasterTransformer max-length reservation), `swap` (paged +
//! host swap space over the host↔device link) and `prefix_cache`
//! (paged layered over the MemServe/CachedAttention-style
//! cross-request memory pool).
//!
//! Mirrors the paper's §III-B: "TokenSim implements memory managers for
//! various worker types … to monitor memory utilization at any
//! granularity — by block, token, or byte — supporting user-defined
//! scheduler behaviors." Preemption (recompute vs swap) is a config
//! knob ([`PreemptionPolicy`]), orthogonal to the manager choice.

mod contiguous;
mod manager;
mod paged;
mod pool_cache;
mod prefix;
pub mod registry;
mod swap;

pub use contiguous::TokenContiguousManager;
pub use manager::{MemoryManager, PoolStats, PreemptionPolicy, SwapStats};
pub use paged::{AllocOutcome, PagedBlockManager};
pub use pool_cache::{PoolCache, PoolHit};
pub use prefix::PrefixCacheManager;
pub use registry::{
    build_memory, memory_managers, register_memory, MemoryCtx, MemoryEntry, MemorySpec,
    MEMORY_MANAGERS,
};
pub use swap::SwapMemoryManager;


/// Accounting granularity for utilization reports (the paper exposes
/// block / token / byte granularity to user-defined schedulers).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Granularity {
    #[default]
    Block,
    Token,
    Byte,
}

/// Configuration of a worker's KV memory manager.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryConfig {
    /// Tokens per KV block (vLLM default: 16).
    pub block_size: u32,
    /// Fraction of post-weights device memory given to the KV pool
    /// (vLLM's `gpu_memory_utilization`).
    pub gpu_utilization: f64,
    /// Admission cap: new requests are only scheduled while
    /// `used/total <= max_mem_ratio` (Fig 10's "Max Mem Ratio").
    pub max_mem_ratio: f64,
    /// Low-watermark fraction reserved for decode growth.
    pub watermark: f64,
}

impl Default for MemoryConfig {
    fn default() -> Self {
        Self {
            block_size: 16,
            gpu_utilization: 0.9,
            max_mem_ratio: 1.0,
            watermark: 0.01,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_vllm_conventions() {
        let c = MemoryConfig::default();
        assert_eq!(c.block_size, 16);
        assert!((c.gpu_utilization - 0.9).abs() < 1e-9);
        assert_eq!(c.max_mem_ratio, 1.0);
    }

    #[test]
    fn granularity_default_is_block() {
        assert_eq!(Granularity::default(), Granularity::Block);
    }
}
