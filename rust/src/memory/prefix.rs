//! Paged memory manager layered over the cross-request KV pool
//! (CachedAttention / MemServe): the `prefix_cache` registry plugin.
//!
//! Composes the `paged` device allocator with the existing
//! [`PoolCache`] so the Fig 14 memory-cache study is a *memory-manager
//! choice* (`memory: {manager: prefix_cache}`) rather than a cluster
//! special case. Finished conversation rounds store their context in
//! the pool; the next round's prompt prefix is fetched over the pool
//! fabric (800 ns/block in the paper's setting) instead of recomputed.
//!
//! The pool layer is **worker-local** (CachedAttention-style
//! per-instance caching): rounds only hit when the global scheduler
//! routes them to the worker that stored the context. Clusters that
//! want one shared pool across workers — in particular disaggregated
//! clusters, where prefills and finishes happen on different workers —
//! should use the cluster-level `pool_cache:` config section instead
//! (which, when present, takes precedence and keeps this layer inert).

use crate::hardware::LinkSpec;
use crate::model::ModelSpec;
use crate::network::{xfer_time_uniform, Schedule};
use crate::request::{ConversationId, RequestId};

use super::manager::{MemoryManager, PoolStats};
use super::paged::PagedBlockManager;
use super::pool_cache::{PoolCache, PoolHit};
use super::{AllocOutcome, MemoryConfig};

/// Paged device pool + LRU cross-request KV pool.
#[derive(Debug, Clone)]
pub struct PrefixCacheManager {
    device: PagedBlockManager,
    pool: PoolCache,
    link: LinkSpec,
}

impl PrefixCacheManager {
    /// Size the device pool like `paged`; the pool holds
    /// `capacity_blocks` KV blocks behind `link`.
    pub fn new(
        model: &ModelSpec,
        mem_cap_bytes: f64,
        cfg: MemoryConfig,
        capacity_blocks: u64,
        link: LinkSpec,
    ) -> Self {
        let block_size = cfg.block_size;
        Self {
            device: PagedBlockManager::new(model, mem_cap_bytes, cfg),
            pool: PoolCache::new(capacity_blocks, block_size),
            link,
        }
    }

    /// Construct with explicit block counts (tests / custom sizing).
    pub fn with_blocks(
        total_blocks: u64,
        block_size: u32,
        block_bytes: u64,
        pool_blocks: u64,
    ) -> Self {
        Self {
            device: PagedBlockManager::with_blocks(total_blocks, block_size, block_bytes),
            pool: PoolCache::new(pool_blocks, block_size),
            link: LinkSpec::pool_fabric(),
        }
    }

    /// The pool layer (diagnostics).
    pub fn pool(&self) -> &PoolCache {
        &self.pool
    }
}

impl MemoryManager for PrefixCacheManager {
    fn name(&self) -> &'static str {
        "prefix_cache"
    }

    fn block_size(&self) -> u32 {
        MemoryManager::block_size(&self.device)
    }

    fn block_bytes(&self) -> u64 {
        MemoryManager::block_bytes(&self.device)
    }

    fn total_blocks(&self) -> u64 {
        self.device.total_blocks()
    }

    fn free_blocks(&self) -> u64 {
        self.device.free_blocks()
    }

    fn blocks_held(&self, req: RequestId) -> u64 {
        self.device.blocks_held(req)
    }

    fn can_admit_with_pending(&self, tokens: u32, pending: u64) -> bool {
        self.device.can_admit_with_pending(tokens, pending)
    }

    fn reserve(&mut self, req: RequestId, tokens: u32) -> AllocOutcome {
        self.device.reserve(req, tokens)
    }

    fn release(&mut self, req: RequestId) -> u64 {
        self.device.release(req)
    }

    fn release_preempted(&mut self, req: RequestId) -> u64 {
        self.device.release_preempted(req)
    }

    fn preemption_frees(&self) -> u64 {
        self.device.preemption_frees
    }

    fn live_requests(&self) -> usize {
        self.device.live_requests()
    }

    fn check_invariants(&self) -> bool {
        self.device.check_invariants() && self.pool.check_invariants()
    }

    fn has_prefix_layer(&self) -> bool {
        true
    }

    fn prefix_lookup(&mut self, conv: ConversationId, prompt_len: u32) -> Option<PoolHit> {
        self.pool.lookup(conv, prompt_len)
    }

    fn prefix_store(&mut self, conv: ConversationId, tokens: u32) {
        self.pool.store(conv, tokens);
    }

    fn prefix_invalidate(&mut self, conv: ConversationId) {
        self.pool.invalidate(conv);
    }

    fn prefix_fetch_time(&self, blocks: u64) -> f64 {
        xfer_time_uniform(blocks, MemoryManager::block_bytes(&self.device), &self.link)
            .of(Schedule::Sequential)
    }

    fn pool_stats(&self) -> PoolStats {
        PoolStats {
            hits: self.pool.hits,
            misses: self.pool.misses,
            evictions: self.pool.evictions,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> PrefixCacheManager {
        PrefixCacheManager::with_blocks(1000, 16, 1024, 500)
    }

    #[test]
    fn lookup_store_roundtrip_through_the_manager() {
        let mut m = mgr();
        assert!(m.has_prefix_layer(), "affinity anchor for the driver");
        assert!(m.prefix_lookup(7, 100).is_none());
        m.prefix_store(7, 96);
        let hit = m.prefix_lookup(7, 200).unwrap();
        assert_eq!(hit.cached_tokens, 96);
        assert_eq!(hit.blocks, 6);
        let s = m.pool_stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        m.prefix_invalidate(7);
        assert!(m.prefix_lookup(7, 200).is_none());
        assert!(m.check_invariants());
    }

    #[test]
    fn fetch_time_matches_pool_fabric() {
        let m = mgr();
        // sequential: n * (latency + bytes/bw)
        let link = LinkSpec::pool_fabric();
        let expect = 6.0 * (link.latency + 1024.0 / link.bandwidth);
        assert!((m.prefix_fetch_time(6) - expect).abs() < 1e-12);
        assert_eq!(m.prefix_fetch_time(0), 0.0);
    }

    #[test]
    fn device_allocation_is_plain_paged() {
        let mut m = mgr();
        assert_eq!(m.reserve(1, 100), AllocOutcome::Ok);
        assert_eq!(m.blocks_held(1), 7);
        assert_eq!(m.release(1), 7);
        assert!(m.check_invariants());
    }

    #[test]
    fn sized_constructor_wires_pool_capacity() {
        let m = PrefixCacheManager::new(
            &ModelSpec::llama2_7b(),
            80e9,
            MemoryConfig::default(),
            2_000,
            LinkSpec::pool_fabric(),
        );
        assert!(m.total_blocks() > 0);
        assert!(m.pool().is_empty());
    }
}
