//! Paged KV-cache block manager (PagedAttention semantics).
//!
//! GPU memory after weights is split into fixed-size blocks of
//! `block_size` tokens; each live request owns `ceil(kv_tokens /
//! block_size)` blocks. The manager tracks allocation at block
//! granularity (and exposes token/byte views), enforces the
//! `gpu_utilization` pool sizing and the Fig-10 `max_mem_ratio`
//! admission cap, and supports preemption accounting.

use std::collections::HashMap;

use crate::model::ModelSpec;
use crate::request::RequestId;

use super::manager::MemoryManager;
use super::MemoryConfig;

/// Result of an allocation attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocOutcome {
    Ok,
    /// Not enough free blocks.
    OutOfMemory,
}

/// Block-granularity KV cache manager for one worker.
#[derive(Debug, Clone)]
pub struct PagedBlockManager {
    cfg: MemoryConfig,
    /// Total KV pool size in blocks.
    total_blocks: u64,
    free_blocks: u64,
    /// Blocks held per live request.
    held: HashMap<RequestId, u64>,
    /// Bytes of KV per block.
    block_bytes: u64,
    /// Tokens per block.
    block_size: u32,
    /// Cumulative preemption-driven frees (diagnostics).
    pub preemption_frees: u64,
}

impl PagedBlockManager {
    /// Size the pool for `model` on a device with `mem_cap` bytes.
    ///
    /// Pool blocks = (mem_cap * gpu_utilization - weights) / block_bytes,
    /// matching vLLM's profiling-based sizing.
    pub fn new(model: &ModelSpec, mem_cap_bytes: f64, cfg: MemoryConfig) -> Self {
        let block_bytes = model.kv_bytes_per_token() * cfg.block_size as u64;
        let weights = model.weight_bytes_per_shard() as f64;
        let budget = (mem_cap_bytes * cfg.gpu_utilization - weights).max(0.0);
        let total_blocks = (budget / block_bytes as f64).floor() as u64;
        Self {
            block_size: cfg.block_size,
            cfg,
            total_blocks,
            free_blocks: total_blocks,
            held: HashMap::new(),
            block_bytes,
            preemption_frees: 0,
        }
    }

    /// Construct with an explicit block count (tests / custom sizing).
    /// No watermark is applied — the caller sized the pool explicitly.
    pub fn with_blocks(total_blocks: u64, block_size: u32, block_bytes: u64) -> Self {
        Self {
            cfg: MemoryConfig {
                block_size,
                watermark: 0.0,
                ..Default::default()
            },
            total_blocks,
            free_blocks: total_blocks,
            held: HashMap::new(),
            block_bytes,
            block_size,
            preemption_frees: 0,
        }
    }

    #[inline]
    pub fn blocks_for_tokens(&self, tokens: u32) -> u64 {
        (tokens as u64).div_ceil(self.block_size as u64)
    }

    #[inline]
    pub fn total_blocks(&self) -> u64 {
        self.total_blocks
    }

    #[inline]
    pub fn free_blocks(&self) -> u64 {
        self.free_blocks
    }

    #[inline]
    pub fn used_blocks(&self) -> u64 {
        self.total_blocks - self.free_blocks
    }

    /// Utilization in [0, 1] at block granularity.
    #[inline]
    pub fn utilization(&self) -> f64 {
        if self.total_blocks == 0 {
            return 1.0;
        }
        self.used_blocks() as f64 / self.total_blocks as f64
    }

    /// Token-granularity view: tokens representable in used blocks.
    pub fn used_tokens(&self) -> u64 {
        self.used_blocks() * self.block_size as u64
    }

    /// Byte-granularity view.
    pub fn used_bytes(&self) -> u64 {
        self.used_blocks() * self.block_bytes
    }

    pub fn block_bytes(&self) -> u64 {
        self.block_bytes
    }

    pub fn blocks_held(&self, req: RequestId) -> u64 {
        self.held.get(&req).copied().unwrap_or(0)
    }

    /// Can a *new* request with `tokens` KV be admitted under the
    /// admission cap (`max_mem_ratio`) and watermark?
    pub fn can_admit(&self, tokens: u32) -> bool {
        self.can_admit_with_pending(tokens, 0)
    }

    /// [`Self::can_admit`] with `pending` blocks already promised to
    /// other admissions in the same batch-formation pass (the scheduler
    /// defers the actual reservations).
    pub fn can_admit_with_pending(&self, tokens: u32, pending: u64) -> bool {
        let need = self.blocks_for_tokens(tokens);
        let free = self.free_blocks.saturating_sub(pending);
        if need > free {
            return false;
        }
        let watermark_blocks = (self.total_blocks as f64 * self.cfg.watermark).ceil() as u64;
        if free - need < watermark_blocks {
            return false;
        }
        let used_after = self.used_blocks() + pending + need;
        used_after as f64 / self.total_blocks.max(1) as f64 <= self.cfg.max_mem_ratio
    }

    /// Reserve blocks so `req` can hold `tokens` total KV tokens.
    /// Growing an existing reservation only allocates the delta.
    pub fn reserve(&mut self, req: RequestId, tokens: u32) -> AllocOutcome {
        let need = self.blocks_for_tokens(tokens);
        let have = self.blocks_held(req);
        if need <= have {
            return AllocOutcome::Ok;
        }
        let delta = need - have;
        if delta > self.free_blocks {
            return AllocOutcome::OutOfMemory;
        }
        self.free_blocks -= delta;
        *self.held.entry(req).or_insert(0) = need;
        AllocOutcome::Ok
    }

    /// Grow a decode request by one token; allocates a block only at
    /// block boundaries. `current_tokens` is the KV size *after* the
    /// new token.
    pub fn grow_one_token(&mut self, req: RequestId, current_tokens: u32) -> AllocOutcome {
        self.reserve(req, current_tokens)
    }

    /// Release all blocks of `req` (finish or preemption).
    pub fn release(&mut self, req: RequestId) -> u64 {
        let blocks = self.held.remove(&req).unwrap_or(0);
        self.free_blocks += blocks;
        debug_assert!(self.free_blocks <= self.total_blocks);
        blocks
    }

    /// Release due to preemption (tracked separately for diagnostics).
    pub fn release_preempted(&mut self, req: RequestId) -> u64 {
        let blocks = self.release(req);
        self.preemption_frees += blocks;
        blocks
    }

    /// Live request count.
    pub fn live_requests(&self) -> usize {
        self.held.len()
    }

    /// Invariant check used by property tests.
    pub fn check_invariants(&self) -> bool {
        let held_sum: u64 = self.held.values().sum();
        held_sum + self.free_blocks == self.total_blocks
    }
}

/// The `paged` registry plugin is the manager itself: the trait surface
/// delegates to the inherent methods above.
impl MemoryManager for PagedBlockManager {
    fn name(&self) -> &'static str {
        "paged"
    }

    fn block_size(&self) -> u32 {
        self.block_size
    }

    fn block_bytes(&self) -> u64 {
        self.block_bytes
    }

    fn total_blocks(&self) -> u64 {
        self.total_blocks
    }

    fn free_blocks(&self) -> u64 {
        self.free_blocks
    }

    fn blocks_held(&self, req: RequestId) -> u64 {
        PagedBlockManager::blocks_held(self, req)
    }

    fn can_admit_with_pending(&self, tokens: u32, pending: u64) -> bool {
        PagedBlockManager::can_admit_with_pending(self, tokens, pending)
    }

    fn reserve(&mut self, req: RequestId, tokens: u32) -> AllocOutcome {
        PagedBlockManager::reserve(self, req, tokens)
    }

    fn release(&mut self, req: RequestId) -> u64 {
        PagedBlockManager::release(self, req)
    }

    fn release_preempted(&mut self, req: RequestId) -> u64 {
        PagedBlockManager::release_preempted(self, req)
    }

    fn preemption_frees(&self) -> u64 {
        self.preemption_frees
    }

    fn live_requests(&self) -> usize {
        self.held.len()
    }

    fn check_invariants(&self) -> bool {
        PagedBlockManager::check_invariants(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(blocks: u64) -> PagedBlockManager {
        PagedBlockManager::with_blocks(blocks, 16, 16 * 1024)
    }

    #[test]
    fn sizing_from_model_and_capacity() {
        let model = ModelSpec::llama2_7b();
        let cfg = MemoryConfig {
            gpu_utilization: 0.9,
            ..Default::default()
        };
        let m = PagedBlockManager::new(&model, 80e9, cfg);
        // (80e9*0.9 - 13.5e9) / (16 * 512KiB) ~ 6.9k blocks
        assert!((5000..9000).contains(&(m.total_blocks() as i64)), "{}", m.total_blocks());
    }

    #[test]
    fn weights_larger_than_memory_gives_empty_pool() {
        let model = ModelSpec::llama2_7b();
        let m = PagedBlockManager::new(&model, 10e9, MemoryConfig::default());
        assert_eq!(m.total_blocks(), 0);
        assert!(!m.can_admit(1));
    }

    #[test]
    fn reserve_and_release_roundtrip() {
        let mut m = mgr(100);
        assert_eq!(m.reserve(1, 100), AllocOutcome::Ok); // 7 blocks
        assert_eq!(m.blocks_held(1), 7);
        assert_eq!(m.free_blocks(), 93);
        assert_eq!(m.release(1), 7);
        assert_eq!(m.free_blocks(), 100);
        assert!(m.check_invariants());
    }

    #[test]
    fn growth_allocates_only_at_boundaries() {
        let mut m = mgr(100);
        m.reserve(1, 16);
        assert_eq!(m.blocks_held(1), 1);
        assert_eq!(m.grow_one_token(1, 17), AllocOutcome::Ok);
        assert_eq!(m.blocks_held(1), 2);
        assert_eq!(m.grow_one_token(1, 18), AllocOutcome::Ok);
        assert_eq!(m.blocks_held(1), 2, "within-block growth is free");
    }

    #[test]
    fn oom_on_exhaustion() {
        let mut m = mgr(4);
        assert_eq!(m.reserve(1, 64), AllocOutcome::Ok); // all 4 blocks
        assert_eq!(m.reserve(2, 1), AllocOutcome::OutOfMemory);
        assert!(m.check_invariants());
    }

    #[test]
    fn admission_cap_enforced() {
        let model = ModelSpec::tiny_test();
        let mut m = PagedBlockManager::with_blocks(100, 16, 1024);
        m.cfg.max_mem_ratio = 0.5;
        m.cfg.watermark = 0.0;
        assert!(m.can_admit(16 * 50)); // exactly 50 blocks = 0.5
        assert!(!m.can_admit(16 * 51));
        m.reserve(1, 16 * 40);
        assert!(m.can_admit(16 * 10));
        assert!(!m.can_admit(16 * 11));
        let _ = model;
    }

    #[test]
    fn watermark_reserves_headroom() {
        let mut m = mgr(100);
        m.cfg.watermark = 0.10;
        assert!(m.can_admit(16 * 90));
        assert!(!m.can_admit(16 * 91), "would dip under the watermark");
    }

    #[test]
    fn preemption_accounting() {
        let mut m = mgr(10);
        m.reserve(1, 160);
        assert_eq!(m.release_preempted(1), 10);
        assert_eq!(m.preemption_frees, 10);
    }

    #[test]
    fn utilization_views_consistent() {
        let mut m = mgr(10);
        m.reserve(1, 32); // 2 blocks
        assert!((m.utilization() - 0.2).abs() < 1e-12);
        assert_eq!(m.used_tokens(), 32);
        assert_eq!(m.used_bytes(), 2 * 16 * 1024);
    }
}
