//! Cross-request KV memory pool (CachedAttention / MemServe style).
//!
//! Stores the KV cache of finished conversation rounds in a shared pool
//! (host memory / fabric-attached) so that the next round's prompt
//! prefix can be *fetched* (at `LinkSpec::pool_fabric()`'s 800 ns/block,
//! the paper's Fig 14 setting) instead of recomputed. Eviction is LRU at
//! conversation granularity.

use std::collections::HashMap;

use crate::request::ConversationId;

/// Result of a pool lookup at a new round's arrival.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PoolHit {
    /// Tokens of prompt prefix whose KV is in the pool.
    pub cached_tokens: u32,
    /// Blocks to transfer from the pool.
    pub blocks: u64,
}

#[derive(Debug, Clone)]
struct Entry {
    tokens: u32,
    last_use: u64,
}

/// Shared KV pool keyed by conversation.
#[derive(Debug, Clone)]
pub struct PoolCache {
    /// Capacity in blocks (0 disables the pool entirely).
    capacity_blocks: u64,
    block_size: u32,
    used_blocks: u64,
    entries: HashMap<ConversationId, Entry>,
    clock: u64,
    // diagnostics
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl PoolCache {
    pub fn new(capacity_blocks: u64, block_size: u32) -> Self {
        Self {
            capacity_blocks,
            block_size,
            used_blocks: 0,
            entries: HashMap::new(),
            clock: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// A disabled pool (memory cache off).
    pub fn disabled() -> Self {
        Self::new(0, 16)
    }

    pub fn enabled(&self) -> bool {
        self.capacity_blocks > 0
    }

    fn blocks_for(&self, tokens: u32) -> u64 {
        (tokens as u64).div_ceil(self.block_size as u64)
    }

    /// Look up the cached context of `conv` for a round whose prompt is
    /// `prompt_len` tokens (history + new text). Returns the usable
    /// cached prefix (clamped to `prompt_len - 1` so at least one prompt
    /// token is always computed, which keeps prefill non-degenerate).
    pub fn lookup(&mut self, conv: ConversationId, prompt_len: u32) -> Option<PoolHit> {
        if !self.enabled() {
            return None;
        }
        self.clock += 1;
        let clock = self.clock;
        match self.entries.get_mut(&conv) {
            Some(e) => {
                e.last_use = clock;
                let cached = e.tokens.min(prompt_len.saturating_sub(1));
                if cached == 0 {
                    self.misses += 1;
                    return None;
                }
                self.hits += 1;
                Some(PoolHit {
                    cached_tokens: cached,
                    blocks: self.blocks_for(cached),
                })
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Store (replace) the KV of `conv` after a round finishes with
    /// `tokens` total context. Evicts LRU conversations as needed;
    /// contexts larger than the pool are not stored.
    pub fn store(&mut self, conv: ConversationId, tokens: u32) {
        if !self.enabled() {
            return;
        }
        let need = self.blocks_for(tokens);
        if need > self.capacity_blocks {
            return;
        }
        self.clock += 1;
        if let Some(old) = self.entries.remove(&conv) {
            self.used_blocks -= self.blocks_for(old.tokens);
        }
        while self.used_blocks + need > self.capacity_blocks {
            let lru = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_use)
                .map(|(&c, _)| c)
                .expect("pool over capacity but empty");
            let e = self.entries.remove(&lru).unwrap();
            self.used_blocks -= self.blocks_for(e.tokens);
            self.evictions += 1;
        }
        self.used_blocks += need;
        self.entries.insert(
            conv,
            Entry {
                tokens,
                last_use: self.clock,
            },
        );
    }

    /// Drop a conversation (e.g. it ended).
    pub fn invalidate(&mut self, conv: ConversationId) {
        if let Some(e) = self.entries.remove(&conv) {
            self.used_blocks -= self.blocks_for(e.tokens);
        }
    }

    pub fn used_blocks(&self) -> u64 {
        self.used_blocks
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Invariant for property tests: used == Σ per-entry blocks ≤ cap.
    pub fn check_invariants(&self) -> bool {
        let sum: u64 = self
            .entries
            .values()
            .map(|e| self.blocks_for(e.tokens))
            .sum();
        sum == self.used_blocks && self.used_blocks <= self.capacity_blocks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_hit() {
        let mut p = PoolCache::new(1000, 16);
        assert!(p.lookup(7, 100).is_none());
        p.store(7, 96);
        let hit = p.lookup(7, 200).unwrap();
        assert_eq!(hit.cached_tokens, 96);
        assert_eq!(hit.blocks, 6);
        assert_eq!((p.hits, p.misses), (1, 1));
    }

    #[test]
    fn cached_prefix_clamped_below_prompt() {
        let mut p = PoolCache::new(1000, 16);
        p.store(1, 500);
        // next round's prompt shorter than stored context (edge case)
        let hit = p.lookup(1, 100).unwrap();
        assert_eq!(hit.cached_tokens, 99, "must leave >=1 token to compute");
    }

    #[test]
    fn lru_eviction() {
        let mut p = PoolCache::new(10, 16); // 10 blocks
        p.store(1, 64); // 4 blocks
        p.store(2, 64); // 4 blocks
        p.lookup(1, 65); // touch 1 -> 2 becomes LRU
        p.store(3, 64); // needs 4, evicts 2
        assert!(p.lookup(2, 65).is_none());
        assert!(p.lookup(1, 65).is_some());
        assert_eq!(p.evictions, 1);
        assert!(p.check_invariants());
    }

    #[test]
    fn replace_same_conversation() {
        let mut p = PoolCache::new(100, 16);
        p.store(1, 160);
        p.store(1, 320);
        assert_eq!(p.used_blocks(), 20);
        assert_eq!(p.len(), 1);
        assert!(p.check_invariants());
    }

    #[test]
    fn oversized_context_not_stored() {
        let mut p = PoolCache::new(4, 16);
        p.store(1, 16 * 100);
        assert!(p.is_empty());
    }

    #[test]
    fn disabled_pool_is_inert() {
        let mut p = PoolCache::disabled();
        p.store(1, 64);
        assert!(p.lookup(1, 100).is_none());
        assert!(!p.enabled());
    }

    #[test]
    fn invalidate_frees_space() {
        let mut p = PoolCache::new(10, 16);
        p.store(1, 160);
        assert_eq!(p.used_blocks(), 10);
        p.invalidate(1);
        assert_eq!(p.used_blocks(), 0);
        assert!(p.check_invariants());
    }
}
