//! Paged memory manager with host swap space: preemption moves a
//! victim's KV cache to host DRAM over the host↔device link instead of
//! discarding it, and the victim later *swaps back in* with no
//! re-prefill (vLLM's `--swap-space` / the paper's swap-vs-recompute
//! axis).
//!
//! The transfer cost is charged by the cluster driver through this
//! manager's [`swap_link`](MemoryManager::swap_link) (default:
//! [`LinkSpec::host_bus`]), replacing the recompute policy's wasted
//! prefill FLOPs with host-link bytes.

use std::collections::HashMap;

use crate::hardware::LinkSpec;
use crate::model::ModelSpec;
use crate::request::RequestId;

use super::manager::{MemoryManager, SwapStats};
use super::paged::PagedBlockManager;
use super::{AllocOutcome, MemoryConfig};

/// Paged device pool + bounded host swap space.
#[derive(Debug, Clone)]
pub struct SwapMemoryManager {
    device: PagedBlockManager,
    /// Host swap capacity in blocks.
    swap_capacity: u64,
    /// Blocks parked in host memory, per swapped-out request.
    swapped: HashMap<RequestId, u64>,
    swap_used: u64,
    link: LinkSpec,
    stats: SwapStats,
}

impl SwapMemoryManager {
    /// Size the device pool like `paged`; `swap_blocks` bounds the host
    /// space (`None` = 4x the device pool, the vLLM-flavoured default).
    pub fn new(
        model: &ModelSpec,
        mem_cap_bytes: f64,
        cfg: MemoryConfig,
        swap_blocks: Option<u64>,
        link: LinkSpec,
    ) -> Self {
        let device = PagedBlockManager::new(model, mem_cap_bytes, cfg);
        let swap_capacity = swap_blocks.unwrap_or_else(|| device.total_blocks().saturating_mul(4));
        Self {
            device,
            swap_capacity,
            swapped: HashMap::new(),
            swap_used: 0,
            link,
            stats: SwapStats::default(),
        }
    }

    /// Construct with explicit block counts (tests / custom sizing).
    pub fn with_blocks(
        total_blocks: u64,
        block_size: u32,
        block_bytes: u64,
        swap_capacity: u64,
    ) -> Self {
        Self {
            device: PagedBlockManager::with_blocks(total_blocks, block_size, block_bytes),
            swap_capacity,
            swapped: HashMap::new(),
            swap_used: 0,
            link: LinkSpec::host_bus(),
            stats: SwapStats::default(),
        }
    }

    /// Host blocks currently parked in swap space.
    pub fn swap_space_used(&self) -> u64 {
        self.swap_used
    }

    /// Host swap capacity in blocks.
    pub fn swap_capacity(&self) -> u64 {
        self.swap_capacity
    }
}

impl MemoryManager for SwapMemoryManager {
    fn name(&self) -> &'static str {
        "swap"
    }

    fn block_size(&self) -> u32 {
        MemoryManager::block_size(&self.device)
    }

    fn block_bytes(&self) -> u64 {
        MemoryManager::block_bytes(&self.device)
    }

    fn total_blocks(&self) -> u64 {
        self.device.total_blocks()
    }

    fn free_blocks(&self) -> u64 {
        self.device.free_blocks()
    }

    fn blocks_held(&self, req: RequestId) -> u64 {
        self.device.blocks_held(req)
    }

    fn can_admit_with_pending(&self, tokens: u32, pending: u64) -> bool {
        self.device.can_admit_with_pending(tokens, pending)
    }

    fn reserve(&mut self, req: RequestId, tokens: u32) -> AllocOutcome {
        self.device.reserve(req, tokens)
    }

    fn release(&mut self, req: RequestId) -> u64 {
        // a finishing request cannot be swapped out, but clear any host
        // copy defensively so space never leaks
        if let Some(b) = self.swapped.remove(&req) {
            self.swap_used -= b;
        }
        self.device.release(req)
    }

    fn release_preempted(&mut self, req: RequestId) -> u64 {
        self.device.release_preempted(req)
    }

    fn preemption_frees(&self) -> u64 {
        self.device.preemption_frees
    }

    fn live_requests(&self) -> usize {
        self.device.live_requests() + self.swapped.len()
    }

    fn check_invariants(&self) -> bool {
        self.device.check_invariants()
            && self.swap_used == self.swapped.values().sum::<u64>()
            && self.swap_used <= self.swap_capacity
    }

    fn swap_out(&mut self, req: RequestId) -> Option<u64> {
        let blocks = self.device.blocks_held(req);
        if blocks == 0 || self.swap_used + blocks > self.swap_capacity {
            return None;
        }
        debug_assert!(!self.swapped.contains_key(&req), "double swap-out of {req}");
        self.device.release_preempted(req);
        self.swapped.insert(req, blocks);
        self.swap_used += blocks;
        self.stats.swap_outs += 1;
        self.stats.blocks_out += blocks;
        Some(blocks)
    }

    fn swap_in(&mut self, req: RequestId, tokens: u32) -> AllocOutcome {
        if !self.swapped.contains_key(&req) {
            return AllocOutcome::OutOfMemory;
        }
        match self.device.reserve(req, tokens) {
            AllocOutcome::Ok => {
                let blocks = self.swapped.remove(&req).expect("checked above");
                self.swap_used -= blocks;
                self.stats.swap_ins += 1;
                self.stats.blocks_in += blocks;
                AllocOutcome::Ok
            }
            oom => oom,
        }
    }

    fn discard_swapped(&mut self, req: RequestId) -> u64 {
        match self.swapped.remove(&req) {
            Some(b) => {
                self.swap_used -= b;
                b
            }
            None => 0,
        }
    }

    fn swapped_blocks(&self, req: RequestId) -> u64 {
        self.swapped.get(&req).copied().unwrap_or(0)
    }

    fn swap_link(&self) -> Option<&LinkSpec> {
        Some(&self.link)
    }

    fn swap_stats(&self) -> SwapStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(device: u64, swap: u64) -> SwapMemoryManager {
        SwapMemoryManager::with_blocks(device, 16, 1024, swap)
    }

    #[test]
    fn swap_roundtrip_preserves_blocks() {
        let mut m = mgr(10, 100);
        assert_eq!(m.reserve(1, 100), AllocOutcome::Ok); // 7 blocks
        let held = m.blocks_held(1);
        assert_eq!(m.swap_out(1), Some(held));
        assert_eq!(m.blocks_held(1), 0, "device blocks freed");
        assert_eq!(m.swap_space_used(), held);
        assert_eq!(m.free_blocks(), 10);
        assert_eq!(m.preemption_frees(), held, "swap-out is a preemption free");

        assert_eq!(m.swap_in(1, 101), AllocOutcome::Ok);
        assert_eq!(m.blocks_held(1), held, "101 tokens still fit 7 blocks");
        assert_eq!(m.swap_space_used(), 0);
        assert!(m.check_invariants());
        let s = m.swap_stats();
        assert_eq!((s.swap_outs, s.swap_ins), (1, 1));
        assert_eq!(s.blocks_out, s.blocks_in);
    }

    #[test]
    fn swap_space_capacity_bounds_swap_out() {
        let mut m = mgr(10, 5);
        m.reserve(1, 100); // 7 blocks > 5 swap capacity
        assert_eq!(m.swap_out(1), None, "no host space: fall back to recompute");
        assert_eq!(m.blocks_held(1), 7, "device state untouched");
        m.reserve(2, 32); // 2 blocks
        assert_eq!(m.swap_out(2), Some(2));
        assert!(m.check_invariants());
    }

    #[test]
    fn swap_in_oom_keeps_host_copy() {
        let mut m = mgr(10, 100);
        m.reserve(1, 160); // all 10 blocks
        assert_eq!(m.swap_out(1), Some(10));
        m.reserve(2, 160); // refill the device
        assert_eq!(m.swap_in(1, 161), AllocOutcome::OutOfMemory);
        assert_eq!(m.swapped_blocks(1), 10, "host copy intact for retry");
        m.release(2);
        assert_eq!(m.swap_in(1, 161), AllocOutcome::OutOfMemory, "161 tokens need 11 blocks");
        assert_eq!(m.swap_in(1, 160), AllocOutcome::Ok);
        assert!(m.check_invariants());
    }

    #[test]
    fn discard_swapped_frees_host_space() {
        let mut m = mgr(10, 100);
        m.reserve(1, 64);
        m.swap_out(1);
        assert_eq!(m.discard_swapped(1), 4);
        assert_eq!(m.swap_space_used(), 0);
        assert_eq!(m.discard_swapped(1), 0);
        assert!(m.check_invariants());
    }

    #[test]
    fn default_swap_capacity_is_4x_device() {
        let m = SwapMemoryManager::new(
            &ModelSpec::llama2_7b(),
            80e9,
            MemoryConfig::default(),
            None,
            LinkSpec::host_bus(),
        );
        assert_eq!(m.swap_capacity(), m.total_blocks() * 4);
        assert!(m.swap_link().is_some());
    }
}
