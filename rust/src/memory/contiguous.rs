//! Token-contiguous memory manager: the Orca / FasterTransformer
//! baseline that reserves each request's *maximum* KV footprint
//! (prompt + full output) contiguously at admission time.
//!
//! Accounting is at token granularity (1-token "blocks"). Because the
//! final footprint is reserved up front, decode growth never allocates
//! and running requests are never preempted — the cost is wasted
//! reservation for every token not yet generated, which is exactly the
//! fragmentation/utilization gap PagedAttention closes (compare with
//! `paged` via `tokensim exp memory`).

use crate::model::ModelSpec;
use crate::request::{Request, RequestId};

use super::manager::MemoryManager;
use super::paged::PagedBlockManager;
use super::{AllocOutcome, Granularity, MemoryConfig};

/// Contiguous max-length reservation at token granularity.
#[derive(Debug, Clone)]
pub struct TokenContiguousManager {
    /// Token-granularity pool: a block pool with 1-token blocks.
    inner: PagedBlockManager,
}

impl TokenContiguousManager {
    /// Size the pool for `model` on a device with `mem_cap_bytes`.
    /// The configured `block_size` is ignored — accounting is per token.
    pub fn new(model: &ModelSpec, mem_cap_bytes: f64, cfg: MemoryConfig) -> Self {
        let cfg = MemoryConfig {
            block_size: 1,
            ..cfg
        };
        Self {
            inner: PagedBlockManager::new(model, mem_cap_bytes, cfg),
        }
    }

    /// Construct with an explicit token capacity (tests / custom sizing).
    pub fn with_tokens(total_tokens: u64, token_bytes: u64) -> Self {
        Self {
            inner: PagedBlockManager::with_blocks(total_tokens, 1, token_bytes),
        }
    }
}

impl MemoryManager for TokenContiguousManager {
    fn name(&self) -> &'static str {
        "token_contiguous"
    }

    fn block_size(&self) -> u32 {
        1
    }

    fn block_bytes(&self) -> u64 {
        MemoryManager::block_bytes(&self.inner)
    }

    fn total_blocks(&self) -> u64 {
        self.inner.total_blocks()
    }

    fn free_blocks(&self) -> u64 {
        self.inner.free_blocks()
    }

    fn blocks_held(&self, req: RequestId) -> u64 {
        self.inner.blocks_held(req)
    }

    fn can_admit_with_pending(&self, tokens: u32, pending: u64) -> bool {
        self.inner.can_admit_with_pending(tokens, pending)
    }

    fn reserve(&mut self, req: RequestId, tokens: u32) -> AllocOutcome {
        self.inner.reserve(req, tokens)
    }

    fn release(&mut self, req: RequestId) -> u64 {
        self.inner.release(req)
    }

    fn release_preempted(&mut self, req: RequestId) -> u64 {
        self.inner.release_preempted(req)
    }

    fn preemption_frees(&self) -> u64 {
        self.inner.preemption_frees
    }

    fn live_requests(&self) -> usize {
        self.inner.live_requests()
    }

    fn check_invariants(&self) -> bool {
        self.inner.check_invariants()
    }

    fn granularity(&self) -> Granularity {
        Granularity::Token
    }

    /// The defining behaviour: admission reserves the *final* footprint
    /// (effective prompt + every output token still to generate), so
    /// decode growth is always pre-paid.
    fn admission_tokens(&self, r: &Request) -> u32 {
        r.effective_prompt_len() + (r.output_len - r.generated)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_granularity_accounting() {
        let mut m = TokenContiguousManager::with_tokens(1000, 64);
        assert_eq!(m.block_size(), 1);
        assert_eq!(m.blocks_for_tokens(100), 100);
        assert_eq!(m.reserve(1, 100), AllocOutcome::Ok);
        assert_eq!(m.used(Granularity::Token), 100);
        assert_eq!(m.used(Granularity::Byte), 100 * 64);
        assert_eq!(m.release(1), 100);
        assert!(m.check_invariants());
    }

    #[test]
    fn admission_covers_final_footprint() {
        let m = TokenContiguousManager::with_tokens(1000, 64);
        let r = Request::new(0, 0, 0, 100, 50, 0.0);
        assert_eq!(m.admission_tokens(&r), 150);
        // after a recompute preemption the generated tokens migrate into
        // the effective prompt but the total stays prompt + output
        let mut r = Request::new(1, 1, 0, 100, 50, 0.0);
        r.generated = 20;
        assert_eq!(m.admission_tokens(&r), 150);
    }

    #[test]
    fn growth_after_admission_is_free() {
        let mut m = TokenContiguousManager::with_tokens(1000, 64);
        let r = Request::new(0, 0, 0, 100, 50, 0.0);
        assert_eq!(m.reserve(0, m.admission_tokens(&r)), AllocOutcome::Ok);
        let before = m.free_blocks();
        // decode growth: reserve(ctx + 1) never exceeds the admission
        for ctx in 100..150 {
            assert_eq!(m.reserve(0, ctx + 1), AllocOutcome::Ok);
        }
        assert_eq!(m.free_blocks(), before, "growth must be pre-paid");
    }

    #[test]
    fn sizing_ignores_configured_block_size() {
        let model = ModelSpec::llama2_7b();
        let cfg = MemoryConfig {
            block_size: 16,
            ..Default::default()
        };
        let m = TokenContiguousManager::new(&model, 80e9, cfg);
        assert_eq!(m.block_size(), 1);
        // pool tokens ~ (80e9*0.9 - 13.5e9) / 512KiB ~ 111k
        assert!(m.total_blocks() > 50_000, "{}", m.total_blocks());
    }
}
