//! String-keyed memory-manager registry — the memory counterpart of
//! [`crate::scheduler::registry`].
//!
//! A manager is selected by name — from YAML (`memory: {manager: swap}`)
//! or programmatically via [`MemorySpec`] — and built from its parameter
//! map by a registered constructor. The cluster driver only ever sees
//! `Box<dyn MemoryManager>`, so adding an allocation policy never
//! touches `cluster/mod.rs`: implement the trait, then either add a
//! [`MemoryEntry`] to the built-in table or call [`register_memory`] at
//! startup.

use std::sync::{Mutex, OnceLock};

use anyhow::{bail, Context, Result};

use crate::config::yaml::Yaml;
use crate::hardware::LinkSpec;
use crate::model::ModelSpec;

use super::contiguous::TokenContiguousManager;
use super::manager::{MemoryManager, PreemptionPolicy};
use super::paged::PagedBlockManager;
use super::prefix::PrefixCacheManager;
use super::swap::SwapMemoryManager;
use super::MemoryConfig;

/// Sizing context a manager is built against: the served model (KV
/// bytes per token, weight footprint) and the device memory capacity.
pub struct MemoryCtx<'a> {
    pub model: &'a ModelSpec,
    pub mem_cap_bytes: f64,
}

/// A declarative, cloneable memory-manager selection: a registry name
/// plus a parameter map (the YAML subtree, or a programmatically built
/// map). This is what configs store — the built `Box<dyn MemoryManager>`
/// is neither cloneable nor comparable, and every worker needs its own
/// instance sized for its own hardware.
///
/// # Examples
///
/// ```
/// use tokensim::memory::MemorySpec;
/// use tokensim::model::ModelSpec;
///
/// let spec = MemorySpec::new("swap").with("swap_blocks", 10_000u64);
/// let mem = spec.build(&ModelSpec::llama2_7b(), 80e9).unwrap();
/// assert_eq!(mem.name(), "swap");
///
/// // unknown names are errors listing the known managers
/// assert!(MemorySpec::new("infinite").build(&ModelSpec::tiny_test(), 1e9).is_err());
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct MemorySpec {
    /// Registry name (case-insensitive; aliases accepted).
    pub name: String,
    /// Manager parameters (a [`Yaml::Map`]).
    pub params: Yaml,
}

impl Default for MemorySpec {
    /// The default manager: `paged` with vLLM-convention parameters.
    fn default() -> Self {
        Self::new("paged")
    }
}

impl MemorySpec {
    /// A spec with no parameters (registry defaults apply).
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            params: Yaml::Map(Default::default()),
        }
    }

    /// Builder-style parameter.
    pub fn with(mut self, key: &str, value: impl Into<Yaml>) -> Self {
        if let Yaml::Map(m) = &mut self.params {
            m.insert(key.to_string(), value.into());
        }
        self
    }

    /// Parse from a YAML map of the form `{manager: <name>, <params>…}`.
    /// A missing `manager` key selects `paged` (the pre-registry
    /// `memory:` sections keep working unchanged).
    pub fn from_yaml(y: &Yaml) -> Result<Self> {
        let name = match y.get("manager") {
            None => "paged".to_string(),
            Some(v) => v
                .as_str()
                .context("'manager' must be a string (a memory-manager name)")?
                .to_string(),
        };
        Ok(Self {
            name,
            params: y.clone(),
        })
    }

    /// Build the manager this spec names, sized for `model` on a device
    /// with `mem_cap_bytes` of memory.
    pub fn build(&self, model: &ModelSpec, mem_cap_bytes: f64) -> Result<Box<dyn MemoryManager>> {
        build_memory(self, &MemoryCtx { model, mem_cap_bytes })
    }

    /// Check the spec without sizing it for real hardware: unknown
    /// names, typo'd parameter keys and malformed values are errors at
    /// parse time, not mid-simulation.
    pub fn validate(&self) -> Result<()> {
        self.build(&ModelSpec::tiny_test(), 1e9).map(|_| ())?;
        self.preemption().map(|_| ())
    }

    /// The preemption policy this spec selects (`preemption: recompute`
    /// / `preemption: swap`). Defaults to swap for the `swap` manager
    /// (under any of its aliases) and recompute for everything else.
    pub fn preemption(&self) -> Result<PreemptionPolicy> {
        match self.params.get("preemption") {
            None => {
                // resolve aliases so `manager: paged_swap` also defaults
                // to swap preemption
                let is_swap = MEMORY_MANAGERS
                    .iter()
                    .find(|e| matches_name(&self.name, e.name, e.aliases))
                    .is_some_and(|e| e.name == "swap");
                Ok(if is_swap {
                    PreemptionPolicy::Swap
                } else {
                    PreemptionPolicy::Recompute
                })
            }
            Some(v) => match v.as_str() {
                Some("recompute") => Ok(PreemptionPolicy::Recompute),
                Some("swap") => Ok(PreemptionPolicy::Swap),
                Some(other) => {
                    bail!("unknown preemption policy '{other}' (known: recompute, swap)")
                }
                None => bail!("'preemption' must be a string (recompute or swap)"),
            },
        }
    }

    /// Tokens per KV block this spec configures (pool-cache sizing).
    pub fn block_size(&self) -> u32 {
        self.params.opt_u32("block_size", 16)
    }
}

/// A built-in memory manager: name, aliases, summary, parameter keys,
/// constructor.
pub struct MemoryEntry {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    /// One-line description (shown by `tokensim list`).
    pub summary: &'static str,
    /// Accepted parameter keys — anything else in the spec is an error
    /// (catches typo'd keys at parse time).
    pub params: &'static [&'static str],
    pub build: fn(&Yaml, &MemoryCtx) -> Result<Box<dyn MemoryManager>>,
}

// Strict optional accessors: a *missing* key takes the default, but a
// present-and-malformed value is an error rather than a silent default.

fn opt_u32_strict(p: &Yaml, key: &str, default: u32) -> Result<u32> {
    match p.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_u32()
            .with_context(|| format!("'{key}' must be a non-negative integer")),
    }
}

fn opt_u64_strict(p: &Yaml, key: &str, default: u64) -> Result<u64> {
    match p.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_u64()
            .with_context(|| format!("'{key}' must be a non-negative integer")),
    }
}

fn opt_f64_strict(p: &Yaml, key: &str, default: f64) -> Result<f64> {
    match p.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .with_context(|| format!("'{key}' must be a number")),
    }
}

fn common_config(p: &Yaml) -> Result<MemoryConfig> {
    let cfg = MemoryConfig {
        block_size: opt_u32_strict(p, "block_size", 16)?,
        gpu_utilization: opt_f64_strict(p, "gpu_utilization", 0.9)?,
        max_mem_ratio: opt_f64_strict(p, "max_mem_ratio", 1.0)?,
        watermark: opt_f64_strict(p, "watermark", 0.01)?,
    };
    if cfg.block_size == 0 {
        bail!("'block_size' must be >= 1");
    }
    Ok(cfg)
}

fn link_param(p: &Yaml, key: &str, default: LinkSpec) -> Result<LinkSpec> {
    match p.get(key) {
        None => Ok(default),
        Some(v) => {
            let name = v
                .as_str()
                .with_context(|| format!("'{key}' must be a link preset name"))?;
            LinkSpec::by_name(name).with_context(|| format!("unknown link preset '{name}'"))
        }
    }
}

fn build_paged(p: &Yaml, ctx: &MemoryCtx) -> Result<Box<dyn MemoryManager>> {
    Ok(Box::new(PagedBlockManager::new(
        ctx.model,
        ctx.mem_cap_bytes,
        common_config(p)?,
    )))
}

fn build_token_contiguous(p: &Yaml, ctx: &MemoryCtx) -> Result<Box<dyn MemoryManager>> {
    Ok(Box::new(TokenContiguousManager::new(
        ctx.model,
        ctx.mem_cap_bytes,
        common_config(p)?,
    )))
}

fn build_swap(p: &Yaml, ctx: &MemoryCtx) -> Result<Box<dyn MemoryManager>> {
    let swap_blocks = match p.get("swap_blocks") {
        None => None,
        Some(v) => Some(
            v.as_u64()
                .context("'swap_blocks' must be a non-negative integer")?,
        ),
    };
    Ok(Box::new(SwapMemoryManager::new(
        ctx.model,
        ctx.mem_cap_bytes,
        common_config(p)?,
        swap_blocks,
        link_param(p, "link", LinkSpec::host_bus())?,
    )))
}

fn build_prefix_cache(p: &Yaml, ctx: &MemoryCtx) -> Result<Box<dyn MemoryManager>> {
    Ok(Box::new(PrefixCacheManager::new(
        ctx.model,
        ctx.mem_cap_bytes,
        common_config(p)?,
        opt_u64_strict(p, "capacity_blocks", 1_000_000)?,
        link_param(p, "link", LinkSpec::pool_fabric())?,
    )))
}

/// Built-in memory managers.
pub const MEMORY_MANAGERS: &[MemoryEntry] = &[
    MemoryEntry {
        name: "paged",
        aliases: &["vllm", "paged_attention"],
        summary: "paged KV blocks (PagedAttention): reserve prompt, grow per token",
        params: &[
            "block_size",
            "gpu_utilization",
            "max_mem_ratio",
            "watermark",
            "preemption",
        ],
        build: build_paged,
    },
    MemoryEntry {
        name: "token_contiguous",
        aliases: &["contiguous", "orca"],
        summary: "Orca/FasterTransformer baseline: over-reserve to max length, token granularity",
        // block_size is accepted for config uniformity but ignored —
        // accounting is always per token
        params: &[
            "block_size",
            "gpu_utilization",
            "max_mem_ratio",
            "watermark",
            "preemption",
        ],
        build: build_token_contiguous,
    },
    MemoryEntry {
        name: "swap",
        aliases: &["paged_swap"],
        summary: "paged + host swap space; preemption moves KV over the host link",
        params: &[
            "block_size",
            "gpu_utilization",
            "max_mem_ratio",
            "watermark",
            "preemption",
            "swap_blocks",
            "link",
        ],
        build: build_swap,
    },
    MemoryEntry {
        name: "prefix_cache",
        aliases: &["pool_cache", "memserve"],
        summary: "paged layered over the cross-request KV pool (CachedAttention/MemServe)",
        params: &[
            "block_size",
            "gpu_utilization",
            "max_mem_ratio",
            "watermark",
            "preemption",
            "capacity_blocks",
            "link",
        ],
        build: build_prefix_cache,
    },
];

// ---------------------------------------------------------------------------
// Runtime registration (library users; built-ins live in the table)
// ---------------------------------------------------------------------------

struct DynMemoryEntry {
    name: String,
    summary: String,
    #[allow(clippy::type_complexity)]
    build: Box<dyn Fn(&Yaml, &MemoryCtx) -> Result<Box<dyn MemoryManager>> + Send + Sync>,
}

fn extra_memory() -> &'static Mutex<Vec<DynMemoryEntry>> {
    static EXTRA: OnceLock<Mutex<Vec<DynMemoryEntry>>> = OnceLock::new();
    EXTRA.get_or_init(|| Mutex::new(Vec::new()))
}

/// Register a memory manager at runtime. Registered names take
/// precedence over built-ins, so a library user can also shadow a
/// built-in manager.
///
/// # Examples
///
/// A "bring your own allocator" flow — here just a reparameterized
/// built-in, but any [`MemoryManager`] implementation works the same:
///
/// ```
/// use tokensim::memory::{register_memory, MemoryConfig, MemorySpec, PagedBlockManager};
/// use tokensim::model::ModelSpec;
///
/// register_memory("tiny_blocks", "paged with 4-token blocks (demo)", |_params, ctx| {
///     let cfg = MemoryConfig { block_size: 4, ..Default::default() };
///     Ok(Box::new(PagedBlockManager::new(ctx.model, ctx.mem_cap_bytes, cfg)))
/// });
///
/// let mem = MemorySpec::new("tiny_blocks")
///     .build(&ModelSpec::llama2_7b(), 80e9)
///     .unwrap();
/// assert_eq!(mem.block_size(), 4);
/// ```
pub fn register_memory(
    name: &str,
    summary: &str,
    build: impl Fn(&Yaml, &MemoryCtx) -> Result<Box<dyn MemoryManager>> + Send + Sync + 'static,
) {
    extra_memory().lock().unwrap().push(DynMemoryEntry {
        name: name.to_string(),
        summary: summary.to_string(),
        build: Box::new(build),
    });
}

fn matches_name(candidate: &str, name: &str, aliases: &[&str]) -> bool {
    candidate.eq_ignore_ascii_case(name)
        || aliases.iter().any(|a| candidate.eq_ignore_ascii_case(a))
}

/// Reject typo'd parameter keys for built-in managers ("manager" itself
/// is the selector key YAML specs carry). Runtime-registered managers
/// validate their own params in their builder.
fn check_param_keys(spec: &MemorySpec, known: &[&str]) -> Result<()> {
    if let Yaml::Map(m) = &spec.params {
        for key in m.keys() {
            if key != "manager" && !known.contains(&key.as_str()) {
                bail!(
                    "unknown parameter '{key}' for memory manager '{}' (accepted: {})",
                    spec.name,
                    known.join(", ")
                );
            }
        }
    }
    Ok(())
}

/// Build a memory manager from a spec. Unknown names list the known
/// managers in the error.
pub fn build_memory(spec: &MemorySpec, ctx: &MemoryCtx) -> Result<Box<dyn MemoryManager>> {
    {
        let extras = extra_memory().lock().unwrap();
        if let Some(e) = extras
            .iter()
            .rev()
            .find(|e| spec.name.eq_ignore_ascii_case(&e.name))
        {
            return (e.build)(&spec.params, ctx)
                .with_context(|| format!("building memory manager '{}'", spec.name));
        }
    }
    let entry = MEMORY_MANAGERS
        .iter()
        .find(|e| matches_name(&spec.name, e.name, e.aliases))
        .with_context(|| {
            format!(
                "unknown memory manager '{}' (known: {})",
                spec.name,
                memory_managers()
                    .iter()
                    .map(|(n, _, _)| n.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })?;
    check_param_keys(spec, entry.params)?;
    (entry.build)(&spec.params, ctx)
        .with_context(|| format!("building memory manager '{}'", spec.name))
}

/// All registered managers as `(name, summary, accepted-params)`,
/// built-ins first.
pub fn memory_managers() -> Vec<(String, String, String)> {
    let mut out: Vec<(String, String, String)> = MEMORY_MANAGERS
        .iter()
        .map(|e| {
            (
                e.name.to_string(),
                e.summary.to_string(),
                e.params.join(", "),
            )
        })
        .collect();
    for e in extra_memory().lock().unwrap().iter() {
        out.push((e.name.clone(), e.summary.clone(), "(manager-defined)".to_string()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ModelSpec {
        ModelSpec::llama2_7b()
    }

    #[test]
    fn builds_every_builtin_manager_with_defaults() {
        for e in MEMORY_MANAGERS {
            let mem = MemorySpec::new(e.name)
                .build(&model(), 80e9)
                .unwrap_or_else(|err| panic!("{}: {err:#}", e.name));
            assert_eq!(mem.name(), e.name);
            assert!(mem.total_blocks() > 0, "{}", e.name);
            assert!(mem.check_invariants(), "{}", e.name);
        }
    }

    #[test]
    fn aliases_and_case_resolve() {
        for (alias, canonical) in [
            ("PagedAttention", "paged"),
            ("Orca", "token_contiguous"),
            ("paged_swap", "swap"),
            ("MemServe", "prefix_cache"),
        ] {
            let mem = MemorySpec::new(alias).build(&model(), 80e9).unwrap();
            assert_eq!(mem.name(), canonical);
        }
    }

    #[test]
    fn unknown_manager_is_an_error_listing_known() {
        let err = MemorySpec::new("infinite").build(&model(), 80e9).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown memory manager"), "{msg}");
        assert!(msg.contains("token_contiguous"), "{msg}");
    }

    #[test]
    fn typod_or_malformed_params_are_errors() {
        let err = MemorySpec::new("paged")
            .with("block_sze", 16u32)
            .build(&model(), 80e9)
            .unwrap_err();
        assert!(format!("{err:#}").contains("unknown parameter 'block_sze'"));
        let err = MemorySpec::new("swap")
            .with("swap_blocks", "lots")
            .build(&model(), 80e9)
            .unwrap_err();
        assert!(format!("{err:#}").contains("swap_blocks"));
        // zero-token blocks would divide by zero downstream
        assert!(MemorySpec::new("paged")
            .with("block_size", 0u32)
            .build(&model(), 80e9)
            .is_err());
        // validate() catches the same without hardware sizing
        assert!(MemorySpec::new("paged").with("block_sze", 16u32).validate().is_err());
        assert!(MemorySpec::default().validate().is_ok());
    }

    #[test]
    fn preemption_policy_parses_with_manager_aware_default() {
        assert_eq!(
            MemorySpec::new("paged").preemption().unwrap(),
            PreemptionPolicy::Recompute
        );
        assert_eq!(
            MemorySpec::new("swap").preemption().unwrap(),
            PreemptionPolicy::Swap
        );
        assert_eq!(
            MemorySpec::new("paged_swap").preemption().unwrap(),
            PreemptionPolicy::Swap,
            "aliases get the same default"
        );
        assert_eq!(
            MemorySpec::new("swap")
                .with("preemption", "recompute")
                .preemption()
                .unwrap(),
            PreemptionPolicy::Recompute
        );
        assert_eq!(
            MemorySpec::new("paged")
                .with("preemption", "swap")
                .preemption()
                .unwrap(),
            PreemptionPolicy::Swap
        );
        assert!(MemorySpec::new("paged")
            .with("preemption", "pray")
            .preemption()
            .is_err());
    }

    #[test]
    fn from_yaml_defaults_to_paged() {
        let y = Yaml::parse("block_size: 32\ngpu_utilization: 0.8\n").unwrap();
        let spec = MemorySpec::from_yaml(&y).unwrap();
        assert_eq!(spec.name, "paged");
        assert_eq!(spec.block_size(), 32);
        assert!(spec.validate().is_ok());
        let y = Yaml::parse("manager: swap\nswap_blocks: 1000\n").unwrap();
        let spec = MemorySpec::from_yaml(&y).unwrap();
        assert_eq!(spec.name, "swap");
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn runtime_registration_shadows_builtins() {
        register_memory("test_shadow_paged", "test", build_paged);
        let mem = MemorySpec::new("test_shadow_paged")
            .build(&model(), 80e9)
            .unwrap();
        assert_eq!(mem.name(), "paged");
        assert!(memory_managers().iter().any(|(n, _, _)| n == "test_shadow_paged"));
    }

    #[test]
    fn common_params_flow_to_the_pool() {
        let mem = MemorySpec::new("paged")
            .with("gpu_utilization", 0.5)
            .build(&model(), 80e9)
            .unwrap();
        let full = MemorySpec::new("paged").build(&model(), 80e9).unwrap();
        assert!(mem.total_blocks() < full.total_blocks());
    }
}
