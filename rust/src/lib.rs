//! # TokenSim
//!
//! A hardware/software exploration simulator for large-language-model
//! inference systems — a rust + JAX + Pallas reproduction of
//! *TokenSim: Enabling Hardware and Software Exploration for Large
//! Language Model Inference Systems* (CS.DC 2025).
//!
//! TokenSim simulates a *serving system*, not a single batch: dynamic
//! request arrivals sampled from dataset-fitted distributions, two-stage
//! (global + per-worker local) scheduling, pluggable compute cost
//! models (HLO artifacts / extracted tables / analytic mirror /
//! roofline / oracle / Vidur-like / LLMServingSim-like, per-worker
//! selectable for heterogeneous clusters), pluggable KV-cache memory
//! management (paged / contiguous / host-swap / cross-request prefix
//! cache, with recompute or swap preemption), pluggable workload
//! generators (synthetic / trace replay / bursty / multi-tenant /
//! long-context), pluggable network topologies for KV movement (flat /
//! NVLink islands / fat-tree / shared ethernet, with per-link
//! bandwidth contention), and QoS metrics (latency percentiles / CDFs,
//! TTFT / mTPOT SLO attainment, per-tenant breakdowns, memory
//! timelines).
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the discrete-event coordinator: engine,
//!   schedulers, memory managers, workload generation, metrics, CLI.
//! * **L2 (JAX, build-time)** — the per-iteration compute cost model,
//!   AOT-lowered to `artifacts/*.hlo.txt` by `python/compile/aot.py`.
//! * **L1 (Pallas, build-time)** — the vectorized roofline / attention
//!   descriptor kernels inside the L2 computation.
//!
//! The rust binary loads the HLO artifacts through the PJRT C API
//! ([`runtime`]) and evaluates them on the simulation hot path; Python
//! never runs at simulation time. A bit-compatible analytic mirror
//! ([`compute::AnalyticCost`]) is cross-validated against the artifacts
//! and serves as a fallback when artifacts are absent.
//!
//! ## Quickstart
//!
//! ```no_run
//! use tokensim::prelude::*;
//!
//! let model = ModelSpec::llama2_7b();
//! let hw = HardwareSpec::a100_80g();
//! let workload = WorkloadSpec::sharegpt(2000, 30.0);
//! let cfg = SimulationConfig::single_worker(model, hw, workload);
//! let report = Simulation::from_config(&cfg)
//!     .expect("valid config")
//!     .run()
//!     .expect("workload must complete");
//! println!("p99 latency = {:.3}s", report.latency_percentile(0.99));
//! ```

pub mod baselines;
pub mod cluster;
pub mod compute;
pub mod config;
pub mod experiments;
pub mod hardware;
pub mod lint;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod network;
pub mod oracle;
pub mod request;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod util;
pub mod workload;

/// Convenient re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::cluster::{Simulation, SimulationReport, WorkerRole};
    pub use crate::compute::{
        AnalyticCost, BatchDesc, ComputeCtx, ComputeModel, ComputeSpec, CostModelKind,
        RooflineCost,
    };
    pub use crate::config::{ClusterConfig, PoolCacheConfig, SchedulerConfig, SimulationConfig, WorkerConfig};
    pub use crate::hardware::{HardwareSpec, LinkSpec};
    pub use crate::memory::{
        MemoryConfig, MemoryManager, MemorySpec, PagedBlockManager, PreemptionPolicy,
    };
    pub use crate::metrics::{RequestRecord, SloSpec};
    pub use crate::model::ModelSpec;
    pub use crate::network::{NetworkModel, NetworkSpec};
    pub use crate::scheduler::{GlobalScheduler, LocalScheduler, PolicySpec};
    pub use crate::sim::SimTime;
    pub use crate::workload::{
        LengthDistribution, WorkloadGenerator, WorkloadSpec, WorkloadSpecV2,
    };
}
