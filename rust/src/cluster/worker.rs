//! Per-worker state: hardware, queues, memory, cost model.

use std::collections::VecDeque;

use crate::compute::ComputeModel;
use crate::hardware::HardwareSpec;
use crate::memory::{MemoryManager, PreemptionPolicy};
use crate::request::{Request, RequestId};
use crate::scheduler::{BatchPlan, LocalScheduler, WorkerView};
use crate::sim::SimTime;

/// Worker role in a (possibly disaggregated) cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerRole {
    Unified,
    PrefillOnly,
    DecodeOnly,
}

/// One accelerator running an inference engine instance.
pub struct Worker {
    pub id: usize,
    pub hw: HardwareSpec,
    pub run_prefill: bool,
    pub run_decode: bool,
    /// The worker's local scheduling policy (each worker owns its own
    /// instance — policies may keep cross-iteration state).
    pub local: Box<dyn LocalScheduler>,
    /// The worker's KV memory manager, selected through the memory
    /// registry (each worker owns its own instance, sized for its
    /// hardware).
    pub mem: Box<dyn MemoryManager>,
    /// Preemption mechanism the local scheduler applies when KV blocks
    /// run out (recompute vs swap-out).
    pub preemption: PreemptionPolicy,
    pub cost: Box<dyn ComputeModel>,

    pub waiting: VecDeque<RequestId>,
    pub running: Vec<RequestId>,
    /// Transferred-in requests parked until KV blocks free up.
    pub pending_kv: VecDeque<RequestId>,
    pub busy: bool,
    pub current: Option<BatchPlan>,
    /// Enqueue time of the request at the head of the wait queue — the
    /// oldest waiter for FIFO-ordered queues (static batching, the only
    /// consumer, never preempts so its queue is pure FIFO). Re-anchored
    /// after every batch formation so linger deadlines are measured
    /// from a request that is still waiting.
    pub oldest_wait: Option<SimTime>,
    /// A linger-deadline kick is already scheduled.
    pub linger_armed: bool,

    // ---- statistics ----
    pub iterations: u64,
    pub busy_time: f64,
    /// Decode windows coalesced by fast-forwarding (window length > 1).
    /// Engine-mode dependent: kept out of the byte-diffed JSON report.
    pub ff_windows: u64,
    /// Coalesced windows costed by the closed-form affine series
    /// (`engine: window_cost: affine`) instead of per-iteration replay.
    pub affine_windows: u64,
    /// Cost-model calls the affine series avoided (window iterations
    /// minus the three real calls that fit and verify the series).
    pub window_calls_saved: u64,
}

impl Worker {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        hw: HardwareSpec,
        run_prefill: bool,
        run_decode: bool,
        local: Box<dyn LocalScheduler>,
        mem: Box<dyn MemoryManager>,
        preemption: PreemptionPolicy,
        cost: Box<dyn ComputeModel>,
    ) -> Self {
        assert!(run_prefill || run_decode, "worker with no role");
        Self {
            id,
            hw,
            run_prefill,
            run_decode,
            local,
            mem,
            preemption,
            cost,
            waiting: VecDeque::new(),
            running: Vec::new(),
            pending_kv: VecDeque::new(),
            busy: false,
            current: None,
            oldest_wait: None,
            linger_armed: false,
            iterations: 0,
            busy_time: 0.0,
            ff_windows: 0,
            affine_windows: 0,
            window_calls_saved: 0,
        }
    }

    pub fn role(&self) -> WorkerRole {
        match (self.run_prefill, self.run_decode) {
            (true, true) => WorkerRole::Unified,
            (true, false) => WorkerRole::PrefillOnly,
            (false, true) => WorkerRole::DecodeOnly,
            (false, false) => unreachable!("checked at construction"),
        }
    }

    /// Remove a batch of requests from the running set in **one**
    /// order-preserving pass. Departures cluster at iteration
    /// boundaries (completions, disaggregation hand-offs), and the old
    /// per-request `Vec::retain` made each boundary O(departures ×
    /// running) — a measured hot spot at million-request scale. Order
    /// must be preserved: running order is batch-slot order, which
    /// feeds the cost model and preemption victim selection.
    pub fn remove_running(&mut self, gone: &[RequestId]) {
        match gone.len() {
            0 => {}
            // the common single-departure case needs no membership scan
            1 => self.running.retain(|&rid| rid != gone[0]),
            // a handful of departures: linear probes beat hashing
            2..=8 => self.running.retain(|rid| !gone.contains(rid)),
            // bulk departures (static batches draining whole cohorts):
            // hash the gone-set so the pass stays O(running), not
            // O(departures x running)
            _ => {
                let set: std::collections::HashSet<RequestId> = gone.iter().copied().collect();
                self.running.retain(|rid| !set.contains(rid));
            }
        }
    }

    /// Audit-mode drain check ([`crate::lint::AUDIT_CHECKS`] A002): at
    /// the end of a fully-finished run this worker must hold no queued
    /// or running work, and its allocator must be self-consistent and —
    /// absent a prefix-cache layer, which legitimately retains
    /// conversation KV — empty.
    pub fn audit_drained(&self) -> Result<(), String> {
        if !self.waiting.is_empty() || !self.running.is_empty() || !self.pending_kv.is_empty() {
            return Err(format!(
                "worker {}: drained with waiting={:?} running={:?} pending_kv={:?}",
                self.id, self.waiting, self.running, self.pending_kv
            ));
        }
        if self.busy || self.current.is_some() {
            return Err(format!(
                "worker {}: drained while an iteration is in flight",
                self.id
            ));
        }
        if !self.mem.check_invariants() {
            return Err(format!(
                "worker {}: manager '{}' failed its invariant check at drain",
                self.id,
                self.mem.name()
            ));
        }
        if !self.mem.has_prefix_layer()
            && (self.mem.live_requests() != 0 || self.mem.used_blocks() != 0)
        {
            return Err(format!(
                "worker {}: manager '{}' drained with {} live requests and {} blocks in use",
                self.id,
                self.mem.name(),
                self.mem.live_requests(),
                self.mem.used_blocks()
            ));
        }
        Ok(())
    }

    /// Read-only view for the global scheduler.
    pub fn view(&self, requests: &[Request]) -> WorkerView {
        let queued_tokens: u64 = self
            .waiting
            .iter()
            .map(|&rid| requests[rid].effective_prompt_len() as u64)
            .sum();
        let live_tokens: u64 = self
            .running
            .iter()
            .map(|&rid| requests[rid].live_kv_tokens() as u64)
            .sum();
        WorkerView {
            id: self.id,
            hardware: self.hw.name.clone(),
            run_prefill: self.run_prefill,
            run_decode: self.run_decode,
            waiting_requests: self.waiting.len(),
            running_requests: self.running.len(),
            outstanding_tokens: queued_tokens + live_tokens,
            free_blocks: self.mem.free_blocks(),
            total_blocks: self.mem.total_blocks(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::AnalyticCost;
    use crate::memory::PagedBlockManager;
    use crate::model::ModelSpec;

    fn worker(prefill: bool, decode: bool) -> Worker {
        let hw = HardwareSpec::a100_80g();
        let model = ModelSpec::tiny_test();
        Worker::new(
            0,
            hw.clone(),
            prefill,
            decode,
            Box::new(crate::scheduler::ContinuousBatching::vllm_default()),
            Box::new(PagedBlockManager::with_blocks(100, 16, 1024)),
            PreemptionPolicy::Recompute,
            Box::new(AnalyticCost::new(&model, &hw)),
        )
    }

    #[test]
    fn roles() {
        assert_eq!(worker(true, true).role(), WorkerRole::Unified);
        assert_eq!(worker(true, false).role(), WorkerRole::PrefillOnly);
        assert_eq!(worker(false, true).role(), WorkerRole::DecodeOnly);
    }

    #[test]
    #[should_panic(expected = "worker with no role")]
    fn no_role_rejected() {
        worker(false, false);
    }

    #[test]
    fn remove_running_is_order_preserving() {
        let mut w = worker(true, true);
        w.running = vec![4, 1, 7, 3, 9, 2];
        w.remove_running(&[]);
        assert_eq!(w.running, vec![4, 1, 7, 3, 9, 2]);
        w.remove_running(&[7]);
        assert_eq!(w.running, vec![4, 1, 3, 9, 2]);
        w.remove_running(&[9, 4, 55]);
        assert_eq!(w.running, vec![1, 3, 2], "survivors keep batch order");
        // the hashed bulk path behaves identically
        w.running = (0..40).collect();
        let gone: Vec<RequestId> = (0..40).filter(|r| r % 3 == 0).collect();
        w.remove_running(&gone);
        assert_eq!(w.running, (0..40).filter(|r| r % 3 != 0).collect::<Vec<_>>());
    }

    #[test]
    fn audit_drained_flags_leftover_work() {
        let mut w = worker(true, true);
        assert_eq!(w.audit_drained(), Ok(()));
        w.waiting.push_back(3);
        let msg = w.audit_drained().unwrap_err();
        assert!(msg.contains("waiting=[3]"), "{msg}");
        w.waiting.clear();
        w.busy = true;
        let msg = w.audit_drained().unwrap_err();
        assert!(msg.contains("in flight"), "{msg}");
    }

    #[test]
    fn view_aggregates_tokens() {
        let mut w = worker(true, true);
        let mut requests = vec![
            Request::new(0, 0, 0, 100, 10, 0.0),
            Request::new(1, 1, 0, 50, 10, 0.0),
        ];
        requests[1].ctx_in_cache = 30;
        w.waiting.push_back(0);
        w.running.push(1);
        let v = w.view(&requests);
        assert_eq!(v.waiting_requests, 1);
        assert_eq!(v.running_requests, 1);
        assert_eq!(v.outstanding_tokens, 100 + 30);
    }
}
